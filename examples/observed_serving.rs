//! Serving with the observability layer on: `egpu::obs`.
//!
//! The same serving runtime as `examples/serving_runtime.rs`, but with
//! the event recorder attached. The recorder stamps every request's
//! lifecycle (admitted → batched → dispatched → exec → retired, or
//! shed) and every core loan in **modeled bus cycles**, so the
//! exported Chrome trace and the occupancy report are pure functions
//! of the model: byte-identical across sequential and parallel
//! dispatch, and bit-identical to a run with recording off. This
//! example proves both claims inline, then writes the trace next to
//! the binary for chrome://tracing / Perfetto.
//!
//!     cargo run --release --example observed_serving
//!
//! The trace lands in `observed_serving_trace.json`.

use egpu::api::Server;
use egpu::harness::loadgen::{demo_requests, LoadSpec};
use egpu::harness::Table;
use egpu::obs::EventKind;

fn trace_spec(server: &Server) -> LoadSpec {
    LoadSpec {
        seed: 0x0B5E,
        requests: 40,
        mean_gap: 2_000,
        dim: 64,
        deadline_slack: Some(server.us_to_cycles(120)),
    }
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // The demo fleet behind a recording server. `.recording(true)` is
    // the only difference from an unobserved server.
    let mut server = Server::builder().qdepth(48).max_batch(8).recording(true).build()?;
    let requests = demo_requests(&trace_spec(&server));
    let offered = requests.len();
    let report = server.serve(requests)?;
    let t = &report.telemetry;
    assert!(t.completed > 0 && t.batches > 1);

    // Claim 1: the recorder observed, it did not participate. A second
    // server with recording off models the exact same serving run.
    let mut unobserved = Server::builder().qdepth(48).max_batch(8).build()?;
    let baseline = unobserved.serve(demo_requests(&trace_spec(&unobserved)))?;
    assert_eq!(report, baseline, "recording must not move a modeled cycle");

    // Claim 2: the exported artifacts are byte-identical under
    // sequential dispatch — no wall clock, no thread ids.
    let recorder = server.recorder().expect("recording server has a recorder");
    let mut seq = Server::builder()
        .qdepth(48)
        .max_batch(8)
        .recording(true)
        .sequential(true)
        .build()?;
    seq.serve(demo_requests(&trace_spec(&seq)))?;
    let seq_rec = seq.recorder().unwrap();
    assert_eq!(recorder.chrome_trace(), seq_rec.chrome_trace());
    assert_eq!(
        recorder.occupancy_report(server.num_cores()),
        seq_rec.occupancy_report(seq.num_cores())
    );

    // The span stream, summarized per lifecycle stage.
    let events = recorder.events();
    let count = |label: &str| events.iter().filter(|e| e.kind.label() == label).count();
    let mut spans = Table::new(format!(
        "Observed serving: {offered} offered, {} served, {} shed, {} events recorded",
        t.completed,
        t.shed,
        events.len()
    ));
    spans.headers(["lifecycle event", "count"]);
    for label in ["admitted", "batched", "dispatched", "exec_start", "exec_end", "retired", "shed"]
    {
        spans.row([label.to_string(), count(label).to_string()]);
    }
    spans.print();

    // Accounting closes: every offered request retired or shed.
    assert_eq!(count("retired") + count("shed"), offered);
    assert_eq!(count("exec_start"), count("exec_end"));

    // Exec spans carry the report's own modeled timeline.
    for r in &report.results {
        assert!(events.iter().any(|e| {
            e.cycle == r.end
                && matches!(&e.kind, EventKind::ExecEnd { req, .. } if *req == r.id)
        }));
    }

    // The unified registry view: runtime gauges + serve counters,
    // including the shed-reason breakdown the telemetry total hides.
    let metrics = server.metrics();
    println!(
        "\nregistry: {} kernel compiles, {} machine-reuse hits, shed {} queue-full / {} expired",
        metrics.gauge("cache.kernel.compiles"),
        metrics.gauge("reuse.machine.hits"),
        metrics.counter("serve.shed.queue_full"),
        metrics.counter("serve.shed.deadline_expired"),
    );

    // The per-core occupancy/gap summary (`egpu serve --report`).
    println!("\n{}", recorder.occupancy_report(server.num_cores()));

    // And the Chrome trace itself (`egpu serve --trace-out`).
    let path = "observed_serving_trace.json";
    std::fs::write(path, recorder.chrome_trace())?;
    println!("trace: {} events -> {path} (open in chrome://tracing)", recorder.len());
    Ok(())
}
