//! Serving a mixed kernel batch on a *heterogeneous* fleet.
//!
//! The paper's static-scalability headline is that many differently
//! configured eGPU instances coexist on one fabric (Tables 4/5), each
//! closing timing at its own embedded limit — 771 MHz for DP-memory
//! instances, 600 MHz for QP (§6). This example deploys that story:
//! a 2×DP + 2×QP fleet behind one data bus, serving a batch of mixed
//! kernels. The dispatcher
//!
//! - extracts each job's `FeatureSet` requirement from its program
//!   (predicates, dot core, thread space) and routes it only to cores
//!   that satisfy it — the bitonic sort and DOT reduction never land on
//!   the plain QP cores,
//! - converts cycle estimates to wall-clock through the per-core clock
//!   model, so a free 771 MHz core outbids a free 600 MHz core,
//! - compiles each kernel once per `(generator, dim, config
//!   fingerprint)` through the shared `KernelCache`, however many jobs
//!   replay it.
//!
//!     cargo run --release --example fleet_serving

use egpu::api::{FleetBuilder, KernelCache};
use egpu::harness::{demo_job_io, demo_specs, Rng, Table};
use egpu::kernels::reduction;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // Two fully-featured DP cores (predicates + dot core), two plain
    // QP cores — a fleet only the heterogeneous coordinator can model
    // (the same reference mix `egpu fleet` and the perf bench use).
    let cache = KernelCache::shared();
    let mut fleet = FleetBuilder::demo_mixed().kernel_cache(cache.clone()).build()?;

    // A batch of mixed work (the shared demo wiring): reductions, FFTs
    // and a transpose (any core), sorts and DOT reductions (DP-only
    // features).
    let n = 64usize;
    let mut rng = Rng::new(0x5E11);
    let specs = demo_specs(n);
    let jobs = 12usize;
    let mut submitted = Vec::new();
    for j in 0..jobs {
        let spec = specs[j % specs.len()];
        let (loads, unloads) = demo_job_io(&spec, &mut rng);
        let mut launch = fleet.launch_spec_any(spec)?;
        for (base, data) in &loads {
            launch = launch.input_words(*base, data.clone());
        }
        for &(base, len) in &unloads {
            launch = launch.output(base, len);
        }
        launch.submit();
        submitted.push(loads);
    }
    let reports = fleet.sync()?;

    // Placement: feature-aware and wall-clock-aware.
    let mut t = Table::new(format!(
        "Placement — {jobs} jobs over {} cores, bus at {:.0} MHz",
        fleet.num_cores(),
        fleet.coordinator().bus_mhz()
    ));
    t.headers(["job", "core", "config", "cycles", "time(us)", "requires"]);
    for r in &reports {
        let mhz = fleet.coordinator().core_mhz(r.core);
        t.row([
            r.name.clone(),
            r.core.to_string(),
            fleet.core_configs()[r.core].name.clone(),
            r.compute_cycles.to_string(),
            format!("{:.2}", r.compute_cycles as f64 / mhz),
            r.requires.to_string(),
        ]);
    }
    t.print();

    // Feature routing holds, and the results are right (oracles over
    // each job's own input block).
    for (r, loads) in reports.iter().zip(&submitted) {
        let cfg = &fleet.core_configs()[r.core];
        assert!(cfg.satisfies(&r.requires), "{} misrouted", r.name);
        if r.name.starts_with("bitonic") {
            assert!(cfg.predicate_levels > 0);
            let mut want = loads[0].1.clone();
            want.sort_unstable();
            assert_eq!(r.output_words(0), &want[..], "sort output");
        }
        if r.name.starts_with("reduction") {
            let input: Vec<f32> = loads[0].1.iter().map(|&b| f32::from_bits(b)).collect();
            let want = reduction::oracle(&input);
            let got = f32::from_bits(r.output_words(0)[0]);
            assert!((got - want).abs() < want.abs() * 1e-3 + 1e-2, "{got} vs {want}");
        }
    }
    // The bitonic/dot jobs all sit on DP cores.
    let dp_only: Vec<_> = reports
        .iter()
        .filter(|r| r.requires.predicate_depth > 0 || r.requires.dot_core)
        .map(|r| r.core)
        .collect();
    assert!(dp_only.iter().all(|&c| c < 2), "feature routing: {dp_only:?}");

    // Utilization + cache economics.
    let util = fleet.core_utilization();
    println!();
    let mut t = Table::new("Per-core utilization");
    t.headers(["core", "config", "MHz", "jobs", "util"]);
    for c in 0..fleet.num_cores() {
        t.row([
            c.to_string(),
            fleet.core_configs()[c].name.clone(),
            format!("{:.0}", fleet.coordinator().core_mhz(c)),
            reports.iter().filter(|r| r.core == c).count().to_string(),
            format!("{:.1}%", util[c] * 100.0),
        ]);
    }
    t.print();

    let stats = cache.stats();
    println!(
        "\nkernel cache: {} compiles for {} launches ({} hits) — one compile \
         per (kernel, dim, config fingerprint)",
        stats.compiles, jobs, stats.hits
    );
    let span_us = fleet.makespan_us();
    println!(
        "makespan {span_us:.2} us → {:.0} modeled jobs/s on the mixed fleet",
        jobs as f64 / (span_us * 1e-6)
    );
    Ok(())
}
