//! Vector reduction — the paper's showcase for dynamic thread-space
//! scaling (§3.1): the reduction tree narrows the machine level by level
//! (full SIMT → quarter depth → 4-SP CPU → single-thread MCU), and the
//! optional dot-product extension core replaces the whole tree with one
//! SUM instruction.
//!
//! Runs the tree kernel and the DOT kernel on the same data, on both the
//! native datapath and (if `make artifacts` has been run) the AOT-compiled
//! XLA datapath through PJRT, comparing cycles against the paper's
//! Table 7.
//!
//!     cargo run --release --example vector_reduction

use egpu::datapath::xla::XlaDatapath;
use egpu::harness::{paper_cycles, suite, Table};
use egpu::kernels::{f32_bits, reduction};
use egpu::runtime::default_artifacts_dir;
use egpu::sim::{EgpuConfig, Machine, MemoryMode};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut table = Table::new("Vector reduction: measured vs paper (Table 7)");
    table.headers(["n", "variant", "cycles", "paper", "time(us)", "result"]);

    for n in [32usize, 64, 128] {
        let data: Vec<f32> = (0..n).map(|i| (i as f32) * 0.25 - 3.0).collect();
        let want: f32 = data.iter().sum();

        for (kernel, dot, variant) in [
            (reduction::reduction(n), false, suite::Variant::Dp),
            (reduction::reduction_dot(n), true, suite::Variant::Dot),
        ] {
            let cfg = EgpuConfig::benchmark(MemoryMode::Dp, dot);
            let (stats, m) = kernel.run(&cfg, &[(0, f32_bits(&data))])?;
            let got = f32::from_bits(m.shared().read(n as u32).unwrap());
            assert!((got - want).abs() < want.abs() * 1e-4 + 1e-2);
            table.row([
                n.to_string(),
                variant.label().to_string(),
                stats.cycles.to_string(),
                paper_cycles(suite::Benchmark::Reduction, n, variant)
                    .map(|c| c.to_string())
                    .unwrap_or_default(),
                format!("{:.2}", stats.time_us(cfg.core_mhz())),
                format!("{got:.2}"),
            ]);
        }
    }
    table.print();

    // The same kernel through the AOT-compiled JAX/Pallas datapath: every
    // wavefront ALU/DOT op executes in the PJRT-loaded HLO executable.
    let dir = default_artifacts_dir();
    if dir.join("opmap.json").is_file() {
        let n = 64;
        let data: Vec<f32> = (0..n).map(|i| (i as f32) * 0.125 - 2.0).collect();
        let cfg = EgpuConfig::benchmark(MemoryMode::Dp, true);
        let kernel = reduction::reduction_dot(n);
        let prog = kernel.assemble(&cfg).map_err(std::io::Error::other)?;

        let be = XlaDatapath::new(&dir, cfg.wavefronts()).map_err(std::io::Error::other)?;
        let mut m = Machine::with_backend(cfg.clone(), Some(Box::new(be)))
            .map_err(std::io::Error::other)?;
        m.load_program(prog)?;
        m.set_threads(kernel.threads)?;
        m.shared_mut().write_block(0, &f32_bits(&data));
        let stats = m.run(1_000_000)?;
        let got = f32::from_bits(m.shared().read(n as u32).unwrap());
        let want: f32 = data.iter().sum();
        println!(
            "\nXLA datapath (PJRT, artifacts/): reduction-dot-{n} -> {got:.3} \
             (expect {want:.3}), {} cycles — identical to native",
            stats.cycles
        );
        assert!((got - want).abs() < want.abs() * 1e-4 + 1e-2);
    } else {
        println!("\n(artifacts not built; run `make artifacts` to exercise the XLA datapath)");
    }
    Ok(())
}
