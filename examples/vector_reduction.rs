//! Vector reduction — the paper's showcase for dynamic thread-space
//! scaling (§3.1): the reduction tree narrows the machine level by level
//! (full SIMT → quarter depth → 4-SP CPU → single-thread MCU), and the
//! optional dot-product extension core replaces the whole tree with one
//! SUM instruction.
//!
//! Runs the tree kernel and the DOT kernel on the same data through
//! `Gpu::launch`, on both the native datapath and (if `make artifacts`
//! has been run) the AOT-compiled XLA datapath through PJRT, comparing
//! cycles against the paper's Table 7.
//!
//!     cargo run --release --example vector_reduction

use egpu::api::{Backend, Gpu};
use egpu::harness::{paper_cycles, suite, Table};
use egpu::kernels::reduction;
use egpu::runtime::default_artifacts_dir;
use egpu::sim::{EgpuConfig, MemoryMode};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut table = Table::new("Vector reduction: measured vs paper (Table 7)");
    table.headers(["n", "variant", "cycles", "paper", "time(us)", "result"]);

    for n in [32usize, 64, 128] {
        let data: Vec<f32> = (0..n).map(|i| (i as f32) * 0.25 - 3.0).collect();
        let want: f32 = data.iter().sum();

        for (kernel, dot, variant) in [
            (reduction::reduction(n), false, suite::Variant::Dp),
            (reduction::reduction_dot(n), true, suite::Variant::Dot),
        ] {
            let cfg = EgpuConfig::benchmark(MemoryMode::Dp, dot);
            let mut gpu = Gpu::new(&cfg)?;
            let input = gpu.alloc_at::<f32>(0, n)?;
            let sum = gpu.alloc_at::<f32>(n, 1)?;
            gpu.upload(&input, &data)?;
            let report = gpu.launch(&kernel).run()?;
            let got = gpu.download(&sum)?[0];
            assert!((got - want).abs() < want.abs() * 1e-4 + 1e-2);
            table.row([
                n.to_string(),
                variant.label().to_string(),
                report.compute_cycles.to_string(),
                paper_cycles(suite::Benchmark::Reduction, n, variant)
                    .map(|c| c.to_string())
                    .unwrap_or_default(),
                format!("{:.2}", report.time_us(cfg.core_mhz())),
                format!("{got:.2}"),
            ]);
        }
    }
    table.print();

    // The same kernel through the AOT-compiled JAX/Pallas datapath: every
    // wavefront ALU/DOT op executes in the PJRT-loaded HLO executable —
    // the only change is the builder's backend.
    let dir = default_artifacts_dir();
    if dir.join("opmap.json").is_file() {
        let n = 64;
        let data: Vec<f32> = (0..n).map(|i| (i as f32) * 0.125 - 2.0).collect();
        let cfg = EgpuConfig::benchmark(MemoryMode::Dp, true);
        let mut gpu = Gpu::builder()
            .config(cfg)
            .backend(Backend::Xla(dir))
            .build()
            .map_err(std::io::Error::other)?;
        let input = gpu.alloc_at::<f32>(0, n)?;
        let sum = gpu.alloc_at::<f32>(n, 1)?;
        gpu.upload(&input, &data)?;
        let report = gpu.launch(&reduction::reduction_dot(n)).run()?;
        let got = gpu.download(&sum)?[0];
        let want: f32 = data.iter().sum();
        println!(
            "\nXLA datapath (PJRT, artifacts/): reduction-dot-{n} -> {got:.3} \
             (expect {want:.3}), {} cycles — identical to native",
            report.compute_cycles
        );
        assert!((got - want).abs() < want.abs() * 1e-4 + 1e-2);
    } else {
        println!("\n(artifacts not built; run `make artifacts` to exercise the XLA datapath)");
    }
    Ok(())
}
