//! Quickstart: configure an eGPU, assemble a small program, run it, and
//! inspect the result — the five-minute tour of the public API.
//!
//!     cargo run --release --example quickstart

use egpu::asm::assemble;
use egpu::sim::{EgpuConfig, Machine, MemoryMode};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. Static scalability: pick the configuration at "compile time"
    //    (paper §3). This is the base machine: 512 threads on 16 SPs,
    //    32 registers/thread, 32 KB shared memory, full 32-bit ALU.
    let mut cfg = EgpuConfig::default();
    cfg.memory = MemoryMode::Dp; // 4R/1W shared-memory ports, 771 MHz
    println!(
        "eGPU '{}': {} threads ({} wavefronts), {} regs/thread, {} KB shared @ {} MHz",
        cfg.name,
        cfg.threads,
        cfg.wavefronts(),
        cfg.regs_per_thread,
        cfg.shared_kb,
        cfg.core_mhz()
    );

    // 2. Write a kernel in eGPU assembly. This one squares each element
    //    of a 512-word vector, then uses *dynamic* scalability (§3.1) to
    //    collapse the machine to a single-thread MCU and write a flag —
    //    no dead cycles between the personalities.
    let src = "
        tdx r0               ; r0 = thread id (one element per thread)
        lod r1, (r0)+0       ; x = shared[tid]
        fmul r2, r1, r1      ; x^2        (runs on all 32 wavefronts)
        sto r2, (r0)+512     ; shared[512 + tid] = x^2
        [w1,d0] ldi r3, #1   ; MCU personality: single thread only
        nop                  ; cover the 1-wavefront RAW window
        nop
        nop
        nop
        nop
        [w1,d0] sto r3, (r3)+1023   ; done-flag at shared[1024]
        stop
    ";
    let prog = assemble(src, cfg.word_layout())?;
    println!("assembled {} instructions", prog.len());

    // 3. Build the machine, load data, run.
    let mut m = Machine::new(cfg.clone())?;
    m.load_program(prog)?;
    for i in 0..512u32 {
        m.shared_mut().write(i, (i as f32 * 0.5).to_bits())?;
    }
    let stats = m.run(1_000_000)?;

    // 4. Inspect results.
    let x100 = f32::from_bits(m.shared().read(100).unwrap());
    let y100 = f32::from_bits(m.shared().read(512 + 100).unwrap());
    println!(
        "shared[100] = {x100}, squared -> {y100} (expect {})",
        x100 * x100
    );
    assert_eq!(y100, x100 * x100);
    assert_eq!(m.shared().read(1024).unwrap(), 1);

    println!(
        "ran in {} cycles = {:.3} us at {} MHz ({} would-be hazards)",
        stats.cycles,
        stats.time_us(cfg.core_mhz()),
        cfg.core_mhz(),
        stats.hazards
    );
    println!("\ninstruction mix:\n{}", stats.profile.render());
    Ok(())
}
