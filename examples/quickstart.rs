//! Quickstart: build a `Gpu`, launch a kernel, read back typed buffers —
//! the five-minute tour of the `egpu::api` runtime.
//!
//!     cargo run --release --example quickstart

use egpu::api::Gpu;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // Static scalability (§3) on the builder; dynamic scalability (§3.1)
    // is in the kernel itself: square 512 elements SIMT-wide, then
    // collapse to a single-thread MCU and write a done-flag.
    let mut gpu = Gpu::builder().threads(512).shared_kb(32).build()?;
    let src = "
        tdx r0               ; r0 = thread id (one element per thread)
        lod r1, (r0)+0       ; x = shared[tid]
        fmul r2, r1, r1      ; x^2        (runs on all 32 wavefronts)
        sto r2, (r0)+512     ; shared[512 + tid] = x^2
        [w1,d0] ldi r3, #1   ; MCU personality: single thread only
        nop                  ; cover the 1-wavefront RAW window
        nop
        nop
        nop
        nop
        [w1,d0] sto r3, (r3)+1023   ; done-flag at shared[1024]
        stop
    ";

    // Typed device buffers; transfers are accounted on the 32-bit bus.
    let xs: Vec<f32> = (0..512).map(|i| i as f32 * 0.5).collect();
    let input = gpu.alloc_at::<f32>(0, 512)?;
    let squares = gpu.alloc_at::<f32>(512, 512)?;
    let flag = gpu.alloc_at::<u32>(1024, 1)?;
    gpu.upload(&input, &xs)?;

    let report = gpu.launch_asm("square", src).run()?;

    let ys = gpu.download(&squares)?;
    assert_eq!(ys[100], xs[100] * xs[100]);
    assert_eq!(gpu.download(&flag)?[0], 1);
    println!(
        "'{}': squared 512 elements in {} cycles = {:.3} us at {} MHz \
         ({} hazards, {:.1}% bus overhead)",
        gpu.config().name,
        report.compute_cycles,
        report.time_us(gpu.config().core_mhz()),
        gpu.config().core_mhz(),
        report.stats.hazards,
        100.0 * gpu.bus_overhead()
    );
    println!("\ninstruction mix:\n{}", report.stats.profile.render());
    Ok(())
}
