//! Workload-driven fleet synthesis: `egpu::synth`.
//!
//! The other fleet examples run hand-picked configurations; this one
//! lets the machine pick. Given an Agilex area budget (ALMs / DSPs /
//! M20Ks) and a seeded heavy-tail traffic trace, `synthesize` walks
//! the paper's static-scalability axes, keeps the candidates that fit
//! the budget *and* place into a sector, and beam-searches fleet
//! compositions by replaying the trace through the serving runtime —
//! the objective is SLO-met requests in modeled bus cycles, so the
//! result is deterministic: re-running this example reproduces the
//! same fleet bit-for-bit. The winner is emitted as the same fleet
//! JSON `egpu serve --configs` consumes.
//!
//!     cargo run --release --example fleet_synthesis

use egpu::api::{synthesize, AreaBudget, SynthOptions};
use egpu::harness::loadgen::{heavy_tail_requests, BurstSpec};
use egpu::harness::Table;
use egpu::model::resources::ResourceReport;
use egpu::sim::config_json;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // Roughly two and a half sectors of logic with matching embedded
    // columns — enough for the demo fleet plus headroom, so the search
    // has real choices.
    let budget = AreaBudget::demo();

    // Bursty arrivals over mixed kernel dims {32, 64, 128}: the
    // traffic shape that actually differentiates fleet compositions.
    let trace = heavy_tail_requests(&BurstSpec::demo(24));

    let result = synthesize(&budget, &trace, &SynthOptions::default())?;

    if !result.rejected.is_empty() {
        println!("rejected candidates (with the feasibility filter's reasons):");
        for r in &result.rejected {
            println!("  {} — {}", r.name, r.reason);
        }
        println!();
    }

    let mut t = Table::new(format!(
        "Synthesized fleet under {budget} — {} of {} requests SLO-met",
        result.score.slo_met, result.offered
    ));
    t.headers(["core", "config", "MHz", "ALMs", "DSPs", "M20Ks"]);
    for (c, cfg) in result.fleet.iter().enumerate() {
        let r = ResourceReport::for_config(cfg);
        t.row([
            c.to_string(),
            cfg.name.clone(),
            format!("{:.0}", cfg.core_mhz()),
            r.alms.to_string(),
            r.dsps.to_string(),
            r.m20ks.to_string(),
        ]);
    }
    t.print();
    println!(
        "used {} of {budget} — cost {} ALM-equivalents, {} fleets scored",
        result.usage, result.score.cost, result.evaluated
    );

    // The fleet must dominate both homogeneous demo baselines on the
    // same trace — that is the point of searching.
    println!("\nversus the homogeneous demo-fleet baselines:");
    for b in &result.baselines {
        let note = b.note.as_deref().unwrap_or("served");
        println!(
            "  {:>2} x {:<14} {:>3} SLO-met, cost {:>6}  ({note})",
            b.cores, b.name, b.slo_met, b.cost
        );
        assert!(result.score.slo_met >= b.slo_met);
    }

    // The emitted JSON is exactly what `egpu serve --configs` eats.
    let json = result.fleet_json();
    let parsed = config_json::configs_from_json(&json)?;
    assert_eq!(parsed, result.fleet, "fleet JSON must round-trip");
    println!("\nfleet JSON (feed to `egpu serve --configs`):\n{json}");
    Ok(())
}
