//! FFT signal-processing pipeline on a multi-core `GpuArray`.
//!
//! The paper motivates the eGPU with exactly this workload class: "many of
//! the signal processing applications that we expect that the eGPU will be
//! used for (such as FFTs and matrix decomposition)" (§3.2), managed by an
//! external host over the 32-bit data bus (§2, §7).
//!
//! This example builds a 4-core array, streams a batch of frames through
//! it (one `Stream` per frame: window → FFT → magnitude-peak readback),
//! chains a second kernel onto a stream's resident data (the §7 "multiple
//! algorithms to the same data" mode), and reports throughput, per-core
//! utilization and the bus overhead against the paper's 4.7% average.
//!
//!     cargo run --release --example fft_pipeline

use egpu::api::{average_bus_overhead, Gpu};
use egpu::harness::Table;
use egpu::kernels::fft;
use egpu::sim::{EgpuConfig, MemoryMode};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n = 256usize;
    let frames = 16usize;
    let cores = 4usize;
    let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
    println!(
        "{} eGPU cores ({}), {}-point FFT, {} frames",
        cores, cfg.name, n, frames
    );

    // Synthetic sensor frames: two tones + phase-shifting interference.
    let frame = |f: usize| -> (Vec<f32>, Vec<f32>) {
        let re = (0..n)
            .map(|i| {
                let x = i as f64 / n as f64;
                let ph = f as f64 * 0.37;
                ((2.0 * std::f64::consts::PI * 17.0 * x + ph).cos()
                    + 0.25 * (2.0 * std::f64::consts::PI * 51.0 * x).sin()) as f32
            })
            .collect();
        (re, vec![0f32; n])
    };

    let mut array = Gpu::builder().config(cfg.clone()).build_array(cores)?;
    for f in 0..frames {
        let (re, im) = frame(f);
        let stream = array.stream();
        let mut launch = array.launch_on(&stream, fft::fft(n)).output(0, 2 * n);
        for (base, words) in fft::shared_init(&re, &im) {
            launch = launch.input_words(base, words);
        }
        launch.submit();
    }
    let reports = array.sync()?;

    // Verify each frame's spectrum against the DFT oracle and find peaks.
    let mut peaks = Vec::new();
    for (f, r) in reports.iter().enumerate() {
        let out = r.output_f32(0);
        let (re, im) = frame(f);
        let (wr, wi) = fft::oracle(&re, &im);
        let mut best = (0usize, 0f64);
        for k in 0..n / 2 {
            let gr = out[k] as f64;
            let gi = out[n + k] as f64;
            assert!(
                (gr - wr[k]).abs() < 1e-3 * n as f64 && (gi - wi[k]).abs() < 1e-3 * n as f64,
                "frame {f} bin {k} mismatch"
            );
            let mag = (gr * gr + gi * gi).sqrt();
            if mag > best.1 {
                best = (k, mag);
            }
        }
        peaks.push(best);
    }
    assert!(peaks.iter().all(|&(k, _)| k == 17), "dominant tone at bin 17");
    println!("all {frames} spectra match the DFT oracle; dominant bin = 17 in every frame");

    let mut t = Table::new("per-frame timeline (first 8)");
    t.headers(["frame", "stream", "core", "start", "end", "compute", "bus", "bus %"]);
    for (f, r) in reports.iter().take(8).enumerate() {
        t.row([
            f.to_string(),
            r.stream.map(|s| s.to_string()).unwrap_or_default(),
            r.core.to_string(),
            r.start.to_string(),
            r.end.to_string(),
            r.compute_cycles.to_string(),
            r.bus_cycles.to_string(),
            format!("{:.1}%", r.bus_overhead() * 100.0),
        ]);
    }
    t.print();

    let makespan = array.makespan();
    let total_compute: u64 = reports.iter().map(|r| r.compute_cycles).sum();
    println!(
        "\nmakespan {} cycles = {:.1} us at {:.0} MHz  ({:.2} frames/ms)",
        makespan,
        array.makespan_us(),
        cfg.core_mhz(),
        frames as f64 / (array.makespan_us() / 1000.0)
    );
    println!(
        "core utilization {:.0}%   average bus overhead {:.1}% (paper §7: 4.7%)",
        100.0 * total_compute as f64 / (makespan * cores as u64) as f64,
        100.0 * average_bus_overhead(&reports)
    );

    // Chained mode: a second FFT on the stream's resident spectrum —
    // stream affinity keeps it on the core holding the data, and the
    // input DMA is skipped entirely.
    let mut chain = Gpu::builder().config(cfg).build_array(1)?;
    let s = chain.stream();
    let (re, im) = frame(0);
    let mut first = chain.launch_on(&s, fft::fft(n));
    for (base, words) in fft::shared_init(&re, &im) {
        first = first.input_words(base, words);
    }
    first.submit();
    chain.launch_on(&s, fft::fft(n)).output(0, n).chained().submit();
    let rs = chain.sync()?;
    println!(
        "\nchained second kernel reused stream-resident data: bus cycles {} -> {}",
        rs[0].bus_cycles, rs[1].bus_cycles
    );
    assert!(rs[1].bus_cycles < rs[0].bus_cycles / 2);
    Ok(())
}
