//! FFT signal-processing pipeline on the multi-core coordinator.
//!
//! The paper motivates the eGPU with exactly this workload class: "many of
//! the signal processing applications that we expect that the eGPU will be
//! used for (such as FFTs and matrix decomposition)" (§3.2), managed by an
//! external host over the 32-bit data bus (§2, §7).
//!
//! This example builds a 4-core eGPU array, streams a batch of frames
//! through it (window → FFT → magnitude-peak readback), chains a second
//! kernel onto resident data (the §7 "multiple algorithms to the same
//! data" mode), and reports throughput, per-core utilization and the bus
//! overhead against the paper's 4.7% average.
//!
//!     cargo run --release --example fft_pipeline

use egpu::coordinator::{average_bus_overhead, Coordinator, Job};
use egpu::harness::Table;
use egpu::kernels::fft;
use egpu::sim::{EgpuConfig, MemoryMode};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n = 256usize;
    let frames = 16usize;
    let cores = 4usize;
    let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
    println!(
        "{} eGPU cores ({}), {}-point FFT, {} frames",
        cores,
        cfg.name,
        n,
        frames
    );

    // Synthetic sensor frames: two tones + phase-shifting interference.
    let frame = |f: usize| -> (Vec<f32>, Vec<f32>) {
        let re = (0..n)
            .map(|i| {
                let x = i as f64 / n as f64;
                let ph = f as f64 * 0.37;
                ((2.0 * std::f64::consts::PI * 17.0 * x + ph).cos()
                    + 0.25 * (2.0 * std::f64::consts::PI * 51.0 * x).sin()) as f32
            })
            .collect();
        (re, vec![0f32; n])
    };

    let mut coord = Coordinator::new(cfg.clone(), cores)?;
    for f in 0..frames {
        let (re, im) = frame(f);
        let mut job = Job::new(fft::fft(n)).unload(0, 2 * n);
        for (base, data) in fft::shared_init(&re, &im) {
            job = job.load(base, data);
        }
        coord.submit(job);
    }
    let results = coord.run_all()?;

    // Verify each frame's spectrum against the DFT oracle and find peaks.
    let mut peaks = Vec::new();
    for (f, r) in results.iter().enumerate() {
        let out = &r.outputs[0];
        let (re, im) = frame(f);
        let (wr, wi) = fft::oracle(&re, &im);
        let mut best = (0usize, 0f64);
        for k in 0..n / 2 {
            let gr = f32::from_bits(out[k]) as f64;
            let gi = f32::from_bits(out[n + k]) as f64;
            assert!(
                (gr - wr[k]).abs() < 1e-3 * n as f64 && (gi - wi[k]).abs() < 1e-3 * n as f64,
                "frame {f} bin {k} mismatch"
            );
            let mag = (gr * gr + gi * gi).sqrt();
            if mag > best.1 {
                best = (k, mag);
            }
        }
        peaks.push(best);
    }
    assert!(peaks.iter().all(|&(k, _)| k == 17), "dominant tone at bin 17");
    println!("all {frames} spectra match the DFT oracle; dominant bin = 17 in every frame");

    let mut t = Table::new("per-frame timeline (first 8)");
    t.headers(["frame", "core", "start", "end", "compute", "bus", "bus %"]);
    for (f, r) in results.iter().take(8).enumerate() {
        t.row([
            f.to_string(),
            r.core.to_string(),
            r.start.to_string(),
            r.end.to_string(),
            r.compute_cycles.to_string(),
            r.bus_cycles.to_string(),
            format!("{:.1}%", r.bus_overhead() * 100.0),
        ]);
    }
    t.print();

    let makespan = coord.makespan();
    let total_compute: u64 = results.iter().map(|r| r.compute_cycles).sum();
    println!(
        "\nmakespan {} cycles = {:.1} us at {:.0} MHz  ({:.2} frames/ms)",
        makespan,
        coord.makespan_us(),
        cfg.core_mhz(),
        frames as f64 / (coord.makespan_us() / 1000.0)
    );
    println!(
        "core utilization {:.0}%   average bus overhead {:.1}% (paper §7: 4.7%)",
        100.0 * total_compute as f64 / (makespan * cores as u64) as f64,
        100.0 * average_bus_overhead(&results)
    );

    // Chained mode: magnitude-squared via MMM-free path — re-run an FFT on
    // the last core's resident spectrum (demonstrates keep_data chaining).
    let mut chain = Coordinator::new(cfg, 1)?;
    let (re, im) = frame(0);
    let mut first = Job::new(fft::fft(n));
    for (base, data) in fft::shared_init(&re, &im) {
        first = first.load(base, data);
    }
    chain.submit(first);
    chain.submit(Job::new(fft::fft(n)).unload(0, n).chained());
    let rs = chain.run_all()?;
    println!(
        "\nchained second kernel reused resident data: bus cycles {} -> {}",
        rs[0].bus_cycles, rs[1].bus_cycles
    );
    assert!(rs[1].bus_cycles < rs[0].bus_cycles / 2);
    Ok(())
}
