//! End-to-end evaluation driver: proves all three layers compose and
//! regenerates the paper's evaluation on a real workload set.
//!
//! 1. Loads the AOT-compiled JAX/Pallas artifacts through the rust PJRT
//!    runtime and runs a kernel on the XLA datapath, asserting bit-equal
//!    architectural state against the native datapath (L1/L2 ↔ L3 compose).
//! 2. Runs the full §7 benchmark suite — 5 benchmarks × all paper
//!    dimensions × {Nios, eGPU-DP, eGPU-QP, eGPU-Dot} — with every result
//!    checked against its oracle, and prints Tables 7/8 next to the
//!    paper's numbers with band checks.
//! 3. Prints the Figure 6 instruction-mix profile and the Table 4/5/6
//!    resource models, and places a core into an Agilex sector (Fig 4/5).
//!
//! The run is recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example full_eval

use egpu::api::{Backend, Gpu};
use egpu::harness::{paper_cycles, suite, within_band, Table, Variant};
use egpu::isa::Group;
use egpu::model::frequency::FrequencyReport;
use egpu::model::resources::ResourceReport;
use egpu::place;
use egpu::runtime::default_artifacts_dir;
use egpu::sim::{EgpuConfig, MemoryMode};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let t0 = std::time::Instant::now();

    // ---------------------------------------------------------------
    // 1. Layer composition: XLA datapath ≡ native datapath.
    // ---------------------------------------------------------------
    println!("=== 1. AOT artifact check (L1 Pallas / L2 JAX -> PJRT -> L3 rust) ===");
    let dir = default_artifacts_dir();
    if !dir.join("opmap.json").is_file() {
        return Err("artifacts missing — run `make artifacts` first".into());
    }
    let cfg = EgpuConfig::benchmark(MemoryMode::Dp, true);
    // r0/r1 are seeded host-side with normal-range f32 values (XLA CPU
    // flushes denormals; see DESIGN.md §Substitutions).
    let src = "
        fadd r2, r0, r1
        fmul r3, r2, r2
        tdx r7
        ldi r8, #13
        mul16lo.i32 r4, r7, r8
        max.u32 r5, r4, r7
        dot r6, r2, r3
        stop
    ";
    // The same device configuration on both datapaths: only the
    // builder's backend differs.
    let mut native = Gpu::new(&cfg)?;
    let mut xla = Gpu::builder()
        .config(cfg.clone())
        .backend(Backend::Xla(dir.clone()))
        .build()
        .map_err(std::io::Error::other)?;
    let threads = cfg.threads;
    for g in [&mut native, &mut xla] {
        // r0/r1 seeding happens post-load via the setup hook (program
        // load resets architectural state).
        g.launch_asm("compose-check", src)
            .max_cycles(1_000_000)
            .setup(move |m| {
                for t in 0..threads {
                    m.regs_mut().write_thread(t, 0, (t as f32 * 0.75 - 100.0).to_bits());
                    m.regs_mut().write_thread(t, 1, (t as f32 * -0.125 + 3.0).to_bits());
                }
            })
            .run()?;
    }
    let mut compared = 0usize;
    for t in 0..cfg.threads {
        for r in 2..=5u8 {
            assert_eq!(
                native.machine().regs().read_thread(t, r),
                xla.machine().regs().read_thread(t, r),
                "thread {t} r{r} diverges between datapaths"
            );
            compared += 1;
        }
    }
    // DOT reduces across 512 threads; the Pallas kernel's accumulation
    // order differs from the rust lanes by a few ULPs — bounded, not bug.
    let nd = f32::from_bits(native.machine().regs().read_thread(0, 6));
    let xd = f32::from_bits(xla.machine().regs().read_thread(0, 6));
    assert!(
        (nd - xd).abs() <= nd.abs() * 1e-5,
        "dot diverges beyond rounding: {nd} vs {xd}"
    );
    println!(
        "native and XLA datapaths agree on {compared} register values \
         ({} threads x 4 regs, bit-exact) + DOT to f32 rounding \
         ({nd} vs {xd}); cycle counts {} == {}\n",
        cfg.threads,
        native.machine().cycles(),
        xla.machine().cycles()
    );

    // ---------------------------------------------------------------
    // 2. The §7 benchmark suite: Tables 7 and 8.
    // ---------------------------------------------------------------
    println!("=== 2. Benchmark suite (Tables 7/8) — every cell verified against its oracle ===");
    let results = suite::run_all();
    let mut band_fail = 0usize;
    let mut cells = 0usize;
    for b in suite::Benchmark::ALL {
        let mut t = Table::new(format!("{} — cycles, measured (paper)", b.name()));
        t.headers(["Dim", "Nios", "eGPU-DP", "eGPU-QP", "eGPU-Dot", "DP in 2x band"]);
        for r in results.iter().filter(|r| r.bench == b) {
            let cell = |m: Option<&suite::Measurement>, v: Variant| match m {
                None => "-".to_string(),
                Some(m) => match paper_cycles(b, r.dim, v) {
                    Some(p) => format!("{} ({p})", m.cycles),
                    None => m.cycles.to_string(),
                },
            };
            let mut ok = true;
            for (m, v) in [
                (Some(&r.nios), Variant::Nios),
                (Some(&r.dp), Variant::Dp),
                (Some(&r.qp), Variant::Qp),
                (r.dot.as_ref(), Variant::Dot),
            ] {
                if let (Some(m), Some(p)) = (m, paper_cycles(b, r.dim, v)) {
                    cells += 1;
                    // Nios gets a wider band: the ISS CPI model is coarse,
                    // and the paper's Nios reduction scales superlinearly
                    // (459 -> 1803 cycles for 2x data) in a way a simple
                    // CPI model cannot reproduce. See EXPERIMENTS.md.
                    let band = if v == Variant::Nios { 4.0 } else { 2.0 };
                    if !within_band(m.cycles as f64, p as f64, band) {
                        band_fail += 1;
                        ok = false;
                        eprintln!(
                            "  BAND MISS {b:?}-{} {}: {} vs paper {p}",
                            r.dim,
                            v.label(),
                            m.cycles
                        );
                    }
                }
            }
            t.row([
                r.dim.to_string(),
                cell(Some(&r.nios), Variant::Nios),
                cell(Some(&r.dp), Variant::Dp),
                cell(Some(&r.qp), Variant::Qp),
                cell(r.dot.as_ref(), Variant::Dot),
                if ok { "yes".into() } else { "NO".to_string() },
            ]);
        }
        t.print();
        println!();
    }
    println!("band check: {}/{} cells within tolerance\n", cells - band_fail, cells);

    // Headline claims (§7/§8).
    let speedups: Vec<f64> = results
        .iter()
        .map(|r| r.ratio_time(Variant::Nios).unwrap())
        .collect();
    let min = speedups.iter().cloned().fold(f64::MAX, f64::min);
    let geo = (speedups.iter().map(|s| s.ln()).sum::<f64>() / speedups.len() as f64).exp();
    println!(
        "eGPU-DP vs Nios elapsed-time speedup: min {min:.1}x, geomean {geo:.1}x \
         (paper: \"at least an OOM performance difference based on time\")"
    );
    let norm_wins = results
        .iter()
        .filter(|r| r.normalized(Variant::Nios).unwrap() > 1.0)
        .count();
    println!(
        "area-normalized: eGPU-DP better than Nios in {norm_wins}/{} instances\n",
        results.len()
    );

    // ---------------------------------------------------------------
    // 3. Figure 6 profiles + resource models + placement.
    // ---------------------------------------------------------------
    println!("=== 3. Figure 6: cycle mix by instruction type (eGPU-DP) ===");
    for r in &results {
        let p = r.dp.profile.as_ref().unwrap();
        let mut bars = String::new();
        for g in [Group::Nop, Group::FpAlu, Group::Memory, Group::Control, Group::Conditional] {
            bars.push_str(&format!("{}: {:4.1}%  ", g.label(), 100.0 * p.cycle_fraction(g)));
        }
        let int: f64 = [Group::IntArith, Group::IntMul, Group::IntLogic, Group::IntShift, Group::IntOther]
            .iter()
            .map(|&g| p.cycle_fraction(g))
            .sum();
        println!("{:<18} {:>4}: {bars}INT: {:4.1}%", r.bench.name(), r.dim, 100.0 * int);
    }

    println!("\n=== Tables 4/5 resource model and Figure 4 placement ===");
    for cfg in EgpuConfig::table4_presets() {
        let r = ResourceReport::for_config(&cfg);
        let f = FrequencyReport::for_config(&cfg);
        let p = place::place(&cfg).map_err(std::io::Error::other)?;
        println!(
            "{:<12} {:>6} ALMs {:>3} DSP {:>3} M20K  {:>4.0}/{:.0} MHz  placed: spine central={} preds remote={}",
            cfg.name,
            r.alms,
            r.dsps,
            r.m20ks,
            f.soft_mhz,
            f.core_mhz,
            p.spine_is_central(),
            p.predicates_remote()
        );
    }

    println!(
        "\nfull evaluation complete in {:.1}s — {} benchmark instances, all oracles passed",
        t0.elapsed().as_secs_f64(),
        results.len()
    );
    if band_fail > 0 {
        return Err(format!("{band_fail} cycle cells outside the reproduction band").into());
    }
    Ok(())
}
