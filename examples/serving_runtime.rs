//! Continuous serving on a heterogeneous fleet: `api::Server`.
//!
//! Where `examples/fleet_serving.rs` dispatches one pre-built batch,
//! this example runs the full serving runtime over the same 2×DP +
//! 2×QP mix: a seeded stream of requests with arrivals, deadlines and
//! priorities flows through the bounded admission queue, the
//! deadline-aware batcher, and the fleet's feature-routed wall-clock
//! placement — with per-request latency telemetry at the end. Every
//! number is modeled and deterministic: re-running this example
//! reproduces it bit-for-bit.
//!
//!     cargo run --release --example serving_runtime

use egpu::api::{Server, ShedReason};
use egpu::harness::loadgen::{demo_requests, LoadSpec};
use egpu::harness::Table;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // The demo fleet behind a server: queue bound 48, batches of 8,
    // up to 12 µs of lingering to fill them.
    let mut server = Server::builder().qdepth(48).max_batch(8).linger_us(12).build()?;

    // A seeded trace: 48 mixed-kernel requests (reductions, FFTs,
    // sorts, DOT reductions, transposes), arrivals ~2000 bus cycles
    // apart, deadlines on half of them.
    let trace = demo_requests(&LoadSpec {
        seed: 0xCAFE,
        requests: 48,
        mean_gap: 2_000,
        dim: 64,
        deadline_slack: Some(server.us_to_cycles(120)),
    });
    let offered = trace.len();
    let report = server.serve(trace)?;
    let t = &report.telemetry;
    let mhz = server.bus_mhz();

    // Every offered request is accounted for: served or shed.
    assert_eq!(report.submitted(), offered);
    // The queue never outgrew its bound.
    assert!(t.peak_queue <= server.qdepth());
    // Deterministic totals for the fixed seed.
    assert!(t.completed > 0 && t.batches > 1);

    let mut lat = Table::new(format!(
        "Serving {} requests: {} served, {} shed, {} batches",
        offered, t.completed, t.shed, t.batches
    ));
    lat.headers(["latency (us)", "p50", "p95", "p99", "max"]);
    for (name, h) in [
        ("queue wait", &t.queue_wait),
        ("service", &t.service),
        ("end-to-end", &t.e2e),
    ] {
        lat.row([
            name.to_string(),
            format!("{:.2}", h.p50() as f64 / mhz),
            format!("{:.2}", h.p95() as f64 / mhz),
            format!("{:.2}", h.p99() as f64 / mhz),
            format!("{:.2}", h.max() as f64 / mhz),
        ]);
    }
    lat.print();

    println!();
    let util = server.core_utilization();
    for (c, u) in util.iter().enumerate() {
        let placed = report.results.iter().filter(|r| r.core == c).count();
        println!(
            "core {c} ({:<12}): {placed:>2} requests, {:.1}% utilized",
            server.fleet().core_configs()[c].name,
            u * 100.0
        );
    }

    if !report.shed.is_empty() {
        let full = report.shed.iter().filter(|s| s.reason == ShedReason::QueueFull).count();
        println!(
            "\nshed: {full} queue-full, {} deadline-expired (all reported)",
            report.shed.len() - full
        );
    }
    let stats = server.cache_stats();
    println!(
        "\nkernel cache: {} compiles for {} served requests ({} hits) — \
         compile once, serve forever",
        stats.compiles, t.completed, stats.hits
    );
    println!(
        "sustained: {:.0} requests/s over {:.1} us modeled ({} deadline misses, \
         peak queue {})",
        t.jobs_per_s(mhz),
        server.cycles_to_us(t.span_cycles()),
        t.deadline_missed,
        t.peak_queue
    );
    Ok(())
}
