#!/usr/bin/env python3
"""CI bench-regression gate.

Diffs a freshly produced BENCH_simulator.json against the committed
BENCH_baseline.json and fails (exit 1) when any gated wall-clock rate
regresses more than ``max_regression_pct`` below its floor:

- per-kernel ``mcyc_per_s_unchecked`` (the fast-path simulator rate)
- serving ``wall_jobs_per_s`` (steady-state serving throughput)
- dispatch ``steady_batches_per_s`` (warmed-server batch throughput),
  plus two exact caps with no tolerance: ``pool_spawns_max`` (the
  worker pool spawns once per server lifetime) and
  ``steady_superplan_compiles_max`` (steady-state rounds recompile
  nothing)
- synthesis ``fleets_per_s`` (frontier-batched fleet-scoring throughput)
- observability ``overhead_pct`` (wall-clock cost of serving with the
  event recorder on vs off), gated against an absolute ceiling
  ``overhead_pct_max`` rather than a relative floor

Modeled quantities are deliberately *not* gated here — bit-identity of
modeled cycles is the parity test suites' job; this gate only stops
silent wall-clock losses.

Usage: check_bench_regression.py BENCH_baseline.json BENCH_simulator.json
"""

import json
import sys


def fail(msg: str) -> None:
    print(f"bench-regression: FAIL: {msg}")
    sys.exit(1)


def main() -> None:
    if len(sys.argv) != 3:
        fail(f"usage: {sys.argv[0]} BENCH_baseline.json BENCH_simulator.json")
    with open(sys.argv[1]) as f:
        baseline = json.load(f)
    with open(sys.argv[2]) as f:
        bench = json.load(f)

    max_reg = float(baseline.get("max_regression_pct", 20))
    factor = 1.0 - max_reg / 100.0
    checked = 0
    errors = []

    measured = {k["name"]: k for k in bench.get("kernels", [])}
    for name, floor in baseline.get("kernels_mcyc_per_s_unchecked", {}).items():
        if name not in measured:
            errors.append(f"kernel '{name}' is in the baseline but not in the bench output")
            continue
        rate = float(measured[name]["mcyc_per_s_unchecked"])
        limit = float(floor) * factor
        status = "ok" if rate >= limit else "REGRESSED"
        print(
            f"bench-regression: {name}: {rate:.2f} Mcyc/s "
            f"(floor {floor}, limit {limit:.2f}) {status}"
        )
        if rate < limit:
            errors.append(
                f"{name}: {rate:.2f} Mcyc/s is more than {max_reg:.0f}% below "
                f"the committed floor of {floor} Mcyc/s"
            )
        checked += 1

    serving_floor = baseline.get("serving", {}).get("wall_jobs_per_s")
    if serving_floor is not None:
        serving = bench.get("serving", {})
        if "wall_jobs_per_s" not in serving:
            errors.append("serving.wall_jobs_per_s missing from the bench output")
        else:
            rate = float(serving["wall_jobs_per_s"])
            limit = float(serving_floor) * factor
            status = "ok" if rate >= limit else "REGRESSED"
            print(
                f"bench-regression: serving wall_jobs_per_s: {rate:.1f} "
                f"(floor {serving_floor}, limit {limit:.1f}) {status}"
            )
            if rate < limit:
                errors.append(
                    f"serving wall_jobs_per_s: {rate:.1f} is more than "
                    f"{max_reg:.0f}% below the committed floor of {serving_floor}"
                )
            checked += 1

    dispatch_base = baseline.get("dispatch", {})
    dispatch = bench.get("dispatch", {})
    dispatch_floor = dispatch_base.get("steady_batches_per_s")
    if dispatch_floor is not None:
        if "steady_batches_per_s" not in dispatch:
            errors.append("dispatch.steady_batches_per_s missing from the bench output")
        else:
            rate = float(dispatch["steady_batches_per_s"])
            limit = float(dispatch_floor) * factor
            status = "ok" if rate >= limit else "REGRESSED"
            print(
                f"bench-regression: dispatch steady_batches_per_s: {rate:.1f} "
                f"(floor {dispatch_floor}, limit {limit:.1f}) {status}"
            )
            if rate < limit:
                errors.append(
                    f"dispatch steady_batches_per_s: {rate:.1f} is more than "
                    f"{max_reg:.0f}% below the committed floor of {dispatch_floor}"
                )
            checked += 1
    # Exact caps: structural counters, gated with zero tolerance — a
    # second pool spawn or a steady-state recompile is a bug, not noise.
    for base_key, bench_key in (
        ("pool_spawns_max", "pool_spawns"),
        ("steady_superplan_compiles_max", "steady_superplan_compiles"),
    ):
        cap = dispatch_base.get(base_key)
        if cap is None:
            continue
        if bench_key not in dispatch:
            errors.append(f"dispatch.{bench_key} missing from the bench output")
            continue
        value = int(dispatch[bench_key])
        status = "ok" if value <= int(cap) else "EXCEEDED"
        print(f"bench-regression: dispatch {bench_key}: {value} (cap {cap}) {status}")
        if value > int(cap):
            errors.append(f"dispatch {bench_key}: {value} exceeds the exact cap of {cap}")
        checked += 1

    synth_floor = baseline.get("synthesis", {}).get("fleets_per_s")
    if synth_floor is not None:
        synthesis = bench.get("synthesis", {})
        if "fleets_per_s" not in synthesis:
            errors.append("synthesis.fleets_per_s missing from the bench output")
        else:
            rate = float(synthesis["fleets_per_s"])
            limit = float(synth_floor) * factor
            status = "ok" if rate >= limit else "REGRESSED"
            print(
                f"bench-regression: synthesis fleets_per_s: {rate:.1f} "
                f"(floor {synth_floor}, limit {limit:.1f}) {status}"
            )
            if rate < limit:
                errors.append(
                    f"synthesis fleets_per_s: {rate:.1f} is more than "
                    f"{max_reg:.0f}% below the committed floor of {synth_floor}"
                )
            checked += 1

    obs_cap = baseline.get("observability", {}).get("overhead_pct_max")
    if obs_cap is not None:
        observability = bench.get("observability", {})
        if "overhead_pct" not in observability:
            errors.append("observability.overhead_pct missing from the bench output")
        else:
            # An absolute ceiling, not a relative floor: tracing must
            # stay cheap in absolute terms, and negative overhead
            # (wall-clock noise) is fine.
            pct = float(observability["overhead_pct"])
            status = "ok" if pct <= float(obs_cap) else "EXCEEDED"
            print(
                f"bench-regression: observability overhead_pct: {pct:.2f}% "
                f"(cap {obs_cap}%) {status}"
            )
            if pct > float(obs_cap):
                errors.append(
                    f"observability overhead_pct: {pct:.2f}% exceeds the "
                    f"tracing-overhead cap of {obs_cap}%"
                )
            checked += 1

    if checked == 0:
        fail("baseline contains no gated metrics — the gate would pass vacuously")
    for e in errors:
        print(f"bench-regression: {e}")
    if errors:
        sys.exit(1)
    print(f"bench-regression: PASS ({checked} metrics within {max_reg:.0f}% of their floors)")


if __name__ == "__main__":
    main()
