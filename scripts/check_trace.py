#!/usr/bin/env python3
"""CI smoke check for exported Chrome trace files (``--trace-out``).

Validates the structural contract of ``egpu::obs::chrome``, stdlib
only (no pip deps in CI):

- the document is well-formed JSON with a non-empty ``traceEvents``
  list and every event carries ``name``/``ph``/``pid``;
- timestamps are non-negative **integers** (modeled bus cycles — a
  float would smell of wall clock) and non-decreasing in file order,
  which is the exporter's deterministic ``(cycle, seq)`` order;
- async spans balance: every ``"e"`` closes a previously opened
  ``"b"`` with the same ``(cat, id, name)`` key, and nothing is left
  open at the end of the file;
- complete ``"X"`` slices carry a non-negative integer ``dur``;
- no event leaks wall-clock or host-thread residue (``tts``,
  ``tdur``, or a ``tid`` that is not a modeled track id) — the same
  trace must be byte-identical across dispatch modes, which those
  fields would break.

Usage: check_trace.py TRACE.json
"""

import json
import sys
from collections import defaultdict

KNOWN_PHASES = {"M", "X", "b", "e", "n", "i"}
WALL_CLOCK_KEYS = {"tts", "tdur", "dts"}


def fail(msg: str) -> None:
    print(f"check-trace: FAIL: {msg}")
    sys.exit(1)


def main() -> None:
    if len(sys.argv) != 2:
        fail(f"usage: {sys.argv[0]} TRACE.json")
    path = sys.argv[1]
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{path}: {e}")

    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail(f"{path}: traceEvents missing or empty")

    open_spans = defaultdict(int)
    phases = defaultdict(int)
    last_ts = None
    for i, e in enumerate(events):
        ph = e.get("ph")
        if ph not in KNOWN_PHASES:
            fail(f"event {i}: unknown phase {ph!r}")
        if "name" not in e or "pid" not in e:
            fail(f"event {i}: missing name/pid")
        leaked = WALL_CLOCK_KEYS & set(e)
        if leaked:
            fail(f"event {i}: wall-clock field(s) {sorted(leaked)} in a modeled trace")
        phases[ph] += 1
        if ph == "M":
            continue  # metadata rows are ts-less

        ts = e.get("ts")
        if not isinstance(ts, int) or ts < 0:
            fail(f"event {i}: ts {ts!r} is not a non-negative integer bus cycle")
        if last_ts is not None and ts < last_ts:
            fail(f"event {i}: ts {ts} < {last_ts} — file order is not (cycle, seq)")
        last_ts = ts

        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, int) or dur < 0:
                fail(f"event {i}: X slice dur {dur!r} is not a non-negative integer")
        elif ph == "b":
            open_spans[(e.get("cat"), e.get("id"), e["name"])] += 1
        elif ph == "e":
            key = (e.get("cat"), e.get("id"), e["name"])
            if open_spans[key] <= 0:
                fail(f"event {i}: 'e' closes nothing open for {key}")
            open_spans[key] -= 1

    dangling = sorted(k for k, n in open_spans.items() if n > 0)
    if dangling:
        fail(f"{len(dangling)} span(s) never closed, e.g. {dangling[0]}")
    if phases["b"] + phases["X"] == 0:
        fail("no spans at all — the trace recorded nothing")

    total = len(events)
    summary = ", ".join(f"{ph}:{phases[ph]}" for ph in sorted(phases))
    print(f"check-trace: PASS ({path}: {total} events, {summary})")


if __name__ == "__main__":
    main()
