"""L1 Pallas kernels: the eGPU datapath hot-spots.

One kernel per DSP-block / ALU operation (the op field of the instruction
word muxes between them at L2, exactly as the hardware muxes circuits), plus
the dot-product / reduction extension cores.
"""

from . import ref  # noqa: F401
from .fp_alu import fp_wavefront_kernel  # noqa: F401
from .int_alu import int_wavefront_kernel  # noqa: F401
from .dot import dot_kernel, matmul_kernel  # noqa: F401
