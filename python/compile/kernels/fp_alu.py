"""L1 Pallas kernel: the FP32 wavefront lane ALU.

One `(depth, 16)` block is one VMEM-resident thread block: 16 lanes map to
the 16 SPs (in hardware, 16 Agilex FP32 DSP blocks working in lockstep);
`depth` is the temporal wavefront dimension the sequencer streams, one
wavefront per clock. The `thread_active` writeback gate (§3.2 of the paper)
is the mask select at the end of the kernel — inactive lanes keep the old
destination-register value.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the FPGA's embedded
FP32 DSP column plays the role the MXU plays on TPU; a whole block is a
single VMEM tile (≤ 64×16×4 B = 4 KB per operand), so BlockSpec is the
identity mapping and the kernel is purely element-wise — the fusion shape
the paper gets for free from the DSP hard datapath.

interpret=True: the CPU PJRT client cannot execute Mosaic custom-calls; the
interpret path lowers to plain HLO, which is what the rust runtime loads.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from ..opmap import FP_OPS, WAVEFRONT_WIDTH


def _fp_body(name, a, b):
    """The per-lane FP32 circuit for one op (matches ref.fp_op_ref)."""
    if name == "fadd":
        return a + b
    if name == "fsub":
        return a - b
    if name == "fneg":
        return -a
    if name == "fabs":
        return jnp.abs(a)
    if name == "fmul":
        return a * b
    if name == "fmax":
        return jnp.maximum(a, b)
    if name == "fmin":
        return jnp.minimum(a, b)
    if name == "finvsqrt":
        return lax.rsqrt(a)
    raise ValueError(f"unknown fp op {name}")


def _make_kernel(name):
    def kernel(a_ref, b_ref, old_ref, mask_ref, o_ref):
        a = a_ref[...]
        b = b_ref[...]
        r = _fp_body(name, a, b)
        # thread_active writeback gating: zero'd write_enable keeps old Rd.
        o_ref[...] = jnp.where(mask_ref[...] != 0.0, r, old_ref[...])

    kernel.__name__ = f"fp_{name}_kernel"
    return kernel


@functools.lru_cache(maxsize=None)
def _op_call(name, depth):
    shape = jax.ShapeDtypeStruct((depth, WAVEFRONT_WIDTH), jnp.float32)
    return pl.pallas_call(
        _make_kernel(name),
        out_shape=shape,
        interpret=True,
    )


def fp_wavefront_kernel(op_index, a, b, old, mask):
    """Execute one FP op across a `(depth, 16)` wavefront block.

    `op_index` is a traced i32 scalar — the instruction word's opcode field.
    lax.switch is the HLO form of the hardware's operator mux.
    """
    depth = a.shape[0]
    branches = [
        functools.partial(
            lambda nm, a_, b_, o_, m_: _op_call(nm, depth)(a_, b_, o_, m_),
            name,
        )
        for name in FP_OPS
    ]
    return lax.switch(op_index, branches, a, b, old, mask)
