"""L1 Pallas kernels: the dot-product / reduction extension core.

The paper's optional DOT core (§4, Figure 1) takes the Ra/Rb operand
streams of the selected thread subset and produces a single scalar; SUM is
the add-only reduction variant. In hardware these are chained DSP blocks
hanging off the SP array; on TPU the natural mapping is an MXU contraction
over the `(depth, 16)` thread block, with the active-thread mask applied to
the operand stream (DESIGN.md §Hardware-Adaptation).

`matmul_kernel` is the L2 building block: a classic Pallas tiled matmul in
which each output tile is produced by the dot core — the structure the
paper's MMM-with-DOT benchmark realizes in time (one DOT per output
element) is realized here in space.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..opmap import WAVEFRONT_WIDTH


def _dot_block_kernel(a_ref, b_ref, mask_ref, o_ref):
    """One grid step: accumulate one wavefront row's masked dot product.

    The output block is revisited by every grid step (classic Pallas
    reduction): step 0 initializes, later steps accumulate — exactly the
    accumulator register inside the hard dot-product core.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[0, 0] = 0.0

    row = a_ref[...] * b_ref[...] * mask_ref[...]
    o_ref[0, 0] += jnp.sum(row)


@functools.lru_cache(maxsize=None)
def _dot_call(depth):
    w = WAVEFRONT_WIDTH
    return pl.pallas_call(
        _dot_block_kernel,
        grid=(depth,),
        in_specs=[
            pl.BlockSpec((1, w), lambda i: (i, 0)),
            pl.BlockSpec((1, w), lambda i: (i, 0)),
            pl.BlockSpec((1, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=True,
    )


def dot_kernel(a, b, mask):
    """DOT extension core over a `(depth, 16)` block → scalar f32.

    SUM is expressed through the same core with b = ones (the rust side
    does exactly this — one artifact serves both instructions).
    """
    return _dot_call(a.shape[0])(a, b, mask)[0, 0]


# --------------------------------------------------------------------------
# Tiled matmul built on the dot core (L2 building block)
# --------------------------------------------------------------------------

def _matmul_tile_kernel(a_ref, b_ref, o_ref):
    """One (tm, tn) output tile: full-K contraction on the MXU."""
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.lru_cache(maxsize=None)
def _matmul_call(m, k, n, tm, tn):
    return pl.pallas_call(
        _matmul_tile_kernel,
        grid=(m // tm, n // tn),
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )


def matmul_kernel(a, b, tile=16):
    """C = A @ B with `(tile, tile)` output tiles fed by the dot core.

    Tile defaults to 16 — one wavefront width, i.e. one output row per SP.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims {k} != {k2}"
    tm = min(tile, m)
    tn = min(tile, n)
    assert m % tm == 0 and n % tn == 0, "tile must divide output shape"
    return _matmul_call(m, k, n, tm, tn)(a, b)
