"""Pure-jnp oracle for every L1 Pallas kernel.

These are the ground-truth semantics of the eGPU datapath. The Pallas
kernels in fp_alu.py / int_alu.py / dot.py must match these bit-for-bit
(f32) / exactly (i32); pytest + hypothesis enforce it.
"""

import jax.numpy as jnp
from jax import lax


# --------------------------------------------------------------------------
# FP32 lane ops (index order must match opmap.FP_OPS)
# --------------------------------------------------------------------------

def fp_op_ref(name, a, b):
    """Reference semantics of one FP32 lane op over equal-shaped arrays."""
    if name == "fadd":
        return a + b
    if name == "fsub":
        return a - b
    if name == "fneg":
        return -a
    if name == "fabs":
        return jnp.abs(a)
    if name == "fmul":
        return a * b
    if name == "fmax":
        return jnp.maximum(a, b)
    if name == "fmin":
        return jnp.minimum(a, b)
    if name == "finvsqrt":
        return lax.rsqrt(a)
    raise ValueError(f"unknown fp op {name}")


# --------------------------------------------------------------------------
# Integer lane ops (index order must match opmap.INT_OPS)
# --------------------------------------------------------------------------

def _sext16(x):
    """Sign-extend the low 16 bits of an i32 lane."""
    return (x.astype(jnp.int32) << 16) >> 16


def _sext24(x):
    return (x.astype(jnp.int32) << 8) >> 8


def _as_u32(x):
    return x.astype(jnp.uint32)


def bit_reverse_32_ref(x):
    """Classic O(log n) bit reversal on u32 lanes."""
    x = _as_u32(x)
    x = ((x >> 1) & 0x55555555) | ((x & 0x55555555) << 1)
    x = ((x >> 2) & 0x33333333) | ((x & 0x33333333) << 2)
    x = ((x >> 4) & 0x0F0F0F0F) | ((x & 0x0F0F0F0F) << 4)
    x = ((x >> 8) & 0x00FF00FF) | ((x & 0x00FF00FF) << 8)
    x = (x >> 16) | (x << 16)
    return x.astype(jnp.int32)


def popcount_ref(x):
    x = _as_u32(x)
    x = x - ((x >> 1) & 0x55555555)
    x = (x & 0x33333333) + ((x >> 2) & 0x33333333)
    x = (x + (x >> 4)) & 0x0F0F0F0F
    return ((x * 0x01010101) >> 24).astype(jnp.int32)


def int_op_ref(name, a, b):
    """Reference semantics of one integer lane op (i32 lanes, wrapping)."""
    a = a.astype(jnp.int32)
    b = b.astype(jnp.int32)
    sh = b & 31
    if name == "add":
        return a + b
    if name == "sub":
        return a - b
    if name == "neg":
        return -a
    if name == "abs":
        return jnp.abs(a)
    if name == "mul16lo":
        return _sext16(a) * _sext16(b)
    if name == "mul16hi":
        return (_sext16(a) * _sext16(b)) >> 16
    if name == "mul24lo":
        p = _sext24(a).astype(jnp.int64) * _sext24(b).astype(jnp.int64)
        return p.astype(jnp.int32)
    if name == "mul24hi":
        p = _sext24(a).astype(jnp.int64) * _sext24(b).astype(jnp.int64)
        return (p >> 24).astype(jnp.int32)
    if name == "and":
        return a & b
    if name == "or":
        return a | b
    if name == "xor":
        return a ^ b
    if name == "not":
        return ~a
    if name == "cnot":
        return jnp.where(a == 0, 1, 0).astype(jnp.int32)
    if name == "bvs":
        return bit_reverse_32_ref(a)
    if name == "shl":
        return a << sh
    if name == "shr_l":
        return lax.shift_right_logical(a, sh)
    if name == "shr_a":
        return a >> sh
    if name == "pop":
        return popcount_ref(a)
    if name == "max_s":
        return jnp.maximum(a, b)
    if name == "min_s":
        return jnp.minimum(a, b)
    if name == "max_u":
        return jnp.where(_as_u32(a) > _as_u32(b), a, b)
    if name == "min_u":
        return jnp.where(_as_u32(a) < _as_u32(b), a, b)
    raise ValueError(f"unknown int op {name}")


def int_precision_mask_ref(x, precision):
    """16-bit ALU configs truncate results to the low 16 bits (§5.2).

    Registers are 32-bit; the 16-bit ALU writes back the low half
    zero-extended (the upper half is only driven by the FP datapath).
    """
    if precision == 16:
        return x & 0xFFFF
    return x


# --------------------------------------------------------------------------
# Extension cores
# --------------------------------------------------------------------------

def dot_ref(a, b, mask):
    """Dot-product extension core: sum over *active* lanes of a*b.

    Models the paper's DOT instruction: operands stream from the selected
    thread subset into the hard dot-product core; inactive lanes contribute
    nothing.
    """
    return jnp.sum(a * b * mask)


def sum_ref(a, mask):
    """SUM reduction core: sum of Ra over active lanes."""
    return jnp.sum(a * mask)


def masked_writeback_ref(result, old, mask):
    """thread_active writeback gating: keep `old` where mask == 0 (§3.2)."""
    return jnp.where(mask != 0, result, old)


def matmul_ref(a, b):
    """C = A @ B, f32 — oracle for the L2 dot-core matmul model."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)
