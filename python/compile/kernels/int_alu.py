"""L1 Pallas kernel: the integer wavefront lane ALU.

In hardware this is the soft-logic ALU of Table 6 (90–394 ALMs per SP
depending on precision/features). Here every op is its own Pallas kernel —
one circuit per op, muxed by the opcode field via lax.switch at L2 — over
the same `(depth, 16)` VMEM-resident thread block as the FP ALU.

The `precision` operand models the 16-bit ALU configurations (§5.2):
results are truncated to the low 16 bits (zero-extended in the 32-bit
register file) when precision == 16.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from ..opmap import INT_OPS, WAVEFRONT_WIDTH


def _sext16(x):
    return (x << 16) >> 16


def _sext24(x):
    return (x << 8) >> 8


def _bit_reverse_32(x):
    u = x.astype(jnp.uint32)
    u = ((u >> 1) & 0x55555555) | ((u & 0x55555555) << 1)
    u = ((u >> 2) & 0x33333333) | ((u & 0x33333333) << 2)
    u = ((u >> 4) & 0x0F0F0F0F) | ((u & 0x0F0F0F0F) << 4)
    u = ((u >> 8) & 0x00FF00FF) | ((u & 0x00FF00FF) << 8)
    u = (u >> 16) | (u << 16)
    return u.astype(jnp.int32)


def _popcount(x):
    u = x.astype(jnp.uint32)
    u = u - ((u >> 1) & 0x55555555)
    u = (u & 0x33333333) + ((u >> 2) & 0x33333333)
    u = (u + (u >> 4)) & 0x0F0F0F0F
    return ((u * 0x01010101) >> 24).astype(jnp.int32)


def _int_body(name, a, b):
    """Per-lane integer circuit for one op (matches ref.int_op_ref)."""
    sh = b & 31
    if name == "add":
        return a + b
    if name == "sub":
        return a - b
    if name == "neg":
        return -a
    if name == "abs":
        return jnp.abs(a)
    if name == "mul16lo":
        return _sext16(a) * _sext16(b)
    if name == "mul16hi":
        return (_sext16(a) * _sext16(b)) >> 16
    if name == "mul24lo":
        p = _sext24(a).astype(jnp.int64) * _sext24(b).astype(jnp.int64)
        return p.astype(jnp.int32)
    if name == "mul24hi":
        p = _sext24(a).astype(jnp.int64) * _sext24(b).astype(jnp.int64)
        return (p >> 24).astype(jnp.int32)
    if name == "and":
        return a & b
    if name == "or":
        return a | b
    if name == "xor":
        return a ^ b
    if name == "not":
        return ~a
    if name == "cnot":
        return jnp.where(a == 0, 1, 0).astype(jnp.int32)
    if name == "bvs":
        return _bit_reverse_32(a)
    if name == "shl":
        return a << sh
    if name == "shr_l":
        return lax.shift_right_logical(a, sh)
    if name == "shr_a":
        return a >> sh
    if name == "pop":
        return _popcount(a)
    if name == "max_s":
        return jnp.maximum(a, b)
    if name == "min_s":
        return jnp.minimum(a, b)
    if name == "max_u":
        au = a.astype(jnp.uint32)
        bu = b.astype(jnp.uint32)
        return jnp.where(au > bu, a, b)
    if name == "min_u":
        au = a.astype(jnp.uint32)
        bu = b.astype(jnp.uint32)
        return jnp.where(au < bu, a, b)
    raise ValueError(f"unknown int op {name}")


def _make_kernel(name):
    def kernel(prec_ref, a_ref, b_ref, old_ref, mask_ref, o_ref):
        a = a_ref[...]
        b = b_ref[...]
        r = _int_body(name, a, b)
        # 16-bit ALU configs truncate to the low half (zero-extended).
        r = jnp.where(prec_ref[0, 0] == 16, r & 0xFFFF, r)
        o_ref[...] = jnp.where(mask_ref[...] != 0, r, old_ref[...])

    kernel.__name__ = f"int_{name}_kernel"
    return kernel


@functools.lru_cache(maxsize=None)
def _op_call(name, depth):
    shape = jax.ShapeDtypeStruct((depth, WAVEFRONT_WIDTH), jnp.int32)
    return pl.pallas_call(
        _make_kernel(name),
        out_shape=shape,
        interpret=True,
    )


def int_wavefront_kernel(op_index, precision, a, b, old, mask):
    """Execute one integer op across a `(depth, 16)` wavefront block.

    `op_index`: traced i32 scalar (decoded opcode+TYPE → datapath index).
    `precision`: i32[1,1], 16 or 32 — the static ALU-precision parameter
    threaded as data so a single artifact serves both configs.
    """
    depth = a.shape[0]
    branches = [
        functools.partial(
            lambda nm, p_, a_, b_, o_, m_: _op_call(nm, depth)(p_, a_, b_, o_, m_),
            name,
        )
        for name in INT_OPS
    ]
    return lax.switch(op_index, branches, precision, a, b, old, mask)
