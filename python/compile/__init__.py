"""eGPU compile path (build-time only; never imported at runtime).

jax_enable_x64: the mul24 datapath ops need a genuine 48-bit product
(24x24 -> >>24); with x64 off jax silently truncates the int64 intermediate
to int32 and the HLO artifact would disagree with the rust native datapath.
All dtypes in this package are explicit, so enabling x64 changes nothing
else.
"""

import jax

jax.config.update("jax_enable_x64", True)
