"""L2: the eGPU datapath as a JAX compute graph.

This is the "model" layer of the three-layer stack: the wavefront-block
executors that the rust coordinator (L3) drives on its hot path, built from
the L1 Pallas kernels. Each entry point below is AOT-lowered by aot.py to
one HLO-text artifact; the rust runtime compiles them once with the PJRT
CPU client and executes them per decoded instruction when running with
`--datapath xla`.

Shapes are static per artifact: a `(depth, 16)` block covers the whole
initialized thread space (depth = threads / 16). Dynamic thread-space
scaling (§3.1 — the 4-bit instruction field) reaches the datapath purely as
the `mask` operand: de-selected wavefronts/SPs have mask 0 and their lanes'
writebacks are suppressed, which is exactly how the hardware's
`thread_active` gating realizes the feature with "no dead time".
"""

import jax.numpy as jnp

from .kernels.fp_alu import fp_wavefront_kernel
from .kernels.int_alu import int_wavefront_kernel
from .kernels.dot import dot_kernel, matmul_kernel


def wavefront_fp(op_index, a, b, old, mask):
    """FP32 wavefront executor: (op, Ra, Rb, old Rd, active) → new Rd.

    op_index: i32[1,1] — decoded datapath op (opmap.FP_OPS order).
    a, b, old, mask: f32[depth, 16].
    """
    return (fp_wavefront_kernel(op_index[0, 0], a, b, old, mask),)


def wavefront_int(op_index, precision, a, b, old, mask):
    """Integer wavefront executor (opmap.INT_OPS order; precision 16/32)."""
    return (int_wavefront_kernel(op_index[0, 0], precision, a, b, old, mask),)


def wavefront_dot(a, b, mask):
    """DOT extension core → scalar. SUM = wavefront_dot(a, ones, mask)."""
    return (dot_kernel(a, b, mask),)


def dot_core_matmul(a, b):
    """C = A @ B through the dot-product core (L2 model of the MMM-with-DOT
    benchmark): every 16×16 output tile is one spatial instance of the
    reduction the eGPU performs temporally, one DOT per output element."""
    return (matmul_kernel(a, b, tile=16),)


def dot_core_matmul_ref(a, b):
    """Reference graph for dot_core_matmul (no Pallas) — used by tests and
    by HLO cost-analysis in the perf pass."""
    return (jnp.dot(a, b, preferred_element_type=jnp.float32),)
