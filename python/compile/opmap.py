"""Datapath op-index contract shared between the python compile path and the
rust coordinator.

The eGPU instruction word carries (opcode, TYPE); the rust decoder resolves
that pair to a *datapath op index* used both by the native rust backend and
by the AOT-compiled XLA executables. The indices below are the single source
of truth: `aot.py` writes them into `artifacts/opmap.json`, and the rust
`datapath::xla` backend refuses to start if its enum disagrees (see
rust/src/datapath/opmap.rs).

FP ops operate on IEEE-754 f32 lanes — in hardware these live inside the
Agilex DSP blocks (§4: "the FP instructions are almost completely contained
inside the DSP Block"). INT ops are the soft-logic integer ALU of Table 6.
"""

# FP32 lane ALU (one entry per DSP-block operation).
FP_OPS = [
    "fadd",     # 0: Rd = Ra + Rb
    "fsub",     # 1: Rd = Ra - Rb
    "fneg",     # 2: Rd = -Ra
    "fabs",     # 3: Rd = |Ra|
    "fmul",     # 4: Rd = Ra * Rb
    "fmax",     # 5: Rd = max(Ra, Rb)
    "fmin",     # 6: Rd = min(Ra, Rb)
    "finvsqrt", # 7: Rd = 1/sqrt(Ra)   (SFU extension core)
]

# Integer lane ALU. Signed/unsigned TYPE variants that change semantics get
# their own index (the rust decoder folds TYPE into the index).
INT_OPS = [
    "add",      # 0: Rd = Ra + Rb                  (wrapping)
    "sub",      # 1: Rd = Ra - Rb                  (wrapping)
    "neg",      # 2: Rd = -Ra                      (wrapping)
    "abs",      # 3: Rd = |Ra|                     (wrapping at i32::MIN)
    "mul16lo",  # 4: Rd = sext16(Ra) * sext16(Rb)  (full 32-bit product)
    "mul16hi",  # 5: Rd = (sext16(Ra)*sext16(Rb)) >> 16
    "mul24lo",  # 6: Rd = low32(sext24(Ra) * sext24(Rb))
    "mul24hi",  # 7: Rd = low32((sext24(Ra)*sext24(Rb)) >> 24)
    "and",      # 8
    "or",       # 9
    "xor",      # 10
    "not",      # 11: Rd = ~Ra (bitwise; paper's '!Ra')
    "cnot",     # 12: Rd = (Ra == 0) ? 1 : 0
    "bvs",      # 13: Rd = bit_reverse_32(Ra)
    "shl",      # 14: Rd = Ra << (Rb & 31)
    "shr_l",    # 15: Rd = Ra >>> (Rb & 31)        (logical, UINT TYPE)
    "shr_a",    # 16: Rd = Ra >> (Rb & 31)         (arithmetic, INT TYPE)
    "pop",      # 17: Rd = popcount(Ra)
    "max_s",    # 18: signed max
    "min_s",    # 19: signed min
    "max_u",    # 20: unsigned max
    "min_u",    # 21: unsigned min
]

WAVEFRONT_WIDTH = 16  # SPs per SM — fixed by the architecture (§3)

# Wavefront-block depths we AOT-compile artifacts for. depth = threads / 16;
# 32 covers the paper's 512-thread base config, 64 the 1024-thread QP ones.
DEPTHS = [32, 64]
