"""AOT compile path: lower every L2 entry point to HLO *text* artifacts.

Run once by `make artifacts` (`python -m compile.aot --out ../artifacts`);
python never appears on the request path afterwards.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (depth D ∈ opmap.DEPTHS, lane width 16):
  fp_alu_d{D}.hlo.txt   (op i32[1,1], a,b,old,mask f32[D,16]) → f32[D,16]
  int_alu_d{D}.hlo.txt  (op i32[1,1], prec i32[1,1], a,b,old,mask i32[D,16])
                        → i32[D,16]
  dot_d{D}.hlo.txt      (a,b,mask f32[D,16]) → f32 scalar (as (1,1)→[0,0])
  mmm32.hlo.txt         (A f32[32,32], B f32[32,32]) → f32[32,32]
  opmap.json            the datapath op-index contract (checked by rust)
  manifest.json         artifact inventory + shapes, for runtime discovery
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, opmap


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_entries():
    """(name, fn, arg_specs) for every artifact."""
    entries = []
    w = opmap.WAVEFRONT_WIDTH
    for d in opmap.DEPTHS:
        fblk = _spec((d, w), jnp.float32)
        iblk = _spec((d, w), jnp.int32)
        s11 = _spec((1, 1), jnp.int32)
        entries.append(
            (f"fp_alu_d{d}", model.wavefront_fp, (s11, fblk, fblk, fblk, fblk))
        )
        entries.append(
            (
                f"int_alu_d{d}",
                model.wavefront_int,
                (s11, s11, iblk, iblk, iblk, iblk),
            )
        )
        entries.append((f"dot_d{d}", model.wavefront_dot, (fblk, fblk, fblk)))
    m32 = _spec((32, 32), jnp.float32)
    entries.append(("mmm32", model.dot_core_matmul, (m32, m32)))
    return entries


def emit(out_dir: str, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"wavefront_width": opmap.WAVEFRONT_WIDTH, "artifacts": {}}
    for name, fn, specs in build_entries():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "args": [[list(s.shape), str(s.dtype)] for s in specs],
        }
        if verbose:
            print(f"  {name}: {len(text)} chars -> {path}")
    with open(os.path.join(out_dir, "opmap.json"), "w") as f:
        json.dump(
            {
                "fp_ops": opmap.FP_OPS,
                "int_ops": opmap.INT_OPS,
                "depths": opmap.DEPTHS,
                "wavefront_width": opmap.WAVEFRONT_WIDTH,
            },
            f,
            indent=2,
        )
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output dir")
    args = parser.parse_args()
    manifest = emit(args.out)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out}")


if __name__ == "__main__":
    main()
