"""L2 dot-core matmul model (Pallas tiled) vs jnp reference."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile import model
from compile.kernels import ref
from compile.kernels.dot import matmul_kernel


def _mat(seed, m, n):
    r = np.random.RandomState(seed)
    return jnp.asarray(r.randn(m, n).astype(np.float32))


@pytest.mark.parametrize("n", [16, 32, 64, 128])
def test_square_matmul(n):
    a, b = _mat(n, n, n), _mat(n + 1, n, n)
    out = np.asarray(matmul_kernel(a, b))
    expect = np.asarray(ref.matmul_ref(a, b))
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(16, 32, 48), (32, 16, 16), (48, 64, 32)])
def test_rect_matmul(m, k, n):
    a, b = _mat(1, m, k), _mat(2, k, n)
    out = np.asarray(matmul_kernel(a, b))
    np.testing.assert_allclose(
        out, np.asarray(ref.matmul_ref(a, b)), rtol=1e-5, atol=1e-4
    )


def test_identity():
    a = _mat(3, 32, 32)
    out = np.asarray(matmul_kernel(a, jnp.eye(32, dtype=jnp.float32)))
    np.testing.assert_allclose(out, np.asarray(a), rtol=1e-6)


def test_model_entry_point_mmm32():
    """The exact entry point that becomes artifacts/mmm32.hlo.txt."""
    a, b = _mat(4, 32, 32), _mat(5, 32, 32)
    out = model.dot_core_matmul(a, b)
    assert isinstance(out, tuple) and len(out) == 1
    np.testing.assert_allclose(
        np.asarray(out[0]), np.asarray(a) @ np.asarray(b), rtol=1e-5, atol=1e-4
    )


def test_tile_must_divide():
    with pytest.raises(AssertionError):
        matmul_kernel(_mat(6, 24, 16), _mat(7, 16, 16))


@given(seed=st.integers(0, 2**31 - 1))
def test_matmul_property(seed):
    r = np.random.RandomState(seed)
    m, k, n = (int(r.choice([16, 32])) for _ in range(3))
    a = jnp.asarray(r.randn(m, k).astype(np.float32))
    b = jnp.asarray(r.randn(k, n).astype(np.float32))
    out = np.asarray(matmul_kernel(a, b))
    np.testing.assert_allclose(
        out, np.asarray(a) @ np.asarray(b), rtol=1e-4, atol=1e-3
    )
