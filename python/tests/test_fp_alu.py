"""L1 FP ALU Pallas kernel vs the pure-jnp oracle.

The FP datapath lives inside the DSP blocks in hardware; correctness here
means bit-exact IEEE-754 f32 agreement with ref.fp_op_ref, including the
thread_active writeback gating.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile import model, opmap
from compile.kernels import ref
from compile.kernels.fp_alu import fp_wavefront_kernel

W = opmap.WAVEFRONT_WIDTH


def _blk(seed, depth=8, lo=-100.0, hi=100.0):
    r = np.random.RandomState(seed)
    return jnp.asarray(
        r.uniform(lo, hi, (depth, W)).astype(np.float32)
    )


def _run(op_name, a, b, old=None, mask=None):
    if old is None:
        old = jnp.zeros_like(a)
    if mask is None:
        mask = jnp.ones_like(a)
    idx = opmap.FP_OPS.index(op_name)
    return fp_wavefront_kernel(jnp.int32(idx), a, b, old, mask)


@pytest.mark.parametrize("op", opmap.FP_OPS)
def test_fp_op_matches_ref(op):
    a = _blk(1)
    b = _blk(2)
    if op == "finvsqrt":
        a = jnp.abs(a) + 0.5  # SFU domain: positive inputs
    out = _run(op, a, b)
    expect = ref.fp_op_ref(op, a, b)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("op", ["fadd", "fmul", "fmax"])
def test_writeback_gating_keeps_old(op):
    """Inactive lanes must keep the old Rd value exactly (§3.2)."""
    a, b = _blk(3), _blk(4)
    old = _blk(5)
    r = np.random.RandomState(6)
    mask = jnp.asarray((r.rand(8, W) > 0.5).astype(np.float32))
    out = np.asarray(_run(op, a, b, old, mask))
    expect = np.where(
        np.asarray(mask) != 0,
        np.asarray(ref.fp_op_ref(op, a, b)),
        np.asarray(old),
    )
    np.testing.assert_array_equal(out, expect)


def test_all_lanes_masked_is_identity():
    a, b, old = _blk(7), _blk(8), _blk(9)
    out = _run("fadd", a, b, old, jnp.zeros_like(a))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(old))


def test_model_entry_point_tuple():
    a, b = _blk(10), _blk(11)
    out = model.wavefront_fp(
        jnp.array([[0]], jnp.int32), a, b, jnp.zeros_like(a), jnp.ones_like(a)
    )
    assert isinstance(out, tuple) and len(out) == 1
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(a + b))


def test_fmax_negative_zero_and_inf():
    a = jnp.asarray(np.array([[np.inf, -np.inf, 0.0, 1e38] * 4], np.float32))
    b = jnp.asarray(np.array([[1.0, 1.0, -1.0, 1e38] * 4], np.float32))
    out = np.asarray(_run("fmax", a, b, old=jnp.zeros_like(a)))
    expect = np.maximum(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(out, expect)


def test_finvsqrt_matches_rsqrt_exactly():
    a = jnp.asarray(
        np.random.RandomState(12).uniform(1e-3, 1e6, (8, W)).astype(np.float32)
    )
    out = np.asarray(_run("finvsqrt", a, a))
    expect = np.asarray(ref.fp_op_ref("finvsqrt", a, a))
    np.testing.assert_array_equal(out, expect)


@given(
    seed=st.integers(0, 2**31 - 1),
    op=st.sampled_from([o for o in opmap.FP_OPS if o != "finvsqrt"]),
)
def test_fp_property_random_blocks(seed, op):
    """Hypothesis sweep: random values + random masks, all binary/unary ops."""
    r = np.random.RandomState(seed)
    a = jnp.asarray(r.uniform(-1e6, 1e6, (4, W)).astype(np.float32))
    b = jnp.asarray(r.uniform(-1e6, 1e6, (4, W)).astype(np.float32))
    old = jnp.asarray(r.uniform(-1.0, 1.0, (4, W)).astype(np.float32))
    mask = jnp.asarray((r.rand(4, W) > 0.3).astype(np.float32))
    out = np.asarray(_run(op, a, b, old, mask))
    expect = np.where(
        np.asarray(mask) != 0,
        np.asarray(ref.fp_op_ref(op, a, b)),
        np.asarray(old),
    )
    np.testing.assert_array_equal(out, expect)


@given(depth=st.sampled_from([1, 2, 4, 8, 16, 32, 64]))
def test_fp_depth_sweep(depth):
    """Kernel must work for every wavefront depth the configs can produce."""
    r = np.random.RandomState(depth)
    a = jnp.asarray(r.randn(depth, W).astype(np.float32))
    b = jnp.asarray(r.randn(depth, W).astype(np.float32))
    out = np.asarray(_run("fsub", a, b))
    np.testing.assert_array_equal(out, np.asarray(a) - np.asarray(b))
