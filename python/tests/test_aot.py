"""AOT emission: every artifact lowers, parses as HLO text, and the
opmap/manifest contract the rust runtime depends on is complete."""

import json
import os
import tempfile

import pytest

from compile import aot, opmap


@pytest.fixture(scope="module")
def emitted():
    with tempfile.TemporaryDirectory() as d:
        manifest = aot.emit(d, verbose=False)
        files = {
            name: open(os.path.join(d, meta["file"])).read()
            for name, meta in manifest["artifacts"].items()
        }
        om = json.load(open(os.path.join(d, "opmap.json")))
        mf = json.load(open(os.path.join(d, "manifest.json")))
        yield manifest, files, om, mf


def test_all_expected_artifacts_present(emitted):
    manifest, files, _, _ = emitted
    expected = {"mmm32"}
    for d in opmap.DEPTHS:
        expected |= {f"fp_alu_d{d}", f"int_alu_d{d}", f"dot_d{d}"}
    assert set(manifest["artifacts"]) == expected
    assert set(files) == expected


def test_artifacts_are_hlo_text(emitted):
    """HLO text (never serialized protos — xla_extension 0.5.1 gate)."""
    _, files, _, _ = emitted
    for name, text in files.items():
        assert text.startswith("HloModule"), f"{name} is not HLO text"
        assert "ENTRY" in text, f"{name} missing ENTRY computation"


def test_artifacts_output_is_tuple(emitted):
    """Lowered with return_tuple=True → rust unwraps with to_tuple1()."""
    _, files, _, _ = emitted
    for name, text in files.items():
        roots = [l for l in text.splitlines() if "ROOT" in l and " tuple(" in l]
        assert roots, f"{name} has no ROOT tuple instruction"


def test_fp_artifact_signature(emitted):
    manifest, _, _, _ = emitted
    for d in opmap.DEPTHS:
        args = manifest["artifacts"][f"fp_alu_d{d}"]["args"]
        assert args[0] == [[1, 1], "int32"]
        assert args[1:] == [[[d, 16], "float32"]] * 4


def test_int_artifact_signature(emitted):
    manifest, _, _, _ = emitted
    for d in opmap.DEPTHS:
        args = manifest["artifacts"][f"int_alu_d{d}"]["args"]
        assert args[0] == [[1, 1], "int32"]
        assert args[1] == [[1, 1], "int32"]
        assert args[2:] == [[[d, 16], "int32"]] * 4


def test_opmap_json_matches_module(emitted):
    _, _, om, _ = emitted
    assert om["fp_ops"] == opmap.FP_OPS
    assert om["int_ops"] == opmap.INT_OPS
    assert om["depths"] == opmap.DEPTHS
    assert om["wavefront_width"] == 16


def test_manifest_covers_all_files(emitted):
    manifest, _, _, mf = emitted
    assert mf == manifest


def test_opmap_indices_stable():
    """The rust datapath enum hard-codes these indices; lock them."""
    assert opmap.FP_OPS.index("fadd") == 0
    assert opmap.FP_OPS.index("fmul") == 4
    assert opmap.FP_OPS.index("finvsqrt") == 7
    assert opmap.INT_OPS.index("add") == 0
    assert opmap.INT_OPS.index("bvs") == 13
    assert opmap.INT_OPS.index("shl") == 14
    assert opmap.INT_OPS.index("min_u") == 21
    assert len(opmap.FP_OPS) == 8
    assert len(opmap.INT_OPS) == 22
