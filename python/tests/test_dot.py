"""L1 dot-product / reduction extension core vs the oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile import model, opmap
from compile.kernels import ref
from compile.kernels.dot import dot_kernel

W = opmap.WAVEFRONT_WIDTH


def _blk(seed, depth=8, scale=10.0):
    r = np.random.RandomState(seed)
    return jnp.asarray((r.randn(depth, W) * scale).astype(np.float32))


def test_dot_matches_ref():
    a, b = _blk(1), _blk(2)
    mask = jnp.ones_like(a)
    out = float(dot_kernel(a, b, mask))
    expect = float(ref.dot_ref(a, b, mask))
    assert np.isclose(out, expect, rtol=1e-5)


def test_dot_masked_lanes_excluded():
    a, b = _blk(3), _blk(4)
    mask = np.zeros((8, W), np.float32)
    mask[0, :4] = 1.0  # only first 4 SPs of wavefront 0 (width=1/4, depth=0)
    out = float(dot_kernel(a, b, jnp.asarray(mask)))
    expect = float(np.sum(np.asarray(a)[0, :4] * np.asarray(b)[0, :4]))
    assert np.isclose(out, expect, rtol=1e-5)


def test_dot_zero_mask_is_zero():
    a, b = _blk(5), _blk(6)
    assert float(dot_kernel(a, b, jnp.zeros_like(a))) == 0.0


def test_sum_via_ones_operand():
    """SUM = DOT with b = ones — the rust backend relies on this identity."""
    a = _blk(7)
    mask = jnp.ones_like(a)
    out = float(dot_kernel(a, jnp.ones_like(a), mask))
    expect = float(ref.sum_ref(a, mask))
    assert np.isclose(out, expect, rtol=1e-5)


def test_model_entry_point():
    a, b = _blk(8), _blk(9)
    out = model.wavefront_dot(a, b, jnp.ones_like(a))
    assert isinstance(out, tuple) and len(out) == 1
    assert np.isclose(
        float(out[0]), float(np.sum(np.asarray(a) * np.asarray(b))), rtol=1e-5
    )


@given(
    seed=st.integers(0, 2**31 - 1),
    depth=st.sampled_from([1, 2, 8, 32]),
)
def test_dot_property(seed, depth):
    """Random blocks + random wavefront-subset masks, vs fp64 numpy.

    The Pallas grid accumulates row-by-row (one wavefront per grid step,
    like the hard core accumulates cycle by cycle); compare against the
    same row-ordered f32 accumulation.
    """
    r = np.random.RandomState(seed)
    a = (r.randn(depth, W) * 100).astype(np.float32)
    b = (r.randn(depth, W) * 100).astype(np.float32)
    mask = (r.rand(depth, W) > 0.5).astype(np.float32)
    out = float(dot_kernel(jnp.asarray(a), jnp.asarray(b), jnp.asarray(mask)))
    acc = np.float32(0.0)
    for i in range(depth):
        acc = np.float32(acc + np.sum(a[i] * b[i] * mask[i], dtype=np.float32))
    assert np.isclose(out, float(acc), rtol=1e-4, atol=1e-3)
