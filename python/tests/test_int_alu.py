"""L1 integer ALU Pallas kernel vs the pure-jnp oracle and python ints.

Exact i32 agreement, wrapping semantics, TYPE-variant ops, and the 16-bit
precision truncation of the small ALU configs (§5.2).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile import opmap
from compile.kernels import ref
from compile.kernels.int_alu import int_wavefront_kernel

W = opmap.WAVEFRONT_WIDTH
P32 = jnp.array([[32]], jnp.int32)
P16 = jnp.array([[16]], jnp.int32)


def _iblk(seed, depth=4, lo=-(2**31), hi=2**31):
    r = np.random.RandomState(seed)
    return jnp.asarray(r.randint(lo, hi, (depth, W)).astype(np.int32))


def _run(op_name, a, b, prec=P32, old=None, mask=None):
    if old is None:
        old = jnp.zeros_like(a)
    if mask is None:
        mask = jnp.ones_like(a)
    idx = opmap.INT_OPS.index(op_name)
    return int_wavefront_kernel(jnp.int32(idx), prec, a, b, old, mask)


@pytest.mark.parametrize("op", opmap.INT_OPS)
def test_int_op_matches_ref(op):
    a = _iblk(1)
    b = _iblk(2) if "sh" not in op else _iblk(2, lo=0, hi=32)
    out = np.asarray(_run(op, a, b))
    expect = np.asarray(ref.int_op_ref(op, a, b))
    np.testing.assert_array_equal(out, expect)


def test_add_wraps():
    a = jnp.full((1, W), 2**31 - 1, jnp.int32)
    b = jnp.ones((1, W), jnp.int32)
    out = np.asarray(_run("add", a, b))
    assert (out == -(2**31)).all()


def test_sub_wraps():
    a = jnp.full((1, W), -(2**31), jnp.int32)
    b = jnp.ones((1, W), jnp.int32)
    out = np.asarray(_run("sub", a, b))
    assert (out == 2**31 - 1).all()


def test_mul16_signed_product():
    """MUL16LO yields the full 32-bit product of sign-extended 16-bit lanes."""
    a = jnp.full((1, W), -3 & 0xFFFF, jnp.int32)  # 0xFFFD = sext -3
    b = jnp.full((1, W), 7, jnp.int32)
    lo = np.asarray(_run("mul16lo", a, b))
    hi = np.asarray(_run("mul16hi", a, b))
    assert (lo == -21).all()
    assert (hi == (-21 >> 16)).all()


def test_mul24_full_48bit_product():
    """The mul24 HI path needs a genuine 48-bit intermediate (x64 on)."""
    v = 0x7FFFFF  # max positive 24-bit
    a = jnp.full((1, W), v, jnp.int32)
    hi = np.asarray(_run("mul24hi", a, a))
    assert (hi == (v * v) >> 24).all()
    lo = np.asarray(_run("mul24lo", a, a))
    assert (lo == np.int64(v * v).astype(np.int32)).all()


def test_bvs_involution():
    """bit_reverse(bit_reverse(x)) == x."""
    a = _iblk(3)
    once = _run("bvs", a, a)
    twice = np.asarray(_run("bvs", once, once))
    np.testing.assert_array_equal(twice, np.asarray(a))


def test_bvs_known_values():
    a = jnp.asarray(np.array([[1, 2, 0x80000000 - 2**32, 0b1010] * 4], np.int32))
    out = np.asarray(_run("bvs", a, a)).astype(np.uint32)
    expect = np.array(
        [[0x80000000, 0x40000000, 0x00000001, 0x50000000] * 4], np.uint32
    )
    np.testing.assert_array_equal(out, expect)


def test_pop_known_values():
    a = jnp.asarray(np.array([[0, 1, 0xFF, -1] * 4], np.int32))
    out = np.asarray(_run("pop", a, a))
    np.testing.assert_array_equal(out, np.array([[0, 1, 8, 32] * 4], np.int32))


def test_cnot_semantics():
    a = jnp.asarray(np.array([[0, 1, -5, 0] * 4], np.int32))
    out = np.asarray(_run("cnot", a, a))
    np.testing.assert_array_equal(out, np.array([[1, 0, 0, 1] * 4], np.int32))


def test_shr_arith_vs_logical():
    a = jnp.full((1, W), -16, jnp.int32)
    b = jnp.full((1, W), 2, jnp.int32)
    sa = np.asarray(_run("shr_a", a, b))
    sl = np.asarray(_run("shr_l", a, b))
    assert (sa == -4).all()
    assert (sl == ((0xFFFFFFF0 >> 2) - 2**32 + 2**32)).all()
    assert (sl.astype(np.uint32) == 0x3FFFFFFC).all()


def test_shift_amount_masked_to_5_bits():
    a = jnp.full((1, W), 1, jnp.int32)
    b = jnp.full((1, W), 33, jnp.int32)  # & 31 == 1
    out = np.asarray(_run("shl", a, b))
    assert (out == 2).all()


def test_unsigned_max_min():
    a = jnp.full((1, W), -1, jnp.int32)  # 0xFFFFFFFF unsigned max
    b = jnp.full((1, W), 1, jnp.int32)
    assert (np.asarray(_run("max_u", a, b)) == -1).all()
    assert (np.asarray(_run("min_u", a, b)) == 1).all()
    assert (np.asarray(_run("max_s", a, b)) == 1).all()
    assert (np.asarray(_run("min_s", a, b)) == -1).all()


def test_16bit_precision_truncates():
    """16-bit ALU configs write back the low half zero-extended."""
    a = jnp.full((2, W), 0x12345, jnp.int32)
    b = jnp.full((2, W), 0x1, jnp.int32)
    out = np.asarray(_run("add", a, b, prec=P16))
    assert (out == ((0x12345 + 1) & 0xFFFF)).all()


def test_writeback_gating_int():
    a, b = _iblk(4), _iblk(5)
    old = _iblk(6)
    r = np.random.RandomState(7)
    mask = jnp.asarray((r.rand(4, W) > 0.5).astype(np.int32))
    out = np.asarray(_run("xor", a, b, old=old, mask=mask))
    expect = np.where(
        np.asarray(mask) != 0,
        np.asarray(a) ^ np.asarray(b),
        np.asarray(old),
    )
    np.testing.assert_array_equal(out, expect)


@given(
    seed=st.integers(0, 2**31 - 1),
    op=st.sampled_from(opmap.INT_OPS),
)
def test_int_property_random_blocks(seed, op):
    """Hypothesis sweep: every op, random operands/masks, vs the oracle."""
    r = np.random.RandomState(seed)
    a = jnp.asarray(r.randint(-(2**31), 2**31, (2, W)).astype(np.int32))
    b = jnp.asarray(r.randint(-(2**31), 2**31, (2, W)).astype(np.int32))
    old = jnp.asarray(r.randint(-100, 100, (2, W)).astype(np.int32))
    mask = jnp.asarray((r.rand(2, W) > 0.3).astype(np.int32))
    out = np.asarray(_run(op, a, b, old=old, mask=mask))
    expect = np.where(
        np.asarray(mask) != 0,
        np.asarray(ref.int_op_ref(op, a, b)),
        np.asarray(old),
    )
    np.testing.assert_array_equal(out, expect)


@given(seed=st.integers(0, 2**31 - 1))
def test_int_16bit_property(seed):
    """16-bit truncation applies after the op, before writeback gating."""
    r = np.random.RandomState(seed)
    a = jnp.asarray(r.randint(-(2**31), 2**31, (2, W)).astype(np.int32))
    b = jnp.asarray(r.randint(-(2**31), 2**31, (2, W)).astype(np.int32))
    out = np.asarray(_run("add", a, b, prec=P16))
    expect = np.asarray(
        ref.int_precision_mask_ref(ref.int_op_ref("add", a, b), 16)
    )
    np.testing.assert_array_equal(out, expect)
