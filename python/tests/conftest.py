import os
import sys

# Make `compile` importable when pytest runs from python/ or repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from hypothesis import settings

# interpret-mode Pallas is slow; disable deadlines, keep example counts sane.
settings.register_profile("egpu", deadline=None, max_examples=25)
settings.load_profile("egpu")
