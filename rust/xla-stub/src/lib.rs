//! Offline stub of the xla-rs PJRT bindings.
//!
//! The eGPU crate's XLA datapath (`egpu::runtime`, `egpu::datapath::xla`)
//! is written against the xla-rs API. That crate links the XLA
//! `xla_extension` shared library, which cannot be fetched or built in
//! this offline environment — so this stub provides the exact API surface
//! the crate uses, with every runtime entry point returning a descriptive
//! error instead of executing.
//!
//! Behavioral contract:
//! - Pure host-side constructors ([`Literal::vec1`],
//!   [`XlaComputation::from_proto`]) succeed.
//! - Anything that would touch PJRT ([`PjRtClient::cpu`], compile,
//!   execute, literal readback) fails with [`Error::Unavailable`].
//!
//! The `egpu` code paths that reach these entry points are all gated on
//! the presence of the AOT `artifacts/` directory, so `cargo test` and
//! the examples degrade gracefully. To enable the real backend, replace
//! the `xla = { path = "xla-stub" }` dependency with xla-rs.

use std::fmt;

/// The single error the stub produces.
#[derive(Debug, Clone)]
pub enum Error {
    /// The real XLA/PJRT runtime is not linked into this build.
    Unavailable,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "XLA/PJRT runtime not linked (offline build uses rust/xla-stub; \
             depend on xla-rs to enable the XLA datapath)"
        )
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host-side literal (tensor) handle. The stub carries no data: literals
/// can be constructed (so pure helper code compiles and runs) but any
/// readback fails with [`Error::Unavailable`].
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::Unavailable)
    }

    /// Copy the literal out to a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable)
    }

    /// Unwrap a 1-tuple literal.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::Unavailable)
    }

    /// First element of the flattened literal.
    pub fn get_first_element<T>(&self) -> Result<T> {
        Err(Error::Unavailable)
    }
}

/// Parsed HLO module (text format).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unavailable)
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer returned by an execution.
#[derive(Debug, Clone)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable)
    }
}

/// Compiled executable handle.
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given arguments; results are grouped per device.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable)
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// CPU client — always fails in the stub: there is no runtime.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_is_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("not linked"));
    }

    #[test]
    fn literals_construct_but_do_not_read_back() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2, 1]).is_err());
        assert!(l.to_vec::<f32>().is_err());
    }
}
