//! Regenerates Table 4: fitting results for the DP-memory instances —
//! resource model (ALM/FF/DSP/M20K) and frequency model vs the paper's
//! post-place-and-route numbers.
//!
//!     cargo bench --bench table4_dp_fitting

use egpu::harness::{within_band, Table};
use egpu::model::frequency::FrequencyReport;
use egpu::model::resources::ResourceReport;
use egpu::sim::EgpuConfig;

/// Paper Table 4 rows: (ALM, FF, DSP, M20K, soft-logic Fmax, core Fmax).
const PAPER: [(u32, u32, u32, u32, f64, f64); 6] = [
    (4243, 13635, 24, 50, 1018.0, 771.0),
    (7518, 18992, 24, 98, 898.0, 771.0),
    (7579, 19155, 24, 131, 883.0, 771.0),
    (9754, 25425, 24, 131, 902.0, 771.0),
    (10127, 26040, 32, 195, 860.0, 771.0),
    (10697, 26618, 32, 259, 841.0, 771.0),
];

fn main() {
    let mut t = Table::new("Table 4: Fitting Results - DP Memory, measured (paper)");
    t.headers(["Config", "ALM", "FF", "DSP", "M20K", "SoftMHz", "CoreMHz", "ok"]);
    let mut fail = 0usize;
    for (cfg, p) in EgpuConfig::table4_presets().iter().zip(PAPER) {
        let r = ResourceReport::for_config(cfg);
        let f = FrequencyReport::for_config(cfg);
        let ok = within_band(r.alms as f64, p.0 as f64, 1.15)
            && within_band(r.registers as f64, p.1 as f64, 1.15)
            && r.dsps == p.2
            && r.m20ks == p.3
            && within_band(f.soft_mhz, p.4, 1.15)
            && f.core_mhz == p.5;
        if !ok {
            fail += 1;
        }
        t.row([
            cfg.name.clone(),
            format!("{} ({})", r.alms, p.0),
            format!("{} ({})", r.registers, p.1),
            format!("{} ({})", r.dsps, p.2),
            format!("{} ({})", r.m20ks, p.3),
            format!("{:.0} ({:.0})", f.soft_mhz, p.4),
            format!("{:.0} ({:.0})", f.core_mhz, p.5),
            if ok { "yes".into() } else { "NO".to_string() },
        ]);
    }
    t.print();
    println!("\nall instances close timing at the 771 MHz DSP limit (§6)");
    if fail > 0 {
        eprintln!("{fail} rows outside tolerance");
        std::process::exit(1);
    }
}
