//! Regenerates Table 1: resource comparison and the PPA metric across
//! published soft GPGPUs vs the eGPU model.
//!
//!     cargo bench --bench table1_comparison

use egpu::harness::{within_band, Table};
use egpu::model::cost::{normalized_cost, ppa_metric, TABLE1_PUBLISHED};
use egpu::model::resources::ResourceReport;
use egpu::sim::EgpuConfig;

fn main() {
    // Paper Table 1 PPA column: FGPU 36, DO-GPU 133, FlexGrip 175, eGPU 1.
    let paper_ppa = [36.0, 133.0, 175.0];
    let mut t = Table::new("Table 1: Resource Comparison");
    t.headers(["Architecture", "Config", "LUTs", "DSP", "FMax", "PPA (paper)", "Device"]);
    let mut fail = 0;
    for (row, paper) in TABLE1_PUBLISHED.iter().zip(paper_ppa) {
        let ppa = ppa_metric(row.luts as f64, row.dsps as f64, row.fmax_mhz);
        if !within_band(ppa, paper, 2.0) {
            fail += 1;
        }
        t.row([
            row.arch.to_string(),
            row.config.to_string(),
            format!("{}K", row.luts / 1000),
            row.dsps.to_string(),
            format!("{:.0}", row.fmax_mhz),
            format!("{ppa:.0} ({paper:.0})"),
            row.device.to_string(),
        ]);
    }
    let small = EgpuConfig::table4_presets().into_iter().next().unwrap();
    let r = ResourceReport::for_config(&small);
    t.row([
        "eGPU".into(),
        "1SMx16SP".into(),
        format!("{}K ({}ALM)", r.alms / 1000, r.alms),
        r.dsps.to_string(),
        "771".into(),
        "1 (1)".into(),
        "Agilex".to_string(),
    ]);
    t.print();
    println!(
        "\neGPU normalized cost: {:.0} ALM-equivalents (5K LUT / 24 DSP class)",
        normalized_cost(r.alms, r.dsps)
    );
    println!(
        "PPA gap vs nearest prior work: {:.0}x",
        ppa_metric(57_000.0, 48.0, 250.0)
    );
    if fail > 0 {
        eprintln!("{fail} PPA cells outside the 2x band");
        std::process::exit(1);
    }
}
