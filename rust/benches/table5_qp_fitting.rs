//! Regenerates Table 5: fitting results for the QP-memory instances.
//!
//!     cargo bench --bench table5_qp_fitting

use egpu::harness::{within_band, Table};
use egpu::model::frequency::FrequencyReport;
use egpu::model::resources::ResourceReport;
use egpu::sim::EgpuConfig;

/// Paper Table 5 rows: (ALM, FF, DSP, M20K, soft Fmax, core Fmax).
const PAPER: [(u32, u32, u32, u32, f64, f64); 4] = [
    (5468, 14487, 24, 99, 840.0, 600.0),
    (7057, 16722, 32, 131, 763.0, 600.0),
    (11314, 25050, 32, 131, 763.0, 600.0),
    (10174, 23094, 32, 195, 714.0, 600.0),
];

fn main() {
    let mut t = Table::new("Table 5: Fitting Results - QP Memory, measured (paper)");
    t.headers(["Config", "ALM", "FF", "DSP", "M20K", "SoftMHz", "CoreMHz", "ok"]);
    let mut fail = 0usize;
    for (cfg, p) in EgpuConfig::table5_presets().iter().zip(PAPER) {
        let r = ResourceReport::for_config(cfg);
        let f = FrequencyReport::for_config(cfg);
        let ok = within_band(r.alms as f64, p.0 as f64, 1.15)
            && within_band(r.registers as f64, p.1 as f64, 1.15)
            && r.dsps == p.2
            && (r.m20ks as i64 - p.3 as i64).abs() <= 1
            && within_band(f.soft_mhz, p.4, 1.15)
            && f.core_mhz == p.5;
        if !ok {
            fail += 1;
        }
        t.row([
            cfg.name.clone(),
            format!("{} ({})", r.alms, p.0),
            format!("{} ({})", r.registers, p.1),
            format!("{} ({})", r.dsps, p.2),
            format!("{} ({})", r.m20ks, p.3),
            format!("{:.0} ({:.0})", f.soft_mhz, p.4),
            format!("{:.0} ({:.0})", f.core_mhz, p.5),
            if ok { "yes".into() } else { "NO".to_string() },
        ]);
    }
    t.print();
    println!("\nQP M20Ks cap the core at 600 MHz; halved M20K count, doubled write ports (§3, §5.1)");
    if fail > 0 {
        eprintln!("{fail} rows outside tolerance");
        std::process::exit(1);
    }
}
