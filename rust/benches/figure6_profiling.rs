//! Regenerates Figure 6: stacked instruction-mix profiles (proportion of
//! execution cycles by instruction type) for every benchmark × dimension,
//! on the eGPU-DP and eGPU-QP variants, as ASCII bars.
//!
//! Checks the figure's qualitative claims: memory ops dominate, FP is
//! ~10% on reduction/FFT, NOPs shrink as wavefront depth grows, and
//! bitonic shows predicate + branch activity.
//!
//!     cargo bench --bench figure6_profiling

use egpu::harness::suite::{self, Benchmark, Variant};
use egpu::isa::Group;
use egpu::sim::Profile;

const BAR: usize = 50;

fn bar(p: &Profile) -> String {
    // One character class per group, proportional to cycle share.
    let glyphs = [
        (Group::Nop, '.'),
        (Group::IntArith, 'i'),
        (Group::IntMul, 'i'),
        (Group::IntLogic, 'i'),
        (Group::IntShift, 'i'),
        (Group::IntOther, 'i'),
        (Group::FpAlu, 'F'),
        (Group::Memory, 'M'),
        (Group::Immediate, 'l'),
        (Group::Thread, 't'),
        (Group::Extension, 'X'),
        (Group::Control, 'B'),
        (Group::Conditional, 'P'),
    ];
    let mut s = String::new();
    for (g, ch) in glyphs {
        let n = (p.cycle_fraction(g) * BAR as f64).round() as usize;
        s.extend(std::iter::repeat_n(ch, n));
    }
    while s.len() < BAR {
        s.push(' ');
    }
    s.truncate(BAR);
    s
}

fn main() {
    println!("Figure 6: cycle mix by type ('.'=NOP i=INT F=FP M=Memory l=LDI t=TID X=ext B=branch P=predicate)\n");
    let mut nop_shrinks = 0usize;
    let mut checked = 0usize;
    for b in Benchmark::ALL {
        let mut last_nop = f64::MAX;
        for &dim in b.dims() {
            let r = suite::run(b, dim);
            for (label, m) in [("DP", &r.dp), ("QP", &r.qp)] {
                let p = m.profile.as_ref().unwrap();
                println!("{:<16} {:>4} {label}: |{}|", b.name(), dim, bar(p));
            }
            let p = r.dp.profile.as_ref().unwrap();
            // Claim checks on the DP profile.
            let mem = p.cycle_fraction(Group::Memory);
            assert!(
                mem > 0.30,
                "{b:?}-{dim}: memory should dominate, got {mem:.2}"
            );
            if b == Benchmark::Fft || b == Benchmark::Reduction {
                let fp = p.cycle_fraction(Group::FpAlu);
                assert!(
                    (0.02..=0.25).contains(&fp),
                    "{b:?}-{dim}: FP fraction {fp:.2} (paper: ~10%)"
                );
            }
            if b == Benchmark::Bitonic {
                assert!(p.cycle_fraction(Group::Conditional) > 0.0, "predicates used");
                assert!(p.cycle_fraction(Group::Control) > 0.0, "subroutine calls");
            }
            let nop = p.cycle_fraction(Group::Nop);
            checked += 1;
            // Small absolute slack: the list scheduler fills delay slots
            // most aggressively at shallow dims, which can locally flatten
            // the NOP-share curve without breaking the paper's trend.
            if nop <= last_nop + 0.03 {
                nop_shrinks += 1;
            }
            last_nop = nop;
        }
        println!();
    }
    // §7: "The smaller sorts require many NOPs, which progressively
    // reduce as the number of wavefronts increase for the larger
    // datasets" — monotone NOP shrink per benchmark.
    assert_eq!(nop_shrinks, checked, "NOP share must shrink with dim");
    println!("claims verified: memory dominates; FP ~10% on FFT/reduction; NOPs shrink with depth");
}
