//! Simulator performance harness (EXPERIMENTS.md §Perf): wall-clock
//! throughput of the cycle-accurate core on the benchmark suite, for both
//! the default checked mode and the verified-program fast path (hazard
//! checking off).
//!
//! This is the L3 hot path the PERFORMANCE OPTIMIZATION pass iterates on;
//! run before/after each change.
//!
//!     cargo bench --bench perf_simulator

use egpu::api::Gpu;
use egpu::harness::{sim_rate, time, Rng, Table};
use egpu::kernels::{bitonic, f32_bits, fft, mmm, reduction, transpose, Kernel};
use egpu::sim::{EgpuConfig, MemoryMode};

fn run_once(kernel: &Kernel, cfg: &EgpuConfig, init: &[(usize, Vec<u32>)], hazards: bool) -> u64 {
    let mut gpu = Gpu::new(cfg).unwrap();
    for (b, d) in init {
        gpu.write_words(*b, d).unwrap();
    }
    gpu.launch(kernel)
        .hazard_checking(hazards)
        .run()
        .unwrap()
        .compute_cycles
}

fn main() {
    let mut rng = Rng::new(0xBE);
    let samples = 7;
    let mut t = Table::new("Simulator throughput (simulated cycles per wall-clock second)");
    t.headers(["kernel", "cycles", "checked", "unchecked", "Mcyc/s", "Mcyc/s (fast)", "wall(ms)"]);

    let base = EgpuConfig::benchmark(MemoryMode::Dp, false);
    let pred = EgpuConfig::benchmark_predicated(MemoryMode::Dp);
    let n = 128usize;
    let vecd: Vec<u32> = f32_bits(&(0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect::<Vec<_>>());
    let mat: Vec<u32> = (0..n * n).map(|_| rng.next_u32()).collect();
    let a: Vec<u32> = f32_bits(&(0..n * n).map(|_| rng.f32_in(-1.0, 1.0)).collect::<Vec<_>>());
    let b: Vec<u32> = f32_bits(&(0..n * n).map(|_| rng.f32_in(-1.0, 1.0)).collect::<Vec<_>>());
    let sortd: Vec<u32> = (0..256).map(|_| rng.next_u32()).collect();
    let re: Vec<f32> = (0..256).map(|_| rng.f32_in(-1.0, 1.0)).collect();
    let im = vec![0f32; 256];

    let cases: Vec<(Kernel, EgpuConfig, Vec<(usize, Vec<u32>)>)> = vec![
        (reduction::reduction(n), base.clone(), vec![(0, vecd)]),
        (transpose::transpose(n), base.clone(), vec![(0, mat)]),
        (
            mmm::mmm(n),
            mmm::config(n, MemoryMode::Dp, false),
            vec![(0, a.clone()), (n * n, b.clone())],
        ),
        (bitonic::bitonic(256), pred, vec![(0, sortd)]),
        (fft::fft(256), base, fft::shared_init(&re, &im)),
    ];

    let mut total_cycles = 0u64;
    let mut total_ms = 0f64;
    for (kernel, cfg, init) in &cases {
        let cycles = run_once(kernel, cfg, init, true);
        let checked = time(samples, || run_once(kernel, cfg, init, true));
        let fast = time(samples, || run_once(kernel, cfg, init, false));
        total_cycles += cycles;
        total_ms += fast.median_ms();
        t.row([
            kernel.name.clone(),
            cycles.to_string(),
            format!("{:.2}ms", checked.median_ms()),
            format!("{:.2}ms", fast.median_ms()),
            format!("{:.1}", sim_rate(cycles, &checked) / 1e6),
            format!("{:.1}", sim_rate(cycles, &fast) / 1e6),
            format!("{:.2}", fast.median_ms()),
        ]);
    }
    t.print();
    println!(
        "\naggregate: {:.1} M simulated cycles/s (fast path) over {} kernels",
        total_cycles as f64 / total_ms / 1e3,
        cases.len()
    );
    println!("target: simulate 771 MHz real time / 1000 => >= 0.77 Mcyc/s (trivially exceeded);");
    println!("practical target: > 50 Mcyc/s on MMM-class kernels so the full suite stays < 5 s");
}
