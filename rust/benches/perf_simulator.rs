//! Simulator performance harness (EXPERIMENTS.md §Perf): wall-clock
//! throughput of the cycle-accurate core on the benchmark suite, for both
//! the default checked mode and the verified-program fast path (hazard
//! checking off), plus a multi-core scaling row (sequential vs parallel
//! dispatch of a 4-core `GpuArray`).
//!
//! This is the L3 hot path the PERFORMANCE OPTIMIZATION pass iterates on;
//! run before/after each change. Besides the human-readable table it
//! emits machine-readable `BENCH_simulator.json` into the working
//! directory so the repo's perf trajectory can be tracked across PRs.
//!
//!     cargo bench --bench perf_simulator
//!
//! `EGPU_BENCH_SAMPLES` overrides the per-case sample count (CI smoke
//! runs use 1).

use egpu::api::{synthesize, AreaBudget, FleetBuilder, Gpu, KernelCache, Server, SynthOptions};
use egpu::harness::loadgen::{demo_requests, heavy_tail_requests, BurstSpec, LoadSpec};
use egpu::harness::{demo_job_io, demo_specs, sim_rate, time, Rng, Table, Timing};
use egpu::kc::SchedMode;
use egpu::kernels::{bitonic, f32_bits, fft, fft4, mmm, reduction, transpose, Kernel};
use egpu::sim::{EgpuConfig, MemoryMode, TraceStats};

fn run_once(kernel: &Kernel, cfg: &EgpuConfig, init: &[(usize, Vec<u32>)], hazards: bool) -> u64 {
    let mut gpu = Gpu::new(cfg).unwrap();
    for (b, d) in init {
        gpu.write_words(*b, d).unwrap();
    }
    gpu.launch(kernel)
        .hazard_checking(hazards)
        .run()
        .unwrap()
        .compute_cycles
}

/// One full run for the superplan coverage numbers: trace count, mean
/// trace length, and the share of dynamic instructions retired inside
/// fused traces.
fn trace_stats_once(kernel: &Kernel, cfg: &EgpuConfig, init: &[(usize, Vec<u32>)]) -> TraceStats {
    let mut gpu = Gpu::new(cfg).unwrap();
    for (b, d) in init {
        gpu.write_words(*b, d).unwrap();
    }
    gpu.launch(kernel).run().unwrap();
    gpu.machine().trace_stats()
}

/// Wall-clock a 4-job FFT batch through a 4-core `GpuArray`, with the
/// dispatch mode under test. Returns (makespan, timing).
fn run_array(samples: usize, parallel: bool) -> (u64, Timing) {
    let n = 256usize;
    let mut rng = Rng::new(0xA44A);
    let re: Vec<f32> = (0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
    let im = vec![0f32; n];
    let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
    let mut makespan = 0;
    let t = time(samples, || {
        let mut array = Gpu::builder().config(cfg.clone()).build_array(4).unwrap();
        array.set_parallel(parallel);
        for _ in 0..4 {
            let s = array.stream();
            let mut launch = array.launch_on(&s, fft::fft(n)).output(0, 2 * n);
            for (base, words) in fft::shared_init(&re, &im) {
                launch = launch.input_words(base, words);
            }
            launch.submit();
        }
        let reports = array.sync().unwrap();
        makespan = array.makespan();
        reports.len()
    });
    (makespan, t)
}

/// Minimal JSON string escaping (kernel names are plain ASCII, but stay
/// correct anyway).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn main() {
    let samples = std::env::var("EGPU_BENCH_SAMPLES")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&s| s >= 1)
        .unwrap_or(7);
    let mut rng = Rng::new(0xBE);
    let mut t = Table::new("Simulator throughput (simulated cycles per wall-clock second)");
    t.headers(["kernel", "cycles", "checked", "unchecked", "Mcyc/s", "Mcyc/s (fast)", "wall(ms)"]);

    let base = EgpuConfig::benchmark(MemoryMode::Dp, false);
    let pred = EgpuConfig::benchmark_predicated(MemoryMode::Dp);
    let n = 128usize;
    let vecd: Vec<u32> = f32_bits(&(0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect::<Vec<_>>());
    let mat: Vec<u32> = (0..n * n).map(|_| rng.next_u32()).collect();
    let a: Vec<u32> = f32_bits(&(0..n * n).map(|_| rng.f32_in(-1.0, 1.0)).collect::<Vec<_>>());
    let b: Vec<u32> = f32_bits(&(0..n * n).map(|_| rng.f32_in(-1.0, 1.0)).collect::<Vec<_>>());
    let sortd: Vec<u32> = (0..256).map(|_| rng.next_u32()).collect();
    let re: Vec<f32> = (0..256).map(|_| rng.f32_in(-1.0, 1.0)).collect();
    let im = vec![0f32; 256];

    let cases: Vec<(Kernel, EgpuConfig, Vec<(usize, Vec<u32>)>)> = vec![
        (reduction::reduction(n), base.clone(), vec![(0, vecd)]),
        (transpose::transpose(n), base.clone(), vec![(0, mat)]),
        (
            mmm::mmm(n),
            mmm::config(n, MemoryMode::Dp, false),
            vec![(0, a.clone()), (n * n, b.clone())],
        ),
        (bitonic::bitonic(256), pred, vec![(0, sortd)]),
        (fft::fft(256), base, fft::shared_init(&re, &im)),
    ];

    let mut total_cycles = 0u64;
    let mut total_ms = 0f64;
    let mut kernel_rows = Vec::new();
    let mut superplan_rows = Vec::new();
    let mut total_traces = 0usize;
    let (mut fused_dyn, mut total_dyn) = (0u64, 0u64);
    for (kernel, cfg, init) in &cases {
        let ts = trace_stats_once(kernel, cfg, init);
        assert!(
            ts.traces > 0 && ts.fused_retired > 0,
            "{}: the superplan compiler must fuse straight-line runs",
            kernel.name
        );
        total_traces += ts.traces;
        fused_dyn += ts.fused_retired;
        total_dyn += ts.retired;
        superplan_rows.push(format!(
            "    {{\"name\": {}, \"traces\": {}, \"fused_pcs\": {}, \"program_pcs\": {}, \
             \"mean_trace_len\": {:.2}, \"dynamic_fused_pct\": {:.2}}}",
            json_str(&kernel.name),
            ts.traces,
            ts.fused_pcs,
            ts.program_pcs,
            ts.mean_trace_len,
            ts.dynamic_fused_pct(),
        ));
        let cycles = run_once(kernel, cfg, init, true);
        let checked = time(samples, || run_once(kernel, cfg, init, true));
        let fast = time(samples, || run_once(kernel, cfg, init, false));
        total_cycles += cycles;
        total_ms += fast.median_ms();
        let mcyc_checked = sim_rate(cycles, &checked) / 1e6;
        let mcyc_fast = sim_rate(cycles, &fast) / 1e6;
        t.row([
            kernel.name.clone(),
            cycles.to_string(),
            format!("{:.2}ms", checked.median_ms()),
            format!("{:.2}ms", fast.median_ms()),
            format!("{mcyc_checked:.1}"),
            format!("{mcyc_fast:.1}"),
            format!("{:.2}", fast.median_ms()),
        ]);
        kernel_rows.push(format!(
            "    {{\"name\": {}, \"cycles\": {cycles}, \"checked_ms\": {:.4}, \
             \"unchecked_ms\": {:.4}, \"mcyc_per_s_checked\": {mcyc_checked:.2}, \
             \"mcyc_per_s_unchecked\": {mcyc_fast:.2}}}",
            json_str(&kernel.name),
            checked.median_ms(),
            fast.median_ms(),
        ));
    }
    t.print();
    let aggregate = total_cycles as f64 / total_ms / 1e3;
    println!(
        "\naggregate: {:.1} M simulated cycles/s (fast path) over {} kernels",
        aggregate,
        cases.len()
    );
    let fused_pct = 100.0 * fused_dyn as f64 / total_dyn as f64;
    println!(
        "superplan coverage: {total_traces} traces across {} kernels, \
         {fused_dyn}/{total_dyn} dynamic instructions fused ({fused_pct:.1}%)",
        cases.len()
    );
    let superplan_json = format!(
        "  \"superplan\": {{\"traces\": {total_traces}, \"dynamic_fused_pct\": {fused_pct:.2}, \
         \"kernels\": [\n{}\n  ]}},\n",
        superplan_rows.join(",\n"),
    );

    // Static-schedule section: the kernel compiler's modeled-cycle win at
    // shallow configurations (16-64 threads), where delay slots dominate.
    // Every kernel is run in all three build modes — list-scheduled,
    // linear (in-order padding, the legacy emitters' behavior) and fenced
    // (schedule disabled) — through the same machine.
    type BuildFn = Box<dyn Fn(SchedMode) -> Kernel>;
    fn f32v(rng: &mut Rng, n: usize) -> Vec<u32> {
        let v: Vec<f32> = (0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
        f32_bits(&v)
    }
    let sched_cases: Vec<(BuildFn, EgpuConfig, Vec<(usize, Vec<u32>)>)> = {
        let mut rng = Rng::new(0x5C4ED);
        let v32 = f32v(&mut rng, 32);
        let m32: Vec<u32> = (0..32 * 32).map(|_| rng.next_u32()).collect();
        let a32 = f32v(&mut rng, 32 * 32);
        let b32 = f32v(&mut rng, 32 * 32);
        let s64: Vec<u32> = (0..64).map(|_| rng.next_u32()).collect();
        let re64: Vec<f32> = (0..64).map(|_| rng.f32_in(-1.0, 1.0)).collect();
        let im64 = vec![0f32; 64];
        let base = EgpuConfig::benchmark(MemoryMode::Dp, false);
        let pred = EgpuConfig::benchmark_predicated(MemoryMode::Dp);
        vec![
            (
                Box::new(|m| reduction::reduction_mode(32, m)) as BuildFn,
                base.clone(),
                vec![(0, v32)],
            ),
            (
                Box::new(|m| transpose::transpose_mode(32, MemoryMode::Dp, m)),
                base.clone(),
                vec![(0, m32)],
            ),
            (
                Box::new(|m| mmm::mmm_mode(32, MemoryMode::Dp, m)),
                mmm::config(32, MemoryMode::Dp, false),
                vec![(0, a32), (32 * 32, b32)],
            ),
            (
                Box::new(|m| bitonic::bitonic_mode(64, MemoryMode::Dp, m)),
                pred,
                vec![(0, s64)],
            ),
            (
                Box::new(|m| fft::fft_mode(64, MemoryMode::Dp, m)),
                base.clone(),
                fft::shared_init(&re64, &im64),
            ),
            (
                Box::new(|m| fft4::fft4_mode(64, MemoryMode::Dp, m)),
                base,
                fft4::shared_init(&re64, &im64),
            ),
        ]
    };
    let mut t2 = Table::new(
        "Kernel compiler: modeled cycles at shallow dims (list vs padded vs fenced)",
    );
    t2.headers([
        "kernel", "instrs", "NOPs pad", "NOPs list", "cyc fenced", "cyc pad", "cyc list",
        "vs pad", "vs fenced",
    ]);
    let mut sched_rows = Vec::new();
    for (build, cfg, init) in &sched_cases {
        let list = build(SchedMode::List);
        let linear = build(SchedMode::Linear);
        let fenced = build(SchedMode::Fenced);
        let cy_list = run_once(&list, cfg, init, true);
        let cy_lin = run_once(&linear, cfg, init, true);
        let cy_fen = run_once(&fenced, cfg, init, true);
        let st = list.sched.as_ref().expect("compiled kernels carry stats");
        let vs_lin = 100.0 * (1.0 - cy_list as f64 / cy_lin as f64);
        let vs_fen = 100.0 * (1.0 - cy_list as f64 / cy_fen as f64);
        t2.row([
            list.name.clone(),
            st.instructions.to_string(),
            st.nops_linear.to_string(),
            st.nops_scheduled.to_string(),
            cy_fen.to_string(),
            cy_lin.to_string(),
            cy_list.to_string(),
            format!("{vs_lin:.1}%"),
            format!("{vs_fen:.1}%"),
        ]);
        sched_rows.push(format!(
            "    {{\"name\": {}, \"instructions\": {}, \"nops_linear\": {}, \
             \"nops_scheduled\": {}, \"cycles_fenced\": {cy_fen}, \
             \"cycles_linear\": {cy_lin}, \"cycles_scheduled\": {cy_list}, \
             \"reduction_vs_linear_pct\": {vs_lin:.2}, \
             \"reduction_vs_fenced_pct\": {vs_fen:.2}}}",
            json_str(&list.name),
            st.instructions,
            st.nops_linear,
            st.nops_scheduled,
        ));
        assert!(
            cy_list <= cy_lin && cy_lin <= cy_fen,
            "{}: schedule modes must be ordered (list {cy_list}, pad {cy_lin}, fenced {cy_fen})",
            list.name
        );
    }
    t2.print();
    println!();

    // Heterogeneous fleet: a mixed kernel batch over 2 × 771 MHz DP
    // (predicates + dot core) + 2 × 600 MHz QP cores — modeled
    // throughput and per-core utilization of the feature-routed,
    // wall-clock-aware dispatcher, plus the kernel cache's economics.
    let fleet_json = {
        let cache = KernelCache::shared();
        let mut fleet = FleetBuilder::demo_mixed().kernel_cache(cache.clone()).build().unwrap();
        let mut rng = Rng::new(0xF1EE7);
        let specs = demo_specs(64);
        let jobs = 12usize;
        for j in 0..jobs {
            let spec = specs[j % specs.len()];
            let (loads, unloads) = demo_job_io(&spec, &mut rng);
            let mut launch = fleet.launch_spec_any(spec).unwrap();
            for (base, data) in loads {
                launch = launch.input_words(base, data);
            }
            for (base, len) in unloads {
                launch = launch.output(base, len);
            }
            launch.submit();
        }
        let reports = fleet.sync().unwrap();
        let span_us = fleet.makespan_us();
        let jobs_per_s = reports.len() as f64 / (span_us * 1e-6);
        let util = fleet.core_utilization();
        let stats = cache.stats();
        let core_rows: Vec<String> = (0..fleet.num_cores())
            .map(|c| {
                format!(
                    "      {{\"name\": {}, \"mhz\": {:.0}, \"jobs\": {}, \
                     \"utilization\": {:.4}}}",
                    json_str(&fleet.core_configs()[c].name),
                    fleet.coordinator().core_mhz(c),
                    reports.iter().filter(|r| r.core == c).count(),
                    util[c],
                )
            })
            .collect();
        println!(
            "heterogeneous fleet (2x771 DP + 2x600 QP, {jobs} mixed jobs): \
             {jobs_per_s:.0} modeled jobs/s, {} kernel compiles for {} launches",
            stats.compiles, jobs
        );
        assert!(
            reports
                .iter()
                .filter(|r| r.requires.predicate_depth > 0 || r.requires.dot_core)
                .all(|r| r.core < 2),
            "feature routing must keep predicated/dot jobs on the DP cores"
        );
        format!(
            "  \"fleet\": {{\"jobs\": {jobs}, \"makespan_cycles\": {}, \
             \"modeled_jobs_per_s\": {jobs_per_s:.1}, \"cache_compiles\": {}, \
             \"cache_hits\": {}, \"cores\": [\n{}\n    ]}},\n",
            fleet.makespan(),
            stats.compiles,
            stats.hits,
            core_rows.join(",\n"),
        )
    };

    // Serving: the continuous runtime (bounded admission + deadline
    // batcher) over the same demo fleet, driven by the reference
    // seeded trace. Modeled numbers — sustained requests/s, shed rate,
    // latency percentiles, per-core utilization — are deterministic
    // (independent of EGPU_BENCH_SAMPLES and of dispatch mode).
    let serving_json = {
        let mut server = Server::builder().build().unwrap();
        let offered = 60usize;
        let wall = std::time::Instant::now();
        let report = server.serve(demo_requests(&LoadSpec::demo(offered))).unwrap();
        let wall_s = wall.elapsed().as_secs_f64().max(1e-9);
        let t = &report.telemetry;
        let mhz = server.bus_mhz();
        let rps = t.jobs_per_s(mhz);
        let wall_jobs_per_s = t.completed as f64 / wall_s;
        let reuse = server.reuse_stats();
        // Shed-reason breakdown from the unified metrics registry (the
        // aggregate telemetry only carries the total).
        let metrics = server.metrics();
        let shed_queue_full = metrics.counter("serve.shed.queue_full");
        let shed_deadline_expired = metrics.counter("serve.shed.deadline_expired");
        assert_eq!(
            shed_queue_full + shed_deadline_expired,
            t.shed,
            "shed reasons must add up to the shed total"
        );
        assert!(t.completed > 0, "the serving bench must serve something");
        assert_eq!(report.submitted(), offered, "every request served or shed");
        let util = server.core_utilization();
        let core_rows: Vec<String> = (0..server.num_cores())
            .map(|c| {
                format!(
                    "      {{\"name\": {}, \"mhz\": {:.0}, \"requests\": {}, \
                     \"utilization\": {:.4}}}",
                    json_str(&server.fleet().core_configs()[c].name),
                    server.fleet().coordinator().core_mhz(c),
                    report.results.iter().filter(|r| r.core == c).count(),
                    util[c],
                )
            })
            .collect();
        println!(
            "serving ({offered} offered): {} served, {} shed, {} batches, \
             {rps:.0} requests/s, p99 e2e {:.1} us, wall {wall_jobs_per_s:.0} jobs/s, \
             machine reuse {}/{} (hits/misses)",
            t.completed,
            t.shed,
            t.batches,
            t.e2e.p99() as f64 / mhz,
            reuse.hits,
            reuse.misses
        );
        format!(
            "  \"serving\": {{\"offered\": {offered}, \"completed\": {}, \"shed\": {}, \
             \"shed_queue_full\": {shed_queue_full}, \
             \"shed_deadline_expired\": {shed_deadline_expired}, \
             \"batches\": {}, \"requests_per_s\": {rps:.1}, \"wall_jobs_per_s\": \
             {wall_jobs_per_s:.1}, \"reuse_hits\": {}, \"reuse_misses\": {}, \
             \"shed_rate\": {:.4}, \
             \"deadline_missed\": {}, \"peak_queue\": {}, \"queue_wait_p50_us\": {:.3}, \
             \"e2e_p50_us\": {:.3}, \"e2e_p95_us\": {:.3}, \"e2e_p99_us\": {:.3}, \
             \"cores\": [\n{}\n    ]}},\n",
            t.completed,
            t.shed,
            t.batches,
            reuse.hits,
            reuse.misses,
            t.shed_rate(),
            t.deadline_missed,
            t.peak_queue,
            t.queue_wait.p50() as f64 / mhz,
            t.e2e.p50() as f64 / mhz,
            t.e2e.p95() as f64 / mhz,
            t.e2e.p99() as f64 / mhz,
            core_rows.join(",\n"),
        )
    };

    // Dispatch plane: steady-state serve rounds over one warmed server —
    // the persistent-pool + superplan-cache hot path. After a warmup
    // round, every round replays the identical trace on a fresh
    // measurement window; steady-state rounds must spawn no worker
    // threads and compile nothing (kernels or fused superplans).
    let dispatch_json = {
        let mut server = Server::builder().build().unwrap();
        let trace = demo_requests(&LoadSpec::demo(40));
        let warm = server.serve_slice(&trace).unwrap();
        assert!(warm.telemetry.completed > 0, "the warmup round must serve");
        let warm_superplans = server.superplan_stats().compiles;
        let warm_kernels = server.cache_stats().compiles;
        let rounds = samples.max(3);
        let wall = std::time::Instant::now();
        for _ in 0..rounds {
            server.reset_timeline();
            let r = server.serve_slice(&trace).unwrap();
            assert_eq!(
                r.telemetry.completed, warm.telemetry.completed,
                "steady-state rounds must serve the identical workload"
            );
        }
        let wall_s = wall.elapsed().as_secs_f64().max(1e-9);
        let steady_batches_per_s = (rounds as u64 * warm.telemetry.batches) as f64 / wall_s;
        let sp = server.superplan_stats();
        let steady_superplan_compiles = sp.compiles - warm_superplans;
        let steady_kernel_compiles = server.cache_stats().compiles - warm_kernels;
        let pool_spawns = server.pool_spawns();
        assert_eq!(
            steady_superplan_compiles, 0,
            "steady-state rounds must not recompile superplans"
        );
        assert_eq!(pool_spawns, 1, "one worker-pool spawn per server lifetime");
        println!(
            "dispatch ({rounds} steady rounds): {steady_batches_per_s:.0} batches/s wall, \
             pool spawns {pool_spawns}, superplan {}/{} (compiles/hits), \
             0 steady-state recompiles",
            sp.compiles, sp.hits
        );
        format!(
            "  \"dispatch\": {{\"rounds\": {rounds}, \"steady_batches_per_s\": \
             {steady_batches_per_s:.1}, \"pool_spawns\": {pool_spawns}, \
             \"pool_revives\": {}, \"superplan_compiles\": {}, \"superplan_hits\": {}, \
             \"superplan_entries\": {}, \
             \"steady_superplan_compiles\": {steady_superplan_compiles}, \
             \"steady_kernel_compiles\": {steady_kernel_compiles}}},\n",
            server.pool_revives(),
            sp.compiles,
            sp.hits,
            sp.entries,
        )
    };

    // Observability: the same steady-state replay with the recorder off
    // vs on. Recording must not move a single modeled cycle (the reports
    // are asserted identical), so the only cost is wall clock — and that
    // overhead is capped by check_bench_regression.py against
    // BENCH_baseline.json.
    let observability_json = {
        let trace = demo_requests(&LoadSpec::demo(40));
        let rounds = samples.max(3);

        let mut plain = Server::builder().build().unwrap();
        let warm_plain = plain.serve_slice(&trace).unwrap();
        let wall = std::time::Instant::now();
        for _ in 0..rounds {
            plain.reset_timeline();
            let r = plain.serve_slice(&trace).unwrap();
            assert_eq!(r, warm_plain, "steady-state rounds replay identically");
        }
        let off_s = wall.elapsed().as_secs_f64().max(1e-9);

        let mut traced = Server::builder().recording(true).build().unwrap();
        let warm_traced = traced.serve_slice(&trace).unwrap();
        assert_eq!(
            warm_traced, warm_plain,
            "recording must not change the modeled serve report"
        );
        let rec = traced.recorder().expect("recording server has a recorder");
        let mut events = 0usize;
        let wall = std::time::Instant::now();
        for _ in 0..rounds {
            traced.reset_timeline();
            rec.clear();
            let r = traced.serve_slice(&trace).unwrap();
            assert_eq!(r, warm_plain, "recording must not change the replay");
            events = rec.len();
        }
        let on_s = wall.elapsed().as_secs_f64().max(1e-9);

        assert!(events > 0, "the traced rounds must record span events");
        let overhead_pct = (on_s - off_s) / off_s * 100.0;
        let events_per_s = (rounds * events) as f64 / on_s;
        println!(
            "observability ({rounds} rounds): tracing off {:.1} ms, on {:.1} ms \
             ({overhead_pct:+.1}% wall), {events} events/round, {events_per_s:.0} events/s",
            off_s * 1e3,
            on_s * 1e3,
        );
        format!(
            "  \"observability\": {{\"rounds\": {rounds}, \"off_wall_ms\": {:.3}, \
             \"on_wall_ms\": {:.3}, \"overhead_pct\": {overhead_pct:.2}, \
             \"events_per_round\": {events}, \"events_per_s\": {events_per_s:.0}}},\n",
            off_s * 1e3,
            on_s * 1e3,
        )
    };

    // Fleet synthesis: the full model → place → serve loop under the
    // demo area budget, scored on the seeded heavy-tail trace. The
    // result is modeled-cycle deterministic (same budget, trace and
    // options → bit-identical fleet, at any `jobs` value), so the
    // section doubles as a perf trajectory for the search itself:
    // `fleets_scored` pins the replay count, `synth_wall_ms` /
    // `fleets_per_s` gate scoring throughput with 4 frontier workers.
    let synthesis_json = {
        let budget = AreaBudget::demo();
        let trace = heavy_tail_requests(&BurstSpec::demo(24));
        let opts = SynthOptions {
            jobs: 4,
            ..SynthOptions::default()
        };
        let wall = std::time::Instant::now();
        let result = synthesize(&budget, &trace, &opts)
            .expect("synthesis under the demo budget must find a fleet");
        let synth_wall_ms = wall.elapsed().as_secs_f64() * 1e3;
        let fleets_per_s = result.evaluated as f64 / (synth_wall_ms / 1e3).max(1e-9);
        assert!(
            result.score.slo_met > 0,
            "the synthesized fleet must meet at least one SLO"
        );
        for b in &result.baselines {
            assert!(
                result.score.slo_met >= b.slo_met,
                "synthesized fleet ({}) must dominate baseline {} ({})",
                result.score.slo_met,
                b.name,
                b.slo_met
            );
        }
        let fleet_names: Vec<String> =
            result.fleet.iter().map(|c| json_str(&c.name)).collect();
        let baseline_rows: Vec<String> = result
            .baselines
            .iter()
            .map(|b| {
                format!(
                    "      {{\"name\": {}, \"cores\": {}, \"slo_met\": {}, \"cost\": {}}}",
                    json_str(&b.name),
                    b.cores,
                    b.slo_met,
                    b.cost,
                )
            })
            .collect();
        println!(
            "synthesis (budget {budget}, {} offered): {}-core fleet, {} SLO-met, \
             cost {} ALM-eq, {} fleets scored in {synth_wall_ms:.0}ms \
             ({fleets_per_s:.1} fleets/s, 4 jobs)",
            result.offered,
            result.fleet.len(),
            result.score.slo_met,
            result.score.cost,
            result.evaluated
        );
        format!(
            "  \"synthesis\": {{\"alms_budget\": {}, \"dsps_budget\": {}, \
             \"m20ks_budget\": {}, \"offered\": {}, \"cores\": {}, \
             \"slo_met\": {}, \"completed\": {}, \"shed\": {}, \
             \"deadline_missed\": {}, \"cost_alm_eq\": {}, \
             \"alms_used\": {}, \"dsps_used\": {}, \"m20ks_used\": {}, \
             \"fleets_scored\": {}, \"jobs\": {}, \
             \"synth_wall_ms\": {synth_wall_ms:.2}, \
             \"fleets_per_s\": {fleets_per_s:.1}, \
             \"fleet\": [{}], \"baselines\": [\n{}\n    ]}},\n",
            budget.alms,
            budget.dsps,
            budget.m20ks,
            result.offered,
            result.fleet.len(),
            result.score.slo_met,
            result.completed,
            result.shed,
            result.deadline_missed,
            result.score.cost,
            result.usage.alms,
            result.usage.dsps,
            result.usage.m20ks,
            result.evaluated,
            opts.jobs,
            fleet_names.join(", "),
            baseline_rows.join(",\n"),
        )
    };

    // Multi-core scaling: the same 4-job batch through sequential and
    // parallel dispatch — identical modeled timelines, different
    // wall-clock.
    let (seq_span, seq_t) = run_array(samples, false);
    let (par_span, par_t) = run_array(samples, true);
    assert_eq!(
        seq_span, par_span,
        "parallel dispatch must not change the modeled timeline"
    );
    let speedup = seq_t.median_ns as f64 / par_t.median_ns as f64;
    println!(
        "multi-core (4 cores, 4 FFT-256 jobs): sequential {:.2}ms, parallel {:.2}ms, \
         {speedup:.2}x wall-clock",
        seq_t.median_ms(),
        par_t.median_ms()
    );
    println!("target: simulate 771 MHz real time / 1000 => >= 0.77 Mcyc/s (trivially exceeded);");
    println!("practical target: > 50 Mcyc/s on MMM-class kernels so the full suite stays < 5 s");

    let json = format!(
        "{{\n  \"samples\": {samples},\n  \"kernels\": [\n{}\n  ],\n  \
         \"static_schedule\": [\n{}\n  ],\n{superplan_json}{fleet_json}{serving_json}{dispatch_json}{observability_json}{synthesis_json}  \
         \"aggregate_mcyc_per_s_unchecked\": {aggregate:.2},\n  \
         \"multi_core\": {{\"cores\": 4, \"jobs\": 4, \"kernel\": \"fft-256\", \
         \"makespan_cycles\": {seq_span}, \"sequential_ms\": {:.4}, \
         \"parallel_ms\": {:.4}, \"wall_clock_speedup\": {speedup:.3}}}\n}}\n",
        kernel_rows.join(",\n"),
        sched_rows.join(",\n"),
        seq_t.median_ms(),
        par_t.median_ms(),
    );
    match std::fs::write("BENCH_simulator.json", &json) {
        Ok(()) => println!("\nwrote BENCH_simulator.json"),
        Err(e) => eprintln!("\ncould not write BENCH_simulator.json: {e}"),
    }
}
