//! Ablation study of the paper's design choices (DESIGN.md §5 calls these
//! out): what each eGPU feature buys, measured on the cycle-accurate core.
//!
//!  A. Dynamic thread-space scaling vs conventional predication (§3.1) —
//!     the paper's core "dynamic scalability" claim.
//!  B. The DOT extension core on/off (§4/§7).
//!  C. QP vs DP memory organization across the write-heavy kernels (§3).
//!  D. Radix-4 vs radix-2 FFT — the §7 "higher radix" future-work item,
//!     implemented in `kernels::fft4`.
//!
//!     cargo bench --bench ablation_features

use egpu::harness::{Rng, Table};
use egpu::kernels::{f32_bits, fft, fft4, mmm, reduction, transpose, Kernel};
use egpu::sim::{EgpuConfig, MemoryMode};

/// Cycle count of one kernel (Kernel::run is the `Gpu::launch` shim).
fn cycles(kernel: &Kernel, cfg: &EgpuConfig, init: &[(usize, Vec<u32>)]) -> u64 {
    kernel.run(cfg, init).unwrap().0.cycles
}

fn main() {
    let mut rng = Rng::new(0xAB1A);

    // ------------------------------------------------------------------
    // A. Dynamic scaling vs predication.
    // ------------------------------------------------------------------
    let mut t = Table::new("A. Reduction: dynamic thread-space scaling vs predication (§3.1)");
    t.headers(["n", "dynamic (cycles)", "predicated (cycles)", "penalty"]);
    for n in [32usize, 64, 128] {
        let d: Vec<f32> = (0..n).map(|_| rng.f32_in(-2.0, 2.0)).collect();
        let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
        let pcfg = EgpuConfig::benchmark_predicated(MemoryMode::Dp);
        let dyn_c = cycles(&reduction::reduction(n), &cfg, &[(0, f32_bits(&d))]);
        let pred_c = cycles(&reduction::reduction_predicated(n), &pcfg, &[(0, f32_bits(&d))]);
        let penalty = pred_c as f64 / dyn_c as f64;
        assert!(penalty > 2.0, "n={n}: dynamic scaling must win big");
        t.row([
            n.to_string(),
            dyn_c.to_string(),
            pred_c.to_string(),
            format!("{penalty:.1}x"),
        ]);
    }
    t.print();
    println!("dynamic narrowing skips idle wavefronts; predication issues all of them\n");

    // ------------------------------------------------------------------
    // B. DOT extension core.
    // ------------------------------------------------------------------
    let mut t = Table::new("B. DOT extension core on/off (§4, §7)");
    t.headers(["kernel", "tree (cycles)", "dot (cycles)", "speedup", "extra DSPs"]);
    for n in [64usize, 128] {
        let d: Vec<f32> = (0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
        let cfg = EgpuConfig::benchmark(MemoryMode::Dp, true);
        let tree = cycles(&reduction::reduction(n), &cfg, &[(0, f32_bits(&d))]);
        let dot = cycles(&reduction::reduction_dot(n), &cfg, &[(0, f32_bits(&d))]);
        t.row([
            format!("reduction-{n}"),
            tree.to_string(),
            dot.to_string(),
            format!("{:.1}x", tree as f64 / dot as f64),
            "8".into(),
        ]);
    }
    {
        let n = 64;
        let a: Vec<f32> = (0..n * n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
        let b: Vec<f32> = (0..n * n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
        let init = vec![(0, f32_bits(&a)), (n * n, f32_bits(&b))];
        let tree = cycles(&mmm::mmm(n), &mmm::config(n, MemoryMode::Dp, false), &init);
        let dot = cycles(&mmm::mmm_dot(n), &mmm::config(n, MemoryMode::Dp, true), &init);
        t.row([
            format!("mmm-{n}"),
            tree.to_string(),
            dot.to_string(),
            format!("{:.1}x", tree as f64 / dot as f64),
            "8".into(),
        ]);
    }
    t.print();
    println!("the paper: \"the advantage can increase again by several times\" (§8)\n");

    // ------------------------------------------------------------------
    // C. QP vs DP across write intensity.
    // ------------------------------------------------------------------
    let mut t = Table::new("C. QP (4R/2W @600) vs DP (4R/1W @771) by write intensity (§3)");
    t.headers(["kernel", "DP cycles", "QP cycles", "cycle ratio", "time ratio"]);
    for n in [64usize] {
        let d: Vec<f32> = (0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
        let mat: Vec<u32> = (0..n * n).map(|_| rng.next_u32()).collect();
        let cases: Vec<(String, u64, u64)> = vec![
            {
                let (dp, _) = reduction::reduction(n)
                    .run(&EgpuConfig::benchmark(MemoryMode::Dp, false), &[(0, f32_bits(&d))])
                    .unwrap();
                let (qp, _) = reduction::reduction(n)
                    .run(&EgpuConfig::benchmark(MemoryMode::Qp, false), &[(0, f32_bits(&d))])
                    .unwrap();
                (format!("reduction-{n} (read-heavy)"), dp.cycles, qp.cycles)
            },
            {
                let (dp, _) = transpose::transpose_for(n, MemoryMode::Dp)
                    .run(&EgpuConfig::benchmark(MemoryMode::Dp, false), &[(0, mat.clone())])
                    .unwrap();
                let (qp, _) = transpose::transpose_for(n, MemoryMode::Qp)
                    .run(&EgpuConfig::benchmark(MemoryMode::Qp, false), &[(0, mat.clone())])
                    .unwrap();
                (format!("transpose-{n} (write-heavy)"), dp.cycles, qp.cycles)
            },
        ];
        for (name, dp, qp) in cases {
            let rc = qp as f64 / dp as f64;
            let rt = (qp as f64 / 600.0) / (dp as f64 / 771.0);
            t.row([
                name,
                dp.to_string(),
                qp.to_string(),
                format!("{rc:.2}"),
                format!("{rt:.2}"),
            ]);
        }
    }
    t.print();
    println!("write-heavy kernels gain cycles under QP; the 600 MHz clock claws it back\n");

    // ------------------------------------------------------------------
    // D. FFT radix.
    // ------------------------------------------------------------------
    let mut t = Table::new("D. FFT radix-2 vs radix-4 (§7 \"higher radix\" extension)");
    t.headers(["n", "mode", "radix-2", "radix-4", "speedup"]);
    for n in [64usize, 256] {
        let re: Vec<f32> = (0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
        let im = vec![0f32; n];
        for mode in [MemoryMode::Dp, MemoryMode::Qp] {
            let cfg = EgpuConfig::benchmark(mode, false);
            let (s2, _) = fft::fft_for(n, mode).run(&cfg, &fft::shared_init(&re, &im)).unwrap();
            let (s4, m) = fft4::fft4_for(n, mode).run(&cfg, &fft4::shared_init(&re, &im)).unwrap();
            // Cross-check the two kernels agree.
            let (wr, _) = fft::oracle(&re, &im);
            for k in 0..n {
                let got = f32::from_bits(m.shared().read(k as u32).unwrap()) as f64;
                assert!((got - wr[k]).abs() < 1e-3 * n as f64, "radix-4 {mode:?} n={n} bin {k}");
            }
            let speedup = s2.cycles as f64 / s4.cycles as f64;
            t.row([
                n.to_string(),
                mode.name().to_string(),
                s2.cycles.to_string(),
                s4.cycles.to_string(),
                format!("{speedup:.2}x"),
            ]);
        }
    }
    t.print();
    println!("half the stages -> ~half the shared-memory write passes; win grows with n");
}
