//! Regenerates Table 6: integer-ALU resource breakdown, including the
//! per-function ALM columns and the QP 4-stage variant (§5.2).
//!
//!     cargo bench --bench table6_int_alu

use egpu::harness::Table;
use egpu::model::alu_model::{alu_fmax, QP_32_FULL, TABLE6};

fn opt(v: Option<u32>) -> String {
    v.map(|x| x.to_string()).unwrap_or_else(|| "-".into())
}

fn main() {
    let mut t = Table::new("Table 6: Fitting Results - Integer ALU");
    t.headers(["Prec", "Type", "ALM", "Registers", "Add/Sub", "Logic", "SHL", "SHR", "Pop", "Stages", "Fmax"]);
    for a in TABLE6.iter().chain([&QP_32_FULL]) {
        t.row([
            a.precision.to_string(),
            if a.stages == 4 { format!("{} (QP)", a.class.name()) } else { a.class.name().into() },
            a.alms.to_string(),
            a.regs.to_string(),
            opt(a.add_sub),
            opt(a.logic),
            opt(a.shl),
            opt(a.shr),
            opt(a.pop),
            a.stages.to_string(),
            format!("{:.0}", alu_fmax(a)),
        ]);
    }
    t.print();
    println!("\n5-stage ALUs exceed 800 MHz; the 4-stage QP variant lands ~700 MHz (§5.2)");

    // Sanity: the three §5.2 scaling claims.
    let min16 = &TABLE6[0];
    let full16 = &TABLE6[2];
    let full32 = &TABLE6[4];
    assert!(full16.alms >= 2 * min16.alms - 30, "full16 ~2x min16");
    assert!(full32.alms >= 2 * full16.alms - 30, "full32 ~2x full16 ALMs");
    assert!(full32.regs as f64 >= 2.4 * full16.regs as f64, "full32 ~3x full16 FFs");
    println!("scaling claims hold: full16 ≈ 2x min16, full32 ≈ 2x ALM / ~3x FF of full16");
}
