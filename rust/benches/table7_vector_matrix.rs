//! Regenerates Table 7: vector reduction, matrix transpose and
//! matrix-matrix multiply on Nios / eGPU-DP / eGPU-QP / eGPU-Dot (and the
//! FlexGrip MMM comparison), with every metric row the paper reports:
//! cycles, elapsed time, cycle/time ratios and the resource-normalized
//! ratio.
//!
//!     cargo bench --bench table7_vector_matrix

use egpu::baseline::flexgrip;
use egpu::harness::{paper_cycles, suite, within_band, Table, Variant};
use egpu::harness::suite::{Benchmark, Measurement};

fn main() {
    let mut fail = 0usize;
    for b in [Benchmark::Reduction, Benchmark::Transpose, Benchmark::Mmm] {
        let mut t = Table::new(format!("Table 7 — {} (paper values in parens)", b.name()));
        t.headers(["Dim", "Metric", "Nios", "FlexGrip", "eGPU-DP", "eGPU-QP", "eGPU-Dot"]);
        for &dim in b.dims() {
            let r = suite::run(b, dim);
            let meas = |v: Variant| -> Option<&Measurement> {
                match v {
                    Variant::Nios => Some(&r.nios),
                    Variant::Dp => Some(&r.dp),
                    Variant::Qp => Some(&r.qp),
                    Variant::Dot => r.dot.as_ref(),
                }
            };
            for (m, v) in [(Variant::Nios, 4.0f64), (Variant::Dp, 2.0), (Variant::Qp, 2.0), (Variant::Dot, 2.0)]
                .iter()
                .filter_map(|&(v, band)| meas(v).map(|m| ((m, band), v)))
            {
                if let Some(p) = paper_cycles(b, dim, v) {
                    if !within_band(m.0.cycles as f64, p as f64, m.1) {
                        eprintln!("BAND MISS: {b:?}-{dim} {}: {} vs {p}", v.label(), m.0.cycles);
                        fail += 1;
                    }
                }
            }
            let fmt_cycles = |v: Variant| match meas(v) {
                None => "-".to_string(),
                Some(m) => match paper_cycles(b, dim, v) {
                    Some(p) => format!("{} ({p})", m.cycles),
                    None => m.cycles.to_string(),
                },
            };
            let fg_cycles = if b == Benchmark::Mmm {
                flexgrip::mmm_cycles(dim).map(|c| c.to_string()).unwrap_or_default()
            } else {
                "-".into()
            };
            let fg_time = if b == Benchmark::Mmm {
                flexgrip::mmm_time_us(dim).map(|t| format!("{t:.0}")).unwrap_or_default()
            } else {
                "-".into()
            };
            t.row([
                dim.to_string(),
                "Cycles".into(),
                fmt_cycles(Variant::Nios),
                fg_cycles,
                fmt_cycles(Variant::Dp),
                fmt_cycles(Variant::Qp),
                fmt_cycles(Variant::Dot),
            ]);
            let fmt_time = |v: Variant| {
                meas(v).map(|m| format!("{:.2}", m.time_us())).unwrap_or_else(|| "-".into())
            };
            t.row([
                dim.to_string(),
                "Time(us)".into(),
                fmt_time(Variant::Nios),
                fg_time,
                fmt_time(Variant::Dp),
                fmt_time(Variant::Qp),
                fmt_time(Variant::Dot),
            ]);
            let fmt_rc = |v: Variant| {
                r.ratio_cycles(v).map(|x| format!("{x:.2}")).unwrap_or_else(|| "-".into())
            };
            t.row([
                dim.to_string(),
                "Ratio(cycles)".into(),
                fmt_rc(Variant::Nios),
                "-".into(),
                fmt_rc(Variant::Dp),
                fmt_rc(Variant::Qp),
                fmt_rc(Variant::Dot),
            ]);
            let fmt_rt = |v: Variant| {
                r.ratio_time(v).map(|x| format!("{x:.2}")).unwrap_or_else(|| "-".into())
            };
            t.row([
                dim.to_string(),
                "Ratio(time)".into(),
                fmt_rt(Variant::Nios),
                "-".into(),
                fmt_rt(Variant::Dp),
                fmt_rt(Variant::Qp),
                fmt_rt(Variant::Dot),
            ]);
            let fmt_n = |v: Variant| {
                r.normalized(v).map(|x| format!("{x:.2}")).unwrap_or_else(|| "-".into())
            };
            t.row([
                dim.to_string(),
                "Normalized".into(),
                fmt_n(Variant::Nios),
                "-".into(),
                fmt_n(Variant::Dp),
                fmt_n(Variant::Qp),
                fmt_n(Variant::Dot),
            ]);
        }
        t.print();
        println!();
    }
    println!(
        "FlexGrip underperforms eGPU-DP by {:.0}x on MMM cycles (paper: ~31x avg over all benchmarks)",
        flexgrip::MMM_CYCLE_RATIO_VS_EGPU.iter().map(|&(_, r)| r).sum::<f64>()
            / flexgrip::MMM_CYCLE_RATIO_VS_EGPU.len() as f64
    );
    if fail > 0 {
        eprintln!("{fail} cells outside the reproduction band");
        std::process::exit(1);
    }
}
