//! Regenerates Figures 4 and 5: unconstrained placement of eGPU instances
//! into the Agilex sector model, rendered as ASCII floorplans, and the
//! three structural observations §6 makes about every instance:
//!
//!   (a) the majority of each SP's logic is one contiguous block,
//!   (b) the predicate block is a separate structure placed away from
//!       its SP (narrow interface),
//!   (c) each SP straddles a column of DSP blocks,
//! plus the shared-memory spine in the middle of the core.
//!
//!     cargo bench --bench figure45_placement

use egpu::place::render::{render, render_sp, stats};
use egpu::place::place;
use egpu::sim::EgpuConfig;

fn main() {
    let mut checked = 0usize;
    for cfg in EgpuConfig::table4_presets()
        .into_iter()
        .chain(EgpuConfig::table5_presets())
    {
        let p = match place(&cfg) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("{}: placement failed: {e}", cfg.name);
                std::process::exit(1);
            }
        };
        let straddle = (0..16).filter(|&s| p.sp_straddles_dsp(s)).count();
        println!(
            "{:<12} contiguous-SP-logic={} predicates-remote={} spine-central={} DSP-straddling-SPs={}/16 max-reg->DSP-hops={}",
            cfg.name,
            p.sp_logic_contiguous(),
            p.predicates_remote(),
            p.spine_is_central(),
            straddle,
            p.max_reg_to_dsp_hops()
        );
        assert!(p.sp_logic_contiguous(), "{}: observation (a)", cfg.name);
        if cfg.predicate_levels > 0 {
            assert!(p.predicates_remote(), "{}: observation (b)", cfg.name);
        }
        assert!(straddle >= 12, "{}: observation (c)", cfg.name);
        assert!(p.spine_is_central(), "{}: shared-memory spine", cfg.name);
        checked += 1;
    }
    println!("\nall {checked} instances show the Figure 4 pattern\n");

    // Figure 4: the largest DP instance, full floorplan.
    let large = EgpuConfig::table4_presets().into_iter().last().unwrap();
    let p = place(&large).unwrap();
    println!("Figure 4 — {} floorplan:\n{}", large.name, render(&p));
    println!("{}", stats(&p));

    // Figure 5: one SP in detail.
    println!("\nFigure 5 — SP0 detail:\n{}", render_sp(&p, 0));
}
