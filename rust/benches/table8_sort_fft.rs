//! Regenerates Table 8: bitonic sort and FFT on Nios / eGPU-DP / eGPU-QP
//! across dimensions 32..256, with the paper's metric rows.
//!
//!     cargo bench --bench table8_sort_fft

use egpu::harness::suite::Benchmark;
use egpu::harness::{paper_cycles, suite, within_band, Table, Variant};

fn main() {
    let mut fail = 0usize;
    for b in [Benchmark::Bitonic, Benchmark::Fft] {
        let mut t = Table::new(format!("Table 8 — {} (paper values in parens)", b.name()));
        t.headers(["Dim", "Metric", "Nios", "eGPU-DP", "eGPU-QP"]);
        for &dim in b.dims() {
            let r = suite::run(b, dim);
            for (m, v, band) in [
                (&r.nios, Variant::Nios, 4.0f64),
                (&r.dp, Variant::Dp, 2.0),
                (&r.qp, Variant::Qp, 2.0),
            ] {
                if let Some(p) = paper_cycles(b, dim, v) {
                    if !within_band(m.cycles as f64, p as f64, band) {
                        eprintln!("BAND MISS: {b:?}-{dim} {}: {} vs {p}", v.label(), m.cycles);
                        fail += 1;
                    }
                }
            }
            let cyc = |m: &suite::Measurement, v: Variant| match paper_cycles(b, dim, v) {
                Some(p) => format!("{} ({p})", m.cycles),
                None => m.cycles.to_string(),
            };
            t.row([
                dim.to_string(),
                "Cycles".into(),
                cyc(&r.nios, Variant::Nios),
                cyc(&r.dp, Variant::Dp),
                cyc(&r.qp, Variant::Qp),
            ]);
            t.row([
                dim.to_string(),
                "Time(us)".into(),
                format!("{:.2}", r.nios.time_us()),
                format!("{:.2}", r.dp.time_us()),
                format!("{:.2}", r.qp.time_us()),
            ]);
            t.row([
                dim.to_string(),
                "Ratio(cycles)".into(),
                format!("{:.2}", r.ratio_cycles(Variant::Nios).unwrap()),
                "1.00".into(),
                format!("{:.2}", r.ratio_cycles(Variant::Qp).unwrap()),
            ]);
            t.row([
                dim.to_string(),
                "Ratio(time)".into(),
                format!("{:.2}", r.ratio_time(Variant::Nios).unwrap()),
                "1.00".into(),
                format!("{:.2}", r.ratio_time(Variant::Qp).unwrap()),
            ]);
            t.row([
                dim.to_string(),
                "Normalized".into(),
                format!("{:.2}", r.normalized(Variant::Nios).unwrap()),
                "1.00".into(),
                format!("{:.2}", r.normalized(Variant::Qp).unwrap()),
            ]);
        }
        t.print();
        println!();
    }
    println!("QP cuts cycles on write-heavy passes but its 600 MHz clock offsets the gain (§7)");
    if fail > 0 {
        eprintln!("{fail} cells outside the reproduction band");
        std::process::exit(1);
    }
}
