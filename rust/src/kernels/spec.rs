//! Kernel *specifications*: what to compute, decoupled from the
//! configuration it is compiled for.
//!
//! The generators in this crate ([`reduction`](super::reduction),
//! [`transpose`](super::transpose), …) eagerly compile for one fixed
//! target (DP memory, 32-register layout). A [`KernelSpec`] instead
//! names the `(generator, dim)` pair and defers the compile until a
//! concrete [`EgpuConfig`] is known — which is what a heterogeneous
//! fleet needs (the same logical kernel specializes differently per
//! memory mode and register layout) and what the
//! [`KernelCache`](super::KernelCache) keys on.

use super::{bitonic, fft, fft4, mmm, reduction, transpose, Kernel};
use crate::kc::SchedMode;
use crate::sim::config::EgpuConfig;

/// A `(generator, dim)` pair: the identity of a kernel before it is
/// specialized to a configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelSpec {
    Reduction { n: usize },
    ReductionDot { n: usize },
    ReductionPredicated { n: usize },
    Transpose { n: usize },
    Mmm { n: usize },
    MmmDot { n: usize },
    Bitonic { n: usize },
    Fft { n: usize },
    Fft4 { n: usize },
}

impl KernelSpec {
    /// Parse a CLI-style kernel name ("reduction", "mmm-dot", …) plus a
    /// dimension. Returns `None` for unknown names.
    pub fn parse(name: &str, n: usize) -> Option<KernelSpec> {
        use KernelSpec::*;
        Some(match name {
            "reduction" => Reduction { n },
            "reduction-dot" => ReductionDot { n },
            "reduction-pred" => ReductionPredicated { n },
            "transpose" => Transpose { n },
            "mmm" => Mmm { n },
            "mmm-dot" => MmmDot { n },
            "bitonic" => Bitonic { n },
            "fft" => Fft { n },
            "fft4" => Fft4 { n },
            _ => return None,
        })
    }

    /// The generator's CLI name.
    pub fn generator(&self) -> &'static str {
        use KernelSpec::*;
        match self {
            Reduction { .. } => "reduction",
            ReductionDot { .. } => "reduction-dot",
            ReductionPredicated { .. } => "reduction-pred",
            Transpose { .. } => "transpose",
            Mmm { .. } => "mmm",
            MmmDot { .. } => "mmm-dot",
            Bitonic { .. } => "bitonic",
            Fft { .. } => "fft",
            Fft4 { .. } => "fft4",
        }
    }

    pub fn dim(&self) -> usize {
        use KernelSpec::*;
        match *self {
            Reduction { n }
            | ReductionDot { n }
            | ReductionPredicated { n }
            | Transpose { n }
            | Mmm { n }
            | MmmDot { n }
            | Bitonic { n }
            | Fft { n }
            | Fft4 { n } => n,
        }
    }

    /// Is the dimension inside the generator's supported envelope? The
    /// generators `assert!` their constraints; this is the checkable
    /// front door ([`KernelSpec::build`] refuses instead of panicking).
    pub fn valid_dim(&self) -> bool {
        use KernelSpec::*;
        let n = self.dim();
        match self {
            // The narrowing tree needs Table 3-expressible prefixes per
            // level.
            Reduction { .. } => matches!(n, 32 | 64 | 128),
            // One thread per element; 512 is the thread-space cap.
            ReductionDot { .. } | ReductionPredicated { .. } => {
                n.is_power_of_two() && (32..=512).contains(&n)
            }
            Transpose { .. } => n.is_power_of_two() && (32..=transpose::MAX_N).contains(&n),
            Mmm { .. } | MmmDot { .. } => n.is_power_of_two() && (32..=mmm::MAX_N).contains(&n),
            Bitonic { .. } => {
                n.is_power_of_two() && (bitonic::MIN_N..=bitonic::MAX_N).contains(&n)
            }
            Fft { .. } => n.is_power_of_two() && (fft::MIN_N..=fft::MAX_N).contains(&n),
            Fft4 { .. } => fft4::supported(n),
        }
    }

    /// Compile-and-schedule this kernel for a configuration: the memory
    /// mode drives the scheduler's port-cost model, the register-file
    /// size picks the word layout and the allocator budget. Two configs
    /// with equal [`EgpuConfig::fingerprint`]s get byte-identical
    /// results, which is the invariant the [`super::KernelCache`]
    /// relies on.
    pub fn build(&self, cfg: &EgpuConfig) -> Result<Kernel, String> {
        self.build_mode(cfg, SchedMode::List)
    }

    /// [`KernelSpec::build`] with an explicit schedule mode.
    pub fn build_mode(&self, cfg: &EgpuConfig, mode: SchedMode) -> Result<Kernel, String> {
        use KernelSpec::*;
        if !self.valid_dim() {
            return Err(format!(
                "kernel '{}' does not support DIM {}",
                self.generator(),
                self.dim()
            ));
        }
        let n = self.dim();
        let layout = cfg.word_layout();
        let memory = cfg.memory;
        Ok(match self {
            Reduction { .. } => reduction::reduction_cfg(n, memory, layout, mode),
            ReductionDot { .. } => reduction::reduction_dot_cfg(n, memory, layout, mode),
            ReductionPredicated { .. } => {
                reduction::reduction_predicated_cfg(n, memory, layout, mode)
            }
            Transpose { .. } => transpose::transpose_cfg(n, memory, layout, mode),
            Mmm { .. } => mmm::mmm_cfg(n, memory, layout, mode),
            MmmDot { .. } => mmm::mmm_dot_cfg(n, memory, layout, mode),
            Bitonic { .. } => bitonic::bitonic_cfg(n, memory, layout, mode),
            Fft { .. } => fft::fft_cfg(n, memory, layout, mode),
            Fft4 { .. } => fft4::fft4_cfg(n, memory, layout, mode),
        })
    }

    /// A fully-featured reference target (DP memory, 32-register
    /// layout): the default build configuration for tooling (`egpu
    /// sched`) and tests. Its fingerprint coincides with the common
    /// benchmark configurations, so builds against it are shared with
    /// any (DP, 32-reg) fleet core. Fleet dispatchers derive job
    /// requirements from their *own* first core's build instead
    /// (`Coordinator::job_from_spec`), keeping the cache at one compile
    /// per fingerprint actually present.
    pub fn canonical_config() -> EgpuConfig {
        let mut cfg = EgpuConfig::benchmark(crate::sim::config::MemoryMode::Dp, true);
        cfg.predicate_levels = 8;
        cfg.name = "spec-canonical".into();
        cfg
    }
}

impl std::fmt::Display for KernelSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}-{}", self.generator(), self.dim())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::MemoryMode;

    #[test]
    fn parse_round_trips_generator_names() {
        for name in [
            "reduction", "reduction-dot", "reduction-pred", "transpose", "mmm", "mmm-dot",
            "bitonic", "fft", "fft4",
        ] {
            let spec = KernelSpec::parse(name, 64).unwrap();
            assert_eq!(spec.generator(), name);
            assert_eq!(spec.dim(), 64);
        }
        assert!(KernelSpec::parse("sort", 64).is_none());
    }

    #[test]
    fn invalid_dims_refuse_instead_of_panicking() {
        assert!(!KernelSpec::Reduction { n: 48 }.valid_dim());
        let err = KernelSpec::Reduction { n: 48 }
            .build(&KernelSpec::canonical_config())
            .unwrap_err();
        assert!(err.contains("DIM 48"), "{err}");
    }

    #[test]
    fn builds_specialize_per_memory_mode_and_layout() {
        let spec = KernelSpec::Fft { n: 64 };
        let dp = spec.build(&EgpuConfig::benchmark(MemoryMode::Dp, false)).unwrap();
        let qp = spec.build(&EgpuConfig::benchmark(MemoryMode::Qp, false)).unwrap();
        // Same logical kernel, same name, same thread shape...
        assert_eq!(dp.name, qp.name);
        assert_eq!(dp.threads, qp.threads);
        assert_eq!(dp.dim_x, qp.dim_x);
        // ...but the QP schedule sees doubled store bandwidth.
        let (sd, sq) = (dp.sched.unwrap(), qp.sched.unwrap());
        assert!(
            sq.static_cycles_scheduled <= sd.static_cycles_scheduled,
            "QP {} vs DP {}",
            sq.static_cycles_scheduled,
            sd.static_cycles_scheduled
        );
        // A 64-register config compiles to a different word layout.
        let mut wide = EgpuConfig::benchmark(MemoryMode::Dp, false);
        wide.regs_per_thread = 64;
        let w = spec.build(&wide).unwrap();
        assert_eq!(w.program.as_ref().unwrap().layout, wide.word_layout());
        assert_ne!(
            w.program.as_ref().unwrap().layout,
            dp.program.as_ref().unwrap().layout
        );
    }
}
