//! Matrix transpose (Table 7, middle block): `out = inᵀ`, out at `n²`.
//!
//! §7 gives the cycle mechanism directly: "for a given n×n matrix, we know
//! that the eGPU will need n² cycles to write the transposed elements to
//! shared memory and 1/4th of those cycles to initially read them ... the
//! number of cycles clocked is marginally larger than this; these are
//! largely used for the integer instructions needed to generate the
//! transposed write addresses."
//!
//! The kernel runs the full 512-thread space over the n² elements in
//! chunks of 512. The transposed address is computed once from the thread
//! ID with mask/shift arithmetic, then updated *incrementally* per chunk:
//! element g+512 lands 512/n rows below element g in the same column, so
//! two ADDs replace the full recomputation — this is what keeps the
//! integer overhead "marginal". The list scheduler additionally moves
//! those ADDs into the stores' shadow.

use super::Kernel;
use crate::isa::WordLayout;
use crate::kc::{KernelBuilder, SchedMode};
use crate::sim::config::MemoryMode;

/// Largest transpose the 16-bit store offset allows (out base = n² must
/// encode as an immediate).
pub const MAX_N: usize = 128;

/// Transpose an `n × n` matrix of 32-bit words from shared `[0, n²)` to
/// shared `[n², 2n²)`. `n` must be a power of two in `[32, 128]`.
pub fn transpose(n: usize) -> Kernel {
    transpose_for(n, MemoryMode::Dp)
}

/// Memory-mode-aware variant (the program text is identical; the mode only
/// drives the scheduler's store-cost model, and the DP schedule is
/// valid — merely conservative — on QP).
pub fn transpose_for(n: usize, memory: MemoryMode) -> Kernel {
    transpose_mode(n, memory, SchedMode::List)
}

/// Schedule-mode-aware build (List = default; Fenced = the
/// schedule-disabled correctness oracle; Linear = in-order padding).
pub fn transpose_mode(n: usize, memory: MemoryMode, mode: SchedMode) -> Kernel {
    transpose_cfg(n, memory, WordLayout::for_regs(32), mode)
}

/// Fully specialized build: target memory organization *and* register
/// layout (the kernel-specialization cache's entry point — one compile
/// per [`crate::sim::EgpuConfig::fingerprint`]).
pub fn transpose_cfg(n: usize, memory: MemoryMode, layout: WordLayout, mode: SchedMode) -> Kernel {
    assert!(
        n.is_power_of_two() && (32..=MAX_N).contains(&n),
        "n must be a power of two in [32, {MAX_N}]"
    );
    let threads = 512.min(n * n);
    let chunks = n * n / threads;
    let log2n = n.trailing_zeros();
    let out = n * n;

    let name = format!("transpose-{n}");
    let mut b = KernelBuilder::new(&name, threads, layout, memory);
    b.comment("g = element index, dest = transposed index col*n + row");
    let g = b.tdx();
    let mask = b.ldi((n - 1) as i64);
    let shift = b.ldi(log2n as i64);
    let step_g = b.ldi(threads as i64);
    let step_d = b.ldi((threads / n) as i64);
    b.comment("col = g & (n-1); row = g >> log2n; dest = (col << log2n) + row");
    let col = b.and_i(g, mask);
    let row = b.shr_u(g, shift);
    let colsh = b.shl_u(col, shift);
    let dest = b.add_u(colsh, row);
    for c in 0..chunks {
        b.comment(&format!("chunk {c}: elements [{}, {})", c * threads, (c + 1) * threads));
        let v = b.lod(g, 0);
        b.sto(v, dest, out);
        if c + 1 < chunks {
            b.comment("advance g by one chunk; dest moves 512/n rows down");
            b.add_u_into(g, g, step_g);
            b.add_u_into(dest, dest, step_d);
        }
    }
    b.stop();
    Kernel::from_compiled(name, b.finish(mode).unwrap(), threads, threads)
}

/// Oracle: `out[j·n + i] = in[i·n + j]`.
pub fn oracle(input: &[u32], n: usize) -> Vec<u32> {
    assert_eq!(input.len(), n * n);
    let mut out = vec![0u32; n * n];
    for i in 0..n {
        for j in 0..n {
            out[j * n + i] = input[i * n + j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::{EgpuConfig, MemoryMode};

    fn data(n: usize) -> Vec<u32> {
        (0..n * n).map(|i| (i as u32).wrapping_mul(2654435761) ^ 0xA5A5) .collect()
    }

    #[test]
    fn transpose_correct_all_sizes() {
        for n in [32usize, 64, 128] {
            let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
            let d = data(n);
            let (stats, m) = transpose(n)
                .run(&cfg, &[(0, d.clone())])
                .unwrap_or_else(|e| panic!("n={n}: {e}"));
            assert_eq!(m.shared().read_block(n * n, n * n), &oracle(&d, n)[..], "n={n}");
            assert_eq!(stats.hazards, 0, "n={n}: {:?}", stats.hazard_samples);
        }
    }

    #[test]
    fn qp_variant_correct_and_faster() {
        for n in [32usize, 64] {
            let dp = EgpuConfig::benchmark(MemoryMode::Dp, false);
            let qp = EgpuConfig::benchmark(MemoryMode::Qp, false);
            let d = data(n);
            let (s_dp, _) = transpose(n).run(&dp, &[(0, d.clone())]).unwrap();
            let (s_qp, m) = transpose_for(n, MemoryMode::Qp).run(&qp, &[(0, d.clone())]).unwrap();
            assert_eq!(m.shared().read_block(n * n, n * n), &oracle(&d, n)[..]);
            // Table 7: QP transpose ≈ 0.6-0.7× DP cycles (writes dominate).
            let ratio = s_qp.cycles as f64 / s_dp.cycles as f64;
            assert!((0.45..=0.9).contains(&ratio), "n={n}: QP/DP = {ratio:.2}");
        }
    }

    #[test]
    fn cycle_counts_at_or_below_paper() {
        // Table 7 eGPU-DP: 1720 / 5529 / 20481 cycles for n = 32/64/128.
        // Upper bound only — the list scheduler may beat the paper.
        let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
        for (n, paper) in [(32usize, 1720u64), (64, 5529), (128, 20481)] {
            let (stats, _) = transpose(n).run(&cfg, &[(0, data(n))]).unwrap();
            let ratio = stats.cycles as f64 / paper as f64;
            assert!(
                ratio <= 2.0,
                "n={n}: {} vs paper {paper} ({ratio:.2}x)",
                stats.cycles
            );
        }
    }

    #[test]
    fn cycles_dominated_by_stores() {
        // §7: n² write cycles + n²/4 read cycles is the floor.
        let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
        let n = 64;
        let (stats, _) = transpose(n).run(&cfg, &[(0, data(n))]).unwrap();
        let floor = (n * n + n * n / 4) as u64;
        assert!(stats.cycles > floor, "{} <= floor {floor}", stats.cycles);
        assert!(stats.cycles < floor + floor / 2, "overhead not marginal: {}", stats.cycles);
    }

    #[test]
    fn rejects_bad_sizes() {
        for n in [8usize, 48, 256] {
            assert!(std::panic::catch_unwind(|| transpose(n)).is_err(), "n={n}");
        }
    }
}
