//! The paper's benchmark kernels as eGPU assembly generators (§7).
//!
//! "All benchmarks were written in assembly code (we have not written our
//! compiler yet)" — these generators emit that assembly, parameterized by
//! problem size and memory organization, using the paper's techniques:
//!
//! - dynamic thread-space narrowing for reduction trees (§3.1),
//! - NOP scheduling to cover the interlock-free 8-stage pipeline when the
//!   wavefront depth is too shallow to hide latency (§3, Figure 6),
//! - predicates only where data-dependent decisions exist (bitonic sort),
//! - loop constructs in the sequencer everywhere else.
//!
//! Each generator also states its runtime thread count and a rust oracle
//! for correctness; `rust/tests/benchmark_correctness.rs` runs every
//! kernel against its oracle, and the Table 7/8 benches report cycles.

pub mod bitonic;
pub mod fft;
pub mod fft4;
pub mod mmm;
pub mod reduction;
pub mod sched;
pub mod transpose;

use crate::asm::{assemble, Program};
use crate::isa::{DepthSel, WAVEFRONT_WIDTH};
use crate::sim::config::EgpuConfig;
use crate::sim::{Machine, RunStats, SimError};

/// A generated benchmark kernel.
#[derive(Debug, Clone)]
pub struct Kernel {
    pub name: String,
    /// eGPU assembly source.
    pub asm: String,
    /// Runtime-initialized threads the kernel expects.
    pub threads: usize,
    /// TDx grid x-dimension.
    pub dim_x: usize,
}

impl Kernel {
    /// Assemble against a configuration's word layout.
    pub fn assemble(&self, cfg: &EgpuConfig) -> Result<Program, String> {
        assemble(&self.asm, cfg.word_layout()).map_err(|e| format!("{}: {e}", self.name))
    }

    /// Build a device, load data into shared memory, run to STOP.
    /// Returns the stats and the machine (for reading results back).
    ///
    /// Legacy shim over [`crate::api::Gpu`], kept because the bench and
    /// oracle harnesses want the raw machine back. New code should use
    /// [`crate::api::Gpu::launch`] directly; the two paths are
    /// cycle- and bit-identical (`rust/tests/api_parity.rs`).
    pub fn run(
        &self,
        cfg: &EgpuConfig,
        shared_init: &[(usize, Vec<u32>)],
    ) -> Result<(RunStats, Machine), SimError> {
        let mut gpu = crate::api::Gpu::new(cfg)?;
        for (base, data) in shared_init {
            gpu.write_words(*base, data)?;
        }
        let report = gpu.launch(self).run()?;
        Ok((report.stats, gpu.into_machine()))
    }
}

/// Emission helper shared by the generators.
pub struct AsmWriter {
    out: String,
    /// Current wavefront count of full-depth ops (for NOP scheduling).
    waves: usize,
}

/// Hazard window the NOP scheduler covers (sim::hazard::REG_WINDOW).
const WINDOW: usize = 6;

impl AsmWriter {
    pub fn new(name: &str, threads: usize) -> AsmWriter {
        AsmWriter {
            out: format!("; {name} — generated eGPU assembly ({threads} threads)\n"),
            waves: threads / WAVEFRONT_WIDTH,
        }
    }

    /// Emit one instruction line.
    pub fn op(&mut self, line: impl AsRef<str>) -> &mut Self {
        self.out.push_str("    ");
        self.out.push_str(line.as_ref());
        self.out.push('\n');
        self
    }

    pub fn label(&mut self, name: &str) -> &mut Self {
        self.out.push_str(name);
        self.out.push_str(":\n");
        self
    }

    pub fn comment(&mut self, text: &str) -> &mut Self {
        self.out.push_str("    ; ");
        self.out.push_str(text);
        self.out.push('\n');
        self
    }

    /// NOPs to cover a RAW dependency after an op that issued for
    /// `writer_waves` wavefronts (§3: no hardware interlocks — "hazards
    /// are hidden for most programs"; shallow subsets need NOPs).
    pub fn pad(&mut self, writer_waves: usize) -> &mut Self {
        for _ in 0..WINDOW.saturating_sub(writer_waves.max(1)) {
            self.op("nop");
        }
        self
    }

    /// NOPs covering a store→load turnaround on the same addresses
    /// (sim::hazard::MEM_WINDOW: writes land shortly after their last
    /// arbitration slot regardless of depth).
    pub fn pad_mem(&mut self) -> &mut Self {
        for _ in 0..crate::sim::hazard::MEM_WINDOW {
            self.op("nop");
        }
        self
    }

    /// NOPs after a full-depth op.
    pub fn pad_full(&mut self) -> &mut Self {
        let w = self.waves;
        self.pad(w)
    }

    /// NOPs covering an extension-core writeback (DOT/SUM latency).
    pub fn pad_dot(&mut self, writer_waves: usize) -> &mut Self {
        let need = (crate::sim::hazard::DOT_WINDOW as usize + writer_waves)
            .saturating_sub(writer_waves.max(1));
        for _ in 0..need {
            self.op("nop");
        }
        self
    }

    pub fn finish(mut self) -> String {
        self.out.push_str("    stop\n");
        self.out
    }
}

/// Depth selector that narrows a `total_waves` machine to `want_waves`
/// (prefix subsets only — Table 3). Returns `None` when not expressible.
pub fn depth_for(total_waves: usize, want_waves: usize) -> Option<DepthSel> {
    if want_waves == total_waves {
        Some(DepthSel::All)
    } else if want_waves * 2 == total_waves {
        Some(DepthSel::Half)
    } else if want_waves * 4 == total_waves {
        Some(DepthSel::Quarter)
    } else if want_waves == 1 {
        Some(DepthSel::Wave0)
    } else {
        None
    }
}

/// f32 slice → register bit patterns.
pub fn f32_bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// i32 slice → register bit patterns.
pub fn i32_bits(v: &[i32]) -> Vec<u32> {
    v.iter().map(|x| *x as u32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_selection() {
        assert_eq!(depth_for(32, 32), Some(DepthSel::All));
        assert_eq!(depth_for(32, 16), Some(DepthSel::Half));
        assert_eq!(depth_for(32, 8), Some(DepthSel::Quarter));
        assert_eq!(depth_for(32, 1), Some(DepthSel::Wave0));
        assert_eq!(depth_for(32, 4), None);
    }

    #[test]
    fn writer_emits_and_pads() {
        let mut w = AsmWriter::new("t", 32); // 2 waves
        w.op("tdx r0").pad_full().op("lod r1, (r0)+0");
        let s = w.finish();
        // 6-2 = 4 nops between the dependent pair.
        assert_eq!(s.matches("nop").count(), 4);
        assert!(s.ends_with("stop\n"));
    }

    #[test]
    fn deep_machines_need_no_padding() {
        let mut w = AsmWriter::new("t", 512); // 32 waves
        w.op("tdx r0").pad_full().op("lod r1, (r0)+0");
        assert_eq!(w.finish().matches("nop").count(), 0);
    }
}
