//! The paper's benchmark kernels as compiled eGPU programs (§7).
//!
//! The paper wrote these by hand: "All benchmarks were written in assembly
//! code (we have not written our compiler yet)". This repo *has* written
//! that compiler ([`crate::kc`]): each generator here builds its kernel
//! through [`crate::kc::KernelBuilder`] — typed IR over virtual registers —
//! and the compiler derives the NOP schedule from the machine's own hazard
//! model (`sim::hazard`), list-scheduling independent instructions into
//! the interlock-free 8-stage pipeline's delay slots instead of padding
//! them. The paper's techniques survive unchanged:
//!
//! - dynamic thread-space narrowing for reduction trees (§3.1),
//! - delay-slot covering where the wavefront depth is too shallow to hide
//!   latency (§3, Figure 6) — now filled with useful work where possible,
//! - predicates only where data-dependent decisions exist (bitonic sort),
//! - loop constructs in the sequencer everywhere else.
//!
//! Each generator also states its runtime thread count and a rust oracle
//! for correctness; `rust/tests/benchmark_correctness.rs` runs every
//! kernel against its oracle, `rust/tests/kc_schedule.rs` pins every
//! scheduled kernel bit-identical to its schedule-disabled (fenced) build,
//! and the Table 7/8 benches report cycles.

pub mod bitonic;
mod cache;
pub mod fft;
pub mod fft4;
pub mod mmm;
pub mod reduction;
pub mod sched;
mod spec;
pub mod transpose;

pub use cache::{CacheStats, KernelCache};
pub use spec::KernelSpec;

use crate::asm::{assemble, Program};
use crate::isa::{DepthSel, WordLayout};
use crate::kc;
use crate::sim::config::{EgpuConfig, FeatureSet};
use crate::sim::{Machine, RunStats, SimError};

/// A generated benchmark kernel.
#[derive(Debug, Clone)]
pub struct Kernel {
    pub name: String,
    /// eGPU assembly listing (kc kernels: the compiler's pretty-printed
    /// form, which reassembles to exactly `program`). **Precedence:**
    /// when `program` is present and its word layout matches the target
    /// configuration, [`Kernel::assemble`] and `Gpu::launch` use the
    /// program and ignore this text — to run modified assembly, build a
    /// fresh kernel with [`Kernel::from_asm`].
    pub asm: String,
    /// Runtime-initialized threads the kernel expects.
    pub threads: usize,
    /// TDx grid x-dimension.
    pub dim_x: usize,
    /// Directly lowered program with issue plans attached (kc kernels;
    /// `None` for hand-written assembly). Takes precedence over `asm` on
    /// matching layouts — see the `asm` field note.
    pub program: Option<Program>,
    /// Static-schedule statistics (kc kernels).
    pub sched: Option<kc::ScheduleStats>,
}

impl Kernel {
    /// A kernel from raw assembly text (user programs, the CLI).
    pub fn from_asm(
        name: impl Into<String>,
        asm: impl Into<String>,
        threads: usize,
        dim_x: usize,
    ) -> Kernel {
        Kernel {
            name: name.into(),
            asm: asm.into(),
            threads,
            dim_x,
            program: None,
            sched: None,
        }
    }

    /// A kernel from a compiled build (program + listing + stats).
    pub fn from_compiled(
        name: impl Into<String>,
        c: kc::Compiled,
        threads: usize,
        dim_x: usize,
    ) -> Kernel {
        Kernel {
            name: name.into(),
            asm: c.asm,
            threads,
            dim_x,
            program: Some(c.program),
            sched: Some(c.stats),
        }
    }

    /// What this kernel demands of a configuration: the feature axes
    /// scanned off its instruction stream plus its thread count. Used
    /// by the fleet dispatcher to route jobs onto capable cores.
    ///
    /// Kernels carrying a compiled program are scanned directly; raw
    /// assembly is parsed against the widest register layout (the most
    /// permissive read — register usage still surfaces in `min_regs`).
    /// Unparseable assembly yields the kernel's capacity floors only;
    /// the real error then surfaces at assemble/load time, as before.
    pub fn requirements(&self) -> FeatureSet {
        let mut req = match &self.program {
            Some(p) => FeatureSet::required_by(p.instrs.iter()),
            None => assemble(&self.asm, WordLayout::for_regs(64))
                .map(|p| FeatureSet::required_by(p.instrs.iter()))
                .unwrap_or_default(),
        };
        req.min_threads = self.threads;
        req
    }

    /// The program for a configuration: the directly lowered program when
    /// its word layout matches (no string round-trip), otherwise assembled
    /// from the listing against the configuration's layout.
    pub fn assemble(&self, cfg: &EgpuConfig) -> Result<Program, String> {
        if let Some(p) = &self.program {
            if p.layout == cfg.word_layout() {
                return Ok(p.clone());
            }
        }
        assemble(&self.asm, cfg.word_layout()).map_err(|e| format!("{}: {e}", self.name))
    }

    /// Build a device, load data into shared memory, run to STOP.
    /// Returns the stats and the machine (for reading results back).
    ///
    /// Legacy shim over [`crate::api::Gpu`], kept because the bench and
    /// oracle harnesses want the raw machine back. New code should use
    /// [`crate::api::Gpu::launch`] directly; the two paths are
    /// cycle- and bit-identical (`rust/tests/api_parity.rs`).
    pub fn run(
        &self,
        cfg: &EgpuConfig,
        shared_init: &[(usize, Vec<u32>)],
    ) -> Result<(RunStats, Machine), SimError> {
        let mut gpu = crate::api::Gpu::new(cfg)?;
        for (base, data) in shared_init {
            gpu.write_words(*base, data)?;
        }
        let report = gpu.launch(self).run()?;
        Ok((report.stats, gpu.into_machine()))
    }
}

/// Depth selector that narrows a `total_waves` machine to `want_waves`
/// (prefix subsets only — Table 3). Returns `None` when not expressible.
pub fn depth_for(total_waves: usize, want_waves: usize) -> Option<DepthSel> {
    if want_waves == total_waves {
        Some(DepthSel::All)
    } else if want_waves * 2 == total_waves {
        Some(DepthSel::Half)
    } else if want_waves * 4 == total_waves {
        Some(DepthSel::Quarter)
    } else if want_waves == 1 {
        Some(DepthSel::Wave0)
    } else {
        None
    }
}

/// f32 slice → register bit patterns.
pub fn f32_bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// i32 slice → register bit patterns.
pub fn i32_bits(v: &[i32]) -> Vec<u32> {
    v.iter().map(|x| *x as u32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::WAVEFRONT_WIDTH;

    #[test]
    fn depth_selection() {
        assert_eq!(depth_for(32, 32), Some(DepthSel::All));
        assert_eq!(depth_for(32, 16), Some(DepthSel::Half));
        assert_eq!(depth_for(32, 8), Some(DepthSel::Quarter));
        assert_eq!(depth_for(32, 1), Some(DepthSel::Wave0));
        assert_eq!(depth_for(32, 4), None);
    }

    #[test]
    fn requirements_reflect_the_instruction_stream() {
        let pred = bitonic::bitonic(64).requirements();
        assert!(pred.predicate_depth >= 1, "{pred}");
        assert!(!pred.dot_core);
        assert_eq!(pred.min_threads, 32);

        let dot = reduction::reduction_dot(64).requirements();
        assert!(dot.dot_core, "{dot}");
        assert_eq!(dot.predicate_depth, 0);

        let plain = reduction::reduction(64).requirements();
        assert!(EgpuConfig::benchmark(crate::sim::MemoryMode::Dp, false).satisfies(&plain));

        // Raw-asm kernels are scanned through the permissive assembler.
        let k = Kernel::from_asm("t", "if.lt.u32 r0, r1\nendif\nstop\n", 16, 16);
        assert_eq!(k.requirements().predicate_depth, 1);
        // Unparseable asm degrades to capacity floors only.
        let bad = Kernel::from_asm("t", "not a program\n", 16, 16);
        assert_eq!(bad.requirements().min_threads, 16);
        assert_eq!(bad.requirements().predicate_depth, 0);
    }

    #[test]
    fn asm_kernels_have_no_program() {
        let k = Kernel::from_asm("t", "nop\nstop\n", WAVEFRONT_WIDTH, WAVEFRONT_WIDTH);
        assert!(k.program.is_none() && k.sched.is_none());
        let cfg = EgpuConfig::default();
        assert_eq!(k.assemble(&cfg).unwrap().len(), 2);
    }
}
