//! Vector reduction (Table 7, left block): `shared[n] = Σ shared[0..n]`.
//!
//! This is the paper's showcase for dynamic thread-space scaling (§3.1):
//! every tree level runs on a *prefix subset* of the thread space selected
//! by the instruction's 4-bit field, so no cycles are spent on idle
//! threads and no predicates are needed. The final scalar is written by
//! the single-thread MCU personality.
//!
//! The DOT-core variant replaces the whole tree with one SUM instruction
//! followed by NOPs covering the core's writeback latency (§7: "most of
//! the time is spent waiting (NOPs) for the dot product to write back").

use super::{depth_for, AsmWriter, Kernel};
use crate::isa::WAVEFRONT_WIDTH;

/// Tree reduction via dynamic narrowing. `n` must be a power of two
/// ≥ 32 with n/16 expressible prefixes at every level (32/64/128 are).
pub fn reduction(n: usize) -> Kernel {
    assert!(n.is_power_of_two() && n >= 32, "n must be a power of two ≥ 32");
    let total_waves = n / WAVEFRONT_WIDTH;
    let mut w = AsmWriter::new(&format!("reduction-{n}"), n);

    w.comment("fold pairs through shared memory until 16 partials remain");
    let mut s = n / 2;
    while s >= WAVEFRONT_WIDTH {
        let waves = s / WAVEFRONT_WIDTH;
        let d = depth_for(total_waves, waves)
            .unwrap_or_else(|| panic!("level {s} not expressible from {total_waves} waves"));
        let sel = format!("[w16,{}]", d.name());
        w.comment(&format!("level: {s} partial sums"));
        w.op(format!("{sel} lod r1, (r0)+0"));
        w.op(format!("{sel} lod r2, (r0)+{s}"));
        w.pad(waves);
        w.op(format!("{sel} fadd r1, r1, r2"));
        w.pad(waves);
        w.op(format!("{sel} sto r1, (r0)+0"));
        w.pad_mem();
        w.pad(waves);
        s /= 2;
    }

    w.comment("16 -> 4 on the first four SPs");
    w.op("[w4,d0] lod r1, (r0)+0");
    w.op("[w4,d0] lod r2, (r0)+4");
    w.op("[w4,d0] lod r3, (r0)+8");
    w.op("[w4,d0] lod r4, (r0)+12");
    w.pad(1);
    w.op("[w4,d0] fadd r1, r1, r2");
    w.op("[w4,d0] fadd r3, r3, r4");
    w.pad(1);
    w.op("[w4,d0] fadd r1, r1, r3");
    w.pad(1);
    w.op("[w4,d0] sto r1, (r0)+0");
    w.pad_mem();
    w.pad(1);

    w.comment("4 -> 1 in the MCU personality, result to shared[n]");
    w.op("[w1,d0] lod r1, (r0)+0");
    w.op("[w1,d0] lod r2, (r0)+1");
    w.op("[w1,d0] lod r3, (r0)+2");
    w.op("[w1,d0] lod r4, (r0)+3");
    w.pad(1);
    w.op("[w1,d0] fadd r1, r1, r2");
    w.op("[w1,d0] fadd r3, r3, r4");
    w.pad(1);
    w.op("[w1,d0] fadd r1, r1, r3");
    w.pad(1);
    w.op(format!("[w1,d0] sto r1, (r0)+{n}"));

    let mut asm = String::from("    tdx r0\n");
    asm.push_str(&"    nop\n".repeat(6usize.saturating_sub(n / 16)));
    asm.push_str(&w.finish());
    Kernel {
        name: format!("reduction-{n}"),
        asm,
        threads: n,
        dim_x: n,
    }
}

/// DOT-core variant: one SUM over the whole thread space.
pub fn reduction_dot(n: usize) -> Kernel {
    assert!(n.is_power_of_two() && n >= 32);
    let waves = n / WAVEFRONT_WIDTH;
    let mut w = AsmWriter::new(&format!("reduction-dot-{n}"), n);
    w.op("tdx r0");
    w.pad_full();
    w.op("lod r1, (r0)+0");
    w.pad_full();
    w.comment("SUM streams all wavefronts into the reduction core");
    w.op("sum r2, r1, r1");
    w.comment("wait for the extension core writeback (§7)");
    w.pad_dot(waves);
    w.op(format!("[w1,d0] sto r2, (r0)+{n}"));
    Kernel {
        name: format!("reduction-dot-{n}"),
        asm: w.finish(),
        threads: n,
        dim_x: n,
    }
}

/// Ablation variant: the same tree WITHOUT dynamic thread-space scaling,
/// using predicates the way a conventional SIMT machine would (§3.1:
/// "Most GPGPUs support thread divergence by predicates but these have a
/// potential significant performance impact, as all threads are run,
/// whether or not they are written back"). Every level issues over the
/// full thread space; only the writebacks are gated. Requires a
/// predicated configuration. Result lands at `shared[n]`.
pub fn reduction_predicated(n: usize) -> Kernel {
    assert!(n.is_power_of_two() && n >= 32);
    use super::sched::Sched;
    use crate::isa::WordLayout;
    use crate::sim::config::MemoryMode;
    let mut s = Sched::new(
        &format!("reduction-pred-{n}"),
        n,
        WordLayout::for_regs(32),
        MemoryMode::Dp,
    );
    s.op("tdx r0");
    let mut span = n / 2;
    while span >= 1 {
        s.comment(&format!("level: threads < {span} fold, all threads issue"));
        s.op(format!("ldi r5, #{span}"));
        s.op("if.lo r0, r5");
        s.op("lod r1, (r0)+0")
            .op(format!("lod r2, (r0)+{span}"))
            .op("fadd r1, r1, r2")
            .op("sto r1, (r0)+0");
        s.op("endif");
        span /= 2;
    }
    s.comment("copy the scalar to shared[n] (thread 0 only, still gated)");
    s.op("ldi r5, #1");
    s.op("if.lo r0, r5");
    s.op("lod r1, (r0)+0").op(format!("sto r1, (r0)+{n}"));
    s.op("endif");
    Kernel {
        name: format!("reduction-pred-{n}"),
        asm: s.finish(),
        threads: n,
        dim_x: n,
    }
}

/// Oracle: f32 sum in tree order (close enough — tests use a tolerance).
pub fn oracle(data: &[f32]) -> f32 {
    data.iter().copied().sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::f32_bits;
    use crate::sim::config::{EgpuConfig, MemoryMode};

    fn data(n: usize) -> Vec<f32> {
        (0..n).map(|i| (i as f32 * 0.5) - 7.0).collect()
    }

    #[test]
    fn tree_reduction_correct_all_sizes() {
        for n in [32usize, 64, 128] {
            let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
            let d = data(n);
            let (stats, m) = reduction(n)
                .run(&cfg, &[(0, f32_bits(&d))])
                .unwrap_or_else(|e| panic!("n={n}: {e}"));
            let got = f32::from_bits(m.shared().read(n as u32).unwrap());
            let want = oracle(&d);
            assert!(
                (got - want).abs() < want.abs() * 1e-5 + 1e-3,
                "n={n}: got {got}, want {want}"
            );
            assert_eq!(stats.hazards, 0, "n={n}: {:?}", stats.hazard_samples);
        }
    }

    #[test]
    fn dot_variant_correct_and_faster() {
        let cfg = EgpuConfig::benchmark(MemoryMode::Dp, true);
        for n in [32usize, 64, 128] {
            let d = data(n);
            let (dstats, m) = reduction_dot(n).run(&cfg, &[(0, f32_bits(&d))]).unwrap();
            let got = f32::from_bits(m.shared().read(n as u32).unwrap());
            let want = oracle(&d);
            assert!((got - want).abs() < want.abs() * 1e-5 + 1e-3, "n={n}");
            assert_eq!(dstats.hazards, 0, "n={n}");
            let (tstats, _) = reduction(n).run(&cfg, &[(0, f32_bits(&d))]).unwrap();
            assert!(
                dstats.cycles * 2 < tstats.cycles,
                "n={n}: dot {} vs tree {}",
                dstats.cycles,
                tstats.cycles
            );
        }
    }

    #[test]
    fn cycle_counts_in_paper_band() {
        // Table 7 eGPU-DP: 168/202/216 cycles for n = 32/64/128; we
        // assert the same order and the slow growth with n.
        let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
        let mut last = 0;
        for (n, paper) in [(32usize, 168u64), (64, 202), (128, 216)] {
            let (stats, _) = reduction(n).run(&cfg, &[(0, f32_bits(&data(n)))]).unwrap();
            assert!(
                (stats.cycles as f64) < paper as f64 * 2.0
                    && (stats.cycles as f64) > paper as f64 * 0.4,
                "n={n}: {} vs paper {paper}",
                stats.cycles
            );
            assert!(stats.cycles > last, "cycles must grow with n");
            last = stats.cycles;
        }
    }

    #[test]
    fn predicated_variant_correct_but_much_slower() {
        // §3.1 ablation: dynamic narrowing vs conventional predication.
        let pcfg = EgpuConfig::benchmark_predicated(MemoryMode::Dp);
        let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
        for n in [32usize, 128] {
            let d = data(n);
            let (ps, m) = reduction_predicated(n).run(&pcfg, &[(0, f32_bits(&d))]).unwrap();
            let got = f32::from_bits(m.shared().read(n as u32).unwrap());
            let want = oracle(&d);
            assert!((got - want).abs() < want.abs() * 1e-5 + 1e-3, "n={n}");
            assert_eq!(ps.hazards, 0, "n={n}: {:?}", ps.hazard_samples);
            let (ds, _) = reduction(n).run(&cfg, &[(0, f32_bits(&d))]).unwrap();
            assert!(
                ps.cycles as f64 > 2.0 * ds.cycles as f64,
                "n={n}: predicated {} vs dynamic {}",
                ps.cycles,
                ds.cycles
            );
        }
    }

    #[test]
    fn qp_similar_cycles() {
        // Table 7: reduction QP ≈ 0.95× DP cycles (few wide stores).
        let n = 64;
        let dp = EgpuConfig::benchmark(MemoryMode::Dp, false);
        let qp = EgpuConfig::benchmark(MemoryMode::Qp, false);
        let (s_dp, _) = reduction(n).run(&dp, &[(0, f32_bits(&data(n)))]).unwrap();
        let (s_qp, _) = reduction(n).run(&qp, &[(0, f32_bits(&data(n)))]).unwrap();
        let ratio = s_qp.cycles as f64 / s_dp.cycles as f64;
        assert!((0.7..=1.05).contains(&ratio), "QP/DP = {ratio:.2}");
    }
}
