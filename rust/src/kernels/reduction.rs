//! Vector reduction (Table 7, left block): `shared[n] = Σ shared[0..n]`.
//!
//! This is the paper's showcase for dynamic thread-space scaling (§3.1):
//! every tree level runs on a *prefix subset* of the thread space selected
//! by the instruction's 4-bit field, so no cycles are spent on idle
//! threads and no predicates are needed. The final scalar is written by
//! the single-thread MCU personality.
//!
//! The DOT-core variant replaces the whole tree with one SUM instruction
//! followed by NOPs covering the core's writeback latency (§7: "most of
//! the time is spent waiting (NOPs) for the dot product to write back").

use super::{depth_for, Kernel};
use crate::isa::{CondCode, TType, ThreadCtrl, WidthSel, WordLayout, WAVEFRONT_WIDTH};
use crate::kc::{KernelBuilder, SchedMode};
use crate::sim::config::MemoryMode;

/// Tree reduction via dynamic narrowing. `n` must be a power of two
/// ≥ 32 with n/16 expressible prefixes at every level (32/64/128 are).
pub fn reduction(n: usize) -> Kernel {
    reduction_mode(n, SchedMode::List)
}

/// Schedule-mode-aware build (List = default; Fenced = the
/// schedule-disabled correctness oracle; Linear = in-order padding).
pub fn reduction_mode(n: usize, mode: SchedMode) -> Kernel {
    reduction_cfg(n, MemoryMode::Dp, WordLayout::for_regs(32), mode)
}

/// Fully specialized build: target memory organization *and* register
/// layout (the kernel-specialization cache's entry point — under QP the
/// scheduler sees the doubled store bandwidth).
pub fn reduction_cfg(n: usize, memory: MemoryMode, layout: WordLayout, mode: SchedMode) -> Kernel {
    assert!(n.is_power_of_two() && n >= 32, "n must be a power of two ≥ 32");
    let total_waves = n / WAVEFRONT_WIDTH;
    let name = format!("reduction-{n}");
    let mut b = KernelBuilder::new(&name, n, layout, memory);
    let t = b.tdx();

    b.comment("fold pairs through shared memory until 16 partials remain");
    let mut s = n / 2;
    while s >= WAVEFRONT_WIDTH {
        let waves = s / WAVEFRONT_WIDTH;
        let d = depth_for(total_waves, waves)
            .unwrap_or_else(|| panic!("level {s} not expressible from {total_waves} waves"));
        b.space(ThreadCtrl::new(WidthSel::All16, d));
        b.comment(&format!("level: {s} partial sums"));
        let x = b.lod(t, 0);
        let y = b.lod(t, s);
        let z = b.fadd(x, y);
        b.sto(z, t, 0);
        s /= 2;
    }

    b.comment("16 -> 4 on the first four SPs");
    b.space(ThreadCtrl::new(WidthSel::Quarter4, crate::isa::DepthSel::Wave0));
    let x1 = b.lod(t, 0);
    let x2 = b.lod(t, 4);
    let x3 = b.lod(t, 8);
    let x4 = b.lod(t, 12);
    let s1 = b.fadd(x1, x2);
    let s2 = b.fadd(x3, x4);
    let s3 = b.fadd(s1, s2);
    b.sto(s3, t, 0);

    b.comment("4 -> 1 in the MCU personality, result to shared[n]");
    b.space(ThreadCtrl::MCU);
    let y1 = b.lod(t, 0);
    let y2 = b.lod(t, 1);
    let y3 = b.lod(t, 2);
    let y4 = b.lod(t, 3);
    let u1 = b.fadd(y1, y2);
    let u2 = b.fadd(y3, y4);
    let u3 = b.fadd(u1, u2);
    b.sto(u3, t, n);
    b.full();
    b.stop();

    Kernel::from_compiled(name, b.finish(mode).unwrap(), n, n)
}

/// DOT-core variant: one SUM over the whole thread space.
pub fn reduction_dot(n: usize) -> Kernel {
    reduction_dot_mode(n, SchedMode::List)
}

pub fn reduction_dot_mode(n: usize, mode: SchedMode) -> Kernel {
    reduction_dot_cfg(n, MemoryMode::Dp, WordLayout::for_regs(32), mode)
}

/// Fully specialized DOT-core build.
pub fn reduction_dot_cfg(
    n: usize,
    memory: MemoryMode,
    layout: WordLayout,
    mode: SchedMode,
) -> Kernel {
    assert!(n.is_power_of_two() && n >= 32);
    let name = format!("reduction-dot-{n}");
    let mut b = KernelBuilder::new(&name, n, layout, memory);
    let t = b.tdx();
    let x = b.lod(t, 0);
    b.comment("SUM streams all wavefronts into the reduction core");
    let s = b.sum(x);
    b.comment("extension-core writeback latency covered by the schedule (§7)");
    b.space(ThreadCtrl::MCU);
    b.sto(s, t, n);
    b.full();
    b.stop();
    Kernel::from_compiled(name, b.finish(mode).unwrap(), n, n)
}

/// Ablation variant: the same tree WITHOUT dynamic thread-space scaling,
/// using predicates the way a conventional SIMT machine would (§3.1:
/// "Most GPGPUs support thread divergence by predicates but these have a
/// potential significant performance impact, as all threads are run,
/// whether or not they are written back"). Every level issues over the
/// full thread space; only the writebacks are gated. Requires a
/// predicated configuration. Result lands at `shared[n]`.
pub fn reduction_predicated(n: usize) -> Kernel {
    reduction_predicated_mode(n, SchedMode::List)
}

pub fn reduction_predicated_mode(n: usize, mode: SchedMode) -> Kernel {
    reduction_predicated_cfg(n, MemoryMode::Dp, WordLayout::for_regs(32), mode)
}

/// Fully specialized predicated-ablation build.
pub fn reduction_predicated_cfg(
    n: usize,
    memory: MemoryMode,
    layout: WordLayout,
    mode: SchedMode,
) -> Kernel {
    assert!(n.is_power_of_two() && n >= 32);
    let name = format!("reduction-pred-{n}");
    let mut b = KernelBuilder::new(&name, n, layout, memory);
    let t = b.tdx();
    let mut span = n / 2;
    while span >= 1 {
        b.comment(&format!("level: threads < {span} fold, all threads issue"));
        let lim = b.ldi(span as i64);
        b.if_cc(CondCode::Lt, TType::Uint, t, lim);
        let x = b.lod(t, 0);
        let y = b.lod(t, span);
        let z = b.fadd(x, y);
        b.sto(z, t, 0);
        b.endif();
        span /= 2;
    }
    b.comment("copy the scalar to shared[n] (thread 0 only, still gated)");
    let one = b.ldi(1);
    b.if_cc(CondCode::Lt, TType::Uint, t, one);
    let x = b.lod(t, 0);
    b.sto(x, t, n);
    b.endif();
    b.stop();
    Kernel::from_compiled(name, b.finish(mode).unwrap(), n, n)
}

/// Oracle: f32 sum in tree order (close enough — tests use a tolerance).
pub fn oracle(data: &[f32]) -> f32 {
    data.iter().copied().sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::f32_bits;
    use crate::sim::config::{EgpuConfig, MemoryMode};

    fn data(n: usize) -> Vec<f32> {
        (0..n).map(|i| (i as f32 * 0.5) - 7.0).collect()
    }

    #[test]
    fn tree_reduction_correct_all_sizes() {
        for n in [32usize, 64, 128] {
            let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
            let d = data(n);
            let (stats, m) = reduction(n)
                .run(&cfg, &[(0, f32_bits(&d))])
                .unwrap_or_else(|e| panic!("n={n}: {e}"));
            let got = f32::from_bits(m.shared().read(n as u32).unwrap());
            let want = oracle(&d);
            assert!(
                (got - want).abs() < want.abs() * 1e-5 + 1e-3,
                "n={n}: got {got}, want {want}"
            );
            assert_eq!(stats.hazards, 0, "n={n}: {:?}", stats.hazard_samples);
        }
    }

    #[test]
    fn dot_variant_correct_and_faster() {
        let cfg = EgpuConfig::benchmark(MemoryMode::Dp, true);
        for n in [32usize, 64, 128] {
            let d = data(n);
            let (dstats, m) = reduction_dot(n).run(&cfg, &[(0, f32_bits(&d))]).unwrap();
            let got = f32::from_bits(m.shared().read(n as u32).unwrap());
            let want = oracle(&d);
            assert!((got - want).abs() < want.abs() * 1e-5 + 1e-3, "n={n}");
            assert_eq!(dstats.hazards, 0, "n={n}");
            let (tstats, _) = reduction(n).run(&cfg, &[(0, f32_bits(&d))]).unwrap();
            assert!(
                dstats.cycles * 2 < tstats.cycles,
                "n={n}: dot {} vs tree {}",
                dstats.cycles,
                tstats.cycles
            );
        }
    }

    #[test]
    fn cycle_counts_at_or_below_paper() {
        // Table 7 eGPU-DP: 168/202/216 cycles for n = 32/64/128. The list
        // scheduler may beat the paper's hand schedules, so the band is an
        // upper bound only; growth with n must survive.
        let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
        let mut last = 0;
        for (n, paper) in [(32usize, 168u64), (64, 202), (128, 216)] {
            let (stats, _) = reduction(n).run(&cfg, &[(0, f32_bits(&data(n)))]).unwrap();
            assert!(
                (stats.cycles as f64) < paper as f64 * 2.0,
                "n={n}: {} vs paper {paper}",
                stats.cycles
            );
            assert!(stats.cycles > last, "cycles must grow with n");
            last = stats.cycles;
        }
    }

    #[test]
    fn predicated_variant_correct_but_much_slower() {
        // §3.1 ablation: dynamic narrowing vs conventional predication.
        let pcfg = EgpuConfig::benchmark_predicated(MemoryMode::Dp);
        let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
        for n in [32usize, 128] {
            let d = data(n);
            let (ps, m) = reduction_predicated(n).run(&pcfg, &[(0, f32_bits(&d))]).unwrap();
            let got = f32::from_bits(m.shared().read(n as u32).unwrap());
            let want = oracle(&d);
            assert!((got - want).abs() < want.abs() * 1e-5 + 1e-3, "n={n}");
            assert_eq!(ps.hazards, 0, "n={n}: {:?}", ps.hazard_samples);
            let (ds, _) = reduction(n).run(&cfg, &[(0, f32_bits(&d))]).unwrap();
            assert!(
                ps.cycles as f64 > 2.0 * ds.cycles as f64,
                "n={n}: predicated {} vs dynamic {}",
                ps.cycles,
                ds.cycles
            );
        }
    }

    #[test]
    fn qp_similar_cycles() {
        // Table 7: reduction QP ≈ 0.95× DP cycles (few wide stores).
        let n = 64;
        let dp = EgpuConfig::benchmark(MemoryMode::Dp, false);
        let qp = EgpuConfig::benchmark(MemoryMode::Qp, false);
        let (s_dp, _) = reduction(n).run(&dp, &[(0, f32_bits(&data(n)))]).unwrap();
        let (s_qp, _) = reduction(n).run(&qp, &[(0, f32_bits(&data(n)))]).unwrap();
        let ratio = s_qp.cycles as f64 / s_dp.cycles as f64;
        assert!((0.6..=1.1).contains(&ratio), "QP/DP = {ratio:.2}");
    }
}
