//! Radix-4 DIT FFT — the optimization §7 suggests: "by using a higher
//! radix FFT, there will be correspondingly fewer passes through the
//! shared memory. (We have a extensive flexibility in specifying the
//! register and thread parameters, we can easily support much higher
//! radices, which will require much larger register spaces)."
//!
//! Half the stages of the radix-2 kernel, so roughly half the shared-
//! memory write traffic — the dominant cycle cost. The butterfly keeps
//! four complex values plus three twiddles in registers (22 live
//! registers vs 13 for radix-2 — exactly the register-space trade the
//! paper describes).
//!
//! Layout (32-bit words): re at 0, im at `n`, twiddle cos at `2n`
//! (3n/4 entries — radix-4 needs angles up to 3·2π·(n/4-1)/n), sin at
//! `2n + 3n/4`, digit-reverse staging at `4n`/`5n`.
//!
//! `n` must be a power of 4 (64, 256): pure radix-4 with base-4 digit
//! reversal (bit reversal + adjacent-bit swap via BVS/shift/mask).

use super::sched::Sched;
use super::Kernel;
use crate::isa::{WordLayout, WAVEFRONT_WIDTH};
use crate::sim::config::MemoryMode;

/// Supported sizes: powers of 4 with at least one full wavefront of
/// butterflies.
pub fn supported(n: usize) -> bool {
    n.is_power_of_two() && n.trailing_zeros() % 2 == 0 && (64..=1024).contains(&n)
}

/// Radix-4 FFT of `n` complex points in place at re `[0,n)` / im `[n,2n)`.
pub fn fft4(n: usize) -> Kernel {
    fft4_for(n, MemoryMode::Dp)
}

pub fn fft4_for(n: usize, memory: MemoryMode) -> Kernel {
    assert!(supported(n), "n must be a power of 4 in [64, 1024]");
    let threads = (n / 4).max(WAVEFRONT_WIDTH);
    let log2n = n.trailing_zeros();
    let stages = log2n / 2;
    let im = n;
    let cos = 2 * n;
    let sin = 2 * n + 3 * n / 4;
    let sre = 4 * n;
    let sim = 5 * n;

    let mut s = Sched::new(&format!("fft4-{n}"), threads, WordLayout::for_regs(32), memory);
    s.comment("r0 = butterfly index t; constants: r13=1, r3=32-log2n, r14=0x5555 mask");
    s.op("tdx r0")
        .op("ldi r13, #1")
        .op(format!("ldi r3, #{}", 32 - log2n))
        .op("ldi r14, #0x5555")
        .op(format!("ldi r15, #{}", 16))
        .op("shl.u32 r15, r14, r15")
        .op("or r14, r14, r15");
    s.comment("--- base-4 digit-reverse permutation via staging copy ---");
    s.comment("stage copy: thread t moves elements t + c*n/4, c = 0..3");
    for c in 0..4usize {
        s.op(format!("lod r{}, (r0)+{}", 19 + c, c * n / 4));
        s.op(format!("lod r{}, (r0)+{}", 23 + c, im + c * n / 4));
    }
    for c in 0..4usize {
        s.op(format!("sto r{}, (r0)+{}", 19 + c, sre + c * n / 4));
        s.op(format!("sto r{}, (r0)+{}", 23 + c, sim + c * n / 4));
    }
    s.comment("rev4(t) = bitrev(t) with adjacent bit pairs swapped; low digit 0");
    s.op("bvs r9, r0")
        .op("shr.u32 r9, r9, r3")
        .op("and r10, r9, r14")
        .op("shl.u32 r10, r10, r13")
        .op("shr.u32 r11, r9, r13")
        .op("and r11, r11, r14")
        .op("or r9, r10, r11");
    s.comment("gather: x[t + c*n/4] = staged[rev4(t) + c]");
    for c in 0..4usize {
        if c > 0 {
            s.op("add.u32 r9, r9, r13");
        }
        s.op(format!("lod r{}, (r9)+{}", 19 + c, sre));
        s.op(format!("lod r{}, (r9)+{}", 23 + c, sim));
    }
    for c in 0..4usize {
        s.op(format!("sto r{}, (r0)+{}", 19 + c, c * n / 4));
        s.op(format!("sto r{}, (r0)+{}", 23 + c, im + c * n / 4));
    }

    s.comment("--- radix-4 stages, shared subroutine ---");
    for stage in 0..stages {
        let q = 1usize << (2 * stage); // quarter-span
        s.comment(&format!("stage {stage}: span {}", 4 * q));
        s.op(format!("ldi r16, #{}", q - 1))
            .op(format!("ldi r17, #{q}"))
            .op(format!("ldi r18, #{}", log2n - 2 * stage - 2));
        s.fence();
        s.op("jsr stage4");
    }
    s.op("stop");

    // Stage subroutine: r16 = q-1, r17 = q, r18 = twiddle shift.
    // Registers: i0..i3 in r4..r7 (i0 via expand), u0..u3 in
    // (r19,r20),(r21,r22),(r23,r24),(r25,r26), temps r8..r12, r27..r29.
    s.label("stage4");
    s.comment("i0 = (t - p)*4 + p; i1..i3 = i0 + c*q");
    s.op("and r8, r0, r16")
        .op("sub.u32 r4, r0, r8")
        .op("shl.u32 r4, r4, r13")
        .op("shl.u32 r4, r4, r13")
        .op("add.u32 r4, r4, r8")
        .op("add.u32 r5, r4, r17")
        .op("add.u32 r6, r5, r17")
        .op("add.u32 r7, r6, r17");
    s.comment("u0 = x[i0] (no twiddle)");
    s.op("lod r19, (r4)+0").op(format!("lod r20, (r4)+{im}"));
    s.comment("u_c = W^(c*p*n/m) * x[i_c], c = 1..3");
    s.op("shl.u32 r9, r8, r18") // base twiddle index p << shift
        .op("or r10, r9, r9"); // keep the base for the 2p/3p accumulation
    for c in 1..4usize {
        let (ur, ui) = (17 + 2 * c + 2, 18 + 2 * c + 2); // r21/r22, r23/r24, r25/r26
        let addr = 4 + c; // i1..i3 live in r5, r6, r7
        if c > 1 {
            s.op("add.u32 r9, r9, r10"); // idx += base idx (2p, 3p)
        }
        s.op(format!("lod r11, (r9)+{cos}")) // wr
            .op(format!("lod r12, (r9)+{sin}")) // sin
            .op("fneg r12, r12") // wi = -sin
            .op(format!("lod r27, (r{addr})+0")) // xr
            .op(format!("lod r28, (r{addr})+{im}")); // xi
        s.op(format!("fmul r{ur}, r27, r11"))
            .op("fmul r29, r28, r12")
            .op(format!("fsub r{ur}, r{ur}, r29"))
            .op(format!("fmul r{ui}, r27, r12"))
            .op("fmul r29, r28, r11")
            .op(format!("fadd r{ui}, r{ui}, r29"));
    }
    s.comment("a = u0+u2, b = u0-u2, c = u1+u3, d = u1-u3 (in place)");
    s.op("fadd r27, r19, r23") // ar
        .op("fadd r28, r20, r24") // ai
        .op("fsub r19, r19, r23") // br (overwrites u0r)
        .op("fsub r20, r20, r24") // bi
        .op("fadd r23, r21, r25") // cr (overwrites u2r)
        .op("fadd r24, r22, r26") // ci
        .op("fsub r21, r21, r25") // dr (overwrites u1r)
        .op("fsub r22, r22, r26"); // di
    s.comment("y0 = a+c, y2 = a-c, y1 = b - j*d, y3 = b + j*d");
    s.op("fadd r29, r27, r23").op("sto r29, (r4)+0");
    s.op("fadd r29, r28, r24").op(format!("sto r29, (r4)+{im}"));
    s.op("fsub r29, r27, r23").op("sto r29, (r6)+0");
    s.op("fsub r29, r28, r24").op(format!("sto r29, (r6)+{im}"));
    // -j*d = (di, -dr): y1 = (br + di, bi - dr)
    s.op("fadd r29, r19, r22").op("sto r29, (r5)+0");
    s.op("fsub r29, r20, r21").op(format!("sto r29, (r5)+{im}"));
    // +j*d = (-di, dr): y3 = (br - di, bi + dr)
    s.op("fsub r29, r19, r22").op("sto r29, (r7)+0");
    s.op("fadd r29, r20, r21").op(format!("sto r29, (r7)+{im}"));
    s.op("rts");

    Kernel {
        name: format!("fft4-{n}"),
        asm: s.into_source(),
        threads,
        dim_x: threads,
    }
}

/// Radix-4 twiddle tables: 3n/4 entries of cos/sin at angle 2πt/n.
pub fn twiddles4(n: usize) -> (Vec<f32>, Vec<f32>) {
    let mut c = Vec::with_capacity(3 * n / 4);
    let mut s = Vec::with_capacity(3 * n / 4);
    for t in 0..3 * n / 4 {
        let w = 2.0 * std::f64::consts::PI * t as f64 / n as f64;
        c.push(w.cos() as f32);
        s.push(w.sin() as f32);
    }
    (c, s)
}

/// Shared-memory initialization for `run()`: input + radix-4 twiddles.
pub fn shared_init(re: &[f32], im: &[f32]) -> Vec<(usize, Vec<u32>)> {
    let n = re.len();
    assert_eq!(im.len(), n);
    let (c, s) = twiddles4(n);
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    vec![
        (0, bits(re)),
        (n, bits(im)),
        (2 * n, bits(&c)),
        (2 * n + 3 * n / 4, bits(&s)),
    ]
}

#[cfg(test)]
mod tests {
    use super::super::fft;
    use super::*;
    use crate::sim::config::EgpuConfig;

    fn tones(n: usize) -> (Vec<f32>, Vec<f32>) {
        let re: Vec<f32> = (0..n)
            .map(|i| {
                let x = i as f64 / n as f64;
                ((2.0 * std::f64::consts::PI * 5.0 * x).cos()
                    + 0.3 * (2.0 * std::f64::consts::PI * 11.0 * x).sin()) as f32
            })
            .collect();
        let im: Vec<f32> = (0..n).map(|i| (i as f32 * 0.01) - 0.1).collect();
        (re, im)
    }

    fn run4(n: usize, memory: MemoryMode) -> (crate::sim::RunStats, Vec<f32>, Vec<f32>) {
        let cfg = EgpuConfig::benchmark(memory, false);
        let (re, im) = tones(n);
        let (stats, m) = fft4_for(n, memory)
            .run(&cfg, &shared_init(&re, &im))
            .unwrap_or_else(|e| panic!("n={n}: {e}"));
        let gr = m.shared().read_block(0, n).iter().map(|&b| f32::from_bits(b)).collect();
        let gi = m.shared().read_block(n, n).iter().map(|&b| f32::from_bits(b)).collect();
        (stats, gr, gi)
    }

    #[test]
    fn matches_dft() {
        for n in [64usize, 256] {
            let (stats, gr, gi) = run4(n, MemoryMode::Dp);
            assert_eq!(stats.hazards, 0, "n={n}: {:?}", stats.hazard_samples);
            let (re, im) = tones(n);
            let (wr, wi) = fft::oracle(&re, &im);
            let tol = 1e-3 * n as f64;
            for k in 0..n {
                assert!(
                    (gr[k] as f64 - wr[k]).abs() < tol && (gi[k] as f64 - wi[k]).abs() < tol,
                    "n={n} bin {k}: ({}, {}) vs ({:.4}, {:.4})",
                    gr[k],
                    gi[k],
                    wr[k],
                    wi[k]
                );
            }
        }
    }

    #[test]
    fn fewer_cycles_than_radix2() {
        // §7: fewer passes through shared memory. The win grows with n:
        // at n=64 the 16-thread machine is NOP-bound (1 wavefront), at
        // n=256 the halved store traffic dominates (measured 1.26x/1.53x).
        for (n, want) in [(64usize, 1.2), (256, 1.45)] {
            let (s4, ..) = run4(n, MemoryMode::Dp);
            let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
            let (re, im) = tones(n);
            let (s2, _) = fft::fft(n).run(&cfg, &fft::shared_init(&re, &im)).unwrap();
            let ratio = s2.cycles as f64 / s4.cycles as f64;
            assert!(
                ratio >= want,
                "n={n}: radix-4 {} vs radix-2 {} ({ratio:.2}x < {want}x)",
                s4.cycles,
                s2.cycles
            );
        }
    }

    #[test]
    fn qp_variant_works() {
        let (stats, gr, _) = run4(64, MemoryMode::Qp);
        assert_eq!(stats.hazards, 0);
        assert!(gr.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn rejects_non_power_of_4() {
        assert!(!supported(32));
        assert!(!supported(128));
        assert!(supported(64));
        assert!(supported(256));
        assert!(std::panic::catch_unwind(|| fft4(128)).is_err());
    }
}
