//! Radix-4 DIT FFT — the optimization §7 suggests: "by using a higher
//! radix FFT, there will be correspondingly fewer passes through the
//! shared memory. (We have a extensive flexibility in specifying the
//! register and thread parameters, we can easily support much higher
//! radices, which will require much larger register spaces)."
//!
//! Half the stages of the radix-2 kernel, so roughly half the shared-
//! memory write traffic — the dominant cycle cost. The butterfly keeps
//! four complex values plus three twiddles live at once (the register-
//! space trade the paper describes; the allocator packs the temporaries).
//!
//! Layout (32-bit words): re at 0, im at `n`, twiddle cos at `2n`
//! (3n/4 entries — radix-4 needs angles up to 3·2π·(n/4-1)/n), sin at
//! `2n + 3n/4`, digit-reverse staging at `4n`/`5n`.
//!
//! `n` must be a power of 4 (64, 256): pure radix-4 with base-4 digit
//! reversal (bit reversal + adjacent-bit swap via BVS/shift/mask).

use super::Kernel;
use crate::isa::{WordLayout, WAVEFRONT_WIDTH};
use crate::kc::{KernelBuilder, SchedMode, V};
use crate::sim::config::MemoryMode;

/// Supported sizes: powers of 4 with at least one full wavefront of
/// butterflies.
pub fn supported(n: usize) -> bool {
    n.is_power_of_two() && n.trailing_zeros() % 2 == 0 && (64..=1024).contains(&n)
}

/// Radix-4 FFT of `n` complex points in place at re `[0,n)` / im `[n,2n)`.
pub fn fft4(n: usize) -> Kernel {
    fft4_for(n, MemoryMode::Dp)
}

pub fn fft4_for(n: usize, memory: MemoryMode) -> Kernel {
    fft4_mode(n, memory, SchedMode::List)
}

/// Schedule-mode-aware build (List = default; Fenced = the
/// schedule-disabled correctness oracle; Linear = in-order padding).
pub fn fft4_mode(n: usize, memory: MemoryMode, mode: SchedMode) -> Kernel {
    fft4_cfg(n, memory, WordLayout::for_regs(32), mode)
}

/// Fully specialized build: target memory organization *and* register
/// layout (the kernel-specialization cache's entry point).
pub fn fft4_cfg(n: usize, memory: MemoryMode, layout: WordLayout, mode: SchedMode) -> Kernel {
    assert!(supported(n), "n must be a power of 4 in [64, 1024]");
    let threads = (n / 4).max(WAVEFRONT_WIDTH);
    let log2n = n.trailing_zeros();
    let stages = log2n / 2;
    let im = n;
    let cos = 2 * n;
    let sin = 2 * n + 3 * n / 4;
    let sre = 4 * n;
    let sim = 5 * n;

    let name = format!("fft4-{n}");
    let mut b = KernelBuilder::new(&name, threads, layout, memory);
    b.comment("t = butterfly index; constants: one, shv = 32-log2n, 0x55555555 mask");
    let t = b.tdx();
    let one = b.ldi(1);
    let shv = b.ldi((32 - log2n) as i64);
    let m_lo = b.ldi(0x5555);
    let m_sh = b.ldi(16);
    let m_hi = b.shl_u(m_lo, m_sh);
    let mask = b.or_i(m_lo, m_hi);

    b.comment("--- base-4 digit-reverse permutation via staging copy ---");
    b.comment("stage copy: thread t moves elements t + c*n/4, c = 0..3");
    let mut gre = Vec::new();
    let mut gim = Vec::new();
    for c in 0..4usize {
        gre.push(b.lod(t, c * n / 4));
        gim.push(b.lod(t, im + c * n / 4));
    }
    for c in 0..4usize {
        b.sto(gre[c], t, sre + c * n / 4);
        b.sto(gim[c], t, sim + c * n / 4);
    }
    b.comment("rev4(t) = bitrev(t) with adjacent bit pairs swapped; low digit 0");
    let rv = b.bvs(t);
    let rsh = b.shr_u(rv, shv);
    let even = b.and_i(rsh, mask);
    let even_up = b.shl_u(even, one);
    let odd = b.shr_u(rsh, one);
    let odd_lo = b.and_i(odd, mask);
    let rev = b.or_i(even_up, odd_lo);
    b.comment("gather: x[t + c*n/4] = staged[rev4(t) + c]");
    let mut hre = Vec::new();
    let mut him = Vec::new();
    for c in 0..4usize {
        if c > 0 {
            b.add_u_into(rev, rev, one);
        }
        hre.push(b.lod(rev, sre));
        him.push(b.lod(rev, sim));
    }
    for c in 0..4usize {
        b.sto(hre[c], t, c * n / 4);
        b.sto(him[c], t, im + c * n / 4);
    }

    b.comment("--- radix-4 stages, shared subroutine ---");
    let mut p_mask: Option<V> = None;
    let mut p_q: Option<V> = None;
    let mut p_shift: Option<V> = None;
    for stage in 0..stages {
        let q = 1usize << (2 * stage); // quarter-span
        b.comment(&format!("stage {stage}: span {}", 4 * q));
        b.ldi_reuse(&mut p_mask, (q - 1) as i64);
        b.ldi_reuse(&mut p_q, q as i64);
        b.ldi_reuse(&mut p_shift, (log2n - 2 * stage - 2) as i64);
        b.jsr("stage4");
    }
    b.stop();
    let (p_mask, p_q, p_shift) = (p_mask.unwrap(), p_q.unwrap(), p_shift.unwrap());

    // Stage subroutine: p_mask = q-1, p_q = q, p_shift = twiddle shift.
    b.label("stage4");
    b.comment("i0 = (t - p)*4 + p; i1..i3 = i0 + c*q");
    let p = b.and_i(t, p_mask);
    let d0 = b.sub_u(t, p);
    let d1 = b.shl_u(d0, one);
    let d2 = b.shl_u(d1, one);
    let i0 = b.add_u(d2, p);
    let i1 = b.add_u(i0, p_q);
    let i2 = b.add_u(i1, p_q);
    let i3 = b.add_u(i2, p_q);
    b.comment("u0 = x[i0] (no twiddle)");
    let u0r = b.lod(i0, 0);
    let u0i = b.lod(i0, im);
    b.comment("u_c = W^(c*p*n/m) * x[i_c], c = 1..3");
    let base = b.shl_u(p, p_shift);
    let idx = b.or_i(base, base); // running twiddle index: p, 2p, 3p
    let addrs = [i1, i2, i3];
    let mut ure = Vec::new();
    let mut uim = Vec::new();
    for (c, &ic) in addrs.iter().enumerate() {
        if c > 0 {
            b.add_u_into(idx, idx, base);
        }
        let wr = b.lod(idx, cos);
        let ws = b.lod(idx, sin);
        let wi = b.fneg(ws);
        let xr = b.lod(ic, 0);
        let xi = b.lod(ic, im);
        let t1 = b.fmul(xr, wr);
        let t2 = b.fmul(xi, wi);
        ure.push(b.fsub(t1, t2));
        let t3 = b.fmul(xr, wi);
        let t4 = b.fmul(xi, wr);
        uim.push(b.fadd(t3, t4));
    }
    let (u1r, u2r, u3r) = (ure[0], ure[1], ure[2]);
    let (u1i, u2i, u3i) = (uim[0], uim[1], uim[2]);
    b.comment("a = u0+u2, b = u0-u2, c = u1+u3, d = u1-u3");
    let ar = b.fadd(u0r, u2r);
    let ai = b.fadd(u0i, u2i);
    let br = b.fsub(u0r, u2r);
    let bi = b.fsub(u0i, u2i);
    let cr = b.fadd(u1r, u3r);
    let ci = b.fadd(u1i, u3i);
    let dr = b.fsub(u1r, u3r);
    let di = b.fsub(u1i, u3i);
    b.comment("y0 = a+c, y2 = a-c, y1 = b - j*d, y3 = b + j*d");
    let y0r = b.fadd(ar, cr);
    b.sto(y0r, i0, 0);
    let y0i = b.fadd(ai, ci);
    b.sto(y0i, i0, im);
    let y2r = b.fsub(ar, cr);
    b.sto(y2r, i2, 0);
    let y2i = b.fsub(ai, ci);
    b.sto(y2i, i2, im);
    // -j*d = (di, -dr): y1 = (br + di, bi - dr)
    let y1r = b.fadd(br, di);
    b.sto(y1r, i1, 0);
    let y1i = b.fsub(bi, dr);
    b.sto(y1i, i1, im);
    // +j*d = (-di, dr): y3 = (br - di, bi + dr)
    let y3r = b.fsub(br, di);
    b.sto(y3r, i3, 0);
    let y3i = b.fadd(bi, dr);
    b.sto(y3i, i3, im);
    b.rts();

    Kernel::from_compiled(name, b.finish(mode).unwrap(), threads, threads)
}

/// Radix-4 twiddle tables: 3n/4 entries of cos/sin at angle 2πt/n.
pub fn twiddles4(n: usize) -> (Vec<f32>, Vec<f32>) {
    let mut c = Vec::with_capacity(3 * n / 4);
    let mut s = Vec::with_capacity(3 * n / 4);
    for t in 0..3 * n / 4 {
        let w = 2.0 * std::f64::consts::PI * t as f64 / n as f64;
        c.push(w.cos() as f32);
        s.push(w.sin() as f32);
    }
    (c, s)
}

/// Shared-memory initialization for `run()`: input + radix-4 twiddles.
pub fn shared_init(re: &[f32], im: &[f32]) -> Vec<(usize, Vec<u32>)> {
    let n = re.len();
    assert_eq!(im.len(), n);
    let (c, s) = twiddles4(n);
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    vec![
        (0, bits(re)),
        (n, bits(im)),
        (2 * n, bits(&c)),
        (2 * n + 3 * n / 4, bits(&s)),
    ]
}

#[cfg(test)]
mod tests {
    use super::super::fft;
    use super::*;
    use crate::sim::config::EgpuConfig;

    fn tones(n: usize) -> (Vec<f32>, Vec<f32>) {
        let re: Vec<f32> = (0..n)
            .map(|i| {
                let x = i as f64 / n as f64;
                ((2.0 * std::f64::consts::PI * 5.0 * x).cos()
                    + 0.3 * (2.0 * std::f64::consts::PI * 11.0 * x).sin()) as f32
            })
            .collect();
        let im: Vec<f32> = (0..n).map(|i| (i as f32 * 0.01) - 0.1).collect();
        (re, im)
    }

    fn run4(n: usize, memory: MemoryMode) -> (crate::sim::RunStats, Vec<f32>, Vec<f32>) {
        let cfg = EgpuConfig::benchmark(memory, false);
        let (re, im) = tones(n);
        let (stats, m) = fft4_for(n, memory)
            .run(&cfg, &shared_init(&re, &im))
            .unwrap_or_else(|e| panic!("n={n}: {e}"));
        let gr = m.shared().read_block(0, n).iter().map(|&b| f32::from_bits(b)).collect();
        let gi = m.shared().read_block(n, n).iter().map(|&b| f32::from_bits(b)).collect();
        (stats, gr, gi)
    }

    #[test]
    fn matches_dft() {
        for n in [64usize, 256] {
            let (stats, gr, gi) = run4(n, MemoryMode::Dp);
            assert_eq!(stats.hazards, 0, "n={n}: {:?}", stats.hazard_samples);
            let (re, im) = tones(n);
            let (wr, wi) = fft::oracle(&re, &im);
            let tol = 1e-3 * n as f64;
            for k in 0..n {
                assert!(
                    (gr[k] as f64 - wr[k]).abs() < tol && (gi[k] as f64 - wi[k]).abs() < tol,
                    "n={n} bin {k}: ({}, {}) vs ({:.4}, {:.4})",
                    gr[k],
                    gi[k],
                    wr[k],
                    wi[k]
                );
            }
        }
    }

    #[test]
    fn fewer_cycles_than_radix2() {
        // §7: fewer passes through shared memory. The win grows with n: at
        // n=64 the 16-thread machine is delay-slot-bound (and the list
        // scheduler shrinks that overhead for both radices), at n=256 the
        // halved store traffic dominates.
        for (n, want) in [(64usize, 1.02), (256, 1.3)] {
            let (s4, ..) = run4(n, MemoryMode::Dp);
            let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
            let (re, im) = tones(n);
            let (s2, _) = fft::fft(n).run(&cfg, &fft::shared_init(&re, &im)).unwrap();
            let ratio = s2.cycles as f64 / s4.cycles as f64;
            assert!(
                ratio >= want,
                "n={n}: radix-4 {} vs radix-2 {} ({ratio:.2}x < {want}x)",
                s4.cycles,
                s2.cycles
            );
        }
    }

    #[test]
    fn qp_variant_works() {
        let (stats, gr, _) = run4(64, MemoryMode::Qp);
        assert_eq!(stats.hazards, 0);
        assert!(gr.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn rejects_non_power_of_4() {
        assert!(!supported(32));
        assert!(!supported(128));
        assert!(supported(64));
        assert!(supported(256));
        assert!(std::panic::catch_unwind(|| fft4(128)).is_err());
    }
}
