//! Bitonic sort (Table 8, left block): sorts `shared[0..n]` ascending.
//!
//! §7: "The bitonic sort benchmark requires a wider mix of instructions.
//! Predicates are required ... The nature of the bitonic sort tends to use
//! many subroutine calls, which we can see here in the relatively large
//! number of branch operations. Again, the memory operations take the
//! majority of all cycles, as each pass of the sort requires a
//! redistribution of the data among the SPs."
//!
//! One thread per compare-exchange pair (n/2 threads). The log²(n)-pass
//! network shares a single JSR subroutine; each pass loads its (k, j)
//! parameters into registers and calls it. Ascending/descending selection
//! uses one predicate level (IF.eq/ELSE/ENDIF on `i & k`), with MIN/MAX
//! computing both outcomes unconditionally — only the register moves are
//! predicated, and every store slot is consumed whether or not a thread's
//! write lands (§3.2: predicates gate `write_enable`, not issue cycles).
//! Both predicate arms write the same compiler value (`or_i_into`), which
//! is how the post-ENDIF stores see the per-thread merge.

use super::Kernel;
use crate::isa::{CondCode, TType, WordLayout, WAVEFRONT_WIDTH};
use crate::kc::{KernelBuilder, SchedMode};
use crate::sim::config::MemoryMode;

/// Valid sizes: one thread per pair, at least one full wavefront.
pub const MIN_N: usize = 32;
pub const MAX_N: usize = 512;

/// Bitonic sort of `n` unsigned 32-bit words in place at shared `[0, n)`.
pub fn bitonic(n: usize) -> Kernel {
    bitonic_for(n, MemoryMode::Dp)
}

/// Memory-mode-aware variant (the schedule follows the mode's port costs).
pub fn bitonic_for(n: usize, memory: MemoryMode) -> Kernel {
    bitonic_mode(n, memory, SchedMode::List)
}

/// Schedule-mode-aware build (List = default; Fenced = the
/// schedule-disabled correctness oracle; Linear = in-order padding).
pub fn bitonic_mode(n: usize, memory: MemoryMode, mode: SchedMode) -> Kernel {
    bitonic_cfg(n, memory, WordLayout::for_regs(32), mode)
}

/// Fully specialized build: target memory organization *and* register
/// layout (the kernel-specialization cache's entry point).
pub fn bitonic_cfg(n: usize, memory: MemoryMode, layout: WordLayout, mode: SchedMode) -> Kernel {
    assert!(
        n.is_power_of_two() && (MIN_N..=MAX_N).contains(&n),
        "n must be a power of two in [{MIN_N}, {MAX_N}]"
    );
    let threads = (n / 2).max(WAVEFRONT_WIDTH);
    let name = format!("bitonic-{n}");
    let mut b = KernelBuilder::new(&name, threads, layout, memory);
    b.comment("t = pair index; constants one, zero");
    let t = b.tdx();
    let one = b.ldi(1);
    let zero = b.ldi(0);

    // Pass schedule: k = 2,4,..,n; j = k/2 .. 1. The (k, j) parameters are
    // compiler values redefined per call site; the subroutine reads them.
    let mut p_jm1 = None;
    let mut p_j = None;
    let mut p_k = None;
    let mut k = 2;
    while k <= n {
        b.comment(&format!("=== merge stage k={k} ==="));
        b.ldi_reuse(&mut p_k, k as i64);
        let mut j = k / 2;
        while j >= 1 {
            b.ldi_reuse(&mut p_jm1, (j - 1) as i64);
            b.ldi_reuse(&mut p_j, j as i64);
            b.jsr("pass");
            j /= 2;
        }
        k *= 2;
    }
    b.stop();
    let (p_jm1, p_j, p_k) = (p_jm1.unwrap(), p_j.unwrap(), p_k.unwrap());

    // The shared compare-exchange pass: params p_jm1 = j-1, p_j = j, p_k = k.
    b.label("pass");
    b.comment("expand pair index t to element index i (insert 0 at bit log2 j)");
    let low = b.and_i(t, p_jm1);
    let hi0 = b.sub_u(t, low);
    let hi1 = b.shl_u(hi0, one);
    let i6 = b.add_u(hi1, low);
    let l7 = b.xor_i(i6, p_j);
    let dir = b.and_i(i6, p_k);
    b.comment("compare-exchange: compute both orders, predicate the select");
    let a = b.lod(i6, 0);
    let c = b.lod(l7, 0);
    let lo = b.min_u(a, c);
    let hi = b.max_u(a, c);
    b.if_cc(CondCode::Eq, TType::Int, dir, zero);
    b.comment("ascending: mem[i] <- min, mem[l] <- max");
    let first = b.or_i(lo, zero);
    let second = b.or_i(hi, zero);
    b.else_();
    b.comment("descending: mem[i] <- max, mem[l] <- min");
    b.or_i_into(first, hi, zero);
    b.or_i_into(second, lo, zero);
    b.endif();
    b.sto(first, i6, 0);
    b.sto(second, l7, 0);
    b.rts();

    Kernel::from_compiled(name, b.finish(mode).unwrap(), threads, threads)
}

/// Oracle: ascending sort.
pub fn oracle(data: &[u32]) -> Vec<u32> {
    let mut v = data.to_vec();
    v.sort_unstable();
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::EgpuConfig;

    fn data(n: usize) -> Vec<u32> {
        let mut lcg = 0x2545F4914F6CDD1Du64;
        (0..n)
            .map(|_| {
                lcg = lcg.wrapping_mul(6364136223846793005).wrapping_add(1);
                (lcg >> 33) as u32
            })
            .collect()
    }

    #[test]
    fn sorts_all_sizes() {
        for n in [32usize, 64, 128, 256] {
            let cfg = EgpuConfig::benchmark_predicated(MemoryMode::Dp);
            let d = data(n);
            let (stats, m) = bitonic(n)
                .run(&cfg, &[(0, d.clone())])
                .unwrap_or_else(|e| panic!("n={n}: {e}"));
            assert_eq!(m.shared().read_block(0, n), &oracle(&d)[..], "n={n}");
            assert_eq!(stats.hazards, 0, "n={n}: {:?}", stats.hazard_samples);
        }
    }

    #[test]
    fn sorts_adversarial_patterns() {
        let cfg = EgpuConfig::benchmark_predicated(MemoryMode::Dp);
        let n = 64;
        for d in [
            (0..n as u32).rev().collect::<Vec<_>>(), // descending
            vec![7; n],                               // all equal
            (0..n as u32).collect::<Vec<_>>(),        // pre-sorted
            (0..n as u32).map(|i| i ^ 0x80000000).collect(), // high-bit mix
        ] {
            let (_, m) = bitonic(n).run(&cfg, &[(0, d.clone())]).unwrap();
            assert_eq!(m.shared().read_block(0, n), &oracle(&d)[..]);
        }
    }

    #[test]
    fn cycle_counts_at_or_below_paper() {
        // Table 8 eGPU-DP: 1742 / 3728 / 8326 / 16578 for n = 32..256.
        // Upper bound only — the list scheduler may beat the paper.
        let cfg = EgpuConfig::benchmark_predicated(MemoryMode::Dp);
        for (n, paper) in [(32usize, 1742u64), (64, 3728), (128, 8326), (256, 16578)] {
            let (stats, _) = bitonic(n).run(&cfg, &[(0, data(n))]).unwrap();
            let r = stats.cycles as f64 / paper as f64;
            assert!(
                r <= 2.0,
                "n={n}: {} vs paper {paper} ({r:.2}x)",
                stats.cycles
            );
        }
    }

    #[test]
    fn qp_fewer_cycles() {
        // Table 8: QP needs ~0.72-0.86x the DP cycles (write bandwidth).
        let n = 128;
        let d = data(n);
        let dp_cfg = EgpuConfig::benchmark_predicated(MemoryMode::Dp);
        let qp_cfg = EgpuConfig::benchmark_predicated(MemoryMode::Qp);
        let (s_dp, _) = bitonic(n).run(&dp_cfg, &[(0, d.clone())]).unwrap();
        let (s_qp, m) = bitonic_for(n, MemoryMode::Qp).run(&qp_cfg, &[(0, d.clone())]).unwrap();
        assert_eq!(m.shared().read_block(0, n), &oracle(&d)[..]);
        let ratio = s_qp.cycles as f64 / s_dp.cycles as f64;
        assert!((0.5..=0.98).contains(&ratio), "QP/DP = {ratio:.2}");
    }

    #[test]
    fn requires_predicates() {
        let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false); // no predicates
        let err = match bitonic(32).run(&cfg, &[(0, data(32))]) {
            Err(e) => e,
            Ok(_) => panic!("bitonic must fail to load without predicates"),
        };
        assert!(err.message.contains("predicates"), "{err}");
    }

    #[test]
    fn uses_subroutine_calls() {
        // §7: "many subroutine calls" — the profile must show branches.
        let cfg = EgpuConfig::benchmark_predicated(MemoryMode::Dp);
        let (stats, _) = bitonic(64).run(&cfg, &[(0, data(64))]).unwrap();
        let branches = stats.profile.count(crate::isa::Group::Control);
        assert!(branches > 40, "only {branches} control instructions");
    }
}
