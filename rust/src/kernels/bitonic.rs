//! Bitonic sort (Table 8, left block): sorts `shared[0..n]` ascending.
//!
//! §7: "The bitonic sort benchmark requires a wider mix of instructions.
//! Predicates are required ... The nature of the bitonic sort tends to use
//! many subroutine calls, which we can see here in the relatively large
//! number of branch operations. Again, the memory operations take the
//! majority of all cycles, as each pass of the sort requires a
//! redistribution of the data among the SPs."
//!
//! One thread per compare-exchange pair (n/2 threads). The log²(n)-pass
//! network shares a single JSR subroutine; each pass loads its (k, j)
//! parameters into registers and calls it. Ascending/descending selection
//! uses one predicate level (IF.eq/ELSE/ENDIF on `i & k`), with MIN/MAX
//! computing both outcomes unconditionally — only the register moves are
//! predicated, and every store slot is consumed whether or not a thread's
//! write lands (§3.2: predicates gate `write_enable`, not issue cycles).

use super::sched::Sched;
use super::Kernel;
use crate::isa::{WordLayout, WAVEFRONT_WIDTH};
use crate::sim::config::MemoryMode;

/// Valid sizes: one thread per pair, at least one full wavefront.
pub const MIN_N: usize = 32;
pub const MAX_N: usize = 512;

/// Bitonic sort of `n` unsigned 32-bit words in place at shared `[0, n)`.
pub fn bitonic(n: usize) -> Kernel {
    bitonic_for(n, MemoryMode::Dp)
}

/// Memory-mode-aware variant (NOP schedule follows the mode's port costs).
pub fn bitonic_for(n: usize, memory: MemoryMode) -> Kernel {
    assert!(
        n.is_power_of_two() && (MIN_N..=MAX_N).contains(&n),
        "n must be a power of two in [{MIN_N}, {MAX_N}]"
    );
    let threads = (n / 2).max(WAVEFRONT_WIDTH);
    let mut s = Sched::new(
        &format!("bitonic-{n}"),
        threads,
        WordLayout::for_regs(32),
        memory,
    );
    s.comment("r0 = pair index t; r13 = 1, r14 = 0");
    s.op("tdx r0").op("ldi r13, #1").op("ldi r14, #0");

    // Pass schedule: k = 2,4,..,n; j = k/2 .. 1.
    let mut k = 2;
    while k <= n {
        s.comment(&format!("=== merge stage k={k} ==="));
        s.op(format!("ldi r18, #{k}"));
        let mut j = k / 2;
        while j >= 1 {
            s.op(format!("ldi r16, #{}", j - 1)).op(format!("ldi r17, #{j}"));
            s.fence();
            s.op("jsr pass");
            j /= 2;
        }
        k *= 2;
    }
    s.op("stop");

    // The shared compare-exchange pass: params r16 = j-1, r17 = j, r18 = k.
    s.label("pass");
    s.comment("expand pair index t to element index i (insert 0 at bit log2 j)");
    s.op("and r4, r0, r16")
        .op("sub.u32 r5, r0, r4")
        .op("shl.u32 r5, r5, r13")
        .op("add.u32 r6, r5, r4")
        .op("xor r7, r6, r17")
        .op("and r8, r6, r18");
    s.comment("compare-exchange: compute both orders, predicate the select");
    s.op("lod r9, (r6)+0")
        .op("lod r10, (r7)+0")
        .op("min.u32 r11, r9, r10")
        .op("max.u32 r12, r9, r10");
    s.op("if.eq r8, r14");
    s.comment("ascending: mem[i] <- min, mem[l] <- max");
    s.op("or r15, r11, r14").op("or r19, r12, r14");
    s.op("else");
    s.comment("descending: mem[i] <- max, mem[l] <- min");
    s.op("or r15, r12, r14").op("or r19, r11, r14");
    s.op("endif");
    s.op("sto r15, (r6)+0").op("sto r19, (r7)+0");
    s.op("rts");

    Kernel {
        name: format!("bitonic-{n}"),
        asm: s.into_source(),
        threads,
        dim_x: threads,
    }
}

/// Oracle: ascending sort.
pub fn oracle(data: &[u32]) -> Vec<u32> {
    let mut v = data.to_vec();
    v.sort_unstable();
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::EgpuConfig;

    fn data(n: usize) -> Vec<u32> {
        let mut lcg = 0x2545F4914F6CDD1Du64;
        (0..n)
            .map(|_| {
                lcg = lcg.wrapping_mul(6364136223846793005).wrapping_add(1);
                (lcg >> 33) as u32
            })
            .collect()
    }

    #[test]
    fn sorts_all_sizes() {
        for n in [32usize, 64, 128, 256] {
            let cfg = EgpuConfig::benchmark_predicated(MemoryMode::Dp);
            let d = data(n);
            let (stats, m) = bitonic(n)
                .run(&cfg, &[(0, d.clone())])
                .unwrap_or_else(|e| panic!("n={n}: {e}"));
            assert_eq!(m.shared().read_block(0, n), &oracle(&d)[..], "n={n}");
            assert_eq!(stats.hazards, 0, "n={n}: {:?}", stats.hazard_samples);
        }
    }

    #[test]
    fn sorts_adversarial_patterns() {
        let cfg = EgpuConfig::benchmark_predicated(MemoryMode::Dp);
        let n = 64;
        for d in [
            (0..n as u32).rev().collect::<Vec<_>>(), // descending
            vec![7; n],                               // all equal
            (0..n as u32).collect::<Vec<_>>(),        // pre-sorted
            (0..n as u32).map(|i| i ^ 0x80000000).collect(), // high-bit mix
        ] {
            let (_, m) = bitonic(n).run(&cfg, &[(0, d.clone())]).unwrap();
            assert_eq!(m.shared().read_block(0, n), &oracle(&d)[..]);
        }
    }

    #[test]
    fn cycle_counts_in_paper_band() {
        // Table 8 eGPU-DP: 1742 / 3728 / 8326 / 16578 for n = 32..256.
        let cfg = EgpuConfig::benchmark_predicated(MemoryMode::Dp);
        for (n, paper) in [(32usize, 1742u64), (64, 3728), (128, 8326), (256, 16578)] {
            let (stats, _) = bitonic(n).run(&cfg, &[(0, data(n))]).unwrap();
            let r = stats.cycles as f64 / paper as f64;
            assert!(
                (0.4..=2.0).contains(&r),
                "n={n}: {} vs paper {paper} ({r:.2}x)",
                stats.cycles
            );
        }
    }

    #[test]
    fn qp_fewer_cycles() {
        // Table 8: QP needs 0.72-0.86x the DP cycles (write bandwidth).
        let n = 128;
        let d = data(n);
        let dp_cfg = EgpuConfig::benchmark_predicated(MemoryMode::Dp);
        let qp_cfg = EgpuConfig::benchmark_predicated(MemoryMode::Qp);
        let (s_dp, _) = bitonic(n).run(&dp_cfg, &[(0, d.clone())]).unwrap();
        let (s_qp, m) = bitonic_for(n, MemoryMode::Qp).run(&qp_cfg, &[(0, d.clone())]).unwrap();
        assert_eq!(m.shared().read_block(0, n), &oracle(&d)[..]);
        let ratio = s_qp.cycles as f64 / s_dp.cycles as f64;
        assert!((0.6..=0.95).contains(&ratio), "QP/DP = {ratio:.2}");
    }

    #[test]
    fn requires_predicates() {
        let cfg = EgpuConfig::benchmark(MemoryMode::Dp, false); // no predicates
        let err = match bitonic(32).run(&cfg, &[(0, data(32))]) {
            Err(e) => e,
            Ok(_) => panic!("bitonic must fail to load without predicates"),
        };
        assert!(err.message.contains("predicates"), "{err}");
    }

    #[test]
    fn uses_subroutine_calls() {
        // §7: "many subroutine calls" — the profile must show branches.
        let cfg = EgpuConfig::benchmark_predicated(MemoryMode::Dp);
        let (stats, _) = bitonic(64).run(&cfg, &[(0, data(64))]).unwrap();
        let branches = stats.profile.count(crate::isa::Group::Control);
        assert!(branches > 40, "only {branches} control instructions");
    }
}
