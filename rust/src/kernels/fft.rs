//! Radix-2 DIT FFT, FP32 (Table 8, right block).
//!
//! Layout (32-bit words): re at 0, im at `n`, twiddle cos at `2n` (n/2
//! entries), twiddle sin at `2n + n/2`, bit-reverse staging at `3n`/`4n`.
//! Twiddles are preloaded by the host ([`twiddles`]) — the eGPU has no
//! trig instruction, and the paper loads data externally (§2).
//!
//! §7: "A similar pattern of instruction distribution is seen in the FFT
//! ... The number of FP instructions (which are doing the actual FFT
//! calculations) is relatively small, at about 10%. The largest proportion
//! of operations are once again the memory accesses, especially in the
//! write to shared memory."
//!
//! One thread per butterfly (n/2 threads). The log₂(n) stages share a
//! single JSR subroutine parameterized by registers (position mask, half
//! span, twiddle shift); the bit-reverse permutation uses the BVS
//! instruction through a staging copy.

use super::sched::Sched;
use super::Kernel;
use crate::isa::{WordLayout, WAVEFRONT_WIDTH};
use crate::sim::config::MemoryMode;

pub const MIN_N: usize = 32;
pub const MAX_N: usize = 512;

/// FFT of `n` complex points in place at re `[0,n)` / im `[n,2n)`.
pub fn fft(n: usize) -> Kernel {
    fft_for(n, MemoryMode::Dp)
}

/// Memory-mode-aware variant (NOP schedule follows the mode's port costs).
pub fn fft_for(n: usize, memory: MemoryMode) -> Kernel {
    assert!(
        n.is_power_of_two() && (MIN_N..=MAX_N).contains(&n),
        "n must be a power of two in [{MIN_N}, {MAX_N}]"
    );
    let threads = (n / 2).max(WAVEFRONT_WIDTH);
    let log2n = n.trailing_zeros();
    let im = n;
    let cos = 2 * n;
    let sin = 2 * n + n / 2;
    let sre = 3 * n;
    let sim = 4 * n;

    let mut s = Sched::new(&format!("fft-{n}"), threads, WordLayout::for_regs(32), memory);
    s.comment("r0 = butterfly index t; r13 = 1; r3 = 32 - log2n (BVS shift)");
    s.op("tdx r0")
        .op("ldi r13, #1")
        .op(format!("ldi r3, #{}", 32 - log2n));

    s.comment("--- bit-reverse permutation: stage through scratch ---");
    s.op("lod r1, (r0)+0")
        .op(format!("lod r2, (r0)+{}", n / 2))
        .op(format!("lod r4, (r0)+{im}"))
        .op(format!("lod r5, (r0)+{}", im + n / 2))
        .op(format!("sto r1, (r0)+{sre}"))
        .op(format!("sto r2, (r0)+{}", sre + n / 2))
        .op(format!("sto r4, (r0)+{sim}"))
        .op(format!("sto r5, (r0)+{}", sim + n / 2));
    s.comment("gather: x[t] = staged[rev(t)]; rev(t + n/2) = rev(t) + 1");
    s.op("bvs r6, r0")
        .op("shr.u32 r6, r6, r3")
        .op("add.u32 r7, r6, r13")
        .op(format!("lod r1, (r6)+{sre}"))
        .op(format!("lod r2, (r7)+{sre}"))
        .op(format!("lod r4, (r6)+{sim}"))
        .op(format!("lod r5, (r7)+{sim}"))
        .op("sto r1, (r0)+0")
        .op(format!("sto r2, (r0)+{}", n / 2))
        .op(format!("sto r4, (r0)+{im}"))
        .op(format!("sto r5, (r0)+{}", im + n / 2));

    s.comment("--- butterfly stages, shared subroutine ---");
    for stage in 0..log2n {
        let half = 1usize << stage;
        s.comment(&format!("stage {stage}: span {}", 2 * half));
        s.op(format!("ldi r16, #{}", half - 1))
            .op(format!("ldi r17, #{half}"))
            .op(format!("ldi r18, #{}", log2n - 1 - stage));
        s.fence();
        s.op("jsr stage");
    }
    s.op("stop");

    // Stage subroutine: params r16 = half-1, r17 = half, r18 = twshift.
    s.label("stage");
    s.comment("expand t to u-index (insert 0 at bit log2 half); v = u + half");
    s.op("and r4, r0, r16")
        .op("sub.u32 r5, r0, r4")
        .op("shl.u32 r5, r5, r13")
        .op("add.u32 r5, r5, r4")
        .op("add.u32 r6, r5, r17");
    s.comment("twiddle w = cos - i*sin at index p << twshift");
    s.op("shl.u32 r7, r4, r18")
        .op(format!("lod r8, (r7)+{cos}"))
        .op(format!("lod r9, (r7)+{sin}"))
        .op("fneg r9, r9");
    s.comment("u = x[iu], v = x[iv]");
    s.op("lod r10, (r5)+0")
        .op(format!("lod r11, (r5)+{im}"))
        .op("lod r14, (r6)+0")
        .op(format!("lod r15, (r6)+{im}"));
    s.comment("p = w*v (complex)");
    s.op("fmul r19, r14, r8")
        .op("fmul r20, r15, r9")
        .op("fsub r19, r19, r20")
        .op("fmul r20, r14, r9")
        .op("fmul r21, r15, r8")
        .op("fadd r20, r20, r21");
    s.comment("x[iu] = u + p; x[iv] = u - p");
    s.op("fadd r21, r10, r19")
        .op("sto r21, (r5)+0")
        .op("fsub r21, r10, r19")
        .op("sto r21, (r6)+0")
        .op("fadd r21, r11, r20")
        .op(format!("sto r21, (r5)+{im}"))
        .op("fsub r21, r11, r20")
        .op(format!("sto r21, (r6)+{im}"));
    s.op("rts");

    Kernel {
        name: format!("fft-{n}"),
        asm: s.into_source(),
        threads,
        dim_x: threads,
    }
}

/// Host-side twiddle tables: `(cos table, sin table)`, n/2 entries each,
/// angle 2πt/n.
pub fn twiddles(n: usize) -> (Vec<f32>, Vec<f32>) {
    let mut c = Vec::with_capacity(n / 2);
    let mut sn = Vec::with_capacity(n / 2);
    for t in 0..n / 2 {
        let w = 2.0 * std::f64::consts::PI * t as f64 / n as f64;
        c.push(w.cos() as f32);
        sn.push(w.sin() as f32);
    }
    (c, sn)
}

/// Shared-memory initialization blocks for `run()`: input + twiddles.
pub fn shared_init(re: &[f32], im: &[f32]) -> Vec<(usize, Vec<u32>)> {
    let n = re.len();
    assert_eq!(im.len(), n);
    let (c, s) = twiddles(n);
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    vec![
        (0, bits(re)),
        (n, bits(im)),
        (2 * n, bits(&c)),
        (2 * n + n / 2, bits(&s)),
    ]
}

/// Oracle: direct DFT, `X[k] = Σ_t x[t]·e^{-2πi·kt/n}` in f64.
pub fn oracle(re: &[f32], im: &[f32]) -> (Vec<f64>, Vec<f64>) {
    let n = re.len();
    let mut xr = vec![0f64; n];
    let mut xi = vec![0f64; n];
    for k in 0..n {
        for t in 0..n {
            let w = -2.0 * std::f64::consts::PI * (k * t % n) as f64 / n as f64;
            xr[k] += re[t] as f64 * w.cos() - im[t] as f64 * w.sin();
            xi[k] += re[t] as f64 * w.sin() + im[t] as f64 * w.cos();
        }
    }
    (xr, xi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::EgpuConfig;

    fn tones(n: usize) -> (Vec<f32>, Vec<f32>) {
        let re: Vec<f32> = (0..n)
            .map(|i| {
                let x = i as f64 / n as f64;
                ((2.0 * std::f64::consts::PI * 3.0 * x).cos()
                    + 0.5 * (2.0 * std::f64::consts::PI * 7.0 * x).sin()) as f32
            })
            .collect();
        (re, vec![0f32; n])
    }

    fn run_fft(n: usize, memory: MemoryMode) -> (crate::sim::RunStats, Vec<f32>, Vec<f32>) {
        let cfg = EgpuConfig::benchmark(memory, false);
        let (re, im) = tones(n);
        let (stats, m) = fft_for(n, memory)
            .run(&cfg, &shared_init(&re, &im))
            .unwrap_or_else(|e| panic!("n={n}: {e}"));
        let out_re: Vec<f32> = m.shared().read_block(0, n).iter().map(|&b| f32::from_bits(b)).collect();
        let out_im: Vec<f32> = m.shared().read_block(n, n).iter().map(|&b| f32::from_bits(b)).collect();
        (stats, out_re, out_im)
    }

    #[test]
    fn matches_dft_all_sizes() {
        for n in [32usize, 64, 128, 256] {
            let (stats, got_r, got_i) = run_fft(n, MemoryMode::Dp);
            assert_eq!(stats.hazards, 0, "n={n}: {:?}", stats.hazard_samples);
            let (re, im) = tones(n);
            let (want_r, want_i) = oracle(&re, &im);
            let tol = 1e-3 * n as f64;
            for k in 0..n {
                assert!(
                    (got_r[k] as f64 - want_r[k]).abs() < tol
                        && (got_i[k] as f64 - want_i[k]).abs() < tol,
                    "n={n} bin {k}: got ({},{}) want ({:.4},{:.4})",
                    got_r[k],
                    got_i[k],
                    want_r[k],
                    want_i[k]
                );
            }
        }
    }

    #[test]
    fn tone_peaks_where_expected() {
        let n = 64;
        let (_, got_r, got_i) = run_fft(n, MemoryMode::Dp);
        let mag: Vec<f64> = (0..n)
            .map(|k| ((got_r[k] as f64).powi(2) + (got_i[k] as f64).powi(2)).sqrt())
            .collect();
        // Tones at bins 3 and 7 (and mirrors n-3, n-7).
        for peak in [3usize, 7, n - 3, n - 7] {
            assert!(mag[peak] > 10.0, "bin {peak}: {}", mag[peak]);
        }
        assert!(mag[10] < 1.0, "leakage at bin 10: {}", mag[10]);
    }

    #[test]
    fn cycle_counts_in_paper_band() {
        // Table 8 eGPU-DP: 876 / 1695 / 3463 / 6813 for n = 32..256.
        for (n, paper) in [(32usize, 876u64), (64, 1695), (128, 3463), (256, 6813)] {
            let (stats, _, _) = run_fft(n, MemoryMode::Dp);
            let r = stats.cycles as f64 / paper as f64;
            assert!(
                (0.4..=2.0).contains(&r),
                "n={n}: {} vs paper {paper} ({r:.2}x)",
                stats.cycles
            );
        }
    }

    #[test]
    fn qp_saves_cycles() {
        // Table 8: FFT-QP ≈ 0.70-0.82x DP cycles.
        for n in [64usize, 256] {
            let (dp, ..) = run_fft(n, MemoryMode::Dp);
            let (qp, got_r, _) = run_fft(n, MemoryMode::Qp);
            assert!(got_r.iter().all(|x| x.is_finite()));
            let ratio = qp.cycles as f64 / dp.cycles as f64;
            assert!((0.55..=0.95).contains(&ratio), "n={n}: QP/DP = {ratio:.2}");
        }
    }

    #[test]
    fn fp_fraction_near_ten_percent() {
        // §7: "The number of FP instructions ... is relatively small, at
        // about 10%" (of executed cycles).
        let (stats, _, _) = run_fft(128, MemoryMode::Dp);
        let fp = stats.profile.cycle_fraction(crate::isa::Group::FpAlu);
        assert!((0.03..=0.30).contains(&fp), "FP fraction {fp:.2}");
    }
}
