//! Radix-2 DIT FFT, FP32 (Table 8, right block).
//!
//! Layout (32-bit words): re at 0, im at `n`, twiddle cos at `2n` (n/2
//! entries), twiddle sin at `2n + n/2`, bit-reverse staging at `3n`/`4n`.
//! Twiddles are preloaded by the host ([`twiddles`]) — the eGPU has no
//! trig instruction, and the paper loads data externally (§2).
//!
//! §7: "A similar pattern of instruction distribution is seen in the FFT
//! ... The number of FP instructions (which are doing the actual FFT
//! calculations) is relatively small, at about 10%. The largest proportion
//! of operations are once again the memory accesses, especially in the
//! write to shared memory."
//!
//! One thread per butterfly (n/2 threads). The log₂(n) stages share a
//! single JSR subroutine parameterized by registers (position mask, half
//! span, twiddle shift); the bit-reverse permutation uses the BVS
//! instruction through a staging copy. At shallow depths the subroutine is
//! where the delay slots concentrate — the list scheduler overlaps the
//! twiddle-address chain and its table loads with the butterfly-index
//! chain instead of padding each in turn.

use super::Kernel;
use crate::isa::{WordLayout, WAVEFRONT_WIDTH};
use crate::kc::{KernelBuilder, SchedMode, V};
use crate::sim::config::MemoryMode;

pub const MIN_N: usize = 32;
pub const MAX_N: usize = 512;

/// FFT of `n` complex points in place at re `[0,n)` / im `[n,2n)`.
pub fn fft(n: usize) -> Kernel {
    fft_for(n, MemoryMode::Dp)
}

/// Memory-mode-aware variant (the schedule follows the mode's port costs).
pub fn fft_for(n: usize, memory: MemoryMode) -> Kernel {
    fft_mode(n, memory, SchedMode::List)
}

/// Schedule-mode-aware build (List = default; Fenced = the
/// schedule-disabled correctness oracle; Linear = in-order padding).
pub fn fft_mode(n: usize, memory: MemoryMode, mode: SchedMode) -> Kernel {
    fft_cfg(n, memory, WordLayout::for_regs(32), mode)
}

/// Fully specialized build: target memory organization *and* register
/// layout (the kernel-specialization cache's entry point).
pub fn fft_cfg(n: usize, memory: MemoryMode, layout: WordLayout, mode: SchedMode) -> Kernel {
    assert!(
        n.is_power_of_two() && (MIN_N..=MAX_N).contains(&n),
        "n must be a power of two in [{MIN_N}, {MAX_N}]"
    );
    let threads = (n / 2).max(WAVEFRONT_WIDTH);
    let log2n = n.trailing_zeros();
    let im = n;
    let cos = 2 * n;
    let sin = 2 * n + n / 2;
    let sre = 3 * n;
    let sim = 4 * n;

    let name = format!("fft-{n}");
    let mut b = KernelBuilder::new(&name, threads, layout, memory);
    b.comment("t = butterfly index; one = 1; shv = 32 - log2n (BVS shift)");
    let t = b.tdx();
    let one = b.ldi(1);
    let shv = b.ldi((32 - log2n) as i64);

    b.comment("--- bit-reverse permutation: stage through scratch ---");
    let x1 = b.lod(t, 0);
    let x2 = b.lod(t, n / 2);
    let y1 = b.lod(t, im);
    let y2 = b.lod(t, im + n / 2);
    b.sto(x1, t, sre);
    b.sto(x2, t, sre + n / 2);
    b.sto(y1, t, sim);
    b.sto(y2, t, sim + n / 2);
    b.comment("gather: x[t] = staged[rev(t)]; rev(t + n/2) = rev(t) + 1");
    let rv = b.bvs(t);
    let r6 = b.shr_u(rv, shv);
    let r7 = b.add_u(r6, one);
    let g1 = b.lod(r6, sre);
    let g2 = b.lod(r7, sre);
    let g3 = b.lod(r6, sim);
    let g4 = b.lod(r7, sim);
    b.sto(g1, t, 0);
    b.sto(g2, t, n / 2);
    b.sto(g3, t, im);
    b.sto(g4, t, im + n / 2);

    b.comment("--- butterfly stages, shared subroutine ---");
    let mut p_mask: Option<V> = None;
    let mut p_half: Option<V> = None;
    let mut p_shift: Option<V> = None;
    for stage in 0..log2n {
        let half = 1usize << stage;
        b.comment(&format!("stage {stage}: span {}", 2 * half));
        b.ldi_reuse(&mut p_mask, (half - 1) as i64);
        b.ldi_reuse(&mut p_half, half as i64);
        b.ldi_reuse(&mut p_shift, (log2n - 1 - stage) as i64);
        b.jsr("stage");
    }
    b.stop();
    let (p_mask, p_half, p_shift) = (p_mask.unwrap(), p_half.unwrap(), p_shift.unwrap());

    // Stage subroutine: params p_mask = half-1, p_half = half, p_shift.
    b.label("stage");
    b.comment("expand t to u-index (insert 0 at bit log2 half); v = u + half");
    let p = b.and_i(t, p_mask);
    let h0 = b.sub_u(t, p);
    let h1 = b.shl_u(h0, one);
    let u = b.add_u(h1, p);
    let v = b.add_u(u, p_half);
    b.comment("twiddle w = cos - i*sin at index p << twshift");
    let tw = b.shl_u(p, p_shift);
    let wr = b.lod(tw, cos);
    let ws = b.lod(tw, sin);
    let wi = b.fneg(ws);
    b.comment("u = x[iu], v = x[iv]");
    let ur = b.lod(u, 0);
    let ui = b.lod(u, im);
    let vr = b.lod(v, 0);
    let vi = b.lod(v, im);
    b.comment("p = w*v (complex)");
    let pr1 = b.fmul(vr, wr);
    let pr2 = b.fmul(vi, wi);
    let pr = b.fsub(pr1, pr2);
    let pi1 = b.fmul(vr, wi);
    let pi2 = b.fmul(vi, wr);
    let pi = b.fadd(pi1, pi2);
    b.comment("x[iu] = u + p; x[iv] = u - p");
    let o1 = b.fadd(ur, pr);
    b.sto(o1, u, 0);
    let o2 = b.fsub(ur, pr);
    b.sto(o2, v, 0);
    let o3 = b.fadd(ui, pi);
    b.sto(o3, u, im);
    let o4 = b.fsub(ui, pi);
    b.sto(o4, v, im);
    b.rts();

    Kernel::from_compiled(name, b.finish(mode).unwrap(), threads, threads)
}

/// Host-side twiddle tables: `(cos table, sin table)`, n/2 entries each,
/// angle 2πt/n.
pub fn twiddles(n: usize) -> (Vec<f32>, Vec<f32>) {
    let mut c = Vec::with_capacity(n / 2);
    let mut sn = Vec::with_capacity(n / 2);
    for t in 0..n / 2 {
        let w = 2.0 * std::f64::consts::PI * t as f64 / n as f64;
        c.push(w.cos() as f32);
        sn.push(w.sin() as f32);
    }
    (c, sn)
}

/// Shared-memory initialization blocks for `run()`: input + twiddles.
pub fn shared_init(re: &[f32], im: &[f32]) -> Vec<(usize, Vec<u32>)> {
    let n = re.len();
    assert_eq!(im.len(), n);
    let (c, s) = twiddles(n);
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    vec![
        (0, bits(re)),
        (n, bits(im)),
        (2 * n, bits(&c)),
        (2 * n + n / 2, bits(&s)),
    ]
}

/// Oracle: direct DFT, `X[k] = Σ_t x[t]·e^{-2πi·kt/n}` in f64.
pub fn oracle(re: &[f32], im: &[f32]) -> (Vec<f64>, Vec<f64>) {
    let n = re.len();
    let mut xr = vec![0f64; n];
    let mut xi = vec![0f64; n];
    for k in 0..n {
        for t in 0..n {
            let w = -2.0 * std::f64::consts::PI * (k * t % n) as f64 / n as f64;
            xr[k] += re[t] as f64 * w.cos() - im[t] as f64 * w.sin();
            xi[k] += re[t] as f64 * w.sin() + im[t] as f64 * w.cos();
        }
    }
    (xr, xi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::EgpuConfig;

    fn tones(n: usize) -> (Vec<f32>, Vec<f32>) {
        let re: Vec<f32> = (0..n)
            .map(|i| {
                let x = i as f64 / n as f64;
                ((2.0 * std::f64::consts::PI * 3.0 * x).cos()
                    + 0.5 * (2.0 * std::f64::consts::PI * 7.0 * x).sin()) as f32
            })
            .collect();
        (re, vec![0f32; n])
    }

    fn run_fft(n: usize, memory: MemoryMode) -> (crate::sim::RunStats, Vec<f32>, Vec<f32>) {
        let cfg = EgpuConfig::benchmark(memory, false);
        let (re, im) = tones(n);
        let (stats, m) = fft_for(n, memory)
            .run(&cfg, &shared_init(&re, &im))
            .unwrap_or_else(|e| panic!("n={n}: {e}"));
        let out_re: Vec<f32> = m.shared().read_block(0, n).iter().map(|&b| f32::from_bits(b)).collect();
        let out_im: Vec<f32> = m.shared().read_block(n, n).iter().map(|&b| f32::from_bits(b)).collect();
        (stats, out_re, out_im)
    }

    #[test]
    fn matches_dft_all_sizes() {
        for n in [32usize, 64, 128, 256] {
            let (stats, got_r, got_i) = run_fft(n, MemoryMode::Dp);
            assert_eq!(stats.hazards, 0, "n={n}: {:?}", stats.hazard_samples);
            let (re, im) = tones(n);
            let (want_r, want_i) = oracle(&re, &im);
            let tol = 1e-3 * n as f64;
            for k in 0..n {
                assert!(
                    (got_r[k] as f64 - want_r[k]).abs() < tol
                        && (got_i[k] as f64 - want_i[k]).abs() < tol,
                    "n={n} bin {k}: got ({},{}) want ({:.4},{:.4})",
                    got_r[k],
                    got_i[k],
                    want_r[k],
                    want_i[k]
                );
            }
        }
    }

    #[test]
    fn tone_peaks_where_expected() {
        let n = 64;
        let (_, got_r, got_i) = run_fft(n, MemoryMode::Dp);
        let mag: Vec<f64> = (0..n)
            .map(|k| ((got_r[k] as f64).powi(2) + (got_i[k] as f64).powi(2)).sqrt())
            .collect();
        // Tones at bins 3 and 7 (and mirrors n-3, n-7).
        for peak in [3usize, 7, n - 3, n - 7] {
            assert!(mag[peak] > 10.0, "bin {peak}: {}", mag[peak]);
        }
        assert!(mag[10] < 1.0, "leakage at bin 10: {}", mag[10]);
    }

    #[test]
    fn cycle_counts_at_or_below_paper() {
        // Table 8 eGPU-DP: 876 / 1695 / 3463 / 6813 for n = 32..256.
        // Upper bound only — the list scheduler may beat the paper.
        for (n, paper) in [(32usize, 876u64), (64, 1695), (128, 3463), (256, 6813)] {
            let (stats, _, _) = run_fft(n, MemoryMode::Dp);
            let r = stats.cycles as f64 / paper as f64;
            assert!(
                r <= 2.0,
                "n={n}: {} vs paper {paper} ({r:.2}x)",
                stats.cycles
            );
        }
    }

    #[test]
    fn qp_saves_cycles() {
        // Table 8: FFT-QP ≈ 0.70-0.82x DP cycles.
        for n in [64usize, 256] {
            let (dp, ..) = run_fft(n, MemoryMode::Dp);
            let (qp, got_r, _) = run_fft(n, MemoryMode::Qp);
            assert!(got_r.iter().all(|x| x.is_finite()));
            let ratio = qp.cycles as f64 / dp.cycles as f64;
            assert!((0.45..=0.98).contains(&ratio), "n={n}: QP/DP = {ratio:.2}");
        }
    }

    #[test]
    fn fp_fraction_near_ten_percent() {
        // §7: "The number of FP instructions ... is relatively small, at
        // about 10%" (of executed cycles).
        let (stats, _, _) = run_fft(128, MemoryMode::Dp);
        let fp = stats.profile.cycle_fraction(crate::isa::Group::FpAlu);
        assert!((0.03..=0.30).contains(&fp), "FP fraction {fp:.2}");
    }
}
