//! Matrix-matrix multiply (Table 7, right block): `C = A·B`, FP32.
//!
//! Layout: A at 0, B at `n²`, C at `2n²`, reduction scratch at `3n²`.
//!
//! §7: "Although the algorithm itself is very simple, consisting only of a
//! three level loop, the standard GPU implementation requires a vector
//! reduction." Each output element C[i][j] is an n-term dot product
//! computed across the thread space and folded through shared memory —
//! exactly the reduction kernel's narrowing tree, run n² times inside a
//! two-level sequencer loop (INIT/LOOP, no predicates).
//!
//! Two k-terms are accumulated per thread in registers before the tree
//! (the paper holds matrix data "in the SP registers" to cut memory
//! traffic; two-way register batching is the expressible equivalent for
//! this thread shape), so the machine runs n/2 threads for the tree
//! variant and n threads for the DOT variant, whose extension core
//! replaces the whole tree with one instruction (§7: "If we are using the
//! dot product operator ... most of the time is spent waiting (NOPs) for
//! the dot product to write back").

use super::sched::Sched;
use super::{depth_for, Kernel};
use crate::isa::{WordLayout, WAVEFRONT_WIDTH};
use crate::sim::config::{EgpuConfig, MemoryMode};

/// Valid problem sizes: 16-bit immediates must encode `3n² + n/2`.
pub const MAX_N: usize = 128;

fn check_n(n: usize) {
    assert!(
        n.is_power_of_two() && (32..=MAX_N).contains(&n),
        "n must be a power of two in [32, {MAX_N}]"
    );
}

/// Benchmark configuration sized for an `n × n` MMM. The paper's §7
/// instance (128 KB shared) holds A, B and C for n ≤ 64; the 128×128 case
/// needs 3n² = 192 KB, which the paper handles by register reloading — we
/// size the shared memory up instead and note the substitution in
/// DESIGN.md §Substitutions.
pub fn config(n: usize, memory: MemoryMode, dot_core: bool) -> EgpuConfig {
    check_n(n);
    let mut c = EgpuConfig::benchmark(memory, dot_core);
    let words_needed = 3 * n * n + n;
    if c.shared_words() < words_needed {
        c.shared_kb = (words_needed * 4).div_ceil(1024).next_power_of_two();
        c.name += "-XL";
    }
    c
}

/// Tree-reduction MMM: `n/2` threads, each accumulating two k-terms in
/// registers, then a shared-memory narrowing tree per output element.
pub fn mmm(n: usize) -> Kernel {
    mmm_for(n, MemoryMode::Dp)
}

/// Memory-mode-aware tree variant (schedule follows the mode's port costs;
/// the DP schedule is valid on QP, just conservatively padded).
pub fn mmm_for(n: usize, memory: MemoryMode) -> Kernel {
    check_n(n);
    let threads = (n / 2).max(WAVEFRONT_WIDTH);
    let waves = threads / WAVEFRONT_WIDTH;
    let n2 = n * n;
    let scr = 3 * n2;
    let log2n = n.trailing_zeros();

    let mut s = Sched::new(&format!("mmm-{n}"), threads, WordLayout::for_regs(32), memory);
    s.comment("r0=t (k-lane), r5=A addr i*n+t, r7=B addr t*n+j, r8=C index i*n+j");
    s.op("tdx r0")
        .op(format!("ldi r12, #{n}"))
        .op("ldi r13, #1")
        .op(format!("ldi r3, #{log2n}"))
        .op("shl.u32 r7, r0, r3")
        .op("ldi r8, #0")
        .op("add.u32 r5, r0, r8");
    s.op(format!("init #{n}"));
    s.label("iloop");
    s.comment("A[i][t] and A[i][t+n/2] stay in registers for the whole row");
    s.op("lod r1, (r5)+0").op(format!("lod r9, (r5)+{}", n / 2));
    s.op(format!("init #{n}"));
    s.fence();
    s.label("jloop");
    s.comment("two k-terms per thread, accumulated in-register");
    s.op(format!("lod r2, (r7)+{n2}"))
        .op(format!("lod r10, (r7)+{}", n2 + n2 / 2))
        .op("fmul r4, r1, r2")
        .op("fmul r11, r9, r10")
        .op("fadd r4, r4, r11")
        .op(format!("sto r4, (r0)+{scr}"));
    // Narrowing tree: fold s partials to 16 through shared scratch.
    let mut fold = n / 4;
    while fold >= WAVEFRONT_WIDTH {
        let d = depth_for(waves, fold / WAVEFRONT_WIDTH)
            .unwrap_or_else(|| panic!("fold {fold} not expressible from {waves} waves"));
        let sel = format!("[w16,{}]", d.name());
        s.comment(&format!("fold to {fold} partials"));
        s.op(format!("{sel} lod r4, (r0)+{scr}"))
            .op(format!("{sel} lod r11, (r0)+{}", scr + fold))
            .op(format!("{sel} fadd r4, r4, r11"))
            .op(format!("{sel} sto r4, (r0)+{scr}"));
        fold /= 2;
    }
    s.comment("16 -> 4 -> 1 tail; scalar lands in thread 0");
    s.op(format!("[w4,d0] lod r4, (r0)+{scr}"))
        .op(format!("[w4,d0] lod r11, (r0)+{}", scr + 4))
        .op(format!("[w4,d0] lod r15, (r0)+{}", scr + 8))
        .op(format!("[w4,d0] lod r16, (r0)+{}", scr + 12))
        .op("[w4,d0] fadd r4, r4, r11")
        .op("[w4,d0] fadd r15, r15, r16")
        .op("[w4,d0] fadd r4, r4, r15")
        .op(format!("[w4,d0] sto r4, (r0)+{scr}"))
        .op(format!("[w1,d0] lod r4, (r0)+{scr}"))
        .op(format!("[w1,d0] lod r11, (r0)+{}", scr + 1))
        .op(format!("[w1,d0] lod r15, (r0)+{}", scr + 2))
        .op(format!("[w1,d0] lod r16, (r0)+{}", scr + 3))
        .op("[w1,d0] fadd r4, r4, r11")
        .op("[w1,d0] fadd r15, r15, r16")
        .op("[w1,d0] fadd r4, r4, r15")
        .op(format!("[w1,d0] sto r4, (r8)+{}", 2 * n2));
    s.comment("j++: B column and C index advance by one");
    s.op("add.u32 r7, r7, r13").op("add.u32 r8, r8, r13");
    s.fence();
    s.op("loop jloop");
    s.comment("next row: A advances n, B address rewinds to t*n");
    s.op("add.u32 r5, r5, r12").op("sub.u32 r7, r7, r12");
    s.fence();
    s.op("loop iloop");
    Kernel {
        name: format!("mmm-{n}"),
        asm: s.finish(),
        threads,
        dim_x: threads,
    }
}

/// DOT-core MMM: `n` threads; the extension core computes each C[i][j] in
/// one instruction. The j-loop is software-pipelined two elements deep so
/// the next B column streams in during the dot-product writeback window.
pub fn mmm_dot(n: usize) -> Kernel {
    check_n(n);
    let threads = n;
    let n2 = n * n;
    let log2n = n.trailing_zeros();

    let mut s = Sched::new(
        &format!("mmm-dot-{n}"),
        threads,
        WordLayout::for_regs(32),
        MemoryMode::Dp,
    );
    s.comment("r0=t (k-lane), r5=A addr, r7=B addr, r8=C index + 1");
    s.op("tdx r0")
        .op(format!("ldi r12, #{n}"))
        .op("ldi r13, #1")
        .op(format!("ldi r3, #{log2n}"))
        .op("shl.u32 r7, r0, r3")
        .op("ldi r8, #0")
        .op("add.u32 r5, r0, r8");
    s.op(format!("init #{n}"));
    s.fence();
    s.label("iloop");
    s.comment("row of A in registers; prologue-load B column 0");
    s.op("lod r1, (r5)+0").op(format!("lod r2, (r7)+{n2}"));
    s.op(format!("init #{}", n / 2));
    s.fence();
    s.label("jloop");
    s.comment("dot j; prefetch column j+1 inside the writeback window");
    s.op("dot r4, r1, r2")
        .op("add.u32 r7, r7, r13")
        .op(format!("lod r10, (r7)+{n2}"))
        .op("add.u32 r8, r8, r13")
        .op(format!("[w1,d0] sto r4, (r8)+{}", 2 * n2 - 1));
    s.comment("dot j+1; prefetch column j+2");
    s.op("dot r4, r1, r10")
        .op("add.u32 r7, r7, r13")
        .op(format!("lod r2, (r7)+{n2}"))
        .op("add.u32 r8, r8, r13")
        .op(format!("[w1,d0] sto r4, (r8)+{}", 2 * n2 - 1));
    s.fence();
    s.op("loop jloop");
    s.op("add.u32 r5, r5, r12").op("sub.u32 r7, r7, r12");
    s.fence();
    s.op("loop iloop");
    Kernel {
        name: format!("mmm-dot-{n}"),
        asm: s.finish(),
        threads,
        dim_x: threads,
    }
}

/// Oracle: FP32 matmul in the kernel's accumulation order is not bit-exact
/// to a naive sum; tests use a tolerance.
pub fn oracle(a: &[f32], b: &[f32], n: usize) -> Vec<f32> {
    let mut c = vec![0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            c[i * n + j] = (0..n).map(|k| a[i * n + k] * b[k * n + j]).sum();
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::f32_bits;

    fn data(n: usize, seed: u32) -> Vec<f32> {
        (0..n * n)
            .map(|i| ((i as u32).wrapping_mul(2654435761).wrapping_add(seed) >> 20) as f32 / 512.0 - 4.0)
            .collect()
    }

    fn check(kernel: Kernel, cfg: &EgpuConfig, n: usize) -> u64 {
        let a = data(n, 1);
        let b = data(n, 2);
        let (stats, m) = kernel
            .run(cfg, &[(0, f32_bits(&a)), (n * n, f32_bits(&b))])
            .unwrap_or_else(|e| panic!("n={n}: {e}"));
        assert_eq!(stats.hazards, 0, "n={n}: {:?}", stats.hazard_samples);
        let want = oracle(&a, &b, n);
        for (idx, w) in want.iter().enumerate() {
            let got = f32::from_bits(m.shared().read((2 * n * n + idx) as u32).unwrap());
            assert!(
                (got - w).abs() < w.abs() * 1e-4 + 1e-2,
                "n={n} C[{idx}]: got {got}, want {w}"
            );
        }
        stats.cycles
    }

    #[test]
    fn tree_mmm_correct() {
        for n in [32usize, 64] {
            check(mmm(n), &config(n, MemoryMode::Dp, false), n);
        }
    }

    #[test]
    fn tree_mmm_correct_128() {
        check(mmm(128), &config(128, MemoryMode::Dp, false), 128);
    }

    #[test]
    fn dot_mmm_correct_and_faster() {
        for n in [32usize, 64] {
            let dot = check(mmm_dot(n), &config(n, MemoryMode::Dp, true), n);
            let tree = check(mmm(n), &config(n, MemoryMode::Dp, false), n);
            // Table 7: eGPU-Dot is ~5x faster than eGPU-DP on MMM.
            assert!(dot * 2 < tree, "n={n}: dot {dot} vs tree {tree}");
        }
    }

    #[test]
    fn cycle_counts_in_paper_band() {
        // Table 7 eGPU-DP: 111546 / 451066 / 2342356 for n = 32/64/128;
        // eGPU-Dot: 19800 / 84425 / 886452.
        for (n, paper) in [(32usize, 111_546u64), (64, 451_066)] {
            let c = check(mmm(n), &config(n, MemoryMode::Dp, false), n);
            let r = c as f64 / paper as f64;
            assert!((0.4..=2.0).contains(&r), "tree n={n}: {c} vs {paper} ({r:.2}x)");
        }
        for (n, paper) in [(32usize, 19_800u64), (64, 84_425)] {
            let c = check(mmm_dot(n), &config(n, MemoryMode::Dp, true), n);
            let r = c as f64 / paper as f64;
            assert!((0.4..=2.0).contains(&r), "dot n={n}: {c} vs {paper} ({r:.2}x)");
        }
    }

    #[test]
    fn qp_variant_correct() {
        let n = 32;
        check(mmm_for(n, MemoryMode::Qp), &config(n, MemoryMode::Qp, false), n);
    }

    #[test]
    fn config_sizes_shared_memory() {
        assert_eq!(config(64, MemoryMode::Dp, false).shared_kb, 128);
        let big = config(128, MemoryMode::Dp, false);
        assert!(big.shared_words() >= 3 * 128 * 128 + 128, "{}", big.shared_kb);
        assert!(big.name.ends_with("-XL"));
    }
}
