//! Matrix-matrix multiply (Table 7, right block): `C = A·B`, FP32.
//!
//! Layout: A at 0, B at `n²`, C at `2n²`, reduction scratch at `3n²`.
//!
//! §7: "Although the algorithm itself is very simple, consisting only of a
//! three level loop, the standard GPU implementation requires a vector
//! reduction." Each output element C[i][j] is an n-term dot product
//! computed across the thread space and folded through shared memory —
//! exactly the reduction kernel's narrowing tree, run n² times inside a
//! two-level sequencer loop (INIT/LOOP, no predicates).
//!
//! Two k-terms are accumulated per thread in registers before the tree
//! (the paper holds matrix data "in the SP registers" to cut memory
//! traffic; two-way register batching is the expressible equivalent for
//! this thread shape), so the machine runs n/2 threads for the tree
//! variant and n threads for the DOT variant, whose extension core
//! replaces the whole tree with one instruction (§7: "If we are using the
//! dot product operator ... most of the time is spent waiting (NOPs) for
//! the dot product to write back"). The list scheduler overlaps the two
//! k-term load/multiply chains and moves the j-advance address arithmetic
//! into the tree's delay slots.

use super::{depth_for, Kernel};
use crate::isa::{DepthSel, ThreadCtrl, WidthSel, WordLayout, WAVEFRONT_WIDTH};
use crate::kc::{KernelBuilder, SchedMode};
use crate::sim::config::{EgpuConfig, MemoryMode};

/// Valid problem sizes: 16-bit immediates must encode `3n² + n/2`.
pub const MAX_N: usize = 128;

fn check_n(n: usize) {
    assert!(
        n.is_power_of_two() && (32..=MAX_N).contains(&n),
        "n must be a power of two in [32, {MAX_N}]"
    );
}

/// Benchmark configuration sized for an `n × n` MMM. The paper's §7
/// instance (128 KB shared) holds A, B and C for n ≤ 64; the 128×128 case
/// needs 3n² = 192 KB, which the paper handles by register reloading — we
/// size the shared memory up instead and note the substitution in
/// DESIGN.md §Substitutions.
pub fn config(n: usize, memory: MemoryMode, dot_core: bool) -> EgpuConfig {
    check_n(n);
    let mut c = EgpuConfig::benchmark(memory, dot_core);
    let words_needed = 3 * n * n + n;
    if c.shared_words() < words_needed {
        c.shared_kb = (words_needed * 4).div_ceil(1024).next_power_of_two();
        c.name += "-XL";
    }
    c
}

/// Tree-reduction MMM: `n/2` threads, each accumulating two k-terms in
/// registers, then a shared-memory narrowing tree per output element.
pub fn mmm(n: usize) -> Kernel {
    mmm_for(n, MemoryMode::Dp)
}

/// Memory-mode-aware tree variant (schedule follows the mode's port costs;
/// the DP schedule is valid on QP, just conservatively padded).
pub fn mmm_for(n: usize, memory: MemoryMode) -> Kernel {
    mmm_mode(n, memory, SchedMode::List)
}

/// Schedule-mode-aware build (List = default; Fenced = the
/// schedule-disabled correctness oracle; Linear = in-order padding).
pub fn mmm_mode(n: usize, memory: MemoryMode, mode: SchedMode) -> Kernel {
    mmm_cfg(n, memory, WordLayout::for_regs(32), mode)
}

/// Fully specialized build: target memory organization *and* register
/// layout (the kernel-specialization cache's entry point).
pub fn mmm_cfg(n: usize, memory: MemoryMode, layout: WordLayout, mode: SchedMode) -> Kernel {
    check_n(n);
    let threads = (n / 2).max(WAVEFRONT_WIDTH);
    let waves = threads / WAVEFRONT_WIDTH;
    let n2 = n * n;
    let scr = 3 * n2;
    let log2n = n.trailing_zeros();

    let name = format!("mmm-{n}");
    let mut b = KernelBuilder::new(&name, threads, layout, memory);
    b.comment("t = k-lane, arow = A addr i*n+t, bcol = B addr t*n+j, ci = C index i*n+j");
    let t = b.tdx();
    let cn = b.ldi(n as i64);
    let one = b.ldi(1);
    let csh = b.ldi(log2n as i64);
    let bcol = b.shl_u(t, csh);
    let ci = b.ldi(0);
    let arow = b.add_u(t, ci);
    b.init(n);
    b.label("iloop");
    b.comment("A[i][t] and A[i][t+n/2] stay in registers for the whole row");
    let a1 = b.lod(arow, 0);
    let a2 = b.lod(arow, n / 2);
    b.init(n);
    b.label("jloop");
    b.comment("two k-terms per thread, accumulated in-register");
    let b1 = b.lod(bcol, n2);
    let b2 = b.lod(bcol, n2 + n2 / 2);
    let m1 = b.fmul(a1, b1);
    let m2 = b.fmul(a2, b2);
    let acc = b.fadd(m1, m2);
    b.sto(acc, t, scr);
    // Narrowing tree: fold partials to 16 through shared scratch.
    let mut fold = n / 4;
    while fold >= WAVEFRONT_WIDTH {
        let d = depth_for(waves, fold / WAVEFRONT_WIDTH)
            .unwrap_or_else(|| panic!("fold {fold} not expressible from {waves} waves"));
        b.space(ThreadCtrl::new(WidthSel::All16, d));
        b.comment(&format!("fold to {fold} partials"));
        let x = b.lod(t, scr);
        let y = b.lod(t, scr + fold);
        let z = b.fadd(x, y);
        b.sto(z, t, scr);
        fold /= 2;
    }
    b.comment("16 -> 4 -> 1 tail; scalar lands in thread 0");
    b.space(ThreadCtrl::new(WidthSel::Quarter4, DepthSel::Wave0));
    let x1 = b.lod(t, scr);
    let x2 = b.lod(t, scr + 4);
    let x3 = b.lod(t, scr + 8);
    let x4 = b.lod(t, scr + 12);
    let s1 = b.fadd(x1, x2);
    let s2 = b.fadd(x3, x4);
    let s3 = b.fadd(s1, s2);
    b.sto(s3, t, scr);
    b.space(ThreadCtrl::MCU);
    let y1 = b.lod(t, scr);
    let y2 = b.lod(t, scr + 1);
    let y3 = b.lod(t, scr + 2);
    let y4 = b.lod(t, scr + 3);
    let u1 = b.fadd(y1, y2);
    let u2 = b.fadd(y3, y4);
    let u3 = b.fadd(u1, u2);
    b.sto(u3, ci, 2 * n2);
    b.full();
    b.comment("j++: B column and C index advance by one");
    b.add_u_into(bcol, bcol, one);
    b.add_u_into(ci, ci, one);
    b.loop_("jloop");
    b.comment("next row: A advances n, B address rewinds to t*n");
    b.add_u_into(arow, arow, cn);
    b.sub_u_into(bcol, bcol, cn);
    b.loop_("iloop");
    b.stop();
    Kernel::from_compiled(name, b.finish(mode).unwrap(), threads, threads)
}

/// DOT-core MMM: `n` threads; the extension core computes each C[i][j] in
/// one instruction. The j-loop is software-pipelined two elements deep so
/// the next B column streams in during the dot-product writeback window.
pub fn mmm_dot(n: usize) -> Kernel {
    mmm_dot_mode(n, SchedMode::List)
}

pub fn mmm_dot_mode(n: usize, mode: SchedMode) -> Kernel {
    mmm_dot_cfg(n, MemoryMode::Dp, WordLayout::for_regs(32), mode)
}

/// Fully specialized DOT-core build (memory mode drives the scheduler's
/// port-cost model exactly like the tree variant).
pub fn mmm_dot_cfg(n: usize, memory: MemoryMode, layout: WordLayout, mode: SchedMode) -> Kernel {
    check_n(n);
    let threads = n;
    let n2 = n * n;
    let log2n = n.trailing_zeros();

    let name = format!("mmm-dot-{n}");
    let mut b = KernelBuilder::new(&name, threads, layout, memory);
    b.comment("t = k-lane, arow = A addr, bcol = B addr, ci = C index + 1");
    let t = b.tdx();
    let cn = b.ldi(n as i64);
    let one = b.ldi(1);
    let csh = b.ldi(log2n as i64);
    let bcol = b.shl_u(t, csh);
    let ci = b.ldi(0);
    let arow = b.add_u(t, ci);
    b.init(n);
    b.label("iloop");
    b.comment("row of A in registers; prologue-load B column 0");
    let a = b.lod(arow, 0);
    let b0 = b.lod(bcol, n2);
    b.init(n / 2);
    b.label("jloop");
    b.comment("dot j; prefetch column j+1 inside the writeback window");
    let d1 = b.dot(a, b0);
    b.add_u_into(bcol, bcol, one);
    let b1 = b.lod(bcol, n2);
    b.add_u_into(ci, ci, one);
    b.space(ThreadCtrl::MCU);
    b.sto(d1, ci, 2 * n2 - 1);
    b.full();
    b.comment("dot j+1; prefetch column j+2");
    let d2 = b.dot(a, b1);
    b.add_u_into(bcol, bcol, one);
    b.lod_into(b0, bcol, n2);
    b.add_u_into(ci, ci, one);
    b.space(ThreadCtrl::MCU);
    b.sto(d2, ci, 2 * n2 - 1);
    b.full();
    b.loop_("jloop");
    b.add_u_into(arow, arow, cn);
    b.sub_u_into(bcol, bcol, cn);
    b.loop_("iloop");
    b.stop();
    Kernel::from_compiled(name, b.finish(mode).unwrap(), threads, threads)
}

/// Oracle: FP32 matmul in the kernel's accumulation order is not bit-exact
/// to a naive sum; tests use a tolerance.
pub fn oracle(a: &[f32], b: &[f32], n: usize) -> Vec<f32> {
    let mut c = vec![0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            c[i * n + j] = (0..n).map(|k| a[i * n + k] * b[k * n + j]).sum();
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::f32_bits;

    fn data(n: usize, seed: u32) -> Vec<f32> {
        (0..n * n)
            .map(|i| ((i as u32).wrapping_mul(2654435761).wrapping_add(seed) >> 20) as f32 / 512.0 - 4.0)
            .collect()
    }

    fn check(kernel: Kernel, cfg: &EgpuConfig, n: usize) -> u64 {
        let a = data(n, 1);
        let b = data(n, 2);
        let (stats, m) = kernel
            .run(cfg, &[(0, f32_bits(&a)), (n * n, f32_bits(&b))])
            .unwrap_or_else(|e| panic!("n={n}: {e}"));
        assert_eq!(stats.hazards, 0, "n={n}: {:?}", stats.hazard_samples);
        let want = oracle(&a, &b, n);
        for (idx, w) in want.iter().enumerate() {
            let got = f32::from_bits(m.shared().read((2 * n * n + idx) as u32).unwrap());
            assert!(
                (got - w).abs() < w.abs() * 1e-4 + 1e-2,
                "n={n} C[{idx}]: got {got}, want {w}"
            );
        }
        stats.cycles
    }

    #[test]
    fn tree_mmm_correct() {
        for n in [32usize, 64] {
            check(mmm(n), &config(n, MemoryMode::Dp, false), n);
        }
    }

    #[test]
    fn tree_mmm_correct_128() {
        check(mmm(128), &config(128, MemoryMode::Dp, false), 128);
    }

    #[test]
    fn dot_mmm_correct_and_faster() {
        for n in [32usize, 64] {
            let dot = check(mmm_dot(n), &config(n, MemoryMode::Dp, true), n);
            let tree = check(mmm(n), &config(n, MemoryMode::Dp, false), n);
            // Table 7: eGPU-Dot is ~5x faster than eGPU-DP on MMM.
            assert!(dot * 2 < tree, "n={n}: dot {dot} vs tree {tree}");
        }
    }

    #[test]
    fn cycle_counts_at_or_below_paper() {
        // Table 7 eGPU-DP: 111546 / 451066 for n = 32/64; eGPU-Dot:
        // 19800 / 84425. Upper bound only — the list scheduler may beat
        // the paper's hand schedules.
        for (n, paper) in [(32usize, 111_546u64), (64, 451_066)] {
            let c = check(mmm(n), &config(n, MemoryMode::Dp, false), n);
            let r = c as f64 / paper as f64;
            assert!(r <= 2.0, "tree n={n}: {c} vs {paper} ({r:.2}x)");
        }
        for (n, paper) in [(32usize, 19_800u64), (64, 84_425)] {
            let c = check(mmm_dot(n), &config(n, MemoryMode::Dp, true), n);
            let r = c as f64 / paper as f64;
            assert!(r <= 2.0, "dot n={n}: {c} vs {paper} ({r:.2}x)");
        }
    }

    #[test]
    fn qp_variant_correct() {
        let n = 32;
        check(mmm_for(n, MemoryMode::Qp), &config(n, MemoryMode::Qp, false), n);
    }

    #[test]
    fn config_sizes_shared_memory() {
        assert_eq!(config(64, MemoryMode::Dp, false).shared_kb, 128);
        let big = config(128, MemoryMode::Dp, false);
        assert!(big.shared_words() >= 3 * 128 * 128 + 128, "{}", big.shared_kb);
        assert!(big.name.ends_with("-XL"));
    }
}
