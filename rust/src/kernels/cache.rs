//! The kernel-specialization cache.
//!
//! Compiling-and-scheduling a kernel is pure: the output depends only
//! on the `(generator, dim)` pair — a [`KernelSpec`] — and the two
//! configuration axes the compiler consumes (memory organization and
//! register layout), which [`EgpuConfig::fingerprint`] condenses to a
//! key. So a fleet serving repeated launches should compile each
//! specialization exactly once, however many streams, batches or cores
//! replay it. This cache is that memoization point, shared (via `Arc`)
//! by `Gpu::launch_spec`, `GpuArray`/`Stream` submission and the fleet
//! dispatcher.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::{Kernel, KernelSpec};
use crate::sim::config::EgpuConfig;
use crate::sim::SuperplanCache;

/// Counters proving the compile-once property (asserted by
/// `rust/tests/fleet_heterogeneous.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    /// Specializations compiled (unique `(spec, fingerprint)` pairs).
    pub compiles: u64,
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Entries currently resident.
    pub entries: usize,
}

/// Memoizes compiled kernels per `(spec, config fingerprint)`, and
/// carries the fleet-shared [`SuperplanCache`] so every machine attached
/// to the same kernel cache also shares one superplan compile per
/// (program, config fingerprint, thread count) triple.
#[derive(Debug, Default)]
pub struct KernelCache {
    entries: Mutex<HashMap<(KernelSpec, u64), Arc<Kernel>>>,
    compiles: AtomicU64,
    hits: AtomicU64,
    superplans: Arc<SuperplanCache>,
}

impl KernelCache {
    pub fn new() -> KernelCache {
        KernelCache::default()
    }

    /// A fresh cache behind an `Arc`, ready to share across devices.
    pub fn shared() -> Arc<KernelCache> {
        Arc::new(KernelCache::new())
    }

    /// The kernel for `spec` specialized to `cfg`, compiling at most
    /// once per `(spec, cfg.fingerprint())`. The compile happens under
    /// the lock — dispatchers are single-threaded, and holding it keeps
    /// a racing second caller from compiling the same entry twice.
    pub fn get(&self, spec: &KernelSpec, cfg: &EgpuConfig) -> Result<Arc<Kernel>, String> {
        let key = (*spec, cfg.fingerprint());
        let mut entries = self.entries.lock().unwrap();
        if let Some(k) = entries.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(k));
        }
        let kernel = Arc::new(spec.build(cfg)?);
        self.compiles.fetch_add(1, Ordering::Relaxed);
        entries.insert(key, Arc::clone(&kernel));
        Ok(kernel)
    }

    /// The fleet-shared superplan cache riding along with this kernel
    /// cache; attach it to every machine the owning device manages.
    pub fn superplans(&self) -> &Arc<SuperplanCache> {
        &self.superplans
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            compiles: self.compiles.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            entries: self.entries.lock().unwrap().len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::MemoryMode;

    #[test]
    fn one_compile_per_spec_and_fingerprint() {
        let cache = KernelCache::new();
        let spec = KernelSpec::Reduction { n: 64 };
        let dp = EgpuConfig::benchmark(MemoryMode::Dp, false);
        let qp = EgpuConfig::benchmark(MemoryMode::Qp, false);

        let a = cache.get(&spec, &dp).unwrap();
        let b = cache.get(&spec, &dp).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit");
        assert_eq!(cache.stats().compiles, 1);
        assert_eq!(cache.stats().hits, 1);

        // A different fingerprint compiles separately...
        cache.get(&spec, &qp).unwrap();
        assert_eq!(cache.stats().compiles, 2);
        // ...but a config differing only in non-compile axes does not.
        let mut renamed = dp.clone();
        renamed.name = "other".into();
        renamed.predicate_levels = 8;
        renamed.shared_kb = 256;
        cache.get(&spec, &renamed).unwrap();
        let s = cache.stats();
        assert_eq!((s.compiles, s.hits, s.entries), (2, 2, 2));
    }

    #[test]
    fn build_errors_are_not_cached() {
        let cache = KernelCache::new();
        let bad = KernelSpec::Bitonic { n: 7 };
        assert!(cache.get(&bad, &EgpuConfig::default()).is_err());
        let s = cache.stats();
        assert_eq!((s.compiles, s.entries), (0, 0));
    }
}
