//! Cycle-tracking assembly emitter (legacy string front-end).
//!
//! The eGPU pipeline has no interlocks (§3), so a program emitter must
//! insert the NOPs a hand-assembling programmer would. [`Sched`] mirrors
//! the machine's issue-cost and hazard-window model (`sim::hazard` /
//! `sim::machine`) instruction by instruction and pads automatically, so
//! emitted programs are hazard-free by construction and
//! `estimated_cycles` matches the simulator exactly for straight-line
//! programs.
//!
//! The benchmark kernels no longer use this: they build through the
//! kernel compiler ([`crate::kc::KernelBuilder`]), which *fills* delay
//! slots by list scheduling instead of only padding them. `Sched` remains
//! as the string-level emitter for hand-written/randomized programs (the
//! property tests in `rust/tests/asm_sim_properties.rs` lean on it).
//!
//! Control flow (JMP/JSR/RTS/LOOP) breaks the linear cycle model, so
//! [`Sched::op`] fences automatically at every control transfer — pending
//! windows are waited out before the transfer issues. (Historically this
//! was the caller's job via [`Sched::fence`]; a generator that forgot it
//! could under-pad a loop back-edge without any test noticing.)

use crate::asm::assemble;
use crate::isa::opcode::OperandShape;
use crate::isa::{Group, Instr, Opcode, WordLayout};
use crate::sim::config::MemoryMode;
use crate::sim::hazard::{DOT_WINDOW, MEM_WINDOW, REG_WINDOW};

/// Cycle-tracking emitter for one kernel.
pub struct Sched {
    out: String,
    layout: WordLayout,
    /// Initialized wavefronts of the target machine (threads / 16).
    total_waves: usize,
    memory: MemoryMode,
    cycle: u64,
    reg_ready: Vec<u64>,
    /// Coarse store→load turnaround: one global ready cycle (the machine
    /// tracks per address; global is conservative, never under-pads).
    mem_ready: u64,
    nops: u64,
}

impl Sched {
    pub fn new(name: &str, threads: usize, layout: WordLayout, memory: MemoryMode) -> Sched {
        assert!(threads >= 16 && threads % 16 == 0, "threads must be a multiple of 16");
        Sched {
            out: format!("; {name} — generated eGPU assembly ({threads} threads)\n"),
            layout,
            total_waves: threads / 16,
            memory,
            cycle: 0,
            reg_ready: vec![0; layout.max_reg() as usize + 1],
            mem_ready: 0,
            nops: 0,
        }
    }

    pub fn comment(&mut self, text: &str) -> &mut Self {
        self.out.push_str("    ; ");
        self.out.push_str(text);
        self.out.push('\n');
        self
    }

    /// Emit a label. Cycle tracking continues linearly; callers that jump
    /// here from elsewhere must [`fence`](Self::fence) at the jump site.
    pub fn label(&mut self, name: &str) -> &mut Self {
        self.out.push_str(name);
        self.out.push_str(":\n");
        self
    }

    fn parse(&self, line: &str) -> Instr {
        let p = assemble(&format!("{line}\n"), self.layout)
            .unwrap_or_else(|e| panic!("kernel generator emitted bad asm '{line}': {e}"));
        assert_eq!(p.instrs.len(), 1, "one instruction per op() call: '{line}'");
        p.instrs[0]
    }

    fn raw_nop(&mut self) {
        self.out.push_str("    nop\n");
        self.cycle += 1;
        self.nops += 1;
    }

    /// Emit one instruction, preceded by however many NOPs its operand
    /// reads require under the machine's hazard model.
    pub fn op(&mut self, line: impl AsRef<str>) -> &mut Self {
        let line = line.as_ref();
        // Branches to labels can't be parsed in isolation (the target is
        // resolved program-wide); they are 1-cycle control ops with no
        // register operands, so handle them without parsing.
        let mnemonic = line.trim_start().split_whitespace().next().unwrap_or("");
        if matches!(mnemonic, "jmp" | "jsr" | "loop") {
            // Control transfers invalidate the linear hazard model:
            // settle every pending window first so the destination (a
            // subroutine, a loop header) starts from a clean pipeline.
            self.fence();
            self.out.push_str("    ");
            self.out.push_str(line);
            self.out.push('\n');
            self.cycle += 1;
            return self;
        }
        let i = self.parse(line);
        if i.op.group() == Group::Control && !matches!(i.op, Opcode::Init | Opcode::Stop) {
            // RTS (and numeric-target branches): same control-transfer
            // settle as the label-target path above.
            self.fence();
        }
        let waves = i.tc.depth.waves(self.total_waves) as u64;
        let lanes = i.tc.width.lanes() as u64;
        let selected = waves * lanes;

        // Operand-read set (mirrors Machine::execute's hazard reads).
        let mut reads: Vec<u8> = Vec::with_capacity(2);
        match i.op.operands() {
            OperandShape::RdRa => reads.push(i.ra),
            OperandShape::RdRaRb | OperandShape::RaRb => {
                reads.push(i.ra);
                reads.push(i.rb);
            }
            OperandShape::RdMem => {
                reads.push(i.ra);
                if i.op == Opcode::Sto {
                    reads.push(i.rd);
                }
            }
            _ => {}
        }

        // Pad until every read is ready.
        let mut ready = 0u64;
        for &r in &reads {
            ready = ready.max(self.reg_ready[r as usize]);
        }
        if i.op == Opcode::Lod {
            ready = ready.max(self.mem_ready);
        }
        while self.cycle < ready {
            self.raw_nop();
        }

        // Issue cost (the machine's own charge formulas — shared, not
        // mirrored: MemoryMode::load_cycles/store_cycles back SharedMem).
        let cost = match i.op.group() {
            Group::Nop | Group::Control => 1,
            Group::Memory => {
                if i.op == Opcode::Lod {
                    self.memory.load_cycles(selected as usize)
                } else {
                    self.memory.store_cycles(selected as usize)
                }
            }
            _ => waves,
        };

        // Writer windows (mirrors sim::hazard usage in the machine).
        if i.op == Opcode::Sto {
            self.mem_ready = self.cycle + cost + MEM_WINDOW;
        } else if i.op.writes_rd() {
            let window = match i.op {
                Opcode::Lod => REG_WINDOW + cost.saturating_sub(waves),
                Opcode::Dot | Opcode::Sum => waves + DOT_WINDOW,
                _ => REG_WINDOW,
            };
            self.reg_ready[i.rd as usize] = self.cycle + window;
        }

        self.out.push_str("    ");
        self.out.push_str(line);
        self.out.push('\n');
        self.cycle += cost;
        self
    }

    /// Emit NOPs until every pending register window and the memory
    /// turnaround have expired — a full pipeline settle. Call before JSR
    /// targets' first use of caller-set registers and at LOOP back-edges.
    pub fn fence(&mut self) -> &mut Self {
        let mut ready = self.mem_ready;
        for &r in self.reg_ready.iter() {
            ready = ready.max(r);
        }
        while self.cycle < ready {
            self.raw_nop();
        }
        self
    }

    /// Cycles issued so far (exact for straight-line code).
    pub fn estimated_cycles(&self) -> u64 {
        self.cycle
    }

    /// NOPs inserted so far.
    pub fn nops_inserted(&self) -> u64 {
        self.nops
    }

    /// Finish with STOP (1 cycle; the machine adds the 8-cycle drain).
    pub fn finish(mut self) -> String {
        self.op("stop");
        self.out
    }

    /// Finish without appending STOP (the generator already emitted it).
    pub fn into_source(self) -> String {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::EgpuConfig;
    use crate::sim::Machine;

    fn layout() -> WordLayout {
        WordLayout::for_regs(32)
    }

    /// Run a Sched-emitted program and check (a) zero hazards and (b) the
    /// estimate matches the machine exactly.
    fn check(threads: usize, build: impl FnOnce(&mut Sched)) {
        let mut s = Sched::new("t", threads, layout(), MemoryMode::Dp);
        build(&mut s);
        let est = s.estimated_cycles() + 1; // + stop
        let src = s.finish();
        let mut cfg = EgpuConfig::default();
        cfg.dot_core = true;
        let mut m = Machine::new(cfg).unwrap();
        m.set_threads(threads).unwrap();
        let p = assemble(&src, layout()).unwrap();
        m.load_program(p).unwrap();
        let stats = m.run(1_000_000).unwrap();
        assert_eq!(stats.hazards, 0, "{:?}\n{src}", stats.hazard_samples);
        assert_eq!(stats.cycles, est + 8, "estimate mismatch\n{src}");
    }

    #[test]
    fn full_depth_ops_need_no_pads() {
        check(512, |s| {
            s.op("tdx r0").op("add.u32 r1, r0, r0").op("lod r2, (r1)+0");
        });
    }

    #[test]
    fn narrow_dependent_ops_are_padded() {
        let mut s = Sched::new("t", 512, layout(), MemoryMode::Dp);
        s.op("[w1,d0] ldi r1, #1").op("[w1,d0] add.u32 r2, r1, r1");
        assert_eq!(s.nops_inserted(), 5); // 6-cycle window, 1-cycle writer
        check(512, |s| {
            s.op("[w1,d0] ldi r1, #1").op("[w1,d0] add.u32 r2, r1, r1");
        });
    }

    #[test]
    fn load_use_latency_padded() {
        // 16-thread machine: lod costs 4, window 6+4-1=9 → 5 pads.
        check(16, |s| {
            s.op("tdx r0");
            s.fence();
            s.op("lod r1, (r0)+0").op("fadd r2, r1, r1");
        });
    }

    #[test]
    fn store_load_turnaround_padded() {
        check(16, |s| {
            s.op("tdx r0");
            s.fence();
            s.op("sto r0, (r0)+0").op("lod r1, (r0)+0");
        });
    }

    #[test]
    fn dot_writeback_window() {
        check(32, |s| {
            s.op("tdx r0");
            s.fence();
            s.op("sum r2, r0, r0").op("[w1,d0] sto r2, (r0)+64");
        });
    }

    #[test]
    fn fence_settles_everything() {
        let mut s = Sched::new("t", 16, layout(), MemoryMode::Dp);
        s.op("[w1,d0] ldi r1, #1").op("sto r1, (r1)+0");
        s.fence();
        let c = s.estimated_cycles();
        s.fence();
        assert_eq!(s.estimated_cycles(), c, "second fence is a no-op");
    }

    #[test]
    fn qp_store_cost_halved() {
        let mut dp = Sched::new("t", 512, layout(), MemoryMode::Dp);
        let mut qp = Sched::new("t", 512, layout(), MemoryMode::Qp);
        dp.op("sto r1, (r0)+0");
        qp.op("sto r1, (r0)+0");
        assert_eq!(dp.estimated_cycles(), 512);
        assert_eq!(qp.estimated_cycles(), 256);
    }

    #[test]
    #[should_panic(expected = "bad asm")]
    fn bad_asm_panics() {
        let mut s = Sched::new("t", 16, layout(), MemoryMode::Dp);
        s.op("frobnicate r1");
    }

    /// Regression for the control-flow hole: JMP/JSR/LOOP used to bypass
    /// hazard tracking entirely, so an emitter could under-pad a branch
    /// target's first read without any test noticing. Control transfers
    /// now settle automatically.
    #[test]
    fn control_ops_auto_fence() {
        // A 1-cycle writer immediately before a JSR whose subroutine
        // reads it: the fence must insert the full window.
        let mut s = Sched::new("t", 16, layout(), MemoryMode::Dp);
        s.op("[w1,d0] ldi r1, #1");
        s.op("jsr sub");
        s.op("stop");
        s.label("sub");
        s.op("[w1,d0] add.u32 r2, r1, r1");
        s.op("rts");
        let nops = s.nops_inserted();
        assert!(nops >= 5, "expected an auto-fence before jsr, got {nops} nops");
        let src = s.into_source();
        let mut m = Machine::new(EgpuConfig::default()).unwrap();
        m.set_threads(16).unwrap();
        m.load_program(assemble(&src, layout()).unwrap()).unwrap();
        let stats = m.run(100_000).unwrap();
        assert_eq!(stats.hazards, 0, "{:?}\n{src}", stats.hazard_samples);
    }

    /// Same for a LOOP back-edge: the body's trailing writer must be
    /// settled before the branch re-enters the header.
    #[test]
    fn loop_back_edge_auto_fences() {
        let mut s = Sched::new("t", 16, layout(), MemoryMode::Dp);
        s.op("ldi r1, #0");
        s.op("init #3");
        s.label("body");
        s.op("[w1,d0] add.u32 r1, r1, r1");
        s.op("loop body");
        let nops = s.nops_inserted();
        assert!(nops >= 5, "expected an auto-fence before loop, got {nops} nops");
        let src = s.finish();
        let mut m = Machine::new(EgpuConfig::default()).unwrap();
        m.set_threads(16).unwrap();
        m.load_program(assemble(&src, layout()).unwrap()).unwrap();
        let stats = m.run(100_000).unwrap();
        assert_eq!(stats.hazards, 0, "{:?}\n{src}", stats.hazard_samples);
    }
}
