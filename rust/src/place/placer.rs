//! Greedy column-affine placement (the Figure 4/5 substitution).

use crate::model::memory_model::{regfile_m20ks, shared_m20ks};
use crate::model::resources::ResourceReport;
use crate::sim::config::EgpuConfig;

use super::sector::{ColumnKind, Sector, ALMS_PER_LAB, SECTOR_ROWS};

/// What occupies one grid cell (a LAB, an M20K, or a DSP site).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cell {
    Empty,
    /// Shared-memory spine M20K.
    Shared,
    /// SP `i` datapath logic (LAB).
    SpLogic(u8),
    /// SP `i` register-file M20K.
    SpReg(u8),
    /// SP `i` DSP block (FP32 or integer multiplier).
    SpDsp(u8),
    /// SP `i` predicate block (LAB).
    Pred(u8),
    /// Instruction fetch/decode/control (LAB).
    Control,
}

/// A completed placement plus the structural statistics the paper reads
/// off Figures 4/5.
#[derive(Debug, Clone)]
pub struct Placement {
    pub sector: Sector,
    /// `grid[col][row]`.
    pub grid: Vec<Vec<Cell>>,
    /// Column index of each SP's DSP slice.
    pub sp_dsp_col: Vec<usize>,
    /// Column span (min..=max) of each SP's logic.
    pub sp_logic_span: Vec<(usize, usize)>,
    /// Column distance from each SP's logic to its predicate block.
    pub pred_distance: Vec<usize>,
    /// Shared-memory spine column indices.
    pub spine_cols: Vec<usize>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlaceError(pub String);

impl std::fmt::Display for PlaceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "placement: {}", self.0)
    }
}

impl std::error::Error for PlaceError {}

struct Grid {
    cells: Vec<Vec<Cell>>,
}

impl Grid {
    /// Fill `n` cells in a column starting at the first empty row;
    /// returns how many were actually placed.
    fn fill(&mut self, col: usize, n: usize, what: Cell) -> usize {
        let mut placed = 0;
        for cell in self.cells[col].iter_mut() {
            if placed == n {
                break;
            }
            if *cell == Cell::Empty {
                *cell = what;
                placed += 1;
            }
        }
        placed
    }
}

/// Place one eGPU instance into a sector.
pub fn place(cfg: &EgpuConfig) -> Result<Placement, PlaceError> {
    let report = ResourceReport::for_config(cfg);
    // Size the fabric: one sector when everything fits, more otherwise.
    let m20k_need = shared_m20ks(cfg) + regfile_m20ks(cfg) + 4;
    let one = Sector::agilex();
    let sectors = m20k_need
        .div_ceil(one.total_m20ks())
        .max((report.alms as usize).div_ceil(one.total_alms()))
        .max(1);
    let sector = Sector::multi(sectors);
    let mut grid = Grid {
        cells: sector
            .columns
            .iter()
            .map(|k| {
                vec![
                    Cell::Empty;
                    match k {
                        ColumnKind::Lab => SECTOR_ROWS,
                        _ => k.capacity(),
                    }
                ]
            })
            .collect(),
    };
    let center = sector.width() / 2;
    let mut m20k_cols = sector.columns_of(ColumnKind::M20k);
    // Memory columns sorted centre-outward: the spine takes the middle.
    m20k_cols.sort_by_key(|c| (*c as i64 - center as i64).abs());

    // 1. Shared-memory spine.
    let mut spine_need = shared_m20ks(cfg);
    let mut spine_cols = Vec::new();
    for &col in &m20k_cols {
        if spine_need == 0 {
            break;
        }
        let placed = grid.fill(col, spine_need, Cell::Shared);
        if placed > 0 {
            spine_cols.push(col);
        }
        spine_need -= placed;
    }
    if spine_need > 0 {
        return Err(PlaceError(format!(
            "shared memory does not fit: {spine_need} M20Ks left over"
        )));
    }

    // 2. SPs: 8 on each side of the spine, 4 SPs per DSP column.
    let dsp_cols = sector.columns_of(ColumnKind::Dsp);
    if dsp_cols.len() < 4 {
        return Err(PlaceError("sector has too few DSP columns".into()));
    }
    // The SP share splits into the contiguous datapath block and the
    // remotely-placed predicate block (step 3) — don't place it twice.
    let pred_alms_sp = crate::model::resources::pred_alms_per_sp(cfg) as usize;
    let sp_alm_labs = (report.sp_alms as usize)
        .saturating_sub(pred_alms_sp)
        .div_ceil(ALMS_PER_LAB);
    let sp_dsps = (report.dsps as usize).div_ceil(16);
    let sp_regs = regfile_m20ks(cfg).div_ceil(16);
    let mut sp_dsp_col = vec![0usize; 16];
    let mut sp_logic_span = vec![(usize::MAX, 0usize); 16];
    for sp in 0..16u8 {
        // SPs 0..7 west of the spine, 8..15 east; two DSP columns per side.
        let side_cols: Vec<usize> = if sp < 8 {
            dsp_cols.iter().copied().filter(|c| *c < center).collect()
        } else {
            dsp_cols.iter().copied().filter(|c| *c >= center).collect()
        };
        let dcol = side_cols[(sp as usize / 4) % side_cols.len().max(1)];
        sp_dsp_col[sp as usize] = dcol;
        if grid.fill(dcol, sp_dsps, Cell::SpDsp(sp)) < sp_dsps {
            return Err(PlaceError(format!("SP{sp}: DSP column {dcol} full")));
        }
        // Logic deliberately straddles the DSP column (Figure 5: the
        // operators sit in the LAB group on one side of the DSP pair,
        // pipelining on the other): half the LABs west, half east.
        let mut sides = [sp_alm_labs.div_ceil(2), sp_alm_labs / 2];
        for dist in 1..sector.width() {
            if sides == [0, 0] {
                break;
            }
            for (si, col) in [(0usize, dcol.wrapping_sub(dist)), (1, dcol + dist)] {
                if sides[si] == 0 || col >= sector.width() {
                    continue;
                }
                if sector.columns[col] != ColumnKind::Lab {
                    continue;
                }
                let placed = grid.fill(col, sides[si], Cell::SpLogic(sp));
                if placed > 0 {
                    let (lo, hi) = sp_logic_span[sp as usize];
                    sp_logic_span[sp as usize] = (lo.min(col), hi.max(col));
                }
                sides[si] -= placed;
            }
            // Column exhaustion on one side: shift the remainder over.
            if dist > 8 {
                let total = sides[0] + sides[1];
                sides = [total.div_ceil(2), total / 2];
            }
        }
        if sides != [0, 0] {
            return Err(PlaceError(format!("SP{sp}: logic does not fit")));
        }
        // Register-file M20Ks in the nearest memory column(s).
        let mut rneed = sp_regs;
        let mut near_mem = sector.columns_of(ColumnKind::M20k);
        near_mem.sort_by_key(|c| (*c as i64 - dcol as i64).abs());
        for col in near_mem {
            if rneed == 0 {
                break;
            }
            rneed -= grid.fill(col, rneed, Cell::SpReg(sp));
        }
        if rneed > 0 {
            return Err(PlaceError(format!("SP{sp}: register M20Ks do not fit")));
        }
    }

    // 3. Predicate blocks: placed in the *farthest* LAB column with space
    // (Quartus floats them away — narrow interface, §6).
    let mut pred_distance = vec![0usize; 16];
    if cfg.predicate_levels > 0 {
        let pred_labs = pred_alms_sp.div_ceil(ALMS_PER_LAB).max(1);
        for sp in 0..16u8 {
            let dcol = sp_dsp_col[sp as usize];
            let mut labs: Vec<usize> = sector.columns_of(ColumnKind::Lab);
            labs.sort_by_key(|c| std::cmp::Reverse((*c as i64 - dcol as i64).abs()));
            let mut need = pred_labs;
            for col in labs {
                if need == 0 {
                    break;
                }
                let placed = grid.fill(col, need, Cell::Pred(sp));
                if placed > 0 {
                    pred_distance[sp as usize] =
                        pred_distance[sp as usize].max((col as i64 - dcol as i64).unsigned_abs() as usize);
                }
                need -= placed;
            }
            if need > 0 {
                return Err(PlaceError(format!("SP{sp}: predicate block does not fit")));
            }
        }
    }

    // 4. Control wherever there is room near the centre.
    let ctrl_labs = 250usize.div_ceil(ALMS_PER_LAB);
    let mut labs: Vec<usize> = sector.columns_of(ColumnKind::Lab);
    labs.sort_by_key(|c| (*c as i64 - center as i64).abs());
    let mut need = ctrl_labs;
    for col in labs {
        if need == 0 {
            break;
        }
        need -= grid.fill(col, need, Cell::Control);
    }
    if need > 0 {
        return Err(PlaceError("control logic does not fit".into()));
    }

    Ok(Placement {
        sector,
        grid: grid.cells,
        sp_dsp_col,
        sp_logic_span,
        pred_distance,
        spine_cols,
    })
}

impl Placement {
    /// Figure-4 check (a): each SP's logic is one contiguous column band
    /// (within two LAB groups of its DSP column).
    pub fn sp_logic_contiguous(&self) -> bool {
        self.sp_logic_span
            .iter()
            .all(|(lo, hi)| hi.saturating_sub(*lo) <= 10)
    }

    /// Figure-4 check (c): the SP straddles its DSP column.
    pub fn sp_straddles_dsp(&self, sp: usize) -> bool {
        let (lo, hi) = self.sp_logic_span[sp];
        let d = self.sp_dsp_col[sp];
        lo < d && d < hi
    }

    /// Figure-4 check (b): predicate blocks sit away from the SP core.
    pub fn predicates_remote(&self) -> bool {
        self.pred_distance.iter().all(|d| *d == 0)
            || self.pred_distance.iter().any(|d| *d >= 8)
    }

    /// The spine is central: its columns are exactly the innermost M20K
    /// columns of the fabric ("the shared memory creates a spine in the
    /// middle of the core", §6) — a set-prefix of the centre-outward
    /// ordering, however many columns the spine needs.
    pub fn spine_is_central(&self) -> bool {
        let center = self.sector.width() as i64 / 2;
        let mut mem_cols = self.sector.columns_of(super::sector::ColumnKind::M20k);
        mem_cols.sort_by_key(|c| (*c as i64 - center).abs());
        let innermost: std::collections::BTreeSet<usize> =
            mem_cols.into_iter().take(self.spine_cols.len()).collect();
        self.spine_cols.iter().all(|c| innermost.contains(c))
    }

    /// Worst column distance between an SP's register M20Ks and its DSP
    /// column — the wire-hop statistic behind the §6 Fmax argument.
    pub fn max_reg_to_dsp_hops(&self) -> usize {
        let mut worst = 0;
        for (col, cells) in self.grid.iter().enumerate() {
            for cell in cells {
                if let Cell::SpReg(sp) = cell {
                    let d = (col as i64 - self.sp_dsp_col[*sp as usize] as i64).unsigned_abs()
                        as usize;
                    worst = worst.max(d);
                }
            }
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::EgpuConfig;

    #[test]
    fn all_table4_instances_place() {
        for cfg in EgpuConfig::table4_presets() {
            let p = place(&cfg).unwrap_or_else(|e| panic!("{}: {e}", cfg.name));
            assert!(p.spine_is_central(), "{}", cfg.name);
            assert!(p.sp_logic_contiguous(), "{}", cfg.name);
        }
    }

    #[test]
    fn largest_instance_shows_figure4_structure() {
        // Figure 4 is the largest Table 4 instance.
        let cfg = EgpuConfig::table4_presets().remove(5);
        let p = place(&cfg).unwrap();
        // (a) contiguous SP logic
        assert!(p.sp_logic_contiguous());
        // (b) predicate blocks placed some distance away
        assert!(p.predicates_remote());
        // (c) SPs straddle DSP columns
        let straddling = (0..16).filter(|&sp| p.sp_straddles_dsp(sp)).count();
        assert!(straddling >= 12, "only {straddling}/16 SPs straddle");
    }

    #[test]
    fn spine_splits_sps_eight_per_side() {
        let cfg = EgpuConfig::table4_presets().remove(5);
        let p = place(&cfg).unwrap();
        let center = p.sector.width() / 2;
        let west = (0..8).filter(|&sp| p.sp_dsp_col[sp] < center).count();
        let east = (8..16).filter(|&sp| p.sp_dsp_col[sp] >= center).count();
        assert_eq!(west, 8);
        assert_eq!(east, 8);
    }

    #[test]
    fn wire_hops_bounded() {
        // §6: performance comes from minimal wire hops; register→DSP
        // paths must stay within a handful of columns.
        for cfg in EgpuConfig::table4_presets() {
            let p = place(&cfg).unwrap();
            assert!(
                p.max_reg_to_dsp_hops() <= 14,
                "{}: {} hops",
                cfg.name,
                p.max_reg_to_dsp_hops()
            );
        }
    }

    #[test]
    fn benchmark_config_places_in_one_sector() {
        // 128KB shared = 256 M20Ks + 64 regfile + instruction store: very
        // close to the 240-M20K sector — the QP variant fits.
        use crate::sim::config::MemoryMode;
        let qp = EgpuConfig::benchmark(MemoryMode::Qp, false);
        let p = place(&qp).unwrap();
        assert_eq!(p.sector.width(), 50, "QP fits one sector");
        // The DP 128KB variant overflows into a second sector (§5.6).
        let dp = EgpuConfig::benchmark(MemoryMode::Dp, false);
        let p = place(&dp).unwrap();
        assert_eq!(p.sector.width(), 100, "DP needs two sectors");
    }
}
