//! Agilex sector placement model (paper §5.6, §6, Figures 4 and 5).
//!
//! Quartus placement is substituted (DESIGN.md §3) by a greedy
//! column-affine placer over the paper's sector geometry. It reproduces
//! the *structural* findings of Figures 4/5: the shared-memory spine in
//! the middle M20K columns, 8 SPs on either side each straddling a DSP
//! column with its register M20Ks in adjacent memory columns, and the
//! predicate blocks placed as separate contiguous blobs away from their
//! SPs (possible because their interface is a few bits wide).

pub mod placer;
pub mod render;
pub mod sector;

pub use placer::{place, PlaceError, Placement};
pub use sector::{ColumnKind, Sector};
