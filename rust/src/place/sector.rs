//! Agilex sector geometry (paper §5.6).
//!
//! "The Intel Agilex devices are arranged in sectors, the most common of
//! which contains about 16400 ALMs, 240 M20K memories, and 160 DSP
//! Blocks. ... there is a constant 4 columns of logic between each column
//! of either DSP or M20K. In a sector we will have 40 columns of logic, 4
//! columns of DSP, and 6 columns of M20K" — columns ≈ 41 rows high.

/// Rows per column (≈41 LAB rows; memories/DSPs pack ~40 usable sites).
pub const SECTOR_ROWS: usize = 41;

/// ALMs per LAB (Agilex).
pub const ALMS_PER_LAB: usize = 10;

/// Column types in a sector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColumnKind {
    /// LAB column: 41 LABs × 10 ALMs = 410 ALMs.
    Lab,
    /// M20K column: 40 memories.
    M20k,
    /// DSP column: 40 DSP blocks.
    Dsp,
}

impl ColumnKind {
    /// Capacity in that column's native unit (ALMs / M20Ks / DSPs).
    pub fn capacity(self) -> usize {
        match self {
            ColumnKind::Lab => SECTOR_ROWS * ALMS_PER_LAB,
            ColumnKind::M20k => 40,
            ColumnKind::Dsp => 40,
        }
    }

    pub fn glyph(self) -> char {
        match self {
            ColumnKind::Lab => '.',
            ColumnKind::M20k => 'm',
            ColumnKind::Dsp => 'd',
        }
    }
}

/// One sector: a left-to-right column sequence.
#[derive(Debug, Clone)]
pub struct Sector {
    pub columns: Vec<ColumnKind>,
}

impl Default for Sector {
    fn default() -> Self {
        Self::agilex()
    }
}

impl Sector {
    /// The paper's sector: 40 LAB + 4 DSP + 6 M20K columns, a constant 4
    /// LAB columns between embedded columns. Embedded order chosen so the
    /// M20K columns are densest near the center (where the shared-memory
    /// spine lands) and DSP columns flank them — the Figure 4 pattern.
    pub fn agilex() -> Sector {
        Self::multi(1)
    }

    /// `n` sectors side by side (§5.6: "we are not limited to a single
    /// sector (additional pipelining may be required to maintain
    /// performance across sector boundaries)").
    pub fn multi(n: usize) -> Sector {
        use ColumnKind::*;
        let embedded = [M20k, Dsp, M20k, Dsp, M20k, M20k, Dsp, M20k, Dsp, M20k];
        let mut columns = Vec::with_capacity(50 * n);
        for _ in 0..n.max(1) {
            for e in embedded {
                columns.extend([Lab, Lab, Lab, Lab]);
                columns.push(e);
            }
        }
        Sector { columns }
    }

    pub fn width(&self) -> usize {
        self.columns.len()
    }

    pub fn total_alms(&self) -> usize {
        self.count(ColumnKind::Lab) * ColumnKind::Lab.capacity()
    }

    pub fn total_m20ks(&self) -> usize {
        self.count(ColumnKind::M20k) * ColumnKind::M20k.capacity()
    }

    pub fn total_dsps(&self) -> usize {
        self.count(ColumnKind::Dsp) * ColumnKind::Dsp.capacity()
    }

    fn count(&self, k: ColumnKind) -> usize {
        self.columns.iter().filter(|c| **c == k).count()
    }

    /// Column indices of the given kind.
    pub fn columns_of(&self, k: ColumnKind) -> Vec<usize> {
        self.columns
            .iter()
            .enumerate()
            .filter(|(_, c)| **c == k)
            .map(|(i, _)| i)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sector_matches_paper_capacities() {
        let s = Sector::agilex();
        // "about 16400 ALMs, 240 M20K memories, and 160 DSP Blocks"
        assert_eq!(s.total_alms(), 16_400);
        assert_eq!(s.total_m20ks(), 240);
        assert_eq!(s.total_dsps(), 160);
        assert_eq!(s.width(), 50);
    }

    #[test]
    fn four_labs_between_embedded_columns() {
        let s = Sector::agilex();
        let mut run = 0;
        for c in &s.columns {
            match c {
                ColumnKind::Lab => run += 1,
                _ => {
                    assert_eq!(run, 4, "embedded column not preceded by 4 LABs");
                    run = 0;
                }
            }
        }
    }

    #[test]
    fn column_counts() {
        let s = Sector::agilex();
        assert_eq!(s.columns_of(ColumnKind::M20k).len(), 6);
        assert_eq!(s.columns_of(ColumnKind::Dsp).len(), 4);
        assert_eq!(s.columns_of(ColumnKind::Lab).len(), 40);
    }
}
