//! ASCII rendering of a placement (the Figure 4/5 analogue).

use super::placer::{Cell, Placement};
use super::sector::SECTOR_ROWS;

/// Render the whole sector, one character per cell, columns left→right.
/// SPs are hex digits, spine `M`, register M20Ks `r`, DSPs `D`,
/// predicates `p`, control `#`, empty by column kind.
pub fn render(p: &Placement) -> String {
    let mut out = String::new();
    out.push_str("  Figure-4 analogue: one Agilex sector, 50 columns x 41 rows\n");
    out.push_str("  (hex digit = SP logic, D = SP DSP, r = SP register M20K,\n");
    out.push_str("   M = shared-memory spine, p = predicate block, # = control)\n\n");
    let height = SECTOR_ROWS;
    for row in 0..height {
        out.push_str("  ");
        for (col, cells) in p.grid.iter().enumerate() {
            // Memory/DSP columns have 40 sites vs 41 LAB rows; clamp.
            let c = if row < cells.len() {
                cells[row]
            } else {
                Cell::Empty
            };
            out.push(match c {
                Cell::Empty => p.sector.columns[col].glyph(),
                Cell::Shared => 'M',
                Cell::SpLogic(sp) => char::from_digit(sp as u32, 16).unwrap(),
                Cell::SpReg(_) => 'r',
                Cell::SpDsp(_) => 'D',
                Cell::Pred(_) => 'p',
                Cell::Control => '#',
            });
        }
        out.push('\n');
    }
    out
}

/// One-SP zoom (the Figure 5 analogue): the columns around `sp`'s DSP.
pub fn render_sp(p: &Placement, sp: u8) -> String {
    let d = p.sp_dsp_col[sp as usize];
    let lo = d.saturating_sub(6);
    let hi = (d + 6).min(p.sector.width() - 1);
    let mut out = format!(
        "  Figure-5 analogue: SP{sp} (DSP column {d}, logic span {:?})\n\n",
        p.sp_logic_span[sp as usize]
    );
    for row in 0..SECTOR_ROWS {
        out.push_str("  ");
        for col in lo..=hi {
            let cells = &p.grid[col];
            let c = if row < cells.len() {
                cells[row]
            } else {
                Cell::Empty
            };
            out.push(match c {
                Cell::SpLogic(s) if s == sp => 'X',
                Cell::SpDsp(s) if s == sp => 'D',
                Cell::SpReg(s) if s == sp => 'r',
                Cell::Pred(s) if s == sp => 'p',
                Cell::Empty => p.sector.columns[col].glyph(),
                _ => ' ',
            });
        }
        out.push('\n');
    }
    out
}

/// Summary statistics block printed under the figures.
pub fn stats(p: &Placement) -> String {
    format!(
        "  spine columns: {:?} (central: {})\n  SP logic contiguous: {}\n  \
         SPs straddling their DSP column: {}/16\n  predicates remote: {}\n  \
         max register->DSP wire hops: {}\n",
        p.spine_cols,
        p.spine_is_central(),
        p.sp_logic_contiguous(),
        (0..16).filter(|&sp| p.sp_straddles_dsp(sp)).count(),
        p.predicates_remote(),
        p.max_reg_to_dsp_hops(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::place::place;
    use crate::sim::config::EgpuConfig;

    #[test]
    fn renders_all_cell_kinds() {
        let cfg = EgpuConfig::table4_presets().remove(5);
        let p = place(&cfg).unwrap();
        let r = render(&p);
        for ch in ['M', 'D', 'r', '#', 'p', '0', 'f'] {
            assert!(r.contains(ch), "missing glyph {ch}");
        }
        assert_eq!(r.lines().count(), 4 + SECTOR_ROWS);
    }

    #[test]
    fn sp_zoom_contains_dsp_and_logic() {
        let cfg = EgpuConfig::table4_presets().remove(3);
        let p = place(&cfg).unwrap();
        let z = render_sp(&p, 3);
        assert!(z.contains('D'));
        assert!(z.contains('X'));
    }

    #[test]
    fn stats_summarize() {
        let cfg = EgpuConfig::table4_presets().remove(0);
        let p = place(&cfg).unwrap();
        let s = stats(&p);
        assert!(s.contains("spine columns"));
        assert!(s.contains("/16"));
    }
}
