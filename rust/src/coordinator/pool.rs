//! The resident core worker pool.
//!
//! `Coordinator::run_all` used to build its execution fabric per batch:
//! `std::thread::scope` spawned one worker per core, fresh channels
//! carried the jobs, and a batch-scoped mutex/condvar pair carried the
//! outcomes. In a serving loop that is thread spawn/join plus channel
//! and buffer allocation on every batch window — infrastructure churn
//! the modeled hardware never pays, since the paper's whole point is
//! that the datapath stays resident and is *fed*. This module makes the
//! host simulator match that discipline: a [`CorePool`] of worker
//! threads created once (lazily, on the first parallel batch) and owned
//! by the `Coordinator` for its lifetime.
//!
//! # Batch protocol
//!
//! Machines live in `Coordinator::cores` between batches (the escape
//! hatches and the sequential path borrow them directly) and are
//! *loaned* to the workers for the duration of one batch:
//!
//! ```text
//! begin_batch:  dispatcher --Batch{machine, shared}--> worker c   (all c)
//! dispatch:     dispatcher --Job{idx, prog, job}-----> worker c   (per job)
//!               worker c   --shared.complete(idx, outcome)
//! end_batch:    dispatcher --EndBatch---------------> worker c   (all c)
//!               worker c   --ret channel------------> machine back
//! ```
//!
//! [`BatchShared`] replaces the old `(Mutex<Vec<Option<..>>>, Condvar)`
//! + `notify_all` pattern with *targeted* signaling: the dispatcher is
//! the only waiter and it accounts jobs in submission order, so it
//! records the one index it is blocked on and a completing worker
//! notifies only when it fills exactly that slot. A 4-core fleet no
//! longer wakes every sleeper on every retire — there is one sleeper,
//! woken once per job it actually waits for. The slot vector itself is
//! retained across batches (reset in place once the workers' `Arc`
//! clones return), as is each worker's channel pair.
//!
//! # Poison and revive
//!
//! A job that fails or panics marks its worker *dead for the rest of
//! the batch* (later jobs on that core answer "skipped", exactly like
//! the scoped-thread implementation) — but the thread itself survives,
//! and the next `begin_batch` clears the flag: poisoned cores drain and
//! revive between batches instead of killing the fabric. If a worker
//! thread genuinely dies (only reachable through the test-only poison
//! message — user panics are caught inside the worker), the pool
//! rebuilds: a failed loan send returns the machine (`SendError` gives
//! the message back) and the worker respawns; a failed reclaim rebuilds
//! the machine from the core's config and poisons the coordinator's
//! resident-kernel tracking so no stale reuse decision survives.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::asm::Program;
use crate::kernels::Kernel;
use crate::sim::{Machine, SimError};

use super::{exec_assembled, Job, JobOutcome};

/// Run one job on a loaned machine with panics contained: both dispatch
/// paths use this, so a panicking job produces the *same* `SimError`
/// sequentially and in a pooled worker (serve-report bit-identity
/// includes error strings).
pub(super) fn run_job_guarded(m: &mut Machine, prog: Option<Program>, job: &Job) -> JobOutcome {
    catch_unwind(AssertUnwindSafe(|| exec_assembled(m, prog, job))).unwrap_or_else(|_| {
        Err(SimError::new(
            0,
            format!("job '{}' panicked in its worker", job.kernel.name),
        ))
    })
}

/// Outcome slots for one batch, indexed by submission order.
struct SlotState {
    slots: Vec<Option<JobOutcome>>,
    /// Submission index the dispatcher is currently blocked on, if any.
    /// The dispatcher is the only waiter, so completions notify only
    /// when they fill exactly this slot.
    waiting: Option<usize>,
}

/// Worker → dispatcher completion board for one batch window. Allocated
/// once and reset in place between batches (the pool holds the `Arc`
/// across windows; workers hold clones only while a batch is open).
pub(super) struct BatchShared {
    state: Mutex<SlotState>,
    cv: Condvar,
}

impl BatchShared {
    fn new(n: usize) -> BatchShared {
        BatchShared {
            state: Mutex::new(SlotState {
                slots: (0..n).map(|_| None).collect(),
                waiting: None,
            }),
            cv: Condvar::new(),
        }
    }

    /// Reset for a batch of `n` jobs. Requires exclusive ownership
    /// (`Arc::get_mut`), which holds once every worker has dropped its
    /// clone at `EndBatch`; the slot allocation is reused.
    fn reset(&mut self, n: usize) {
        let state = self.state.get_mut().unwrap();
        state.slots.clear();
        state.slots.resize_with(n, || None);
        state.waiting = None;
    }

    /// Deliver job `idx`'s outcome, waking the dispatcher only if it is
    /// blocked on exactly this index.
    fn complete(&self, idx: usize, outcome: JobOutcome) {
        let mut st = self.state.lock().unwrap();
        st.slots[idx] = Some(outcome);
        if st.waiting == Some(idx) {
            self.cv.notify_one();
        }
    }

    /// Block until job `idx`'s outcome lands, then take it. Called only
    /// by the dispatcher, in submission order.
    pub(super) fn take(&self, idx: usize) -> JobOutcome {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(o) = st.slots[idx].take() {
                st.waiting = None;
                return o;
            }
            st.waiting = Some(idx);
            st = self.cv.wait(st).unwrap();
        }
    }
}

/// Dispatcher → worker messages (one persistent channel per core).
enum WorkerMsg {
    /// Open a batch window: loan the core's machine and the batch's
    /// completion board. Clears the worker's dead flag.
    Batch {
        machine: Box<Machine>,
        shared: Arc<BatchShared>,
    },
    /// One job for the open window.
    Job {
        idx: usize,
        prog: Option<Program>,
        job: Box<Job>,
    },
    /// Close the window: drop the board clone, return the machine.
    EndBatch,
    /// Kill the worker thread outright (thread-death recovery tests;
    /// real job panics are caught and never get here).
    #[cfg(test)]
    PoisonForTest,
}

fn worker_loop(rx: Receiver<WorkerMsg>, ret: Sender<Box<Machine>>) {
    let mut loan: Option<(Box<Machine>, Arc<BatchShared>)> = None;
    let mut dead = false;
    for msg in rx {
        match msg {
            WorkerMsg::Batch { machine, shared } => {
                loan = Some((machine, shared));
                dead = false;
            }
            WorkerMsg::Job { idx, prog, job } => {
                let (m, shared) = loan.as_mut().expect("job sent outside a batch window");
                // A worker stops at its first failure: the sequential
                // path never runs anything after a failed job, so later
                // jobs queued to this core are skipped until the next
                // batch revives it.
                let outcome = if dead {
                    Err(SimError::new(
                        0,
                        "skipped: an earlier job on this core failed",
                    ))
                } else {
                    run_job_guarded(m, prog, &job)
                };
                dead = dead || outcome.is_err();
                shared.complete(idx, outcome);
            }
            WorkerMsg::EndBatch => {
                if let Some((m, shared)) = loan.take() {
                    // Release the board before returning the machine, so
                    // the dispatcher's reclaim implies exclusive board
                    // ownership (`Arc::get_mut` succeeds next batch).
                    drop(shared);
                    if ret.send(m).is_err() {
                        return;
                    }
                }
            }
            #[cfg(test)]
            WorkerMsg::PoisonForTest => return,
        }
    }
}

/// One resident worker: its job channel, its machine-return channel and
/// its join handle (`None` once joined during a revive).
struct Worker {
    tx: Sender<WorkerMsg>,
    ret: Receiver<Box<Machine>>,
    handle: Option<JoinHandle<()>>,
}

fn spawn_worker(core: usize) -> Worker {
    let (tx, rx) = channel::<WorkerMsg>();
    let (ret_tx, ret) = channel::<Box<Machine>>();
    let handle = std::thread::Builder::new()
        .name(format!("egpu-core-{core}"))
        .spawn(move || worker_loop(rx, ret_tx))
        .expect("spawn coordinator worker thread");
    Worker {
        tx,
        ret,
        handle: Some(handle),
    }
}

/// The long-lived worker pool: one thread per core, created on the
/// coordinator's first parallel batch and reused by every subsequent
/// `run_all` call and serve window until the coordinator drops.
pub(super) struct CorePool {
    workers: Vec<Worker>,
    /// The retained completion board (reset in place per batch).
    shared: Option<Arc<BatchShared>>,
    /// Worker threads revived after dying (0 outside thread-death
    /// recovery; batch-level job failures never kill a thread).
    revives: u64,
}

impl CorePool {
    pub(super) fn new(ncores: usize) -> CorePool {
        CorePool {
            workers: (0..ncores).map(spawn_worker).collect(),
            shared: None,
            revives: 0,
        }
    }

    pub(super) fn revives(&self) -> u64 {
        self.revives
    }

    fn respawn(&mut self, core: usize) {
        let old = std::mem::replace(&mut self.workers[core], spawn_worker(core));
        drop(old.tx);
        if let Some(h) = old.handle {
            let _ = h.join();
        }
        self.revives += 1;
    }

    /// Open a batch window of `n_jobs` submission slots: loan every
    /// machine in `cores` (drained in core order, buffer retained) to
    /// its worker. A dead worker is respawned and the machine — handed
    /// back by the failed send — re-loaned to its replacement.
    pub(super) fn begin_batch(
        &mut self,
        cores: &mut Vec<Machine>,
        n_jobs: usize,
    ) -> Arc<BatchShared> {
        let shared = match self.shared.take() {
            Some(mut arc) => {
                match Arc::get_mut(&mut arc) {
                    Some(b) => b.reset(n_jobs),
                    // Unreachable in practice (workers drop their clones
                    // before the machines come back), but a fresh board
                    // is always correct.
                    None => arc = Arc::new(BatchShared::new(n_jobs)),
                }
                arc
            }
            None => Arc::new(BatchShared::new(n_jobs)),
        };
        for (c, m) in cores.drain(..).enumerate() {
            let msg = WorkerMsg::Batch {
                machine: Box::new(m),
                shared: Arc::clone(&shared),
            };
            if let Err(failed) = self.workers[c].tx.send(msg) {
                self.respawn(c);
                self.workers[c]
                    .tx
                    .send(failed.0)
                    .expect("freshly spawned coordinator worker hung up");
            }
        }
        self.shared = Some(Arc::clone(&shared));
        shared
    }

    /// Queue one job on `core`'s worker for the open window.
    pub(super) fn send(&self, core: usize, idx: usize, prog: Option<Program>, job: Job) {
        self.workers[core]
            .tx
            .send(WorkerMsg::Job {
                idx,
                prog,
                job: Box::new(job),
            })
            .expect("coordinator worker hung up");
    }

    /// Close the window: each worker drains its remaining jobs (error
    /// paths leave unread outcomes behind; the board reset discards
    /// them) and returns its machine, reclaimed here in core order so
    /// `cores[c]` stays core `c`'s machine. A worker that died mid-batch
    /// lost its machine: `rebuild(c)` constructs a replacement, the
    /// worker respawns, and the caller's resident-kernel/resident-data
    /// trackers for that core are poisoned — the machine is blank, so no
    /// reuse or chaining decision may trust it.
    pub(super) fn end_batch(
        &mut self,
        cores: &mut Vec<Machine>,
        rebuild: impl Fn(usize) -> Machine,
        core_loaded: &mut [Option<Arc<Kernel>>],
        core_resident: &mut [Option<u64>],
    ) {
        debug_assert!(cores.is_empty(), "machines still resident at end_batch");
        for c in 0..self.workers.len() {
            let _ = self.workers[c].tx.send(WorkerMsg::EndBatch);
            match self.workers[c].ret.recv() {
                Ok(m) => cores.push(*m),
                Err(_) => {
                    cores.push(rebuild(c));
                    core_loaded[c] = None;
                    core_resident[c] = None;
                    self.respawn(c);
                }
            }
        }
    }

    /// Kill core `c`'s worker thread and wait for it to exit — the
    /// thread-death recovery paths are otherwise unreachable.
    #[cfg(test)]
    fn kill_worker_for_test(&mut self, core: usize) {
        self.workers[core]
            .tx
            .send(WorkerMsg::PoisonForTest)
            .expect("worker already dead");
        if let Some(h) = self.workers[core].handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for CorePool {
    fn drop(&mut self) {
        for w in self.workers.drain(..) {
            // Disconnect first so the worker's receive loop ends, then
            // join — machines still on loan are dropped with the thread
            // (the coordinator is being torn down with us).
            drop(w.tx);
            drop(w.ret);
            if let Some(h) = w.handle {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::EgpuConfig;

    fn machines(n: usize) -> Vec<Machine> {
        (0..n)
            .map(|_| Machine::new(EgpuConfig::default()).unwrap())
            .collect()
    }

    fn reclaim(pool: &mut CorePool, cores: &mut Vec<Machine>, n: usize) {
        let mut loaded: Vec<Option<Arc<Kernel>>> = vec![None; n];
        let mut resident: Vec<Option<u64>> = vec![None; n];
        pool.end_batch(
            cores,
            |_| Machine::new(EgpuConfig::default()).unwrap(),
            &mut loaded,
            &mut resident,
        );
    }

    #[test]
    fn machines_survive_a_loan_round_trip() {
        let mut pool = CorePool::new(2);
        let mut cores = machines(2);
        for _ in 0..3 {
            pool.begin_batch(&mut cores, 4);
            assert!(cores.is_empty(), "machines are on loan");
            reclaim(&mut pool, &mut cores, 2);
            assert_eq!(cores.len(), 2, "every machine comes back");
        }
        assert_eq!(pool.revives(), 0);
    }

    #[test]
    fn dead_worker_revives_on_begin_batch_without_losing_its_machine() {
        let mut pool = CorePool::new(2);
        let mut cores = machines(2);
        pool.kill_worker_for_test(0);
        // The failed loan send hands the machine back; the worker
        // respawns and the batch proceeds normally.
        pool.begin_batch(&mut cores, 1);
        reclaim(&mut pool, &mut cores, 2);
        assert_eq!(cores.len(), 2);
        assert_eq!(pool.revives(), 1);
        // The revived worker keeps working on later batches.
        pool.begin_batch(&mut cores, 1);
        reclaim(&mut pool, &mut cores, 2);
        assert_eq!((cores.len(), pool.revives()), (2, 1));
    }

    #[test]
    fn mid_batch_death_rebuilds_the_machine_and_poisons_tracking() {
        let mut pool = CorePool::new(2);
        let mut cores = machines(2);
        pool.begin_batch(&mut cores, 1);
        // The worker dies holding its loaned machine.
        pool.kill_worker_for_test(1);
        let mut loaded: Vec<Option<Arc<Kernel>>> =
            vec![Some(Arc::new(crate::kernels::reduction::reduction(32))); 2];
        let mut resident: Vec<Option<u64>> = vec![Some(7); 2];
        pool.end_batch(
            &mut cores,
            |_| Machine::new(EgpuConfig::default()).unwrap(),
            &mut loaded,
            &mut resident,
        );
        assert_eq!(cores.len(), 2, "the lost machine was rebuilt");
        assert_eq!(pool.revives(), 1);
        assert!(loaded[0].is_some() && resident[0].is_some(), "core 0 untouched");
        assert!(loaded[1].is_none(), "rebuilt core's reuse tracking poisoned");
        assert!(resident[1].is_none(), "rebuilt core's residency poisoned");
    }

    #[test]
    fn take_returns_outcomes_in_dispatch_order_with_targeted_wakeups() {
        let shared = Arc::new(BatchShared::new(2));
        let s = Arc::clone(&shared);
        let t = std::thread::spawn(move || {
            // Complete out of order: idx 1 lands while the dispatcher
            // waits on idx 0 (no wakeup), then idx 0 (targeted wakeup).
            s.complete(1, Err(SimError::new(0, "second")));
            s.complete(0, Err(SimError::new(0, "first")));
        });
        assert_eq!(shared.take(0).unwrap_err().message, "first");
        assert_eq!(shared.take(1).unwrap_err().message, "second");
        t.join().unwrap();
    }
}
