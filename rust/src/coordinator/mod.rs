//! Multi-core job dispatch and the external 32-bit data bus.
//!
//! The eGPU "has a single local data memory ... the loading and unloading
//! of which has to be managed externally" (§2), over a 32-bit bus whose
//! cost the paper quantifies: "we also ran all of our benchmarks taking
//! into account the time to load and unload the data over the 32-bit wide
//! data bus. The performance impact was only 4.7%, averaged over all
//! benchmarks" (§7). And "the eGPU only uses 1%-2% of a current mid-range
//! device ... even if multiple cores are required" (§8).
//!
//! This module is that external manager: a [`Coordinator`] owning N eGPU
//! cores, dispatching queued [`Job`]s to the earliest-free core, and
//! serializing shared-memory load/unload DMA over one [`DataBus`]. Chained
//! jobs (`keep_data`) skip the bus entirely — the paper's "multiple
//! algorithms to the same data" mode.

use std::collections::HashMap;

use crate::kernels::Kernel;
use crate::sim::config::EgpuConfig;
use crate::sim::{Machine, RunStats, SimError};

/// Default kernel cycle budget: bounds runaway programs without ever
/// tripping on a real workload (the largest paper kernel, MMM-128, runs
/// ~2.3M cycles). [`crate::api::LaunchBuilder::max_cycles`] and
/// [`Job::budget`] override it.
pub const DEFAULT_CYCLE_BUDGET: u64 = 10_000_000_000;

/// The external 32-bit data bus: one 32-bit word per bus cycle, clocked at
/// the core frequency (§7 measures load/unload at the core clock).
#[derive(Debug, Clone, Copy)]
pub struct DataBus {
    pub mhz: f64,
}

impl DataBus {
    pub fn new(mhz: f64) -> DataBus {
        DataBus { mhz }
    }

    /// Cycles to move `words` 32-bit words.
    pub fn transfer_cycles(&self, words: usize) -> u64 {
        words as u64
    }
}

/// One unit of work: a kernel plus its data movement.
#[derive(Debug, Clone)]
pub struct Job {
    pub kernel: Kernel,
    /// Blocks DMA'd into shared memory before the run.
    pub loads: Vec<(usize, Vec<u32>)>,
    /// `(base, len)` blocks DMA'd out after the run.
    pub unloads: Vec<(usize, usize)>,
    /// Chain onto the previous job's shared memory: skip the load DMA and
    /// do not clear shared memory (§7: "there is no loading and unloading
    /// of data between different algorithms").
    pub keep_data: bool,
    /// Stream this job belongs to. Jobs on one stream execute in
    /// submission order on one core (stream→core affinity), which is what
    /// makes `keep_data` chaining well-defined; `None` uses the legacy
    /// earliest-free-core placement.
    pub stream: Option<u64>,
    /// Cycle budget for the kernel run.
    pub max_cycles: u64,
}

impl Job {
    pub fn new(kernel: Kernel) -> Job {
        Job {
            kernel,
            loads: Vec::new(),
            unloads: Vec::new(),
            keep_data: false,
            stream: None,
            max_cycles: DEFAULT_CYCLE_BUDGET,
        }
    }

    pub fn load(mut self, base: usize, data: Vec<u32>) -> Job {
        self.loads.push((base, data));
        self
    }

    pub fn unload(mut self, base: usize, len: usize) -> Job {
        self.unloads.push((base, len));
        self
    }

    pub fn chained(mut self) -> Job {
        self.keep_data = true;
        self
    }

    /// Bind the job to a stream (ordered-per-stream, core affinity).
    pub fn on_stream(mut self, stream: u64) -> Job {
        self.stream = Some(stream);
        self
    }

    /// Override the default kernel cycle budget.
    pub fn budget(mut self, max_cycles: u64) -> Job {
        self.max_cycles = max_cycles;
        self
    }

    fn load_words(&self) -> usize {
        if self.keep_data {
            0
        } else {
            self.loads.iter().map(|(_, d)| d.len()).sum()
        }
    }

    fn unload_words(&self) -> usize {
        self.unloads.iter().map(|&(_, l)| l).sum()
    }
}

/// Completed-job record with its timeline on the shared bus + core.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub name: String,
    pub core: usize,
    /// Stream the job was submitted on, if any.
    pub stream: Option<u64>,
    /// Kernel cycles (the paper's core-performance metric).
    pub compute_cycles: u64,
    /// Bus cycles spent on load + unload DMA.
    pub bus_cycles: u64,
    /// Timeline: job start (bus acquisition) and end (unload complete).
    pub start: u64,
    pub end: u64,
    pub stats: RunStats,
    /// Unloaded blocks, in `unloads` order.
    pub outputs: Vec<Vec<u32>>,
}

/// Bus share of an end-to-end interval: `bus / (bus + compute)`, and 0
/// (not NaN) when both terms are zero. The single definition behind
/// [`JobResult::bus_overhead`] and the `api` accounting.
pub fn bus_fraction(bus_cycles: u64, compute_cycles: u64) -> f64 {
    let total = bus_cycles + compute_cycles;
    if total == 0 {
        return 0.0;
    }
    bus_cycles as f64 / total as f64
}

impl JobResult {
    /// Fraction of end-to-end time spent on the bus (§7's 4.7% claim).
    pub fn bus_overhead(&self) -> f64 {
        bus_fraction(self.bus_cycles, self.compute_cycles)
    }
}

/// Busy-interval calendar for the shared bus: reservations are placed in
/// the first gap large enough, never earlier than requested.
#[derive(Debug, Clone, Default)]
struct BusCalendar {
    /// Sorted, disjoint `(start, end)` reservations.
    busy: Vec<(u64, u64)>,
}

impl BusCalendar {
    /// Reserve `duration` cycles starting no earlier than `earliest`;
    /// returns the granted start cycle.
    fn reserve(&mut self, earliest: u64, duration: u64) -> u64 {
        if duration == 0 {
            return earliest;
        }
        let mut start = earliest;
        let mut at = 0usize;
        for (i, &(b, e)) in self.busy.iter().enumerate() {
            if start + duration <= b {
                at = i;
                break;
            }
            start = start.max(e);
            at = i + 1;
        }
        self.busy.insert(at, (start, start + duration));
        // Merge adjacent intervals to keep the calendar small.
        let mut merged: Vec<(u64, u64)> = Vec::with_capacity(self.busy.len());
        for &(b, e) in &self.busy {
            match merged.last_mut() {
                Some(last) if b <= last.1 => last.1 = last.1.max(e),
                _ => merged.push((b, e)),
            }
        }
        self.busy = merged;
        start
    }
}

/// N-core dispatcher with a single shared data bus.
pub struct Coordinator {
    cfg: EgpuConfig,
    bus: DataBus,
    cores: Vec<Machine>,
    /// Cycle at which each core finishes its current work.
    core_free: Vec<u64>,
    /// Shared-bus reservation calendar.
    bus_cal: BusCalendar,
    queue: Vec<Job>,
    /// Stream → core affinity (persists across `run_all` batches so a
    /// stream's data stays resident where it was placed).
    stream_core: HashMap<u64, usize>,
    /// Stream whose data is currently resident on each core (the stream
    /// of the last job dispatched there; `None` = an unordered job).
    /// Chained jobs must find their own stream's data still resident.
    core_resident: Vec<Option<u64>>,
    /// Core of the most recently dispatched job (legacy `keep_data`
    /// chaining for jobs without a stream).
    last_core: Option<usize>,
}

impl Coordinator {
    pub fn new(cfg: EgpuConfig, num_cores: usize) -> Result<Coordinator, SimError> {
        assert!(num_cores >= 1);
        let cores = (0..num_cores)
            .map(|_| Machine::new(cfg.clone()))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Coordinator {
            bus: DataBus::new(cfg.core_mhz()),
            core_free: vec![0; num_cores],
            bus_cal: BusCalendar::default(),
            queue: Vec::new(),
            stream_core: HashMap::new(),
            core_resident: vec![None; num_cores],
            last_core: None,
            cfg,
            cores,
        })
    }

    pub fn num_cores(&self) -> usize {
        self.cores.len()
    }

    pub fn config(&self) -> &EgpuConfig {
        &self.cfg
    }

    /// Queue a job (FIFO dispatch order).
    pub fn submit(&mut self, job: Job) {
        self.queue.push(job);
    }

    /// Dispatch every queued job: bus DMA serialized across cores,
    /// compute overlapped. Placement policy, in priority order:
    ///
    /// 1. A job on a stream that already owns a core goes to that core
    ///    (stream affinity — this is what makes `keep_data` chaining
    ///    well-defined). A *chained* stream job additionally requires its
    ///    stream's data to still be resident there — if other work has
    ///    since been placed on that core, dispatch errors rather than
    ///    silently computing on someone else's data.
    /// 2. A chained (`keep_data`) job without an affine core goes to the
    ///    core of the previously dispatched job; if there is no previous
    ///    job, that is an error (there is no resident data to chain onto
    ///    — previously this silently chained onto core 0).
    /// 3. Everything else goes to the earliest-free core.
    ///
    /// A chained job declaring input loads is an error: the loads would
    /// be silently skipped.
    pub fn run_all(&mut self) -> Result<Vec<JobResult>, SimError> {
        let jobs = std::mem::take(&mut self.queue);
        // Statically-checkable submission errors fail the whole batch
        // up front, before any job executes or reserves bus time. Only
        // data *eviction* (which depends on earliest-free placement of
        // other jobs) must be detected during dispatch.
        let mut known_streams: std::collections::HashSet<u64> =
            self.stream_core.keys().copied().collect();
        let mut any_prior = self.last_core.is_some();
        for job in &jobs {
            if job.keep_data {
                if !job.loads.is_empty() {
                    return Err(SimError {
                        pc: 0,
                        message: format!(
                            "job '{}' chains (keep_data) but also declares input loads; \
                             chained jobs reuse resident data and skip the load DMA",
                            job.kernel.name
                        ),
                    });
                }
                match job.stream {
                    Some(s) if !known_streams.contains(&s) => {
                        return Err(SimError {
                            pc: 0,
                            message: format!(
                                "job '{}' chains (keep_data) as the first job on \
                                 stream {s}: no resident data to chain onto",
                                job.kernel.name
                            ),
                        })
                    }
                    None if !any_prior => {
                        return Err(SimError {
                            pc: 0,
                            message: format!(
                                "job '{}' chains (keep_data) but no job has run \
                                 yet: no resident data to chain onto",
                                job.kernel.name
                            ),
                        })
                    }
                    _ => {}
                }
            }
            if let Some(s) = job.stream {
                known_streams.insert(s);
            }
            any_prior = true;
        }
        let mut results = Vec::with_capacity(jobs.len());
        for job in jobs {
            let affine = job.stream.and_then(|s| self.stream_core.get(&s).copied());
            let core = match affine {
                Some(c) => {
                    // Chaining requires the stream's data to still be
                    // resident: another stream (or an unordered job) may
                    // have been placed on this core since and cleared it.
                    if job.keep_data && self.core_resident[c] != job.stream {
                        return Err(SimError {
                            pc: 0,
                            message: format!(
                                "job '{}' chains (keep_data) on stream {}, but core {c} \
                                 has since run other work: the stream's resident data \
                                 is gone",
                                job.kernel.name,
                                job.stream.unwrap_or_default()
                            ),
                        });
                    }
                    c
                }
                // Backstop arms: the pre-validation above already rejects
                // these; kept so a placement bug degrades to an error,
                // not a silent wrong answer.
                None if job.keep_data => match (job.stream, self.last_core) {
                    (Some(s), _) => {
                        return Err(SimError {
                            pc: 0,
                            message: format!(
                                "job '{}' chains (keep_data) as the first job on \
                                 stream {s}: no resident data to chain onto",
                                job.kernel.name
                            ),
                        })
                    }
                    (None, Some(c)) => c,
                    (None, None) => {
                        return Err(SimError {
                            pc: 0,
                            message: format!(
                                "job '{}' chains (keep_data) but no job has run \
                                 yet: no resident data to chain onto",
                                job.kernel.name
                            ),
                        })
                    }
                },
                None => (0..self.cores.len())
                    .min_by_key(|&c| self.core_free[c])
                    .unwrap(),
            };
            if let Some(s) = job.stream {
                self.stream_core.insert(s, core);
            }
            self.last_core = Some(core);
            self.core_resident[core] = job.stream;
            let r = self.run_on(core, job)?;
            results.push(r);
        }
        Ok(results)
    }

    fn run_on(&mut self, core: usize, job: Job) -> Result<JobResult, SimError> {
        let prog = job
            .kernel
            .assemble(&self.cfg)
            .map_err(|msg| SimError { pc: 0, message: msg })?;
        let m = &mut self.cores[core];

        // Bus phase 1: load DMA (a reservation on the shared bus).
        let load_cycles = self.bus.transfer_cycles(job.load_words());
        let start = self.bus_cal.reserve(self.core_free[core], load_cycles);
        let compute_start = start + load_cycles;

        if !job.keep_data {
            m.shared_mut().fill(0);
        }
        m.load_program(prog)?;
        m.set_threads(job.kernel.threads)?;
        m.set_dim_x(job.kernel.dim_x)?;
        if !job.keep_data {
            for (base, data) in &job.loads {
                m.shared_mut().write_block(*base, data);
            }
        }
        let stats = m.run(job.max_cycles)?;

        // Bus phase 2: unload DMA.
        let unload_cycles = self.bus.transfer_cycles(job.unload_words());
        let compute_end = compute_start + stats.cycles;
        let unload_start = self.bus_cal.reserve(compute_end, unload_cycles);
        let end = unload_start + unload_cycles;
        self.core_free[core] = end;

        let outputs = job
            .unloads
            .iter()
            .map(|&(base, len)| m.shared().read_block(base, len).to_vec())
            .collect();
        Ok(JobResult {
            name: job.kernel.name.clone(),
            core,
            stream: job.stream,
            compute_cycles: stats.cycles,
            bus_cycles: load_cycles + unload_cycles,
            start,
            end,
            stats,
            outputs,
        })
    }

    /// Completion cycle of the last finishing core.
    pub fn makespan(&self) -> u64 {
        self.core_free.iter().copied().max().unwrap_or(0)
    }

    /// Makespan in microseconds at the configured core clock.
    pub fn makespan_us(&self) -> f64 {
        self.makespan() as f64 / self.cfg.core_mhz()
    }
}

/// Mean of overhead fractions; 0 on an empty set. Shared by
/// [`average_bus_overhead`] and [`crate::api::average_bus_overhead`].
pub(crate) fn mean_overhead(overheads: impl Iterator<Item = f64>) -> f64 {
    let (mut sum, mut n) = (0.0f64, 0usize);
    for v in overheads {
        sum += v;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

/// Unweighted mean of per-job bus overheads.
pub fn average_bus_overhead(results: &[JobResult]) -> f64 {
    mean_overhead(results.iter().map(JobResult::bus_overhead))
}

/// Time-weighted bus overhead: total bus cycles over total end-to-end
/// cycles. This is the §7 metric — "the performance impact was only 4.7%,
/// averaged over all benchmarks" — where long-running kernels (MMM)
/// dominate the aggregate and amortize their data movement.
pub fn aggregate_bus_overhead(results: &[JobResult]) -> f64 {
    let bus: u64 = results.iter().map(|r| r.bus_cycles).sum();
    let compute: u64 = results.iter().map(|r| r.compute_cycles).sum();
    if bus + compute == 0 {
        return 0.0;
    }
    bus as f64 / (bus + compute) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{f32_bits, reduction};
    use crate::sim::config::MemoryMode;

    fn job(n: usize) -> Job {
        let data: Vec<f32> = (0..n).map(|i| i as f32 * 0.25).collect();
        Job::new(reduction::reduction(n))
            .load(0, f32_bits(&data))
            .unload(n, 1)
    }

    fn cfg() -> EgpuConfig {
        EgpuConfig::benchmark(MemoryMode::Dp, false)
    }

    #[test]
    fn single_core_runs_jobs() {
        let mut c = Coordinator::new(cfg(), 1).unwrap();
        c.submit(job(32));
        c.submit(job(64));
        let rs = c.run_all().unwrap();
        assert_eq!(rs.len(), 2);
        for (r, n) in rs.iter().zip([32usize, 64]) {
            let got = f32::from_bits(r.outputs[0][0]);
            let want: f32 = (0..n).map(|i| i as f32 * 0.25).sum();
            assert!((got - want).abs() < 1e-2, "{}: {got} vs {want}", r.name);
            assert_eq!(r.core, 0);
        }
        // FIFO on one core: the second job starts after the first ends.
        assert!(rs[1].start >= rs[0].end);
    }

    #[test]
    fn multi_core_overlaps_compute() {
        // Bus-bound jobs (reduction: ~129 bus vs ~287 compute cycles)
        // overlap partially; the serialized bus bounds the speedup.
        let mut one = Coordinator::new(cfg(), 1).unwrap();
        let mut four = Coordinator::new(cfg(), 4).unwrap();
        for c in [&mut one, &mut four] {
            for _ in 0..4 {
                c.submit(job(128));
            }
            c.run_all().unwrap();
        }
        assert!(
            four.makespan() < one.makespan(),
            "4 cores {} vs 1 core {}",
            four.makespan(),
            one.makespan()
        );
        assert!(four.makespan() > one.makespan() / 4);
    }

    #[test]
    fn compute_heavy_jobs_scale_nearly_linearly() {
        use crate::kernels::fft;
        let n = 128;
        let re: Vec<f32> = (0..n).map(|i| (i as f32 * 0.1).sin()).collect();
        let im = vec![0f32; n];
        let mk_job = || {
            let mut j = Job::new(fft::fft(n)).unload(0, 2 * n);
            for (base, data) in fft::shared_init(&re, &im) {
                j = j.load(base, data);
            }
            j
        };
        let mut one = Coordinator::new(cfg(), 1).unwrap();
        let mut four = Coordinator::new(cfg(), 4).unwrap();
        for c in [&mut one, &mut four] {
            for _ in 0..4 {
                c.submit(mk_job());
            }
            c.run_all().unwrap();
        }
        // FFT-128: ~3.5k compute vs ~0.7k bus cycles → near-4x overlap.
        assert!(
            four.makespan() * 2 < one.makespan(),
            "4 cores {} vs 1 core {}",
            four.makespan(),
            one.makespan()
        );
    }

    #[test]
    fn chained_jobs_skip_bus_and_stay_on_core() {
        // Transpose reads [0, n²) without mutating it, so a chained
        // second transpose sees the data the first job loaded.
        use crate::kernels::transpose;
        let n = 32;
        let data: Vec<u32> = (0..(n * n) as u32).collect();
        let mut c = Coordinator::new(cfg(), 4).unwrap();
        c.submit(Job::new(transpose::transpose(n)).load(0, data.clone()));
        c.submit(Job::new(transpose::transpose(n)).unload(n * n, n * n).chained());
        let rs = c.run_all().unwrap();
        assert_eq!(rs[0].core, rs[1].core, "chained job must stay on core");
        assert_eq!(rs[1].bus_cycles, (n * n) as u64, "only the unload DMA");
        assert_eq!(rs[1].outputs[0], transpose::oracle(&data, n));
    }

    #[test]
    fn bus_overhead_small_for_compute_heavy_jobs() {
        let mut c = Coordinator::new(cfg(), 1).unwrap();
        c.submit(job(128));
        let rs = c.run_all().unwrap();
        // 129 bus words vs ~230 compute cycles: meaningful but bounded.
        let o = rs[0].bus_overhead();
        assert!((0.01..0.6).contains(&o), "overhead {o}");
    }

    #[test]
    fn fresh_jobs_clear_shared_memory() {
        let n = 32;
        let mut c = Coordinator::new(cfg(), 1).unwrap();
        c.submit(job(n));
        // Second job loads zeros; result must be 0, not stale data.
        c.submit(
            Job::new(reduction::reduction(n))
                .load(0, vec![0u32; n])
                .unload(n, 1),
        );
        let rs = c.run_all().unwrap();
        assert_eq!(f32::from_bits(rs[1].outputs[0][0]), 0.0);
    }

    #[test]
    fn makespan_tracks_cycles() {
        let mut c = Coordinator::new(cfg(), 2).unwrap();
        assert_eq!(c.makespan(), 0);
        c.submit(job(32));
        c.run_all().unwrap();
        assert!(c.makespan() > 0);
        assert!(c.makespan_us() > 0.0);
    }

    #[test]
    fn bus_overhead_of_zero_cycle_job_is_zero_not_nan() {
        // Regression: bus_cycles + compute_cycles == 0 divided by zero.
        let r = JobResult {
            name: "empty".into(),
            core: 0,
            stream: None,
            compute_cycles: 0,
            bus_cycles: 0,
            start: 0,
            end: 0,
            stats: RunStats {
                cycles: 0,
                instructions: 0,
                profile: crate::sim::Profile::new(),
                hazards: 0,
                hazard_samples: Vec::new(),
            },
            outputs: Vec::new(),
        };
        assert_eq!(r.bus_overhead(), 0.0);
        assert_eq!(average_bus_overhead(&[r]), 0.0);
    }

    #[test]
    fn first_chained_job_is_an_error_not_core0() {
        // Regression: a first-submitted keep_data job used to silently
        // chain onto core 0 with no resident data.
        let mut c = Coordinator::new(cfg(), 2).unwrap();
        c.submit(Job::new(reduction::reduction(32)).chained());
        let err = c.run_all().unwrap_err();
        assert!(err.message.contains("no resident data"), "{err}");
        // The coordinator stays usable.
        c.submit(job(32));
        assert_eq!(c.run_all().unwrap().len(), 1);
    }

    #[test]
    fn first_chained_job_on_a_stream_is_an_error() {
        let mut c = Coordinator::new(cfg(), 2).unwrap();
        c.submit(job(32).on_stream(7));
        c.run_all().unwrap();
        // Stream 9 has never run: chaining onto it must fail even though
        // stream 7 has resident data.
        c.submit(Job::new(reduction::reduction(32)).on_stream(9).chained());
        let err = c.run_all().unwrap_err();
        assert!(err.message.contains("stream 9"), "{err}");
    }

    #[test]
    fn stream_affinity_pins_jobs_to_one_core() {
        let mut c = Coordinator::new(cfg(), 4).unwrap();
        for _ in 0..3 {
            c.submit(job(32).on_stream(1));
        }
        let rs = c.run_all().unwrap();
        assert!(rs.iter().all(|r| r.core == rs[0].core), "stream hops cores");
        assert!(rs.iter().all(|r| r.stream == Some(1)));
        // Ordered per stream: each job starts at or after the previous end.
        assert!(rs.windows(2).all(|w| w[1].start >= w[0].end));
    }

    #[test]
    fn stream_affinity_survives_run_all_batches() {
        let mut c = Coordinator::new(cfg(), 4).unwrap();
        c.submit(job(32).on_stream(3));
        let first = c.run_all().unwrap();
        // A later batch chains onto the stream's resident data: same core,
        // no load DMA.
        use crate::kernels::transpose;
        let n = 32;
        let data: Vec<u32> = (0..(n * n) as u32).collect();
        c.submit(Job::new(transpose::transpose(n)).load(0, data).on_stream(3));
        c.submit(
            Job::new(transpose::transpose(n))
                .unload(n * n, n * n)
                .on_stream(3)
                .chained(),
        );
        let rs = c.run_all().unwrap();
        assert_eq!(rs[0].core, first[0].core);
        assert_eq!(rs[1].core, first[0].core);
        assert_eq!(rs[1].bus_cycles, (n * n) as u64, "chained: unload DMA only");
    }

    #[test]
    fn chained_job_errors_when_stream_data_evicted() {
        // Streams outnumber cores: stream 2's fresh job lands on stream
        // 0's core (earliest free) and clears it. Chaining on stream 0
        // afterwards must error, not silently compute on stream 2's data.
        let mut c = Coordinator::new(cfg(), 2).unwrap();
        c.submit(job(32).on_stream(0));
        c.submit(job(32).on_stream(1));
        c.submit(job(32).on_stream(2));
        let rs = c.run_all().unwrap();
        assert_eq!(rs[0].core, rs[2].core, "stream 2 evicts stream 0");
        c.submit(Job::new(reduction::reduction(32)).on_stream(0).chained());
        let err = c.run_all().unwrap_err();
        assert!(err.message.contains("resident data is gone"), "{err}");
    }

    #[test]
    fn chained_job_with_input_loads_is_rejected_before_anything_runs() {
        // The load DMA of a keep_data job would be silently skipped;
        // declaring both fails the batch up front — the earlier valid
        // job must not have half-executed.
        let mut c = Coordinator::new(cfg(), 1).unwrap();
        c.submit(job(32));
        c.submit(job(32).chained());
        let err = c.run_all().unwrap_err();
        assert!(err.message.contains("input loads"), "{err}");
        assert_eq!(c.makespan(), 0, "no job may execute on a rejected batch");
    }

    #[test]
    fn job_budget_bounds_the_run() {
        let mut c = Coordinator::new(cfg(), 1).unwrap();
        c.submit(job(128).budget(10));
        let err = c.run_all().unwrap_err();
        assert!(err.message.contains("cycle limit"), "{err}");
    }
}
