//! Multi-core job dispatch and the external 32-bit data bus.
//!
//! The eGPU "has a single local data memory ... the loading and unloading
//! of which has to be managed externally" (§2), over a 32-bit bus whose
//! cost the paper quantifies: "we also ran all of our benchmarks taking
//! into account the time to load and unload the data over the 32-bit wide
//! data bus. The performance impact was only 4.7%, averaged over all
//! benchmarks" (§7). And "the eGPU only uses 1%-2% of a current mid-range
//! device ... even if multiple cores are required" (§8).
//!
//! This module is that external manager: a [`Coordinator`] owning N eGPU
//! cores, dispatching queued [`Job`]s to the earliest-free core, and
//! serializing shared-memory load/unload DMA over one [`DataBus`]. Chained
//! jobs (`keep_data`) skip the bus entirely — the paper's "multiple
//! algorithms to the same data" mode.

use crate::kernels::Kernel;
use crate::sim::config::EgpuConfig;
use crate::sim::{Machine, RunStats, SimError};

/// The external 32-bit data bus: one 32-bit word per bus cycle, clocked at
/// the core frequency (§7 measures load/unload at the core clock).
#[derive(Debug, Clone, Copy)]
pub struct DataBus {
    pub mhz: f64,
}

impl DataBus {
    pub fn new(mhz: f64) -> DataBus {
        DataBus { mhz }
    }

    /// Cycles to move `words` 32-bit words.
    pub fn transfer_cycles(&self, words: usize) -> u64 {
        words as u64
    }
}

/// One unit of work: a kernel plus its data movement.
#[derive(Debug, Clone)]
pub struct Job {
    pub kernel: Kernel,
    /// Blocks DMA'd into shared memory before the run.
    pub loads: Vec<(usize, Vec<u32>)>,
    /// `(base, len)` blocks DMA'd out after the run.
    pub unloads: Vec<(usize, usize)>,
    /// Chain onto the previous job's shared memory: skip the load DMA and
    /// do not clear shared memory (§7: "there is no loading and unloading
    /// of data between different algorithms").
    pub keep_data: bool,
}

impl Job {
    pub fn new(kernel: Kernel) -> Job {
        Job {
            kernel,
            loads: Vec::new(),
            unloads: Vec::new(),
            keep_data: false,
        }
    }

    pub fn load(mut self, base: usize, data: Vec<u32>) -> Job {
        self.loads.push((base, data));
        self
    }

    pub fn unload(mut self, base: usize, len: usize) -> Job {
        self.unloads.push((base, len));
        self
    }

    pub fn chained(mut self) -> Job {
        self.keep_data = true;
        self
    }

    fn load_words(&self) -> usize {
        if self.keep_data {
            0
        } else {
            self.loads.iter().map(|(_, d)| d.len()).sum()
        }
    }

    fn unload_words(&self) -> usize {
        self.unloads.iter().map(|&(_, l)| l).sum()
    }
}

/// Completed-job record with its timeline on the shared bus + core.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub name: String,
    pub core: usize,
    /// Kernel cycles (the paper's core-performance metric).
    pub compute_cycles: u64,
    /// Bus cycles spent on load + unload DMA.
    pub bus_cycles: u64,
    /// Timeline: job start (bus acquisition) and end (unload complete).
    pub start: u64,
    pub end: u64,
    pub stats: RunStats,
    /// Unloaded blocks, in `unloads` order.
    pub outputs: Vec<Vec<u32>>,
}

impl JobResult {
    /// Fraction of end-to-end time spent on the bus (§7's 4.7% claim).
    pub fn bus_overhead(&self) -> f64 {
        self.bus_cycles as f64 / (self.bus_cycles + self.compute_cycles) as f64
    }
}

/// Busy-interval calendar for the shared bus: reservations are placed in
/// the first gap large enough, never earlier than requested.
#[derive(Debug, Clone, Default)]
struct BusCalendar {
    /// Sorted, disjoint `(start, end)` reservations.
    busy: Vec<(u64, u64)>,
}

impl BusCalendar {
    /// Reserve `duration` cycles starting no earlier than `earliest`;
    /// returns the granted start cycle.
    fn reserve(&mut self, earliest: u64, duration: u64) -> u64 {
        if duration == 0 {
            return earliest;
        }
        let mut start = earliest;
        let mut at = 0usize;
        for (i, &(b, e)) in self.busy.iter().enumerate() {
            if start + duration <= b {
                at = i;
                break;
            }
            start = start.max(e);
            at = i + 1;
        }
        self.busy.insert(at, (start, start + duration));
        // Merge adjacent intervals to keep the calendar small.
        let mut merged: Vec<(u64, u64)> = Vec::with_capacity(self.busy.len());
        for &(b, e) in &self.busy {
            match merged.last_mut() {
                Some(last) if b <= last.1 => last.1 = last.1.max(e),
                _ => merged.push((b, e)),
            }
        }
        self.busy = merged;
        start
    }
}

/// N-core dispatcher with a single shared data bus.
pub struct Coordinator {
    cfg: EgpuConfig,
    bus: DataBus,
    cores: Vec<Machine>,
    /// Cycle at which each core finishes its current work.
    core_free: Vec<u64>,
    /// Shared-bus reservation calendar.
    bus_cal: BusCalendar,
    queue: Vec<Job>,
}

impl Coordinator {
    pub fn new(cfg: EgpuConfig, num_cores: usize) -> Result<Coordinator, SimError> {
        assert!(num_cores >= 1);
        let cores = (0..num_cores)
            .map(|_| Machine::new(cfg.clone()))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Coordinator {
            bus: DataBus::new(cfg.core_mhz()),
            core_free: vec![0; num_cores],
            bus_cal: BusCalendar::default(),
            queue: Vec::new(),
            cfg,
            cores,
        })
    }

    pub fn num_cores(&self) -> usize {
        self.cores.len()
    }

    pub fn config(&self) -> &EgpuConfig {
        &self.cfg
    }

    /// Queue a job (FIFO dispatch order).
    pub fn submit(&mut self, job: Job) {
        self.queue.push(job);
    }

    /// Dispatch every queued job: earliest-free-core policy, bus DMA
    /// serialized across cores, compute overlapped. Chained jobs must run
    /// on the core holding their data, so they go to the same core as the
    /// previous job.
    pub fn run_all(&mut self) -> Result<Vec<JobResult>, SimError> {
        let mut results = Vec::with_capacity(self.queue.len());
        let jobs = std::mem::take(&mut self.queue);
        let mut last_core = 0usize;
        for job in jobs {
            let core = if job.keep_data {
                last_core
            } else {
                (0..self.cores.len())
                    .min_by_key(|&c| self.core_free[c])
                    .unwrap()
            };
            last_core = core;
            let r = self.run_on(core, job)?;
            results.push(r);
        }
        Ok(results)
    }

    fn run_on(&mut self, core: usize, job: Job) -> Result<JobResult, SimError> {
        let prog = job
            .kernel
            .assemble(&self.cfg)
            .map_err(|msg| SimError { pc: 0, message: msg })?;
        let m = &mut self.cores[core];

        // Bus phase 1: load DMA (a reservation on the shared bus).
        let load_cycles = self.bus.transfer_cycles(job.load_words());
        let start = self.bus_cal.reserve(self.core_free[core], load_cycles);
        let compute_start = start + load_cycles;

        if !job.keep_data {
            m.shared_mut().fill(0);
        }
        m.load_program(prog)?;
        m.set_threads(job.kernel.threads)?;
        m.set_dim_x(job.kernel.dim_x)?;
        if !job.keep_data {
            for (base, data) in &job.loads {
                m.shared_mut().write_block(*base, data);
            }
        }
        let stats = m.run(10_000_000_000)?;

        // Bus phase 2: unload DMA.
        let unload_cycles = self.bus.transfer_cycles(job.unload_words());
        let compute_end = compute_start + stats.cycles;
        let unload_start = self.bus_cal.reserve(compute_end, unload_cycles);
        let end = unload_start + unload_cycles;
        self.core_free[core] = end;

        let outputs = job
            .unloads
            .iter()
            .map(|&(base, len)| m.shared().read_block(base, len).to_vec())
            .collect();
        Ok(JobResult {
            name: job.kernel.name.clone(),
            core,
            compute_cycles: stats.cycles,
            bus_cycles: load_cycles + unload_cycles,
            start,
            end,
            stats,
            outputs,
        })
    }

    /// Completion cycle of the last finishing core.
    pub fn makespan(&self) -> u64 {
        self.core_free.iter().copied().max().unwrap_or(0)
    }

    /// Makespan in microseconds at the configured core clock.
    pub fn makespan_us(&self) -> f64 {
        self.makespan() as f64 / self.cfg.core_mhz()
    }
}

/// Unweighted mean of per-job bus overheads.
pub fn average_bus_overhead(results: &[JobResult]) -> f64 {
    if results.is_empty() {
        return 0.0;
    }
    results.iter().map(JobResult::bus_overhead).sum::<f64>() / results.len() as f64
}

/// Time-weighted bus overhead: total bus cycles over total end-to-end
/// cycles. This is the §7 metric — "the performance impact was only 4.7%,
/// averaged over all benchmarks" — where long-running kernels (MMM)
/// dominate the aggregate and amortize their data movement.
pub fn aggregate_bus_overhead(results: &[JobResult]) -> f64 {
    let bus: u64 = results.iter().map(|r| r.bus_cycles).sum();
    let compute: u64 = results.iter().map(|r| r.compute_cycles).sum();
    if bus + compute == 0 {
        return 0.0;
    }
    bus as f64 / (bus + compute) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{f32_bits, reduction};
    use crate::sim::config::MemoryMode;

    fn job(n: usize) -> Job {
        let data: Vec<f32> = (0..n).map(|i| i as f32 * 0.25).collect();
        Job::new(reduction::reduction(n))
            .load(0, f32_bits(&data))
            .unload(n, 1)
    }

    fn cfg() -> EgpuConfig {
        EgpuConfig::benchmark(MemoryMode::Dp, false)
    }

    #[test]
    fn single_core_runs_jobs() {
        let mut c = Coordinator::new(cfg(), 1).unwrap();
        c.submit(job(32));
        c.submit(job(64));
        let rs = c.run_all().unwrap();
        assert_eq!(rs.len(), 2);
        for (r, n) in rs.iter().zip([32usize, 64]) {
            let got = f32::from_bits(r.outputs[0][0]);
            let want: f32 = (0..n).map(|i| i as f32 * 0.25).sum();
            assert!((got - want).abs() < 1e-2, "{}: {got} vs {want}", r.name);
            assert_eq!(r.core, 0);
        }
        // FIFO on one core: the second job starts after the first ends.
        assert!(rs[1].start >= rs[0].end);
    }

    #[test]
    fn multi_core_overlaps_compute() {
        // Bus-bound jobs (reduction: ~129 bus vs ~287 compute cycles)
        // overlap partially; the serialized bus bounds the speedup.
        let mut one = Coordinator::new(cfg(), 1).unwrap();
        let mut four = Coordinator::new(cfg(), 4).unwrap();
        for c in [&mut one, &mut four] {
            for _ in 0..4 {
                c.submit(job(128));
            }
            c.run_all().unwrap();
        }
        assert!(
            four.makespan() < one.makespan(),
            "4 cores {} vs 1 core {}",
            four.makespan(),
            one.makespan()
        );
        assert!(four.makespan() > one.makespan() / 4);
    }

    #[test]
    fn compute_heavy_jobs_scale_nearly_linearly() {
        use crate::kernels::fft;
        let n = 128;
        let re: Vec<f32> = (0..n).map(|i| (i as f32 * 0.1).sin()).collect();
        let im = vec![0f32; n];
        let mk_job = || {
            let mut j = Job::new(fft::fft(n)).unload(0, 2 * n);
            for (base, data) in fft::shared_init(&re, &im) {
                j = j.load(base, data);
            }
            j
        };
        let mut one = Coordinator::new(cfg(), 1).unwrap();
        let mut four = Coordinator::new(cfg(), 4).unwrap();
        for c in [&mut one, &mut four] {
            for _ in 0..4 {
                c.submit(mk_job());
            }
            c.run_all().unwrap();
        }
        // FFT-128: ~3.5k compute vs ~0.7k bus cycles → near-4x overlap.
        assert!(
            four.makespan() * 2 < one.makespan(),
            "4 cores {} vs 1 core {}",
            four.makespan(),
            one.makespan()
        );
    }

    #[test]
    fn chained_jobs_skip_bus_and_stay_on_core() {
        // Transpose reads [0, n²) without mutating it, so a chained
        // second transpose sees the data the first job loaded.
        use crate::kernels::transpose;
        let n = 32;
        let data: Vec<u32> = (0..(n * n) as u32).collect();
        let mut c = Coordinator::new(cfg(), 4).unwrap();
        c.submit(Job::new(transpose::transpose(n)).load(0, data.clone()));
        c.submit(Job::new(transpose::transpose(n)).unload(n * n, n * n).chained());
        let rs = c.run_all().unwrap();
        assert_eq!(rs[0].core, rs[1].core, "chained job must stay on core");
        assert_eq!(rs[1].bus_cycles, (n * n) as u64, "only the unload DMA");
        assert_eq!(rs[1].outputs[0], transpose::oracle(&data, n));
    }

    #[test]
    fn bus_overhead_small_for_compute_heavy_jobs() {
        let mut c = Coordinator::new(cfg(), 1).unwrap();
        c.submit(job(128));
        let rs = c.run_all().unwrap();
        // 129 bus words vs ~230 compute cycles: meaningful but bounded.
        let o = rs[0].bus_overhead();
        assert!((0.01..0.6).contains(&o), "overhead {o}");
    }

    #[test]
    fn fresh_jobs_clear_shared_memory() {
        let n = 32;
        let mut c = Coordinator::new(cfg(), 1).unwrap();
        c.submit(job(n));
        // Second job loads zeros; result must be 0, not stale data.
        c.submit(
            Job::new(reduction::reduction(n))
                .load(0, vec![0u32; n])
                .unload(n, 1),
        );
        let rs = c.run_all().unwrap();
        assert_eq!(f32::from_bits(rs[1].outputs[0][0]), 0.0);
    }

    #[test]
    fn makespan_tracks_cycles() {
        let mut c = Coordinator::new(cfg(), 2).unwrap();
        assert_eq!(c.makespan(), 0);
        c.submit(job(32));
        c.run_all().unwrap();
        assert!(c.makespan() > 0);
        assert!(c.makespan_us() > 0.0);
    }
}
