//! Multi-core job dispatch and the external 32-bit data bus.
//!
//! The eGPU "has a single local data memory ... the loading and unloading
//! of which has to be managed externally" (§2), over a 32-bit bus whose
//! cost the paper quantifies: "we also ran all of our benchmarks taking
//! into account the time to load and unload the data over the 32-bit wide
//! data bus. The performance impact was only 4.7%, averaged over all
//! benchmarks" (§7). And "the eGPU only uses 1%-2% of a current mid-range
//! device ... even if multiple cores are required" (§8).
//!
//! This module is that external manager: a [`Coordinator`] owning a
//! *fleet* of eGPU cores — each with its **own** [`EgpuConfig`], the
//! paper's static-scalability story deployed (Tables 4/5 describe many
//! differently-configured instances coexisting on one fabric) —
//! dispatching queued [`Job`]s and serializing shared-memory
//! load/unload DMA over one [`DataBus`]. Chained jobs (`keep_data`)
//! skip the bus entirely — the paper's "multiple algorithms to the same
//! data" mode.
//!
//! # Heterogeneous fleets
//!
//! Each job derives a [`FeatureSet`] requirement from its program
//! ([`Job::requires`]) and is only placed on cores whose configuration
//! [`satisfies`](EgpuConfig::satisfies) it: a predicated sort never
//! lands on a `predicate_levels == 0` core, a DOT kernel only on a
//! dot-core instance. Cores run at different clocks (771 MHz DP vs
//! 600 MHz QP, §6), so the modeled timeline is kept in cycles of the
//! shared **bus clock** (the fastest core's clock — identical to the
//! core clock on a homogeneous fleet, which keeps every homogeneous
//! timeline bit-identical to the historical single-config coordinator).
//! A core's compute cycles are converted onto that timeline with exact
//! integer (kHz-ratio, round-up) arithmetic, and earliest-completion
//! placement compares *wall-clock* scores — a free 771 MHz DP core
//! outbids a free 600 MHz QP core for the same kernel.
//!
//! Jobs submitted as [`KernelSpec`]s are specialized to their placed
//! core's configuration through a shared [`KernelCache`]: one
//! compile-and-schedule per `(generator, dim, fingerprint)` across the
//! fleet's lifetime, however many streams resubmit the kernel.
//!
//! # Parallel dispatch
//!
//! On a multi-core coordinator the cores *simulate* in parallel: each core
//! has a resident worker thread in a [`pool::CorePool`] — spawned once,
//! on the coordinator's first parallel batch, and reused by every
//! subsequent `run_all` call and serve window — running its job sequence
//! in dispatch order, while the *modeled* timeline — bus reservations,
//! core free times, `JobResult` start/end — is replayed sequentially in
//! submission order on the dispatching thread. The simulated-cycle
//! accounting is therefore bit-identical to the sequential reference path
//! (`set_parallel(false)`), which `rust/tests/coordinator_integration.rs`
//! asserts; only wall-clock time changes. Placement of unordered jobs
//! needs eventual core-free times, so the dispatcher only commits an
//! earliest-free choice once it is provable from accounted jobs plus a
//! lower bound on outstanding ones, waiting for workers otherwise.

mod pool;

use std::collections::HashMap;
use std::sync::Arc;

use crate::asm::Program;
use crate::kernels::{Kernel, KernelCache, KernelSpec};
use crate::model::frequency::modeled_core_khz;
use crate::obs::{EventKind, Recorder, StatsSnapshot};
use crate::sim::config::{EgpuConfig, FeatureSet};
use crate::sim::{
    Machine, RunStats, SimError, SuperplanActivity, SuperplanCacheStats, PIPELINE_DEPTH,
};

/// Default kernel cycle budget: bounds runaway programs without ever
/// tripping on a real workload (the largest paper kernel, MMM-128, runs
/// ~2.3M cycles). [`crate::api::LaunchBuilder::max_cycles`] and
/// [`Job::budget`] override it.
pub const DEFAULT_CYCLE_BUDGET: u64 = 10_000_000_000;

/// Lower bound on any successful job's end-to-end cycles: even an empty
/// program issues STOP (1 cycle) and drains the pipeline. Used to prove
/// earliest-free placements before every outstanding job is accounted.
/// Core cycles; convert per core with [`to_bus_cycles`].
const MIN_JOB_CYCLES: u64 = 1 + PIPELINE_DEPTH;

/// Convert a core-clock cycle count onto the shared bus timeline
/// (round-up, exact integer arithmetic over kHz so heterogeneous
/// accounting is deterministic). Identity when the clocks match — the
/// homogeneous case stays bit-identical to the historical
/// single-clock timeline.
fn to_bus_cycles(cycles: u64, core_khz: u64, bus_khz: u64) -> u64 {
    if core_khz == bus_khz {
        return cycles;
    }
    (cycles as u128 * bus_khz as u128).div_ceil(core_khz as u128) as u64
}

/// The external 32-bit data bus: one 32-bit word per bus cycle, clocked at
/// the core frequency (§7 measures load/unload at the core clock).
#[derive(Debug, Clone, Copy)]
pub struct DataBus {
    pub mhz: f64,
}

impl DataBus {
    pub fn new(mhz: f64) -> DataBus {
        DataBus { mhz }
    }

    /// Cycles to move `words` 32-bit words.
    pub fn transfer_cycles(&self, words: usize) -> u64 {
        words as u64
    }
}

/// One unit of work: a kernel plus its data movement.
#[derive(Debug, Clone)]
pub struct Job {
    /// The kernel to run (shared, so cache-served kernels are a
    /// refcount bump per job, not a deep copy of the compiled program).
    /// For spec-submitted jobs this is the *reference* build (used for
    /// naming, thread shape and requirement extraction); the dispatcher
    /// re-specializes it to the placed core's configuration through the
    /// [`KernelCache`].
    pub kernel: Arc<Kernel>,
    /// Present when the job was submitted as a [`KernelSpec`]: the
    /// dispatcher then compiles per placed-core fingerprint (cached)
    /// instead of running the prebuilt kernel everywhere.
    pub spec: Option<KernelSpec>,
    /// Blocks DMA'd into shared memory before the run.
    pub loads: Vec<(usize, Vec<u32>)>,
    /// `(base, len)` blocks DMA'd out after the run.
    pub unloads: Vec<(usize, usize)>,
    /// Chain onto the previous job's shared memory: skip the load DMA and
    /// do not clear shared memory (§7: "there is no loading and unloading
    /// of data between different algorithms").
    pub keep_data: bool,
    /// Stream this job belongs to. Jobs on one stream execute in
    /// submission order on one core (stream→core affinity), which is what
    /// makes `keep_data` chaining well-defined; `None` uses the legacy
    /// earliest-free-core placement.
    pub stream: Option<u64>,
    /// Cycle budget for the kernel run.
    pub max_cycles: u64,
    /// Test hook: panic inside job execution instead of running it, so
    /// the poison/revive paths can be exercised without a kernel that
    /// defeats the validation layers. Never set outside tests.
    #[doc(hidden)]
    pub panic_for_test: bool,
}

impl Job {
    pub fn new(kernel: Kernel) -> Job {
        Job::new_shared(Arc::new(kernel))
    }

    /// [`Job::new`] over an already-shared kernel (no copy).
    pub fn new_shared(kernel: Arc<Kernel>) -> Job {
        Job {
            kernel,
            spec: None,
            loads: Vec::new(),
            unloads: Vec::new(),
            keep_data: false,
            stream: None,
            max_cycles: DEFAULT_CYCLE_BUDGET,
            panic_for_test: false,
        }
    }

    /// A job from a kernel *specification*: a reference build (compiled
    /// through `cache` against `reference` — dispatchers pass their own
    /// first core, so the compile is reused, not wasted) supplies the
    /// name, thread shape, requirements and placement estimate; dispatch
    /// re-specializes per placed core. This is the entry point that
    /// makes a mixed DP/QP fleet run per-config schedules.
    pub fn from_spec(
        spec: KernelSpec,
        cache: &KernelCache,
        reference: &EgpuConfig,
    ) -> Result<Job, SimError> {
        let kernel = cache.get(&spec, reference).map_err(|m| SimError::new(0, m))?;
        let mut job = Job::new_shared(kernel);
        job.spec = Some(spec);
        Ok(job)
    }

    /// What this job demands of a core: the kernel's feature
    /// requirements plus the DMA footprint (the shared-memory words its
    /// loads and unloads touch). The dispatcher only places the job on
    /// cores whose [`EgpuConfig::satisfies`] answers yes; the same
    /// value is surfaced on [`JobResult::requires`] for observability.
    pub fn requires(&self) -> FeatureSet {
        let mut req = self.kernel.requirements();
        for (base, data) in &self.loads {
            req.min_shared_words = req.min_shared_words.max(base + data.len());
        }
        for &(base, len) in &self.unloads {
            req.min_shared_words = req.min_shared_words.max(base + len);
        }
        req
    }

    /// Static compute-cycle estimate used for wall-clock-aware
    /// placement (compiled kernels carry their schedule's straight-line
    /// cycle count; hand-written assembly estimates 0 and degrades to
    /// earliest-free placement). Never used for accounting — only for
    /// choosing among eligible cores.
    fn est_compute_cycles(&self) -> u64 {
        self.kernel
            .sched
            .as_ref()
            .map(|s| s.static_cycles_emitted())
            .unwrap_or(0)
    }

    pub fn load(mut self, base: usize, data: Vec<u32>) -> Job {
        self.loads.push((base, data));
        self
    }

    pub fn unload(mut self, base: usize, len: usize) -> Job {
        self.unloads.push((base, len));
        self
    }

    pub fn chained(mut self) -> Job {
        self.keep_data = true;
        self
    }

    /// Bind the job to a stream (ordered-per-stream, core affinity).
    pub fn on_stream(mut self, stream: u64) -> Job {
        self.stream = Some(stream);
        self
    }

    /// Override the default kernel cycle budget.
    pub fn budget(mut self, max_cycles: u64) -> Job {
        self.max_cycles = max_cycles;
        self
    }

    /// Test hook: make this job panic at execution time (see
    /// [`Job::panic_for_test`]).
    #[doc(hidden)]
    pub fn inject_panic(mut self) -> Job {
        self.panic_for_test = true;
        self
    }

    fn load_words(&self) -> usize {
        if self.keep_data {
            0
        } else {
            self.loads.iter().map(|(_, d)| d.len()).sum()
        }
    }

    fn unload_words(&self) -> usize {
        self.unloads.iter().map(|&(_, l)| l).sum()
    }
}

/// Completed-job record with its timeline on the shared bus + core.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub name: String,
    pub core: usize,
    /// Stream the job was submitted on, if any.
    pub stream: Option<u64>,
    /// The requirement the dispatcher routed on ([`Job::requires`]).
    pub requires: FeatureSet,
    /// Kernel cycles at the *core's* clock (the paper's
    /// core-performance metric).
    pub compute_cycles: u64,
    /// Bus cycles spent on load + unload DMA.
    pub bus_cycles: u64,
    /// Timeline on the shared bus clock: job start (bus acquisition)
    /// and end (unload complete). On a homogeneous fleet the bus clock
    /// is the core clock, so these are plain core cycles as before.
    pub start: u64,
    pub end: u64,
    pub stats: RunStats,
    /// Unloaded blocks, in `unloads` order.
    pub outputs: Vec<Vec<u32>>,
}

/// Bus share of an end-to-end interval: `bus / (bus + compute)`, and 0
/// (not NaN) when both terms are zero. The single definition behind
/// [`JobResult::bus_overhead`] and the `api` accounting.
pub fn bus_fraction(bus_cycles: u64, compute_cycles: u64) -> f64 {
    let total = bus_cycles + compute_cycles;
    if total == 0 {
        return 0.0;
    }
    bus_cycles as f64 / total as f64
}

impl JobResult {
    /// Fraction of end-to-end time spent on the bus (§7's 4.7% claim).
    pub fn bus_overhead(&self) -> f64 {
        bus_fraction(self.bus_cycles, self.compute_cycles)
    }
}

/// Busy-interval calendar for the shared bus: reservations are placed in
/// the first gap large enough, never earlier than requested.
#[derive(Debug, Clone, Default)]
struct BusCalendar {
    /// Sorted, disjoint `(start, end)` reservations.
    busy: Vec<(u64, u64)>,
}

impl BusCalendar {
    /// Reserve `duration` cycles starting no earlier than `earliest`;
    /// returns the granted start cycle.
    fn reserve(&mut self, earliest: u64, duration: u64) -> u64 {
        if duration == 0 {
            return earliest;
        }
        let mut start = earliest;
        let mut at = 0usize;
        for (i, &(b, e)) in self.busy.iter().enumerate() {
            if start + duration <= b {
                at = i;
                break;
            }
            start = start.max(e);
            at = i + 1;
        }
        self.busy.insert(at, (start, start + duration));
        // Merge adjacent intervals to keep the calendar small.
        let mut merged: Vec<(u64, u64)> = Vec::with_capacity(self.busy.len());
        for &(b, e) in &self.busy {
            match merged.last_mut() {
                Some(last) if b <= last.1 => last.1 = last.1.max(e),
                _ => merged.push((b, e)),
            }
        }
        self.busy = merged;
        start
    }
}

/// Where a job goes, or a signal that the dispatcher must account more
/// finished work before the earliest-free winner is provable.
enum Placement {
    Core(usize),
    NeedAccounting,
}

/// Immutable per-fleet placement context: each core's configuration,
/// its modeled clock, and the common bus clock.
struct FleetCtx<'a> {
    cfgs: &'a [EgpuConfig],
    core_khz: &'a [u64],
    bus_khz: u64,
}

impl FleetCtx<'_> {
    /// Wall-clock completion score of placing a job with static
    /// estimate `est` (core cycles) on core `c`, in bus cycles:
    /// `free + est·(bus/core)`. On a homogeneous fleet this adds the
    /// same constant to every core, so the argmin (and its first-index
    /// tie-break) is exactly the historical earliest-free choice.
    fn score(&self, c: usize, free: u64, est: u64) -> u64 {
        free + to_bus_cycles(est, self.core_khz[c], self.bus_khz)
    }

    /// Lower bound (bus cycles) on one outstanding job's occupancy of
    /// core `c`.
    fn min_job_bus(&self, c: usize) -> u64 {
        to_bus_cycles(MIN_JOB_CYCLES, self.core_khz[c], self.bus_khz)
    }

    /// The no-eligible-core dispatch error, naming each core's reason.
    fn no_core_error(&self, job: &Job, req: &FeatureSet) -> SimError {
        let reasons: Vec<String> = self
            .cfgs
            .iter()
            .enumerate()
            .map(|(c, cfg)| {
                let why = cfg.unsatisfied(req).unwrap_or_else(|| "unknown reason".into());
                format!("core {c} ('{}'): {why}", cfg.name)
            })
            .collect();
        SimError::new(
            0,
            format!(
                "no core can run job '{}' (requires: {req}); {}",
                job.kernel.name,
                reasons.join("; ")
            ),
        )
    }

    /// Eligibility error for a core the job is *pinned* to (stream
    /// affinity or legacy chaining).
    fn pinned_core_error(&self, job: &Job, req: &FeatureSet, c: usize) -> SimError {
        let why = self.cfgs[c].unsatisfied(req).unwrap_or_else(|| "unknown reason".into());
        SimError::new(
            0,
            format!(
                "job '{}' is pinned to core {c} ('{}'), which {why} \
                 (requires: {req})",
                job.kernel.name, self.cfgs[c].name
            ),
        )
    }
}

/// Placement policy shared by the sequential and parallel paths, in
/// priority order:
///
/// 1. A job on a stream that already owns a core goes to that core
///    (stream affinity — this is what makes `keep_data` chaining
///    well-defined). A *chained* stream job additionally requires its
///    stream's data to still be resident there — if other work has since
///    been placed on that core, dispatch errors rather than silently
///    computing on someone else's data. The pinned core must satisfy
///    the job's requirement; a stream whose later jobs outgrow its core
///    errors rather than silently migrating away from its data.
/// 2. A chained (`keep_data`) job without an affine core goes to the core
///    of the previously dispatched job; if there is no previous job, that
///    is an error (there is no resident data to chain onto).
/// 3. Everything else goes to the **eligible** core with the earliest
///    wall-clock completion score (first index on ties) — on a
///    homogeneous fleet, exactly the historical earliest-free choice.
///    With `pending` counts (parallel path), the choice is only
///    committed once provable; `pending = None` means every core's free
///    time is final.
#[allow(clippy::too_many_arguments)]
fn place_job(
    job: &Job,
    req: &FeatureSet,
    fleet: &FleetCtx<'_>,
    core_free: &[u64],
    pending: Option<&[usize]>,
    stream_core: &HashMap<u64, usize>,
    core_resident: &[Option<u64>],
    last_core: Option<usize>,
) -> Result<Placement, SimError> {
    let affine = job.stream.and_then(|s| stream_core.get(&s).copied());
    match affine {
        Some(c) => {
            // Chaining requires the stream's data to still be resident:
            // another stream (or an unordered job) may have been placed
            // on this core since and cleared it.
            if job.keep_data && core_resident[c] != job.stream {
                return Err(SimError::new(
                    0,
                    format!(
                        "job '{}' chains (keep_data) on stream {}, but core {c} \
                         has since run other work: the stream's resident data \
                         is gone",
                        job.kernel.name,
                        job.stream.unwrap_or_default()
                    ),
                ));
            }
            if !fleet.cfgs[c].satisfies(req) {
                return Err(fleet.pinned_core_error(job, req, c));
            }
            Ok(Placement::Core(c))
        }
        // Backstop arms: batch pre-validation already rejects these; kept
        // so a placement bug degrades to an error, not a silent wrong
        // answer.
        None if job.keep_data => match (job.stream, last_core) {
            (Some(s), _) => Err(SimError::new(
                0,
                format!(
                    "job '{}' chains (keep_data) as the first job on \
                     stream {s}: no resident data to chain onto",
                    job.kernel.name
                ),
            )),
            (None, Some(c)) => {
                if !fleet.cfgs[c].satisfies(req) {
                    return Err(fleet.pinned_core_error(job, req, c));
                }
                Ok(Placement::Core(c))
            }
            (None, None) => Err(SimError::new(
                0,
                format!(
                    "job '{}' chains (keep_data) but no job has run \
                     yet: no resident data to chain onto",
                    job.kernel.name
                ),
            )),
        },
        None => {
            let eligible: Vec<bool> = fleet.cfgs.iter().map(|cfg| cfg.satisfies(req)).collect();
            if !eligible.iter().any(|&e| e) {
                return Err(fleet.no_core_error(job, req));
            }
            let est = job.est_compute_cycles();
            match pending {
                None => {
                    let c = (0..core_free.len())
                        .filter(|&c| eligible[c])
                        .min_by_key(|&c| fleet.score(c, core_free[c], est))
                        .expect("at least one eligible core");
                    Ok(Placement::Core(c))
                }
                Some(pending) => Ok(provable_first_min(fleet, core_free, est, pending, &eligible)
                    .map_or(Placement::NeedAccounting, Placement::Core)),
            }
        }
    }
}

/// First eligible index minimizing the *eventual* completion score, or
/// `None` while outstanding jobs make the winner unprovable.
/// `score(c, core_free[c], est)` is exact when `pending[c] == 0`;
/// otherwise each outstanding job occupies core `c` for at least
/// [`MIN_JOB_CYCLES`] core cycles (≥ `min_job_bus(c)` bus cycles),
/// giving a lower bound. Tie-breaking matches `min_by_key`: the first
/// index wins, so a pending core *before* the candidate must be
/// provably greater, one *after* only provably not-smaller. Ineligible
/// cores neither win nor block.
fn provable_first_min(
    fleet: &FleetCtx<'_>,
    core_free: &[u64],
    est: u64,
    pending: &[usize],
    eligible: &[bool],
) -> Option<usize> {
    let mut best: Option<(usize, u64)> = None;
    for (c, (&free, &p)) in core_free.iter().zip(pending).enumerate() {
        if !eligible[c] || p != 0 {
            continue;
        }
        let score = fleet.score(c, free, est);
        let beats = match best {
            None => true,
            Some((_, v)) => score < v,
        };
        if beats {
            best = Some((c, score));
        }
    }
    let (ir, v) = best?;
    for (c, (&free, &p)) in core_free.iter().zip(pending).enumerate() {
        if eligible[c] && p > 0 {
            let lb = fleet.score(c, free, est) + fleet.min_job_bus(c) * p as u64;
            if (c < ir && lb <= v) || (c > ir && lb < v) {
                return None;
            }
        }
    }
    Some(ir)
}

/// Run an already-assembled job on one core: the machine half of
/// dispatch, shared verbatim by the sequential path and the parallel
/// workers so per-core state evolution is identical in both.
///
/// `prog == None` is a machine-reuse hit: the dispatcher proved the
/// core's machine already holds this exact kernel's program, so the
/// job skips assembly *and* `load_program` (plan + superplan
/// recompilation) and just resets architectural state via
/// [`Machine::reload`]. Register-file and plan allocations survive
/// across the whole steady-state batch.
fn exec_assembled(
    m: &mut Machine,
    prog: Option<Program>,
    job: &Job,
) -> Result<(RunStats, Vec<Vec<u32>>), SimError> {
    if job.panic_for_test {
        panic!("injected test panic: job '{}'", job.kernel.name);
    }
    if !job.keep_data {
        m.shared_mut().fill(0);
    }
    match prog {
        Some(p) => m.load_program(p)?,
        None => m.reload()?,
    }
    m.set_threads(job.kernel.threads)?;
    m.set_dim_x(job.kernel.dim_x)?;
    if !job.keep_data {
        for (base, data) in &job.loads {
            m.shared_mut().write_block(*base, data);
        }
    }
    let stats = m.run(job.max_cycles)?;
    let outputs = job
        .unloads
        .iter()
        .map(|&(base, len)| m.shared().read_block(base, len).to_vec())
        .collect();
    Ok((stats, outputs))
}

/// Per-job dispatch record for the parallel path's accounting replay.
struct DispatchMeta {
    name: String,
    stream: Option<u64>,
    requires: FeatureSet,
    core: usize,
    load_cycles: u64,
    unload_cycles: u64,
}

/// Undo record for one job's dispatch-time bookkeeping. The parallel
/// dispatcher runs ahead of accounting, so when job *f* fails, jobs
/// dispatched after it must have their bookkeeping unwound — the
/// sequential path never dispatched them, and a later batch must see
/// identical stream affinity (`coordinator_integration.rs` pins the
/// error-path parity down).
struct BookUndo {
    core: usize,
    stream: Option<u64>,
    /// Previous `stream_core` entry for `stream` (restored on unwind).
    prev_affinity: Option<usize>,
    prev_last: Option<usize>,
    /// Machine-reuse decision made for this job at dispatch time:
    /// `Some(true)` = reuse hit, `Some(false)` = miss (fresh assembly),
    /// `None` = assembly never reached (specialize/assemble failed).
    /// Unwinding decrements the matching counter so reuse stats match
    /// the sequential path, which never reaches rolled-back jobs.
    reuse: Option<bool>,
}

/// Unwind dispatch bookkeeping for `undo[from..]`, newest first.
/// `stream_core`/`last_core` are restored exactly; `core_resident` is
/// *poisoned* (set to `None`) instead of restored — the rolled-back
/// job's worker may already have overwritten that core's shared
/// memory, so a later chained job must fail loudly ("resident data is
/// gone") rather than silently read clobbered data. `core_loaded` gets
/// the same treatment for misses: the worker may already have loaded
/// the rolled-back job's program, so the reuse tracker can no longer
/// vouch for what the machine holds. A rolled-back *hit* leaves the
/// tracker alone — `reload` never changes the loaded program, so the
/// entry is still accurate.
#[allow(clippy::too_many_arguments)]
fn rollback_dispatch(
    stream_core: &mut HashMap<u64, usize>,
    core_resident: &mut [Option<u64>],
    last_core: &mut Option<usize>,
    core_loaded: &mut [Option<Arc<Kernel>>],
    reuse_hits: &mut u64,
    reuse_misses: &mut u64,
    undo: &[BookUndo],
    from: usize,
) {
    for u in undo[from.min(undo.len())..].iter().rev() {
        if let Some(s) = u.stream {
            match u.prev_affinity {
                Some(c) => {
                    stream_core.insert(s, c);
                }
                None => {
                    stream_core.remove(&s);
                }
            }
        }
        core_resident[u.core] = None;
        match u.reuse {
            Some(true) => *reuse_hits -= 1,
            Some(false) => {
                *reuse_misses -= 1;
                core_loaded[u.core] = None;
            }
            None => {}
        }
        *last_core = u.prev_last;
    }
}

/// What a worker hands back for one job.
type JobOutcome = Result<(RunStats, Vec<Vec<u32>>), SimError>;

/// [`account_next`] plus error-path unwinding: when the job at the
/// accounting cursor fails, its own bookkeeping stays (the sequential
/// path applies bookkeeping before running a job) but every job
/// dispatched after it is rolled back via [`rollback_dispatch`].
#[allow(clippy::too_many_arguments)]
fn account_next_unwinding(
    slots: &pool::BatchShared,
    metas: &[DispatchMeta],
    acct: &mut usize,
    pending: &mut [usize],
    tl: &mut TimelineState<'_>,
    out: &mut Vec<JobResult>,
    stream_core: &mut HashMap<u64, usize>,
    core_resident: &mut [Option<u64>],
    last_core: &mut Option<usize>,
    core_loaded: &mut [Option<Arc<Kernel>>],
    reuse_hits: &mut u64,
    reuse_misses: &mut u64,
    undo: &[BookUndo],
) -> Result<(), SimError> {
    match account_next(slots, metas, acct, pending, tl, out) {
        Ok(()) => Ok(()),
        Err(e) => {
            // The failing job's own bookkeeping stays (sequential
            // parity), but its machine may have died mid-`load_program`
            // — the reuse tracker can no longer vouch for that core.
            core_loaded[metas[*acct].core] = None;
            rollback_dispatch(
                stream_core,
                core_resident,
                last_core,
                core_loaded,
                reuse_hits,
                reuse_misses,
                undo,
                *acct + 1,
            );
            Err(e)
        }
    }
}

/// The mutable timeline state + clock table the accounting replay
/// writes: per-core free/busy (bus cycles), the bus calendar, and the
/// kHz table for core→bus conversion.
struct TimelineState<'a> {
    core_free: &'a mut [u64],
    core_busy: &'a mut [u64],
    bus_cal: &'a mut BusCalendar,
    core_khz: &'a [u64],
    bus_khz: u64,
}

/// Account the next job in submission order: block until its worker
/// outcome lands ([`pool::BatchShared::take`] — the dispatcher is the
/// board's only waiter, woken only by its own index), then replay the
/// bus/core timeline exactly as the sequential path would (load
/// reservation, compute converted onto the bus clock, unload
/// reservation). On a job error the load reservation persists, matching
/// the sequential path's early return.
fn account_next(
    slots: &pool::BatchShared,
    metas: &[DispatchMeta],
    acct: &mut usize,
    pending: &mut [usize],
    tl: &mut TimelineState<'_>,
    out: &mut Vec<JobResult>,
) -> Result<(), SimError> {
    let idx = *acct;
    assert!(idx < metas.len(), "accounting cursor past dispatched jobs");
    let outcome = slots.take(idx);
    let meta = &metas[idx];
    let start = tl.bus_cal.reserve(tl.core_free[meta.core], meta.load_cycles);
    let (stats, outputs) = outcome?;
    let compute_bus = to_bus_cycles(stats.cycles, tl.core_khz[meta.core], tl.bus_khz);
    let compute_end = start + meta.load_cycles + compute_bus;
    let unload_start = tl.bus_cal.reserve(compute_end, meta.unload_cycles);
    let end = unload_start + meta.unload_cycles;
    tl.core_free[meta.core] = end;
    tl.core_busy[meta.core] += end - start;
    pending[meta.core] -= 1;
    *acct += 1;
    out.push(JobResult {
        name: meta.name.clone(),
        core: meta.core,
        stream: meta.stream,
        requires: meta.requires.clone(),
        compute_cycles: stats.cycles,
        bus_cycles: meta.load_cycles + meta.unload_cycles,
        start,
        end,
        stats,
        outputs,
    });
    Ok(())
}

/// A fleet dispatcher: N eGPU cores, each with its own static
/// configuration, behind a single shared data bus.
pub struct Coordinator {
    /// Per-core static configurations (index = core id).
    cfgs: Vec<EgpuConfig>,
    bus: DataBus,
    /// Modeled clock of each core, integer kHz (771 MHz DP → 771_000).
    core_khz: Vec<u64>,
    /// Shared bus clock: the fastest core's clock (on a homogeneous
    /// fleet, *the* core clock — the historical timeline unit).
    bus_khz: u64,
    cores: Vec<Machine>,
    /// Bus-clock cycle at which each core finishes its current work.
    core_free: Vec<u64>,
    /// Bus-clock cycles each core has spent occupied (utilization).
    core_busy: Vec<u64>,
    /// Shared-bus reservation calendar.
    bus_cal: BusCalendar,
    queue: Vec<Job>,
    /// Stream → core affinity (persists across `run_all` batches so a
    /// stream's data stays resident where it was placed).
    stream_core: HashMap<u64, usize>,
    /// Stream whose data is currently resident on each core (the stream
    /// of the last job dispatched there; `None` = an unordered job).
    /// Chained jobs must find their own stream's data still resident.
    core_resident: Vec<Option<u64>>,
    /// Core of the most recently dispatched job (legacy `keep_data`
    /// chaining for jobs without a stream).
    last_core: Option<usize>,
    /// Simulate cores on worker threads (multi-core batches only).
    /// `false` forces the sequential reference path; both produce
    /// bit-identical results and timelines.
    parallel: bool,
    /// Kernel-specialization cache shared by every spec-submitted job
    /// (and injectable, so several devices can share one).
    cache: Arc<KernelCache>,
    /// Kernel whose program each core's machine currently holds
    /// (identity-compared via `Arc::ptr_eq`). A match lets dispatch
    /// skip assembly and `load_program` entirely — the machine resets
    /// in place ([`Machine::reload`]), reusing its register-file and
    /// plan allocations. `None` = unknown/poisoned: the next job on
    /// that core takes the full path.
    core_loaded: Vec<Option<Arc<Kernel>>>,
    /// Machine-reuse hits (jobs that skipped `load_program`).
    reuse_hits: u64,
    /// Machine-reuse misses (jobs that assembled + loaded fresh).
    reuse_misses: u64,
    /// The resident worker pool ([`pool::CorePool`]): `None` until the
    /// first parallel batch, then alive for the coordinator's lifetime.
    pool: Option<pool::CorePool>,
    /// Worker pools spawned — 0 (sequential-only) or 1, asserted by the
    /// serve-runtime pool-lifecycle tests.
    pool_spawns: u64,
    /// Per-batch dispatch scratch, retained across `run_all` calls.
    scratch: BatchScratch,
    /// Optional observability sink ([`crate::obs`]). Events are
    /// recorded on the dispatching thread only, after a batch's
    /// accounting is final, from the deterministic `JobResult`s and
    /// counter deltas — so the recorded trace is bit-identical between
    /// sequential and parallel dispatch, and `None` costs one branch.
    recorder: Option<Arc<Recorder>>,
}

/// Dispatch scratch reused across batches: the steady-state serve loop
/// re-dispatches every window without reallocating metadata, undo or
/// pending-count buffers (cleared, capacity kept).
#[derive(Default)]
struct BatchScratch {
    metas: Vec<DispatchMeta>,
    undo: Vec<BookUndo>,
    pending: Vec<usize>,
}

/// Machine-reuse counters for steady-state serving assertions: `hits`
/// jobs skipped assembly + `load_program` because their core's machine
/// already held the kernel's program; `misses` took the full path.
/// Bit-identical between sequential and parallel dispatch on
/// successful batches (the decision is made in submission order either
/// way, and error-path rollback unwinds counters for jobs the
/// sequential path never reached).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ReuseStats {
    pub hits: u64,
    pub misses: u64,
}

impl Coordinator {
    /// A homogeneous fleet: `num_cores` copies of one configuration
    /// (the historical constructor; behavior-identical to the
    /// single-config coordinator it replaces).
    pub fn new(cfg: EgpuConfig, num_cores: usize) -> Result<Coordinator, SimError> {
        if num_cores == 0 {
            return Err(SimError::new(
                0,
                "a Coordinator needs at least one core (num_cores == 0)",
            ));
        }
        Self::fleet(vec![cfg; num_cores])
    }

    /// A heterogeneous fleet: one core per configuration, in order.
    /// Core clocks come from the frequency model
    /// ([`modeled_core_khz`]); the shared bus runs at the fastest
    /// core's clock.
    pub fn fleet(cfgs: Vec<EgpuConfig>) -> Result<Coordinator, SimError> {
        if cfgs.is_empty() {
            return Err(SimError::new(
                0,
                "a Coordinator needs at least one core (empty fleet)",
            ));
        }
        let cache = KernelCache::shared();
        let mut cores = cfgs
            .iter()
            .map(|cfg| Machine::new(cfg.clone()))
            .collect::<Result<Vec<_>, _>>()?;
        // Every core shares the cache's superplan side: one fused-trace
        // compile per (program, config fingerprint, threads) triple
        // across the fleet.
        for m in &mut cores {
            m.set_superplan_cache(Arc::clone(cache.superplans()));
        }
        let core_khz: Vec<u64> = cfgs.iter().map(modeled_core_khz).collect();
        let bus_khz = *core_khz.iter().max().expect("at least one core");
        let n = cfgs.len();
        Ok(Coordinator {
            bus: DataBus::new(bus_khz as f64 / 1000.0),
            core_khz,
            bus_khz,
            core_free: vec![0; n],
            core_busy: vec![0; n],
            bus_cal: BusCalendar::default(),
            queue: Vec::new(),
            stream_core: HashMap::new(),
            core_resident: vec![None; n],
            last_core: None,
            parallel: true,
            cache,
            core_loaded: vec![None; n],
            reuse_hits: 0,
            reuse_misses: 0,
            pool: None,
            pool_spawns: 0,
            scratch: BatchScratch::default(),
            recorder: None,
            cfgs,
            cores,
        })
    }

    pub fn num_cores(&self) -> usize {
        self.cores.len()
    }

    /// First core's configuration — *the* configuration on a
    /// homogeneous fleet (kept for the wide pre-fleet call base; use
    /// [`Coordinator::configs`] when cores may differ).
    pub fn config(&self) -> &EgpuConfig {
        &self.cfgs[0]
    }

    /// Every core's configuration, index = core id.
    pub fn configs(&self) -> &[EgpuConfig] {
        &self.cfgs
    }

    /// Modeled clock of core `c` in MHz.
    pub fn core_mhz(&self, c: usize) -> f64 {
        self.core_khz[c] as f64 / 1000.0
    }

    /// The shared bus clock in MHz (fastest core).
    pub fn bus_mhz(&self) -> f64 {
        self.bus_khz as f64 / 1000.0
    }

    /// The shared bus clock in integer kHz — the exact unit the
    /// timeline is kept in (the serving layer converts µs deadlines
    /// and linger windows through it without rounding drift).
    pub fn bus_khz(&self) -> u64 {
        self.bus_khz
    }

    /// The fleet's kernel-specialization cache.
    pub fn kernel_cache(&self) -> &Arc<KernelCache> {
        &self.cache
    }

    /// Machine-reuse counters (see [`ReuseStats`]). Cumulative across
    /// `run_all` batches, like the timeline.
    pub fn reuse_stats(&self) -> ReuseStats {
        ReuseStats {
            hits: self.reuse_hits,
            misses: self.reuse_misses,
        }
    }

    /// Share a kernel cache with other devices (replaces the private
    /// one; call before submitting spec jobs). Every core re-attaches to
    /// the new cache's superplan side, so fused-trace sharing follows
    /// the kernel cache.
    pub fn set_kernel_cache(&mut self, cache: Arc<KernelCache>) {
        self.cache = cache;
        for m in &mut self.cores {
            m.set_superplan_cache(Arc::clone(self.cache.superplans()));
        }
    }

    /// Fleet-wide superplan cache totals (compiles / hits / resident
    /// entries), the fused-trace analogue of
    /// [`crate::kernels::CacheStats`]. Lookups happen under the cache
    /// lock in dispatch order per core, so the totals are deterministic
    /// between sequential and pooled-parallel dispatch.
    pub fn superplan_stats(&self) -> SuperplanCacheStats {
        self.cache.superplans().stats()
    }

    /// Summed per-core superplan rebuild/fast-skip activity (see
    /// [`SuperplanActivity`]). Steady-state serving accumulates only
    /// fast skips after warmup — the zero-recompile property the serve
    /// tests and the CLI's steady-state replay line assert.
    pub fn superplan_activity(&self) -> SuperplanActivity {
        self.cores
            .iter()
            .map(Machine::superplan_activity)
            .fold(SuperplanActivity::default(), |acc, a| SuperplanActivity {
                rebuilds: acc.rebuilds + a.rebuilds,
                fast_skips: acc.fast_skips + a.fast_skips,
            })
    }

    /// Worker pools spawned over this coordinator's lifetime: 0 while
    /// dispatch has been sequential-only, 1 from the first parallel
    /// batch on — never more, however many batches or serve windows run.
    pub fn pool_spawns(&self) -> u64 {
        self.pool_spawns
    }

    /// Worker threads revived after dying (0 in normal operation; job
    /// failures and panics poison a core for the rest of its batch but
    /// never kill the thread).
    pub fn pool_revives(&self) -> u64 {
        self.pool.as_ref().map_or(0, pool::CorePool::revives)
    }

    /// Every runtime cache/reuse/pool counter in one struct (the
    /// unified surface `Gpu`/`GpuArray`/`Server` re-expose; the
    /// per-counter getters above are kept as the assertable veneers).
    pub fn stats_snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            cache: self.cache.stats(),
            reuse: self.reuse_stats(),
            superplan: self.superplan_stats(),
            superplan_activity: self.superplan_activity(),
            pool_spawns: self.pool_spawns,
            pool_revives: self.pool_revives(),
        }
    }

    /// Attach (or detach) an observability recorder. Recording changes
    /// no modeled cycle, placement, or counter — it only keeps a trace
    /// of values the dispatcher computed anyway.
    pub fn set_recorder(&mut self, recorder: Option<Arc<Recorder>>) {
        self.recorder = recorder;
    }

    /// The attached recorder, if any.
    pub fn recorder(&self) -> Option<Arc<Recorder>> {
        self.recorder.clone()
    }

    /// Attach a fresh recorder if none is attached, and return the
    /// (shared) sink. Idempotent.
    pub fn start_recording(&mut self) -> Arc<Recorder> {
        if self.recorder.is_none() {
            self.recorder = Some(Arc::new(Recorder::new()));
        }
        Arc::clone(self.recorder.as_ref().expect("just attached"))
    }

    /// Escape hatch: core `c`'s machine, for architectural-state
    /// inspection (the heterogeneity property tests compare register
    /// files and shared memory against solo runs).
    pub fn core_machine(&self, c: usize) -> &Machine {
        &self.cores[c]
    }

    /// Pin a stream to a core before its first job (per-stream config
    /// affinity): every job on the stream will run there, and jobs
    /// whose requirements the core cannot satisfy fail at dispatch.
    pub fn pin_stream(&mut self, stream: u64, core: usize) -> Result<(), SimError> {
        if core >= self.cores.len() {
            return Err(SimError::new(
                0,
                format!(
                    "cannot pin stream {stream} to core {core}: fleet has {} cores",
                    self.cores.len()
                ),
            ));
        }
        self.stream_core.insert(stream, core);
        Ok(())
    }

    /// Fraction of the makespan each core spent occupied (loading,
    /// computing or unloading). The denominator is guarded: a fleet
    /// that never ran a job (makespan 0) reports all zeros, never
    /// NaN — including after [`Coordinator::advance_timeline_to`]
    /// opened an idle span with no work in it.
    ///
    /// Successive [`Coordinator::run_all`] batches **accumulate** on
    /// one timeline (busy cycles and makespan are cumulative) — that
    /// is the documented default; a fresh measurement window is an
    /// explicit [`Coordinator::reset_timeline`] call, never implicit.
    pub fn core_utilization(&self) -> Vec<f64> {
        let span = self.makespan();
        self.core_busy
            .iter()
            .map(|&b| if span == 0 { 0.0 } else { b as f64 / span as f64 })
            .collect()
    }

    /// Advance every core's free time (and hence the makespan floor)
    /// to `cycle`: an explicit *idle gap* on the modeled timeline. The
    /// serving layer uses this to model the fleet sitting idle between
    /// request batches — jobs dispatched afterwards start no earlier
    /// than `cycle`, and utilization denominators include the gap.
    /// Cycles already past `cycle` are unaffected (time never moves
    /// backwards); the bus stays consistent because every future
    /// reservation's earliest bound comes from a core free time.
    pub fn advance_timeline_to(&mut self, cycle: u64) {
        for free in &mut self.core_free {
            *free = (*free).max(cycle);
        }
    }

    /// Start a fresh measurement window at cycle 0: clears the
    /// per-core free/busy counters and the bus reservation calendar.
    /// This is the explicit counterpart to the cumulative default of
    /// [`Coordinator::run_all`] (see [`Coordinator::core_utilization`]).
    /// Stream→core affinity and resident-data tracking are untouched:
    /// they describe machine state, not accounting.
    pub fn reset_timeline(&mut self) {
        self.core_free.fill(0);
        self.core_busy.fill(0);
        self.bus_cal = BusCalendar::default();
    }

    /// Toggle parallel (worker-thread) dispatch. Defaults to on; the
    /// sequential path is kept as the timing reference
    /// (`coordinator_integration.rs` asserts bit-identical results).
    pub fn set_parallel(&mut self, on: bool) {
        self.parallel = on;
    }

    pub fn parallel(&self) -> bool {
        self.parallel
    }

    /// Queue a job (FIFO dispatch order).
    pub fn submit(&mut self, job: Job) {
        self.queue.push(job);
    }

    /// Queue a kernel by specification: compiled through the fleet's
    /// [`KernelCache`] (reference build against core 0's fingerprint,
    /// so the compile is shared with that core's dispatches),
    /// specialized to whatever core it is placed on. Returns the job
    /// builder-style for chaining loads/unloads via
    /// [`Coordinator::submit`].
    pub fn job_from_spec(&self, spec: KernelSpec) -> Result<Job, SimError> {
        Job::from_spec(spec, &self.cache, &self.cfgs[0])
    }

    /// Statically-checkable submission errors fail the whole batch up
    /// front, before any job executes or reserves bus time. Only data
    /// *eviction* (which depends on earliest-free placement of other
    /// jobs) must be detected during dispatch.
    fn prevalidate(&self, jobs: &[Job]) -> Result<(), SimError> {
        let mut known_streams: std::collections::HashSet<u64> =
            self.stream_core.keys().copied().collect();
        let mut any_prior = self.last_core.is_some();
        for job in jobs {
            if job.keep_data {
                if !job.loads.is_empty() {
                    return Err(SimError::new(
                        0,
                        format!(
                            "job '{}' chains (keep_data) but also declares input loads; \
                             chained jobs reuse resident data and skip the load DMA",
                            job.kernel.name
                        ),
                    ));
                }
                match job.stream {
                    Some(s) if !known_streams.contains(&s) => {
                        return Err(SimError::new(
                            0,
                            format!(
                                "job '{}' chains (keep_data) as the first job on \
                                 stream {s}: no resident data to chain onto",
                                job.kernel.name
                            ),
                        ))
                    }
                    None if !any_prior => {
                        return Err(SimError::new(
                            0,
                            format!(
                                "job '{}' chains (keep_data) but no job has run \
                                 yet: no resident data to chain onto",
                                job.kernel.name
                            ),
                        ))
                    }
                    _ => {}
                }
            }
            if let Some(s) = job.stream {
                known_streams.insert(s);
            }
            any_prior = true;
        }
        Ok(())
    }

    /// Dispatch every queued job: bus DMA serialized across cores,
    /// compute overlapped in the simulated timeline — and, on a
    /// multi-core coordinator, in wall-clock too (see the module docs;
    /// results and cycle accounting are identical either way).
    pub fn run_all(&mut self) -> Result<Vec<JobResult>, SimError> {
        let mut jobs = std::mem::take(&mut self.queue);
        // Snapshot counters before the batch so runtime activity can be
        // recorded as deltas afterwards — on the dispatching thread,
        // from totals that are already proven mode-identical, never
        // per-event from inside workers (which would race).
        let before = self
            .recorder
            .is_some()
            .then(|| (self.stats_snapshot(), self.makespan()));
        let r = (|| {
            self.prevalidate(&jobs)?;
            if self.parallel && self.cores.len() > 1 && jobs.len() > 1 {
                self.run_all_parallel(&mut jobs)
            } else {
                self.run_all_sequential(&mut jobs)
            }
        })();
        // Both paths drain `jobs` (errors included — `Drain` empties on
        // drop); hand the capacity back so steady-state serving submits
        // every window into one retained queue allocation.
        jobs.clear();
        self.queue = jobs;
        if let (Some((before, at)), Ok(results)) = (before, &r) {
            self.record_batch(before, at, results);
        }
        r
    }

    /// Record one dispatched batch's observability events: a core
    /// occupancy span per job (from its final timeline interval) and
    /// the batch's runtime-counter deltas, stamped at the batch's
    /// entry makespan (the serving layer aligns that with the window
    /// close, so deltas land where the dispatch decision was made).
    /// `pool_spawns` is deliberately **not** recorded: it is the one
    /// mode-dependent counter (0 sequential, 1 parallel), so it stays
    /// a snapshot/registry value and never enters the trace.
    fn record_batch(&self, before: StatsSnapshot, at: u64, results: &[JobResult]) {
        let rec = self.recorder.as_ref().expect("recording is on");
        for (i, r) in results.iter().enumerate() {
            rec.record(
                r.start,
                EventKind::PoolLoan {
                    core: r.core,
                    job: i,
                    name: r.name.clone(),
                },
            );
            rec.record(r.end, EventKind::PoolReclaim { core: r.core, job: i });
        }
        let after = self.stats_snapshot();
        let deltas: [(u64, fn(u64) -> EventKind); 7] = [
            (after.cache.compiles - before.cache.compiles, |n| {
                EventKind::KernelCompiles { n }
            }),
            (after.cache.hits - before.cache.hits, |n| {
                EventKind::KernelCacheHits { n }
            }),
            (after.reuse.hits - before.reuse.hits, |n| {
                EventKind::MachineReuses { n }
            }),
            (after.reuse.misses - before.reuse.misses, |n| {
                EventKind::MachineReloads { n }
            }),
            (after.superplan.compiles - before.superplan.compiles, |n| {
                EventKind::SuperplanCompiles { n }
            }),
            (after.superplan.hits - before.superplan.hits, |n| {
                EventKind::SuperplanHits { n }
            }),
            (after.pool_revives - before.pool_revives, |n| {
                EventKind::PoolRevives { n }
            }),
        ];
        for (n, make) in deltas {
            if n != 0 {
                rec.record(at, make(n));
            }
        }
    }

    /// The sequential reference path: place → run → account, one job at
    /// a time.
    fn run_all_sequential(&mut self, jobs: &mut Vec<Job>) -> Result<Vec<JobResult>, SimError> {
        let mut results = Vec::with_capacity(jobs.len());
        for job in jobs.drain(..) {
            let req = job.requires();
            let fleet = FleetCtx {
                cfgs: &self.cfgs,
                core_khz: &self.core_khz,
                bus_khz: self.bus_khz,
            };
            let core = match place_job(
                &job,
                &req,
                &fleet,
                &self.core_free,
                None,
                &self.stream_core,
                &self.core_resident,
                self.last_core,
            )? {
                Placement::Core(c) => c,
                Placement::NeedAccounting => unreachable!("sequential free times are final"),
            };
            self.note_dispatch(&job, core);
            let r = self.run_on(core, job, req)?;
            results.push(r);
        }
        Ok(results)
    }

    /// Dispatch-time bookkeeping shared by both paths.
    fn note_dispatch(&mut self, job: &Job, core: usize) {
        if let Some(s) = job.stream {
            self.stream_core.insert(s, core);
        }
        self.last_core = Some(core);
        self.core_resident[core] = job.stream;
    }

    /// The parallel path: one worker thread per core runs that core's
    /// job sequence; the dispatcher places jobs (waiting for accounting
    /// only when an earliest-free choice is not yet provable) and replays
    /// the timeline in submission order.
    ///
    /// Error semantics match the sequential path for everything the
    /// coordinator exposes: the same first error is returned, no
    /// `JobResult` past it is produced, each worker stops at its own
    /// core's first failure, and dispatch bookkeeping for jobs after the
    /// failing one is unwound ([`rollback_dispatch`]) so later batches
    /// see the same stream affinities either way. The one deliberate
    /// asymmetry: jobs already handed to *other* cores' workers may have
    /// simulated before shutdown, so the unwound cores' residency is
    /// poisoned — a later chained launch onto them errors loudly where
    /// the sequential path would have found intact data.
    fn run_all_parallel(&mut self, jobs: &mut Vec<Job>) -> Result<Vec<JobResult>, SimError> {
        let n = jobs.len();
        let Coordinator {
            cores,
            core_free,
            core_busy,
            bus_cal,
            stream_core,
            core_resident,
            last_core,
            core_loaded,
            reuse_hits,
            reuse_misses,
            cfgs,
            core_khz,
            bus_khz,
            cache,
            bus,
            pool,
            pool_spawns,
            scratch,
            ..
        } = self;
        let ncores = cores.len();
        let (cfgs, core_khz, bus_khz, cache) = (&cfgs[..], &core_khz[..], *bus_khz, &*cache);
        let fleet = FleetCtx {
            cfgs,
            core_khz,
            bus_khz,
        };
        // Each accounting call gets a fresh reborrow of the mutable
        // timeline state (placement reads `core_free` in between).
        macro_rules! timeline {
            () => {
                &mut TimelineState {
                    core_free: &mut core_free[..],
                    core_busy: &mut core_busy[..],
                    bus_cal: &mut *bus_cal,
                    core_khz,
                    bus_khz,
                }
            };
        }
        // The pool spawns once per coordinator lifetime — every later
        // batch reuses the resident workers (counted so tests and the
        // bench harness can assert the spawn-once property).
        if pool.is_none() {
            *pool_spawns += 1;
        }
        let pool = pool.get_or_insert_with(|| pool::CorePool::new(ncores));
        let shared = pool.begin_batch(cores, n);
        let r = {
            let shared = &*shared;
            let pool = &*pool;
            // Dispatch scratch is retained across batches: a steady-state
            // serve window allocates nothing here.
            let BatchScratch {
                metas,
                undo,
                pending,
            } = &mut *scratch;
            metas.clear();
            undo.clear();
            pending.clear();
            pending.resize(ncores, 0);
            let mut out: Vec<JobResult> = Vec::with_capacity(n);
            let mut acct = 0usize;

            let r = (|| -> Result<Vec<JobResult>, SimError> {
                for (i, job) in jobs.drain(..).enumerate() {
                    let req = job.requires();
                    let core = loop {
                        match place_job(
                            &job,
                            &req,
                            &fleet,
                            core_free,
                            Some(pending.as_slice()),
                            stream_core,
                            core_resident,
                            *last_core,
                        ) {
                            Ok(Placement::Core(c)) => break c,
                            Ok(Placement::NeedAccounting) => account_next_unwinding(
                                shared,
                                metas,
                                &mut acct,
                                pending,
                                timeline!(),
                                &mut out,
                                stream_core,
                                core_resident,
                                last_core,
                                core_loaded,
                                reuse_hits,
                                reuse_misses,
                                undo,
                            )?,
                            Err(e) => {
                                // Sequential parity: every job before this
                                // dispatch error fully ran and was
                                // accounted before the error surfaced.
                                while acct < metas.len() {
                                    account_next_unwinding(
                                        shared,
                                        metas,
                                        &mut acct,
                                        pending,
                                        timeline!(),
                                        &mut out,
                                        stream_core,
                                        core_resident,
                                        last_core,
                                        core_loaded,
                                        reuse_hits,
                                        reuse_misses,
                                        undo,
                                    )?;
                                }
                                return Err(e);
                            }
                        }
                    };
                    undo.push(BookUndo {
                        core,
                        stream: job.stream,
                        prev_affinity: job.stream.and_then(|s| stream_core.get(&s).copied()),
                        prev_last: *last_core,
                        reuse: None,
                    });
                    if let Some(s) = job.stream {
                        stream_core.insert(s, core);
                    }
                    *last_core = Some(core);
                    core_resident[core] = job.stream;
                    // Specialize spec jobs to the placed core's config
                    // (cache-memoized), then decide machine reuse: a
                    // core whose machine already holds this kernel's
                    // program skips assembly entirely (`prog = None`;
                    // the worker `reload`s in place). The decision runs
                    // in submission order, so the counters match the
                    // sequential path's. Errors drain accounting
                    // first — sequential parity for everything before
                    // the failing job.
                    let assembled = specialize_job(job, &cfgs[core], cache).and_then(|job| {
                        if core_loaded[core]
                            .as_ref()
                            .is_some_and(|k| Arc::ptr_eq(k, &job.kernel))
                        {
                            *reuse_hits += 1;
                            return Ok((None, job));
                        }
                        match job.kernel.assemble(&cfgs[core]) {
                            Ok(p) => {
                                *reuse_misses += 1;
                                core_loaded[core] = Some(job.kernel.clone());
                                Ok((Some(p), job))
                            }
                            Err(msg) => Err(SimError::new(0, msg)),
                        }
                    });
                    let (prog, job) = match assembled {
                        Ok(pj) => pj,
                        Err(e) => {
                            while acct < metas.len() {
                                account_next_unwinding(
                                    shared,
                                    metas,
                                    &mut acct,
                                    pending,
                                    timeline!(),
                                    &mut out,
                                    stream_core,
                                    core_resident,
                                    last_core,
                                    core_loaded,
                                    reuse_hits,
                                    reuse_misses,
                                    undo,
                                )?;
                            }
                            return Err(e);
                        }
                    };
                    undo.last_mut()
                        .expect("bookkeeping precedes assembly")
                        .reuse = Some(prog.is_none());
                    metas.push(DispatchMeta {
                        name: job.kernel.name.clone(),
                        stream: job.stream,
                        requires: req,
                        core,
                        load_cycles: bus.transfer_cycles(job.load_words()),
                        unload_cycles: bus.transfer_cycles(job.unload_words()),
                    });
                    pending[core] += 1;
                    pool.send(core, i, prog, job);
                }
                while acct < metas.len() {
                    account_next_unwinding(
                        shared,
                        metas,
                        &mut acct,
                        pending,
                        timeline!(),
                        &mut out,
                        stream_core,
                        core_resident,
                        last_core,
                        core_loaded,
                        reuse_hits,
                        reuse_misses,
                        undo,
                    )?;
                }
                Ok(out)
            })();
            r
        };
        // Reclaim every machine (in core order) on success and failure
        // alike; a worker that died mid-batch gets its machine rebuilt
        // and that core's reuse/residency tracking poisoned.
        pool.end_batch(
            cores,
            |c| {
                let mut m = Machine::new(cfgs[c].clone())
                    .expect("core config was valid at fleet construction");
                m.set_superplan_cache(Arc::clone(cache.superplans()));
                m
            },
            core_loaded,
            core_resident,
        );
        r
    }

    /// Decide machine reuse for `job` on `core`: `None` when the
    /// core's machine already holds this exact kernel's program (a
    /// hit — `exec_assembled` will `reload` in place), `Some(prog)`
    /// when it must assemble and load fresh. Counters move here, in
    /// dispatch order, in both dispatch paths.
    fn prepare_program(&mut self, core: usize, job: &Job) -> Result<Option<Program>, SimError> {
        let hit = self.core_loaded[core]
            .as_ref()
            .is_some_and(|k| Arc::ptr_eq(k, &job.kernel));
        if hit {
            self.reuse_hits += 1;
            return Ok(None);
        }
        let prog = job
            .kernel
            .assemble(&self.cfgs[core])
            .map_err(|msg| SimError::new(0, msg))?;
        self.reuse_misses += 1;
        self.core_loaded[core] = Some(job.kernel.clone());
        Ok(Some(prog))
    }

    fn run_on(&mut self, core: usize, job: Job, req: FeatureSet) -> Result<JobResult, SimError> {
        let job = specialize_job(job, &self.cfgs[core], &self.cache)?;
        let prog = self.prepare_program(core, &job)?;

        // Bus phase 1: load DMA (a reservation on the shared bus).
        let load_cycles = self.bus.transfer_cycles(job.load_words());
        let start = self.bus_cal.reserve(self.core_free[core], load_cycles);

        // Guarded like the pooled path, so a panicking job yields the
        // same `SimError` in both dispatch modes (report bit-identity
        // includes error strings).
        let (stats, outputs) = match pool::run_job_guarded(&mut self.cores[core], prog, &job) {
            Ok(r) => r,
            Err(e) => {
                // The machine may have died mid-`load_program`; stop
                // vouching for what it holds.
                self.core_loaded[core] = None;
                return Err(e);
            }
        };

        // Bus phase 2: unload DMA. Compute occupies the bus timeline for
        // the core's cycles converted onto the bus clock.
        let unload_cycles = self.bus.transfer_cycles(job.unload_words());
        let compute_bus = to_bus_cycles(stats.cycles, self.core_khz[core], self.bus_khz);
        let compute_end = start + load_cycles + compute_bus;
        let unload_start = self.bus_cal.reserve(compute_end, unload_cycles);
        let end = unload_start + unload_cycles;
        self.core_free[core] = end;
        self.core_busy[core] += end - start;

        Ok(JobResult {
            name: job.kernel.name.clone(),
            core,
            stream: job.stream,
            requires: req,
            compute_cycles: stats.cycles,
            bus_cycles: load_cycles + unload_cycles,
            start,
            end,
            stats,
            outputs,
        })
    }

    /// Completion cycle (bus clock) of the last finishing core.
    pub fn makespan(&self) -> u64 {
        self.core_free.iter().copied().max().unwrap_or(0)
    }

    /// Makespan in microseconds at the bus clock (on a homogeneous
    /// fleet, the core clock — the historical definition).
    pub fn makespan_us(&self) -> f64 {
        self.makespan() as f64 / self.bus_mhz()
    }
}

/// Re-specialize a spec-submitted job to its placed core's
/// configuration through the cache (no-op for prebuilt-kernel jobs —
/// the historical path, byte-identical behavior).
fn specialize_job(job: Job, cfg: &EgpuConfig, cache: &KernelCache) -> Result<Job, SimError> {
    match job.spec {
        Some(spec) => {
            let kernel = cache.get(&spec, cfg).map_err(|m| SimError::new(0, m))?;
            Ok(Job { kernel, ..job })
        }
        None => Ok(job),
    }
}

/// Mean of overhead fractions; 0 on an empty set. Shared by
/// [`average_bus_overhead`] and [`crate::api::average_bus_overhead`].
pub(crate) fn mean_overhead(overheads: impl Iterator<Item = f64>) -> f64 {
    let (mut sum, mut n) = (0.0f64, 0usize);
    for v in overheads {
        sum += v;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

/// Unweighted mean of per-job bus overheads.
pub fn average_bus_overhead(results: &[JobResult]) -> f64 {
    mean_overhead(results.iter().map(JobResult::bus_overhead))
}

/// Time-weighted bus overhead: total bus cycles over total end-to-end
/// cycles. This is the §7 metric — "the performance impact was only 4.7%,
/// averaged over all benchmarks" — where long-running kernels (MMM)
/// dominate the aggregate and amortize their data movement.
pub fn aggregate_bus_overhead(results: &[JobResult]) -> f64 {
    let bus: u64 = results.iter().map(|r| r.bus_cycles).sum();
    let compute: u64 = results.iter().map(|r| r.compute_cycles).sum();
    if bus + compute == 0 {
        return 0.0;
    }
    bus as f64 / (bus + compute) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{f32_bits, reduction};
    use crate::sim::config::MemoryMode;

    fn job(n: usize) -> Job {
        let data: Vec<f32> = (0..n).map(|i| i as f32 * 0.25).collect();
        Job::new(reduction::reduction(n))
            .load(0, f32_bits(&data))
            .unload(n, 1)
    }

    fn cfg() -> EgpuConfig {
        EgpuConfig::benchmark(MemoryMode::Dp, false)
    }

    #[test]
    fn single_core_runs_jobs() {
        let mut c = Coordinator::new(cfg(), 1).unwrap();
        c.submit(job(32));
        c.submit(job(64));
        let rs = c.run_all().unwrap();
        assert_eq!(rs.len(), 2);
        for (r, n) in rs.iter().zip([32usize, 64]) {
            let got = f32::from_bits(r.outputs[0][0]);
            let want: f32 = (0..n).map(|i| i as f32 * 0.25).sum();
            assert!((got - want).abs() < 1e-2, "{}: {got} vs {want}", r.name);
            assert_eq!(r.core, 0);
        }
        // FIFO on one core: the second job starts after the first ends.
        assert!(rs[1].start >= rs[0].end);
    }

    #[test]
    fn multi_core_overlaps_compute() {
        // Bus-bound jobs (reduction: ~129 bus vs ~287 compute cycles)
        // overlap partially; the serialized bus bounds the speedup.
        let mut one = Coordinator::new(cfg(), 1).unwrap();
        let mut four = Coordinator::new(cfg(), 4).unwrap();
        for c in [&mut one, &mut four] {
            for _ in 0..4 {
                c.submit(job(128));
            }
            c.run_all().unwrap();
        }
        assert!(
            four.makespan() < one.makespan(),
            "4 cores {} vs 1 core {}",
            four.makespan(),
            one.makespan()
        );
        assert!(four.makespan() > one.makespan() / 4);
    }

    #[test]
    fn compute_heavy_jobs_scale_nearly_linearly() {
        use crate::kernels::fft;
        let n = 128;
        let re: Vec<f32> = (0..n).map(|i| (i as f32 * 0.1).sin()).collect();
        let im = vec![0f32; n];
        let mk_job = || {
            let mut j = Job::new(fft::fft(n)).unload(0, 2 * n);
            for (base, data) in fft::shared_init(&re, &im) {
                j = j.load(base, data);
            }
            j
        };
        let mut one = Coordinator::new(cfg(), 1).unwrap();
        let mut four = Coordinator::new(cfg(), 4).unwrap();
        for c in [&mut one, &mut four] {
            for _ in 0..4 {
                c.submit(mk_job());
            }
            c.run_all().unwrap();
        }
        // FFT-128: ~3.5k compute vs ~0.7k bus cycles → near-4x overlap.
        assert!(
            four.makespan() * 2 < one.makespan(),
            "4 cores {} vs 1 core {}",
            four.makespan(),
            one.makespan()
        );
    }

    #[test]
    fn chained_jobs_skip_bus_and_stay_on_core() {
        // Transpose reads [0, n²) without mutating it, so a chained
        // second transpose sees the data the first job loaded.
        use crate::kernels::transpose;
        let n = 32;
        let data: Vec<u32> = (0..(n * n) as u32).collect();
        let mut c = Coordinator::new(cfg(), 4).unwrap();
        c.submit(Job::new(transpose::transpose(n)).load(0, data.clone()));
        c.submit(Job::new(transpose::transpose(n)).unload(n * n, n * n).chained());
        let rs = c.run_all().unwrap();
        assert_eq!(rs[0].core, rs[1].core, "chained job must stay on core");
        assert_eq!(rs[1].bus_cycles, (n * n) as u64, "only the unload DMA");
        assert_eq!(rs[1].outputs[0], transpose::oracle(&data, n));
    }

    #[test]
    fn bus_overhead_small_for_compute_heavy_jobs() {
        let mut c = Coordinator::new(cfg(), 1).unwrap();
        c.submit(job(128));
        let rs = c.run_all().unwrap();
        // 129 bus words vs ~230 compute cycles: meaningful but bounded.
        let o = rs[0].bus_overhead();
        assert!((0.01..0.6).contains(&o), "overhead {o}");
    }

    #[test]
    fn fresh_jobs_clear_shared_memory() {
        let n = 32;
        let mut c = Coordinator::new(cfg(), 1).unwrap();
        c.submit(job(n));
        // Second job loads zeros; result must be 0, not stale data.
        c.submit(
            Job::new(reduction::reduction(n))
                .load(0, vec![0u32; n])
                .unload(n, 1),
        );
        let rs = c.run_all().unwrap();
        assert_eq!(f32::from_bits(rs[1].outputs[0][0]), 0.0);
    }

    #[test]
    fn makespan_tracks_cycles() {
        let mut c = Coordinator::new(cfg(), 2).unwrap();
        assert_eq!(c.makespan(), 0);
        c.submit(job(32));
        c.run_all().unwrap();
        assert!(c.makespan() > 0);
        assert!(c.makespan_us() > 0.0);
    }

    #[test]
    fn bus_overhead_of_zero_cycle_job_is_zero_not_nan() {
        // Regression: bus_cycles + compute_cycles == 0 divided by zero.
        let r = JobResult {
            name: "empty".into(),
            core: 0,
            stream: None,
            requires: FeatureSet::none(),
            compute_cycles: 0,
            bus_cycles: 0,
            start: 0,
            end: 0,
            stats: RunStats {
                cycles: 0,
                instructions: 0,
                profile: crate::sim::Profile::new(),
                hazards: 0,
                hazard_samples: Vec::new(),
            },
            outputs: Vec::new(),
        };
        assert_eq!(r.bus_overhead(), 0.0);
        assert_eq!(average_bus_overhead(&[r]), 0.0);
    }

    #[test]
    fn first_chained_job_is_an_error_not_core0() {
        // Regression: a first-submitted keep_data job used to silently
        // chain onto core 0 with no resident data.
        let mut c = Coordinator::new(cfg(), 2).unwrap();
        c.submit(Job::new(reduction::reduction(32)).chained());
        let err = c.run_all().unwrap_err();
        assert!(err.message.contains("no resident data"), "{err}");
        // The coordinator stays usable.
        c.submit(job(32));
        assert_eq!(c.run_all().unwrap().len(), 1);
    }

    #[test]
    fn first_chained_job_on_a_stream_is_an_error() {
        let mut c = Coordinator::new(cfg(), 2).unwrap();
        c.submit(job(32).on_stream(7));
        c.run_all().unwrap();
        // Stream 9 has never run: chaining onto it must fail even though
        // stream 7 has resident data.
        c.submit(Job::new(reduction::reduction(32)).on_stream(9).chained());
        let err = c.run_all().unwrap_err();
        assert!(err.message.contains("stream 9"), "{err}");
    }

    #[test]
    fn stream_affinity_pins_jobs_to_one_core() {
        let mut c = Coordinator::new(cfg(), 4).unwrap();
        for _ in 0..3 {
            c.submit(job(32).on_stream(1));
        }
        let rs = c.run_all().unwrap();
        assert!(rs.iter().all(|r| r.core == rs[0].core), "stream hops cores");
        assert!(rs.iter().all(|r| r.stream == Some(1)));
        // Ordered per stream: each job starts at or after the previous end.
        assert!(rs.windows(2).all(|w| w[1].start >= w[0].end));
    }

    #[test]
    fn stream_affinity_survives_run_all_batches() {
        let mut c = Coordinator::new(cfg(), 4).unwrap();
        c.submit(job(32).on_stream(3));
        let first = c.run_all().unwrap();
        // A later batch chains onto the stream's resident data: same core,
        // no load DMA.
        use crate::kernels::transpose;
        let n = 32;
        let data: Vec<u32> = (0..(n * n) as u32).collect();
        c.submit(Job::new(transpose::transpose(n)).load(0, data).on_stream(3));
        c.submit(
            Job::new(transpose::transpose(n))
                .unload(n * n, n * n)
                .on_stream(3)
                .chained(),
        );
        let rs = c.run_all().unwrap();
        assert_eq!(rs[0].core, first[0].core);
        assert_eq!(rs[1].core, first[0].core);
        assert_eq!(rs[1].bus_cycles, (n * n) as u64, "chained: unload DMA only");
    }

    #[test]
    fn chained_job_errors_when_stream_data_evicted() {
        // Streams outnumber cores: stream 2's fresh job lands on stream
        // 0's core (earliest free) and clears it. Chaining on stream 0
        // afterwards must error, not silently compute on stream 2's data.
        let mut c = Coordinator::new(cfg(), 2).unwrap();
        c.submit(job(32).on_stream(0));
        c.submit(job(32).on_stream(1));
        c.submit(job(32).on_stream(2));
        let rs = c.run_all().unwrap();
        assert_eq!(rs[0].core, rs[2].core, "stream 2 evicts stream 0");
        c.submit(Job::new(reduction::reduction(32)).on_stream(0).chained());
        let err = c.run_all().unwrap_err();
        assert!(err.message.contains("resident data is gone"), "{err}");
    }

    #[test]
    fn chained_job_with_input_loads_is_rejected_before_anything_runs() {
        // The load DMA of a keep_data job would be silently skipped;
        // declaring both fails the batch up front — the earlier valid
        // job must not have half-executed.
        let mut c = Coordinator::new(cfg(), 1).unwrap();
        c.submit(job(32));
        c.submit(job(32).chained());
        let err = c.run_all().unwrap_err();
        assert!(err.message.contains("input loads"), "{err}");
        assert_eq!(c.makespan(), 0, "no job may execute on a rejected batch");
    }

    #[test]
    fn job_budget_bounds_the_run() {
        let mut c = Coordinator::new(cfg(), 1).unwrap();
        c.submit(job(128).budget(10));
        let err = c.run_all().unwrap_err();
        assert!(err.message.contains("cycle limit"), "{err}");
        // The budget stop preserves the partial run statistics.
        assert!(err.partial.is_some());
    }

    #[test]
    fn sequential_toggle_matches_parallel() {
        // Same batch through both dispatch paths: identical results.
        let run = |parallel: bool| {
            let mut c = Coordinator::new(cfg(), 3).unwrap();
            c.set_parallel(parallel);
            assert_eq!(c.parallel(), parallel);
            for i in 0..6u64 {
                c.submit(job(32 + 32 * (i as usize % 2)).on_stream(i % 3));
            }
            let rs = c.run_all().unwrap();
            (rs, c.makespan())
        };
        let (seq, seq_span) = run(false);
        let (par, par_span) = run(true);
        assert_eq!(seq_span, par_span);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.core, b.core);
            assert_eq!(a.stream, b.stream);
            assert_eq!(a.compute_cycles, b.compute_cycles);
            assert_eq!(a.bus_cycles, b.bus_cycles);
            assert_eq!((a.start, a.end), (b.start, b.end));
            assert_eq!(a.outputs, b.outputs);
        }
    }

    #[test]
    fn failed_parallel_batch_unwinds_dispatch_bookkeeping() {
        // J0 trips its cycle budget; the parallel dispatcher has already
        // handed J1 (first job of a fresh stream) to another core by
        // then. After the error, the coordinator's bookkeeping must look
        // exactly like the sequential path's, which never dispatched J1:
        // chaining onto J1's stream is a fresh-stream error either way.
        for parallel in [false, true] {
            let mut c = Coordinator::new(cfg(), 4).unwrap();
            c.set_parallel(parallel);
            c.submit(job(128).budget(10)); // cycle-limit failure
            c.submit(job(32).on_stream(5)); // eagerly dispatched when parallel
            let err = c.run_all().unwrap_err();
            assert!(err.message.contains("cycle limit"), "{err}");
            c.submit(Job::new(reduction::reduction(32)).on_stream(5).chained());
            let err = c.run_all().unwrap_err();
            assert!(
                err.message.contains("stream 5"),
                "parallel={parallel}: {err}"
            );
        }
    }

    /// Homogeneous 3-core context at one clock, est 0: the historical
    /// earliest-free semantics, which the tie-breaking contract below
    /// pins down.
    fn homog3() -> (Vec<EgpuConfig>, Vec<u64>) {
        let cfgs = vec![cfg(); 3];
        let khz = vec![771_000u64; 3];
        (cfgs, khz)
    }

    #[test]
    fn provable_first_min_respects_tie_breaking() {
        let (cfgs, khz) = homog3();
        let fleet = FleetCtx {
            cfgs: &cfgs,
            core_khz: &khz,
            bus_khz: 771_000,
        };
        let all = [true, true, true];
        let pfm = |free: &[u64], pending: &[usize]| {
            provable_first_min(&fleet, free, 0, pending, &all)
        };
        // All resolved: plain first-min.
        assert_eq!(pfm(&[5, 3, 3], &[0, 0, 0]), Some(1));
        // Pending core 0 could finish anywhere ≥ 9 → core 1 (free=3) wins.
        assert_eq!(pfm(&[0, 3, 5], &[1, 0, 0]), Some(1));
        // Pending core 0's bound (0+9=9) could tie with core 1's 9 and
        // core 0 is first → unprovable.
        assert_eq!(pfm(&[0, 9, 50], &[1, 0, 0]), None);
        // Pending core AFTER the candidate may tie (first-min wins)...
        assert_eq!(pfm(&[9, 50, 0], &[0, 0, 1]), Some(0));
        // ...but one that could finish strictly earlier blocks the call.
        assert_eq!(pfm(&[10, 50, 0], &[0, 0, 1]), None);
        // Nothing resolved → wait.
        assert_eq!(
            provable_first_min(&fleet, &[0, 0], 0, &[1, 1], &[true, true]),
            None
        );
        // An ineligible core neither wins nor blocks: core 0 is free at
        // 0 but can't run the job; pending core 2 can't block core 1.
        assert_eq!(
            provable_first_min(&fleet, &[0, 5, 0], 0, &[0, 0, 3], &[false, true, false]),
            Some(1)
        );
    }

    #[test]
    fn wall_clock_scores_prefer_faster_cores() {
        // A 600 MHz QP core listed first vs a 771 MHz DP core, both
        // free: with a nonzero estimate the DP core's completion score
        // is earlier, so it wins despite the first-index tie-break.
        let cfgs = vec![
            EgpuConfig::benchmark(MemoryMode::Qp, false),
            EgpuConfig::benchmark(MemoryMode::Dp, false),
        ];
        let khz = vec![600_000u64, 771_000];
        let fleet = FleetCtx {
            cfgs: &cfgs,
            core_khz: &khz,
            bus_khz: 771_000,
        };
        // est=1000 core cycles → 1285 bus cycles on QP, 1000 on DP.
        assert_eq!(fleet.score(0, 0, 1000), 1285);
        assert_eq!(fleet.score(1, 0, 1000), 1000);
        assert_eq!(
            provable_first_min(&fleet, &[0, 0], 1000, &[0, 0], &[true, true]),
            Some(1)
        );
        // With est 0 (unknown kernel) it degrades to earliest-free.
        assert_eq!(
            provable_first_min(&fleet, &[0, 0], 0, &[0, 0], &[true, true]),
            Some(0)
        );
    }

    #[test]
    fn to_bus_cycles_is_exact_and_monotone() {
        assert_eq!(to_bus_cycles(600, 600_000, 771_000), 771);
        assert_eq!(to_bus_cycles(1000, 771_000, 771_000), 1000);
        assert_eq!(to_bus_cycles(0, 600_000, 771_000), 0);
        // Round-up: 1 slow-core cycle still occupies ≥ its wall-clock.
        assert_eq!(to_bus_cycles(1, 600_000, 771_000), 2);
        let mut last = 0;
        for c in [1u64, 7, 9, 100, 1_000_000] {
            let b = to_bus_cycles(c, 600_000, 771_000);
            assert!(b >= last && b >= c);
            last = b;
        }
    }

    #[test]
    fn utilization_accumulates_across_batches_until_reset() {
        let mut c = Coordinator::new(cfg(), 2).unwrap();
        // Never ran a job: guarded denominator, all zeros (no NaN).
        assert!(c.core_utilization().iter().all(|&u| u == 0.0));
        c.submit(job(32));
        c.run_all().unwrap();
        let span1 = c.makespan();
        let busy1: f64 = c.core_utilization().iter().sum();
        c.submit(job(32));
        c.run_all().unwrap();
        // Cumulative by default: the second batch extends one timeline.
        assert!(c.makespan() > span1, "{} vs {span1}", c.makespan());
        assert!(busy1 > 0.0);
        // Explicit reset opens a fresh window...
        c.reset_timeline();
        assert_eq!(c.makespan(), 0);
        assert!(c.core_utilization().iter().all(|&u| u == 0.0));
        // ...and the fleet stays fully usable on it.
        c.submit(job(32));
        let rs = c.run_all().unwrap();
        assert_eq!(rs[0].start, 0, "fresh window restarts at cycle 0");
        assert!(c.makespan() > 0);
    }

    #[test]
    fn advance_timeline_models_idle_gaps() {
        let mut c = Coordinator::new(cfg(), 2).unwrap();
        c.advance_timeline_to(1_000);
        // Idle span alone: utilization stays zero, never NaN.
        assert_eq!(c.makespan(), 1_000);
        assert!(c.core_utilization().iter().all(|&u| u == 0.0));
        c.submit(job(32));
        let rs = c.run_all().unwrap();
        assert!(rs[0].start >= 1_000, "jobs start after the gap, got {}", rs[0].start);
        let util = c.core_utilization();
        assert!(util[rs[0].core] > 0.0 && util[rs[0].core] < 1.0, "{util:?}");
        // Time never moves backwards.
        let span = c.makespan();
        c.advance_timeline_to(10);
        assert_eq!(c.makespan(), span);
    }

    #[test]
    fn zero_cores_is_a_sim_error_not_a_panic() {
        let err = Coordinator::new(cfg(), 0).unwrap_err();
        assert!(err.message.contains("at least one core"), "{err}");
        let err = Coordinator::fleet(Vec::new()).unwrap_err();
        assert!(err.message.contains("at least one core"), "{err}");
    }

    #[test]
    fn job_results_surface_requirements() {
        use crate::kernels::bitonic;
        let pcfg = EgpuConfig::benchmark_predicated(MemoryMode::Dp);
        let mut c = Coordinator::new(pcfg, 1).unwrap();
        let data: Vec<u32> = (0..64).map(|i| i as u32).collect();
        let job = Job::new(bitonic::bitonic(64)).load(0, data).unload(0, 64);
        let want = job.requires();
        c.submit(job);
        let rs = c.run_all().unwrap();
        assert!(rs[0].requires.predicate_depth >= 1);
        assert_eq!(rs[0].requires.min_shared_words, 64);
        assert_eq!(rs[0].requires, want);
    }
}
