//! Workload-driven fleet synthesis under an Agilex area budget.
//!
//! This module closes the model → place → serve loop: given an
//! [`AreaBudget`] (ALMs / DSPs / M20Ks) and a `harness::loadgen`
//! traffic trace, [`synthesize`] picks the fleet of statically-scaled
//! cores that serves the most requests within their SLOs. It is the
//! contest the companion paper ("Soft GPGPU versus IP cores") frames:
//! under a fixed fabric budget, which mix of configurations earns its
//! area?
//!
//! The pipeline:
//!
//! 1. **Enumerate** ([`candidate_space`]) — walk the paper's static
//!    axes (memory mode × regs/thread × thread space × feature tier)
//!    into concrete `EgpuConfig`s, deduped by compile fingerprint plus
//!    the serving-relevant axes.
//! 2. **Filter** ([`candidates`]) — each candidate must fit the budget
//!    per [`crate::model::resources::ResourceReport`] and place per
//!    [`crate::place::place`]; refusals carry the placer's reason.
//! 3. **Search** ([`search`]) — deterministic beam search over fleet
//!    compositions: each seeding stage and beam round collects its
//!    frontier of unscored canonical keys, scores all replays in one
//!    wave ([`SynthOptions::jobs`] scoped workers, each replaying the
//!    trace through a fresh in-process [`crate::serve::Server`] in
//!    modeled bus cycles), and merges results in canonical key order.
//!    Dominance pruning skips replays that provably cannot win once
//!    the incumbent meets every SLO.
//! 4. **Emit** — the winner serializes via
//!    [`crate::sim::config_json::fleet_to_json`], so `egpu serve
//!    --configs` / `egpu fleet --configs` consume it unchanged.
//!
//! Determinism rules: no wall-clock anywhere in the objective (bus
//! cycles only), no f64 in comparisons ([`FleetScore`] is integers and
//! fingerprints end-to-end), fixed enumeration order, memoized
//! scoring keyed on canonical sorted compositions, and frontier waves
//! whose merge order never depends on worker scheduling — so the same
//! (budget, trace, options) triple is bit-identical across reruns,
//! under sequential vs parallel serving, at any `jobs` value, and
//! with pruning on or off (pruning only shrinks `evaluated`).

pub mod budget;
pub mod candidates;
pub mod search;

pub use budget::{AreaBudget, AreaUsage};
pub use candidates::{candidate_space, Candidate, Reject};
pub use search::{synthesize, BaselineScore, FleetScore, SynthOptions, SynthResult};
