//! Deterministic beam search over fleet compositions, scored by trace
//! replay.
//!
//! A composition is a multiset of feasible candidates (counts ×
//! configs). Scoring replays the offered trace through an in-process
//! [`Server`] over that fleet and reads the integer telemetry: the
//! objective is SLO-met completions (completed minus deadline misses),
//! measured in modeled bus cycles — wall-clock never enters the score,
//! so the search result is a pure function of (budget, trace, options).
//!
//! Ties break through [`FleetScore`]'s total order: more SLO-met
//! requests, then *lower* fixed-point modeled cost
//! ([`crate::model::cost::config_cost_fixed`]), then the sorted config
//! fingerprints — all integers, so equal fleets compare `==` and
//! reruns are bit-identical. A fleet whose serve replay errors (a
//! kernel no core can accept) scores as unservable and never enters
//! the beam.
//!
//! The search seeds the beam with every covering singleton, a greedy
//! static-cover multiset, and the homogeneous demo-fleet compositions
//! (which are also reported as baselines); expansion appends one
//! candidate at a time, keeping budget fit invariant. The loop stops
//! the first round that fails to strictly improve the best score —
//! improvement is strict in the total order and the composition space
//! is finite, so termination is guaranteed. All candidate fleets share
//! one [`KernelCache`], so each kernel compiles once per fingerprint
//! across the whole search.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::api::FleetBuilder;
use crate::kernels::KernelCache;
use crate::model::cost::config_cost_fixed;
use crate::model::resources::ResourceReport;
use crate::place;
use crate::serve::{Request, Server};
use crate::sim::{config_json, EgpuConfig};

use super::budget::{AreaBudget, AreaUsage};
use super::candidates::{
    candidate_covers, candidate_space, covers, filter_candidates, request_needs, Candidate, Reject,
    RequestNeed,
};

/// Knobs for one synthesis run. The defaults mirror the serving
/// runtime's ([`Server`] qdepth 64, batch 8, 8 µs linger) so the
/// score replays the same policy `egpu serve` runs.
#[derive(Debug, Clone, PartialEq)]
pub struct SynthOptions {
    /// Beam width (compositions expanded per round; ≥ 1).
    pub beam: usize,
    /// Hard cap on fleet size (cores per composition).
    pub max_cores: usize,
    /// Candidate configurations to search over; empty = the default
    /// [`candidate_space`]. Still deduped and feasibility-filtered.
    pub candidates: Vec<EgpuConfig>,
    /// Score with sequential fleet dispatch instead of parallel
    /// workers. Bit-identical result either way (the serving layer's
    /// invariant); exists so tests can pin exactly that.
    pub sequential: bool,
    /// Admission-queue bound for the scoring server.
    pub qdepth: usize,
    /// Maximum batch size for the scoring server.
    pub max_batch: usize,
    /// Batch linger window (µs) for the scoring server.
    pub linger_us: u64,
}

impl Default for SynthOptions {
    fn default() -> SynthOptions {
        SynthOptions {
            beam: 2,
            max_cores: 6,
            candidates: Vec::new(),
            sequential: false,
            qdepth: 64,
            max_batch: 8,
            linger_us: 8,
        }
    }
}

/// Deterministic fleet score: a total order over integers only —
/// no f64 anywhere, so equal scores are exactly equal and reruns
/// cannot drift through rounding or comparison ties.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FleetScore {
    /// Requests completed within their deadline (no deadline = met).
    pub slo_met: u64,
    /// Summed fixed-point normalized cost of the fleet (ALM
    /// equivalents; lower is better).
    pub cost: u64,
    /// Sorted config fingerprints — the final tie-break, so two
    /// distinct compositions with equal throughput and cost still
    /// order deterministically.
    pub fingerprints: Vec<u64>,
}

impl Ord for FleetScore {
    fn cmp(&self, other: &FleetScore) -> std::cmp::Ordering {
        // Greater = better: more SLO-met, then cheaper, then the
        // lexicographically smaller fingerprint multiset.
        self.slo_met
            .cmp(&other.slo_met)
            .then_with(|| other.cost.cmp(&self.cost))
            .then_with(|| other.fingerprints.cmp(&self.fingerprints))
    }
}

impl PartialOrd for FleetScore {
    fn partial_cmp(&self, other: &FleetScore) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// One homogeneous demo-fleet baseline the synthesized fleet is
/// compared against (as many copies of the demo config as the budget
/// admits, capped at `max_cores`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BaselineScore {
    pub name: String,
    pub cores: usize,
    pub slo_met: u64,
    pub cost: u64,
    /// Why the baseline scored zero, when it did ("does not fit the
    /// budget", or the serve error for a fleet the trace defeats).
    pub note: Option<String>,
}

/// The outcome of [`synthesize`]: the winning fleet plus everything
/// needed to audit the decision. `PartialEq` so reruns can be pinned
/// bit-identical.
#[derive(Debug, Clone, PartialEq)]
pub struct SynthResult {
    pub budget: AreaBudget,
    /// The winning fleet, one config per core.
    pub fleet: Vec<EgpuConfig>,
    /// Summed modeled resources of the fleet.
    pub usage: AreaUsage,
    pub score: FleetScore,
    /// Requests in the scoring trace.
    pub offered: usize,
    pub completed: u64,
    pub shed: u64,
    pub deadline_missed: u64,
    /// Candidates the feasibility filter refused, with reasons.
    pub rejected: Vec<Reject>,
    /// The homogeneous demo-fleet baselines and how they scored.
    pub baselines: Vec<BaselineScore>,
    /// Serve replays performed (memoized compositions count once).
    pub evaluated: usize,
}

impl SynthResult {
    /// The winning fleet as a `sim::config_json` fleet file —
    /// consumable by `egpu serve --configs` / `egpu fleet --configs`
    /// unchanged.
    pub fn fleet_json(&self) -> String {
        config_json::fleet_to_json(&self.fleet)
    }
}

/// Integer telemetry extracted from one scoring replay.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct ServeCard {
    slo_met: u64,
    completed: u64,
    shed: u64,
    deadline_missed: u64,
}

/// Replay the trace through a fresh server over `cfgs`. `Err` means
/// the fleet cannot serve the trace at all (e.g. no core accepts a
/// kernel's features) — scored as unservable by the caller.
fn serve_once(
    cfgs: &[EgpuConfig],
    trace: &[Request],
    opts: &SynthOptions,
    cache: &Arc<KernelCache>,
) -> Result<ServeCard, String> {
    let mut fleet = FleetBuilder::new();
    for cfg in cfgs {
        fleet = fleet.core(cfg.clone());
    }
    let mut server = Server::builder()
        .fleet(fleet)
        .kernel_cache(cache.clone())
        .qdepth(opts.qdepth)
        .max_batch(opts.max_batch)
        .linger_us(opts.linger_us)
        .sequential(opts.sequential)
        .build()
        .map_err(|e| e.to_string())?;
    let report = server.serve(trace.to_vec()).map_err(|e| e.to_string())?;
    let t = &report.telemetry;
    Ok(ServeCard {
        slo_met: t.completed.saturating_sub(t.deadline_missed),
        completed: t.completed,
        shed: t.shed,
        deadline_missed: t.deadline_missed,
    })
}

fn usage_of(key: &[usize], cands: &[Candidate]) -> AreaUsage {
    let mut u = AreaUsage::default();
    for &i in key {
        u.alms += cands[i].alms;
        u.dsps += cands[i].dsps;
        u.m20ks += cands[i].m20ks;
    }
    u
}

fn score_of(key: &[usize], cands: &[Candidate], card: ServeCard) -> FleetScore {
    let mut fps: Vec<u64> = key.iter().map(|&i| cands[i].cfg.fingerprint()).collect();
    fps.sort_unstable();
    FleetScore {
        slo_met: card.slo_met,
        cost: key.iter().map(|&i| cands[i].cost).sum(),
        fingerprints: fps,
    }
}

/// Score a composition, memoized on the canonical (sorted) index
/// multiset. `None` = unservable.
#[allow(clippy::too_many_arguments)]
fn eval(
    key: &[usize],
    cands: &[Candidate],
    trace: &[Request],
    opts: &SynthOptions,
    cache: &Arc<KernelCache>,
    memo: &mut BTreeMap<Vec<usize>, Option<(FleetScore, ServeCard)>>,
    evaluated: &mut usize,
) -> Option<(FleetScore, ServeCard)> {
    if let Some(hit) = memo.get(key) {
        return hit.clone();
    }
    let cfgs: Vec<EgpuConfig> = key.iter().map(|&i| cands[i].cfg.clone()).collect();
    *evaluated += 1;
    let out = serve_once(&cfgs, trace, opts, cache)
        .ok()
        .map(|card| (score_of(key, cands, card), card));
    memo.insert(key.to_vec(), out.clone());
    out
}

/// Greedy static cover: repeatedly add the candidate covering the most
/// still-uncovered requests (candidates are cost-sorted, so ties go to
/// the cheapest). `None` if no budget-fitting multiset covers the
/// trace.
fn greedy_cover(
    needs: &[RequestNeed],
    cands: &[Candidate],
    budget: &AreaBudget,
    max_cores: usize,
) -> Option<Vec<usize>> {
    let mut key: Vec<usize> = Vec::new();
    let mut covered = vec![false; needs.len()];
    while key.len() < max_cores && covered.iter().any(|c| !c) {
        let mut pick: Option<(usize, usize)> = None; // (gain, index)
        for (i, c) in cands.iter().enumerate() {
            let mut k2 = key.clone();
            k2.push(i);
            if !budget.admits(&usage_of(&k2, cands)) {
                continue;
            }
            let gain = needs
                .iter()
                .zip(&covered)
                .filter(|(n, done)| !**done && candidate_covers(c, n))
                .count();
            let better = match pick {
                None => gain > 0,
                Some((g, _)) => gain > g,
            };
            if better {
                pick = Some((gain, i));
            }
        }
        let (_, i) = pick?;
        for (n, done) in needs.iter().zip(covered.iter_mut()) {
            if candidate_covers(&cands[i], n) {
                *done = true;
            }
        }
        key.push(i);
    }
    if covered.iter().all(|c| *c) {
        key.sort_unstable();
        Some(key)
    } else {
        None
    }
}

/// Order beam entries: best score first, then the smaller index
/// multiset — fully deterministic.
fn rank(a: &(Vec<usize>, FleetScore), b: &(Vec<usize>, FleetScore)) -> std::cmp::Ordering {
    b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0))
}

/// Synthesize the best fleet for `trace` under `budget`. Deterministic:
/// the same inputs always return the same [`SynthResult`], including
/// under sequential vs parallel serving. Errors when no candidate fits
/// the budget or no feasible fleet can serve the trace.
pub fn synthesize(
    budget: &AreaBudget,
    trace: &[Request],
    opts: &SynthOptions,
) -> Result<SynthResult, String> {
    let beam_width = opts.beam.max(1);
    let max_cores = opts.max_cores.max(1);
    let space = if opts.candidates.is_empty() {
        candidate_space()
    } else {
        opts.candidates.clone()
    };
    let (cands, rejected) = filter_candidates(space, budget);
    if cands.is_empty() {
        return Err(format!(
            "no candidate configuration fits the budget ({budget}); \
             {} candidates rejected (see `egpu synth` output for reasons)",
            rejected.len()
        ));
    }
    let needs = request_needs(trace);
    let cache = KernelCache::shared();
    let mut memo: BTreeMap<Vec<usize>, Option<(FleetScore, ServeCard)>> = BTreeMap::new();
    let mut evaluated = 0usize;
    let mut best: Option<(Vec<EgpuConfig>, FleetScore, ServeCard)> = None;

    // Strict-improvement replacement: the first composition reaching a
    // score wins all later ties, and enumeration order is fixed, so
    // the winner is deterministic.
    fn offer(
        best: &mut Option<(Vec<EgpuConfig>, FleetScore, ServeCard)>,
        fleet: Vec<EgpuConfig>,
        score: FleetScore,
        card: ServeCard,
    ) {
        let better = match best {
            None => true,
            Some((_, incumbent, _)) => score > *incumbent,
        };
        if better {
            *best = Some((fleet, score, card));
        }
    }

    // Seed 1: every covering singleton.
    let mut beam: Vec<(Vec<usize>, FleetScore)> = Vec::new();
    for i in 0..cands.len() {
        let key = vec![i];
        if !covers(&needs, &cands, &key) {
            continue;
        }
        if let Some((score, card)) =
            eval(&key, &cands, trace, opts, &cache, &mut memo, &mut evaluated)
        {
            offer(&mut best, vec![cands[i].cfg.clone()], score.clone(), card);
            beam.push((key, score));
        }
    }

    // Seed 2: the greedy static-cover multiset (covers traces no
    // single candidate can, e.g. dot-needing plus huge-shared mixes).
    if let Some(key) = greedy_cover(&needs, &cands, budget, max_cores) {
        if let Some((score, card)) =
            eval(&key, &cands, trace, opts, &cache, &mut memo, &mut evaluated)
        {
            let fleet = key.iter().map(|&i| cands[i].cfg.clone()).collect();
            offer(&mut best, fleet, score.clone(), card);
            beam.push((key, score));
        }
    }

    // Seed 3 + reporting: the homogeneous demo-fleet baselines, at the
    // largest core count the budget admits. Scored with the same
    // replay and offered into the search, so the winner dominates both
    // baselines by construction whenever they fit the budget at all.
    let mut baselines = Vec::new();
    let mut demo_cfgs: Vec<EgpuConfig> = Vec::new();
    for cfg in FleetBuilder::demo_mixed().as_configs() {
        if !demo_cfgs.iter().any(|c: &EgpuConfig| c.name == cfg.name) {
            demo_cfgs.push(cfg.clone());
        }
    }
    for cfg in demo_cfgs {
        let r = ResourceReport::for_config(&cfg);
        let per = (r.alms as u64, r.dsps as u64, r.m20ks as u64);
        let mut k = 0usize;
        while k < max_cores {
            let next = (k + 1) as u64;
            let fits = per.0 * next <= budget.alms
                && per.1 * next <= budget.dsps
                && per.2 * next <= budget.m20ks;
            if !fits {
                break;
            }
            k += 1;
        }
        if k == 0 {
            baselines.push(BaselineScore {
                name: cfg.name.clone(),
                cores: 0,
                slo_met: 0,
                cost: 0,
                note: Some("does not fit the budget".into()),
            });
            continue;
        }
        let fleet = vec![cfg.clone(); k];
        let cost = k as u64 * config_cost_fixed(&cfg);
        evaluated += 1;
        match serve_once(&fleet, trace, opts, &cache) {
            Ok(card) => {
                baselines.push(BaselineScore {
                    name: cfg.name.clone(),
                    cores: k,
                    slo_met: card.slo_met,
                    cost,
                    note: None,
                });
                // Only a placeable fleet may win (the synthesized
                // fleet's contract); candidates are pre-filtered, the
                // demo configs are checked here.
                if place::place(&cfg).is_ok() {
                    let score = FleetScore {
                        slo_met: card.slo_met,
                        cost,
                        fingerprints: vec![cfg.fingerprint(); k],
                    };
                    offer(&mut best, fleet, score, card);
                }
            }
            Err(e) => baselines.push(BaselineScore {
                name: cfg.name.clone(),
                cores: k,
                slo_met: 0,
                cost,
                note: Some(format!("cannot serve the trace: {e}")),
            }),
        }
    }

    // Beam rounds: expand each beam composition by one candidate,
    // keeping budget fit; stop the first round with no strict
    // improvement of the global best.
    beam.sort_by(rank);
    beam.dedup_by(|a, b| a.0 == b.0);
    beam.truncate(beam_width);
    loop {
        let before = best.as_ref().map(|(_, s, _)| s.clone());
        let mut round: Vec<(Vec<usize>, FleetScore)> = Vec::new();
        for (key, _) in &beam {
            if key.len() >= max_cores {
                continue;
            }
            for i in 0..cands.len() {
                let mut k2 = key.clone();
                k2.push(i);
                k2.sort_unstable();
                if !budget.admits(&usage_of(&k2, &cands)) {
                    continue;
                }
                if round.iter().any(|(k, _)| *k == k2) {
                    continue;
                }
                if let Some((score, card)) =
                    eval(&k2, &cands, trace, opts, &cache, &mut memo, &mut evaluated)
                {
                    let fleet = k2.iter().map(|&j| cands[j].cfg.clone()).collect();
                    offer(&mut best, fleet, score.clone(), card);
                    round.push((k2, score));
                }
            }
        }
        let improved = match (&before, &best) {
            (None, Some(_)) => true,
            (Some(b), Some((_, now, _))) => now > b,
            _ => false,
        };
        if !improved || round.is_empty() {
            break;
        }
        round.sort_by(rank);
        round.truncate(beam_width);
        beam = round;
    }

    let (fleet, score, card) = best.ok_or_else(|| {
        format!(
            "no feasible fleet can serve the trace under the budget ({budget}); \
             {} of {} candidates fit",
            cands.len(),
            cands.len() + rejected.len()
        )
    })?;
    let usage = AreaUsage::of(&fleet);
    Ok(SynthResult {
        budget: *budget,
        fleet,
        usage,
        score,
        offered: trace.len(),
        completed: card.completed,
        shed: card.shed,
        deadline_missed: card.deadline_missed,
        rejected,
        baselines,
        evaluated,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn score(slo: u64, cost: u64, fps: &[u64]) -> FleetScore {
        FleetScore { slo_met: slo, cost, fingerprints: fps.to_vec() }
    }

    #[test]
    fn score_order_is_total_and_integer_only() {
        // SLO-met dominates cost.
        assert!(score(5, 99_999, &[2]) > score(4, 1, &[1]));
        // Equal SLO: cheaper wins.
        assert!(score(5, 100, &[2]) > score(5, 101, &[1]));
        // Equal SLO and cost: smaller fingerprint multiset wins.
        assert!(score(5, 100, &[1, 2]) > score(5, 100, &[1, 3]));
        // Exactly equal scores compare equal (first-seen keeps the win).
        assert_eq!(score(5, 100, &[1, 2]), score(5, 100, &[1, 2]));
    }

    #[test]
    fn default_options_mirror_the_serving_defaults() {
        let o = SynthOptions::default();
        assert_eq!((o.qdepth, o.max_batch, o.linger_us), (64, 8, 8));
        assert!(o.beam >= 1 && o.max_cores >= 1);
    }
}
