//! Deterministic beam search over fleet compositions, scored by trace
//! replay — with frontier-batched, parallel scoring.
//!
//! A composition is a multiset of feasible candidates (counts ×
//! configs). Scoring replays the offered trace through an in-process
//! [`Server`] over that fleet and reads the integer telemetry: the
//! objective is SLO-met completions (completed minus deadline misses),
//! measured in modeled bus cycles — wall-clock never enters the score,
//! so the search result is a pure function of (budget, trace, options).
//!
//! Ties break through [`FleetScore`]'s total order: more SLO-met
//! requests, then *lower* fixed-point modeled cost
//! ([`crate::model::cost::config_cost_fixed`]), then the sorted config
//! fingerprints — all integers, so equal fleets compare `==` and
//! reruns are bit-identical. A fleet whose serve replay errors (a
//! kernel no core can accept) scores as unservable and never enters
//! the beam.
//!
//! # Frontier batching and the determinism discipline
//!
//! The search does not score as it expands. Each stage — the seeding
//! wave (covering singletons + the greedy static cover), the baseline
//! wave, and every beam round — first *collects* its full frontier of
//! not-yet-memoized canonical keys (deduped, deterministic order),
//! then scores all replays at once through [`score_fleets`]-style
//! workers ([`std::thread::scope`], [`SynthOptions::jobs`] of them),
//! and only then merges the results into the memo in canonical
//! (sorted-key) order and replays the offers in the stage's fixed
//! enumeration order. Every replay is independent — fresh [`Server`],
//! shared [`Arc<KernelCache>`], integer-only [`ServeCard`] — so each
//! memo entry is a pure function of its key and the result vector
//! does not depend on worker scheduling: `jobs = 1` and `jobs = N`
//! produce bit-identical [`SynthResult`]s, including `evaluated`.
//! When `jobs > 1` the scoring servers force *sequential* fleet
//! dispatch (bit-identical by the serving layer's invariant), so the
//! thread count is bounded by `jobs` rather than `jobs × cores`.
//!
//! # Dominance pruning
//!
//! Once the incumbent achieves a perfect SLO (`slo_met == offered`),
//! any composition with strictly higher fixed-point cost is a dead
//! end: `slo_met` is bounded by `offered`, so under the [`FleetScore`]
//! order it cannot outrank the incumbent — and appending candidates
//! only adds cost, so neither can anything it expands into. Dead keys
//! are excluded from the beam in *both* pruning modes (the filter is
//! decided at round-collection time, before any scoring, from state
//! identical across `jobs` values); [`SynthOptions::prune`] only
//! controls whether their replays are skipped. The search trajectory —
//! beam contents, offers that can win, the final fleet and score — is
//! therefore identical with pruning on or off; only `evaluated`
//! shrinks.
//!
//! The search seeds the beam with every covering singleton, a greedy
//! static-cover multiset, and the homogeneous demo-fleet compositions
//! (which are also reported as baselines); expansion appends one
//! candidate at a time, keeping budget fit invariant. The loop stops
//! the first round that fails to strictly improve the best score —
//! improvement is strict in the total order and the composition space
//! is finite, so termination is guaranteed. All candidate fleets share
//! one [`KernelCache`] (internally locked, so concurrent scoring still
//! compiles each kernel once per fingerprint across the whole search),
//! and replays borrow the trace ([`Server::serve_slice`]) instead of
//! cloning it per composition.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::api::FleetBuilder;
use crate::kernels::KernelCache;
use crate::model::cost::config_cost_fixed;
use crate::model::resources::ResourceReport;
use crate::place;
use crate::serve::{Request, Server};
use crate::sim::{config_json, EgpuConfig};

use super::budget::{AreaBudget, AreaUsage};
use super::candidates::{
    candidate_covers, candidate_space, covers, filter_candidates, request_needs, Candidate, Reject,
    RequestNeed,
};

/// Knobs for one synthesis run. The defaults mirror the serving
/// runtime's ([`Server`] qdepth 64, batch 8, 8 µs linger) so the
/// score replays the same policy `egpu serve` runs.
#[derive(Debug, Clone, PartialEq)]
pub struct SynthOptions {
    /// Beam width (compositions expanded per round; ≥ 1).
    pub beam: usize,
    /// Hard cap on fleet size (cores per composition).
    pub max_cores: usize,
    /// Candidate configurations to search over; empty = the default
    /// [`candidate_space`]. Still deduped and feasibility-filtered.
    pub candidates: Vec<EgpuConfig>,
    /// Score with sequential fleet dispatch instead of parallel
    /// workers. Bit-identical result either way (the serving layer's
    /// invariant); exists so tests can pin exactly that. Forced on
    /// inside the scoring replays whenever `jobs > 1`.
    pub sequential: bool,
    /// Admission-queue bound for the scoring server.
    pub qdepth: usize,
    /// Maximum batch size for the scoring server.
    pub max_batch: usize,
    /// Batch linger window (µs) for the scoring server.
    pub linger_us: u64,
    /// Scoring worker threads per frontier wave (≥ 1; clamped up from
    /// 0). The result is bit-identical at any value — parallelism
    /// changes wall-clock only (see the module docs).
    pub jobs: usize,
    /// Skip replays of dominance-dead expansions (see the module
    /// docs). Winner-preserving by construction: disabling only adds
    /// replays (`evaluated` grows), never changes the fleet or score.
    pub prune: bool,
    /// Run each scoring replay with an event [`crate::obs::Recorder`]
    /// attached. Scoring reads only the integer telemetry, and
    /// recording never moves a modeled cycle, so the [`SynthResult`]
    /// is bit-identical with recording on or off, at any `jobs` —
    /// pinned by `rust/tests/obs_trace.rs`.
    pub recording: bool,
}

impl Default for SynthOptions {
    fn default() -> SynthOptions {
        SynthOptions {
            beam: 2,
            max_cores: 6,
            candidates: Vec::new(),
            sequential: false,
            qdepth: 64,
            max_batch: 8,
            linger_us: 8,
            jobs: 1,
            prune: true,
            recording: false,
        }
    }
}

/// Deterministic fleet score: a total order over integers only —
/// no f64 anywhere, so equal scores are exactly equal and reruns
/// cannot drift through rounding or comparison ties.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FleetScore {
    /// Requests completed within their deadline (no deadline = met).
    pub slo_met: u64,
    /// Summed fixed-point normalized cost of the fleet (ALM
    /// equivalents; lower is better).
    pub cost: u64,
    /// Sorted config fingerprints — the final tie-break, so two
    /// distinct compositions with equal throughput and cost still
    /// order deterministically.
    pub fingerprints: Vec<u64>,
}

impl Ord for FleetScore {
    fn cmp(&self, other: &FleetScore) -> std::cmp::Ordering {
        // Greater = better: more SLO-met, then cheaper, then the
        // lexicographically smaller fingerprint multiset.
        self.slo_met
            .cmp(&other.slo_met)
            .then_with(|| other.cost.cmp(&self.cost))
            .then_with(|| other.fingerprints.cmp(&self.fingerprints))
    }
}

impl PartialOrd for FleetScore {
    fn partial_cmp(&self, other: &FleetScore) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// One homogeneous demo-fleet baseline the synthesized fleet is
/// compared against (as many copies of the demo config as the budget
/// admits, capped at `max_cores`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BaselineScore {
    pub name: String,
    pub cores: usize,
    pub slo_met: u64,
    pub cost: u64,
    /// Why the baseline scored zero, when it did ("does not fit the
    /// budget", or the serve error for a fleet the trace defeats).
    pub note: Option<String>,
}

/// The outcome of [`synthesize`]: the winning fleet plus everything
/// needed to audit the decision. `PartialEq` so reruns can be pinned
/// bit-identical.
#[derive(Debug, Clone, PartialEq)]
pub struct SynthResult {
    pub budget: AreaBudget,
    /// The winning fleet, one config per core.
    pub fleet: Vec<EgpuConfig>,
    /// Summed modeled resources of the fleet.
    pub usage: AreaUsage,
    pub score: FleetScore,
    /// Requests in the scoring trace.
    pub offered: usize,
    pub completed: u64,
    pub shed: u64,
    pub deadline_missed: u64,
    /// Candidates the feasibility filter refused, with reasons.
    pub rejected: Vec<Reject>,
    /// The homogeneous demo-fleet baselines and how they scored.
    pub baselines: Vec<BaselineScore>,
    /// Serve replays performed (memoized compositions count once;
    /// pruning skips dominance-dead replays entirely).
    pub evaluated: usize,
}

impl SynthResult {
    /// The winning fleet as a `sim::config_json` fleet file —
    /// consumable by `egpu serve --configs` / `egpu fleet --configs`
    /// unchanged.
    pub fn fleet_json(&self) -> String {
        config_json::fleet_to_json(&self.fleet)
    }
}

/// Integer telemetry extracted from one scoring replay.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct ServeCard {
    slo_met: u64,
    completed: u64,
    shed: u64,
    deadline_missed: u64,
}

/// Replay the trace through a fresh server over `cfgs`. `Err` means
/// the fleet cannot serve the trace at all (e.g. no core accepts a
/// kernel's features) — scored as unservable by the caller. The trace
/// is borrowed ([`Server::serve_slice`]): scoring hundreds of
/// compositions copies input blocks only at their own dispatch
/// points, never the workload wholesale.
fn serve_once(
    cfgs: &[EgpuConfig],
    trace: &[Request],
    opts: &SynthOptions,
    cache: &Arc<KernelCache>,
) -> Result<ServeCard, String> {
    let mut fleet = FleetBuilder::new();
    for cfg in cfgs {
        fleet = fleet.core(cfg.clone());
    }
    // Bounded nested parallelism: with outer scoring workers the inner
    // dispatch runs sequentially (bit-identical either way), keeping
    // the live thread count at `jobs`, not `jobs × cores`.
    let sequential = opts.sequential || opts.jobs > 1;
    let mut server = Server::builder()
        .fleet(fleet)
        .kernel_cache(cache.clone())
        .qdepth(opts.qdepth)
        .max_batch(opts.max_batch)
        .linger_us(opts.linger_us)
        .sequential(sequential)
        .recording(opts.recording)
        .build()
        .map_err(|e| e.to_string())?;
    let report = server.serve_slice(trace).map_err(|e| e.to_string())?;
    let t = &report.telemetry;
    Ok(ServeCard {
        slo_met: t.completed.saturating_sub(t.deadline_missed),
        completed: t.completed,
        shed: t.shed,
        deadline_missed: t.deadline_missed,
    })
}

/// Replay every fleet in `fleets`, returning the cards in input
/// order. `opts.jobs > 1` scores concurrently on scoped workers that
/// pull indices from a shared counter; each replay is independent and
/// writes only its own slot, so the output is a pure function of the
/// inputs regardless of worker count or scheduling.
fn score_fleets(
    fleets: &[Vec<EgpuConfig>],
    trace: &[Request],
    opts: &SynthOptions,
    cache: &Arc<KernelCache>,
) -> Vec<Result<ServeCard, String>> {
    let jobs = opts.jobs.clamp(1, fleets.len().max(1));
    if jobs <= 1 {
        return fleets
            .iter()
            .map(|f| serve_once(f, trace, opts, cache))
            .collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<ServeCard, String>>>> =
        fleets.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..jobs {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= fleets.len() {
                    break;
                }
                let card = serve_once(&fleets[i], trace, opts, cache);
                *slots[i].lock().expect("result slot lock") = Some(card);
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .expect("result slot lock")
                .expect("every frontier index was scored")
        })
        .collect()
}

fn usage_of(key: &[usize], cands: &[Candidate]) -> AreaUsage {
    let mut u = AreaUsage::default();
    for &i in key {
        u.alms += cands[i].alms;
        u.dsps += cands[i].dsps;
        u.m20ks += cands[i].m20ks;
    }
    u
}

fn cost_of(key: &[usize], cands: &[Candidate]) -> u64 {
    key.iter().map(|&i| cands[i].cost).sum()
}

fn score_of(key: &[usize], cands: &[Candidate], card: ServeCard) -> FleetScore {
    let mut fps: Vec<u64> = key.iter().map(|&i| cands[i].cfg.fingerprint()).collect();
    fps.sort_unstable();
    FleetScore {
        slo_met: card.slo_met,
        cost: cost_of(key, cands),
        fingerprints: fps,
    }
}

/// Score every not-yet-memoized key of `frontier` in one wave and
/// merge the results into the memo in canonical (sorted) key order.
/// `evaluated` counts actual replays — memo hits cost nothing. The
/// merge order is fixed and each entry is a pure function of its key,
/// so the memo (and every count) is identical at any `jobs` value.
#[allow(clippy::too_many_arguments)]
fn eval_frontier(
    frontier: &[Vec<usize>],
    cands: &[Candidate],
    trace: &[Request],
    opts: &SynthOptions,
    cache: &Arc<KernelCache>,
    memo: &mut BTreeMap<Vec<usize>, Option<(FleetScore, ServeCard)>>,
    evaluated: &mut usize,
) {
    let mut todo: Vec<&Vec<usize>> = frontier
        .iter()
        .filter(|k| !memo.contains_key(k.as_slice()))
        .collect();
    todo.sort();
    todo.dedup();
    let fleets: Vec<Vec<EgpuConfig>> = todo
        .iter()
        .map(|key| key.iter().map(|&i| cands[i].cfg.clone()).collect())
        .collect();
    let cards = score_fleets(&fleets, trace, opts, cache);
    for (key, card) in todo.into_iter().zip(cards) {
        *evaluated += 1;
        let out = card.ok().map(|c| (score_of(key, cands, c), c));
        memo.insert(key.clone(), out);
    }
}

/// Greedy static cover: repeatedly add the candidate covering the most
/// still-uncovered requests (candidates are cost-sorted, so ties go to
/// the cheapest). `None` if no budget-fitting multiset covers the
/// trace.
fn greedy_cover(
    needs: &[RequestNeed],
    cands: &[Candidate],
    budget: &AreaBudget,
    max_cores: usize,
) -> Option<Vec<usize>> {
    let mut key: Vec<usize> = Vec::new();
    let mut covered = vec![false; needs.len()];
    while key.len() < max_cores && covered.iter().any(|c| !c) {
        let mut pick: Option<(usize, usize)> = None; // (gain, index)
        for (i, c) in cands.iter().enumerate() {
            let mut k2 = key.clone();
            k2.push(i);
            if !budget.admits(&usage_of(&k2, cands)) {
                continue;
            }
            let gain = needs
                .iter()
                .zip(&covered)
                .filter(|(n, done)| !**done && candidate_covers(c, n))
                .count();
            let better = match pick {
                None => gain > 0,
                Some((g, _)) => gain > g,
            };
            if better {
                pick = Some((gain, i));
            }
        }
        let (_, i) = pick?;
        for (n, done) in needs.iter().zip(covered.iter_mut()) {
            if candidate_covers(&cands[i], n) {
                *done = true;
            }
        }
        key.push(i);
    }
    if covered.iter().all(|c| *c) {
        key.sort_unstable();
        Some(key)
    } else {
        None
    }
}

/// Order beam entries: best score first, then the smaller index
/// multiset — fully deterministic.
fn rank(a: &(Vec<usize>, FleetScore), b: &(Vec<usize>, FleetScore)) -> std::cmp::Ordering {
    b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0))
}

/// Synthesize the best fleet for `trace` under `budget`. Deterministic:
/// the same inputs always return the same [`SynthResult`], including
/// under sequential vs parallel serving, at any [`SynthOptions::jobs`]
/// value, and with dominance pruning on or off (pruning only shrinks
/// `evaluated`). Errors when no candidate fits the budget or no
/// feasible fleet can serve the trace.
pub fn synthesize(
    budget: &AreaBudget,
    trace: &[Request],
    opts: &SynthOptions,
) -> Result<SynthResult, String> {
    let beam_width = opts.beam.max(1);
    let max_cores = opts.max_cores.max(1);
    let space = if opts.candidates.is_empty() {
        candidate_space()
    } else {
        opts.candidates.clone()
    };
    let (cands, rejected) = filter_candidates(space, budget);
    if cands.is_empty() {
        return Err(format!(
            "no candidate configuration fits the budget ({budget}); \
             {} candidates rejected (see `egpu synth` output for reasons)",
            rejected.len()
        ));
    }
    let needs = request_needs(trace);
    let cache = KernelCache::shared();
    let mut memo: BTreeMap<Vec<usize>, Option<(FleetScore, ServeCard)>> = BTreeMap::new();
    let mut evaluated = 0usize;
    let mut best: Option<(Vec<EgpuConfig>, FleetScore, ServeCard)> = None;

    // Strict-improvement replacement: the first composition reaching a
    // score wins all later ties, and enumeration order is fixed, so
    // the winner is deterministic.
    fn offer(
        best: &mut Option<(Vec<EgpuConfig>, FleetScore, ServeCard)>,
        fleet: Vec<EgpuConfig>,
        score: FleetScore,
        card: ServeCard,
    ) {
        let better = match best {
            None => true,
            Some((_, incumbent, _)) => score > *incumbent,
        };
        if better {
            *best = Some((fleet, score, card));
        }
    }

    // Seeding wave: every covering singleton plus the greedy
    // static-cover multiset (covers traces no single candidate can,
    // e.g. dot-needing plus huge-shared mixes), collected first and
    // scored in one parallel frontier.
    let greedy = greedy_cover(&needs, &cands, budget, max_cores);
    let mut seeds: Vec<Vec<usize>> = Vec::new();
    for i in 0..cands.len() {
        let key = vec![i];
        if covers(&needs, &cands, &key) {
            seeds.push(key);
        }
    }
    if let Some(key) = &greedy {
        seeds.push(key.clone());
    }
    eval_frontier(&seeds, &cands, trace, opts, &cache, &mut memo, &mut evaluated);

    // Offers replay in the fixed enumeration order (singletons by
    // candidate index, then the greedy cover), exactly as the
    // sequential scorer visits them.
    let mut beam: Vec<(Vec<usize>, FleetScore)> = Vec::new();
    for i in 0..cands.len() {
        let key = vec![i];
        if !covers(&needs, &cands, &key) {
            continue;
        }
        if let Some((score, card)) = memo.get(&key).cloned().flatten() {
            offer(&mut best, vec![cands[i].cfg.clone()], score.clone(), card);
            beam.push((key, score));
        }
    }
    if let Some(key) = greedy {
        if let Some((score, card)) = memo.get(&key).cloned().flatten() {
            let fleet = key.iter().map(|&i| cands[i].cfg.clone()).collect();
            offer(&mut best, fleet, score.clone(), card);
            beam.push((key, score));
        }
    }

    // Baseline wave + reporting: the homogeneous demo-fleet baselines,
    // at the largest core count the budget admits, scored as one
    // parallel frontier with the same replay and offered into the
    // search — so the winner dominates both baselines by construction
    // whenever they fit the budget at all. Baselines are scored
    // unconditionally (never memoized), mirroring their report role.
    let mut baselines = Vec::new();
    let mut demo_cfgs: Vec<EgpuConfig> = Vec::new();
    for cfg in FleetBuilder::demo_mixed().as_configs() {
        if !demo_cfgs.iter().any(|c: &EgpuConfig| c.name == cfg.name) {
            demo_cfgs.push(cfg.clone());
        }
    }
    // (config, cores, cost, index into the scored wave — None when the
    // budget admits zero cores.)
    let mut cases: Vec<(EgpuConfig, usize, u64, Option<usize>)> = Vec::new();
    let mut wave: Vec<Vec<EgpuConfig>> = Vec::new();
    for cfg in demo_cfgs {
        let r = ResourceReport::for_config(&cfg);
        let per = (r.alms as u64, r.dsps as u64, r.m20ks as u64);
        let mut k = 0usize;
        while k < max_cores {
            let next = (k + 1) as u64;
            let fits = per.0 * next <= budget.alms
                && per.1 * next <= budget.dsps
                && per.2 * next <= budget.m20ks;
            if !fits {
                break;
            }
            k += 1;
        }
        let wave_idx = if k > 0 {
            wave.push(vec![cfg.clone(); k]);
            Some(wave.len() - 1)
        } else {
            None
        };
        let cost = k as u64 * config_cost_fixed(&cfg);
        cases.push((cfg, k, cost, wave_idx));
    }
    let wave_cards = score_fleets(&wave, trace, opts, &cache);
    for (cfg, k, cost, wave_idx) in cases {
        let Some(idx) = wave_idx else {
            baselines.push(BaselineScore {
                name: cfg.name.clone(),
                cores: 0,
                slo_met: 0,
                cost: 0,
                note: Some("does not fit the budget".into()),
            });
            continue;
        };
        evaluated += 1;
        match &wave_cards[idx] {
            Ok(card) => {
                baselines.push(BaselineScore {
                    name: cfg.name.clone(),
                    cores: k,
                    slo_met: card.slo_met,
                    cost,
                    note: None,
                });
                // Only a placeable fleet may win (the synthesized
                // fleet's contract); candidates are pre-filtered, the
                // demo configs are checked here.
                if place::place(&cfg).is_ok() {
                    let score = FleetScore {
                        slo_met: card.slo_met,
                        cost,
                        fingerprints: vec![cfg.fingerprint(); k],
                    };
                    offer(&mut best, vec![cfg.clone(); k], score, *card);
                }
            }
            Err(e) => baselines.push(BaselineScore {
                name: cfg.name.clone(),
                cores: k,
                slo_met: 0,
                cost,
                note: Some(format!("cannot serve the trace: {e}")),
            }),
        }
    }

    // Beam rounds: collect the round's frontier (each beam composition
    // extended by one candidate, budget fit invariant, deduped in
    // first-appearance order), score it as one wave, then replay the
    // offers in that same order; stop the first round with no strict
    // improvement of the global best. The dominance filter is decided
    // here — before any scoring, from state fixed at round start — so
    // it is identical across `jobs` values and pruning modes.
    beam.sort_by(rank);
    beam.dedup_by(|a, b| a.0 == b.0);
    beam.truncate(beam_width);
    loop {
        let before = best.as_ref().map(|(_, s, _)| s.clone());
        // A perfect incumbent (every offered request met its SLO)
        // makes any strictly costlier composition — and, since
        // expansion only adds cost, its whole subtree — unable to win.
        let perfect_cost: Option<u64> = best
            .as_ref()
            .filter(|(_, s, _)| s.slo_met == trace.len() as u64)
            .map(|(_, s, _)| s.cost);
        let mut seen: BTreeSet<Vec<usize>> = BTreeSet::new();
        // (key, dominated): dominated keys never enter the beam in
        // either mode; with pruning on they are not even generated
        // (their replay is skipped), with pruning off they are scored
        // and offered — harmlessly, they cannot outrank the incumbent.
        let mut frontier: Vec<(Vec<usize>, bool)> = Vec::new();
        for (key, _) in &beam {
            if key.len() >= max_cores {
                continue;
            }
            for i in 0..cands.len() {
                let mut k2 = key.clone();
                k2.push(i);
                k2.sort_unstable();
                if seen.contains(&k2) {
                    continue;
                }
                if !budget.admits(&usage_of(&k2, &cands)) {
                    continue;
                }
                seen.insert(k2.clone());
                let dominated = perfect_cost.is_some_and(|c| cost_of(&k2, &cands) > c);
                if dominated && opts.prune {
                    continue;
                }
                frontier.push((k2, dominated));
            }
        }
        let keys: Vec<Vec<usize>> = frontier.iter().map(|(k, _)| k.clone()).collect();
        eval_frontier(&keys, &cands, trace, opts, &cache, &mut memo, &mut evaluated);

        let mut round: Vec<(Vec<usize>, FleetScore)> = Vec::new();
        for (k2, dominated) in frontier {
            let Some((score, card)) = memo.get(&k2).cloned().flatten() else {
                continue;
            };
            let fleet = k2.iter().map(|&j| cands[j].cfg.clone()).collect();
            offer(&mut best, fleet, score.clone(), card);
            if !dominated {
                round.push((k2, score));
            }
        }
        let improved = match (&before, &best) {
            (None, Some(_)) => true,
            (Some(b), Some((_, now, _))) => now > b,
            _ => false,
        };
        if !improved || round.is_empty() {
            break;
        }
        round.sort_by(rank);
        round.truncate(beam_width);
        beam = round;
    }

    let (fleet, score, card) = best.ok_or_else(|| {
        format!(
            "no feasible fleet can serve the trace under the budget ({budget}); \
             {} of {} candidates fit",
            cands.len(),
            cands.len() + rejected.len()
        )
    })?;
    let usage = AreaUsage::of(&fleet);
    Ok(SynthResult {
        budget: *budget,
        fleet,
        usage,
        score,
        offered: trace.len(),
        completed: card.completed,
        shed: card.shed,
        deadline_missed: card.deadline_missed,
        rejected,
        baselines,
        evaluated,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn score(slo: u64, cost: u64, fps: &[u64]) -> FleetScore {
        FleetScore { slo_met: slo, cost, fingerprints: fps.to_vec() }
    }

    #[test]
    fn score_order_is_total_and_integer_only() {
        // SLO-met dominates cost.
        assert!(score(5, 99_999, &[2]) > score(4, 1, &[1]));
        // Equal SLO: cheaper wins.
        assert!(score(5, 100, &[2]) > score(5, 101, &[1]));
        // Equal SLO and cost: smaller fingerprint multiset wins.
        assert!(score(5, 100, &[1, 2]) > score(5, 100, &[1, 3]));
        // Exactly equal scores compare equal (first-seen keeps the win).
        assert_eq!(score(5, 100, &[1, 2]), score(5, 100, &[1, 2]));
    }

    #[test]
    fn default_options_mirror_the_serving_defaults() {
        let o = SynthOptions::default();
        assert_eq!((o.qdepth, o.max_batch, o.linger_us), (64, 8, 8));
        assert!(o.beam >= 1 && o.max_cores >= 1);
        // Sequential scorer + pruning by default: `jobs` is an opt-in
        // wall-clock knob, never a semantic one.
        assert_eq!((o.jobs, o.prune), (1, true));
    }
}
