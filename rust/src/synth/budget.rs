//! Area budgets and fleet-level usage accounting.
//!
//! A budget is a pool of Agilex fabric resources — ALMs, DSP blocks,
//! M20K memories — the synthesized fleet must fit inside. Usage is the
//! per-resource sum of [`ResourceReport::for_config`] over the fleet's
//! cores; fitting is checked per resource (a fleet that is under on
//! ALMs but over on M20Ks does not fit). Geometry feasibility of each
//! *individual* core is the placer's job ([`crate::place::place`]);
//! the budget only pools totals, exactly like the paper's Table 4/5
//! device-level accounting.

use std::fmt;

use crate::model::resources::ResourceReport;
use crate::sim::EgpuConfig;

/// An Agilex area budget the synthesized fleet must fit inside.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AreaBudget {
    /// Adaptive logic modules available to the fleet.
    pub alms: u64,
    /// DSP blocks available to the fleet.
    pub dsps: u64,
    /// M20K memory blocks available to the fleet.
    pub m20ks: u64,
}

impl AreaBudget {
    /// The demo budget `egpu synth` defaults to: roughly two and a half
    /// Agilex sectors of logic with the matching embedded columns —
    /// enough for the reference 2×DP + 2×QP demo fleet (~35.4k ALMs,
    /// 112 DSPs, 1036 M20Ks) plus headroom, so the search has real
    /// choices to make rather than being forced into one composition.
    pub fn demo() -> AreaBudget {
        AreaBudget {
            alms: 40_000,
            dsps: 128,
            m20ks: 1_200,
        }
    }

    /// Does `usage` fit this budget on every resource?
    pub fn admits(&self, usage: &AreaUsage) -> bool {
        usage.alms <= self.alms && usage.dsps <= self.dsps && usage.m20ks <= self.m20ks
    }
}

impl fmt::Display for AreaBudget {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} ALMs / {} DSPs / {} M20Ks", self.alms, self.dsps, self.m20ks)
    }
}

/// Per-resource totals of a fleet (the summed [`ResourceReport`]s).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AreaUsage {
    pub alms: u64,
    pub dsps: u64,
    pub m20ks: u64,
}

impl AreaUsage {
    /// Sum the modeled resources of a fleet.
    pub fn of(cfgs: &[EgpuConfig]) -> AreaUsage {
        let mut u = AreaUsage::default();
        for cfg in cfgs {
            u.add(&ResourceReport::for_config(cfg));
        }
        u
    }

    /// Accumulate one core's report.
    pub fn add(&mut self, r: &ResourceReport) {
        self.alms += r.alms as u64;
        self.dsps += r.dsps as u64;
        self.m20ks += r.m20ks as u64;
    }
}

impl fmt::Display for AreaUsage {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} ALMs / {} DSPs / {} M20Ks", self.alms, self.dsps, self.m20ks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::FleetBuilder;

    #[test]
    fn fit_is_checked_per_resource() {
        let b = AreaBudget { alms: 100, dsps: 10, m20ks: 10 };
        assert!(b.admits(&AreaUsage { alms: 100, dsps: 10, m20ks: 10 }));
        assert!(!b.admits(&AreaUsage { alms: 101, dsps: 0, m20ks: 0 }));
        assert!(!b.admits(&AreaUsage { alms: 0, dsps: 11, m20ks: 0 }));
        assert!(!b.admits(&AreaUsage { alms: 0, dsps: 0, m20ks: 11 }));
    }

    #[test]
    fn demo_budget_admits_the_demo_fleet() {
        // The reference serving fleet must fit the default budget —
        // otherwise the homogeneous baselines `egpu synth` reports
        // against would be vacuous.
        let usage = AreaUsage::of(FleetBuilder::demo_mixed().as_configs());
        assert!(AreaBudget::demo().admits(&usage), "demo fleet needs {usage}");
    }

    #[test]
    fn usage_sums_reports() {
        let cfgs = FleetBuilder::demo_mixed().as_configs().to_vec();
        let total = AreaUsage::of(&cfgs);
        let by_hand: u64 = cfgs
            .iter()
            .map(|c| ResourceReport::for_config(c).alms as u64)
            .sum();
        assert_eq!(total.alms, by_hand);
    }
}
