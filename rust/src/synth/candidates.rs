//! Candidate enumeration and feasibility filtering.
//!
//! The candidate space walks the paper's static-scalability axes —
//! memory organization (DP/QP), registers per thread, thread space,
//! and the feature set (predicates, dot core, shared-memory size) —
//! as concrete [`EgpuConfig`]s derived from the §7 benchmark
//! configuration. Every candidate then passes two feasibility gates
//! before the search may use it:
//!
//! 1. **Resource fit** — [`ResourceReport::for_config`] must fit the
//!    [`AreaBudget`] on every resource (a candidate alone can already
//!    be too big).
//! 2. **Placement** — [`crate::place::place`] must produce a legal
//!    sector placement; a config the placer refuses is rejected with
//!    the placer's own reason ([`crate::place::PlaceError`]), never
//!    silently skipped.
//!
//! Duplicates are collapsed before filtering. Two candidates are
//! duplicates when they share a compile fingerprint
//! ([`EgpuConfig::fingerprint`] — the axes that change compiled code)
//! *and* every serving-relevant axis (threads, shared size, predicate
//! depth, dot/SFU, ALU class); the fingerprint alone deliberately
//! ignores those axes so the [`crate::kernels::KernelCache`] can share
//! compiles across them.

use std::collections::BTreeSet;

use crate::model::cost::config_cost_fixed;
use crate::model::resources::ResourceReport;
use crate::place;
use crate::serve::Request;
use crate::sim::{EgpuConfig, MemoryMode};

use super::budget::AreaBudget;

/// One budget- and placement-feasible candidate configuration, with
/// its modeled resources and fixed-point cost attached so the search
/// never re-derives them.
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    pub cfg: EgpuConfig,
    pub alms: u64,
    pub dsps: u64,
    pub m20ks: u64,
    /// Fixed-point normalized cost ([`config_cost_fixed`]).
    pub cost: u64,
}

/// A candidate the feasibility filter refused, with the reason —
/// validation, budget overflow, or the placer's `placement: …` error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Reject {
    pub name: String,
    pub reason: String,
}

/// Feature tiers layered over the (memory × regs × threads) axes:
/// shared-memory size plus the predicate/dot extensions. `full128`
/// reproduces the demo fleet's DP core feature-for-feature; `plain128`
/// its QP core — so the homogeneous demo baselines are inside the
/// space by construction.
const TIERS: [(&str, usize, usize, bool); 5] = [
    ("plain32", 32, 0, false),
    ("plain128", 128, 0, false),
    ("pred32", 32, 8, false),
    ("dot32", 32, 0, true),
    ("full128", 128, 8, true),
];

/// Enumerate the default candidate space: memory {DP, QP} × regs/thread
/// {16, 32} × threads {256, 512} × the five feature tiers = 40
/// configurations. 64-register layouts are excluded by default: they
/// serve the same workloads as 32-register ones at strictly higher
/// modeled cost, so they only widen the search without adding winners.
pub fn candidate_space() -> Vec<EgpuConfig> {
    let mut out = Vec::new();
    for memory in [MemoryMode::Dp, MemoryMode::Qp] {
        for regs in [16usize, 32] {
            for threads in [256usize, 512] {
                for (key, shared_kb, pred, dot) in TIERS {
                    let mut cfg = EgpuConfig::benchmark(memory, dot);
                    cfg.threads = threads;
                    cfg.regs_per_thread = regs;
                    cfg.shared_kb = shared_kb;
                    cfg.predicate_levels = pred;
                    cfg.name = format!("{}-{threads}t-{regs}r-{key}", memory.name());
                    out.push(cfg);
                }
            }
        }
    }
    out
}

/// The axes that make two candidates interchangeable for both
/// compilation and serving (see module docs).
fn dedup_key(cfg: &EgpuConfig) -> String {
    format!(
        "{:016x}/{}/{}/{}/{}/{}/{}/{}/{}",
        cfg.fingerprint(),
        cfg.threads,
        cfg.shared_kb,
        cfg.predicate_levels,
        cfg.dot_core,
        cfg.sfu,
        cfg.alu_precision,
        cfg.shift_precision,
        cfg.int_alu.name(),
    )
}

/// Validate, dedup, and feasibility-filter a candidate list against the
/// budget. Returns the surviving candidates in deterministic order
/// (cheapest first, then fingerprint, then name) plus every rejection
/// with its reason.
pub fn filter_candidates(
    space: Vec<EgpuConfig>,
    budget: &AreaBudget,
) -> (Vec<Candidate>, Vec<Reject>) {
    let mut seen = BTreeSet::new();
    let mut fit = Vec::new();
    let mut rejected = Vec::new();
    for cfg in space {
        if let Err(e) = cfg.validate() {
            rejected.push(Reject { name: cfg.name.clone(), reason: e.to_string() });
            continue;
        }
        if !seen.insert(dedup_key(&cfg)) {
            continue; // true duplicate of an earlier candidate
        }
        let r = ResourceReport::for_config(&cfg);
        let (alms, dsps, m20ks) = (r.alms as u64, r.dsps as u64, r.m20ks as u64);
        if alms > budget.alms || dsps > budget.dsps || m20ks > budget.m20ks {
            rejected.push(Reject {
                name: cfg.name.clone(),
                reason: format!(
                    "exceeds the budget: needs {alms} ALMs / {dsps} DSPs / {m20ks} M20Ks \
                     against {budget}"
                ),
            });
            continue;
        }
        if let Err(e) = place::place(&cfg) {
            // PlaceError displays as "placement: <reason>" — surfaced
            // verbatim so the CLI reports why the placer refused.
            rejected.push(Reject { name: cfg.name.clone(), reason: e.to_string() });
            continue;
        }
        let cost = config_cost_fixed(&cfg);
        fit.push(Candidate { cfg, alms, dsps, m20ks, cost });
    }
    fit.sort_by(|a, b| {
        a.cost
            .cmp(&b.cost)
            .then_with(|| a.cfg.fingerprint().cmp(&b.cfg.fingerprint()))
            .then_with(|| a.cfg.name.cmp(&b.cfg.name))
    });
    (fit, rejected)
}

/// What one request statically demands of a core: enough shared memory
/// for its loads/unloads, and the predicate/dot extensions its kernel
/// generator is built on. Used only to *seed* the search with fleets
/// that can plausibly serve the trace — actual servability is decided
/// by the serve replay (feature routing knows more than this summary).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestNeed {
    pub words: usize,
    pub dot: bool,
    pub pred: bool,
}

/// Summarize each request in the trace.
pub(crate) fn request_needs(trace: &[Request]) -> Vec<RequestNeed> {
    trace
        .iter()
        .map(|r| {
            let loads = r.loads.iter().map(|(b, d)| b + d.len()).max().unwrap_or(0);
            let unloads = r.unloads.iter().map(|(b, l)| b + l).max().unwrap_or(0);
            let gen = r.spec.generator();
            RequestNeed {
                words: loads.max(unloads),
                dot: matches!(gen, "reduction-dot" | "mmm-dot"),
                pred: matches!(gen, "reduction-pred" | "bitonic"),
            }
        })
        .collect()
}

/// Can this candidate statically accept the request?
pub(crate) fn candidate_covers(c: &Candidate, n: &RequestNeed) -> bool {
    (!n.dot || c.cfg.dot_core)
        && (!n.pred || c.cfg.predicate_levels > 0)
        && c.cfg.shared_words() >= n.words
}

/// Does the fleet (as candidate indices) statically cover every request?
pub(crate) fn covers(needs: &[RequestNeed], cands: &[Candidate], key: &[usize]) -> bool {
    needs.iter().all(|n| key.iter().any(|&i| candidate_covers(&cands[i], n)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::FleetBuilder;

    #[test]
    fn space_contains_the_demo_fleet_shapes() {
        // The demo fleet's cores must exist in the space up to naming —
        // same fingerprint and same serving-relevant axes — so the
        // search can always rediscover the homogeneous baselines.
        let space = candidate_space();
        for demo in FleetBuilder::demo_mixed().as_configs() {
            assert!(
                space.iter().any(|c| dedup_key(c) == dedup_key(demo)),
                "{} has no equivalent candidate",
                demo.name
            );
        }
    }

    #[test]
    fn duplicates_collapse_and_rejects_carry_reasons() {
        let budget = AreaBudget::demo();
        let mut space = candidate_space();
        let n = space.len();
        space.extend(candidate_space()); // every candidate duplicated
        let (fit, rejected) = filter_candidates(space, &budget);
        assert!(fit.len() <= n, "duplicates must collapse");
        assert!(!fit.is_empty());
        for r in &rejected {
            assert!(!r.reason.is_empty(), "{} rejected without a reason", r.name);
        }
        // Deterministic order: cost is non-decreasing.
        assert!(fit.windows(2).all(|w| w[0].cost <= w[1].cost));
    }

    #[test]
    fn over_budget_candidates_are_rejected_with_the_shortfall() {
        let tiny = AreaBudget { alms: 1_000, dsps: 8, m20ks: 16 };
        let (fit, rejected) = filter_candidates(candidate_space(), &tiny);
        assert!(fit.is_empty(), "nothing fits a 1k-ALM budget");
        assert!(rejected.iter().all(|r| r.reason.contains("exceeds the budget")));
    }
}
