//! Pipeline hazard checking (paper §3: "The eGPU has a very short pipeline
//! (8 stages) ... hazards are hidden for most programs. Consequently, we
//! do not provide hardware support for tracking hazards").
//!
//! The machine executes functionally in order, so results are always
//! architecturally correct; this module answers the question the hardware
//! does NOT: *would this program have read stale data on the real 8-stage
//! pipeline?* Program generators use it to place the same NOPs a
//! programmer would (the NOP bars of Figure 6), and the benchmark tests
//! assert their programs are hazard-free.
//!
//! Model: a writer instruction starting issue at cycle `c` makes register
//! `r` visible to a reader starting at `c + REG_WINDOW` (per-wavefront
//! skew cancels because reader and writer stream wavefronts in the same
//! order). Extension-core results have a longer window; stores complete
//! their last shared-memory write shortly after their last arbitration
//! slot.

/// Register RAW window: writeback (stage 8) to operand fetch (stage 2).
pub const REG_WINDOW: u64 = 6;

/// Dot-product / SUM core result latency beyond its operand streaming.
pub const DOT_WINDOW: u64 = 16;

/// Shared-memory write-to-read turnaround after the last write slot.
pub const MEM_WINDOW: u64 = 2;

/// One recorded would-be hazard.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub pc: usize,
    /// Register index or shared-memory address.
    pub resource: u32,
    pub is_mem: bool,
    /// How many cycles too early the read started (NOPs needed).
    pub deficit: u64,
}

#[derive(Debug, Clone)]
pub struct HazardChecker {
    /// Cycle at which each architectural register becomes readable.
    reg_ready: Vec<u64>,
    /// Cycle at which each shared-memory word becomes readable.
    mem_ready: Vec<u64>,
    pub total: u64,
    pub samples: Vec<Violation>,
    enabled: bool,
}

const MAX_SAMPLES: usize = 32;

impl HazardChecker {
    pub fn new(num_regs: usize, shared_words: usize) -> HazardChecker {
        HazardChecker {
            reg_ready: vec![0; num_regs],
            mem_ready: vec![0; shared_words],
            total: 0,
            samples: Vec::new(),
            enabled: true,
        }
    }

    /// Disable checking (perf runs where the program is already verified).
    pub fn set_enabled(&mut self, on: bool) {
        self.enabled = on;
    }

    /// Is checking on? Lets hot loops hoist the gate instead of paying a
    /// call-and-branch per lane.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn reset(&mut self) {
        self.reg_ready.fill(0);
        self.mem_ready.fill(0);
        self.total = 0;
        self.samples.clear();
    }

    #[inline]
    pub fn read_reg(&mut self, pc: usize, r: u8, now: u64) {
        if !self.enabled {
            return;
        }
        let ready = self.reg_ready[r as usize];
        if now < ready {
            self.record(Violation {
                pc,
                resource: r as u32,
                is_mem: false,
                deficit: ready - now,
            });
        }
    }

    /// Register written by an instruction that started issue at `start`,
    /// visible `window` cycles later.
    #[inline]
    pub fn write_reg(&mut self, r: u8, start: u64, window: u64) {
        if !self.enabled {
            return;
        }
        let ready = start + window;
        if ready > self.reg_ready[r as usize] {
            self.reg_ready[r as usize] = ready;
        }
    }

    #[inline]
    pub fn read_mem(&mut self, pc: usize, addr: u32, now: u64) {
        if !self.enabled {
            return;
        }
        if let Some(&ready) = self.mem_ready.get(addr as usize) {
            if now < ready {
                self.record(Violation {
                    pc,
                    resource: addr,
                    is_mem: true,
                    deficit: ready - now,
                });
            }
        }
    }

    #[inline]
    pub fn write_mem(&mut self, addr: u32, ready: u64) {
        if !self.enabled {
            return;
        }
        if let Some(slot) = self.mem_ready.get_mut(addr as usize) {
            if ready > *slot {
                *slot = ready;
            }
        }
    }

    fn record(&mut self, v: Violation) {
        self.total += 1;
        if self.samples.len() < MAX_SAMPLES {
            self.samples.push(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_within_window_flags() {
        let mut h = HazardChecker::new(32, 64);
        h.write_reg(3, 100, REG_WINDOW);
        h.read_reg(1, 3, 102); // 4 cycles early
        assert_eq!(h.total, 1);
        assert_eq!(h.samples[0].deficit, 4);
        assert!(!h.samples[0].is_mem);
    }

    #[test]
    fn raw_outside_window_clean() {
        let mut h = HazardChecker::new(32, 64);
        h.write_reg(3, 100, REG_WINDOW);
        h.read_reg(1, 3, 106);
        h.read_reg(1, 4, 100); // different register
        assert_eq!(h.total, 0);
    }

    #[test]
    fn deep_wavefront_instruction_hides_hazard() {
        // A 32-wavefront writer issued at c=0 followed immediately by a
        // reader at c=32 is clean: 32 issue cycles > the 6-cycle window.
        let mut h = HazardChecker::new(32, 64);
        h.write_reg(5, 0, REG_WINDOW);
        h.read_reg(1, 5, 32);
        assert_eq!(h.total, 0);
        // An MCU-mode (1-wavefront) writer at c=0, reader at c=1: hazard.
        h.write_reg(6, 0, REG_WINDOW);
        h.read_reg(2, 6, 1);
        assert_eq!(h.total, 1);
    }

    #[test]
    fn dot_needs_longer_window() {
        let mut h = HazardChecker::new(32, 64);
        h.write_reg(7, 0, DOT_WINDOW);
        h.read_reg(1, 7, 8);
        assert_eq!(h.total, 1);
        assert_eq!(h.samples[0].deficit, 8);
    }

    #[test]
    fn mem_turnaround() {
        let mut h = HazardChecker::new(32, 64);
        h.write_mem(10, 50);
        h.read_mem(1, 10, 49);
        h.read_mem(1, 10, 50);
        h.read_mem(1, 11, 0);
        assert_eq!(h.total, 1);
        assert!(h.samples[0].is_mem);
    }

    #[test]
    fn disabled_checker_records_nothing() {
        let mut h = HazardChecker::new(8, 8);
        h.set_enabled(false);
        h.write_reg(1, 0, REG_WINDOW);
        h.read_reg(0, 1, 0);
        assert_eq!(h.total, 0);
    }

    #[test]
    fn sample_cap() {
        let mut h = HazardChecker::new(8, 8);
        for i in 0..100 {
            h.write_reg(1, i * 10, REG_WINDOW);
            h.read_reg(0, 1, i * 10);
        }
        assert_eq!(h.total, 100);
        assert_eq!(h.samples.len(), MAX_SAMPLES);
    }
}
