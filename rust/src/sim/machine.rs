//! The streaming multiprocessor: fetch → decode → issue loop with the
//! paper's cycle model (§6 of DESIGN.md).
//!
//! One `Machine` is one eGPU core: 16 SPs, the configured thread space,
//! shared memory, optional predicate blocks and extension cores. The
//! *coordination* (sequencer, thread-space subsetting, port arbitration,
//! predicates, cycle accounting) is here; the *datapath* is either inlined
//! native lane functions or a pluggable [`BlockExec`] backend driving the
//! AOT-compiled XLA artifacts.
//!
//! Execution is plan-driven: every instruction is compiled once into an
//! [`IssuePlan`] (see [`super::plan`]) so [`Machine::run`]'s hot loop is
//! fetch-plan → execute-lanes → charge, with classification, operand
//! shape, geometry and profiler-slot lookups all resolved ahead of time.
//! On top of the plans sit *superplans* ([`super::plan::Superplan`]):
//! straight-line plan runs fused into traces whose per-op charges and
//! profiler deltas are resolved at compile time, so the hot loop becomes
//! fetch-superplan → execute-trace → charge, with per-instruction
//! dispatch surviving only at control flow and budget-tight boundaries.
//! [`Machine::run_reference`] retains the original per-instruction
//! re-deriving interpreter as the differential-testing oracle
//! (`rust/tests/asm_sim_properties.rs`).

use std::sync::Arc;

use crate::asm::Program;
use crate::datapath::{classify, native, BlockExec, DpOp, FpOp, IntOp};
use crate::isa::{CondCode, DepthSel, Group, Instr, Opcode, TType, WAVEFRONT_WIDTH};

use super::config::EgpuConfig;
use super::hazard::{HazardChecker, DOT_WINDOW, MEM_WINDOW, REG_WINDOW};
use super::plan::{self, IssuePlan, PlanKind};
use super::predicate::PredicateFile;
use super::profiler::Profile;
use super::regfile::RegFile;
use super::sequencer::Sequencer;
use super::shared_mem::SharedMem;

/// Pipeline depth (§3: "a very short pipeline (8 stages)"); charged as the
/// drain cost of STOP.
pub const PIPELINE_DEPTH: u64 = 8;

/// Simulation error, annotated with the faulting PC. Cycle-budget stops
/// additionally carry the progress made before the budget ran out in
/// [`SimError::partial`], so callers can surface cycles/profile/hazards
/// instead of discarding them.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimError {
    pub pc: usize,
    pub message: String,
    /// Partial [`RunStats`] at the point of failure (present on
    /// cycle-limit stops; the machine's architectural state is likewise
    /// preserved and inspectable).
    pub partial: Option<Box<RunStats>>,
}

impl SimError {
    pub fn new(pc: usize, message: impl Into<String>) -> SimError {
        SimError {
            pc,
            message: message.into(),
            partial: None,
        }
    }
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "pc {}: {}", self.pc, self.message)?;
        if let Some(p) = &self.partial {
            write!(
                f,
                " (after {} cycles, {} instructions)",
                p.cycles, p.instructions
            )?;
        }
        Ok(())
    }
}

impl std::error::Error for SimError {}

fn serr<T>(pc: usize, msg: impl Into<String>) -> Result<T, SimError> {
    Err(SimError::new(pc, msg))
}

/// Result of a completed run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RunStats {
    /// Core clock cycles consumed (the paper's benchmark metric).
    pub cycles: u64,
    /// Dynamic instruction count.
    pub instructions: u64,
    /// Instruction-mix profile (Figure 6).
    pub profile: Profile,
    /// Would-be pipeline hazards (0 for correctly NOP-scheduled programs).
    pub hazards: u64,
    /// First few hazard records for diagnostics.
    pub hazard_samples: Vec<super::hazard::Violation>,
}

impl RunStats {
    /// Elapsed time in microseconds at the configuration's core clock.
    pub fn time_us(&self, mhz: f64) -> f64 {
        self.cycles as f64 / mhz
    }
}

/// Superplan trace statistics: static trace shape of the loaded program
/// (at the current thread configuration) plus dynamic fused coverage of
/// the current run. See [`Machine::trace_stats`].
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct TraceStats {
    /// Fused traces compiled over the program.
    pub traces: usize,
    /// Static instruction slots inside fused traces.
    pub fused_pcs: usize,
    /// Program length in instructions.
    pub program_pcs: usize,
    /// Mean fused-trace length (static).
    pub mean_trace_len: f64,
    /// Dynamic instructions retired (this run).
    pub retired: u64,
    /// Dynamic instructions retired inside fused traces (this run).
    pub fused_retired: u64,
}

impl TraceStats {
    /// Percentage of dynamic instructions executed inside superplans.
    pub fn dynamic_fused_pct(&self) -> f64 {
        if self.retired == 0 {
            0.0
        } else {
            100.0 * self.fused_retired as f64 / self.retired as f64
        }
    }
}

/// Lifetime counters for the superplan build path of one machine:
/// how often the fused traces were actually (re)built versus how often a
/// rebuild was provably unnecessary and skipped (`reload`, or
/// `set_threads` re-asserting the current count). Steady-state serving
/// should accumulate only `fast_skips` after warmup. Deterministic per
/// core across sequential and pooled-parallel dispatch — the skip/rebuild
/// decision depends only on the job stream, never on thread timing.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SuperplanActivity {
    /// Superplan (re)builds: program loads plus thread-count changes.
    /// With a [`plan::SuperplanCache`] attached, each rebuild is a cache
    /// lookup (compile or hit); without one, each is a local compile.
    pub rebuilds: u64,
    /// Rebuilds avoided by the unchanged-program/unchanged-threads fast
    /// path.
    pub fast_skips: u64,
}

enum Exec {
    /// Inlined bit-exact rust lanes (default).
    Native,
    /// Pluggable block executor (XLA artifacts through PJRT). `Send` so a
    /// `Machine` can move to a coordinator worker thread.
    Block(Box<dyn BlockExec + Send>),
}

/// One eGPU core. `Send`: the multi-core coordinator hands each core to
/// its own worker thread.
pub struct Machine {
    pub cfg: EgpuConfig,
    prog: Option<Program>,
    /// Decode-time issue plans, one per instruction of `prog`.
    plans: Vec<IssuePlan>,
    /// Fused straight-line traces over `plans`, recompiled whenever the
    /// plans or the runtime thread count change (charges depend on both).
    /// Refcounted so a fleet can share one compiled artifact across
    /// cores through an attached [`plan::SuperplanCache`]; the run loop
    /// only ever reads through it.
    splans: Arc<plan::SuperplanProgram>,
    /// Fleet-shared superplan cache, attached by the owning `Gpu` /
    /// `Coordinator`. `None` = compile locally (standalone machines).
    splan_cache: Option<Arc<plan::SuperplanCache>>,
    /// Encoded words of the loaded program — the cache key's program
    /// identity. Only maintained while a cache is attached.
    splan_words: Option<Arc<[u64]>>,
    /// Lifetime rebuild/fast-skip counters (never reset by `reset`).
    splan_rebuilds: u64,
    splan_fast_skips: u64,
    /// Fused-trace dispatch enabled (default). Off = per-instruction
    /// plan stepping, the second of the three bit-identical exec modes.
    splans_on: bool,
    /// Dynamic instructions retired inside fused traces (per-run, like
    /// `retired`).
    fused_retired: u64,
    seq: Sequencer,
    regs: RegFile,
    shared: SharedMem,
    preds: PredicateFile,
    profile: Profile,
    hazards: HazardChecker,
    cycles: u64,
    retired: u64,
    /// Runtime-initialized threads (≤ cfg.threads; §3.2 "if the run time
    /// configuration of threads is less than this, there is no issue").
    rt_threads: usize,
    /// Wavefront count per depth selector, resolved against `rt_threads`
    /// (indexed by `DepthSel::bits()`; rebuilt by `set_threads`).
    wave_tab: [usize; 4],
    /// TDx/TDy grid x-dimension: TDx = tid % dim_x, TDy = tid / dim_x.
    dim_x: usize,
    /// Instruction trace to stderr (EGPU_TRACE env var, read once — an
    /// env lookup per instruction would dominate the fetch loop).
    trace: bool,
    exec: Exec,
    // Scratch blocks for the block-executor path (reused, not realloc'd).
    scr_a: Vec<u32>,
    scr_b: Vec<u32>,
    scr_old: Vec<u32>,
    scr_out: Vec<u32>,
    scr_mask: Vec<u8>,
}

// The coordinator's parallel dispatch moves `&mut Machine` into scoped
// worker threads; keep the auto-impl from silently regressing.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<Machine>();
};

impl Machine {
    /// New machine with the native datapath.
    pub fn new(cfg: EgpuConfig) -> Result<Machine, SimError> {
        Self::with_backend(cfg, None)
    }

    /// New machine with an explicit block executor (e.g. the XLA backend).
    pub fn with_backend(
        cfg: EgpuConfig,
        backend: Option<Box<dyn BlockExec + Send>>,
    ) -> Result<Machine, SimError> {
        cfg.validate()
            .map_err(|e| SimError::new(0, e.to_string()))?;
        let threads = cfg.threads;
        let mut m = Machine {
            regs: RegFile::new(threads, cfg.regs_per_thread),
            shared: SharedMem::new(cfg.shared_words(), cfg.memory),
            preds: PredicateFile::new(threads, cfg.predicate_levels),
            hazards: HazardChecker::new(cfg.regs_per_thread, cfg.shared_words()),
            profile: Profile::new(),
            seq: Sequencer::new(),
            prog: None,
            plans: Vec::new(),
            splans: Arc::new(plan::SuperplanProgram::default()),
            splan_cache: None,
            splan_words: None,
            splan_rebuilds: 0,
            splan_fast_skips: 0,
            splans_on: true,
            fused_retired: 0,
            cycles: 0,
            retired: 0,
            rt_threads: threads,
            wave_tab: [1; 4],
            dim_x: threads,
            trace: std::env::var_os("EGPU_TRACE").is_some(),
            exec: match backend {
                Some(b) => Exec::Block(b),
                None => Exec::Native,
            },
            scr_a: Vec::new(),
            scr_b: Vec::new(),
            scr_old: Vec::new(),
            scr_out: Vec::new(),
            scr_mask: Vec::new(),
            cfg,
        };
        m.rebuild_wave_tab();
        Ok(m)
    }

    /// Load (and statically validate) a program.
    pub fn load_program(&mut self, prog: Program) -> Result<(), SimError> {
        if prog.layout != self.cfg.word_layout() {
            return serr(
                0,
                format!(
                    "program assembled for a {}-bit IW, machine uses {} bits",
                    prog.layout.word_bits(),
                    self.cfg.word_layout().word_bits()
                ),
            );
        }
        for (pc, i) in prog.instrs.iter().enumerate() {
            self.cfg
                .supports(i.op, None)
                .map_err(|e| SimError::new(pc, e.to_string()))?;
            if matches!(i.op, Opcode::Jmp | Opcode::Jsr | Opcode::Loop)
                && i.imm_u() as usize >= prog.instrs.len()
            {
                return serr(pc, format!("branch target {} out of range", i.imm_u()));
            }
        }
        // Plans are compiled at assembly (early validation, carried on
        // `Program` for tooling), but the machine always recompiles from
        // the instruction stream it is actually loading: every `Program`
        // field is public, so an in-place edit to `instrs` must never
        // leave execution running a stale plan. Compilation is a cheap
        // O(n) decode pass, far off the hot path.
        self.plans =
            plan::compile(&prog.instrs).map_err(|e| SimError::new(e.pc, e.message))?;
        // The encoded word stream is the superplan cache's program
        // identity (exact, collision-free); only maintained while a
        // cache is attached — standalone machines skip the encode pass.
        self.splan_words = self.splan_cache.as_ref().map(|_| {
            prog.instrs
                .iter()
                .map(|i| prog.layout.encode(i))
                .collect::<Vec<_>>()
                .into()
        });
        self.rebuild_superplans();
        self.prog = Some(prog);
        self.reset();
        Ok(())
    }

    /// Re-arm the already-loaded program for a fresh run without
    /// recompiling plans or superplans: the coordinator's machine-reuse
    /// path calls this when a core re-runs its resident kernel build
    /// (reset-don't-reallocate — `RegFile`, plan and trace allocations
    /// all survive). Architectural state is reset exactly as
    /// `load_program` would leave it.
    pub fn reload(&mut self) -> Result<(), SimError> {
        if self.prog.is_none() {
            return serr(0, "no program loaded to reuse");
        }
        self.splan_fast_skips += 1;
        self.reset();
        Ok(())
    }

    /// Reset architectural state (program and shared memory are kept).
    pub fn reset(&mut self) {
        self.seq.reset();
        self.regs.reset();
        self.preds.reset();
        self.hazards.reset();
        self.profile = Profile::new();
        self.cycles = 0;
        self.retired = 0;
        self.fused_retired = 0;
    }

    /// Set the runtime thread count (≤ configured maximum). A change
    /// re-resolves the wave table and recompiles the superplan charges;
    /// re-asserting the current count is free (the steady-state serving
    /// path calls this per job).
    pub fn set_threads(&mut self, threads: usize) -> Result<(), SimError> {
        if threads == 0 || threads % WAVEFRONT_WIDTH != 0 || threads > self.cfg.threads {
            return serr(
                0,
                format!(
                    "runtime threads {} must be a multiple of 16 in [16, {}]",
                    threads, self.cfg.threads
                ),
            );
        }
        if threads != self.rt_threads {
            self.rt_threads = threads;
            self.rebuild_wave_tab();
            self.rebuild_superplans();
        } else {
            self.splan_fast_skips += 1;
        }
        Ok(())
    }

    /// Attach the fleet-shared superplan cache. Subsequent
    /// `load_program`/`set_threads` rebuilds become cache lookups, so a
    /// kernel already compiled at this (program, config, threads) triple
    /// by any core attaches the shared artifact instead of recompiling.
    pub fn set_superplan_cache(&mut self, cache: Arc<plan::SuperplanCache>) {
        self.splan_cache = Some(cache);
        // A program loaded before attachment has no word key; rebuild it
        // lazily on the next load (resident programs keep their local
        // compile — correctness is unaffected, only sharing).
    }

    /// Lifetime superplan rebuild/fast-skip counters for this machine.
    pub fn superplan_activity(&self) -> SuperplanActivity {
        SuperplanActivity {
            rebuilds: self.splan_rebuilds,
            fast_skips: self.splan_fast_skips,
        }
    }

    /// Recompile the fused traces (plan stream or thread count changed) —
    /// through the shared cache when one is attached and the loaded
    /// program's word key is known, locally otherwise.
    fn rebuild_superplans(&mut self) {
        self.splan_rebuilds += 1;
        self.splans = match (&self.splan_cache, &self.splan_words) {
            (Some(cache), Some(words)) => {
                let key = plan::SuperplanKey {
                    words: Arc::clone(words),
                    fingerprint: self.cfg.fingerprint(),
                    threads: self.rt_threads,
                };
                cache.get(&key, &self.plans, &self.wave_tab, &self.shared)
            }
            _ => Arc::new(plan::compile_superplans(
                &self.plans,
                &self.wave_tab,
                &self.shared,
            )),
        };
    }

    /// Toggle fused-trace dispatch (on by default). The per-instruction
    /// plan path and the superplan path are bit-identical; the toggle
    /// exists so the parity suites can run both.
    pub fn set_superplans(&mut self, on: bool) {
        self.splans_on = on;
    }

    /// Superplan trace statistics: the static shape of the compiled
    /// traces plus the dynamic fused coverage of the current run.
    pub fn trace_stats(&self) -> TraceStats {
        TraceStats {
            traces: self.splans.traces.len(),
            fused_pcs: self.splans.ops.len(),
            program_pcs: self.plans.len(),
            mean_trace_len: self.splans.mean_trace_len(),
            retired: self.retired,
            fused_retired: self.fused_retired,
        }
    }

    /// Resolve each depth selector against the runtime wavefront count
    /// (the one plan input that is per-launch, not per-program).
    fn rebuild_wave_tab(&mut self) {
        let total = self.rt_threads / WAVEFRONT_WIDTH;
        for bits in 0..4u8 {
            self.wave_tab[bits as usize] = DepthSel::from_bits(bits).waves(total);
        }
    }

    /// Set the TDx/TDy grid x-dimension.
    pub fn set_dim_x(&mut self, dim_x: usize) -> Result<(), SimError> {
        if dim_x == 0 {
            return serr(0, "dim_x must be positive");
        }
        self.dim_x = dim_x;
        Ok(())
    }

    /// Disable hazard tracking (verified programs on perf runs).
    pub fn set_hazard_checking(&mut self, on: bool) {
        self.hazards.set_enabled(on);
    }

    pub fn shared(&self) -> &SharedMem {
        &self.shared
    }

    pub fn shared_mut(&mut self) -> &mut SharedMem {
        &mut self.shared
    }

    pub fn regs(&self) -> &RegFile {
        &self.regs
    }

    /// Host-side register seeding (tests and examples).
    pub fn regs_mut(&mut self) -> &mut RegFile {
        &mut self.regs
    }

    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// The run statistics accumulated so far (valid mid-run and after a
    /// cycle-limit stop; `run` returns the same snapshot on success).
    pub fn stats_snapshot(&self) -> RunStats {
        RunStats {
            cycles: self.cycles,
            instructions: self.retired,
            profile: self.profile.clone(),
            hazards: self.hazards.total,
            hazard_samples: self.hazards.samples.clone(),
        }
    }

    fn rt_waves(&self) -> usize {
        self.rt_threads / WAVEFRONT_WIDTH
    }

    /// Combined thread-space × predicate gate for (wave, sp).
    #[inline]
    fn thread_active(&self, wave: usize, sp: usize) -> bool {
        !self.preds.configured() || self.preds.active(wave * WAVEFRONT_WIDTH + sp)
    }

    /// Budget-stop error carrying the progress made so far.
    fn cycle_limit(&self, pc: usize, max_cycles: u64) -> SimError {
        SimError {
            pc,
            message: format!("cycle limit {max_cycles} exceeded"),
            partial: Some(Box::new(self.stats_snapshot())),
        }
    }

    /// Run to STOP (or error): fetch-superplan → execute-trace → charge,
    /// falling back to per-instruction plan dispatch at trace boundaries,
    /// control flow, and budget-tight traces. `max_cycles` bounds runaway
    /// programs; the budget is enforced *before* issue, and the error
    /// keeps the partial stats.
    pub fn run(&mut self, max_cycles: u64) -> Result<RunStats, SimError> {
        if self.prog.is_none() {
            return serr(0, "no program loaded");
        }
        let prog_len = self.plans.len();
        // EGPU_TRACE prints per instruction — superplans are bypassed so
        // the trace output stays per-op.
        let fuse = self.splans_on && !self.trace;
        while !self.seq.stopped {
            let pc = self.seq.pc;
            if pc >= prog_len {
                return serr(pc, "execution fell off the end of the program");
            }
            if self.cycles >= max_cycles {
                return Err(self.cycle_limit(pc, max_cycles));
            }
            if fuse {
                if let Some(t) = self.splans.trace_index(pc) {
                    // Issue offsets are strictly increasing, so the whole
                    // trace passes the per-op budget check iff its last
                    // issue slot does. Budget-tight traces fall through to
                    // per-instruction stepping (mid-trace pcs have no
                    // leader entry) for an exact partial stop.
                    let last = self.splans.traces[t].last_offset;
                    if self.cycles.saturating_add(last) < max_cycles {
                        self.run_trace(t)?;
                        continue;
                    }
                }
            }
            let p = self.plans[pc];
            if self.trace {
                let i = self.prog.as_ref().unwrap().instrs[pc];
                eprintln!("pc={} op={:?} tc={} imm={}", pc, i.op, i.tc, i.imm_u());
            }
            self.step_plan(pc, &p)?;
            self.retired += 1;
        }
        // STOP drains the pipeline.
        self.cycles += PIPELINE_DEPTH;
        Ok(self.stats_snapshot())
    }

    /// Execute one fused trace. Per-op lane work and hazard bookkeeping
    /// run with explicit start cycles (identical values to per-op
    /// stepping); the trace's total charge, profiler delta, retire count
    /// and pc advance land once at the end. On a mid-trace fault the
    /// machine is left exactly where per-instruction dispatch would leave
    /// it: charges/profile/pc of the completed prefix, plus whatever
    /// partial lane work the faulting op performed before the fault.
    fn run_trace(&mut self, t: usize) -> Result<(), SimError> {
        let (first, len, start_pc, total) = {
            let tr = &self.splans.traces[t];
            (tr.first_op, tr.len, tr.start_pc, tr.total_cycles)
        };
        let base = self.cycles;
        for k in 0..len {
            let op = self.splans.ops[first + k];
            let pc = start_pc + k;
            if let Err(e) = self.exec_trace_op(pc, &op, base + op.offset) {
                self.cycles = base + op.offset;
                self.retired += k as u64;
                self.fused_retired += k as u64;
                for j in first..first + k {
                    let o = self.splans.ops[j];
                    self.profile.record_slot(o.plan.slot as usize, o.charge);
                }
                self.seq.pc = pc;
                return Err(e);
            }
        }
        self.cycles = base + total;
        self.retired += len as u64;
        self.fused_retired += len as u64;
        self.profile.merge(&self.splans.traces[t].prof);
        self.seq.pc = start_pc + len;
        Ok(())
    }

    /// Dispatch one fused op with an explicit start cycle. Control kinds
    /// never appear: the superplan compiler ends traces at sequencer ops.
    #[inline]
    fn exec_trace_op(
        &mut self,
        pc: usize,
        op: &plan::TraceOp,
        start: u64,
    ) -> Result<(), SimError> {
        let p = &op.plan;
        match p.kind {
            PlanKind::Nop => Ok(()),
            PlanKind::Ldi => {
                let v = p.imm;
                self.exec_set_plan(p, start, move |_| v);
                Ok(())
            }
            PlanKind::TdX => {
                let dx = self.dim_x;
                self.exec_set_plan(p, start, move |t| (t % dx) as u32);
                Ok(())
            }
            PlanKind::TdY => {
                let dx = self.dim_x;
                self.exec_set_plan(p, start, move |t| (t / dx) as u32);
                Ok(())
            }
            PlanKind::Alu(dp) => self.exec_alu_plan(pc, p, dp, start),
            PlanKind::Load => self.exec_load_plan(pc, p, start, op.charge),
            PlanKind::Store => self.exec_store_plan(pc, p, start, op.charge),
            PlanKind::Dot { sum_only } => self.exec_dot_plan(pc, p, sum_only, start),
            PlanKind::If { cc, ttype } => self.exec_if_plan(pc, p, cc, ttype, start),
            PlanKind::Else | PlanKind::EndIf => self.exec_else_endif_plan(pc, p),
            PlanKind::Jmp
            | PlanKind::Jsr
            | PlanKind::Rts
            | PlanKind::Loop
            | PlanKind::Init
            | PlanKind::Stop => unreachable!("sequencer ops are never fused"),
        }
    }

    #[inline]
    fn step_plan(&mut self, pc: usize, p: &IssuePlan) -> Result<(), SimError> {
        match p.kind {
            PlanKind::Nop => {
                self.cycles += 1;
                self.profile.record_slot(p.slot as usize, 1);
                self.seq.step();
            }
            PlanKind::Jmp => {
                self.charge_control(p);
                self.seq.jmp(p.imm as usize);
            }
            PlanKind::Jsr => {
                self.charge_control(p);
                self.seq
                    .jsr(p.imm as usize)
                    .map_err(|e| SimError::new(pc, e.to_string()))?;
            }
            PlanKind::Rts => {
                self.charge_control(p);
                self.seq
                    .rts()
                    .map_err(|e| SimError::new(pc, e.to_string()))?;
            }
            PlanKind::Loop => {
                self.charge_control(p);
                self.seq
                    .loop_dec(p.imm as usize)
                    .map_err(|e| SimError::new(pc, e.to_string()))?;
            }
            PlanKind::Init => {
                self.charge_control(p);
                self.seq
                    .init(p.imm)
                    .map_err(|e| SimError::new(pc, e.to_string()))?;
                self.seq.step();
            }
            PlanKind::Stop => {
                self.charge_control(p);
                self.seq.stop();
            }
            PlanKind::Ldi => {
                let v = p.imm;
                self.plan_set(p, move |_| v);
            }
            PlanKind::TdX => {
                let dx = self.dim_x;
                self.plan_set(p, move |t| (t % dx) as u32);
            }
            PlanKind::TdY => {
                let dx = self.dim_x;
                self.plan_set(p, move |t| (t / dx) as u32);
            }
            PlanKind::Alu(dp) => self.plan_alu(pc, p, dp)?,
            PlanKind::Load => self.plan_load(pc, p)?,
            PlanKind::Store => self.plan_store(pc, p)?,
            PlanKind::Dot { sum_only } => self.plan_dot(pc, p, sum_only)?,
            PlanKind::If { cc, ttype } => self.plan_if(pc, p, cc, ttype)?,
            PlanKind::Else | PlanKind::EndIf => self.plan_else_endif(pc, p)?,
        }
        Ok(())
    }

    #[inline]
    fn charge_control(&mut self, p: &IssuePlan) {
        self.cycles += 1;
        self.profile.record_slot(p.slot as usize, 1);
    }

    /// Charge `charge` cycles to `p`'s profiler slot and advance the pc —
    /// the per-instruction half of every plan op; fused traces apply the
    /// same charges in aggregate.
    #[inline]
    fn charge_step(&mut self, p: &IssuePlan, charge: u64) {
        self.cycles += charge;
        self.profile.record_slot(p.slot as usize, charge);
        self.seq.step();
    }

    /// LDI / TDX / TDY: per-thread generated values, one wavefront/cycle.
    #[inline]
    fn plan_set(&mut self, p: &IssuePlan, value: impl FnMut(usize) -> u32) {
        let start = self.cycles;
        self.exec_set_plan(p, start, value);
        let waves = self.wave_tab[p.depth.bits() as usize];
        self.charge_step(p, waves as u64);
    }

    /// LDI / TDX / TDY lane work — shared by the per-instruction and
    /// fused-trace paths; never touches cycles, profile or the sequencer.
    #[inline]
    fn exec_set_plan(&mut self, p: &IssuePlan, start: u64, value: impl FnMut(usize) -> u32) {
        let waves = self.wave_tab[p.depth.bits() as usize];
        let lanes = p.lanes as usize;
        // Field-level borrow: the gate (self.preds) and the register rows
        // (self.regs) are disjoint.
        let preds = if self.preds.configured() { Some(&self.preds) } else { None };
        self.regs.lane_set(waves, lanes, p.rd, preds, value);
        self.hazards.write_reg(p.rd, start, REG_WINDOW);
    }

    /// FP/INT wavefront ALU ops and INVSQR: one wavefront per cycle.
    fn plan_alu(&mut self, pc: usize, p: &IssuePlan, dp: DpOp) -> Result<(), SimError> {
        let start = self.cycles;
        self.exec_alu_plan(pc, p, dp, start)?;
        let waves = self.wave_tab[p.depth.bits() as usize];
        self.charge_step(p, waves as u64);
        Ok(())
    }

    /// ALU lane work + hazard bookkeeping at an explicit start cycle.
    #[inline]
    fn exec_alu_plan(
        &mut self,
        pc: usize,
        p: &IssuePlan,
        dp: DpOp,
        start: u64,
    ) -> Result<(), SimError> {
        self.hazards.read_reg(pc, p.ra, start);
        if p.uses_rb {
            self.hazards.read_reg(pc, p.rb, start);
        }
        if matches!(self.exec, Exec::Native) {
            self.native_alu_lanes(p, dp);
        } else {
            let waves = self.wave_tab[p.depth.bits() as usize];
            let lanes = p.lanes as usize;
            self.exec_alu_block(pc, p.rd, p.ra, p.rb, dp, waves, lanes)?;
        }
        self.hazards.write_reg(p.rd, start, REG_WINDOW);
        Ok(())
    }

    /// Monomorphic native ALU dispatch: one `lane_apply` instantiation
    /// per datapath op, so the op match happens once per instruction —
    /// not per lane — and each instantiated inner loop is straight-line
    /// code over contiguous register rows that the autovectorizer can
    /// chew on (`fp_lane`/`int_lane` fold to the single op's arithmetic).
    fn native_alu_lanes(&mut self, p: &IssuePlan, dp: DpOp) {
        let waves = self.wave_tab[p.depth.bits() as usize];
        let lanes = p.lanes as usize;
        let prec = self.cfg.alu_precision;
        let preds = if self.preds.configured() { Some(&self.preds) } else { None };
        macro_rules! fp {
            ($op:ident) => {
                self.regs.lane_apply(waves, lanes, p.rd, p.ra, p.rb, preds, |a, b| {
                    native::fp_lane(FpOp::$op, a, b)
                })
            };
        }
        macro_rules! int {
            ($op:ident) => {
                self.regs.lane_apply(waves, lanes, p.rd, p.ra, p.rb, preds, |a, b| {
                    native::int_lane(IntOp::$op, a, b, prec)
                })
            };
        }
        match dp {
            DpOp::Fp(op) => match op {
                FpOp::FAdd => fp!(FAdd),
                FpOp::FSub => fp!(FSub),
                FpOp::FNeg => fp!(FNeg),
                FpOp::FAbs => fp!(FAbs),
                FpOp::FMul => fp!(FMul),
                FpOp::FMax => fp!(FMax),
                FpOp::FMin => fp!(FMin),
                FpOp::FInvSqrt => fp!(FInvSqrt),
            },
            DpOp::Int(op) => match op {
                IntOp::Add => int!(Add),
                IntOp::Sub => int!(Sub),
                IntOp::Neg => int!(Neg),
                IntOp::Abs => int!(Abs),
                IntOp::Mul16Lo => int!(Mul16Lo),
                IntOp::Mul16Hi => int!(Mul16Hi),
                IntOp::Mul24Lo => int!(Mul24Lo),
                IntOp::Mul24Hi => int!(Mul24Hi),
                IntOp::And => int!(And),
                IntOp::Or => int!(Or),
                IntOp::Xor => int!(Xor),
                IntOp::Not => int!(Not),
                IntOp::CNot => int!(CNot),
                IntOp::Bvs => int!(Bvs),
                IntOp::Shl => int!(Shl),
                IntOp::ShrL => int!(ShrL),
                IntOp::ShrA => int!(ShrA),
                IntOp::Pop => int!(Pop),
                IntOp::MaxS => int!(MaxS),
                IntOp::MinS => int!(MinS),
                IntOp::MaxU => int!(MaxU),
                IntOp::MinU => int!(MinU),
            },
            DpOp::Dot { .. } => unreachable!("dot is PlanKind::Dot"),
        }
    }

    /// LOD: 4 lanes per cycle through the shared-memory read ports.
    fn plan_load(&mut self, pc: usize, p: &IssuePlan) -> Result<(), SimError> {
        let waves = self.wave_tab[p.depth.bits() as usize];
        let charge = self.shared.load_cycles(waves * p.lanes as usize);
        let start = self.cycles;
        self.exec_load_plan(pc, p, start, charge)?;
        self.charge_step(p, charge);
        Ok(())
    }

    /// LOD lane work + hazard bookkeeping at an explicit start cycle;
    /// `charge` is the pre-resolved port charge for the selected lanes.
    #[inline]
    fn exec_load_plan(
        &mut self,
        pc: usize,
        p: &IssuePlan,
        start: u64,
        charge: u64,
    ) -> Result<(), SimError> {
        let waves = self.wave_tab[p.depth.bits() as usize];
        let lanes = p.lanes as usize;
        self.hazards.read_reg(pc, p.ra, start);
        let (ra, rd, imm) = (p.ra as usize, p.rd as usize, p.imm);
        let preds_on = self.preds.configured();
        let check = self.hazards.enabled();
        let preds = &self.preds;
        let shared = &self.shared;
        let hazards = &mut self.hazards;
        let r: Result<(), super::shared_mem::MemFault> = if check {
            self.regs.lane_rows_mut(waves, lanes, |t, row| {
                let addr = row[ra].wrapping_add(imm);
                // The port slot is consumed regardless of the predicate;
                // only the register writeback is gated.
                hazards.read_mem(pc, addr, start);
                if preds_on && !preds.active(t) {
                    return Ok(());
                }
                row[rd] = shared.read(addr)?;
                Ok(())
            })
        } else {
            self.regs.lane_rows_mut(waves, lanes, |t, row| {
                let addr = row[ra].wrapping_add(imm);
                if preds_on && !preds.active(t) {
                    return Ok(());
                }
                row[rd] = shared.read(addr)?;
                Ok(())
            })
        };
        r.map_err(|f| SimError::new(pc, f.to_string()))?;
        // rd streams back over `charge` slots; see hazard.rs for the skew
        // argument behind the window.
        self.hazards
            .write_reg(p.rd, start, REG_WINDOW + charge.saturating_sub(waves as u64));
        Ok(())
    }

    /// STO: 1 (DP) or 2 (QP) lanes per cycle through the write ports.
    fn plan_store(&mut self, pc: usize, p: &IssuePlan) -> Result<(), SimError> {
        let waves = self.wave_tab[p.depth.bits() as usize];
        let charge = self.shared.store_cycles(waves * p.lanes as usize);
        let start = self.cycles;
        self.exec_store_plan(pc, p, start, charge)?;
        self.charge_step(p, charge);
        Ok(())
    }

    /// STO lane work + hazard bookkeeping at an explicit start cycle;
    /// `charge` is the pre-resolved port charge for the selected lanes.
    #[inline]
    fn exec_store_plan(
        &mut self,
        pc: usize,
        p: &IssuePlan,
        start: u64,
        charge: u64,
    ) -> Result<(), SimError> {
        let waves = self.wave_tab[p.depth.bits() as usize];
        let lanes = p.lanes as usize;
        self.hazards.read_reg(pc, p.ra, start);
        self.hazards.read_reg(pc, p.rd, start);
        let (ra, rd, imm) = (p.ra as usize, p.rd as usize, p.imm);
        let preds_on = self.preds.configured();
        let ready = start + charge + MEM_WINDOW;
        let preds = &self.preds;
        let shared = &mut self.shared;
        let hazards = &mut self.hazards;
        self.regs
            .lane_rows(waves, lanes, |t, row| {
                if preds_on && !preds.active(t) {
                    return Ok(()); // write_enable gated by thread_active (§3.2)
                }
                let addr = row[ra].wrapping_add(imm);
                shared.write(addr, row[rd])?;
                hazards.write_mem(addr, ready);
                Ok(())
            })
            .map_err(|f: super::shared_mem::MemFault| SimError::new(pc, f.to_string()))?;
        Ok(())
    }

    /// DOT / SUM extension core: operands stream one wavefront per cycle,
    /// the scalar result writes back to thread 0 after the core latency.
    fn plan_dot(&mut self, pc: usize, p: &IssuePlan, sum_only: bool) -> Result<(), SimError> {
        let start = self.cycles;
        self.exec_dot_plan(pc, p, sum_only, start)?;
        let waves = self.wave_tab[p.depth.bits() as usize];
        self.charge_step(p, waves as u64);
        Ok(())
    }

    /// DOT / SUM lane work + hazard bookkeeping at an explicit start.
    #[inline]
    fn exec_dot_plan(
        &mut self,
        pc: usize,
        p: &IssuePlan,
        sum_only: bool,
        start: u64,
    ) -> Result<(), SimError> {
        let waves = self.wave_tab[p.depth.bits() as usize];
        let lanes = p.lanes as usize;
        self.hazards.read_reg(pc, p.ra, start);
        if !sum_only {
            self.hazards.read_reg(pc, p.rb, start);
        }
        let result = match &self.exec {
            Exec::Native => self.exec_dot_native(p.ra, p.rb, sum_only, waves, lanes),
            Exec::Block(_) => self.exec_dot_block(pc, p.ra, p.rb, sum_only, waves, lanes)?,
        };
        // Result lands in the leftmost SP (§3.1): thread 0's rd.
        if self.thread_active(0, 0) {
            self.regs.write(0, 0, p.rd, result.to_bits());
        }
        self.hazards
            .write_reg(p.rd, start, waves as u64 + DOT_WINDOW);
        Ok(())
    }

    /// IF: per-thread predicate push, one wavefront per cycle (§3.2).
    fn plan_if(
        &mut self,
        pc: usize,
        p: &IssuePlan,
        cc: CondCode,
        ttype: TType,
    ) -> Result<(), SimError> {
        let start = self.cycles;
        self.exec_if_plan(pc, p, cc, ttype, start)?;
        let waves = self.wave_tab[p.depth.bits() as usize];
        self.charge_step(p, waves as u64);
        Ok(())
    }

    /// IF lane work + hazard bookkeeping at an explicit start cycle.
    #[inline]
    fn exec_if_plan(
        &mut self,
        pc: usize,
        p: &IssuePlan,
        cc: CondCode,
        ttype: TType,
        start: u64,
    ) -> Result<(), SimError> {
        let waves = self.wave_tab[p.depth.bits() as usize];
        let lanes = p.lanes as usize;
        self.hazards.read_reg(pc, p.ra, start);
        self.hazards.read_reg(pc, p.rb, start);
        let (ra, rb) = (p.ra as usize, p.rb as usize);
        let preds = &mut self.preds;
        self.regs
            .lane_rows(waves, lanes, |t, row| {
                preds.push(t, cc.eval(ttype, row[ra], row[rb]))
            })
            .map_err(|e| SimError::new(pc, e.to_string()))?;
        Ok(())
    }

    /// ELSE / ENDIF: per-thread predicate-stack updates.
    fn plan_else_endif(&mut self, pc: usize, p: &IssuePlan) -> Result<(), SimError> {
        self.exec_else_endif_plan(pc, p)?;
        let waves = self.wave_tab[p.depth.bits() as usize];
        self.charge_step(p, waves as u64);
        Ok(())
    }

    /// ELSE / ENDIF predicate-stack updates (no hazard reads, no charge).
    #[inline]
    fn exec_else_endif_plan(&mut self, pc: usize, p: &IssuePlan) -> Result<(), SimError> {
        let waves = self.wave_tab[p.depth.bits() as usize];
        let lanes = p.lanes as usize;
        let invert = p.kind == PlanKind::Else;
        for w in 0..waves {
            let base = w * WAVEFRONT_WIDTH;
            for sp in 0..lanes {
                let r = if invert {
                    self.preds.invert_top(base + sp)
                } else {
                    self.preds.pop(base + sp)
                };
                r.map_err(|e| SimError::new(pc, e.to_string()))?;
            }
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // Reference interpreter: the original per-instruction re-deriving
    // execution path (classification, operand shape, geometry and cycle
    // charges all computed at issue time). Retained as the differential
    // oracle for the plan compiler and the plan-driven hot loop.
    // -----------------------------------------------------------------

    /// Run to STOP (or error) through the reference interpreter. Same
    /// budget and error semantics as [`Machine::run`]; the two must
    /// produce bit-identical architectural state, cycle counts and
    /// hazard totals on every program.
    pub fn run_reference(&mut self, max_cycles: u64) -> Result<RunStats, SimError> {
        let prog_len = match &self.prog {
            Some(p) => p.instrs.len(),
            None => return serr(0, "no program loaded"),
        };
        while !self.seq.stopped {
            let pc = self.seq.pc;
            if pc >= prog_len {
                return serr(pc, "execution fell off the end of the program");
            }
            if self.cycles >= max_cycles {
                return Err(self.cycle_limit(pc, max_cycles));
            }
            // Fetch (instructions are pre-decoded at assembly; the encoded
            // words are what the M20Ks hold, `Program` keeps both).
            let i = self.prog.as_ref().unwrap().instrs[pc];
            if self.trace {
                eprintln!("pc={} op={:?} tc={} imm={}", pc, i.op, i.tc, i.imm_u());
            }
            self.execute_reference(pc, &i)?;
            self.retired += 1;
        }
        // STOP drains the pipeline.
        self.cycles += PIPELINE_DEPTH;
        Ok(self.stats_snapshot())
    }

    fn execute_reference(&mut self, pc: usize, i: &Instr) -> Result<(), SimError> {
        use Opcode::*;
        match i.op {
            Nop => {
                self.cycles += 1;
                self.profile.record(Group::Nop, 1);
                self.seq.step();
            }
            Jmp => {
                self.cycles += 1;
                self.profile.record(Group::Control, 1);
                self.seq.jmp(i.imm_u() as usize);
            }
            Jsr => {
                self.cycles += 1;
                self.profile.record(Group::Control, 1);
                self.seq
                    .jsr(i.imm_u() as usize)
                    .map_err(|e| SimError::new(pc, e.to_string()))?;
            }
            Rts => {
                self.cycles += 1;
                self.profile.record(Group::Control, 1);
                self.seq
                    .rts()
                    .map_err(|e| SimError::new(pc, e.to_string()))?;
            }
            Loop => {
                self.cycles += 1;
                self.profile.record(Group::Control, 1);
                self.seq
                    .loop_dec(i.imm_u() as usize)
                    .map_err(|e| SimError::new(pc, e.to_string()))?;
            }
            Init => {
                self.cycles += 1;
                self.profile.record(Group::Control, 1);
                self.seq
                    .init(i.imm_u())
                    .map_err(|e| SimError::new(pc, e.to_string()))?;
                self.seq.step();
            }
            Stop => {
                self.cycles += 1;
                self.profile.record(Group::Control, 1);
                self.seq.stop();
            }
            Ldi | TdX | TdY => {
                self.exec_scalar_gen(pc, i);
                self.seq.step();
            }
            Lod => {
                self.exec_load(pc, i)?;
                self.seq.step();
            }
            Sto => {
                self.exec_store(pc, i)?;
                self.seq.step();
            }
            If | Else | EndIf => {
                self.exec_pred(pc, i)?;
                self.seq.step();
            }
            Dot | Sum => {
                self.exec_dot(pc, i)?;
                self.seq.step();
            }
            _ => {
                self.exec_alu(pc, i)?;
                self.seq.step();
            }
        }
        Ok(())
    }

    /// LDI / TDX / TDY: per-thread generated values, one wavefront/cycle.
    fn exec_scalar_gen(&mut self, _pc: usize, i: &Instr) {
        let waves = i.tc.depth.waves(self.rt_waves());
        let lanes = i.tc.width.lanes();
        let start = self.cycles;
        for w in 0..waves {
            for sp in 0..lanes {
                if !self.thread_active(w, sp) {
                    continue;
                }
                let tid = w * WAVEFRONT_WIDTH + sp;
                let v = match i.op {
                    Opcode::Ldi => i.imm_i() as u32,
                    Opcode::TdX => (tid % self.dim_x) as u32,
                    Opcode::TdY => (tid / self.dim_x) as u32,
                    _ => unreachable!(),
                };
                self.regs.write(w, sp, i.rd, v);
            }
        }
        self.hazards.write_reg(i.rd, start, REG_WINDOW);
        self.cycles += waves as u64;
        self.profile.record(i.op.group(), waves as u64);
    }

    /// FP/INT wavefront ALU ops and INVSQR: one wavefront per cycle.
    fn exec_alu(&mut self, pc: usize, i: &Instr) -> Result<(), SimError> {
        let dp = match classify(i) {
            Some(dp) => dp,
            None => return serr(pc, format!("{} is not executable", i.op)),
        };
        let waves = i.tc.depth.waves(self.rt_waves());
        let lanes = i.tc.width.lanes();
        let start = self.cycles;
        let uses_rb = !matches!(
            i.op.operands(),
            crate::isa::opcode::OperandShape::RdRa
        );
        self.hazards.read_reg(pc, i.ra, start);
        if uses_rb {
            self.hazards.read_reg(pc, i.rb, start);
        }

        match (&mut self.exec, dp) {
            (Exec::Native, DpOp::Fp(op)) => {
                // Predicate gate hoisted; row iteration avoids per-lane
                // index math + bounds checks (EXPERIMENTS.md §Perf).
                let preds = if self.preds.configured() { Some(&self.preds) } else { None };
                self.regs.lane_apply(waves, lanes, i.rd, i.ra, i.rb, preds, |a, b| {
                    native::fp_lane(op, a, b)
                });
            }
            (Exec::Native, DpOp::Int(op)) => {
                let prec = self.cfg.alu_precision;
                let preds = if self.preds.configured() { Some(&self.preds) } else { None };
                self.regs.lane_apply(waves, lanes, i.rd, i.ra, i.rb, preds, |a, b| {
                    native::int_lane(op, a, b, prec)
                });
            }
            (Exec::Block(_), DpOp::Fp(_)) | (Exec::Block(_), DpOp::Int(_)) => {
                self.exec_alu_block(pc, i.rd, i.ra, i.rb, dp, waves, lanes)?;
            }
            (_, DpOp::Dot { .. }) => unreachable!("dot handled in exec_dot"),
        }

        self.hazards.write_reg(i.rd, start, REG_WINDOW);
        self.cycles += waves as u64;
        self.profile.record(i.op.group(), waves as u64);
        Ok(())
    }

    /// Block-executor path: gather → one artifact call → scatter. Shared
    /// by the reference and plan-driven paths.
    #[allow(clippy::too_many_arguments)]
    fn exec_alu_block(
        &mut self,
        pc: usize,
        rd: u8,
        ra: u8,
        rb: u8,
        dp: DpOp,
        waves: usize,
        lanes: usize,
    ) -> Result<(), SimError> {
        let depth = self.rt_waves();
        let n = depth * WAVEFRONT_WIDTH;
        self.scr_a.resize(n, 0);
        self.scr_b.resize(n, 0);
        self.scr_old.resize(n, 0);
        self.scr_out.resize(n, 0);
        self.scr_mask.resize(n, 0);
        for w in 0..depth {
            for sp in 0..WAVEFRONT_WIDTH {
                let idx = w * WAVEFRONT_WIDTH + sp;
                self.scr_a[idx] = self.regs.read(w, sp, ra);
                self.scr_b[idx] = self.regs.read(w, sp, rb);
                self.scr_old[idx] = self.regs.read(w, sp, rd);
                self.scr_mask[idx] =
                    (w < waves && sp < lanes && self.thread_active(w, sp)) as u8;
            }
        }
        let be = match &mut self.exec {
            Exec::Block(b) => b,
            Exec::Native => unreachable!(),
        };
        let r = match dp {
            DpOp::Fp(op) => be.fp_block(
                op,
                &self.scr_a,
                &self.scr_b,
                &self.scr_old,
                &self.scr_mask,
                &mut self.scr_out,
            ),
            DpOp::Int(op) => be.int_block(
                op,
                self.cfg.alu_precision,
                &self.scr_a,
                &self.scr_b,
                &self.scr_old,
                &self.scr_mask,
                &mut self.scr_out,
            ),
            DpOp::Dot { .. } => unreachable!(),
        };
        r.map_err(|m| SimError::new(pc, format!("datapath backend: {m}")))?;
        for w in 0..depth {
            for sp in 0..WAVEFRONT_WIDTH {
                let idx = w * WAVEFRONT_WIDTH + sp;
                if self.scr_mask[idx] != 0 {
                    self.regs.write(w, sp, rd, self.scr_out[idx]);
                }
            }
        }
        Ok(())
    }

    /// LOD: 4 lanes per cycle through the shared-memory read ports.
    fn exec_load(&mut self, pc: usize, i: &Instr) -> Result<(), SimError> {
        let waves = i.tc.depth.waves(self.rt_waves());
        let lanes = i.tc.width.lanes();
        let start = self.cycles;
        self.hazards.read_reg(pc, i.ra, start);
        let selected = waves * lanes;
        let charge = self.shared.load_cycles(selected);
        let (ra, rd, imm) = (i.ra as usize, i.rd as usize, i.imm_u());
        let preds_on = self.preds.configured();
        let preds = &self.preds;
        let shared = &self.shared;
        let hazards = &mut self.hazards;
        self.regs
            .lane_rows_mut(waves, lanes, |t, row| {
                let addr = row[ra].wrapping_add(imm);
                // The port slot is consumed regardless of the predicate;
                // only the register writeback is gated.
                hazards.read_mem(pc, addr, start);
                if preds_on && !preds.active(t) {
                    return Ok(());
                }
                row[rd] = shared.read(addr)?;
                Ok(())
            })
            .map_err(|f: super::shared_mem::MemFault| SimError::new(pc, f.to_string()))?;
        // rd streams back over `charge` slots; see hazard.rs for the skew
        // argument behind the window.
        self.hazards
            .write_reg(i.rd, start, REG_WINDOW + charge.saturating_sub(waves as u64));
        self.cycles += charge;
        self.profile.record(Group::Memory, charge);
        Ok(())
    }

    /// STO: 1 (DP) or 2 (QP) lanes per cycle through the write ports.
    fn exec_store(&mut self, pc: usize, i: &Instr) -> Result<(), SimError> {
        let waves = i.tc.depth.waves(self.rt_waves());
        let lanes = i.tc.width.lanes();
        let start = self.cycles;
        self.hazards.read_reg(pc, i.ra, start);
        self.hazards.read_reg(pc, i.rd, start);
        let selected = waves * lanes;
        let charge = self.shared.store_cycles(selected);
        for w in 0..waves {
            for sp in 0..lanes {
                if !self.thread_active(w, sp) {
                    continue; // write_enable gated by thread_active (§3.2)
                }
                let addr = self
                    .regs
                    .read(w, sp, i.ra)
                    .wrapping_add(i.imm_u());
                let v = self.regs.read(w, sp, i.rd);
                self.shared
                    .write(addr, v)
                    .map_err(|f| SimError::new(pc, f.to_string()))?;
                self.hazards.write_mem(addr, start + charge + MEM_WINDOW);
            }
        }
        self.cycles += charge;
        self.profile.record(Group::Memory, charge);
        Ok(())
    }

    /// The DOT core's native accumulation: wavefront-major, row-summed
    /// (matching the Pallas grid). Shared by both execution paths.
    fn exec_dot_native(&self, ra: u8, rb: u8, sum_only: bool, waves: usize, lanes: usize) -> f32 {
        let mut acc = 0f32;
        for w in 0..waves {
            let mut row = 0f32;
            for sp in 0..lanes {
                if !self.thread_active(w, sp) {
                    continue;
                }
                let a = f32::from_bits(self.regs.read(w, sp, ra));
                let b = if sum_only {
                    1.0
                } else {
                    f32::from_bits(self.regs.read(w, sp, rb))
                };
                row += a * b;
            }
            acc += row;
        }
        acc
    }

    /// The DOT core through the block executor: gather → one artifact
    /// call. Shared by both execution paths.
    fn exec_dot_block(
        &mut self,
        pc: usize,
        ra: u8,
        rb: u8,
        sum_only: bool,
        waves: usize,
        lanes: usize,
    ) -> Result<f32, SimError> {
        let depth = self.rt_waves();
        let n = depth * WAVEFRONT_WIDTH;
        self.scr_a.resize(n, 0);
        self.scr_b.resize(n, 0);
        self.scr_mask.resize(n, 0);
        for w in 0..depth {
            for sp in 0..WAVEFRONT_WIDTH {
                let idx = w * WAVEFRONT_WIDTH + sp;
                self.scr_a[idx] = self.regs.read(w, sp, ra);
                self.scr_b[idx] = if sum_only {
                    1f32.to_bits()
                } else {
                    self.regs.read(w, sp, rb)
                };
                self.scr_mask[idx] =
                    (w < waves && sp < lanes && self.thread_active(w, sp)) as u8;
            }
        }
        let be = match &mut self.exec {
            Exec::Block(b) => b,
            Exec::Native => unreachable!(),
        };
        be.dot_block(&self.scr_a, &self.scr_b, &self.scr_mask)
            .map_err(|m| SimError::new(pc, format!("datapath backend: {m}")))
    }

    /// DOT / SUM extension core: operands stream one wavefront per cycle,
    /// the scalar result writes back to thread 0 after the core latency.
    fn exec_dot(&mut self, pc: usize, i: &Instr) -> Result<(), SimError> {
        let sum_only = i.op == Opcode::Sum;
        let waves = i.tc.depth.waves(self.rt_waves());
        let lanes = i.tc.width.lanes();
        let start = self.cycles;
        self.hazards.read_reg(pc, i.ra, start);
        if !sum_only {
            self.hazards.read_reg(pc, i.rb, start);
        }

        let result = match &self.exec {
            Exec::Native => self.exec_dot_native(i.ra, i.rb, sum_only, waves, lanes),
            Exec::Block(_) => self.exec_dot_block(pc, i.ra, i.rb, sum_only, waves, lanes)?,
        };

        // Result lands in the leftmost SP (§3.1): thread 0's rd.
        if self.thread_active(0, 0) {
            self.regs.write(0, 0, i.rd, result.to_bits());
        }
        self.hazards
            .write_reg(i.rd, start, waves as u64 + DOT_WINDOW);
        self.cycles += waves as u64;
        self.profile.record(Group::Extension, waves as u64);
        Ok(())
    }

    /// IF/ELSE/ENDIF: per-thread predicate-stack updates, one wavefront
    /// per cycle (§3.2).
    fn exec_pred(&mut self, pc: usize, i: &Instr) -> Result<(), SimError> {
        let waves = i.tc.depth.waves(self.rt_waves());
        let lanes = i.tc.width.lanes();
        let start = self.cycles;
        if i.op == Opcode::If {
            self.hazards.read_reg(pc, i.ra, start);
            self.hazards.read_reg(pc, i.rb, start);
        }
        for w in 0..waves {
            for sp in 0..lanes {
                let t = w * WAVEFRONT_WIDTH + sp;
                let r = match i.op {
                    Opcode::If => {
                        let cc = i.cond().ok_or_else(|| {
                            SimError::new(pc, "IF without condition code")
                        })?;
                        let a = self.regs.read(w, sp, i.ra);
                        let b = self.regs.read(w, sp, i.rb);
                        self.preds.push(t, cc.eval(i.ttype, a, b))
                    }
                    Opcode::Else => self.preds.invert_top(t),
                    Opcode::EndIf => self.preds.pop(t),
                    _ => unreachable!(),
                };
                r.map_err(|e| SimError::new(pc, e.to_string()))?;
            }
        }
        self.cycles += waves as u64;
        self.profile.record(Group::Conditional, waves as u64);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::assemble;
    use crate::sim::config::MemoryMode;

    fn machine() -> Machine {
        let mut cfg = EgpuConfig::default();
        cfg.dot_core = true;
        cfg.sfu = true;
        Machine::new(cfg).unwrap()
    }

    fn run_src(m: &mut Machine, src: &str) -> RunStats {
        let p = assemble(src, m.cfg.word_layout()).unwrap();
        m.load_program(p).unwrap();
        m.run(10_000_000).unwrap()
    }

    #[test]
    fn tdx_and_alu_over_full_space() {
        let mut m = machine();
        let stats = run_src(
            &mut m,
            "
            tdx r0
            add.i32 r1, r0, r0
            stop
            ",
        );
        // 512 threads = 32 wavefronts per op + stop + drain.
        assert_eq!(stats.cycles, 32 + 32 + 1 + 8);
        for t in [0usize, 1, 17, 511] {
            assert_eq!(m.regs().read_thread(t, 0), t as u32);
            assert_eq!(m.regs().read_thread(t, 1), (2 * t) as u32);
        }
    }

    #[test]
    fn dynamic_narrowing_cycle_counts() {
        let mut m = machine();
        let stats = run_src(
            &mut m,
            "
            [w16,dall]  add.i32 r1, r0, r0   ; 32 cycles
            [w16,dhalf] add.i32 r1, r0, r0   ; 16
            [w16,dquart] add.i32 r1, r0, r0  ; 8
            [w4,d0]     add.i32 r1, r0, r0   ; 1
            [w1,d0]     add.i32 r1, r0, r0   ; 1 (MCU)
            stop
            ",
        );
        assert_eq!(stats.cycles, 32 + 16 + 8 + 1 + 1 + 1 + 8);
    }

    #[test]
    fn narrowed_op_only_touches_selected_threads() {
        let mut m = machine();
        run_src(
            &mut m,
            "
            ldi r1, #7
            [w4,d0] ldi r1, #9
            stop
            ",
        );
        assert_eq!(m.regs().read_thread(0, 1), 9);
        assert_eq!(m.regs().read_thread(3, 1), 9);
        assert_eq!(m.regs().read_thread(4, 1), 7); // SP4: outside w4
        assert_eq!(m.regs().read_thread(16, 1), 7); // wave 1: outside d0
    }

    #[test]
    fn load_store_roundtrip_and_cycles() {
        let mut m = machine();
        for a in 0..512u32 {
            m.shared_mut().write(a, a * 3).unwrap();
        }
        let stats = run_src(
            &mut m,
            "
            tdx r0
            lod r1, (r0)+0
            sto r1, (r0)+512
            stop
            ",
        );
        for a in 0..512u32 {
            assert_eq!(m.shared().read(512 + a).unwrap(), a * 3);
        }
        // tdx 32 + load 512/4 + store 512/1 + stop 1 + drain 8.
        assert_eq!(stats.cycles, 32 + 128 + 512 + 1 + 8);
        assert_eq!(stats.hazards, 0, "{:?}", stats.hazard_samples);
    }

    #[test]
    fn qp_store_is_twice_as_fast() {
        let mut dp = Machine::new(EgpuConfig::benchmark(MemoryMode::Dp, false)).unwrap();
        let mut qp = Machine::new(EgpuConfig::benchmark(MemoryMode::Qp, false)).unwrap();
        let src = "tdx r0\nsto r0, (r0)+0\nstop\n";
        let s_dp = run_src(&mut dp, src);
        let s_qp = run_src(&mut qp, src);
        assert_eq!(s_dp.cycles - s_qp.cycles, 256); // 512 vs 256 write slots
    }

    #[test]
    fn fp_math() {
        let mut m = machine();
        run_src(
            &mut m,
            "
            tdx r0
            ldi r1, #3
            nop
            nop
            nop
            nop
            nop
            nop
            ; int→fp is host-side: build 2.0f and 0.5f via bit patterns
            ldi r2, #0x4000          ; high half of 2.0f
            shl.u32 r2, r2, r3       ; r3 = 0 → shift 0 (placeholder)
            stop
            ",
        );
        // direct register math check through the datapath instead:
        let mut m = machine();
        let two = 2.0f32.to_bits();
        for t in 0..512 {
            m.regs.write_thread(t, 1, two);
            m.regs.write_thread(t, 2, 0.5f32.to_bits());
        }
        let p = assemble(
            "fmul r3, r1, r2\nfadd r4, r3, r1\ninvsqr r5, r1\nstop\n",
            m.cfg.word_layout(),
        )
        .unwrap();
        m.load_program(p).unwrap();
        // load_program resets registers — re-seed.
        for t in 0..512 {
            m.regs.write_thread(t, 1, two);
            m.regs.write_thread(t, 2, 0.5f32.to_bits());
        }
        m.run(1_000_000).unwrap();
        assert_eq!(f32::from_bits(m.regs().read_thread(10, 3)), 1.0);
        assert_eq!(f32::from_bits(m.regs().read_thread(10, 4)), 3.0);
        assert_eq!(
            f32::from_bits(m.regs().read_thread(10, 5)),
            1.0 / 2.0f32.sqrt()
        );
    }

    #[test]
    fn predicated_store_gated() {
        let mut m = machine();
        let stats = run_src(
            &mut m,
            "
            tdx r0
            ldi r1, #8
            nop
            nop
            nop
            nop
            nop
            nop
            if.lt.i32 r0, r1     ; threads 0..7 active
            ldi r2, #1
            else
            ldi r2, #2
            endif
            stop
            ",
        );
        assert_eq!(m.regs().read_thread(3, 2), 1);
        assert_eq!(m.regs().read_thread(9, 2), 2);
        assert_eq!(m.regs().read_thread(500, 2), 2);
        assert_eq!(stats.hazards, 0, "{:?}", stats.hazard_samples);
    }

    #[test]
    fn dot_product_reduces_to_thread0() {
        let mut m = machine();
        let p = assemble("dot r3, r1, r2\nstop\n", m.cfg.word_layout()).unwrap();
        m.load_program(p).unwrap();
        for t in 0..512 {
            m.regs.write_thread(t, 1, 2.0f32.to_bits());
            m.regs.write_thread(t, 2, 0.25f32.to_bits());
        }
        m.run(1_000).unwrap();
        assert_eq!(f32::from_bits(m.regs().read_thread(0, 3)), 256.0);
        // Other threads' r3 untouched.
        assert_eq!(m.regs().read_thread(1, 3), 0);
    }

    #[test]
    fn sum_reduces_ra_only() {
        let mut m = machine();
        let p = assemble("[w16,d0] sum r3, r1, r2\nstop\n", m.cfg.word_layout()).unwrap();
        m.load_program(p).unwrap();
        for sp in 0..16 {
            m.regs.write(0, sp, 1, (sp as f32).to_bits());
            m.regs.write(0, sp, 2, 99.0f32.to_bits()); // must be ignored
        }
        m.run(1_000).unwrap();
        assert_eq!(f32::from_bits(m.regs().read(0, 0, 3)), 120.0);
    }

    #[test]
    fn loop_and_branch_flow() {
        let mut m = machine();
        let stats = run_src(
            &mut m,
            "
            ldi r1, #0
            init #5
            nop
            nop
            nop
            nop
            nop
            nop
        body:
            [w1,d0] add.i32 r1, r1, r2
            nop
            nop
            nop
            nop
            nop
            loop body
            stop
            ",
        );
        // body executed 5 times (r2 is 0 so r1 stays 0 — flow test only).
        assert!(stats.instructions > 30);
        assert_eq!(stats.hazards, 0, "{:?}", stats.hazard_samples);
    }

    #[test]
    fn hazard_detected_for_back_to_back_mcu_ops() {
        let mut m = machine();
        let stats = run_src(
            &mut m,
            "
            [w1,d0] ldi r1, #1
            [w1,d0] add.i32 r2, r1, r1   ; reads r1 one cycle later: hazard
            stop
            ",
        );
        assert!(stats.hazards > 0);
        assert_eq!(stats.hazard_samples[0].resource, 1);
    }

    #[test]
    fn full_width_ops_hide_hazards() {
        let mut m = machine();
        let stats = run_src(
            &mut m,
            "
            ldi r1, #1
            add.i32 r2, r1, r1   ; 32 issue cycles apart: clean
            stop
            ",
        );
        assert_eq!(stats.hazards, 0);
    }

    #[test]
    fn runtime_thread_narrowing() {
        let mut m = machine();
        m.set_threads(128).unwrap(); // 8 wavefronts
        let p = assemble("add.i32 r1, r0, r0\nstop\n", m.cfg.word_layout()).unwrap();
        // set_threads survives load_program (reset keeps rt config).
        m.load_program(p).unwrap();
        let stats = m.run(1_000).unwrap();
        assert_eq!(stats.cycles, 8 + 1 + 8);
        assert!(m.set_threads(1024).is_err());
        assert!(m.set_threads(100).is_err());
    }

    #[test]
    fn dim_x_controls_tdy() {
        let mut m = machine();
        m.set_dim_x(32).unwrap();
        let p = assemble("tdx r0\ntdy r1\nstop\n", m.cfg.word_layout()).unwrap();
        m.load_program(p).unwrap();
        m.run(1_000).unwrap();
        assert_eq!(m.regs().read_thread(37, 0), 5); // 37 % 32
        assert_eq!(m.regs().read_thread(37, 1), 1); // 37 / 32
    }

    #[test]
    fn oob_memory_faults() {
        let mut m = machine();
        let p = assemble("ldi r0, #-1\nnop\nnop\nnop\nnop\nnop\nnop\nlod r1, (r0)+0\nstop\n", m.cfg.word_layout())
            .unwrap();
        m.load_program(p).unwrap();
        let e = m.run(100_000).unwrap_err();
        assert!(e.message.contains("fault"), "{e}");
    }

    #[test]
    fn unsupported_ops_rejected_at_load() {
        let mut cfg = EgpuConfig::default();
        cfg.dot_core = false;
        let mut m = Machine::new(cfg).unwrap();
        let p = assemble("dot r1, r2, r3\nstop\n", m.cfg.word_layout()).unwrap();
        let e = m.load_program(p).unwrap_err();
        assert!(e.message.contains("dot-product"));
    }

    #[test]
    fn branch_target_validated_at_load() {
        let mut m = machine();
        let p = assemble("jmp 40\nstop\n", m.cfg.word_layout()).unwrap();
        assert!(m.load_program(p).is_err());
    }

    #[test]
    fn stop_drains_pipeline() {
        let mut m = machine();
        let stats = run_src(&mut m, "stop\n");
        assert_eq!(stats.cycles, 1 + PIPELINE_DEPTH);
    }

    #[test]
    fn cycle_limit_guards_runaway() {
        let mut m = machine();
        let p = assemble("top: jmp top\n", m.cfg.word_layout()).unwrap();
        m.load_program(p).unwrap();
        assert!(m.run(100).is_err());
    }

    #[test]
    fn cycle_limit_error_carries_partial_stats() {
        let mut m = machine();
        let p = assemble("top: jmp top\n", m.cfg.word_layout()).unwrap();
        m.load_program(p).unwrap();
        let e = m.run(100).unwrap_err();
        assert!(e.message.contains("cycle limit"), "{e}");
        let partial = e.partial.expect("budget stop keeps progress");
        assert_eq!(partial.cycles, 100);
        assert_eq!(partial.instructions, 100);
        assert!(partial.profile.count(Group::Control) > 0);
        // The machine's own counters agree with the snapshot.
        assert_eq!(m.cycles(), 100);
        assert_eq!(m.stats_snapshot(), *partial);
        // Reference interpreter: identical budget behavior.
        let mut r = machine();
        let p = assemble("top: jmp top\n", r.cfg.word_layout()).unwrap();
        r.load_program(p).unwrap();
        let er = r.run_reference(100).unwrap_err();
        assert_eq!(er.partial.as_deref().map(|s| s.cycles), Some(100));
    }

    #[test]
    fn load_program_recompiles_plans_for_edited_instrs() {
        // Every Program field is public; an in-place edit to `instrs`
        // (stale `plans` still attached) must be what executes.
        let mut m = machine();
        let mut p = assemble("ldi r1, #7\nstop\n", m.cfg.word_layout()).unwrap();
        p.instrs[0].imm = 9;
        m.load_program(p).unwrap();
        m.run(1_000).unwrap();
        assert_eq!(m.regs().read_thread(0, 1), 9, "stale plan executed");
    }

    const PARITY_SRC: &str = "
        tdx r0
        ldi r1, #8
        nop
        nop
        nop
        nop
        nop
        nop
        if.lt.i32 r0, r1
        ldi r2, #1
        else
        ldi r2, #2
        endif
        [w16,dhalf] add.i32 r3, r0, r1
        lod r4, (r0)+0
        sto r4, (r0)+512
        dot r5, r1, r1
        stop
    ";

    fn state(m: &Machine) -> Vec<u32> {
        (0..512)
            .flat_map(|t| (0..8u8).map(move |r| (t, r)))
            .map(|(t, r)| m.regs().read_thread(t, r))
            .collect()
    }

    #[test]
    fn superplan_path_matches_per_instruction_plan_path() {
        let mut fused = machine();
        let sf = run_src(&mut fused, PARITY_SRC);
        assert!(fused.trace_stats().fused_retired > 0, "traces actually ran");

        let mut plain = machine();
        plain.set_superplans(false);
        let sp = run_src(&mut plain, PARITY_SRC);
        assert_eq!(plain.trace_stats().fused_retired, 0);

        assert_eq!(sf, sp);
        assert_eq!(state(&fused), state(&plain));
    }

    #[test]
    fn superplan_trace_stats_cover_the_program() {
        let mut m = machine();
        run_src(&mut m, PARITY_SRC);
        let ts = m.trace_stats();
        assert!(ts.traces >= 1);
        assert!(ts.fused_pcs >= 2);
        assert!(ts.mean_trace_len >= 2.0);
        assert!(ts.retired > 0);
        assert!(ts.fused_retired <= ts.retired);
        assert!(ts.dynamic_fused_pct() > 0.0);
        // Everything except STOP is one straight-line run here.
        assert_eq!(ts.fused_pcs, ts.program_pcs - 1);
    }

    #[test]
    fn budget_stop_mid_trace_matches_per_instruction_path() {
        // Sweep budgets across the whole run: every stop point — including
        // ones that land inside a fused trace — must leave identical
        // partial stats and architectural state in both modes.
        let total = {
            let mut m = machine();
            run_src(&mut m, PARITY_SRC).cycles
        };
        for budget in [1, 33, 64, 65, 100, 170, 200, 300, total - 9] {
            let mut fused = machine();
            let pf = assemble(PARITY_SRC, fused.cfg.word_layout()).unwrap();
            fused.load_program(pf).unwrap();
            let ef = fused.run(budget).unwrap_err();

            let mut plain = machine();
            plain.set_superplans(false);
            let pp = assemble(PARITY_SRC, plain.cfg.word_layout()).unwrap();
            plain.load_program(pp).unwrap();
            let ep = plain.run(budget).unwrap_err();

            assert_eq!(ef, ep, "budget {budget}");
            let partial = ef.partial.expect("budget stop keeps progress");
            assert_eq!(fused.stats_snapshot(), *partial, "budget {budget}");
            assert_eq!(state(&fused), state(&plain), "budget {budget}");
        }
    }

    #[test]
    fn reload_keeps_program_and_resets_state() {
        let mut m = machine();
        assert!(m.reload().is_err(), "no program loaded yet");
        let first = run_src(&mut m, "tdx r0\nadd.i32 r1, r0, r0\nstop\n");
        m.reload().unwrap();
        assert_eq!(m.cycles(), 0);
        assert_eq!(m.regs().read_thread(7, 0), 0, "registers reset");
        let second = m.run(10_000_000).unwrap();
        assert_eq!(first, second, "reused program replays identically");
        assert_eq!(m.regs().read_thread(7, 1), 14);
    }

    #[test]
    fn set_threads_recompiles_superplan_charges() {
        let mut m = machine();
        let p = assemble("tdx r0\nadd.i32 r1, r0, r0\nstop\n", m.cfg.word_layout()).unwrap();
        m.load_program(p).unwrap();
        m.set_threads(128).unwrap(); // 8 wavefronts
        let stats = m.run(1_000).unwrap();
        assert_eq!(stats.cycles, 8 + 8 + 1 + 8);
        assert!(m.trace_stats().fused_retired > 0);
    }

    #[test]
    fn reference_interpreter_matches_planned_loop() {
        let src = "
            tdx r0
            ldi r1, #8
            nop
            nop
            nop
            nop
            nop
            nop
            if.lt.i32 r0, r1
            ldi r2, #1
            else
            ldi r2, #2
            endif
            [w16,dhalf] add.i32 r3, r0, r1
            lod r4, (r0)+0
            sto r4, (r0)+512
            dot r5, r1, r1
            stop
        ";
        let mut a = machine();
        let sa = run_src(&mut a, src);
        let mut b = machine();
        let p = assemble(src, b.cfg.word_layout()).unwrap();
        b.load_program(p).unwrap();
        let sb = b.run_reference(10_000_000).unwrap();
        assert_eq!(sa, sb);
        for t in 0..512 {
            for r in 0..6u8 {
                assert_eq!(
                    a.regs().read_thread(t, r),
                    b.regs().read_thread(t, r),
                    "thread {t} r{r}"
                );
            }
        }
    }
}
