//! Per-thread predicate stacks (paper §3.2, Figure 2).
//!
//! Each initialized thread has a unique single-bit-wide stack. IF pushes
//! the thread's condition result, ELSE inverts the top, ENDIF pops. A
//! thread is active when *every* level of its stack is 1 (nested
//! conditions AND together). The `thread_active` signal gates register and
//! shared-memory write enables — it never gates the sequencer, which is
//! common to all threads.
//!
//! Representation: one `u32` mask + depth per thread; level `i` of the
//! stack is bit `i`. `active` ⇔ the low `depth` bits are all ones.

#[derive(Debug, Clone)]
pub struct PredicateFile {
    /// Per-thread stack bits (bit i = nesting level i condition).
    masks: Vec<u32>,
    /// Per-thread nesting depth.
    depths: Vec<u8>,
    /// Configured maximum nesting (0 = predicates not synthesized).
    max_levels: usize,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PredError {
    /// IF nesting exceeded the configured stack depth.
    Overflow { thread: usize, max_levels: usize },
    /// ELSE/ENDIF with an empty stack.
    Underflow { thread: usize },
    /// Program uses predicates but the configuration omits them.
    NotConfigured,
}

impl std::fmt::Display for PredError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PredError::Overflow { thread, max_levels } => write!(
                f,
                "thread {thread}: IF nesting exceeds the configured {max_levels} levels"
            ),
            PredError::Underflow { thread } => {
                write!(f, "thread {thread}: ELSE/ENDIF without matching IF")
            }
            PredError::NotConfigured => {
                write!(f, "predicates are not synthesized in this configuration")
            }
        }
    }
}

impl std::error::Error for PredError {}

impl PredicateFile {
    pub fn new(threads: usize, max_levels: usize) -> PredicateFile {
        PredicateFile {
            masks: vec![0; threads],
            depths: vec![0; threads],
            max_levels,
        }
    }

    pub fn configured(&self) -> bool {
        self.max_levels > 0
    }

    pub fn reset(&mut self) {
        self.masks.fill(0);
        self.depths.fill(0);
    }

    /// Is this thread's write enable asserted?
    #[inline]
    pub fn active(&self, thread: usize) -> bool {
        let d = self.depths[thread] as u32;
        // All `d` stack levels must be 1.
        self.masks[thread] & ((1u32 << d) - 1) == (1u32 << d) - 1
    }

    /// IF: push the thread's condition result.
    pub fn push(&mut self, thread: usize, cond: bool) -> Result<(), PredError> {
        if self.max_levels == 0 {
            return Err(PredError::NotConfigured);
        }
        let d = self.depths[thread] as usize;
        if d >= self.max_levels {
            return Err(PredError::Overflow {
                thread,
                max_levels: self.max_levels,
            });
        }
        if cond {
            self.masks[thread] |= 1 << d;
        } else {
            self.masks[thread] &= !(1 << d);
        }
        self.depths[thread] += 1;
        Ok(())
    }

    /// ELSE: invert the top of the stack.
    pub fn invert_top(&mut self, thread: usize) -> Result<(), PredError> {
        if self.max_levels == 0 {
            return Err(PredError::NotConfigured);
        }
        let d = self.depths[thread] as usize;
        if d == 0 {
            return Err(PredError::Underflow { thread });
        }
        self.masks[thread] ^= 1 << (d - 1);
        Ok(())
    }

    /// ENDIF: pop, returning to the previous nesting level.
    pub fn pop(&mut self, thread: usize) -> Result<(), PredError> {
        if self.max_levels == 0 {
            return Err(PredError::NotConfigured);
        }
        if self.depths[thread] == 0 {
            return Err(PredError::Underflow { thread });
        }
        self.depths[thread] -= 1;
        Ok(())
    }

    pub fn depth(&self, thread: usize) -> usize {
        self.depths[thread] as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_stack_is_active() {
        let p = PredicateFile::new(4, 5);
        for t in 0..4 {
            assert!(p.active(t));
        }
    }

    #[test]
    fn if_else_endif_sequence() {
        let mut p = PredicateFile::new(2, 5);
        // Thread 0 takes the IF branch, thread 1 the ELSE branch.
        p.push(0, true).unwrap();
        p.push(1, false).unwrap();
        assert!(p.active(0));
        assert!(!p.active(1));
        p.invert_top(0).unwrap();
        p.invert_top(1).unwrap();
        assert!(!p.active(0));
        assert!(p.active(1));
        p.pop(0).unwrap();
        p.pop(1).unwrap();
        assert!(p.active(0));
        assert!(p.active(1));
    }

    #[test]
    fn nesting_ands_conditions() {
        let mut p = PredicateFile::new(1, 5);
        p.push(0, true).unwrap();
        p.push(0, false).unwrap(); // inner false
        assert!(!p.active(0));
        p.push(0, true).unwrap(); // deeper true cannot re-activate
        assert!(!p.active(0));
        p.pop(0).unwrap();
        p.pop(0).unwrap();
        assert!(p.active(0));
        assert_eq!(p.depth(0), 1);
    }

    #[test]
    fn inner_if_under_false_outer_stays_inactive_through_else() {
        // Classic divergence correctness: ELSE of an inner IF nested under
        // a false outer IF must not activate the thread.
        let mut p = PredicateFile::new(1, 5);
        p.push(0, false).unwrap(); // outer false
        p.push(0, false).unwrap(); // inner (not taken anyway)
        p.invert_top(0).unwrap(); // inner ELSE → top true, outer still false
        assert!(!p.active(0));
    }

    #[test]
    fn overflow_at_configured_levels() {
        let mut p = PredicateFile::new(1, 2);
        p.push(0, true).unwrap();
        p.push(0, true).unwrap();
        assert!(matches!(
            p.push(0, true),
            Err(PredError::Overflow { max_levels: 2, .. })
        ));
    }

    #[test]
    fn underflow_errors() {
        let mut p = PredicateFile::new(1, 2);
        assert!(matches!(p.pop(0), Err(PredError::Underflow { .. })));
        assert!(matches!(p.invert_top(0), Err(PredError::Underflow { .. })));
    }

    #[test]
    fn not_configured_errors() {
        let mut p = PredicateFile::new(1, 0);
        assert!(!p.configured());
        assert_eq!(p.push(0, true), Err(PredError::NotConfigured));
        // With no predicates every thread is permanently active.
        assert!(p.active(0));
    }

    #[test]
    fn per_thread_independence() {
        let mut p = PredicateFile::new(512, 8);
        for t in 0..512 {
            p.push(t, t % 3 == 0).unwrap();
        }
        for t in 0..512 {
            assert_eq!(p.active(t), t % 3 == 0);
        }
        p.reset();
        for t in 0..512 {
            assert!(p.active(t));
            assert_eq!(p.depth(t), 0);
        }
    }
}
