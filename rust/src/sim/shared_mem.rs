//! Shared memory with DP/QP port arbitration (paper §3, §5.1).
//!
//! The shared memory is a single local data memory: four read ports and
//! one (DP) or two (QP) write ports *per clock cycle*. Loads and stores
//! are therefore multi-cycle over the selected thread subset — this is the
//! dominant cycle cost in every benchmark (§7: "the memory operations take
//! the majority of all cycles").
//!
//! Functional state is a flat word array; the port model provides the
//! cycle counts the machine charges.

use super::config::MemoryMode;

#[derive(Debug, Clone)]
pub struct SharedMem {
    words: Vec<u32>,
    mode: MemoryMode,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemFault {
    pub addr: u32,
    pub size: usize,
    pub is_store: bool,
}

impl std::fmt::Display for MemFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "shared-memory {} fault: address {} outside {} words",
            if self.is_store { "store" } else { "load" },
            self.addr,
            self.size
        )
    }
}

impl std::error::Error for MemFault {}

impl SharedMem {
    pub fn new(words: usize, mode: MemoryMode) -> SharedMem {
        SharedMem {
            words: vec![0; words],
            mode,
        }
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    pub fn mode(&self) -> MemoryMode {
        self.mode
    }

    #[inline]
    pub fn read(&self, addr: u32) -> Result<u32, MemFault> {
        self.words.get(addr as usize).copied().ok_or(MemFault {
            addr,
            size: self.words.len(),
            is_store: false,
        })
    }

    #[inline]
    pub fn write(&mut self, addr: u32, value: u32) -> Result<(), MemFault> {
        let size = self.words.len();
        match self.words.get_mut(addr as usize) {
            Some(w) => {
                *w = value;
                Ok(())
            }
            None => Err(MemFault {
                addr,
                size,
                is_store: true,
            }),
        }
    }

    /// Cycles to read `lanes` values (4 read ports/cycle, both modes).
    pub fn load_cycles(&self, lanes: usize) -> u64 {
        self.mode.load_cycles(lanes)
    }

    /// Cycles to write `lanes` values (1 DP / 2 QP write ports).
    pub fn store_cycles(&self, lanes: usize) -> u64 {
        self.mode.store_cycles(lanes)
    }

    /// Bulk host access (data is loaded/unloaded externally, §2: "the
    /// loading and unloading of which has to be managed externally").
    pub fn write_block(&mut self, base: usize, data: &[u32]) {
        self.words[base..base + data.len()].copy_from_slice(data);
    }

    pub fn read_block(&self, base: usize, len: usize) -> &[u32] {
        &self.words[base..base + len]
    }

    pub fn fill(&mut self, value: u32) {
        self.words.fill(value);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rw_roundtrip() {
        let mut m = SharedMem::new(64, MemoryMode::Dp);
        m.write(10, 0xDEADBEEF).unwrap();
        assert_eq!(m.read(10).unwrap(), 0xDEADBEEF);
        assert_eq!(m.read(11).unwrap(), 0);
    }

    #[test]
    fn oob_faults() {
        let mut m = SharedMem::new(16, MemoryMode::Dp);
        assert!(m.read(16).is_err());
        assert!(m.write(100, 1).is_err());
        let f = m.read(16).unwrap_err();
        assert_eq!(f.addr, 16);
        assert!(!f.is_store);
    }

    #[test]
    fn dp_port_cycle_model() {
        // §7 transpose analysis: "n² cycles to write ... and 1/4th of
        // those cycles to initially read" → 4 reads/cycle, 1 write/cycle.
        let m = SharedMem::new(1024, MemoryMode::Dp);
        assert_eq!(m.load_cycles(16), 4);
        assert_eq!(m.store_cycles(16), 16);
        assert_eq!(m.load_cycles(512), 128);
        assert_eq!(m.store_cycles(512), 512);
    }

    #[test]
    fn qp_doubles_write_bandwidth() {
        // §3: "The QP memory will double the write bandwidth".
        let m = SharedMem::new(1024, MemoryMode::Qp);
        assert_eq!(m.load_cycles(16), 4); // reads unchanged
        assert_eq!(m.store_cycles(16), 8);
        assert_eq!(m.store_cycles(512), 256);
    }

    #[test]
    fn subset_write_is_16x_faster() {
        // §4: "Writing these results into shared memory using subset
        // write can be 16x faster than using the generic write."
        let m = SharedMem::new(1024, MemoryMode::Dp);
        assert_eq!(m.store_cycles(16) / m.store_cycles(1), 16);
    }

    #[test]
    fn minimum_one_cycle() {
        let m = SharedMem::new(16, MemoryMode::Dp);
        assert_eq!(m.load_cycles(1), 1);
        assert_eq!(m.load_cycles(3), 1);
        assert_eq!(m.store_cycles(1), 1);
    }

    #[test]
    fn block_io() {
        let mut m = SharedMem::new(32, MemoryMode::Dp);
        m.write_block(4, &[1, 2, 3]);
        assert_eq!(m.read_block(4, 3), &[1, 2, 3]);
        m.fill(7);
        assert_eq!(m.read(0).unwrap(), 7);
    }
}
