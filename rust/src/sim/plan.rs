//! Decode-time issue plans.
//!
//! The eGPU pipeline does no per-cycle re-interpretation: an instruction's
//! datapath routing, operand shape, thread-space geometry and port charges
//! are all fixed by its encoding. The simulator mirrors that discipline by
//! compiling every [`Instr`] into an [`IssuePlan`] once — at assembly (the
//! plans travel with [`crate::asm::Program`]) or at program load — so the
//! `Machine::run` hot loop is reduced to fetch-plan → execute-lanes →
//! charge, with `classify()`, `Opcode::operands()`, condition-code
//! decoding and group-slot lookups all hoisted out of the per-instruction
//! path.
//!
//! The only run-time-dependent quantity is the wavefront count selected by
//! the depth field (it depends on the runtime thread configuration,
//! §3.2), so the plan stores the [`DepthSel`] and the machine resolves it
//! through a 4-entry table rebuilt on `set_threads`.
//!
//! `Machine::run_reference` retains the original re-deriving interpreter;
//! `rust/tests/asm_sim_properties.rs` proves the two produce bit-identical
//! architectural state, cycle counts and hazard totals on randomized
//! programs.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::datapath::{classify, DpOp};
use crate::isa::opcode::OperandShape;
use crate::isa::{CondCode, DepthSel, Instr, Opcode, TType};

use super::profiler::Profile;
use super::shared_mem::SharedMem;

/// What the execute stage does for one instruction, with every decode
/// decision already made.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PlanKind {
    Nop,
    /// Sequencer ops; the target/count is the plan's `imm`.
    Jmp,
    Jsr,
    Rts,
    Loop,
    Init,
    Stop,
    /// Per-thread generated values (LDI immediate / thread IDs).
    Ldi,
    TdX,
    TdY,
    /// Wavefront ALU op, pre-classified to its datapath op
    /// ([`DpOp::Fp`] or [`DpOp::Int`] only — DOT/SUM are [`PlanKind::Dot`]).
    Alu(DpOp),
    Load,
    Store,
    /// DOT (a·b) or SUM (Σa) extension core.
    Dot { sum_only: bool },
    /// Predicate push with the pre-decoded condition.
    If { cc: CondCode, ttype: TType },
    Else,
    EndIf,
}

/// A pre-resolved execution plan for one instruction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IssuePlan {
    pub kind: PlanKind,
    /// Wave-depth selector; resolved against the runtime thread count
    /// through the machine's wave table.
    pub depth: DepthSel,
    /// Lanes enabled by the width selector (1, 4 or 16).
    pub lanes: u8,
    /// Does this instruction read Rb? (operand shape, pre-resolved —
    /// drives the hazard-checker's read set.)
    pub uses_rb: bool,
    pub rd: u8,
    pub ra: u8,
    pub rb: u8,
    /// Pre-resolved immediate: sign-extended bits for LDI, zero-extended
    /// raw value otherwise (addresses, offsets, loop counts).
    pub imm: u32,
    /// Profiler slot of the opcode's group ([`crate::isa::Group::index`]).
    pub slot: u8,
}

/// Plan-compilation error, annotated with the instruction address.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanError {
    pub pc: usize,
    pub message: String,
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "pc {}: {}", self.pc, self.message)
    }
}

impl std::error::Error for PlanError {}

/// Compile one instruction. Fails only on encodings the assembler never
/// emits (an IF word whose condition-code bits are unallocated).
pub fn compile_one(i: &Instr) -> Result<IssuePlan, String> {
    use Opcode::*;
    let kind = match i.op {
        Nop => PlanKind::Nop,
        Jmp => PlanKind::Jmp,
        Jsr => PlanKind::Jsr,
        Rts => PlanKind::Rts,
        Loop => PlanKind::Loop,
        Init => PlanKind::Init,
        Stop => PlanKind::Stop,
        Ldi => PlanKind::Ldi,
        TdX => PlanKind::TdX,
        TdY => PlanKind::TdY,
        Lod => PlanKind::Load,
        Sto => PlanKind::Store,
        Dot => PlanKind::Dot { sum_only: false },
        Sum => PlanKind::Dot { sum_only: true },
        If => PlanKind::If {
            cc: i.cond().ok_or("IF without condition code")?,
            ttype: i.ttype,
        },
        Else => PlanKind::Else,
        EndIf => PlanKind::EndIf,
        _ => match classify(i) {
            Some(dp @ (DpOp::Fp(_) | DpOp::Int(_))) => PlanKind::Alu(dp),
            _ => return Err(format!("{} is not executable", i.op)),
        },
    };
    Ok(IssuePlan {
        kind,
        depth: i.tc.depth,
        lanes: i.tc.width.lanes() as u8,
        uses_rb: matches!(
            i.op.operands(),
            OperandShape::RdRaRb | OperandShape::RaRb
        ),
        rd: i.rd,
        ra: i.ra,
        rb: i.rb,
        imm: if i.op == Ldi { i.imm_i() as u32 } else { i.imm_u() },
        slot: i.op.group().index() as u8,
    })
}

/// Compile a whole program's plans, one per instruction.
pub fn compile(instrs: &[Instr]) -> Result<Vec<IssuePlan>, PlanError> {
    instrs
        .iter()
        .enumerate()
        .map(|(pc, i)| compile_one(i).map_err(|message| PlanError { pc, message }))
        .collect()
}

// ---------------------------------------------------------------------
// Superplans: fused straight-line traces.
//
// A trace is a maximal run of fusable plans (everything except the
// sequencer ops) that no branch lands inside. Its per-op cycle charges —
// constant once the runtime thread count and memory mode are fixed — are
// resolved into prefix offsets at compile time, so the machine executes
// the whole run with per-op lane work and hazard bookkeeping at explicit
// start cycles, then applies the trace's total charge, profiler delta and
// retire count once. Per-instruction dispatch survives only at trace
// boundaries (control flow) and when the cycle budget cannot cover the
// trace's last issue slot.
// ---------------------------------------------------------------------

/// Minimum run length worth fusing; a 1-op "trace" is just dispatch.
pub const MIN_TRACE_LEN: usize = 2;

/// `trace_at` sentinel: no trace leads at this pc.
const NO_TRACE: u32 = u32::MAX;

/// One fused instruction: the issue plan plus its cycle charge and issue
/// offset inside the trace, resolved once at superplan-compile time.
#[derive(Debug, Clone, Copy)]
pub struct TraceOp {
    pub plan: IssuePlan,
    /// Cycle charge at the compiled thread configuration.
    pub charge: u64,
    /// Issue offset from the trace start (prefix sum of prior charges;
    /// strictly increasing because every charge is ≥ 1).
    pub offset: u64,
}

/// A fused straight-line trace of [`TraceOp`]s.
#[derive(Debug, Clone)]
pub struct Superplan {
    /// pc of the trace leader.
    pub start_pc: usize,
    /// Index of the leader's op in [`SuperplanProgram::ops`].
    pub first_op: usize,
    /// Fused instruction count (≥ [`MIN_TRACE_LEN`]).
    pub len: usize,
    /// Total cycle charge of the whole trace.
    pub total_cycles: u64,
    /// Issue offset of the final op. The per-instruction budget check
    /// (`cycles >= max` *before* issue) passes for every op in the trace
    /// iff `cycles + last_offset < max`, so the machine can prove the
    /// whole trace budget-clean with one comparison and otherwise fall
    /// back to per-instruction stepping for an exact mid-trace stop.
    pub last_offset: u64,
    /// Precomputed profiler delta (slot counts + cycles) for the whole
    /// trace; merged once on completion, bit-identical to per-op
    /// `record_slot` calls.
    pub prof: Profile,
}

/// All fused traces of one program at one thread configuration.
#[derive(Debug, Clone, Default)]
pub struct SuperplanProgram {
    /// Every trace's ops, flattened (indexed via [`Superplan::first_op`]).
    pub ops: Vec<TraceOp>,
    pub traces: Vec<Superplan>,
    /// pc → trace index for leaders, [`NO_TRACE`] elsewhere. Mid-trace
    /// pcs deliberately have no entry: entering a run mid-way (branch
    /// fallback, budget stop resume) uses per-instruction dispatch.
    trace_at: Vec<u32>,
}

impl SuperplanProgram {
    /// Trace led by `pc`, if any.
    #[inline]
    pub fn trace_index(&self, pc: usize) -> Option<usize> {
        match self.trace_at.get(pc) {
            Some(&t) if t != NO_TRACE => Some(t as usize),
            _ => None,
        }
    }

    /// Mean fused-trace length (static).
    pub fn mean_trace_len(&self) -> f64 {
        if self.traces.is_empty() {
            0.0
        } else {
            self.ops.len() as f64 / self.traces.len() as f64
        }
    }
}

/// Can this plan live inside a trace? Sequencer ops (control transfers,
/// loop bookkeeping, STOP) are trace boundaries; everything else —
/// including predicate ops, whose gating is per-lane state, and NOP delay
/// slots, whose hazard-fence role is preserved by the per-op issue
/// offsets — fuses.
#[inline]
fn fusable(kind: PlanKind) -> bool {
    !matches!(
        kind,
        PlanKind::Jmp
            | PlanKind::Jsr
            | PlanKind::Rts
            | PlanKind::Loop
            | PlanKind::Init
            | PlanKind::Stop
    )
}

/// Cycle charge of one plan at a fixed thread configuration — the same
/// arithmetic the per-instruction path performs at issue, hoisted to
/// compile time (`wave_tab` is the machine's depth-selector resolution,
/// `shared` carries the memory mode's port widths).
fn charge_of(p: &IssuePlan, wave_tab: &[usize; 4], shared: &SharedMem) -> u64 {
    let waves = wave_tab[p.depth.bits() as usize];
    let lanes = p.lanes as usize;
    match p.kind {
        PlanKind::Nop => 1,
        PlanKind::Load => shared.load_cycles(waves * lanes),
        PlanKind::Store => shared.store_cycles(waves * lanes),
        _ => waves as u64,
    }
}

/// Partition a plan stream into fused traces. Leaders start at pc 0,
/// after every sequencer op, and at every branch/call/loop target (a
/// landing pc must begin its own trace so control flow re-enters fused
/// execution immediately). Runs shorter than [`MIN_TRACE_LEN`] are left
/// to per-instruction dispatch.
pub fn compile_superplans(
    plans: &[IssuePlan],
    wave_tab: &[usize; 4],
    shared: &SharedMem,
) -> SuperplanProgram {
    let mut is_target = vec![false; plans.len()];
    for p in plans {
        if matches!(p.kind, PlanKind::Jmp | PlanKind::Jsr | PlanKind::Loop) {
            if let Some(t) = is_target.get_mut(p.imm as usize) {
                *t = true;
            }
        }
    }
    let mut sp = SuperplanProgram {
        ops: Vec::new(),
        traces: Vec::new(),
        trace_at: vec![NO_TRACE; plans.len()],
    };
    let mut pc = 0usize;
    while pc < plans.len() {
        if !fusable(plans[pc].kind) {
            pc += 1;
            continue;
        }
        let start = pc;
        let mut end = pc + 1;
        while end < plans.len() && fusable(plans[end].kind) && !is_target[end] {
            end += 1;
        }
        if end - start >= MIN_TRACE_LEN {
            let first_op = sp.ops.len();
            let mut offset = 0u64;
            let mut last_offset = 0u64;
            let mut prof = Profile::new();
            for p in &plans[start..end] {
                let charge = charge_of(p, wave_tab, shared);
                sp.ops.push(TraceOp {
                    plan: *p,
                    charge,
                    offset,
                });
                prof.record_slot(p.slot as usize, charge);
                last_offset = offset;
                offset += charge;
            }
            sp.trace_at[start] = sp.traces.len() as u32;
            sp.traces.push(Superplan {
                start_pc: start,
                first_op,
                len: end - start,
                total_cycles: offset,
                last_offset,
                prof,
            });
        }
        pc = end;
    }
    sp
}

// ---------------------------------------------------------------------
// Superplan cache: fleet-wide sharing of compiled superplan programs.
//
// `compile_superplans` is pure — its output depends only on the plan
// stream (itself a pure function of the encoded instruction words), the
// wave table (a pure function of the runtime thread count) and the
// shared-memory port charges (a pure function of the config's memory
// mode, which `EgpuConfig::fingerprint` covers). So a fleet whose cores
// replay the same kernels should compile each superplan program exactly
// once per distinct (program, config fingerprint, thread count) triple
// and share the `Arc`, the same economics [`crate::kernels::KernelCache`]
// gives kernel specialization.
// ---------------------------------------------------------------------

/// Exact identity of one superplan compilation. `words` are the encoded
/// instruction words (collision-free program identity — the word layout
/// itself is pinned by the config fingerprint's register axis),
/// `fingerprint` is [`crate::sim::EgpuConfig::fingerprint`] (covers the
/// memory mode driving load/store charges), `threads` is the runtime
/// thread count the wave table derives from.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SuperplanKey {
    pub words: Arc<[u64]>,
    pub fingerprint: u64,
    pub threads: usize,
}

/// Counters proving the compile-once property for superplans, reported
/// beside the kernel cache's [`crate::kernels::CacheStats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SuperplanCacheStats {
    /// Superplan programs compiled (unique [`SuperplanKey`]s).
    pub compiles: u64,
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Entries currently resident.
    pub entries: usize,
}

/// Memoizes compiled [`SuperplanProgram`]s per [`SuperplanKey`].
#[derive(Debug, Default)]
pub struct SuperplanCache {
    entries: Mutex<HashMap<SuperplanKey, Arc<SuperplanProgram>>>,
    compiles: AtomicU64,
    hits: AtomicU64,
}

impl SuperplanCache {
    pub fn new() -> SuperplanCache {
        SuperplanCache::default()
    }

    /// A fresh cache behind an `Arc`, ready to share across cores.
    pub fn shared() -> Arc<SuperplanCache> {
        Arc::new(SuperplanCache::new())
    }

    /// The superplan program for `key`, compiling at most once per key.
    /// The compile happens under the lock, so concurrent lookups of the
    /// same key from pooled workers still produce exactly one compile —
    /// which keeps the compile/hit totals deterministic for a fixed
    /// multiset of lookups, whatever order the workers arrive in.
    pub fn get(
        &self,
        key: &SuperplanKey,
        plans: &[IssuePlan],
        wave_tab: &[usize; 4],
        shared: &SharedMem,
    ) -> Arc<SuperplanProgram> {
        let mut entries = self.entries.lock().unwrap();
        if let Some(sp) = entries.get(key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(sp);
        }
        let sp = Arc::new(compile_superplans(plans, wave_tab, shared));
        self.compiles.fetch_add(1, Ordering::Relaxed);
        entries.insert(key.clone(), Arc::clone(&sp));
        sp
    }

    pub fn stats(&self) -> SuperplanCacheStats {
        SuperplanCacheStats {
            compiles: self.compiles.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            entries: self.entries.lock().unwrap().len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datapath::{FpOp, IntOp};
    use crate::isa::{Group, ThreadCtrl, WidthSel};

    #[test]
    fn every_opcode_compiles() {
        for bits in 0..Opcode::COUNT as u8 {
            let op = Opcode::from_bits(bits).unwrap();
            let mut i = Instr::new(op);
            if op == Opcode::If {
                i.imm = CondCode::Lt.bits() as u16;
            }
            let p = compile_one(&i).unwrap_or_else(|e| panic!("{op:?}: {e}"));
            assert_eq!(p.slot as usize, op.group().index(), "{op:?}");
        }
    }

    #[test]
    fn alu_classification_and_operand_shape() {
        let mut i = Instr::new(Opcode::FAdd);
        i.ttype = TType::Fp32;
        let p = compile_one(&i).unwrap();
        assert_eq!(p.kind, PlanKind::Alu(DpOp::Fp(FpOp::FAdd)));
        assert!(p.uses_rb);

        let mut s = Instr::new(Opcode::Shr);
        s.ttype = TType::Uint;
        let p = compile_one(&s).unwrap();
        assert_eq!(p.kind, PlanKind::Alu(DpOp::Int(IntOp::ShrL)));

        // Unary ops don't read Rb.
        let p = compile_one(&Instr::new(Opcode::Neg)).unwrap();
        assert!(!p.uses_rb);
        let p = compile_one(&Instr::new(Opcode::InvSqr)).unwrap();
        assert_eq!(p.kind, PlanKind::Alu(DpOp::Fp(FpOp::FInvSqrt)));
        assert!(!p.uses_rb);
    }

    #[test]
    fn geometry_and_immediates_pre_resolved() {
        let mut i = Instr::new(Opcode::Ldi);
        i.tc = ThreadCtrl::new(WidthSel::Quarter4, DepthSel::Half);
        i.imm = (-5i16) as u16;
        let p = compile_one(&i).unwrap();
        assert_eq!(p.lanes, 4);
        assert_eq!(p.depth, DepthSel::Half);
        assert_eq!(p.imm, (-5i32) as u32, "LDI immediate sign-extends");

        let mut j = Instr::new(Opcode::Jmp);
        j.imm = 0xFFF0;
        assert_eq!(compile_one(&j).unwrap().imm, 0xFFF0, "addresses zero-extend");
    }

    #[test]
    fn if_without_condition_fails() {
        let mut i = Instr::new(Opcode::If);
        i.imm = 6; // unallocated cc bits
        assert!(compile_one(&i).is_err());
        assert!(compile(&[Instr::nop(), i]).unwrap_err().pc == 1);
    }

    fn instr(op: Opcode) -> Instr {
        let mut i = Instr::new(op);
        if op == Opcode::If {
            i.imm = CondCode::Lt.bits() as u16;
        }
        i
    }

    #[test]
    fn superplans_split_at_control_and_branch_targets() {
        // 0:tdx 1:add 2:add 3:jmp→6 4:nop 5:nop 6:add 7:add 8:stop
        let mut jmp = instr(Opcode::Jmp);
        jmp.imm = 6;
        let instrs = [
            instr(Opcode::TdX),
            instr(Opcode::Add),
            instr(Opcode::Add),
            jmp,
            instr(Opcode::Nop),
            instr(Opcode::Nop),
            instr(Opcode::Add),
            instr(Opcode::Add),
            instr(Opcode::Stop),
        ];
        let plans = compile(&instrs).unwrap();
        let wave_tab = [1usize, 32, 16, 8];
        let shared = SharedMem::new(4096, crate::sim::MemoryMode::Dp);
        let sp = compile_superplans(&plans, &wave_tab, &shared);
        assert_eq!(sp.traces.len(), 3);
        assert_eq!(sp.ops.len(), 7);
        assert_eq!(sp.trace_index(0), Some(0));
        assert_eq!(sp.trace_index(1), None, "mid-trace pc has no leader entry");
        assert_eq!(sp.trace_index(3), None, "control op never leads a trace");
        assert_eq!(sp.trace_index(4), Some(1));
        assert_eq!(sp.trace_index(6), Some(2), "branch target starts its own trace");
        assert_eq!(sp.traces[0].len, 3);
        assert_eq!(sp.traces[1].len, 2);
        assert_eq!(sp.traces[2].len, 2);
        assert!((sp.mean_trace_len() - 7.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn superplan_offsets_are_prefix_sums_of_charges() {
        let instrs = [
            instr(Opcode::Nop),
            instr(Opcode::Add),
            instr(Opcode::Lod),
            instr(Opcode::Sto),
            instr(Opcode::Stop),
        ];
        let plans = compile(&instrs).unwrap();
        let wave_tab = [1usize, 32, 16, 8];
        let shared = SharedMem::new(4096, crate::sim::MemoryMode::Dp);
        let sp = compile_superplans(&plans, &wave_tab, &shared);
        assert_eq!(sp.traces.len(), 1);
        let tr = &sp.traces[0];
        assert_eq!(tr.len, 4);
        let ops = &sp.ops[tr.first_op..tr.first_op + tr.len];
        assert_eq!(ops[0].charge, 1, "NOP charges one cycle");
        let mut offset = 0;
        for o in ops {
            assert_eq!(o.offset, offset);
            assert!(o.charge >= 1);
            offset += o.charge;
        }
        assert_eq!(tr.total_cycles, offset);
        assert_eq!(tr.last_offset, ops[tr.len - 1].offset);
        // The profiler delta counts exactly the fused ops and their
        // charges.
        assert_eq!(tr.prof.total_instructions(), tr.len as u64);
        assert_eq!(tr.prof.total_cycles(), tr.total_cycles);
    }

    #[test]
    fn short_runs_are_not_fused() {
        // A lone fusable op between control ops stays per-instruction.
        let instrs = [instr(Opcode::Add), instr(Opcode::Stop), instr(Opcode::Nop)];
        let plans = compile(&instrs).unwrap();
        let shared = SharedMem::new(64, crate::sim::MemoryMode::Dp);
        let sp = compile_superplans(&plans, &[1, 2, 1, 1], &shared);
        assert_eq!(sp.traces.len(), 0);
        assert_eq!(sp.trace_index(0), None);
    }

    #[test]
    fn pred_and_control_kinds() {
        assert_eq!(compile_one(&Instr::new(Opcode::Else)).unwrap().kind, PlanKind::Else);
        assert_eq!(compile_one(&Instr::new(Opcode::Stop)).unwrap().kind, PlanKind::Stop);
        assert_eq!(
            compile_one(&Instr::new(Opcode::Sum)).unwrap().kind,
            PlanKind::Dot { sum_only: true }
        );
        let p = compile_one(&Instr::new(Opcode::Lod)).unwrap();
        assert_eq!(p.kind, PlanKind::Load);
        assert_eq!(p.slot as usize, Group::Memory.index());
    }

    #[test]
    fn superplan_cache_compiles_once_per_key() {
        let instrs = [
            instr(Opcode::TdX),
            instr(Opcode::Add),
            instr(Opcode::Add),
            instr(Opcode::Stop),
        ];
        let plans = compile(&instrs).unwrap();
        let wave_tab = [1usize, 32, 16, 8];
        let shared = SharedMem::new(4096, crate::sim::MemoryMode::Dp);
        let words: Arc<[u64]> = Arc::from(vec![1u64, 2, 3, 4]);
        let key = SuperplanKey {
            words: Arc::clone(&words),
            fingerprint: 0xF00D,
            threads: 128,
        };

        let cache = SuperplanCache::new();
        let a = cache.get(&key, &plans, &wave_tab, &shared);
        let b = cache.get(&key, &plans, &wave_tab, &shared);
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit");
        let s = cache.stats();
        assert_eq!((s.compiles, s.hits, s.entries), (1, 1, 1));

        // A different thread count is a different compilation (the wave
        // table changes), even for the same program and config.
        let key64 = SuperplanKey {
            words: Arc::clone(&words),
            fingerprint: 0xF00D,
            threads: 64,
        };
        let c = cache.get(&key64, &plans, &[1, 16, 8, 4], &shared);
        assert!(!Arc::ptr_eq(&a, &c));
        let s = cache.stats();
        assert_eq!((s.compiles, s.hits, s.entries), (2, 1, 2));

        // Key equality is by word content, not Arc identity.
        let rewrapped = SuperplanKey {
            words: Arc::from(vec![1u64, 2, 3, 4]),
            fingerprint: 0xF00D,
            threads: 128,
        };
        let d = cache.get(&rewrapped, &plans, &wave_tab, &shared);
        assert!(Arc::ptr_eq(&a, &d));
        assert_eq!(cache.stats().hits, 2);
    }
}
