//! Decode-time issue plans.
//!
//! The eGPU pipeline does no per-cycle re-interpretation: an instruction's
//! datapath routing, operand shape, thread-space geometry and port charges
//! are all fixed by its encoding. The simulator mirrors that discipline by
//! compiling every [`Instr`] into an [`IssuePlan`] once — at assembly (the
//! plans travel with [`crate::asm::Program`]) or at program load — so the
//! `Machine::run` hot loop is reduced to fetch-plan → execute-lanes →
//! charge, with `classify()`, `Opcode::operands()`, condition-code
//! decoding and group-slot lookups all hoisted out of the per-instruction
//! path.
//!
//! The only run-time-dependent quantity is the wavefront count selected by
//! the depth field (it depends on the runtime thread configuration,
//! §3.2), so the plan stores the [`DepthSel`] and the machine resolves it
//! through a 4-entry table rebuilt on `set_threads`.
//!
//! `Machine::run_reference` retains the original re-deriving interpreter;
//! `rust/tests/asm_sim_properties.rs` proves the two produce bit-identical
//! architectural state, cycle counts and hazard totals on randomized
//! programs.

use crate::datapath::{classify, DpOp};
use crate::isa::opcode::OperandShape;
use crate::isa::{CondCode, DepthSel, Instr, Opcode, TType};

/// What the execute stage does for one instruction, with every decode
/// decision already made.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PlanKind {
    Nop,
    /// Sequencer ops; the target/count is the plan's `imm`.
    Jmp,
    Jsr,
    Rts,
    Loop,
    Init,
    Stop,
    /// Per-thread generated values (LDI immediate / thread IDs).
    Ldi,
    TdX,
    TdY,
    /// Wavefront ALU op, pre-classified to its datapath op
    /// ([`DpOp::Fp`] or [`DpOp::Int`] only — DOT/SUM are [`PlanKind::Dot`]).
    Alu(DpOp),
    Load,
    Store,
    /// DOT (a·b) or SUM (Σa) extension core.
    Dot { sum_only: bool },
    /// Predicate push with the pre-decoded condition.
    If { cc: CondCode, ttype: TType },
    Else,
    EndIf,
}

/// A pre-resolved execution plan for one instruction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IssuePlan {
    pub kind: PlanKind,
    /// Wave-depth selector; resolved against the runtime thread count
    /// through the machine's wave table.
    pub depth: DepthSel,
    /// Lanes enabled by the width selector (1, 4 or 16).
    pub lanes: u8,
    /// Does this instruction read Rb? (operand shape, pre-resolved —
    /// drives the hazard-checker's read set.)
    pub uses_rb: bool,
    pub rd: u8,
    pub ra: u8,
    pub rb: u8,
    /// Pre-resolved immediate: sign-extended bits for LDI, zero-extended
    /// raw value otherwise (addresses, offsets, loop counts).
    pub imm: u32,
    /// Profiler slot of the opcode's group ([`crate::isa::Group::index`]).
    pub slot: u8,
}

/// Plan-compilation error, annotated with the instruction address.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanError {
    pub pc: usize,
    pub message: String,
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "pc {}: {}", self.pc, self.message)
    }
}

impl std::error::Error for PlanError {}

/// Compile one instruction. Fails only on encodings the assembler never
/// emits (an IF word whose condition-code bits are unallocated).
pub fn compile_one(i: &Instr) -> Result<IssuePlan, String> {
    use Opcode::*;
    let kind = match i.op {
        Nop => PlanKind::Nop,
        Jmp => PlanKind::Jmp,
        Jsr => PlanKind::Jsr,
        Rts => PlanKind::Rts,
        Loop => PlanKind::Loop,
        Init => PlanKind::Init,
        Stop => PlanKind::Stop,
        Ldi => PlanKind::Ldi,
        TdX => PlanKind::TdX,
        TdY => PlanKind::TdY,
        Lod => PlanKind::Load,
        Sto => PlanKind::Store,
        Dot => PlanKind::Dot { sum_only: false },
        Sum => PlanKind::Dot { sum_only: true },
        If => PlanKind::If {
            cc: i.cond().ok_or("IF without condition code")?,
            ttype: i.ttype,
        },
        Else => PlanKind::Else,
        EndIf => PlanKind::EndIf,
        _ => match classify(i) {
            Some(dp @ (DpOp::Fp(_) | DpOp::Int(_))) => PlanKind::Alu(dp),
            _ => return Err(format!("{} is not executable", i.op)),
        },
    };
    Ok(IssuePlan {
        kind,
        depth: i.tc.depth,
        lanes: i.tc.width.lanes() as u8,
        uses_rb: matches!(
            i.op.operands(),
            OperandShape::RdRaRb | OperandShape::RaRb
        ),
        rd: i.rd,
        ra: i.ra,
        rb: i.rb,
        imm: if i.op == Ldi { i.imm_i() as u32 } else { i.imm_u() },
        slot: i.op.group().index() as u8,
    })
}

/// Compile a whole program's plans, one per instruction.
pub fn compile(instrs: &[Instr]) -> Result<Vec<IssuePlan>, PlanError> {
    instrs
        .iter()
        .enumerate()
        .map(|(pc, i)| compile_one(i).map_err(|message| PlanError { pc, message }))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datapath::{FpOp, IntOp};
    use crate::isa::{Group, ThreadCtrl, WidthSel};

    #[test]
    fn every_opcode_compiles() {
        for bits in 0..Opcode::COUNT as u8 {
            let op = Opcode::from_bits(bits).unwrap();
            let mut i = Instr::new(op);
            if op == Opcode::If {
                i.imm = CondCode::Lt.bits() as u16;
            }
            let p = compile_one(&i).unwrap_or_else(|e| panic!("{op:?}: {e}"));
            assert_eq!(p.slot as usize, op.group().index(), "{op:?}");
        }
    }

    #[test]
    fn alu_classification_and_operand_shape() {
        let mut i = Instr::new(Opcode::FAdd);
        i.ttype = TType::Fp32;
        let p = compile_one(&i).unwrap();
        assert_eq!(p.kind, PlanKind::Alu(DpOp::Fp(FpOp::FAdd)));
        assert!(p.uses_rb);

        let mut s = Instr::new(Opcode::Shr);
        s.ttype = TType::Uint;
        let p = compile_one(&s).unwrap();
        assert_eq!(p.kind, PlanKind::Alu(DpOp::Int(IntOp::ShrL)));

        // Unary ops don't read Rb.
        let p = compile_one(&Instr::new(Opcode::Neg)).unwrap();
        assert!(!p.uses_rb);
        let p = compile_one(&Instr::new(Opcode::InvSqr)).unwrap();
        assert_eq!(p.kind, PlanKind::Alu(DpOp::Fp(FpOp::FInvSqrt)));
        assert!(!p.uses_rb);
    }

    #[test]
    fn geometry_and_immediates_pre_resolved() {
        let mut i = Instr::new(Opcode::Ldi);
        i.tc = ThreadCtrl::new(WidthSel::Quarter4, DepthSel::Half);
        i.imm = (-5i16) as u16;
        let p = compile_one(&i).unwrap();
        assert_eq!(p.lanes, 4);
        assert_eq!(p.depth, DepthSel::Half);
        assert_eq!(p.imm, (-5i32) as u32, "LDI immediate sign-extends");

        let mut j = Instr::new(Opcode::Jmp);
        j.imm = 0xFFF0;
        assert_eq!(compile_one(&j).unwrap().imm, 0xFFF0, "addresses zero-extend");
    }

    #[test]
    fn if_without_condition_fails() {
        let mut i = Instr::new(Opcode::If);
        i.imm = 6; // unallocated cc bits
        assert!(compile_one(&i).is_err());
        assert!(compile(&[Instr::nop(), i]).unwrap_err().pc == 1);
    }

    #[test]
    fn pred_and_control_kinds() {
        assert_eq!(compile_one(&Instr::new(Opcode::Else)).unwrap().kind, PlanKind::Else);
        assert_eq!(compile_one(&Instr::new(Opcode::Stop)).unwrap().kind, PlanKind::Stop);
        assert_eq!(
            compile_one(&Instr::new(Opcode::Sum)).unwrap().kind,
            PlanKind::Dot { sum_only: true }
        );
        let p = compile_one(&Instr::new(Opcode::Lod)).unwrap();
        assert_eq!(p.kind, PlanKind::Load);
        assert_eq!(p.slot as usize, Group::Memory.index());
    }
}
