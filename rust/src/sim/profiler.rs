//! Instruction-mix profiling (paper Figure 6: "proportion of instructions
//! executed by type").

use std::fmt;

use crate::isa::Group;

/// Dynamic execution profile of one run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Profile {
    counts: [u64; Group::ALL.len()],
    cycles: [u64; Group::ALL.len()],
}

impl Profile {
    pub fn new() -> Profile {
        Profile::default()
    }

    fn slot(group: Group) -> usize {
        group.index()
    }

    #[inline]
    pub fn record(&mut self, group: Group, cycles: u64) {
        self.record_slot(group.index(), cycles);
    }

    /// Charge a pre-resolved slot (see [`Group::index`]); the issue-plan
    /// hot loop carries the slot so no group lookup happens per
    /// instruction.
    #[inline]
    pub fn record_slot(&mut self, slot: usize, cycles: u64) {
        self.counts[slot] += 1;
        self.cycles[slot] += cycles;
    }

    pub fn count(&self, group: Group) -> u64 {
        self.counts[Self::slot(group)]
    }

    pub fn cycles(&self, group: Group) -> u64 {
        self.cycles[Self::slot(group)]
    }

    pub fn total_instructions(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn total_cycles(&self) -> u64 {
        self.cycles.iter().sum()
    }

    /// Proportion of executed instructions in this group (Figure 6 y-axis).
    pub fn fraction(&self, group: Group) -> f64 {
        let total = self.total_instructions();
        if total == 0 {
            0.0
        } else {
            self.count(group) as f64 / total as f64
        }
    }

    /// Proportion of cycles spent in this group.
    pub fn cycle_fraction(&self, group: Group) -> f64 {
        let total = self.total_cycles();
        if total == 0 {
            0.0
        } else {
            self.cycles(group) as f64 / total as f64
        }
    }

    /// Accumulate another profile into this one. Besides cross-run
    /// aggregation, this is how the superplan fast path charges a whole
    /// fused trace in one step: `compile_superplans` pre-merges each
    /// trace's per-group counts/cycles into `Superplan::prof`, and a
    /// completed trace merges that instead of calling [`record_slot`]
    /// per op. Addition is commutative and the per-op `record_slot`
    /// replay on a mid-trace stop charges the identical amounts, so the
    /// profile stays bit-identical across fused, per-instruction, and
    /// reference execution (`rust/tests/superplan_parity.rs`).
    ///
    /// [`record_slot`]: Profile::record_slot
    pub fn merge(&mut self, other: &Profile) {
        for i in 0..self.counts.len() {
            self.counts[i] += other.counts[i];
            self.cycles[i] += other.cycles[i];
        }
    }

    /// Figure 6-style stacked bar, one row per group with a share > 0.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let total = self.total_instructions().max(1);
        for g in Group::ALL {
            let n = self.count(g);
            if n == 0 {
                continue;
            }
            let frac = n as f64 / total as f64;
            let bar = "#".repeat((frac * 50.0).round() as usize);
            out.push_str(&format!(
                "  {:<12} {:>8} ({:5.1}%) {}\n",
                g.label(),
                n,
                frac * 100.0,
                bar
            ));
        }
        out
    }
}

impl fmt::Display for Profile {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_fractions() {
        let mut p = Profile::new();
        p.record(Group::FpAlu, 32);
        p.record(Group::FpAlu, 32);
        p.record(Group::Memory, 128);
        p.record(Group::Nop, 1);
        assert_eq!(p.total_instructions(), 4);
        assert_eq!(p.total_cycles(), 193);
        assert_eq!(p.count(Group::FpAlu), 2);
        assert!((p.fraction(Group::FpAlu) - 0.5).abs() < 1e-12);
        assert!((p.cycle_fraction(Group::Memory) - 128.0 / 193.0).abs() < 1e-12);
    }

    #[test]
    fn merge_adds() {
        let mut a = Profile::new();
        a.record(Group::Control, 1);
        let mut b = Profile::new();
        b.record(Group::Control, 2);
        b.record(Group::Thread, 4);
        a.merge(&b);
        assert_eq!(a.count(Group::Control), 2);
        assert_eq!(a.cycles(Group::Control), 3);
        assert_eq!(a.count(Group::Thread), 1);
    }

    #[test]
    fn render_includes_nonzero_groups_only() {
        let mut p = Profile::new();
        p.record(Group::Memory, 10);
        let r = p.render();
        assert!(r.contains("Memory"));
        assert!(!r.contains("FP"));
    }
}
