//! Static scalability: the eGPU configuration space (paper §3, §5).
//!
//! Everything the paper lists as a configuration-time parameter is a field
//! here: thread space, registers per thread, shared-memory size and port
//! organization (DP/QP), integer-ALU precision and feature class, shift
//! precision, predicate support and nesting depth, and the optional
//! extension cores. The Table 4/5 instances are provided as presets.

use std::fmt;

use crate::isa::{Group, Instr, Opcode, WordLayout, WAVEFRONT_WIDTH};

/// Shared-memory organization (§3, §5.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MemoryMode {
    /// Simple dual-port M20Ks: 4 read ports + 1 write port, 1 GHz block
    /// speed — the core closes at the 771 MHz DSP limit.
    #[default]
    Dp,
    /// Emulated quad-port M20Ks: 4 read + 2 write ports, 600 MHz block
    /// speed — doubles write bandwidth, halves M20K count, caps Fmax.
    Qp,
}

impl MemoryMode {
    pub fn write_ports(self) -> usize {
        match self {
            MemoryMode::Dp => 1,
            MemoryMode::Qp => 2,
        }
    }

    /// Shared-memory read ports (4 in both organizations).
    pub fn read_ports(self) -> usize {
        4
    }

    /// Issue charge for a LOD over `selected` lanes. The single
    /// authoritative formula: `SharedMem::load_cycles` (the machine's
    /// charge) and the kernel compiler's cost model both call this.
    pub fn load_cycles(self, selected: usize) -> u64 {
        (selected as u64).div_ceil(self.read_ports() as u64).max(1)
    }

    /// Issue charge for a STO over `selected` lanes (1 DP / 2 QP write
    /// ports); shared by the machine and the kernel compiler like
    /// [`MemoryMode::load_cycles`].
    pub fn store_cycles(self, selected: usize) -> u64 {
        (selected as u64).div_ceil(self.write_ports() as u64).max(1)
    }

    pub fn name(self) -> &'static str {
        match self {
            MemoryMode::Dp => "DP",
            MemoryMode::Qp => "QP",
        }
    }
}

/// Integer-ALU feature class (Table 6 rows). Ordered by capability:
/// `Min < Small < Full`, so a requirement can be compared directly
/// against a configuration's class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum IntAluClass {
    /// Adder/subtractor + AND/OR/XOR only (+ single-bit shift).
    Min,
    /// + full logic set and full shifts.
    Small,
    /// + popcount, max/min, unsigned variants.
    #[default]
    Full,
}

impl IntAluClass {
    pub fn name(self) -> &'static str {
        match self {
            IntAluClass::Min => "Min",
            IntAluClass::Small => "Small",
            IntAluClass::Full => "Full",
        }
    }

    /// Is this integer opcode implemented by this ALU class?
    pub fn supports(self, op: Opcode) -> bool {
        use Opcode::*;
        match self {
            IntAluClass::Min => matches!(op, Add | Sub | And | Or | Xor | Shl | Shr),
            IntAluClass::Small => matches!(
                op,
                Add | Sub | Neg | Abs | And | Or | Xor | Not | CNot | Bvs | Shl | Shr
            ),
            IntAluClass::Full => true,
        }
    }
}

/// A complete static configuration of one eGPU core.
#[derive(Debug, Clone, PartialEq)]
pub struct EgpuConfig {
    /// Human label ("Small-DP-1" etc. for the Table 4/5 presets).
    pub name: String,
    /// Maximum initialized threads (multiple of 16).
    pub threads: usize,
    /// Registers per thread: 16, 32 or 64.
    pub regs_per_thread: usize,
    /// Shared-memory size in KB (32-bit word addressed).
    pub shared_kb: usize,
    /// DP or QP memory organization.
    pub memory: MemoryMode,
    /// Integer-ALU precision: 16 or 32 bits.
    pub alu_precision: u8,
    /// Shift precision: 1 (single-bit shifts only), 16 or 32.
    pub shift_precision: u8,
    /// Integer-ALU feature class.
    pub int_alu: IntAluClass,
    /// Predicate nesting levels (0 = predicates not synthesized).
    pub predicate_levels: usize,
    /// Optional dot-product extension core.
    pub dot_core: bool,
    /// Optional SFU (reciprocal square root).
    pub sfu: bool,
}

impl Default for EgpuConfig {
    /// The paper's base configuration: 1 SM × 16 SPs, 512 threads.
    fn default() -> Self {
        EgpuConfig {
            name: "base".into(),
            threads: 512,
            regs_per_thread: 32,
            shared_kb: 32,
            memory: MemoryMode::Dp,
            alu_precision: 32,
            shift_precision: 16,
            int_alu: IntAluClass::Full,
            predicate_levels: 5,
            dot_core: false,
            sfu: false,
        }
    }
}

/// Configuration validation error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConfigError(pub String);

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "invalid eGPU configuration: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

/// What a program *needs* from a configuration — the static-scalability
/// axes of §3/§5 read in the requirement direction. A fleet dispatcher
/// derives one of these per job ([`FeatureSet::required_by`] over the
/// job's instruction stream, plus capacity floors from its data
/// movement) and only places the job on cores whose [`EgpuConfig`]
/// [`satisfies`](EgpuConfig::satisfies) it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FeatureSet {
    /// Deepest IF nesting in the program (0 = no predicates used).
    pub predicate_depth: usize,
    /// Uses DOT/SUM (the dot-product extension core).
    pub dot_core: bool,
    /// Uses INVSQR (the SFU extension core).
    pub sfu: bool,
    /// Weakest integer-ALU class implementing every integer op used.
    pub int_alu: IntAluClass,
    /// Contains SHL/SHR. Shift amounts live in registers, so a program
    /// with any shift is conservatively routed away from
    /// `shift_precision == 1` cores (the load-time check cannot reject
    /// them, but a runtime amount > 1 would be wrong there).
    pub multi_bit_shift: bool,
    /// Integer-ALU width the program needs (0 = no integer ops, 16 =
    /// plain add/logic only, 32 = ops that inherently produce or move
    /// high bits: multiplies, shifts, bit-reversal). A 16-bit-precision
    /// core masks every integer lane result, so routing such programs
    /// there would silently corrupt results — the same conservatism as
    /// `multi_bit_shift` (plain 16-bit arithmetic is assumed
    /// width-compatible, matching the permissive load-time check).
    pub int_width: u8,
    /// Runtime-initialized threads the job launches with.
    pub min_threads: usize,
    /// Highest architectural register named, plus one.
    pub min_regs: usize,
    /// Highest shared-memory word touched by the job's DMA, plus one
    /// (a floor only: the kernel's own addressing is data-dependent).
    pub min_shared_words: usize,
}

impl Default for FeatureSet {
    /// The empty requirement — note `int_alu` defaults to `Min` (nothing
    /// required), not the configuration-side default of `Full`.
    fn default() -> FeatureSet {
        FeatureSet {
            predicate_depth: 0,
            dot_core: false,
            sfu: false,
            int_alu: IntAluClass::Min,
            multi_bit_shift: false,
            int_width: 0,
            min_threads: 0,
            min_regs: 0,
            min_shared_words: 0,
        }
    }
}

impl FeatureSet {
    /// The empty requirement (placeable on any valid configuration).
    pub fn none() -> FeatureSet {
        FeatureSet::default()
    }

    /// Extract the requirement of an instruction stream: predicates
    /// (with nesting depth), extension cores, the weakest sufficient
    /// integer-ALU class, shifts, and register usage. Capacity floors
    /// (`min_threads`, `min_shared_words`) are the caller's to fill —
    /// they come from the launch, not the program text.
    pub fn required_by<'a>(instrs: impl IntoIterator<Item = &'a Instr>) -> FeatureSet {
        let mut req = FeatureSet::none();
        let mut depth = 0usize;
        for i in instrs {
            req.min_regs = req.min_regs.max(i.rd.max(i.ra).max(i.rb) as usize + 1);
            match i.op.group() {
                Group::Conditional => match i.op {
                    Opcode::If => {
                        depth += 1;
                        req.predicate_depth = req.predicate_depth.max(depth);
                    }
                    Opcode::EndIf => depth = depth.saturating_sub(1),
                    _ => {}
                },
                Group::Extension => match i.op {
                    Opcode::Dot | Opcode::Sum => req.dot_core = true,
                    Opcode::InvSqr => req.sfu = true,
                    _ => {}
                },
                Group::IntShift => {
                    req.multi_bit_shift = true;
                    req.int_width = 32;
                    req.int_alu = req.int_alu.max(weakest_class_for(i.op));
                }
                Group::IntMul => {
                    req.int_width = 32;
                    req.int_alu = req.int_alu.max(weakest_class_for(i.op));
                }
                Group::IntArith | Group::IntLogic | Group::IntOther => {
                    req.int_width = req.int_width.max(match i.op {
                        // Bit-reversal slides bits across the full word.
                        Opcode::Bvs => 32,
                        _ => 16,
                    });
                    req.int_alu = req.int_alu.max(weakest_class_for(i.op));
                }
                _ => {}
            }
        }
        req
    }

    /// True when nothing beyond a base configuration is needed.
    pub fn is_none(&self) -> bool {
        *self == FeatureSet::none()
    }
}

/// Weakest [`IntAluClass`] implementing `op` (callers pass integer ops
/// only; anything else answers `Min`, which never constrains).
fn weakest_class_for(op: Opcode) -> IntAluClass {
    for class in [IntAluClass::Min, IntAluClass::Small] {
        if class.supports(op) {
            return class;
        }
    }
    IntAluClass::Full
}

impl fmt::Display for FeatureSet {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut parts: Vec<String> = Vec::new();
        if self.predicate_depth > 0 {
            parts.push(format!("pred>={}", self.predicate_depth));
        }
        if self.dot_core {
            parts.push("dot".into());
        }
        if self.sfu {
            parts.push("sfu".into());
        }
        if self.int_alu > IntAluClass::Min {
            parts.push(format!("alu>={}", self.int_alu.name()));
        }
        if self.multi_bit_shift {
            parts.push("shift>1".into());
        }
        if self.int_width > 16 {
            parts.push(format!("int{}b", self.int_width));
        }
        if self.min_threads > 0 {
            parts.push(format!("threads>={}", self.min_threads));
        }
        if self.min_regs > 0 {
            parts.push(format!("regs>={}", self.min_regs));
        }
        if self.min_shared_words > 0 {
            parts.push(format!("shared>={}w", self.min_shared_words));
        }
        if parts.is_empty() {
            write!(f, "none")
        } else {
            write!(f, "{}", parts.join(", "))
        }
    }
}

impl EgpuConfig {
    pub fn validate(&self) -> Result<(), ConfigError> {
        let e = |m: String| Err(ConfigError(m));
        if self.threads == 0 || self.threads % WAVEFRONT_WIDTH != 0 {
            return e(format!(
                "threads ({}) must be a positive multiple of {WAVEFRONT_WIDTH}",
                self.threads
            ));
        }
        if !matches!(self.regs_per_thread, 16 | 32 | 64) {
            return e(format!(
                "regs_per_thread ({}) must be 16, 32 or 64",
                self.regs_per_thread
            ));
        }
        if self.shared_kb < 2 || self.shared_kb > 512 {
            return e(format!("shared_kb ({}) out of range [2,512]", self.shared_kb));
        }
        if !matches!(self.alu_precision, 16 | 32) {
            return e(format!("alu_precision ({}) must be 16 or 32", self.alu_precision));
        }
        if !matches!(self.shift_precision, 1 | 16 | 32) {
            return e(format!(
                "shift_precision ({}) must be 1, 16 or 32",
                self.shift_precision
            ));
        }
        if self.shift_precision > self.alu_precision {
            return e(format!(
                "shift_precision ({}) exceeds alu_precision ({})",
                self.shift_precision, self.alu_precision
            ));
        }
        if self.predicate_levels > 32 {
            return e(format!(
                "predicate_levels ({}) exceeds the 32-level stack limit",
                self.predicate_levels
            ));
        }
        Ok(())
    }

    /// Initialized wavefronts: threads / 16 (§3.1).
    pub fn wavefronts(&self) -> usize {
        self.threads / WAVEFRONT_WIDTH
    }

    /// Shared memory size in 32-bit words.
    pub fn shared_words(&self) -> usize {
        self.shared_kb * 1024 / 4
    }

    /// Instruction-word layout for this register space.
    pub fn word_layout(&self) -> WordLayout {
        WordLayout::for_regs(self.regs_per_thread)
    }

    /// Core clock in MHz: always the slowest embedded resource (§6) —
    /// 771 MHz (DSP-limited) for DP, 600 MHz (QP M20K) for QP.
    pub fn core_mhz(&self) -> f64 {
        match self.memory {
            MemoryMode::Dp => 771.0,
            MemoryMode::Qp => 600.0,
        }
    }

    /// Is this instruction legal on this configuration? (The assembler is
    /// configuration-independent; legality is checked at program load.)
    pub fn supports(&self, op: Opcode, shift_amount: Option<u32>) -> Result<(), ConfigError> {
        let group = op.group();
        match group {
            Group::Conditional if self.predicate_levels == 0 => Err(ConfigError(format!(
                "{op} requires predicates, which this configuration omits"
            ))),
            Group::Extension => match op {
                Opcode::Dot | Opcode::Sum if !self.dot_core => Err(ConfigError(format!(
                    "{op} requires the dot-product extension core"
                ))),
                Opcode::InvSqr if !self.sfu => Err(ConfigError(
                    "invsqr requires the SFU extension core".into(),
                )),
                _ => Ok(()),
            },
            Group::IntArith | Group::IntLogic | Group::IntOther | Group::IntMul
                if !self.int_alu.supports(op) =>
            {
                Err(ConfigError(format!(
                    "{op} is not implemented by the {} integer ALU",
                    self.int_alu.name()
                )))
            }
            Group::IntShift => {
                if !self.int_alu.supports(op) {
                    return Err(ConfigError(format!(
                        "{op} is not implemented by the {} integer ALU",
                        self.int_alu.name()
                    )));
                }
                if self.shift_precision == 1 {
                    match shift_amount {
                        Some(1) => Ok(()),
                        Some(n) => Err(ConfigError(format!(
                            "shift by {n} needs multi-bit shifter (shift_precision=1)"
                        ))),
                        // Register-amount shifts can't be statically checked.
                        None => Ok(()),
                    }
                } else {
                    Ok(())
                }
            }
            _ => Ok(()),
        }
    }

    /// Kernel-specialization fingerprint: FNV-1a over the axes the
    /// kernel compiler actually consumes — the memory organization
    /// (`kc`'s cost model charges LOD/STO per-port, so DP and QP
    /// produce different schedules) and the register-file size (the
    /// instruction-word layout and the allocator's budget). Two
    /// configurations with equal fingerprints run byte-identical
    /// compiled kernels, which is what lets the kernel-specialization
    /// cache (`crate::kernels::KernelCache`) share one compile across
    /// a whole homogeneous fleet.
    pub fn fingerprint(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        let mem = match self.memory {
            MemoryMode::Dp => 1u8,
            MemoryMode::Qp => 2u8,
        };
        for b in std::iter::once(mem).chain((self.regs_per_thread as u32).to_le_bytes()) {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        h
    }

    /// Can this configuration run a job with requirement `req`?
    pub fn satisfies(&self, req: &FeatureSet) -> bool {
        self.unsatisfied(req).is_none()
    }

    /// First reason this configuration cannot run a job with
    /// requirement `req`, or `None` when it can. The phrasing matches
    /// [`EgpuConfig::supports`]'s load-time errors where both exist.
    pub fn unsatisfied(&self, req: &FeatureSet) -> Option<String> {
        if req.predicate_depth > self.predicate_levels {
            return Some(format!(
                "requires {} predicate level(s); configuration has {}",
                req.predicate_depth, self.predicate_levels
            ));
        }
        if req.dot_core && !self.dot_core {
            return Some("requires the dot-product extension core".into());
        }
        if req.sfu && !self.sfu {
            return Some("requires the SFU extension core".into());
        }
        if req.int_alu > self.int_alu {
            return Some(format!(
                "requires the {} integer ALU; configuration has {}",
                req.int_alu.name(),
                self.int_alu.name()
            ));
        }
        if req.multi_bit_shift && self.shift_precision == 1 {
            return Some(
                "shifts need a multi-bit shifter (shift_precision=1)".into(),
            );
        }
        if req.int_width > self.alu_precision {
            return Some(format!(
                "needs a {}-bit integer ALU; configuration has {} bits",
                req.int_width, self.alu_precision
            ));
        }
        if req.min_threads > self.threads {
            return Some(format!(
                "needs {} threads; configuration has {}",
                req.min_threads, self.threads
            ));
        }
        if req.min_regs > self.regs_per_thread {
            return Some(format!(
                "names register r{}; configuration has {} registers/thread",
                req.min_regs - 1,
                self.regs_per_thread
            ));
        }
        if req.min_shared_words > self.shared_words() {
            return Some(format!(
                "touches shared word {}; configuration has {} words",
                req.min_shared_words - 1,
                self.shared_words()
            ));
        }
        None
    }

    // ---------------------------------------------------------------
    // Presets: the exact instances of Tables 4 and 5.
    // ---------------------------------------------------------------

    fn preset(
        name: &str,
        alu: u8,
        shift: u8,
        threads: usize,
        regs: usize,
        shared_kb: usize,
        pred: usize,
        memory: MemoryMode,
    ) -> EgpuConfig {
        EgpuConfig {
            name: name.into(),
            threads,
            regs_per_thread: regs,
            shared_kb,
            memory,
            alu_precision: alu,
            shift_precision: shift,
            int_alu: if shift == 1 {
                IntAluClass::Min
            } else {
                IntAluClass::Full
            },
            predicate_levels: pred,
            dot_core: false,
            sfu: false,
        }
    }

    /// Table 4 (DP memory) rows, in order.
    pub fn table4_presets() -> Vec<EgpuConfig> {
        use MemoryMode::Dp;
        vec![
            Self::preset("Small-DP-1", 16, 1, 512, 16, 8, 0, Dp),
            Self::preset("Small-DP-2", 16, 16, 512, 16, 32, 5, Dp),
            Self::preset("Medium-DP-1", 16, 16, 512, 32, 32, 5, Dp),
            Self::preset("Medium-DP-2", 32, 16, 512, 32, 32, 5, Dp),
            Self::preset("Large-DP-1", 32, 16, 512, 64, 32, 8, Dp),
            Self::preset("Large-DP-2", 32, 32, 512, 64, 64, 16, Dp),
        ]
    }

    /// Table 5 (QP memory) rows, in order.
    pub fn table5_presets() -> Vec<EgpuConfig> {
        use MemoryMode::Qp;
        vec![
            Self::preset("Small-QP-1", 32, 1, 512, 64, 32, 0, Qp),
            Self::preset("Medium-QP-1", 32, 32, 1024, 32, 64, 0, Qp),
            Self::preset("Large-QP-1", 32, 32, 1024, 32, 64, 16, Qp),
            Self::preset("Large-QP-2", 32, 32, 1024, 32, 128, 10, Qp),
        ]
    }

    /// The §7 benchmark configuration with predicates, used by the
    /// bitonic-sort benchmark ("Predicates are required, which increases
    /// the effective cost of the eGPU core by about 50%").
    pub fn benchmark_predicated(memory: MemoryMode) -> EgpuConfig {
        let mut c = Self::benchmark(memory, false);
        c.predicate_levels = 8;
        c.name += "-Pred";
        c
    }

    /// The §7 benchmark configuration: 512 threads, 32 regs/thread,
    /// 32-bit ALU, 128 KB shared memory, no predicates (the vector/matrix
    /// and FFT kernels use only loop constructs).
    pub fn benchmark(memory: MemoryMode, dot_core: bool) -> EgpuConfig {
        EgpuConfig {
            name: match (memory, dot_core) {
                (MemoryMode::Dp, false) => "eGPU-DP".into(),
                (MemoryMode::Qp, false) => "eGPU-QP".into(),
                (MemoryMode::Dp, true) => "eGPU-Dot".into(),
                (MemoryMode::Qp, true) => "eGPU-QP-Dot".into(),
            },
            threads: 512,
            regs_per_thread: 32,
            shared_kb: 128,
            memory,
            alu_precision: 32,
            shift_precision: 32,
            int_alu: IntAluClass::Full,
            predicate_levels: 0,
            dot_core,
            sfu: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for c in EgpuConfig::table4_presets()
            .into_iter()
            .chain(EgpuConfig::table5_presets())
        {
            c.validate().unwrap_or_else(|e| panic!("{}: {e}", c.name));
        }
        EgpuConfig::benchmark(MemoryMode::Dp, true).validate().unwrap();
    }

    #[test]
    fn derived_quantities() {
        let c = EgpuConfig::default();
        assert_eq!(c.wavefronts(), 32);
        assert_eq!(c.shared_words(), 8192);
        assert_eq!(c.word_layout().word_bits(), 43);
        assert_eq!(c.core_mhz(), 771.0);
        let q = EgpuConfig::benchmark(MemoryMode::Qp, false);
        assert_eq!(q.core_mhz(), 600.0);
        assert_eq!(q.shared_words(), 32768);
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut c = EgpuConfig::default();
        c.threads = 100;
        assert!(c.validate().is_err());
        let mut c = EgpuConfig::default();
        c.regs_per_thread = 48;
        assert!(c.validate().is_err());
        let mut c = EgpuConfig::default();
        c.shift_precision = 32;
        c.alu_precision = 16;
        assert!(c.validate().is_err());
        let mut c = EgpuConfig::default();
        c.predicate_levels = 64;
        assert!(c.validate().is_err());
    }

    #[test]
    fn feature_gating() {
        let mut c = EgpuConfig::default();
        c.predicate_levels = 0;
        assert!(c.supports(Opcode::If, None).is_err());
        assert!(c.supports(Opcode::Add, None).is_ok());
        assert!(c.supports(Opcode::Dot, None).is_err()); // no dot core
        c.dot_core = true;
        assert!(c.supports(Opcode::Dot, None).is_ok());
        assert!(c.supports(Opcode::InvSqr, None).is_err());
        c.sfu = true;
        assert!(c.supports(Opcode::InvSqr, None).is_ok());
    }

    #[test]
    fn min_alu_feature_gating() {
        let mut c = EgpuConfig::default();
        c.int_alu = IntAluClass::Min;
        c.shift_precision = 1;
        assert!(c.supports(Opcode::Pop, None).is_err());
        assert!(c.supports(Opcode::Max, None).is_err());
        assert!(c.supports(Opcode::Add, None).is_ok());
        assert!(c.supports(Opcode::Shl, Some(1)).is_ok());
        assert!(c.supports(Opcode::Shl, Some(4)).is_err());
    }

    #[test]
    fn fingerprint_tracks_compile_relevant_axes_only() {
        let base = EgpuConfig::default();
        let mut same = base.clone();
        same.name = "renamed".into();
        same.shared_kb = 256;
        same.predicate_levels = 0;
        same.dot_core = true;
        assert_eq!(base.fingerprint(), same.fingerprint());
        let mut qp = base.clone();
        qp.memory = MemoryMode::Qp;
        assert_ne!(base.fingerprint(), qp.fingerprint());
        let mut wide = base.clone();
        wide.regs_per_thread = 64;
        assert_ne!(base.fingerprint(), wide.fingerprint());
    }

    #[test]
    fn feature_set_extraction_and_satisfaction() {
        use crate::isa::Instr;
        let mut ifi = Instr::new(Opcode::If);
        ifi.ra = 3;
        let mut sum = Instr::new(Opcode::Sum);
        sum.rd = 9;
        let stream = [
            ifi,
            Instr::new(Opcode::Pop),
            ifi,
            Instr::new(Opcode::EndIf),
            Instr::new(Opcode::EndIf),
            sum,
            Instr::new(Opcode::Shl),
            Instr::new(Opcode::Stop),
        ];
        let req = FeatureSet::required_by(stream.iter());
        assert_eq!(req.predicate_depth, 2);
        assert!(req.dot_core && !req.sfu);
        assert_eq!(req.int_alu, IntAluClass::Full); // POP
        assert!(req.multi_bit_shift);
        assert_eq!(req.int_width, 32); // SHL
        assert_eq!(req.min_regs, 10);

        // A plain-add program is width-compatible with a 16-bit ALU;
        // bit-reversal is not.
        let plain = FeatureSet::required_by([Instr::new(Opcode::Add)].iter());
        assert_eq!(plain.int_width, 16);
        let mut narrow = EgpuConfig::default();
        narrow.alu_precision = 16;
        narrow.shift_precision = 16;
        assert!(narrow.satisfies(&plain));
        let bvs = FeatureSet::required_by([Instr::new(Opcode::Bvs)].iter());
        assert_eq!(bvs.int_width, 32);
        assert!(narrow.unsatisfied(&bvs).unwrap().contains("16 bits"));

        let mut cfg = EgpuConfig::default();
        assert!(!cfg.satisfies(&req)); // no dot core
        assert!(cfg
            .unsatisfied(&req)
            .unwrap()
            .contains("dot-product"));
        cfg.dot_core = true;
        assert!(cfg.satisfies(&req));
        cfg.predicate_levels = 1;
        assert!(!cfg.satisfies(&req));
    }

    #[test]
    fn feature_set_capacity_floors() {
        let mut req = FeatureSet::none();
        assert!(req.is_none());
        req.min_threads = 1024;
        let cfg = EgpuConfig::default(); // 512 threads
        assert!(cfg.unsatisfied(&req).unwrap().contains("threads"));
        req.min_threads = 0;
        req.min_shared_words = cfg.shared_words() + 1;
        assert!(cfg.unsatisfied(&req).unwrap().contains("shared"));
        assert_eq!(format!("{}", FeatureSet::none()), "none");
    }

    #[test]
    fn wavefront_counts_match_paper_examples() {
        // §3.2: "512 threads with 16 SPs, there will be 32 wavefronts".
        assert_eq!(EgpuConfig::default().wavefronts(), 32);
        // Table 5 medium: 1024 threads → 64 wavefronts.
        assert_eq!(EgpuConfig::table5_presets()[1].wavefronts(), 64);
    }
}
