//! The cycle-accurate eGPU simulator (paper §3, §4).
//!
//! Structure mirrors the hardware: [`machine::Machine`] is the SM
//! (sequencer + 16 SPs); [`regfile`], [`shared_mem`] and [`predicate`] are
//! the M20K-backed state; the datapath proper lives in [`crate::datapath`]
//! so it can be swapped between native rust and the AOT-compiled XLA
//! artifacts.

pub mod config;
pub mod config_json;
pub mod hazard;
pub mod machine;
pub mod plan;
pub mod predicate;
pub mod profiler;
pub mod regfile;
pub mod sequencer;
pub mod shared_mem;

pub use config::{EgpuConfig, FeatureSet, IntAluClass, MemoryMode};
pub use machine::{Machine, RunStats, SimError, SuperplanActivity, TraceStats, PIPELINE_DEPTH};
pub use plan::{
    IssuePlan, PlanKind, Superplan, SuperplanCache, SuperplanCacheStats, SuperplanKey,
    SuperplanProgram, TraceOp,
};
pub use profiler::Profile;
