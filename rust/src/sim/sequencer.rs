//! The eGPU sequencer: PC, subroutine stack, hardware loop counters, STOP
//! flag (paper §3.2: "loop constructs, which are supported in the eGPU
//! sequencer"; Table 2 Control group).

/// Subroutine-stack depth (JSR nesting). Bitonic sort uses "many
/// subroutine calls" (§7); 16 levels is generous for the benchmark set.
pub const CALL_STACK_DEPTH: usize = 16;

/// Hardware loop-counter stack depth (nested INIT/LOOP).
pub const LOOP_STACK_DEPTH: usize = 8;

#[derive(Debug, Clone)]
pub struct Sequencer {
    pub pc: usize,
    call_stack: Vec<usize>,
    loop_stack: Vec<u32>,
    pub stopped: bool,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SeqError {
    CallStackOverflow,
    ReturnWithoutCall,
    LoopWithoutInit,
    LoopStackOverflow,
}

impl std::fmt::Display for SeqError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SeqError::CallStackOverflow => write!(f, "JSR nesting exceeds {CALL_STACK_DEPTH}"),
            SeqError::ReturnWithoutCall => write!(f, "RTS with empty call stack"),
            SeqError::LoopWithoutInit => write!(f, "LOOP with no active loop counter"),
            SeqError::LoopStackOverflow => write!(f, "INIT nesting exceeds {LOOP_STACK_DEPTH}"),
        }
    }
}

impl std::error::Error for SeqError {}

impl Default for Sequencer {
    fn default() -> Self {
        Self::new()
    }
}

impl Sequencer {
    pub fn new() -> Sequencer {
        Sequencer {
            pc: 0,
            call_stack: Vec::with_capacity(CALL_STACK_DEPTH),
            loop_stack: Vec::with_capacity(LOOP_STACK_DEPTH),
            stopped: false,
        }
    }

    pub fn reset(&mut self) {
        self.pc = 0;
        self.call_stack.clear();
        self.loop_stack.clear();
        self.stopped = false;
    }

    /// Advance to the next sequential instruction.
    pub fn step(&mut self) {
        self.pc += 1;
    }

    pub fn jmp(&mut self, addr: usize) {
        self.pc = addr;
    }

    pub fn jsr(&mut self, addr: usize) -> Result<(), SeqError> {
        if self.call_stack.len() >= CALL_STACK_DEPTH {
            return Err(SeqError::CallStackOverflow);
        }
        self.call_stack.push(self.pc + 1);
        self.pc = addr;
        Ok(())
    }

    pub fn rts(&mut self) -> Result<(), SeqError> {
        match self.call_stack.pop() {
            Some(ret) => {
                self.pc = ret;
                Ok(())
            }
            None => Err(SeqError::ReturnWithoutCall),
        }
    }

    /// INIT: push a loop counter (the number of LOOP-taken iterations).
    pub fn init(&mut self, count: u32) -> Result<(), SeqError> {
        if self.loop_stack.len() >= LOOP_STACK_DEPTH {
            return Err(SeqError::LoopStackOverflow);
        }
        self.loop_stack.push(count);
        Ok(())
    }

    /// LOOP: decrement the innermost counter; jump back while non-zero,
    /// pop and fall through at zero.
    pub fn loop_dec(&mut self, addr: usize) -> Result<(), SeqError> {
        match self.loop_stack.last_mut() {
            Some(c) => {
                if *c > 0 {
                    *c -= 1;
                }
                if *c > 0 {
                    self.pc = addr;
                } else {
                    self.loop_stack.pop();
                    self.pc += 1;
                }
                Ok(())
            }
            None => Err(SeqError::LoopWithoutInit),
        }
    }

    pub fn stop(&mut self) {
        self.stopped = true;
    }

    pub fn call_depth(&self) -> usize {
        self.call_stack.len()
    }

    pub fn loop_depth(&self) -> usize {
        self.loop_stack.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loop_runs_exact_iterations() {
        // INIT #4; body at 1; LOOP 1 → body runs 4 times.
        let mut s = Sequencer::new();
        s.init(4).unwrap();
        s.pc = 1;
        let mut body_runs = 0;
        loop {
            body_runs += 1; // "execute" body at pc 1
            s.pc = 2; // arrive at the LOOP instruction
            s.loop_dec(1).unwrap();
            if s.pc != 1 {
                break;
            }
        }
        assert_eq!(body_runs, 4);
        assert_eq!(s.pc, 3);
        assert_eq!(s.loop_depth(), 0);
    }

    #[test]
    fn nested_loops() {
        let mut s = Sequencer::new();
        s.init(3).unwrap();
        s.init(2).unwrap();
        assert_eq!(s.loop_depth(), 2);
        // Inner loop consumes its counter first.
        s.pc = 5;
        s.loop_dec(4).unwrap(); // 2→1, taken
        assert_eq!(s.pc, 4);
        s.pc = 5;
        s.loop_dec(4).unwrap(); // 1→0, fall through + pop
        assert_eq!(s.pc, 6);
        assert_eq!(s.loop_depth(), 1);
    }

    #[test]
    fn jsr_rts_roundtrip() {
        let mut s = Sequencer::new();
        s.pc = 10;
        s.jsr(100).unwrap();
        assert_eq!(s.pc, 100);
        s.jsr(200).unwrap();
        assert_eq!(s.call_depth(), 2);
        s.rts().unwrap();
        assert_eq!(s.pc, 101);
        s.rts().unwrap();
        assert_eq!(s.pc, 11);
        assert_eq!(s.rts(), Err(SeqError::ReturnWithoutCall));
    }

    #[test]
    fn call_stack_overflow() {
        let mut s = Sequencer::new();
        for _ in 0..CALL_STACK_DEPTH {
            s.jsr(0).unwrap();
        }
        assert_eq!(s.jsr(0), Err(SeqError::CallStackOverflow));
    }

    #[test]
    fn loop_without_init_errors() {
        let mut s = Sequencer::new();
        assert_eq!(s.loop_dec(0), Err(SeqError::LoopWithoutInit));
    }

    #[test]
    fn init_zero_falls_through_immediately() {
        let mut s = Sequencer::new();
        s.init(0).unwrap();
        s.pc = 3;
        s.loop_dec(1).unwrap();
        assert_eq!(s.pc, 4);
        assert_eq!(s.loop_depth(), 0);
    }
}
