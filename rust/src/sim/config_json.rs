//! Configs as data: JSON (de)serialization for [`EgpuConfig`].
//!
//! The deployment story of the paper (and of "Soft GPGPU versus IP
//! cores", arXiv 2406.03227) is many *differently configured* eGPU
//! instances on one fabric — which means configurations must be
//! shippable artifacts, not Rust code. `egpu run --config path.json`
//! and `egpu fleet --configs a.json,b.json` consume this format.
//!
//! The codec is hand-rolled: `serde` is not available in the offline
//! build environment (see DESIGN.md §Substitutions — same story as the
//! xla-rs stub), so this module carries a ~100-line recursive-descent
//! JSON parser instead of a derive. The shape is exactly what
//! `#[derive(Serialize, Deserialize)]` on [`EgpuConfig`] would accept:
//! one object per config, field names matching the struct, enums as
//! their `name()` strings ("DP"/"QP", "Min"/"Small"/"Full"). Missing
//! fields take the [`EgpuConfig::default`] value; unknown fields are
//! errors (they are always typos).
//!
//! ```json
//! { "name": "edge-qp", "threads": 1024, "memory": "QP",
//!   "predicate_levels": 8, "dot_core": true }
//! ```
//!
//! A file may also hold an array of such objects (a whole fleet).

use std::collections::BTreeMap;

use super::config::{ConfigError, EgpuConfig, IntAluClass, MemoryMode};

/// Serialize a configuration (stable field order, round-trips through
/// [`config_from_json`]).
pub fn config_to_json(cfg: &EgpuConfig) -> String {
    format!(
        "{{\n  \"name\": {},\n  \"threads\": {},\n  \"regs_per_thread\": {},\n  \
         \"shared_kb\": {},\n  \"memory\": \"{}\",\n  \"alu_precision\": {},\n  \
         \"shift_precision\": {},\n  \"int_alu\": \"{}\",\n  \
         \"predicate_levels\": {},\n  \"dot_core\": {},\n  \"sfu\": {}\n}}",
        json_string(&cfg.name),
        cfg.threads,
        cfg.regs_per_thread,
        cfg.shared_kb,
        cfg.memory.name(),
        cfg.alu_precision,
        cfg.shift_precision,
        cfg.int_alu.name(),
        cfg.predicate_levels,
        cfg.dot_core,
        cfg.sfu,
    )
}

/// Serialize a fleet as a JSON array.
pub fn fleet_to_json(cfgs: &[EgpuConfig]) -> String {
    let body: Vec<String> = cfgs.iter().map(config_to_json).collect();
    format!("[\n{}\n]", body.join(",\n"))
}

/// Parse one configuration object. The result is validated.
pub fn config_from_json(src: &str) -> Result<EgpuConfig, ConfigError> {
    match parse_value(src)? {
        Value::Object(map) => config_from_map(map),
        _ => Err(ConfigError("expected a JSON object".into())),
    }
}

/// Parse a file that holds either one configuration object or an array
/// of them. The results are validated.
pub fn configs_from_json(src: &str) -> Result<Vec<EgpuConfig>, ConfigError> {
    match parse_value(src)? {
        Value::Object(map) => Ok(vec![config_from_map(map)?]),
        Value::Array(items) => items
            .into_iter()
            .map(|v| match v {
                Value::Object(map) => config_from_map(map),
                _ => Err(ConfigError("array elements must be objects".into())),
            })
            .collect(),
        _ => Err(ConfigError("expected a JSON object or array".into())),
    }
}

fn config_from_map(map: BTreeMap<String, Value>) -> Result<EgpuConfig, ConfigError> {
    let mut cfg = EgpuConfig::default();
    for (key, value) in map {
        match key.as_str() {
            "name" => cfg.name = value.string(&key)?,
            "threads" => cfg.threads = value.usize(&key)?,
            "regs_per_thread" => cfg.regs_per_thread = value.usize(&key)?,
            "shared_kb" => cfg.shared_kb = value.usize(&key)?,
            "memory" => {
                cfg.memory = match value.string(&key)?.to_ascii_uppercase().as_str() {
                    "DP" => MemoryMode::Dp,
                    "QP" => MemoryMode::Qp,
                    other => {
                        return Err(ConfigError(format!(
                            "memory must be \"DP\" or \"QP\", got \"{other}\""
                        )))
                    }
                }
            }
            "alu_precision" => cfg.alu_precision = value.u8(&key)?,
            "shift_precision" => cfg.shift_precision = value.u8(&key)?,
            "int_alu" => {
                cfg.int_alu = match value.string(&key)?.to_ascii_lowercase().as_str() {
                    "min" => IntAluClass::Min,
                    "small" => IntAluClass::Small,
                    "full" => IntAluClass::Full,
                    other => {
                        return Err(ConfigError(format!(
                            "int_alu must be \"Min\", \"Small\" or \"Full\", got \"{other}\""
                        )))
                    }
                }
            }
            "predicate_levels" => cfg.predicate_levels = value.usize(&key)?,
            "dot_core" => cfg.dot_core = value.bool(&key)?,
            "sfu" => cfg.sfu = value.bool(&key)?,
            other => {
                return Err(ConfigError(format!(
                    "unknown configuration field \"{other}\""
                )))
            }
        }
    }
    cfg.validate()?;
    Ok(cfg)
}

// ---------------------------------------------------------------------
// A minimal JSON value model + recursive-descent parser. Covers the
// full grammar except `\uXXXX` surrogate pairs (config files are ASCII).
// ---------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    fn string(self, key: &str) -> Result<String, ConfigError> {
        match self {
            Value::String(s) => Ok(s),
            other => Err(ConfigError(format!("{key}: expected a string, got {other:?}"))),
        }
    }

    fn bool(self, key: &str) -> Result<bool, ConfigError> {
        match self {
            Value::Bool(b) => Ok(b),
            other => Err(ConfigError(format!("{key}: expected a bool, got {other:?}"))),
        }
    }

    fn usize(self, key: &str) -> Result<usize, ConfigError> {
        match self {
            Value::Number(n) if n >= 0.0 && n.fract() == 0.0 && n <= u32::MAX as f64 => {
                Ok(n as usize)
            }
            other => Err(ConfigError(format!(
                "{key}: expected a non-negative integer, got {other:?}"
            ))),
        }
    }

    /// Byte-sized field: rejects out-of-range values instead of letting
    /// an `as u8` cast wrap them into different-but-valid settings
    /// (`"shift_precision": 257` must be an error, not a 1-bit shifter).
    fn u8(self, key: &str) -> Result<u8, ConfigError> {
        let v = self.usize(key)?;
        u8::try_from(v).map_err(|_| {
            ConfigError(format!("{key}: {v} is out of range for a byte-sized field"))
        })
    }
}

fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

fn parse_value(src: &str) -> Result<Value, ConfigError> {
    let mut p = Parser {
        bytes: src.as_bytes(),
        pos: 0,
    };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after the JSON value"));
    }
    Ok(v)
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> ConfigError {
        ConfigError(format!("JSON parse error at byte {}: {msg}", self.pos))
    }

    fn skip_ws(&mut self) {
        while matches!(self.bytes.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), ConfigError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ConfigError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ConfigError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Value, ConfigError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.eat(b':')?;
            let value = self.value()?;
            if map.insert(key.clone(), value).is_some() {
                return Err(self.err(&format!("duplicate key \"{key}\"")));
            }
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Object(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ConfigError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Array(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ConfigError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.bytes.get(self.pos) {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let next = self.bytes.get(self.pos).copied();
                    let esc = next.ok_or_else(|| self.err("unterminated escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(
                                char::from_u32(hex)
                                    .ok_or_else(|| self.err("bad \\u code point"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(&b) if b < 0x20 => return Err(self.err("control byte in string")),
                Some(_) => {
                    // Consume one UTF-8 scalar (input is a &str, so the
                    // byte stream is valid UTF-8).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ConfigError> {
        let start = self.pos;
        if self.bytes.get(self.pos) == Some(&b'-') {
            self.pos += 1;
        }
        while matches!(
            self.bytes.get(self.pos),
            Some(b) if b.is_ascii_digit() || matches!(b, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Number)
            .ok_or_else(|| self.err("malformed number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_every_preset() {
        for cfg in EgpuConfig::table4_presets()
            .into_iter()
            .chain(EgpuConfig::table5_presets())
            .chain([
                EgpuConfig::benchmark(MemoryMode::Dp, true),
                EgpuConfig::benchmark_predicated(MemoryMode::Qp),
            ])
        {
            let json = config_to_json(&cfg);
            let back = config_from_json(&json).unwrap_or_else(|e| panic!("{}: {e}", cfg.name));
            assert_eq!(cfg, back, "{json}");
        }
    }

    #[test]
    fn fleet_round_trip() {
        let fleet = vec![
            EgpuConfig::benchmark(MemoryMode::Dp, true),
            EgpuConfig::benchmark(MemoryMode::Qp, false),
        ];
        let back = configs_from_json(&fleet_to_json(&fleet)).unwrap();
        assert_eq!(fleet, back);
        // A single object parses as a one-core fleet too.
        let one = configs_from_json(&config_to_json(&fleet[0])).unwrap();
        assert_eq!(one, vec![fleet[0].clone()]);
    }

    #[test]
    fn partial_objects_take_defaults() {
        let cfg = config_from_json(r#"{ "memory": "QP", "threads": 1024 }"#).unwrap();
        assert_eq!(cfg.memory, MemoryMode::Qp);
        assert_eq!(cfg.threads, 1024);
        assert_eq!(cfg.regs_per_thread, EgpuConfig::default().regs_per_thread);
    }

    #[test]
    fn bad_inputs_are_rejected_with_reasons() {
        assert!(config_from_json("[1, 2]").is_err());
        assert!(config_from_json(r#"{ "memory": "HBM" }"#)
            .unwrap_err()
            .to_string()
            .contains("DP"));
        assert!(config_from_json(r#"{ "turbo": true }"#)
            .unwrap_err()
            .to_string()
            .contains("unknown configuration field"));
        // Validation runs: 100 threads is not a wavefront multiple.
        assert!(config_from_json(r#"{ "threads": 100 }"#).is_err());
        // Byte-sized fields must not wrap (257 as u8 == 1 would be a
        // silently valid single-bit shifter).
        assert!(config_from_json(r#"{ "shift_precision": 257 }"#)
            .unwrap_err()
            .to_string()
            .contains("out of range"));
        assert!(config_from_json(r#"{ "alu_precision": 272 }"#).is_err());
        assert!(config_from_json(r#"{ "threads": }"#).is_err());
        assert!(config_from_json(r#"{ "name": "a", "name": "b" }"#)
            .unwrap_err()
            .to_string()
            .contains("duplicate"));
    }

    #[test]
    fn random_valid_configs_round_trip_bit_identically() {
        // Hand-rolled property test (proptest is unavailable offline):
        // any valid configuration — including u8 fields at their
        // boundary values (shift_precision 1, the 16/32 precision
        // edges) and threads at the wavefront-multiple extremes — must
        // survive encode→decode with every field bit-identical.
        use crate::harness::Rng;
        let mut rng = Rng::new(0xC0DEC);
        for case in 0..500 {
            let name = format!("prop-{case}-{}", rng.next_u32());
            let threads = 16 * rng.range_i64(1, 64) as usize;
            let regs_per_thread = *rng.choose(&[16usize, 32, 64]);
            let shared_kb = *rng.choose(&[2usize, 4, 32, 128, 512]);
            let memory = *rng.choose(&[MemoryMode::Dp, MemoryMode::Qp]);
            let alu_precision = *rng.choose(&[16u8, 32]);
            let mut shift_precision = *rng.choose(&[1u8, 16, 32]);
            if shift_precision > alu_precision {
                shift_precision = alu_precision;
            }
            let int_alu = *rng.choose(&[IntAluClass::Min, IntAluClass::Small, IntAluClass::Full]);
            let predicate_levels = rng.below(33);
            let dot_core = rng.chance(0.5);
            let sfu = rng.chance(0.5);
            let cfg = EgpuConfig {
                name,
                threads,
                regs_per_thread,
                shared_kb,
                memory,
                alu_precision,
                shift_precision,
                int_alu,
                predicate_levels,
                dot_core,
                sfu,
            };
            cfg.validate().unwrap_or_else(|e| panic!("case {case} generated invalid: {e}"));
            let json = config_to_json(&cfg);
            let back = config_from_json(&json).unwrap_or_else(|e| panic!("case {case}: {e}"));
            assert_eq!(cfg, back, "case {case}: {json}");
        }
    }

    #[test]
    fn every_fleet_demo_config_round_trips() {
        // The configs the fleet demo can actually put on cores — the
        // demo_mixed pair plus every Table 4/5 preset the CLI accepts —
        // must ship through JSON unchanged (fleet files are the
        // deployment artifact).
        let mut cfgs: Vec<EgpuConfig> = crate::api::FleetBuilder::demo_mixed()
            .as_configs()
            .to_vec();
        cfgs.extend(EgpuConfig::table4_presets());
        cfgs.extend(EgpuConfig::table5_presets());
        let back = configs_from_json(&fleet_to_json(&cfgs)).unwrap();
        assert_eq!(cfgs, back);
    }

    #[test]
    fn string_escapes_round_trip() {
        let mut cfg = EgpuConfig::default();
        cfg.name = "q\"p\\\n".into();
        let back = config_from_json(&config_to_json(&cfg)).unwrap();
        assert_eq!(back.name, cfg.name);
    }
}
