//! Per-SP thread register memories (paper §5.1).
//!
//! In hardware each SP owns M20K-implemented register memories: two read
//! ports + one write port per cycle in DP mode (two replicated dual-port
//! blocks), doubled writes in QP mode. A thread's registers live in its
//! SP's column; thread `t` maps to SP `t % 16`, wavefront `t / 16`.
//!
//! Layout: `regs[(wave * 16 + sp) * regs_per_thread + r]` — wavefront-major
//! so one wavefront's operands are 16 contiguous strides (cache-friendly
//! for the simulator's wave loop).

use crate::isa::WAVEFRONT_WIDTH;

use super::predicate::PredicateFile;

#[derive(Debug, Clone)]
pub struct RegFile {
    regs: Vec<u32>,
    regs_per_thread: usize,
}

impl RegFile {
    pub fn new(threads: usize, regs_per_thread: usize) -> RegFile {
        RegFile {
            regs: vec![0; threads * regs_per_thread],
            regs_per_thread,
        }
    }

    pub fn regs_per_thread(&self) -> usize {
        self.regs_per_thread
    }

    /// Hot-path row iteration for LOD: visit each selected lane's
    /// register row (mutable) with its thread index.
    #[inline]
    pub fn lane_rows_mut<E>(
        &mut self,
        waves: usize,
        lanes: usize,
        mut f: impl FnMut(usize, &mut [u32]) -> Result<(), E>,
    ) -> Result<(), E> {
        let rpt = self.regs_per_thread;
        for (w, wave_rows) in self
            .regs
            .chunks_exact_mut(rpt * WAVEFRONT_WIDTH)
            .take(waves)
            .enumerate()
        {
            let base = w * WAVEFRONT_WIDTH;
            for (sp, row) in wave_rows.chunks_exact_mut(rpt).take(lanes).enumerate() {
                f(base + sp, row)?;
            }
        }
        Ok(())
    }

    /// Read-only row iteration (STO, IF compares): visit each selected
    /// lane's register row with its thread index.
    #[inline]
    pub fn lane_rows<E>(
        &self,
        waves: usize,
        lanes: usize,
        mut f: impl FnMut(usize, &[u32]) -> Result<(), E>,
    ) -> Result<(), E> {
        let rpt = self.regs_per_thread;
        for (w, wave_rows) in self
            .regs
            .chunks_exact(rpt * WAVEFRONT_WIDTH)
            .take(waves)
            .enumerate()
        {
            let base = w * WAVEFRONT_WIDTH;
            for (sp, row) in wave_rows.chunks_exact(rpt).take(lanes).enumerate() {
                f(base + sp, row)?;
            }
        }
        Ok(())
    }

    /// Hot-path row iteration: apply `f(ra, rb) -> rd` to every selected
    /// lane of the first `waves` wavefronts. `chunks_exact_mut` removes
    /// the per-lane index arithmetic and bounds checks of `read`/`write`
    /// (the simulator's dominant cost, see EXPERIMENTS.md §Perf).
    /// `preds` is the write-enable gate; `None` (predicates not
    /// configured) selects an ungated inner loop with no per-lane branch.
    ///
    /// The superplan executor (`Machine::native_alu_lanes`) instantiates
    /// this once per concrete ALU op, so each closure monomorphizes into
    /// its own branch-free loop over contiguous SoA rows — the shape
    /// LLVM autovectorizes. Keep `f` free of captures with interior
    /// indirection (no `dyn`, no per-lane table lookups) or that
    /// property is lost silently.
    #[inline]
    pub fn lane_apply(
        &mut self,
        waves: usize,
        lanes: usize,
        rd: u8,
        ra: u8,
        rb: u8,
        preds: Option<&PredicateFile>,
        mut f: impl FnMut(u32, u32) -> u32,
    ) {
        let rpt = self.regs_per_thread;
        let (rd, ra, rb) = (rd as usize, ra as usize, rb as usize);
        match preds {
            None => {
                for wave_rows in self
                    .regs
                    .chunks_exact_mut(rpt * WAVEFRONT_WIDTH)
                    .take(waves)
                {
                    for row in wave_rows.chunks_exact_mut(rpt).take(lanes) {
                        row[rd] = f(row[ra], row[rb]);
                    }
                }
            }
            Some(p) => {
                for (w, wave_rows) in self
                    .regs
                    .chunks_exact_mut(rpt * WAVEFRONT_WIDTH)
                    .take(waves)
                    .enumerate()
                {
                    let base = w * WAVEFRONT_WIDTH;
                    for (sp, row) in wave_rows.chunks_exact_mut(rpt).take(lanes).enumerate() {
                        if !p.active(base + sp) {
                            continue;
                        }
                        row[rd] = f(row[ra], row[rb]);
                    }
                }
            }
        }
    }

    /// Per-thread generated writes (LDI/TDX/TDY): `rd = value(thread)`
    /// over the selected subset, gated by `preds` when configured.
    #[inline]
    pub fn lane_set(
        &mut self,
        waves: usize,
        lanes: usize,
        rd: u8,
        preds: Option<&PredicateFile>,
        mut value: impl FnMut(usize) -> u32,
    ) {
        let rpt = self.regs_per_thread;
        let rd = rd as usize;
        match preds {
            None => {
                for (w, wave_rows) in self
                    .regs
                    .chunks_exact_mut(rpt * WAVEFRONT_WIDTH)
                    .take(waves)
                    .enumerate()
                {
                    let base = w * WAVEFRONT_WIDTH;
                    for (sp, row) in wave_rows.chunks_exact_mut(rpt).take(lanes).enumerate() {
                        row[rd] = value(base + sp);
                    }
                }
            }
            Some(p) => {
                for (w, wave_rows) in self
                    .regs
                    .chunks_exact_mut(rpt * WAVEFRONT_WIDTH)
                    .take(waves)
                    .enumerate()
                {
                    let base = w * WAVEFRONT_WIDTH;
                    for (sp, row) in wave_rows.chunks_exact_mut(rpt).take(lanes).enumerate() {
                        if !p.active(base + sp) {
                            continue;
                        }
                        row[rd] = value(base + sp);
                    }
                }
            }
        }
    }

    pub fn threads(&self) -> usize {
        self.regs.len() / self.regs_per_thread
    }

    #[inline]
    fn idx(&self, wave: usize, sp: usize, r: u8) -> usize {
        (wave * WAVEFRONT_WIDTH + sp) * self.regs_per_thread + r as usize
    }

    #[inline]
    pub fn read(&self, wave: usize, sp: usize, r: u8) -> u32 {
        self.regs[self.idx(wave, sp, r)]
    }

    #[inline]
    pub fn write(&mut self, wave: usize, sp: usize, r: u8, v: u32) {
        let i = self.idx(wave, sp, r);
        self.regs[i] = v;
    }

    #[inline]
    pub fn read_thread(&self, thread: usize, r: u8) -> u32 {
        self.regs[thread * self.regs_per_thread + r as usize]
    }

    #[inline]
    pub fn write_thread(&mut self, thread: usize, r: u8, v: u32) {
        self.regs[thread * self.regs_per_thread + r as usize] = v;
    }

    pub fn reset(&mut self) {
        self.regs.fill(0);
    }

    /// All lanes of one register across one wavefront (for block gather).
    pub fn wave_slice(&self, wave: usize, r: u8, out: &mut [u32; WAVEFRONT_WIDTH]) {
        for (sp, o) in out.iter_mut().enumerate() {
            *o = self.read(wave, sp, r);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_to_sp_wave_mapping() {
        // §3.2: thread t → SP (t mod 16), wavefront (t div 16).
        let mut rf = RegFile::new(64, 16);
        rf.write_thread(37, 3, 99);
        assert_eq!(rf.read(37 / 16, 37 % 16, 3), 99);
        rf.write(1, 5, 0, 42);
        assert_eq!(rf.read_thread(21, 0), 42);
    }

    #[test]
    fn independent_registers() {
        let mut rf = RegFile::new(32, 32);
        for t in 0..32 {
            for r in 0..32u8 {
                rf.write_thread(t, r, (t * 100 + r as usize) as u32);
            }
        }
        for t in 0..32 {
            for r in 0..32u8 {
                assert_eq!(rf.read_thread(t, r), (t * 100 + r as usize) as u32);
            }
        }
    }

    #[test]
    fn wave_slice_gathers_lanes() {
        let mut rf = RegFile::new(32, 16);
        for sp in 0..16 {
            rf.write(1, sp, 2, sp as u32 + 100);
        }
        let mut out = [0u32; 16];
        rf.wave_slice(1, 2, &mut out);
        assert_eq!(out[0], 100);
        assert_eq!(out[15], 115);
    }

    #[test]
    fn lane_apply_gates_on_predicates() {
        let mut rf = RegFile::new(32, 16);
        for t in 0..32 {
            rf.write_thread(t, 1, t as u32);
        }
        let mut preds = PredicateFile::new(32, 4);
        for t in 0..32 {
            preds.push(t, t % 2 == 0).unwrap();
        }
        rf.lane_apply(2, 16, 2, 1, 1, Some(&preds), |a, b| a + b);
        for t in 0..32 {
            let want = if t % 2 == 0 { 2 * t as u32 } else { 0 };
            assert_eq!(rf.read_thread(t, 2), want, "thread {t}");
        }
        // Ungated path touches every selected lane.
        rf.lane_apply(1, 4, 3, 1, 1, None, |a, _| a);
        assert_eq!(rf.read_thread(3, 3), 3);
        assert_eq!(rf.read_thread(4, 3), 0); // SP4 outside w4
    }

    #[test]
    fn lane_set_writes_generated_values() {
        let mut rf = RegFile::new(32, 16);
        rf.lane_set(2, 16, 5, None, |t| t as u32 * 10);
        assert_eq!(rf.read_thread(0, 5), 0);
        assert_eq!(rf.read_thread(31, 5), 310);
    }

    #[test]
    fn lane_rows_reads_selected_prefix() {
        let mut rf = RegFile::new(32, 16);
        for t in 0..32 {
            rf.write_thread(t, 0, t as u32);
        }
        let mut seen = Vec::new();
        rf.lane_rows(1, 4, |t, row| -> Result<(), ()> {
            seen.push((t, row[0]));
            Ok(())
        })
        .unwrap();
        assert_eq!(seen, vec![(0, 0), (1, 1), (2, 2), (3, 3)]);
    }

    #[test]
    fn reset_zeroes() {
        let mut rf = RegFile::new(16, 16);
        rf.write_thread(0, 0, 5);
        rf.reset();
        assert_eq!(rf.read_thread(0, 0), 0);
    }
}
