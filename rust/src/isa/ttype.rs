//! The 2-bit TYPE (number representation) field and condition codes.

use std::fmt;

/// Number representation of an instruction's operands (Figure 3: "The
/// 2-bit representation field encodes whether the number is unsigned
/// integer, signed integer, or FP32").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
#[repr(u8)]
pub enum TType {
    /// Unsigned 32-bit (or 16-bit on small-ALU configs).
    Uint = 0,
    /// Signed two's-complement.
    #[default]
    Int = 1,
    /// IEEE-754 single precision.
    Fp32 = 2,
}

impl TType {
    pub fn from_bits(bits: u8) -> Option<TType> {
        match bits & 0b11 {
            0 => Some(TType::Uint),
            1 => Some(TType::Int),
            2 => Some(TType::Fp32),
            _ => None,
        }
    }

    pub fn bits(self) -> u8 {
        self as u8
    }

    /// Assembly suffix (`add.i32`, `shr.u32`, `if.lt.f32`).
    pub fn suffix(self) -> &'static str {
        match self {
            TType::Uint => "u32",
            TType::Int => "i32",
            TType::Fp32 => "f32",
        }
    }

    pub fn from_suffix(s: &str) -> Option<TType> {
        match s {
            "u32" | "u16" | "uint32" | "uint16" => Some(TType::Uint),
            "i32" | "i16" | "int32" | "int16" => Some(TType::Int),
            "f32" | "fp32" => Some(TType::Fp32),
            _ => None,
        }
    }
}

impl fmt::Display for TType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.suffix())
    }
}

/// Condition codes for IF.cc (Table 2 "Int Compare"; FP variants exist for
/// each). Stored in the low 3 bits of the immediate field of an IF word.
///
/// The unsigned mnemonics (lo/ls/hi/hs) are the same codes with TYPE=UINT.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum CondCode {
    Eq = 0,
    Ne = 1,
    Lt = 2,
    Le = 3,
    Gt = 4,
    Ge = 5,
}

impl CondCode {
    pub const ALL: [CondCode; 6] = [
        CondCode::Eq,
        CondCode::Ne,
        CondCode::Lt,
        CondCode::Le,
        CondCode::Gt,
        CondCode::Ge,
    ];

    pub fn from_bits(bits: u8) -> Option<CondCode> {
        Self::ALL.get((bits & 0b111) as usize).copied()
    }

    pub fn bits(self) -> u8 {
        self as u8
    }

    /// Signed/FP mnemonic.
    pub fn mnemonic(self) -> &'static str {
        match self {
            CondCode::Eq => "eq",
            CondCode::Ne => "ne",
            CondCode::Lt => "lt",
            CondCode::Le => "le",
            CondCode::Gt => "gt",
            CondCode::Ge => "ge",
        }
    }

    /// Parse either the signed (`lt`) or unsigned (`lo`) mnemonic; returns
    /// the code and whether the unsigned alias was used.
    pub fn from_mnemonic(s: &str) -> Option<(CondCode, bool)> {
        match s {
            "eq" => Some((CondCode::Eq, false)),
            "ne" => Some((CondCode::Ne, false)),
            "lt" => Some((CondCode::Lt, false)),
            "le" => Some((CondCode::Le, false)),
            "gt" => Some((CondCode::Gt, false)),
            "ge" => Some((CondCode::Ge, false)),
            "lo" => Some((CondCode::Lt, true)),
            "ls" => Some((CondCode::Le, true)),
            "hi" => Some((CondCode::Gt, true)),
            "hs" => Some((CondCode::Ge, true)),
            _ => None,
        }
    }

    /// Evaluate over i32 lanes with the given representation.
    pub fn eval(self, ttype: TType, a: u32, b: u32) -> bool {
        match ttype {
            TType::Uint => self.eval_ord(a.cmp(&b)),
            TType::Int => self.eval_ord((a as i32).cmp(&(b as i32))),
            TType::Fp32 => {
                let (fa, fb) = (f32::from_bits(a), f32::from_bits(b));
                match self {
                    CondCode::Eq => fa == fb,
                    CondCode::Ne => fa != fb,
                    CondCode::Lt => fa < fb,
                    CondCode::Le => fa <= fb,
                    CondCode::Gt => fa > fb,
                    CondCode::Ge => fa >= fb,
                }
            }
        }
    }

    fn eval_ord(self, ord: std::cmp::Ordering) -> bool {
        use std::cmp::Ordering::*;
        match self {
            CondCode::Eq => ord == Equal,
            CondCode::Ne => ord != Equal,
            CondCode::Lt => ord == Less,
            CondCode::Le => ord != Greater,
            CondCode::Gt => ord == Greater,
            CondCode::Ge => ord != Less,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ttype_roundtrip() {
        for t in [TType::Uint, TType::Int, TType::Fp32] {
            assert_eq!(TType::from_bits(t.bits()), Some(t));
            assert_eq!(TType::from_suffix(t.suffix()), Some(t));
        }
        assert_eq!(TType::from_bits(3), None);
    }

    #[test]
    fn condcode_roundtrip() {
        for cc in CondCode::ALL {
            assert_eq!(CondCode::from_bits(cc.bits()), Some(cc));
            assert_eq!(CondCode::from_mnemonic(cc.mnemonic()), Some((cc, false)));
        }
    }

    #[test]
    fn unsigned_aliases() {
        assert_eq!(CondCode::from_mnemonic("lo"), Some((CondCode::Lt, true)));
        assert_eq!(CondCode::from_mnemonic("hs"), Some((CondCode::Ge, true)));
    }

    #[test]
    fn eval_signed_vs_unsigned() {
        let a = (-1i32) as u32; // 0xFFFFFFFF
        let b = 1u32;
        assert!(CondCode::Lt.eval(TType::Int, a, b)); // -1 < 1
        assert!(CondCode::Gt.eval(TType::Uint, a, b)); // 0xFFFFFFFF > 1
    }

    #[test]
    fn eval_fp() {
        let a = 1.5f32.to_bits();
        let b = (-2.0f32).to_bits();
        assert!(CondCode::Gt.eval(TType::Fp32, a, b));
        assert!(CondCode::Ne.eval(TType::Fp32, a, b));
        let nan = f32::NAN.to_bits();
        assert!(!CondCode::Eq.eval(TType::Fp32, nan, nan));
        assert!(CondCode::Ne.eval(TType::Fp32, nan, nan));
    }

    #[test]
    fn eval_all_codes_exhaustive() {
        for (a, b) in [(0u32, 0u32), (1, 2), (2, 1)] {
            let ord = a.cmp(&b);
            assert_eq!(CondCode::Eq.eval(TType::Uint, a, b), ord.is_eq());
            assert_eq!(CondCode::Ne.eval(TType::Uint, a, b), !ord.is_eq());
            assert_eq!(CondCode::Lt.eval(TType::Uint, a, b), ord.is_lt());
            assert_eq!(CondCode::Le.eval(TType::Uint, a, b), ord.is_le());
            assert_eq!(CondCode::Gt.eval(TType::Uint, a, b), ord.is_gt());
            assert_eq!(CondCode::Ge.eval(TType::Uint, a, b), ord.is_ge());
        }
    }
}
