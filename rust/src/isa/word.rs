//! Instruction-word encoding (paper Figure 3).
//!
//! The word is, from most- to least-significant field:
//!
//! ```text
//! | tctrl (4) | opcode (6) | type (2) | rd (R) | ra (R) | rb (R) | imm (16) |
//! ```
//!
//! where `R` = ceil(log2(registers_per_thread)) — 4/5/6 bits for 16/32/64
//! registers, giving the paper's 40/43/46-bit instruction words. Words are
//! stored in a `u64` (`EncodedWord`); the layout object carries `R`.
//!
//! IF.cc words put the condition code in the low 3 bits of the immediate
//! field (the compare operands are in ra/rb).

use std::fmt;

use super::{
    CondCode, Opcode, TType, ThreadCtrl, IMM_BITS, OPCODE_BITS, TCTRL_BITS,
    TTYPE_BITS,
};
use crate::isa::opcode::OperandShape;

/// An encoded instruction word.
pub type EncodedWord = u64;

/// Field geometry for a given registers-per-thread configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WordLayout {
    /// Register-field width in bits (4, 5 or 6).
    pub reg_bits: u32,
}

impl WordLayout {
    /// Layout for a machine with `regs_per_thread` registers.
    pub fn for_regs(regs_per_thread: usize) -> WordLayout {
        assert!(
            regs_per_thread.is_power_of_two() && (16..=64).contains(&regs_per_thread),
            "registers per thread must be 16, 32 or 64 (got {regs_per_thread})"
        );
        WordLayout {
            reg_bits: regs_per_thread.trailing_zeros(),
        }
    }

    /// Total instruction-word width: 40/43/46 bits (paper §5.4).
    pub fn word_bits(&self) -> u32 {
        TCTRL_BITS + OPCODE_BITS + TTYPE_BITS + 3 * self.reg_bits + IMM_BITS
    }

    pub fn max_reg(&self) -> u8 {
        ((1u32 << self.reg_bits) - 1) as u8
    }

    // Field bit offsets from the LSB.
    fn imm_off(&self) -> u32 {
        0
    }
    fn rb_off(&self) -> u32 {
        IMM_BITS
    }
    fn ra_off(&self) -> u32 {
        IMM_BITS + self.reg_bits
    }
    fn rd_off(&self) -> u32 {
        IMM_BITS + 2 * self.reg_bits
    }
    fn ttype_off(&self) -> u32 {
        IMM_BITS + 3 * self.reg_bits
    }
    fn opcode_off(&self) -> u32 {
        self.ttype_off() + TTYPE_BITS
    }
    fn tctrl_off(&self) -> u32 {
        self.opcode_off() + OPCODE_BITS
    }

    /// Encode a decoded instruction. Panics if a register exceeds the
    /// configured register space (the assembler validates first).
    pub fn encode(&self, i: &Instr) -> EncodedWord {
        let rmask = self.max_reg() as u64;
        assert!(
            i.rd as u64 <= rmask && i.ra as u64 <= rmask && i.rb as u64 <= rmask,
            "register out of range for {}-bit register field",
            self.reg_bits
        );
        let mut w: u64 = 0;
        w |= (i.imm as u64 & 0xFFFF) << self.imm_off();
        w |= (i.rb as u64) << self.rb_off();
        w |= (i.ra as u64) << self.ra_off();
        w |= (i.rd as u64) << self.rd_off();
        w |= (i.ttype.bits() as u64) << self.ttype_off();
        w |= (i.op.bits() as u64) << self.opcode_off();
        w |= (i.tc.bits() as u64) << self.tctrl_off();
        w
    }

    /// Decode an instruction word. Errors on unallocated opcodes, the
    /// undefined width coding, or a reserved TYPE value.
    pub fn decode(&self, w: EncodedWord) -> Result<Instr, DecodeError> {
        let rmask = self.max_reg() as u64;
        let op_bits = ((w >> self.opcode_off()) & 0x3F) as u8;
        let op = Opcode::from_bits(op_bits).ok_or(DecodeError::BadOpcode(op_bits))?;
        let tc_bits = ((w >> self.tctrl_off()) & 0xF) as u8;
        let tc = ThreadCtrl::from_bits(tc_bits).ok_or(DecodeError::UndefinedWidth)?;
        let tt_bits = ((w >> self.ttype_off()) & 0x3) as u8;
        let ttype = TType::from_bits(tt_bits).ok_or(DecodeError::BadType(tt_bits))?;
        Ok(Instr {
            op,
            ttype,
            tc,
            rd: ((w >> self.rd_off()) & rmask) as u8,
            ra: ((w >> self.ra_off()) & rmask) as u8,
            rb: ((w >> self.rb_off()) & rmask) as u8,
            imm: ((w >> self.imm_off()) & 0xFFFF) as u16,
        })
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeError {
    BadOpcode(u8),
    UndefinedWidth,
    BadType(u8),
}

impl fmt::Display for DecodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DecodeError::BadOpcode(b) => write!(f, "unallocated opcode {b:#04x}"),
            DecodeError::UndefinedWidth => {
                write!(f, "undefined thread-space width coding \"11\"")
            }
            DecodeError::BadType(b) => write!(f, "reserved TYPE coding {b:#04b}"),
        }
    }
}

impl std::error::Error for DecodeError {}

/// A decoded instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Instr {
    pub op: Opcode,
    pub ttype: TType,
    /// Dynamic thread-space control for this instruction (§3.1).
    pub tc: ThreadCtrl,
    pub rd: u8,
    pub ra: u8,
    pub rb: u8,
    /// Raw 16-bit immediate: LDI value, LOD/STO offset, branch target,
    /// INIT loop count, or IF condition code (low 3 bits).
    pub imm: u16,
}

impl Instr {
    /// A full-space instruction with all fields zeroed except the opcode.
    pub fn new(op: Opcode) -> Instr {
        Instr {
            op,
            ttype: TType::default(),
            tc: ThreadCtrl::FULL,
            rd: 0,
            ra: 0,
            rb: 0,
            imm: 0,
        }
    }

    pub fn nop() -> Instr {
        Instr::new(Opcode::Nop)
    }

    /// Immediate as signed (LDI can load negative constants).
    pub fn imm_i(&self) -> i32 {
        self.imm as i16 as i32
    }

    /// Immediate as unsigned (addresses, offsets, loop counts).
    pub fn imm_u(&self) -> u32 {
        self.imm as u32
    }

    /// Condition code of an IF word.
    pub fn cond(&self) -> Option<CondCode> {
        if self.op == Opcode::If {
            CondCode::from_bits((self.imm & 0b111) as u8)
        } else {
            None
        }
    }

    /// Render in assembly syntax (inverse of the assembler).
    pub fn disasm(&self) -> String {
        let mut s = String::new();
        if self.tc != ThreadCtrl::FULL {
            s.push_str(&format!("{} ", self.tc));
        }
        s.push_str(self.op.mnemonic());
        if self.op == Opcode::If {
            let cc = self.cond().map(|c| c.mnemonic()).unwrap_or("??");
            s.push_str(&format!(".{cc}.{}", self.ttype.suffix()));
        } else if self.op.is_typed() {
            s.push_str(&format!(".{}", self.ttype.suffix()));
        }
        match self.op.operands() {
            OperandShape::None => {}
            OperandShape::Rd => s.push_str(&format!(" r{}", self.rd)),
            OperandShape::RdRa => s.push_str(&format!(" r{}, r{}", self.rd, self.ra)),
            OperandShape::RdRaRb => {
                s.push_str(&format!(" r{}, r{}, r{}", self.rd, self.ra, self.rb))
            }
            OperandShape::RaRb => s.push_str(&format!(" r{}, r{}", self.ra, self.rb)),
            OperandShape::RdMem => {
                s.push_str(&format!(" r{}, (r{})+{}", self.rd, self.ra, self.imm_u()))
            }
            OperandShape::RdImm => s.push_str(&format!(" r{}, #{}", self.rd, self.imm_i())),
            OperandShape::Imm => s.push_str(&format!(" #{}", self.imm_u())),
            OperandShape::Addr => s.push_str(&format!(" {}", self.imm_u())),
        }
        s
    }
}

impl fmt::Display for Instr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.disasm())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{DepthSel, WidthSel};

    #[test]
    fn word_widths_match_paper() {
        // §5.4: 40-bit IW for 16 regs, 43 for 32, 46 for 64.
        assert_eq!(WordLayout::for_regs(16).word_bits(), 40);
        assert_eq!(WordLayout::for_regs(32).word_bits(), 43);
        assert_eq!(WordLayout::for_regs(64).word_bits(), 46);
    }

    #[test]
    #[should_panic(expected = "registers per thread")]
    fn bad_reg_count_panics() {
        WordLayout::for_regs(48);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let layout = WordLayout::for_regs(32);
        let i = Instr {
            op: Opcode::FAdd,
            ttype: TType::Fp32,
            tc: ThreadCtrl::new(WidthSel::Quarter4, DepthSel::Half),
            rd: 31,
            ra: 7,
            rb: 15,
            imm: 0xBEEF,
        };
        let w = layout.encode(&i);
        assert_eq!(layout.decode(w).unwrap(), i);
        assert!(w < (1u64 << layout.word_bits()));
    }

    /// Property: every instruction the machine can express round-trips
    /// exactly through every layout (deterministic LCG sweep).
    #[test]
    fn roundtrip_property_sweep() {
        let mut lcg: u64 = 0x2545F4914F6CDD1D;
        let mut next = move || {
            lcg = lcg.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            lcg >> 16
        };
        for regs in [16usize, 32, 64] {
            let layout = WordLayout::for_regs(regs);
            for _ in 0..2000 {
                let r = next();
                let op = Opcode::from_bits((r % 44) as u8).unwrap();
                let ttype = TType::from_bits(((r >> 8) % 3) as u8).unwrap();
                let tc = ThreadCtrl::new(
                    WidthSel::from_bits(((r >> 16) % 3) as u8).unwrap(),
                    DepthSel::from_bits(((r >> 24) % 4) as u8),
                );
                let i = Instr {
                    op,
                    ttype,
                    tc,
                    rd: ((r >> 32) as u8) & layout.max_reg(),
                    ra: ((r >> 38) as u8) & layout.max_reg(),
                    rb: ((r >> 44) as u8) & layout.max_reg(),
                    imm: (next() & 0xFFFF) as u16,
                };
                let w = layout.encode(&i);
                assert_eq!(layout.decode(w).unwrap(), i, "layout {regs}");
                assert!(w < (1u64 << layout.word_bits()));
            }
        }
    }

    #[test]
    fn decode_rejects_bad_fields() {
        let layout = WordLayout::for_regs(16);
        // Unallocated opcode 63.
        let w = 63u64 << layout.opcode_off();
        assert_eq!(layout.decode(w), Err(DecodeError::BadOpcode(63)));
        // Undefined width coding.
        let w = 0b1100u64 << layout.tctrl_off();
        assert_eq!(layout.decode(w), Err(DecodeError::UndefinedWidth));
        // Reserved TYPE.
        let w = 0b11u64 << layout.ttype_off();
        assert_eq!(layout.decode(w), Err(DecodeError::BadType(3)));
    }

    #[test]
    fn register_out_of_range_panics() {
        let layout = WordLayout::for_regs(16);
        let mut i = Instr::new(Opcode::Add);
        i.rd = 16; // needs 5 bits, layout has 4
        assert!(std::panic::catch_unwind(|| layout.encode(&i)).is_err());
    }

    #[test]
    fn if_condition_code_in_imm() {
        let layout = WordLayout::for_regs(32);
        let mut i = Instr::new(Opcode::If);
        i.ttype = TType::Int;
        i.ra = 1;
        i.rb = 2;
        i.imm = CondCode::Le.bits() as u16;
        let d = layout.decode(layout.encode(&i)).unwrap();
        assert_eq!(d.cond(), Some(CondCode::Le));
        // Non-IF instructions have no condition.
        assert_eq!(Instr::new(Opcode::Add).cond(), None);
    }

    #[test]
    fn imm_signedness_helpers() {
        let mut i = Instr::new(Opcode::Ldi);
        i.imm = (-5i16) as u16;
        assert_eq!(i.imm_i(), -5);
        assert_eq!(i.imm_u(), 0xFFFB);
    }

    #[test]
    fn disasm_formats() {
        let mut i = Instr::new(Opcode::FAdd);
        i.ttype = TType::Fp32;
        i.rd = 2;
        i.ra = 1;
        i.rb = 0;
        assert_eq!(i.disasm(), "fadd r2, r1, r0");

        let mut l = Instr::new(Opcode::Lod);
        l.rd = 4;
        l.ra = 2;
        l.imm = 16;
        assert_eq!(l.disasm(), "lod r4, (r2)+16");

        let mut m = Instr::new(Opcode::Max);
        m.ttype = TType::Uint;
        assert_eq!(m.disasm(), "max.u32 r0, r0, r0");

        let mut s = Instr::new(Opcode::Sto);
        s.tc = ThreadCtrl::MCU;
        s.rd = 1;
        s.ra = 0;
        assert_eq!(s.disasm(), "[w1,d0] sto r1, (r0)+0");
    }
}
