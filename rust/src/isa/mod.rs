//! The eGPU instruction-set architecture (paper §4, Table 2, Figure 3).
//!
//! The ISA is *statically scalable*: the instruction-word width depends on
//! the configured registers-per-thread (40/43/46 bits for 16/32/64
//! registers), and the available instruction subset is a configuration
//! parameter (`sim::config::EgpuConfig`). Every encode/decode detail lives
//! here; the assembler (`asm`) and the simulator (`sim`) share it.

pub mod opcode;
pub mod thread_ctrl;
pub mod ttype;
pub mod word;

pub use opcode::{Group, Opcode};
pub use thread_ctrl::{DepthSel, ThreadCtrl, WidthSel};
pub use ttype::{CondCode, TType};
pub use word::{EncodedWord, Instr, WordLayout};

/// Wavefront width: 16 scalar processors per SM, fixed by the architecture.
pub const WAVEFRONT_WIDTH: usize = 16;

/// Immediate field width (Figure 3).
pub const IMM_BITS: u32 = 16;

/// Opcode field width.
pub const OPCODE_BITS: u32 = 6;

/// TYPE (number representation) field width.
pub const TTYPE_BITS: u32 = 2;

/// Dynamic thread-space control field width (Table 3).
pub const TCTRL_BITS: u32 = 4;

/// Total instructions in the full ISA as the paper counts them (§4):
/// 43 unconditional + 18 conditional cases (6 cc × 3 TYPEs) = 61.
pub const ISA_INSTRUCTION_COUNT: usize = 61;
