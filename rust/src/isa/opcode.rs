//! Opcodes and instruction groups (paper Table 2).

use std::fmt;

/// Instruction group, used for configuration gating (which groups a given
/// eGPU instance implements), for the Figure 6 instruction-mix profiles,
/// and for issue-cost classification in the cycle model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Group {
    /// NOP — issued to fill hazard windows (pipeline has no interlocks).
    Nop,
    /// Integer arithmetic: ADD/SUB/NEG/ABS.
    IntArith,
    /// Integer multiply: MUL16LO/HI, MUL24LO/HI (DSP-block assisted).
    IntMul,
    /// Integer logic: AND/OR/XOR/NOT/CNOT/BVS.
    IntLogic,
    /// Integer shift: SHL/SHR.
    IntShift,
    /// Integer other: POP/MAX/MIN.
    IntOther,
    /// FP32 ALU: ADD/SUB/NEG/ABS/MUL/MAX/MIN (inside the DSP blocks).
    FpAlu,
    /// Shared-memory access: LOD/STO.
    Memory,
    /// Immediate load.
    Immediate,
    /// Thread-ID reads.
    Thread,
    /// Extension cores: DOT/SUM/INVSQR.
    Extension,
    /// Sequencer control: JMP/JSR/RTS/LOOP/INIT/STOP.
    Control,
    /// Predicate ops: IF/ELSE/ENDIF.
    Conditional,
}

impl Group {
    /// All groups, in Figure 6 presentation order.
    pub const ALL: [Group; 13] = [
        Group::Nop,
        Group::IntArith,
        Group::IntMul,
        Group::IntLogic,
        Group::IntShift,
        Group::IntOther,
        Group::FpAlu,
        Group::Memory,
        Group::Immediate,
        Group::Thread,
        Group::Extension,
        Group::Control,
        Group::Conditional,
    ];

    /// Position of this group in [`Group::ALL`] — the profiler's slot
    /// order. O(1) so the per-instruction profile charge in the
    /// simulator's hot loop never searches.
    pub const fn index(self) -> usize {
        match self {
            Group::Nop => 0,
            Group::IntArith => 1,
            Group::IntMul => 2,
            Group::IntLogic => 3,
            Group::IntShift => 4,
            Group::IntOther => 5,
            Group::FpAlu => 6,
            Group::Memory => 7,
            Group::Immediate => 8,
            Group::Thread => 9,
            Group::Extension => 10,
            Group::Control => 11,
            Group::Conditional => 12,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Group::Nop => "NOP",
            Group::IntArith => "INT arith",
            Group::IntMul => "INT mul",
            Group::IntLogic => "INT logic",
            Group::IntShift => "INT shift",
            Group::IntOther => "INT other",
            Group::FpAlu => "FP",
            Group::Memory => "Memory",
            Group::Immediate => "Immediate",
            Group::Thread => "Thread",
            Group::Extension => "Extension",
            Group::Control => "Branch/Ctrl",
            Group::Conditional => "Predicate",
        }
    }
}

/// The 6-bit opcode field values. Discriminants are the encoded field.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Opcode {
    Nop = 0,
    // Integer arithmetic
    Add = 1,
    Sub = 2,
    Neg = 3,
    Abs = 4,
    // Integer multiply
    Mul16Lo = 5,
    Mul16Hi = 6,
    Mul24Lo = 7,
    Mul24Hi = 8,
    // Integer logic
    And = 9,
    Or = 10,
    Xor = 11,
    Not = 12,
    CNot = 13,
    Bvs = 14,
    // Integer shift
    Shl = 15,
    Shr = 16,
    // Integer other
    Pop = 17,
    Max = 18,
    Min = 19,
    // FP32 ALU
    FAdd = 20,
    FSub = 21,
    FNeg = 22,
    FAbs = 23,
    FMul = 24,
    FMax = 25,
    FMin = 26,
    // Memory
    Lod = 27,
    Sto = 28,
    // Immediate
    Ldi = 29,
    // Thread IDs
    TdX = 30,
    TdY = 31,
    // Extensions
    Dot = 32,
    Sum = 33,
    InvSqr = 34,
    // Control
    Jmp = 35,
    Jsr = 36,
    Rts = 37,
    Loop = 38,
    Init = 39,
    Stop = 40,
    // Conditional (predicates)
    If = 41,
    Else = 42,
    EndIf = 43,
}

impl Opcode {
    pub const COUNT: usize = 44;

    /// Decode the 6-bit opcode field. `None` for unallocated encodings.
    pub fn from_bits(bits: u8) -> Option<Opcode> {
        if (bits as usize) < Self::COUNT {
            // SAFETY-free table: match is exhaustive over the valid range.
            Some(Self::TABLE[bits as usize])
        } else {
            None
        }
    }

    const TABLE: [Opcode; Self::COUNT] = [
        Opcode::Nop,
        Opcode::Add,
        Opcode::Sub,
        Opcode::Neg,
        Opcode::Abs,
        Opcode::Mul16Lo,
        Opcode::Mul16Hi,
        Opcode::Mul24Lo,
        Opcode::Mul24Hi,
        Opcode::And,
        Opcode::Or,
        Opcode::Xor,
        Opcode::Not,
        Opcode::CNot,
        Opcode::Bvs,
        Opcode::Shl,
        Opcode::Shr,
        Opcode::Pop,
        Opcode::Max,
        Opcode::Min,
        Opcode::FAdd,
        Opcode::FSub,
        Opcode::FNeg,
        Opcode::FAbs,
        Opcode::FMul,
        Opcode::FMax,
        Opcode::FMin,
        Opcode::Lod,
        Opcode::Sto,
        Opcode::Ldi,
        Opcode::TdX,
        Opcode::TdY,
        Opcode::Dot,
        Opcode::Sum,
        Opcode::InvSqr,
        Opcode::Jmp,
        Opcode::Jsr,
        Opcode::Rts,
        Opcode::Loop,
        Opcode::Init,
        Opcode::Stop,
        Opcode::If,
        Opcode::Else,
        Opcode::EndIf,
    ];

    pub fn bits(self) -> u8 {
        self as u8
    }

    pub fn group(self) -> Group {
        use Opcode::*;
        match self {
            Nop => Group::Nop,
            Add | Sub | Neg | Abs => Group::IntArith,
            Mul16Lo | Mul16Hi | Mul24Lo | Mul24Hi => Group::IntMul,
            And | Or | Xor | Not | CNot | Bvs => Group::IntLogic,
            Shl | Shr => Group::IntShift,
            Pop | Max | Min => Group::IntOther,
            FAdd | FSub | FNeg | FAbs | FMul | FMax | FMin => Group::FpAlu,
            Lod | Sto => Group::Memory,
            Ldi => Group::Immediate,
            TdX | TdY => Group::Thread,
            Dot | Sum | InvSqr => Group::Extension,
            Jmp | Jsr | Rts | Loop | Init | Stop => Group::Control,
            If | Else | EndIf => Group::Conditional,
        }
    }

    /// Assembly mnemonic (lower-case, without the `.TYPE` suffix).
    pub fn mnemonic(self) -> &'static str {
        use Opcode::*;
        match self {
            Nop => "nop",
            Add => "add",
            Sub => "sub",
            Neg => "neg",
            Abs => "abs",
            Mul16Lo => "mul16lo",
            Mul16Hi => "mul16hi",
            Mul24Lo => "mul24lo",
            Mul24Hi => "mul24hi",
            And => "and",
            Or => "or",
            Xor => "xor",
            Not => "not",
            CNot => "cnot",
            Bvs => "bvs",
            Shl => "shl",
            Shr => "shr",
            Pop => "pop",
            Max => "max",
            Min => "min",
            FAdd => "fadd",
            FSub => "fsub",
            FNeg => "fneg",
            FAbs => "fabs",
            FMul => "fmul",
            FMax => "fmax",
            FMin => "fmin",
            Lod => "lod",
            Sto => "sto",
            Ldi => "ldi",
            TdX => "tdx",
            TdY => "tdy",
            Dot => "dot",
            Sum => "sum",
            InvSqr => "invsqr",
            Jmp => "jmp",
            Jsr => "jsr",
            Rts => "rts",
            Loop => "loop",
            Init => "init",
            Stop => "stop",
            If => "if",
            Else => "else",
            EndIf => "endif",
        }
    }

    /// Parse a mnemonic (without `.TYPE`/`.cc` suffixes).
    pub fn from_mnemonic(s: &str) -> Option<Opcode> {
        Self::TABLE.iter().copied().find(|op| op.mnemonic() == s)
    }

    /// Operand shape of this opcode, used by the assembler/disassembler.
    pub fn operands(self) -> OperandShape {
        use Opcode::*;
        match self {
            Nop | Rts | Else | EndIf | Stop => OperandShape::None,
            Neg | Abs | Not | CNot | Bvs | Pop | FNeg | FAbs | InvSqr => {
                OperandShape::RdRa
            }
            Add | Sub | Mul16Lo | Mul16Hi | Mul24Lo | Mul24Hi | And | Or
            | Xor | Shl | Shr | Max | Min | FAdd | FSub | FMul | FMax
            | FMin | Dot | Sum => OperandShape::RdRaRb,
            Lod | Sto => OperandShape::RdMem,
            Ldi => OperandShape::RdImm,
            TdX | TdY => OperandShape::Rd,
            Jmp | Jsr | Loop => OperandShape::Addr,
            Init => OperandShape::Imm,
            If => OperandShape::RaRb,
        }
    }

    /// Does this opcode accept a `.TYPE` suffix in assembly?
    pub fn is_typed(self) -> bool {
        use Opcode::*;
        matches!(
            self,
            Add | Sub
                | Neg
                | Abs
                | Mul16Lo
                | Mul16Hi
                | Mul24Lo
                | Mul24Hi
                | Shl
                | Shr
                | Max
                | Min
                | If
        )
    }

    /// Does this opcode write a destination register?
    pub fn writes_rd(self) -> bool {
        !matches!(
            self.operands(),
            OperandShape::None | OperandShape::Addr | OperandShape::Imm | OperandShape::RaRb
        ) && self != Opcode::Sto
    }
}

/// Operand shape classes for assembly parsing and disassembly printing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OperandShape {
    /// No operands (NOP, RTS, ELSE, ENDIF, STOP).
    None,
    /// `rd` only (TDX/TDY).
    Rd,
    /// `rd, ra`.
    RdRa,
    /// `rd, ra, rb`.
    RdRaRb,
    /// `ra, rb` (IF compares).
    RaRb,
    /// `rd, (ra)+imm` (LOD/STO).
    RdMem,
    /// `rd, #imm` (LDI).
    RdImm,
    /// `#imm` (INIT).
    Imm,
    /// code address (JMP/JSR/LOOP).
    Addr,
}

impl fmt::Display for Opcode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.mnemonic())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_opcodes() {
        for bits in 0..Opcode::COUNT as u8 {
            let op = Opcode::from_bits(bits).unwrap();
            assert_eq!(op.bits(), bits);
            assert_eq!(Opcode::from_mnemonic(op.mnemonic()), Some(op));
        }
    }

    #[test]
    fn invalid_bits_rejected() {
        assert_eq!(Opcode::from_bits(44), None);
        assert_eq!(Opcode::from_bits(63), None);
    }

    #[test]
    fn groups_cover_table2() {
        use Opcode::*;
        assert_eq!(Add.group(), Group::IntArith);
        assert_eq!(Mul24Hi.group(), Group::IntMul);
        assert_eq!(Bvs.group(), Group::IntLogic);
        assert_eq!(Shr.group(), Group::IntShift);
        assert_eq!(Pop.group(), Group::IntOther);
        assert_eq!(FMin.group(), Group::FpAlu);
        assert_eq!(Lod.group(), Group::Memory);
        assert_eq!(Ldi.group(), Group::Immediate);
        assert_eq!(TdY.group(), Group::Thread);
        assert_eq!(InvSqr.group(), Group::Extension);
        assert_eq!(Stop.group(), Group::Control);
        assert_eq!(EndIf.group(), Group::Conditional);
    }

    #[test]
    fn group_index_matches_all_order() {
        for (i, g) in Group::ALL.iter().enumerate() {
            assert_eq!(g.index(), i, "{g:?}");
        }
    }

    #[test]
    fn isa_count_matches_paper() {
        // §4: "a total of 61 instructions, including 18 conditional cases".
        // Table 2 lists 40 operations; MAX, MIN and SHR each have distinct
        // signed/unsigned semantics (TYPE variants) => 43 unconditional;
        // IF.cc expands to 6 condition codes × 3 TYPEs = 18 conditionals.
        let table2_rows = 40usize;
        let type_variants = 3; // MAX, MIN, SHR signed/unsigned
        let conditional_cases = 6 * 3;
        assert_eq!(
            table2_rows + type_variants + conditional_cases,
            crate::isa::ISA_INSTRUCTION_COUNT
        );
    }

    #[test]
    fn operand_shapes() {
        assert_eq!(Opcode::Lod.operands(), OperandShape::RdMem);
        assert_eq!(Opcode::If.operands(), OperandShape::RaRb);
        assert_eq!(Opcode::Init.operands(), OperandShape::Imm);
        assert!(Opcode::Add.writes_rd());
        assert!(!Opcode::Sto.writes_rd());
        assert!(!Opcode::Jmp.writes_rd());
        assert!(Opcode::Lod.writes_rd());
    }
}
