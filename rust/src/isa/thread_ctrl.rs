//! Dynamic thread-space control: the upper 4-bit instruction field
//! (paper §3.1, Table 3).
//!
//! Width selects a subset of the 16 SPs; depth selects a subset of the
//! wavefronts. Together they let one instruction run as a full SIMT op, a
//! multi-threaded-CPU op (width 1) or a single-thread MCU op (width 1,
//! depth = wavefront 0 only) — with no dead cycles between changes.

use std::fmt;

use super::WAVEFRONT_WIDTH;

/// Wavefront width selector (Table 3, bits [4:3]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
#[repr(u8)]
pub enum WidthSel {
    /// All 16 SPs.
    #[default]
    All16 = 0b00,
    /// First 4 SPs (1/4 width).
    Quarter4 = 0b01,
    /// SP0 only.
    Sp0 = 0b10,
    // 0b11 is architecturally undefined (Table 3) and rejected at decode.
}

impl WidthSel {
    pub fn from_bits(bits: u8) -> Option<WidthSel> {
        match bits & 0b11 {
            0b00 => Some(WidthSel::All16),
            0b01 => Some(WidthSel::Quarter4),
            0b10 => Some(WidthSel::Sp0),
            _ => None, // "11" undefined
        }
    }

    pub fn bits(self) -> u8 {
        self as u8
    }

    /// Number of active SPs (lanes) this selector enables.
    pub fn lanes(self) -> usize {
        match self {
            WidthSel::All16 => WAVEFRONT_WIDTH,
            WidthSel::Quarter4 => WAVEFRONT_WIDTH / 4,
            WidthSel::Sp0 => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            WidthSel::All16 => "w16",
            WidthSel::Quarter4 => "w4",
            WidthSel::Sp0 => "w1",
        }
    }

    pub fn from_name(s: &str) -> Option<WidthSel> {
        match s {
            "w16" | "wall" => Some(WidthSel::All16),
            "w4" => Some(WidthSel::Quarter4),
            "w1" | "wsp0" => Some(WidthSel::Sp0),
            _ => None,
        }
    }
}

/// Wavefront depth selector (Table 3, bits [2:1]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
#[repr(u8)]
pub enum DepthSel {
    /// Wavefront 0 only (one wavefront).
    Wave0 = 0b00,
    /// All initialized wavefronts.
    #[default]
    All = 0b01,
    /// First half of the wavefronts.
    Half = 0b10,
    /// First quarter of the wavefronts.
    Quarter = 0b11,
}

impl DepthSel {
    pub fn from_bits(bits: u8) -> DepthSel {
        match bits & 0b11 {
            0b00 => DepthSel::Wave0,
            0b01 => DepthSel::All,
            0b10 => DepthSel::Half,
            _ => DepthSel::Quarter,
        }
    }

    pub fn bits(self) -> u8 {
        self as u8
    }

    /// Number of active wavefronts out of `total` initialized wavefronts.
    ///
    /// Always at least 1: even a 1-wavefront machine runs wavefront 0 for
    /// the Half/Quarter selectors (the subset is a prefix of the space).
    pub fn waves(self, total: usize) -> usize {
        debug_assert!(total >= 1);
        match self {
            DepthSel::Wave0 => 1,
            DepthSel::All => total,
            DepthSel::Half => (total / 2).max(1),
            DepthSel::Quarter => (total / 4).max(1),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DepthSel::Wave0 => "d0",
            DepthSel::All => "dall",
            DepthSel::Half => "dhalf",
            DepthSel::Quarter => "dquart",
        }
    }

    pub fn from_name(s: &str) -> Option<DepthSel> {
        match s {
            "d0" | "dwave0" => Some(DepthSel::Wave0),
            "dall" => Some(DepthSel::All),
            "dhalf" => Some(DepthSel::Half),
            "dquart" | "dquarter" => Some(DepthSel::Quarter),
            _ => None,
        }
    }
}

/// The full 4-bit thread-space control field.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct ThreadCtrl {
    pub width: WidthSel,
    pub depth: DepthSel,
}

impl ThreadCtrl {
    /// Full SIMT: all SPs, all wavefronts.
    pub const FULL: ThreadCtrl = ThreadCtrl {
        width: WidthSel::All16,
        depth: DepthSel::All,
    };

    /// Single-thread MCU personality: SP0, wavefront 0.
    pub const MCU: ThreadCtrl = ThreadCtrl {
        width: WidthSel::Sp0,
        depth: DepthSel::Wave0,
    };

    /// Multi-threaded-CPU personality: SP0, all wavefronts.
    pub const MT_CPU: ThreadCtrl = ThreadCtrl {
        width: WidthSel::Sp0,
        depth: DepthSel::All,
    };

    pub fn new(width: WidthSel, depth: DepthSel) -> ThreadCtrl {
        ThreadCtrl { width, depth }
    }

    /// Encode to the 4-bit field (width in [3:2], depth in [1:0]).
    pub fn bits(self) -> u8 {
        (self.width.bits() << 2) | self.depth.bits()
    }

    /// Decode; `None` when the width coding is the undefined "11".
    pub fn from_bits(bits: u8) -> Option<ThreadCtrl> {
        Some(ThreadCtrl {
            width: WidthSel::from_bits((bits >> 2) & 0b11)?,
            depth: DepthSel::from_bits(bits & 0b11),
        })
    }

    /// Number of threads this instruction operates on, given the machine's
    /// initialized wavefront count.
    pub fn active_threads(self, total_waves: usize) -> usize {
        self.width.lanes() * self.depth.waves(total_waves)
    }

    /// Is lane `sp` of wavefront `wave` selected?
    pub fn selects(self, sp: usize, wave: usize, total_waves: usize) -> bool {
        sp < self.width.lanes() && wave < self.depth.waves(total_waves)
    }
}

impl fmt::Display for ThreadCtrl {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{},{}]", self.width.name(), self.depth.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_all_defined() {
        for w in [WidthSel::All16, WidthSel::Quarter4, WidthSel::Sp0] {
            for d in [
                DepthSel::Wave0,
                DepthSel::All,
                DepthSel::Half,
                DepthSel::Quarter,
            ] {
                let tc = ThreadCtrl::new(w, d);
                assert_eq!(ThreadCtrl::from_bits(tc.bits()), Some(tc));
            }
        }
    }

    #[test]
    fn undefined_width_rejected() {
        // width bits 0b11 is "Undefined" in Table 3.
        assert_eq!(ThreadCtrl::from_bits(0b1100), None);
        assert_eq!(ThreadCtrl::from_bits(0b1111), None);
    }

    #[test]
    fn active_thread_counts_512_thread_machine() {
        // 512 threads / 16 SPs = 32 wavefronts (paper §3.2 example).
        let total = 32;
        assert_eq!(ThreadCtrl::FULL.active_threads(total), 512);
        assert_eq!(ThreadCtrl::MCU.active_threads(total), 1);
        assert_eq!(ThreadCtrl::MT_CPU.active_threads(total), 32);
        let quarter = ThreadCtrl::new(WidthSel::Quarter4, DepthSel::All);
        assert_eq!(quarter.active_threads(total), 128);
        let narrow = ThreadCtrl::new(WidthSel::All16, DepthSel::Quarter);
        assert_eq!(narrow.active_threads(total), 128);
    }

    #[test]
    fn selection_is_prefix_of_space() {
        let tc = ThreadCtrl::new(WidthSel::Quarter4, DepthSel::Half);
        assert!(tc.selects(0, 0, 32));
        assert!(tc.selects(3, 15, 32));
        assert!(!tc.selects(4, 0, 32)); // SP4 outside quarter width
        assert!(!tc.selects(0, 16, 32)); // wave 16 outside half depth
    }

    #[test]
    fn depth_min_one_wave() {
        assert_eq!(DepthSel::Quarter.waves(2), 1);
        assert_eq!(DepthSel::Half.waves(1), 1);
    }

    #[test]
    fn mcu_is_single_thread() {
        assert!(ThreadCtrl::MCU.selects(0, 0, 32));
        assert!(!ThreadCtrl::MCU.selects(1, 0, 32));
        assert!(!ThreadCtrl::MCU.selects(0, 1, 32));
    }
}
