//! Chrome trace-event JSON export (hand-rolled — serde is not in the
//! dependency tree, same discipline as `sim::config_json`).
//!
//! Mapping from [`TraceEvent`]s to the Trace Event Format:
//!
//! - `ts` is the **modeled bus cycle**, emitted as an integer. Chrome
//!   renders it as microseconds; since the bus runs in the hundreds of
//!   MHz the scale reads naturally as "cycles", and what matters is
//!   that the axis is modeled time, not wall clock.
//! - `pid` is always 1 ("egpu fleet"). `tid 0` is the runtime track
//!   (sheds, cache/superplan/reuse instants); `tid core+1` is that
//!   core's occupancy track.
//! - A [`PoolLoan`]/[`PoolReclaim`] pair becomes one complete `"X"`
//!   slice on the core's track, named after the kernel. Cores execute
//!   their jobs serially in modeled time, so loans pair FIFO per core.
//! - A request's lifecycle becomes an async span (`cat:"request"`,
//!   `id` = request id): `"b"` at `Admitted`, `"n"` instants at
//!   `Batched`/`Dispatched`, a nested `"b"`/`"e"` `exec` span from
//!   `ExecStart` to `ExecEnd`, and `"e"` at `Retired` — or at
//!   `Shed` when an admitted request later expires.
//! - Sheds and runtime counter deltas also land as `"i"` instants on
//!   the runtime track so they are visible without expanding spans.
//!
//! Events are rendered in `(cycle, seq)` order — the recorder's
//! deterministic total order — so the exported bytes are identical
//! across sequential and parallel serving and across reruns.
//!
//! [`TraceEvent`]: super::TraceEvent
//! [`PoolLoan`]: super::EventKind::PoolLoan
//! [`PoolReclaim`]: super::EventKind::PoolReclaim

use std::collections::{BTreeSet, HashMap, VecDeque};
use std::fmt::Write as _;

use super::recorder::{EventKind, TraceEvent};

/// JSON string literal with the minimal escapes the trace surface can
/// produce (kernel names and reason labels are ASCII, but stay safe).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Render `events` (already in `(cycle, seq)` order — the recorder's
/// [`events()`](super::Recorder::events) contract) as a Chrome
/// trace-event JSON document.
pub fn chrome_trace(events: &[TraceEvent]) -> String {
    // Pass 1: pair core loans to reclaims (FIFO per core) so "X"
    // slices know their duration, and collect the admitted set so a
    // shed closes its span only if one was opened.
    let mut open: HashMap<usize, VecDeque<(usize, u64)>> = HashMap::new();
    let mut durs: HashMap<usize, u64> = HashMap::new();
    let mut cores: BTreeSet<usize> = BTreeSet::new();
    let mut admitted: BTreeSet<usize> = BTreeSet::new();
    for (i, e) in events.iter().enumerate() {
        match &e.kind {
            EventKind::PoolLoan { core, .. } => {
                cores.insert(*core);
                open.entry(*core).or_default().push_back((i, e.cycle));
            }
            EventKind::PoolReclaim { core, .. } => {
                cores.insert(*core);
                if let Some((loan, at)) = open.entry(*core).or_default().pop_front() {
                    durs.insert(loan, e.cycle.saturating_sub(at));
                }
            }
            EventKind::Admitted { req } => {
                admitted.insert(*req);
            }
            EventKind::Dispatched { core, .. }
            | EventKind::ExecStart { core, .. }
            | EventKind::ExecEnd { core, .. }
            | EventKind::Retired { core, .. } => {
                cores.insert(*core);
            }
            _ => {}
        }
    }

    let mut out = String::new();
    out.push_str("{\"traceEvents\":[\n");
    // Track-name metadata first (ts-less M events).
    let mut lines: Vec<String> = Vec::new();
    lines.push(
        "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":1,\"args\":{\"name\":\"egpu fleet\"}}"
            .to_string(),
    );
    lines.push(
        "{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":0,\
         \"args\":{\"name\":\"runtime\"}}"
            .to_string(),
    );
    for core in &cores {
        lines.push(format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{},\
             \"args\":{{\"name\":{}}}}}",
            core + 1,
            json_str(&format!("core {core}"))
        ));
    }

    // Pass 2: one line per event, in the deterministic event order.
    for (i, e) in events.iter().enumerate() {
        let ts = e.cycle;
        match &e.kind {
            EventKind::Admitted { req } => lines.push(format!(
                "{{\"name\":\"request\",\"cat\":\"request\",\"ph\":\"b\",\
                 \"id\":{req},\"pid\":1,\"tid\":0,\"ts\":{ts}}}"
            )),
            EventKind::Shed { req, reason } => {
                if admitted.contains(req) {
                    lines.push(format!(
                        "{{\"name\":\"request\",\"cat\":\"request\",\"ph\":\"e\",\
                         \"id\":{req},\"pid\":1,\"tid\":0,\"ts\":{ts},\
                         \"args\":{{\"shed\":{}}}}}",
                        json_str(reason)
                    ));
                }
                lines.push(format!(
                    "{{\"name\":\"shed\",\"ph\":\"i\",\"s\":\"t\",\"pid\":1,\
                     \"tid\":0,\"ts\":{ts},\"args\":{{\"req\":{req},\
                     \"reason\":{}}}}}",
                    json_str(reason)
                ));
            }
            EventKind::Batched { req, window } => lines.push(format!(
                "{{\"name\":\"batched\",\"cat\":\"request\",\"ph\":\"n\",\
                 \"id\":{req},\"pid\":1,\"tid\":0,\"ts\":{ts},\
                 \"args\":{{\"window\":{window}}}}}"
            )),
            EventKind::Dispatched { req, core } => lines.push(format!(
                "{{\"name\":\"dispatched\",\"cat\":\"request\",\"ph\":\"n\",\
                 \"id\":{req},\"pid\":1,\"tid\":0,\"ts\":{ts},\
                 \"args\":{{\"core\":{core}}}}}"
            )),
            EventKind::ExecStart { req, core, name } => lines.push(format!(
                "{{\"name\":\"exec\",\"cat\":\"request\",\"ph\":\"b\",\
                 \"id\":{req},\"pid\":1,\"tid\":{},\"ts\":{ts},\
                 \"args\":{{\"kernel\":{}}}}}",
                core + 1,
                json_str(name)
            )),
            EventKind::ExecEnd {
                req,
                core,
                cycles,
                instructions,
            } => lines.push(format!(
                "{{\"name\":\"exec\",\"cat\":\"request\",\"ph\":\"e\",\
                 \"id\":{req},\"pid\":1,\"tid\":{},\"ts\":{ts},\
                 \"args\":{{\"cycles\":{cycles},\"instructions\":{instructions}}}}}",
                core + 1
            )),
            EventKind::Retired { req, core } => lines.push(format!(
                "{{\"name\":\"request\",\"cat\":\"request\",\"ph\":\"e\",\
                 \"id\":{req},\"pid\":1,\"tid\":0,\"ts\":{ts},\
                 \"args\":{{\"core\":{core}}}}}"
            )),
            EventKind::PoolLoan { core, job, name } => {
                let dur = durs.get(&i).copied().unwrap_or(0);
                lines.push(format!(
                    "{{\"name\":{},\"cat\":\"core\",\"ph\":\"X\",\"pid\":1,\
                     \"tid\":{},\"ts\":{ts},\"dur\":{dur},\
                     \"args\":{{\"job\":{job}}}}}",
                    json_str(name),
                    core + 1
                ));
            }
            // Reclaims are consumed by the matching loan's "X" slice.
            EventKind::PoolReclaim { .. } => {}
            EventKind::KernelCompiles { n }
            | EventKind::KernelCacheHits { n }
            | EventKind::MachineReuses { n }
            | EventKind::MachineReloads { n }
            | EventKind::SuperplanCompiles { n }
            | EventKind::SuperplanHits { n }
            | EventKind::PoolRevives { n } => lines.push(format!(
                "{{\"name\":{},\"ph\":\"i\",\"s\":\"t\",\"pid\":1,\"tid\":0,\
                 \"ts\":{ts},\"args\":{{\"n\":{n}}}}}",
                json_str(e.kind.label())
            )),
        }
    }

    out.push_str(&lines.join(",\n"));
    out.push_str("\n]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(cycle: u64, seq: u64, kind: EventKind) -> TraceEvent {
        TraceEvent { cycle, seq, kind }
    }

    #[test]
    fn loan_reclaim_pairs_become_complete_slices() {
        let events = vec![
            ev(
                10,
                0,
                EventKind::PoolLoan {
                    core: 0,
                    job: 0,
                    name: "saxpy".into(),
                },
            ),
            ev(90, 1, EventKind::PoolReclaim { core: 0, job: 0 }),
        ];
        let json = chrome_trace(&events);
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"dur\":80"));
        assert!(json.contains("\"name\":\"saxpy\""));
        assert!(json.contains("\"name\":\"core 0\""));
    }

    #[test]
    fn shed_without_admission_emits_only_the_instant() {
        let events = vec![ev(
            5,
            0,
            EventKind::Shed {
                req: 3,
                reason: "queue_full",
            },
        )];
        let json = chrome_trace(&events);
        assert!(json.contains("\"ph\":\"i\""));
        assert!(!json.contains("\"ph\":\"e\""));
    }

    #[test]
    fn admitted_then_shed_closes_the_span() {
        let events = vec![
            ev(5, 0, EventKind::Admitted { req: 3 }),
            ev(
                50,
                1,
                EventKind::Shed {
                    req: 3,
                    reason: "deadline_expired",
                },
            ),
        ];
        let json = chrome_trace(&events);
        assert!(json.contains("\"ph\":\"b\""));
        assert!(json.contains("\"ph\":\"e\""));
        assert!(json.contains("\"shed\":\"deadline_expired\""));
    }

    #[test]
    fn output_is_a_pure_function_of_events() {
        let events = vec![
            ev(1, 0, EventKind::Admitted { req: 0 }),
            ev(2, 1, EventKind::KernelCompiles { n: 2 }),
            ev(9, 2, EventKind::Retired { req: 0, core: 1 }),
        ];
        assert_eq!(chrome_trace(&events), chrome_trace(&events));
        assert!(chrome_trace(&events).starts_with("{\"traceEvents\":[\n"));
        assert!(chrome_trace(&events).ends_with("\n]}\n"));
    }
}
