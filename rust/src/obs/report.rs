//! Per-core occupancy/gap text summary (`egpu serve --report`).
//!
//! Built purely from the recorded [`PoolLoan`]/[`PoolReclaim`] core
//! occupancy spans, so it reflects modeled time exactly and is
//! identical across sequential and parallel serving. The horizon is
//! the last recorded event cycle; a "gap" is idle modeled time on a
//! core between consecutive jobs (the dispatch/bus/batching slack the
//! paper's §7 profiles make visible).
//!
//! [`PoolLoan`]: super::EventKind::PoolLoan
//! [`PoolReclaim`]: super::EventKind::PoolReclaim

use std::fmt::Write as _;

use super::recorder::{EventKind, TraceEvent};

#[derive(Debug, Clone, Copy, Default)]
struct CoreOcc {
    busy: u64,
    jobs: u64,
    gaps: u64,
    largest_gap: u64,
    first_start: Option<u64>,
    last_end: u64,
    open_at: Option<u64>,
}

/// Render the per-core occupancy summary over `events` (in
/// `(cycle, seq)` order) for a fleet of `num_cores` cores. Cores that
/// never ran a job still get a line (100% idle), so the report shape
/// depends only on the fleet, not the workload.
pub fn occupancy_report(events: &[TraceEvent], num_cores: usize) -> String {
    let mut cores = vec![CoreOcc::default(); num_cores];
    let mut horizon = 0u64;
    for e in events {
        horizon = horizon.max(e.cycle);
        match &e.kind {
            EventKind::PoolLoan { core, .. } if *core < num_cores => {
                let c = &mut cores[*core];
                if c.first_start.is_none() {
                    c.first_start = Some(e.cycle);
                } else if e.cycle > c.last_end {
                    c.gaps += 1;
                    c.largest_gap = c.largest_gap.max(e.cycle - c.last_end);
                }
                c.open_at = Some(e.cycle);
            }
            EventKind::PoolReclaim { core, .. } if *core < num_cores => {
                let c = &mut cores[*core];
                if let Some(at) = c.open_at.take() {
                    c.busy += e.cycle.saturating_sub(at);
                    c.jobs += 1;
                    c.last_end = e.cycle;
                }
            }
            _ => {}
        }
    }

    let mut out = String::new();
    let _ = writeln!(out, "occupancy over {horizon} bus cycles:");
    let _ = writeln!(
        out,
        "  {:<6} {:>6} {:>12} {:>6} {:>6} {:>12}",
        "core", "jobs", "busy cyc", "busy%", "gaps", "largest gap"
    );
    let mut total_busy = 0u64;
    let mut total_jobs = 0u64;
    for (i, c) in cores.iter().enumerate() {
        let pct = if horizon == 0 {
            0.0
        } else {
            100.0 * c.busy as f64 / horizon as f64
        };
        let _ = writeln!(
            out,
            "  {:<6} {:>6} {:>12} {:>5.1}% {:>6} {:>12}",
            i, c.jobs, c.busy, pct, c.gaps, c.largest_gap
        );
        total_busy += c.busy;
        total_jobs += c.jobs;
    }
    let fleet_pct = if horizon == 0 || num_cores == 0 {
        0.0
    } else {
        100.0 * total_busy as f64 / (horizon.saturating_mul(num_cores as u64)) as f64
    };
    let _ = writeln!(
        out,
        "  fleet: {total_jobs} jobs, {total_busy} busy cycles, {fleet_pct:.1}% occupancy"
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(cycle: u64, seq: u64, kind: EventKind) -> TraceEvent {
        TraceEvent { cycle, seq, kind }
    }

    #[test]
    fn busy_cycles_and_gaps_accumulate_per_core() {
        let events = vec![
            ev(
                0,
                0,
                EventKind::PoolLoan {
                    core: 0,
                    job: 0,
                    name: "a".into(),
                },
            ),
            ev(40, 1, EventKind::PoolReclaim { core: 0, job: 0 }),
            ev(
                100,
                2,
                EventKind::PoolLoan {
                    core: 0,
                    job: 1,
                    name: "b".into(),
                },
            ),
            ev(160, 3, EventKind::PoolReclaim { core: 0, job: 1 }),
        ];
        let text = occupancy_report(&events, 2);
        assert!(text.contains("occupancy over 160 bus cycles"));
        // core 0: 2 jobs, 100 busy cycles, one 60-cycle gap.
        assert!(text.contains("100"));
        assert!(text.contains("60"));
        // core 1 gets a line even though it never ran.
        assert!(text.lines().count() >= 5);
        assert!(text.contains("fleet: 2 jobs, 100 busy cycles"));
    }

    #[test]
    fn empty_trace_reports_zero_horizon() {
        let text = occupancy_report(&[], 1);
        assert!(text.contains("occupancy over 0 bus cycles"));
    }
}
