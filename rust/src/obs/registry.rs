//! Named integer metrics and the unified runtime stats snapshot.
//!
//! [`MetricsRegistry`] is the consolidation point for the counters the
//! runtime used to surface through five bespoke getter chains
//! (`cache_stats` / `reuse_stats` / `superplan_stats` /
//! `superplan_activity` / `pool_spawns`): integer counters, gauges,
//! and log₂ histograms keyed by dotted snake_case names, stored in
//! `BTreeMap`s so iteration (and the rendered text report) is
//! deterministic.
//!
//! [`StatsSnapshot`] is the one struct that crosses layers: the
//! `Coordinator` builds it from its internals, `GpuArray`/`Server`
//! re-expose it verbatim, and `Gpu` fills in the single-core subset.
//! The legacy getters survive as thin delegates into it.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::coordinator::ReuseStats;
use crate::kernels::CacheStats;
use crate::serve::Histogram;
use crate::sim::{SuperplanActivity, SuperplanCacheStats};

/// Every runtime cache/reuse/pool counter in one place. `Eq` + `Copy`
/// so tests can pin "recording changed nothing" with a single
/// comparison, and so delta accounting (`after - before` around a
/// dispatch batch) is a plain field-wise subtraction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StatsSnapshot {
    /// Kernel specialization cache (compile-once property).
    pub cache: CacheStats,
    /// Resident-machine reuse across dispatches.
    pub reuse: ReuseStats,
    /// Fleet-shared superplan cache.
    pub superplan: SuperplanCacheStats,
    /// Per-machine superplan rebuild/fast-skip activity, summed.
    pub superplan_activity: SuperplanActivity,
    /// Worker pools spawned (0 sequential, 1 parallel — the only
    /// mode-dependent counter, which is why it lives here and never
    /// in the event trace).
    pub pool_spawns: u64,
    /// Pool workers revived after a panic (0 in normal operation).
    pub pool_revives: u64,
}

impl StatsSnapshot {
    /// Publish the snapshot into `registry` as gauges (current-value
    /// semantics: snapshots are cumulative already).
    pub fn export_into(&self, registry: &mut MetricsRegistry) {
        registry.set_gauge("cache.kernel.compiles", self.cache.compiles);
        registry.set_gauge("cache.kernel.hits", self.cache.hits);
        registry.set_gauge("cache.kernel.entries", self.cache.entries as u64);
        registry.set_gauge("reuse.machine.hits", self.reuse.hits);
        registry.set_gauge("reuse.machine.misses", self.reuse.misses);
        registry.set_gauge("cache.superplan.compiles", self.superplan.compiles);
        registry.set_gauge("cache.superplan.hits", self.superplan.hits);
        registry.set_gauge("cache.superplan.entries", self.superplan.entries as u64);
        registry.set_gauge("superplan.rebuilds", self.superplan_activity.rebuilds);
        registry.set_gauge("superplan.fast_skips", self.superplan_activity.fast_skips);
        registry.set_gauge("pool.spawns", self.pool_spawns);
        registry.set_gauge("pool.revives", self.pool_revives);
    }
}

/// Named integer counters, gauges, and log₂ histograms.
///
/// Counters only go up (`inc`/`inc_by`); gauges are set to the latest
/// value; histograms reuse the serve layer's log₂ [`Histogram`].
/// Lookup of an unset name reads as zero / an empty histogram, so
/// callers never need to pre-register.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, u64>,
    histograms: BTreeMap<String, Histogram>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Add 1 to counter `name`.
    pub fn inc(&mut self, name: &str) {
        self.inc_by(name, 1);
    }

    /// Add `n` to counter `name` (a no-op for `n == 0` still creates
    /// the counter, so it renders as an explicit zero).
    pub fn inc_by(&mut self, name: &str, n: u64) {
        let c = self.counters.entry(name.to_string()).or_insert(0);
        *c = c.saturating_add(n);
    }

    /// Set gauge `name` to its current value.
    pub fn set_gauge(&mut self, name: &str, v: u64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Record one observation into histogram `name`.
    pub fn observe(&mut self, name: &str, v: u64) {
        self.histograms.entry(name.to_string()).or_default().record(v);
    }

    /// Counter value (0 if never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Gauge value (0 if never set).
    pub fn gauge(&self, name: &str) -> u64 {
        self.gauges.get(name).copied().unwrap_or(0)
    }

    /// Histogram by name (`None` if nothing was observed).
    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histograms.is_empty()
    }

    /// Deterministic text rendering: one `name value` line per counter
    /// and gauge (sorted by name — `BTreeMap` order), then one
    /// `name count/p50/p95/max` line per histogram.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.counters {
            let _ = writeln!(out, "{name} {v}");
        }
        for (name, v) in &self.gauges {
            let _ = writeln!(out, "{name} {v}");
        }
        for (name, h) in &self.histograms {
            let _ = writeln!(
                out,
                "{name} count={} p50={} p95={} max={}",
                h.count(),
                h.p50(),
                h.p95(),
                h.max()
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unset_names_read_as_zero() {
        let reg = MetricsRegistry::new();
        assert_eq!(reg.counter("nope"), 0);
        assert_eq!(reg.gauge("nope"), 0);
        assert!(reg.histogram("nope").is_none());
        assert!(reg.is_empty());
    }

    #[test]
    fn counters_accumulate_and_gauges_overwrite() {
        let mut reg = MetricsRegistry::new();
        reg.inc("a");
        reg.inc_by("a", 4);
        reg.set_gauge("g", 7);
        reg.set_gauge("g", 3);
        assert_eq!(reg.counter("a"), 5);
        assert_eq!(reg.gauge("g"), 3);
    }

    #[test]
    fn render_is_sorted_and_stable() {
        let mut reg = MetricsRegistry::new();
        reg.inc("serve.z");
        reg.inc("serve.a");
        reg.observe("lat", 8);
        reg.observe("lat", 100);
        let text = reg.render();
        let a = text.find("serve.a").unwrap();
        let z = text.find("serve.z").unwrap();
        assert!(a < z);
        assert!(text.contains("lat count=2"));
        assert_eq!(text, reg.clone().render());
    }

    #[test]
    fn snapshot_exports_every_surface() {
        let snap = StatsSnapshot {
            cache: CacheStats {
                compiles: 2,
                hits: 9,
                entries: 2,
            },
            reuse: ReuseStats { hits: 5, misses: 3 },
            superplan: SuperplanCacheStats {
                compiles: 1,
                hits: 4,
                entries: 1,
            },
            superplan_activity: SuperplanActivity {
                rebuilds: 5,
                fast_skips: 6,
            },
            pool_spawns: 1,
            pool_revives: 0,
        };
        let mut reg = MetricsRegistry::new();
        snap.export_into(&mut reg);
        assert_eq!(reg.gauge("cache.kernel.compiles"), 2);
        assert_eq!(reg.gauge("reuse.machine.misses"), 3);
        assert_eq!(reg.gauge("cache.superplan.hits"), 4);
        assert_eq!(reg.gauge("superplan.fast_skips"), 6);
        assert_eq!(reg.gauge("pool.spawns"), 1);
    }
}
