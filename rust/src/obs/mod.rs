//! `egpu::obs` — deterministic, integer-only observability.
//!
//! Three pieces, one discipline:
//!
//! - [`Recorder`]: typed [`TraceEvent`]s stamped in **modeled bus
//!   cycles** with a deterministic sequence key. Recording happens on
//!   the dispatching thread only, from values the model already
//!   computed, so sequential and parallel dispatch produce
//!   byte-identical event logs and enabling recording never moves a
//!   modeled cycle.
//! - [`MetricsRegistry`] + [`StatsSnapshot`]: the unified counter
//!   surface. Every runtime cache/reuse/pool counter that used to be
//!   surfaced through its own getter chain flows through one
//!   snapshot; the old getters are thin delegates.
//! - [`chrome_trace`] / [`occupancy_report`]: exports — hand-rolled
//!   Chrome trace-event JSON (`egpu serve --trace-out`) and a
//!   per-core occupancy/gap text summary (`egpu serve --report`).
//!
//! The disabled path is an `Option<&Recorder>` check: no locks, no
//! allocation, no formatting. See DESIGN.md "The observability layer".

pub mod chrome;
pub mod recorder;
pub mod registry;
pub mod report;

pub use chrome::chrome_trace;
pub use recorder::{EventKind, Recorder, TraceEvent};
pub use registry::{MetricsRegistry, StatsSnapshot};
pub use report::occupancy_report;
