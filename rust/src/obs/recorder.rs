//! The deterministic event recorder.
//!
//! A [`Recorder`] collects typed [`TraceEvent`]s stamped in **modeled
//! bus cycles** — never wall-clock time, never thread ids. Every
//! record call happens on the dispatching thread, on the deterministic
//! control path (the serve loop and the coordinator's post-batch
//! accounting), so the sequence of `record` calls — and therefore the
//! `seq` key each event receives — is a pure function of the workload.
//! That is the whole determinism story: sequential and parallel
//! dispatch make byte-identical record calls, so they produce
//! byte-identical event logs and byte-identical exported traces.
//!
//! Recording never feeds back into the model: a recorder only *reads*
//! cycles and counters that the runtime already computed. Enabling it
//! cannot move a modeled cycle (pinned by `rust/tests/obs_trace.rs`).

use std::sync::Mutex;

/// One typed observability event. Serve-layer events describe a
/// request's lifecycle (`req` is the request's index in the offered
/// workload); coordinator-layer events describe core occupancy and
/// runtime-cache activity (`job` is the submission index within its
/// dispatch batch).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EventKind {
    /// The request entered the bounded admission queue (at arrival).
    Admitted { req: usize },
    /// The request was turned away (`reason` is the
    /// [`ShedReason`](crate::serve::ShedReason) label).
    Shed { req: usize, reason: &'static str },
    /// The request won a slot in batch window `window`.
    Batched { req: usize, window: u64 },
    /// The request's batch dispatched; placement chose `core`.
    Dispatched { req: usize, core: usize },
    /// Bus acquisition: load DMA for the request began on `core`.
    ExecStart { req: usize, core: usize, name: String },
    /// Unload complete; `cycles` is kernel compute at the core's
    /// clock, `instructions` the dynamic instruction count (the
    /// run's profile headline).
    ExecEnd {
        req: usize,
        core: usize,
        cycles: u64,
        instructions: u64,
    },
    /// The request's result was returned to the caller.
    Retired { req: usize, core: usize },
    /// A core was loaned to job `job` of its batch (occupancy span
    /// open — the modeled counterpart of a pool worker taking work).
    PoolLoan { core: usize, job: usize, name: String },
    /// The core came back to the pool (occupancy span close).
    PoolReclaim { core: usize, job: usize },
    /// Kernel specializations compiled during the batch.
    KernelCompiles { n: u64 },
    /// Kernel-cache hits during the batch.
    KernelCacheHits { n: u64 },
    /// Jobs that reused their core's resident machine (skipped
    /// assembly and `load_program`).
    MachineReuses { n: u64 },
    /// Jobs that reloaded their core's machine from scratch.
    MachineReloads { n: u64 },
    /// Fused-trace superplans compiled during the batch.
    SuperplanCompiles { n: u64 },
    /// Superplan-cache hits during the batch.
    SuperplanHits { n: u64 },
    /// Worker threads revived after dying (0 in normal operation).
    PoolRevives { n: u64 },
}

impl EventKind {
    /// Stable snake_case label (registry keys, Chrome event names).
    pub fn label(&self) -> &'static str {
        match self {
            EventKind::Admitted { .. } => "admitted",
            EventKind::Shed { .. } => "shed",
            EventKind::Batched { .. } => "batched",
            EventKind::Dispatched { .. } => "dispatched",
            EventKind::ExecStart { .. } => "exec_start",
            EventKind::ExecEnd { .. } => "exec_end",
            EventKind::Retired { .. } => "retired",
            EventKind::PoolLoan { .. } => "pool_loan",
            EventKind::PoolReclaim { .. } => "pool_reclaim",
            EventKind::KernelCompiles { .. } => "kernel_compiles",
            EventKind::KernelCacheHits { .. } => "kernel_cache_hits",
            EventKind::MachineReuses { .. } => "machine_reuses",
            EventKind::MachineReloads { .. } => "machine_reloads",
            EventKind::SuperplanCompiles { .. } => "superplan_compiles",
            EventKind::SuperplanHits { .. } => "superplan_hits",
            EventKind::PoolRevives { .. } => "pool_revives",
        }
    }
}

/// An [`EventKind`] stamped with its modeled bus cycle and the
/// deterministic sequence key (record order on the dispatching
/// thread). Export ordering is `(cycle, seq)` — one total order, no
/// wall-clock tiebreaks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    /// Modeled bus cycle the event is stamped at.
    pub cycle: u64,
    /// Record-order sequence key (unique per recorder).
    pub seq: u64,
    pub kind: EventKind,
}

/// The trace sink. Shared as an `Arc` between the [`Server`], the
/// [`GpuArray`] and the [`Coordinator`] it wraps, behind an
/// `Option` — the disabled path is a `None` check, no locks, no
/// allocation.
///
/// [`Server`]: crate::serve::Server
/// [`GpuArray`]: crate::api::GpuArray
/// [`Coordinator`]: crate::coordinator::Coordinator
///
/// The mutex exists only to make sharing safe (`Arc<Recorder>` must be
/// `Sync`); by construction every record call is made from the single
/// dispatching thread, so it is never contended.
#[derive(Debug, Default)]
pub struct Recorder {
    events: Mutex<Vec<TraceEvent>>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    /// Append one event at modeled `cycle`; the sequence key is the
    /// record index.
    pub fn record(&self, cycle: u64, kind: EventKind) {
        let mut events = self.events.lock().expect("recorder lock");
        let seq = events.len() as u64;
        events.push(TraceEvent { cycle, seq, kind });
    }

    /// Events recorded so far.
    pub fn len(&self) -> usize {
        self.events.lock().expect("recorder lock").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every recorded event (sequence keys restart at 0).
    pub fn clear(&self) {
        self.events.lock().expect("recorder lock").clear();
    }

    /// Snapshot of the event log in export order: sorted by
    /// `(cycle, seq)`. The sort is needed because modeled stamps are
    /// not record-ordered — a request admitted at cycle 12 000 may be
    /// recorded after a batch that retired at cycle 50 000.
    pub fn events(&self) -> Vec<TraceEvent> {
        let mut events = self.events.lock().expect("recorder lock").clone();
        events.sort_by_key(|e| (e.cycle, e.seq));
        events
    }

    /// The event log rendered as Chrome trace-event JSON
    /// (see [`crate::obs::chrome_trace`]).
    pub fn chrome_trace(&self) -> String {
        super::chrome::chrome_trace(&self.events())
    }

    /// Per-core occupancy/gap text summary over the recorded core
    /// loans (see [`crate::obs::occupancy_report`]).
    pub fn occupancy_report(&self, num_cores: usize) -> String {
        super::report::occupancy_report(&self.events(), num_cores)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_sort_by_cycle_then_seq() {
        let rec = Recorder::new();
        rec.record(50, EventKind::Retired { req: 0, core: 1 });
        rec.record(10, EventKind::Admitted { req: 1 });
        rec.record(10, EventKind::Admitted { req: 2 });
        let ev = rec.events();
        assert_eq!(ev.len(), 3);
        assert_eq!((ev[0].cycle, ev[0].seq), (10, 1));
        assert_eq!((ev[1].cycle, ev[1].seq), (10, 2));
        assert_eq!((ev[2].cycle, ev[2].seq), (50, 0));
    }

    #[test]
    fn clear_restarts_sequence_keys() {
        let rec = Recorder::new();
        rec.record(1, EventKind::KernelCompiles { n: 2 });
        assert_eq!(rec.len(), 1);
        rec.clear();
        assert!(rec.is_empty());
        rec.record(2, EventKind::KernelCacheHits { n: 3 });
        assert_eq!(rec.events()[0].seq, 0);
    }

    #[test]
    fn labels_are_stable_snake_case() {
        assert_eq!(EventKind::Admitted { req: 0 }.label(), "admitted");
        assert_eq!(
            EventKind::ExecEnd {
                req: 0,
                core: 0,
                cycles: 1,
                instructions: 1
            }
            .label(),
            "exec_end"
        );
        assert_eq!(EventKind::SuperplanHits { n: 1 }.label(), "superplan_hits");
    }
}
