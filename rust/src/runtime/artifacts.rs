//! Artifact discovery: locate `artifacts/`, check the op-index contract,
//! and pick the right compiled depth for a machine configuration.

use std::path::{Path, PathBuf};

use crate::datapath::opmap::verify_opmap_json;

/// Depths the AOT path compiles artifacts for (python opmap.DEPTHS).
pub const ARTIFACT_DEPTHS: [usize; 2] = [32, 64];

/// The artifact set one machine configuration uses.
#[derive(Debug, Clone)]
pub struct ArtifactSet {
    pub dir: PathBuf,
    /// Compiled block depth (≥ the machine's wavefront count).
    pub depth: usize,
}

impl ArtifactSet {
    /// Resolve the artifact set for a machine with `wavefronts` depth.
    /// Verifies the op-index contract in `opmap.json`.
    pub fn resolve(dir: impl AsRef<Path>, wavefronts: usize) -> Result<ArtifactSet, String> {
        let dir = dir.as_ref().to_path_buf();
        if !dir.is_dir() {
            return Err(format!(
                "artifacts directory {} not found — run `make artifacts`",
                dir.display()
            ));
        }
        let depth = *ARTIFACT_DEPTHS
            .iter()
            .find(|&&d| d >= wavefronts)
            .ok_or_else(|| {
                format!(
                    "no artifact depth covers {wavefronts} wavefronts (max {})",
                    ARTIFACT_DEPTHS[ARTIFACT_DEPTHS.len() - 1]
                )
            })?;
        let opmap_path = dir.join("opmap.json");
        let json = std::fs::read_to_string(&opmap_path)
            .map_err(|e| format!("read {}: {e}", opmap_path.display()))?;
        verify_opmap_json(&json)?;
        for name in [
            format!("fp_alu_d{depth}"),
            format!("int_alu_d{depth}"),
            format!("dot_d{depth}"),
        ] {
            let p = dir.join(format!("{name}.hlo.txt"));
            if !p.is_file() {
                return Err(format!("missing artifact {}", p.display()));
            }
        }
        Ok(ArtifactSet { dir, depth })
    }

    pub fn fp_alu(&self) -> String {
        format!("fp_alu_d{}", self.depth)
    }

    pub fn int_alu(&self) -> String {
        format!("int_alu_d{}", self.depth)
    }

    pub fn dot(&self) -> String {
        format!("dot_d{}", self.depth)
    }
}

/// Default artifacts directory: `$EGPU_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("EGPU_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_selection() {
        // Resolve only checks depths against the table; use the real
        // artifacts dir when present.
        let dir = default_artifacts_dir();
        if !dir.is_dir() {
            return; // artifacts not built in this checkout
        }
        let a = ArtifactSet::resolve(&dir, 32).unwrap();
        assert_eq!(a.depth, 32);
        let a = ArtifactSet::resolve(&dir, 33).unwrap();
        assert_eq!(a.depth, 64);
        let a = ArtifactSet::resolve(&dir, 1).unwrap();
        assert_eq!(a.depth, 32);
        assert!(ArtifactSet::resolve(&dir, 65).is_err());
        assert_eq!(a.fp_alu(), "fp_alu_d32");
    }

    #[test]
    fn missing_dir_errors() {
        let e = ArtifactSet::resolve("/nonexistent/path", 32).unwrap_err();
        assert!(e.contains("make artifacts"));
    }
}
