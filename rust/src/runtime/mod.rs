//! PJRT runtime: load the AOT-compiled HLO-text artifacts and execute
//! them from the rust hot path.
//!
//! This is the only place the `xla` crate is touched. The interchange
//! format is HLO **text** (never serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see python/compile/aot.py and
//! /opt/xla-example/README.md).
//!
//! Python runs only at `make artifacts` time; after that the binary is
//! self-contained given the `artifacts/` directory.

pub mod artifacts;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

pub use artifacts::{default_artifacts_dir, ArtifactSet};

/// A PJRT CPU client with a compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn cpu(dir: impl AsRef<Path>) -> Result<Runtime, String> {
        let client = xla::PjRtClient::cpu().map_err(|e| format!("PJRT client: {e}"))?;
        Ok(Runtime {
            client,
            dir: dir.as_ref().to_path_buf(),
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Compile (once) the named artifact (`<name>.hlo.txt`).
    pub fn load(&mut self, name: &str) -> Result<(), String> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or("non-UTF8 artifact path")?,
        )
        .map_err(|e| format!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| format!("compile {name}: {e}"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute a loaded artifact. All our artifacts are lowered with
    /// `return_tuple=True`, so the single output is unwrapped from the
    /// 1-tuple here.
    pub fn execute(&mut self, name: &str, args: &[xla::Literal]) -> Result<xla::Literal, String> {
        self.load(name)?;
        let exe = self.cache.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| format!("execute {name}: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| format!("fetch {name}: {e}"))?;
        lit.to_tuple1().map_err(|e| format!("untuple {name}: {e}"))
    }

    pub fn loaded(&self) -> Vec<&str> {
        self.cache.keys().map(|s| s.as_str()).collect()
    }
}

/// Build a `(depth, 16)` f32 literal from u32 register lanes.
pub fn f32_block(lanes: &[u32], depth: usize) -> Result<xla::Literal, String> {
    let vals: Vec<f32> = lanes.iter().map(|&u| f32::from_bits(u)).collect();
    xla::Literal::vec1(&vals)
        .reshape(&[depth as i64, 16])
        .map_err(|e| format!("reshape f32 block: {e}"))
}

/// Build a `(depth, 16)` i32 literal from u32 register lanes.
pub fn i32_block(lanes: &[u32], depth: usize) -> Result<xla::Literal, String> {
    let vals: Vec<i32> = lanes.iter().map(|&u| u as i32).collect();
    xla::Literal::vec1(&vals)
        .reshape(&[depth as i64, 16])
        .map_err(|e| format!("reshape i32 block: {e}"))
}

/// Build a `(1,1)` i32 scalar literal (artifact scalar-parameter shape).
pub fn i32_scalar11(v: i32) -> Result<xla::Literal, String> {
    xla::Literal::vec1(&[v])
        .reshape(&[1, 1])
        .map_err(|e| format!("reshape scalar: {e}"))
}
