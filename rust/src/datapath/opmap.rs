//! The datapath op-index contract (rust half).
//!
//! Indices MUST match `python/compile/opmap.py` — `aot.py` writes them to
//! `artifacts/opmap.json` and [`verify_opmap_json`] rejects any drift
//! before the XLA backend is allowed to execute.

/// FP32 lane ops, in artifact switch order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum FpOp {
    FAdd = 0,
    FSub = 1,
    FNeg = 2,
    FAbs = 3,
    FMul = 4,
    FMax = 5,
    FMin = 6,
    FInvSqrt = 7,
}

impl FpOp {
    pub const COUNT: usize = 8;
    pub const ALL: [FpOp; Self::COUNT] = [
        FpOp::FAdd,
        FpOp::FSub,
        FpOp::FNeg,
        FpOp::FAbs,
        FpOp::FMul,
        FpOp::FMax,
        FpOp::FMin,
        FpOp::FInvSqrt,
    ];

    pub fn index(self) -> i32 {
        self as i32
    }

    pub fn name(self) -> &'static str {
        match self {
            FpOp::FAdd => "fadd",
            FpOp::FSub => "fsub",
            FpOp::FNeg => "fneg",
            FpOp::FAbs => "fabs",
            FpOp::FMul => "fmul",
            FpOp::FMax => "fmax",
            FpOp::FMin => "fmin",
            FpOp::FInvSqrt => "finvsqrt",
        }
    }
}

/// Integer lane ops, in artifact switch order. TYPE variants that change
/// semantics (shift sign, max/min sign) are distinct indices.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum IntOp {
    Add = 0,
    Sub = 1,
    Neg = 2,
    Abs = 3,
    Mul16Lo = 4,
    Mul16Hi = 5,
    Mul24Lo = 6,
    Mul24Hi = 7,
    And = 8,
    Or = 9,
    Xor = 10,
    Not = 11,
    CNot = 12,
    Bvs = 13,
    Shl = 14,
    ShrL = 15,
    ShrA = 16,
    Pop = 17,
    MaxS = 18,
    MinS = 19,
    MaxU = 20,
    MinU = 21,
}

impl IntOp {
    pub const COUNT: usize = 22;
    pub const ALL: [IntOp; Self::COUNT] = [
        IntOp::Add,
        IntOp::Sub,
        IntOp::Neg,
        IntOp::Abs,
        IntOp::Mul16Lo,
        IntOp::Mul16Hi,
        IntOp::Mul24Lo,
        IntOp::Mul24Hi,
        IntOp::And,
        IntOp::Or,
        IntOp::Xor,
        IntOp::Not,
        IntOp::CNot,
        IntOp::Bvs,
        IntOp::Shl,
        IntOp::ShrL,
        IntOp::ShrA,
        IntOp::Pop,
        IntOp::MaxS,
        IntOp::MinS,
        IntOp::MaxU,
        IntOp::MinU,
    ];

    pub fn index(self) -> i32 {
        self as i32
    }

    pub fn name(self) -> &'static str {
        match self {
            IntOp::Add => "add",
            IntOp::Sub => "sub",
            IntOp::Neg => "neg",
            IntOp::Abs => "abs",
            IntOp::Mul16Lo => "mul16lo",
            IntOp::Mul16Hi => "mul16hi",
            IntOp::Mul24Lo => "mul24lo",
            IntOp::Mul24Hi => "mul24hi",
            IntOp::And => "and",
            IntOp::Or => "or",
            IntOp::Xor => "xor",
            IntOp::Not => "not",
            IntOp::CNot => "cnot",
            IntOp::Bvs => "bvs",
            IntOp::Shl => "shl",
            IntOp::ShrL => "shr_l",
            IntOp::ShrA => "shr_a",
            IntOp::Pop => "pop",
            IntOp::MaxS => "max_s",
            IntOp::MinS => "min_s",
            IntOp::MaxU => "max_u",
            IntOp::MinU => "min_u",
        }
    }
}

/// Verify `artifacts/opmap.json` (written by aot.py) matches these enums.
///
/// The file is small JSON; we avoid a JSON dependency (offline image) with
/// a targeted extraction of the two string arrays.
pub fn verify_opmap_json(json: &str) -> Result<(), String> {
    let fp = extract_array(json, "fp_ops").ok_or("opmap.json: missing fp_ops")?;
    let int = extract_array(json, "int_ops").ok_or("opmap.json: missing int_ops")?;
    let want_fp: Vec<&str> = FpOp::ALL.iter().map(|o| o.name()).collect();
    let want_int: Vec<&str> = IntOp::ALL.iter().map(|o| o.name()).collect();
    if fp != want_fp {
        return Err(format!("fp op contract drift: artifact {fp:?} != rust {want_fp:?}"));
    }
    if int != want_int {
        return Err(format!(
            "int op contract drift: artifact {int:?} != rust {want_int:?}"
        ));
    }
    Ok(())
}

/// Extract `"key": [ "a", "b", ... ]` string arrays from simple JSON.
fn extract_array(json: &str, key: &str) -> Option<Vec<String>> {
    let kpos = json.find(&format!("\"{key}\""))?;
    let open = json[kpos..].find('[')? + kpos;
    let close = json[open..].find(']')? + open;
    let inner = &json[open + 1..close];
    Some(
        inner
            .split(',')
            .map(|s| s.trim().trim_matches('"').to_string())
            .filter(|s| !s.is_empty())
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indices_are_contiguous() {
        for (i, op) in FpOp::ALL.iter().enumerate() {
            assert_eq!(op.index(), i as i32);
        }
        for (i, op) in IntOp::ALL.iter().enumerate() {
            assert_eq!(op.index(), i as i32);
        }
    }

    #[test]
    fn verify_accepts_matching_json() {
        let fp: Vec<String> = FpOp::ALL.iter().map(|o| format!("\"{}\"", o.name())).collect();
        let int: Vec<String> = IntOp::ALL.iter().map(|o| format!("\"{}\"", o.name())).collect();
        let json = format!(
            "{{\"fp_ops\": [{}], \"int_ops\": [{}], \"depths\": [32, 64]}}",
            fp.join(", "),
            int.join(", ")
        );
        verify_opmap_json(&json).unwrap();
    }

    #[test]
    fn verify_rejects_drift() {
        let json = "{\"fp_ops\": [\"fadd\", \"fmul\"], \"int_ops\": [\"add\"]}";
        assert!(verify_opmap_json(json).is_err());
    }

    #[test]
    fn verify_against_real_artifact_if_present() {
        // When artifacts/ has been built, enforce the real contract.
        if let Ok(json) = std::fs::read_to_string(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/artifacts/opmap.json"
        )) {
            verify_opmap_json(&json).unwrap();
        }
    }
}
