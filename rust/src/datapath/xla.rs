//! XLA datapath backend: execute wavefront blocks through the
//! AOT-compiled PJRT executables (`--datapath xla`).
//!
//! This backend proves the three-layer claim: the python/JAX/Pallas
//! compile path and the rust coordinator implement the *same machine*.
//! Integration tests run whole benchmark programs on both backends and
//! compare architectural state.
//!
//! Blocks arriving from the machine have the machine's wavefront depth;
//! they are padded (mask 0) to the artifact's compiled depth.

use crate::runtime::{f32_block, i32_block, i32_scalar11, ArtifactSet, Runtime};

use super::{BlockExec, FpOp, IntOp};

pub struct XlaDatapath {
    rt: Runtime,
    set: ArtifactSet,
}

impl XlaDatapath {
    /// Compile the artifact set for a machine with `wavefronts` depth.
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>, wavefronts: usize) -> Result<XlaDatapath, String> {
        let set = ArtifactSet::resolve(&artifacts_dir, wavefronts)?;
        let mut rt = Runtime::cpu(&set.dir)?;
        // Compile eagerly so launch-time cost is paid once, off the
        // request path.
        rt.load(&set.fp_alu())?;
        rt.load(&set.int_alu())?;
        rt.load(&set.dot())?;
        Ok(XlaDatapath { rt, set })
    }

    pub fn depth(&self) -> usize {
        self.set.depth
    }

    /// Pad a u32 block (n lanes) to the artifact depth (zeros beyond).
    fn pad(&self, src: &[u32]) -> Vec<u32> {
        let full = self.set.depth * 16;
        let mut v = Vec::with_capacity(full);
        v.extend_from_slice(src);
        v.resize(full, 0);
        v
    }

    fn mask_block_f32(&self, mask: &[u8]) -> Result<xla::Literal, String> {
        let full = self.set.depth * 16;
        let mut vals: Vec<f32> = mask.iter().map(|&m| m as f32).collect();
        vals.resize(full, 0.0);
        xla::Literal::vec1(&vals)
            .reshape(&[self.set.depth as i64, 16])
            .map_err(|e| format!("mask reshape: {e}"))
    }

    fn mask_block_i32(&self, mask: &[u8]) -> Result<xla::Literal, String> {
        let full = self.set.depth * 16;
        let mut vals: Vec<i32> = mask.iter().map(|&m| m as i32).collect();
        vals.resize(full, 0);
        xla::Literal::vec1(&vals)
            .reshape(&[self.set.depth as i64, 16])
            .map_err(|e| format!("mask reshape: {e}"))
    }
}

impl BlockExec for XlaDatapath {
    fn fp_block(
        &mut self,
        op: FpOp,
        a: &[u32],
        b: &[u32],
        old: &[u32],
        mask: &[u8],
        out: &mut [u32],
    ) -> Result<(), String> {
        let d = self.set.depth;
        let args = [
            i32_scalar11(op.index())?,
            f32_block(&self.pad(a), d)?,
            f32_block(&self.pad(b), d)?,
            f32_block(&self.pad(old), d)?,
            self.mask_block_f32(mask)?,
        ];
        let name = self.set.fp_alu();
        let lit = self.rt.execute(&name, &args)?;
        let vals: Vec<f32> = lit.to_vec().map_err(|e| format!("fp result: {e}"))?;
        for (o, v) in out.iter_mut().zip(vals.iter()) {
            *o = v.to_bits();
        }
        Ok(())
    }

    fn int_block(
        &mut self,
        op: IntOp,
        precision: u8,
        a: &[u32],
        b: &[u32],
        old: &[u32],
        mask: &[u8],
        out: &mut [u32],
    ) -> Result<(), String> {
        let d = self.set.depth;
        let args = [
            i32_scalar11(op.index())?,
            i32_scalar11(precision as i32)?,
            i32_block(&self.pad(a), d)?,
            i32_block(&self.pad(b), d)?,
            i32_block(&self.pad(old), d)?,
            self.mask_block_i32(mask)?,
        ];
        let name = self.set.int_alu();
        let lit = self.rt.execute(&name, &args)?;
        let vals: Vec<i32> = lit.to_vec().map_err(|e| format!("int result: {e}"))?;
        for (o, v) in out.iter_mut().zip(vals.iter()) {
            *o = *v as u32;
        }
        Ok(())
    }

    fn dot_block(&mut self, a: &[u32], b: &[u32], mask: &[u8]) -> Result<f32, String> {
        let d = self.set.depth;
        let args = [
            f32_block(&self.pad(a), d)?,
            f32_block(&self.pad(b), d)?,
            self.mask_block_f32(mask)?,
        ];
        let name = self.set.dot();
        let lit = self.rt.execute(&name, &args)?;
        lit.get_first_element::<f32>()
            .map_err(|e| format!("dot result: {e}"))
    }

    fn name(&self) -> &'static str {
        "xla-pjrt"
    }
}
