//! Wavefront datapath backends.
//!
//! The simulator separates *coordination* (sequencer, thread-space
//! control, port arbitration, predicates — `sim`) from the *datapath*
//! (what the DSP blocks and the integer ALU compute). The datapath has two
//! interchangeable implementations:
//!
//! - [`native`] — bit-exact rust lane functions (default; fast),
//! - [`xla`] — the AOT-compiled HLO artifacts executed through PJRT
//!   (`--datapath xla`), proving the python/JAX/Pallas compile path
//!   implements the same machine.
//!
//! [`opmap`] is the rust half of the op-index contract with
//! `python/compile/opmap.py` (checked against `artifacts/opmap.json`).

pub mod native;
pub mod opmap;
pub mod xla;

pub use opmap::{FpOp, IntOp};

use crate::isa::{Instr, Opcode, TType};

/// Which datapath implementation executes wavefront blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    #[default]
    Native,
    Xla,
}

impl std::str::FromStr for BackendKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "native" => Ok(BackendKind::Native),
            "xla" => Ok(BackendKind::Xla),
            other => Err(format!("unknown datapath '{other}' (native|xla)")),
        }
    }
}

/// A pluggable wavefront-block executor (the XLA backend implements this;
/// the native path is inlined in the machine for speed and validated
/// against it by the equivalence tests).
///
/// Blocks are `(depth, 16)` row-major `u32` lanes; `mask` is the combined
/// thread-space-selection × predicate `thread_active` gate. `out` receives
/// the new Rd block (old values where mask is 0).
pub trait BlockExec {
    fn fp_block(
        &mut self,
        op: FpOp,
        a: &[u32],
        b: &[u32],
        old: &[u32],
        mask: &[u8],
        out: &mut [u32],
    ) -> Result<(), String>;

    fn int_block(
        &mut self,
        op: IntOp,
        precision: u8,
        a: &[u32],
        b: &[u32],
        old: &[u32],
        mask: &[u8],
        out: &mut [u32],
    ) -> Result<(), String>;

    /// DOT (or SUM with b = ones) over the masked block → scalar f32.
    fn dot_block(&mut self, a: &[u32], b: &[u32], mask: &[u8]) -> Result<f32, String>;

    /// Human-readable backend name for logs.
    fn name(&self) -> &'static str;
}

/// Classified datapath operation for one instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DpOp {
    Fp(FpOp),
    Int(IntOp),
    /// DOT (a·b) or SUM (Σa, realized as a·1) extension core.
    Dot { sum_only: bool },
}

/// Resolve an instruction's (opcode, TYPE) pair to its datapath op.
/// Returns `None` for non-datapath instructions (control, memory, ...).
pub fn classify(i: &Instr) -> Option<DpOp> {
    use Opcode::*;
    let unsigned = i.ttype == TType::Uint;
    let op = match i.op {
        FAdd => DpOp::Fp(FpOp::FAdd),
        FSub => DpOp::Fp(FpOp::FSub),
        FNeg => DpOp::Fp(FpOp::FNeg),
        FAbs => DpOp::Fp(FpOp::FAbs),
        FMul => DpOp::Fp(FpOp::FMul),
        FMax => DpOp::Fp(FpOp::FMax),
        FMin => DpOp::Fp(FpOp::FMin),
        InvSqr => DpOp::Fp(FpOp::FInvSqrt),
        Add => DpOp::Int(IntOp::Add),
        Sub => DpOp::Int(IntOp::Sub),
        Neg => DpOp::Int(IntOp::Neg),
        Abs => DpOp::Int(IntOp::Abs),
        Mul16Lo => DpOp::Int(IntOp::Mul16Lo),
        Mul16Hi => DpOp::Int(IntOp::Mul16Hi),
        Mul24Lo => DpOp::Int(IntOp::Mul24Lo),
        Mul24Hi => DpOp::Int(IntOp::Mul24Hi),
        And => DpOp::Int(IntOp::And),
        Or => DpOp::Int(IntOp::Or),
        Xor => DpOp::Int(IntOp::Xor),
        Not => DpOp::Int(IntOp::Not),
        CNot => DpOp::Int(IntOp::CNot),
        Bvs => DpOp::Int(IntOp::Bvs),
        Shl => DpOp::Int(IntOp::Shl),
        Shr => DpOp::Int(if unsigned { IntOp::ShrL } else { IntOp::ShrA }),
        Pop => DpOp::Int(IntOp::Pop),
        Max => DpOp::Int(if unsigned { IntOp::MaxU } else { IntOp::MaxS }),
        Min => DpOp::Int(if unsigned { IntOp::MinU } else { IntOp::MinS }),
        Dot => DpOp::Dot { sum_only: false },
        Sum => DpOp::Dot { sum_only: true },
        _ => return None,
    };
    Some(op)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Instr;

    #[test]
    fn classify_type_variants() {
        let mut i = Instr::new(Opcode::Shr);
        i.ttype = TType::Uint;
        assert_eq!(classify(&i), Some(DpOp::Int(IntOp::ShrL)));
        i.ttype = TType::Int;
        assert_eq!(classify(&i), Some(DpOp::Int(IntOp::ShrA)));
        let mut m = Instr::new(Opcode::Max);
        m.ttype = TType::Uint;
        assert_eq!(classify(&m), Some(DpOp::Int(IntOp::MaxU)));
    }

    #[test]
    fn classify_non_datapath() {
        for op in [Opcode::Nop, Opcode::Jmp, Opcode::Lod, Opcode::Sto, Opcode::If] {
            assert_eq!(classify(&Instr::new(op)), None);
        }
    }

    #[test]
    fn classify_extensions() {
        assert_eq!(
            classify(&Instr::new(Opcode::Dot)),
            Some(DpOp::Dot { sum_only: false })
        );
        assert_eq!(
            classify(&Instr::new(Opcode::InvSqr)),
            Some(DpOp::Fp(FpOp::FInvSqrt))
        );
    }
}
