//! Bit-exact native rust datapath (the default backend).
//!
//! Lane semantics are the single rust source of truth for what the DSP
//! blocks / integer ALU compute; they must agree exactly with
//! `python/compile/kernels/ref.py` (enforced by the native↔xla
//! equivalence integration tests, since the artifacts are generated from
//! the python kernels).
//!
//! Register lanes are `u32` bit patterns; FP ops bit-cast to `f32`.

use super::{FpOp, IntOp};

/// One FP32 lane operation (a DSP-block op).
#[inline]
pub fn fp_lane(op: FpOp, a: u32, b: u32) -> u32 {
    let fa = f32::from_bits(a);
    let fb = f32::from_bits(b);
    let r = match op {
        FpOp::FAdd => fa + fb,
        FpOp::FSub => fa - fb,
        FpOp::FNeg => -fa,
        FpOp::FAbs => fa.abs(),
        FpOp::FMul => fa * fb,
        // IEEE maxNum/minNum as XLA implements maximum/minimum: NaN
        // propagates; +0 > -0 is not distinguished by rust's max, so use
        // explicit compare chains matching XLA semantics.
        FpOp::FMax => {
            if fa.is_nan() || fb.is_nan() {
                f32::NAN
            } else if fa > fb {
                fa
            } else {
                fb
            }
        }
        FpOp::FMin => {
            if fa.is_nan() || fb.is_nan() {
                f32::NAN
            } else if fa < fb {
                fa
            } else {
                fb
            }
        }
        FpOp::FInvSqrt => 1.0 / fa.sqrt(),
    };
    r.to_bits()
}

#[inline]
fn sext16(x: u32) -> i32 {
    (x as i32) << 16 >> 16
}

#[inline]
fn sext24(x: u32) -> i32 {
    (x as i32) << 8 >> 8
}

/// One integer lane operation (the Table 6 soft-logic ALU).
/// `precision` is the configured ALU precision (16 truncates results).
#[inline]
pub fn int_lane(op: IntOp, a: u32, b: u32, precision: u8) -> u32 {
    let ia = a as i32;
    let ib = b as i32;
    let sh = b & 31;
    let r: u32 = match op {
        IntOp::Add => ia.wrapping_add(ib) as u32,
        IntOp::Sub => ia.wrapping_sub(ib) as u32,
        IntOp::Neg => ia.wrapping_neg() as u32,
        IntOp::Abs => ia.wrapping_abs() as u32,
        IntOp::Mul16Lo => sext16(a).wrapping_mul(sext16(b)) as u32,
        IntOp::Mul16Hi => (sext16(a).wrapping_mul(sext16(b)) >> 16) as u32,
        IntOp::Mul24Lo => {
            let p = (sext24(a) as i64).wrapping_mul(sext24(b) as i64);
            p as u32
        }
        IntOp::Mul24Hi => {
            let p = (sext24(a) as i64).wrapping_mul(sext24(b) as i64);
            (p >> 24) as u32
        }
        IntOp::And => a & b,
        IntOp::Or => a | b,
        IntOp::Xor => a ^ b,
        IntOp::Not => !a,
        IntOp::CNot => (a == 0) as u32,
        IntOp::Bvs => a.reverse_bits(),
        IntOp::Shl => a.wrapping_shl(sh),
        IntOp::ShrL => a.wrapping_shr(sh),
        IntOp::ShrA => (ia >> sh) as u32,
        IntOp::Pop => a.count_ones(),
        IntOp::MaxS => ia.max(ib) as u32,
        IntOp::MinS => ia.min(ib) as u32,
        IntOp::MaxU => a.max(b),
        IntOp::MinU => a.min(b),
    };
    if precision == 16 {
        r & 0xFFFF
    } else {
        r
    }
}

/// The DOT extension core's accumulation: wavefront-major, row-summed —
/// the same order the Pallas grid accumulates, so native and xla agree to
/// f32 rounding. `rows` iterates wavefronts; each row is ≤16 active lanes.
pub fn dot_accumulate(rows: impl Iterator<Item = f32>) -> f32 {
    let mut acc = 0f32;
    for r in rows {
        acc += r;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp_basic() {
        let f = |x: f32| x.to_bits();
        assert_eq!(fp_lane(FpOp::FAdd, f(1.5), f(2.25)), f(3.75));
        assert_eq!(fp_lane(FpOp::FSub, f(1.0), f(3.0)), f(-2.0));
        assert_eq!(fp_lane(FpOp::FNeg, f(7.0), 0), f(-7.0));
        assert_eq!(fp_lane(FpOp::FAbs, f(-7.0), 0), f(7.0));
        assert_eq!(fp_lane(FpOp::FMul, f(3.0), f(-2.0)), f(-6.0));
        assert_eq!(fp_lane(FpOp::FMax, f(3.0), f(-2.0)), f(3.0));
        assert_eq!(fp_lane(FpOp::FMin, f(3.0), f(-2.0)), f(-2.0));
        assert_eq!(fp_lane(FpOp::FInvSqrt, f(4.0), 0), f(0.5));
    }

    #[test]
    fn fp_nan_propagates_in_max_min() {
        let nan = f32::NAN.to_bits();
        let one = 1f32.to_bits();
        assert!(f32::from_bits(fp_lane(FpOp::FMax, nan, one)).is_nan());
        assert!(f32::from_bits(fp_lane(FpOp::FMin, one, nan)).is_nan());
    }

    #[test]
    fn int_wrapping() {
        assert_eq!(int_lane(IntOp::Add, i32::MAX as u32, 1, 32), i32::MIN as u32);
        assert_eq!(int_lane(IntOp::Neg, i32::MIN as u32, 0, 32), i32::MIN as u32);
        assert_eq!(int_lane(IntOp::Abs, i32::MIN as u32, 0, 32), i32::MIN as u32);
    }

    #[test]
    fn int_mul16() {
        // -3 (as 16-bit 0xFFFD) * 7 = -21, full product in LO.
        assert_eq!(int_lane(IntOp::Mul16Lo, 0xFFFD, 7, 32) as i32, -21);
        assert_eq!(int_lane(IntOp::Mul16Hi, 0xFFFD, 7, 32) as i32, -21 >> 16);
    }

    #[test]
    fn int_mul24_48bit() {
        let v = 0x7FFFFFu32;
        let p = (v as i64) * (v as i64);
        assert_eq!(int_lane(IntOp::Mul24Lo, v, v, 32), p as u32);
        assert_eq!(int_lane(IntOp::Mul24Hi, v, v, 32), (p >> 24) as u32);
    }

    #[test]
    fn int_shifts() {
        assert_eq!(int_lane(IntOp::Shl, 1, 33, 32), 2); // amount & 31
        assert_eq!(int_lane(IntOp::ShrA, (-16i32) as u32, 2, 32) as i32, -4);
        assert_eq!(int_lane(IntOp::ShrL, (-16i32) as u32, 2, 32), 0x3FFFFFFC);
    }

    #[test]
    fn int_bit_ops() {
        assert_eq!(int_lane(IntOp::Bvs, 1, 0, 32), 0x80000000);
        assert_eq!(int_lane(IntOp::Bvs, 0b1010, 0, 32), 0x50000000);
        assert_eq!(int_lane(IntOp::Pop, 0xFF, 0, 32), 8);
        assert_eq!(int_lane(IntOp::Pop, u32::MAX, 0, 32), 32);
        assert_eq!(int_lane(IntOp::CNot, 0, 0, 32), 1);
        assert_eq!(int_lane(IntOp::CNot, 5, 0, 32), 0);
        assert_eq!(int_lane(IntOp::Not, 0, 0, 32), u32::MAX);
    }

    #[test]
    fn int_signed_vs_unsigned_minmax() {
        let m1 = (-1i32) as u32;
        assert_eq!(int_lane(IntOp::MaxS, m1, 1, 32), 1);
        assert_eq!(int_lane(IntOp::MaxU, m1, 1, 32), m1);
        assert_eq!(int_lane(IntOp::MinS, m1, 1, 32), m1);
        assert_eq!(int_lane(IntOp::MinU, m1, 1, 32), 1);
    }

    #[test]
    fn precision_16_truncates() {
        assert_eq!(int_lane(IntOp::Add, 0x12344, 1, 16), (0x12345) & 0xFFFF);
        assert_eq!(int_lane(IntOp::Not, 0, 0, 16), 0xFFFF);
    }

    #[test]
    fn bvs_involution() {
        let mut x: u32 = 0x2545F491;
        for _ in 0..10 {
            let r = int_lane(IntOp::Bvs, x, 0, 32);
            assert_eq!(int_lane(IntOp::Bvs, r, 0, 32), x);
            x = x.wrapping_mul(2654435761).wrapping_add(1);
        }
    }
}
