//! `egpu::serve` — continuous job serving over a heterogeneous fleet.
//!
//! The paper positions the eGPU as a high-clock-rate offload engine for
//! *large numbers of small kernels*, and its companion work ("Soft GPGPU
//! versus IP cores", PAPERS.md) frames the real contest as sustained
//! throughput under a stream of requests — not one-shot launches. The
//! fleet layer ([`crate::coordinator`]) dispatches a pre-built batch;
//! this module adds the missing serving semantics on top of it:
//!
//! - **Admission.** Offered [`Request`]s pass through a *bounded*
//!   [`AdmissionQueue`]; a request that arrives while the queue is full
//!   is **shed** (recorded as a [`ShedRecord`], never silently dropped)
//!   instead of growing the backlog without bound.
//! - **Batching.** A deadline/priority-aware batcher
//!   ([`BatchPolicy`]) closes a batch window when it fills or when the
//!   oldest queued request has lingered `max_linger` modeled cycles,
//!   and dispatches oldest-deadline-first (then priority, arrival,
//!   submission order — a total order, so dispatch is deterministic).
//!   The queue keeps its pending set heap-ordered by exactly that key,
//!   so a window pops its `max_batch` entries in O(k log n) instead of
//!   re-sorting the backlog. Requests whose deadline has already
//!   passed at dispatch time are shed as
//!   [`ShedReason::DeadlineExpired`].
//! - **Dispatch.** Batches run through the existing fleet placement
//!   path ([`crate::api::GpuArray`] over [`crate::coordinator`]):
//!   feature routing, wall-clock-aware placement, the shared
//!   [`KernelCache`](crate::kernels::KernelCache) — compile once, serve
//!   forever.
//! - **Telemetry.** Per-request queue wait, service time and
//!   end-to-end modeled latency feed hand-rolled log₂ [`Histogram`]s
//!   (p50/p95/p99 — no registry dependencies exist offline), collected
//!   in a [`Telemetry`] record alongside shed/deadline-miss counts.
//!
//! # The modeled clock
//!
//! Everything is measured in **bus cycles** — the coordinator's shared
//! timeline unit (the fastest core's clock). Request arrivals are bus
//! cycles; the server advances the fleet's timeline across idle gaps
//! ([`crate::coordinator::Coordinator::advance_timeline_to`]) so a
//! job's `start`/`end` are absolute positions on one continuous
//! timeline and `end - arrival` is a real modeled latency. Batches are
//! serial on that timeline (the fleet drains a batch before the next
//! window closes); admission continues throughout, so arrivals during
//! service accumulate — and shed — exactly as they would against a
//! busy fleet.
//!
//! # Determinism
//!
//! With a fixed seed (see [`crate::harness::loadgen`]) the whole
//! pipeline is reproducible bit-for-bit: admission and batching are
//! pure integer arithmetic over modeled time, and the fleet's parallel
//! dispatch is already bit-identical to its sequential reference path
//! (PR 2/PR 4 discipline) — `rust/tests/serve_runtime.rs` asserts that
//! sequential and parallel serving produce identical results *and*
//! identical telemetry.

mod batcher;
mod queue;
mod server;
mod telemetry;

pub use batcher::BatchPolicy;
pub use queue::AdmissionQueue;
pub use server::{Server, ServerBuilder};
pub use telemetry::{Histogram, Telemetry};

use crate::kernels::KernelSpec;

/// One unit of offered load: a kernel specification plus its data
/// movement, an arrival time on the modeled clock, and optional
/// service-quality attributes (deadline, priority).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    /// What to run (specialized per placed core through the fleet's
    /// kernel cache).
    pub spec: KernelSpec,
    /// Blocks DMA'd into shared memory before the run.
    pub loads: Vec<(usize, Vec<u32>)>,
    /// `(base, len)` blocks DMA'd out after the run.
    pub unloads: Vec<(usize, usize)>,
    /// Arrival on the modeled clock, in bus cycles.
    pub arrival: u64,
    /// Absolute completion deadline (bus cycles). A request whose
    /// deadline has already passed when its batch window closes is
    /// shed ([`ShedReason::DeadlineExpired`]); one dispatched in time
    /// but finishing late is served and counted as a deadline miss.
    pub deadline: Option<u64>,
    /// Urgency among equal deadlines: higher wins a batch slot first.
    pub priority: u8,
}

impl Request {
    pub fn new(spec: KernelSpec) -> Request {
        Request {
            spec,
            loads: Vec::new(),
            unloads: Vec::new(),
            arrival: 0,
            deadline: None,
            priority: 0,
        }
    }

    /// DMA `data` into shared memory at `base` before the run.
    pub fn load(mut self, base: usize, data: Vec<u32>) -> Request {
        self.loads.push((base, data));
        self
    }

    /// DMA `len` words out from `base` after the run.
    pub fn unload(mut self, base: usize, len: usize) -> Request {
        self.unloads.push((base, len));
        self
    }

    /// Arrival time in bus cycles.
    pub fn at(mut self, arrival: u64) -> Request {
        self.arrival = arrival;
        self
    }

    /// Absolute completion deadline in bus cycles.
    pub fn due_by(mut self, deadline: u64) -> Request {
        self.deadline = Some(deadline);
        self
    }

    /// Urgency among equal deadlines (higher = more urgent).
    pub fn priority(mut self, priority: u8) -> Request {
        self.priority = priority;
        self
    }
}

/// Why a request was turned away instead of served.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The admission queue was at capacity when the request arrived.
    QueueFull,
    /// The deadline had already passed at dispatch time.
    DeadlineExpired,
}

impl ShedReason {
    /// Stable snake_case label: registry counter keys
    /// (`serve.shed.<label>`), trace-event args, and the BENCH
    /// `serving` section all use it.
    pub fn label(&self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue_full",
            ShedReason::DeadlineExpired => "deadline_expired",
        }
    }
}

impl std::fmt::Display for ShedReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShedReason::QueueFull => write!(f, "queue full"),
            ShedReason::DeadlineExpired => write!(f, "deadline expired"),
        }
    }
}

/// One shed request: every rejection is reported, never silent.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShedRecord {
    /// Index of the request in the submitted workload.
    pub id: usize,
    pub spec: KernelSpec,
    pub reason: ShedReason,
    /// Modeled bus cycle at which the request was turned away (its
    /// arrival for [`ShedReason::QueueFull`], the dispatch point for
    /// [`ShedReason::DeadlineExpired`]).
    pub at: u64,
}

/// A served request's full timeline and outputs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestResult {
    /// Index of the request in the submitted workload.
    pub id: usize,
    /// Kernel name (from the specialized build).
    pub name: String,
    /// Batch the request was dispatched in (0-based, dispatch order).
    pub batch: usize,
    /// Core the fleet placed it on.
    pub core: usize,
    /// Request arrival (bus cycles).
    pub arrival: u64,
    /// Bus cycle at which its batch was dispatched.
    pub dispatched: u64,
    /// Bus acquisition (load DMA start) on the shared timeline.
    pub start: u64,
    /// Unload-complete cycle on the shared timeline.
    pub end: u64,
    /// The deadline the request carried, if any.
    pub deadline: Option<u64>,
    /// Kernel cycles at the placed core's clock.
    pub compute_cycles: u64,
    /// Load + unload DMA cycles on the shared bus.
    pub bus_cycles: u64,
    /// Unloaded blocks, in `unloads` order.
    pub outputs: Vec<Vec<u32>>,
}

impl RequestResult {
    /// Cycles spent queued before the fleet touched the request.
    pub fn queue_wait(&self) -> u64 {
        self.start - self.arrival
    }

    /// Cycles from bus acquisition to unload complete.
    pub fn service(&self) -> u64 {
        self.end - self.start
    }

    /// End-to-end modeled latency: arrival → unload complete.
    pub fn e2e(&self) -> u64 {
        self.end - self.arrival
    }

    /// Did the request finish by its deadline? (No deadline = yes.)
    pub fn deadline_met(&self) -> bool {
        self.deadline.is_none_or(|d| self.end <= d)
    }
}

/// Everything one [`Server::serve`] call produced: served results in
/// dispatch order, every shed request, and the aggregate telemetry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServeReport {
    /// Served requests, in dispatch order (batch by batch).
    pub results: Vec<RequestResult>,
    /// Shed requests, in the order they were turned away.
    pub shed: Vec<ShedRecord>,
    pub telemetry: Telemetry,
}

impl ServeReport {
    /// Requests offered = served + shed (the accounting identity the
    /// serving tests assert).
    pub fn submitted(&self) -> usize {
        self.results.len() + self.shed.len()
    }

    /// Fraction of offered requests shed; 0 on an empty workload
    /// (delegates to the telemetry counters — one accounting source).
    pub fn shed_rate(&self) -> f64 {
        self.telemetry.shed_rate()
    }
}
