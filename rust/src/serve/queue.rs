//! The bounded admission queue: backpressure by load-shedding.
//!
//! A serving system with an unbounded queue does not degrade, it
//! *explodes* — latency grows without limit while throughput stays
//! flat. The admission queue therefore has a hard capacity: a request
//! that arrives while the queue is full is shed immediately and
//! recorded (reason + modeled time), so the caller can distinguish
//! "served slowly" from "turned away" — the accounting identity
//! `served + shed == offered` is asserted by the serving tests.

use super::{Request, ShedReason, ShedRecord};

/// An admitted request waiting for a batch slot.
#[derive(Debug, Clone)]
pub(crate) struct Pending {
    /// Index of the request in the submitted workload.
    pub id: usize,
    pub req: Request,
}

/// Bounded admission queue with shed-recording overflow.
#[derive(Debug)]
pub struct AdmissionQueue {
    capacity: usize,
    pending: Vec<Pending>,
    shed: Vec<ShedRecord>,
    peak: usize,
}

impl AdmissionQueue {
    /// A queue admitting at most `capacity` requests at once.
    pub fn new(capacity: usize) -> AdmissionQueue {
        AdmissionQueue {
            capacity,
            pending: Vec::new(),
            shed: Vec::new(),
            peak: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// High-water mark of admitted requests (≤ capacity, by
    /// construction — the bound the saturation test leans on).
    pub fn peak(&self) -> usize {
        self.peak
    }

    /// Requests shed so far.
    pub fn shed_count(&self) -> usize {
        self.shed.len()
    }

    /// Admit the request, or shed it (recorded, reason
    /// [`ShedReason::QueueFull`]) when the queue is at capacity. `at`
    /// is the modeled cycle of the admission attempt — the request's
    /// arrival instant.
    pub(crate) fn offer(&mut self, id: usize, req: Request, at: u64) {
        if self.pending.len() >= self.capacity {
            self.shed.push(ShedRecord {
                id,
                spec: req.spec,
                reason: ShedReason::QueueFull,
                at,
            });
        } else {
            self.pending.push(Pending { id, req });
            self.peak = self.peak.max(self.pending.len());
        }
    }

    /// Earliest arrival among queued requests.
    pub(crate) fn oldest_arrival(&self) -> Option<u64> {
        self.pending.iter().map(|p| p.req.arrival).min()
    }

    /// Take the queued requests for batch selection.
    pub(crate) fn take_pending(&mut self) -> Vec<Pending> {
        std::mem::take(&mut self.pending)
    }

    /// Put unselected requests back (they keep their admission).
    pub(crate) fn restore(&mut self, rest: Vec<Pending>) {
        debug_assert!(self.pending.is_empty(), "restore after take_pending only");
        self.pending = rest;
    }

    /// Record a shed decided outside the queue (deadline expiry at
    /// batch formation).
    pub(crate) fn shed_record(&mut self, rec: ShedRecord) {
        self.shed.push(rec);
    }

    /// All shed records, in the order the requests were turned away.
    pub(crate) fn into_shed(self) -> Vec<ShedRecord> {
        self.shed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KernelSpec;

    fn req(arrival: u64) -> Request {
        Request::new(KernelSpec::Reduction { n: 64 }).at(arrival)
    }

    #[test]
    fn overflow_sheds_with_reason_and_time() {
        let mut q = AdmissionQueue::new(2);
        q.offer(0, req(5), 5);
        q.offer(1, req(6), 6);
        q.offer(2, req(7), 7);
        assert_eq!(q.len(), 2);
        assert_eq!(q.peak(), 2);
        assert_eq!(q.shed_count(), 1);
        let shed = q.into_shed();
        assert_eq!(shed[0].id, 2);
        assert_eq!(shed[0].reason, ShedReason::QueueFull);
        assert_eq!(shed[0].at, 7);
    }

    #[test]
    fn take_and_restore_preserve_admission() {
        let mut q = AdmissionQueue::new(4);
        q.offer(0, req(1), 1);
        q.offer(1, req(2), 2);
        let taken = q.take_pending();
        assert!(q.is_empty());
        q.restore(taken);
        assert_eq!(q.len(), 2);
        assert_eq!(q.oldest_arrival(), Some(1));
        // Peak tracks admissions, not restores.
        assert_eq!(q.peak(), 2);
    }
}
