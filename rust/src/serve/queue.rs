//! The bounded admission queue: backpressure by load-shedding.
//!
//! A serving system with an unbounded queue does not degrade, it
//! *explodes* — latency grows without limit while throughput stays
//! flat. The admission queue therefore has a hard capacity: a request
//! that arrives while the queue is full is shed immediately and
//! recorded (reason + modeled time), so the caller can distinguish
//! "served slowly" from "turned away" — the accounting identity
//! `served + shed == offered` is asserted by the serving tests.
//!
//! The pending set is a binary min-heap on the dispatch key
//! (`(deadline, ¬priority, arrival, id)`), maintained *as an
//! invariant* rather than recomputed: admission pushes in O(log n)
//! and batch formation pops exactly the entries it dispatches
//! (O(k log n) per window) instead of re-sorting the whole queue
//! every window. The key is a total order over distinct requests, so
//! pop order — and therefore batch contents — is deterministic.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::kernels::KernelSpec;

use super::{Request, ShedReason, ShedRecord};

/// An admitted request waiting for a batch slot. Deliberately `Copy`:
/// it carries only what dispatch and the result record need (the
/// request's payload stays with the caller's trace, looked up by
/// `id`), so heap maintenance moves a few words, not input blocks.
#[derive(Debug, Clone, Copy)]
pub(crate) struct Pending {
    /// Index of the request in the submitted workload.
    pub id: usize,
    pub spec: KernelSpec,
    pub arrival: u64,
    pub deadline: Option<u64>,
    pub priority: u8,
}

impl Pending {
    /// The total dispatch order: `(deadline, ¬priority, arrival, id)`.
    /// Requests without a deadline sort last.
    pub(crate) fn dispatch_key(&self) -> (u64, u8, u64, usize) {
        (
            self.deadline.unwrap_or(u64::MAX),
            u8::MAX - self.priority,
            self.arrival,
            self.id,
        )
    }
}

/// Heap adapter: `BinaryHeap` is a max-heap, the queue wants the
/// *smallest* dispatch key on top, so the ordering is reversed.
#[derive(Debug)]
struct ByDispatch(Pending);

impl PartialEq for ByDispatch {
    fn eq(&self, other: &ByDispatch) -> bool {
        self.0.dispatch_key() == other.0.dispatch_key()
    }
}

impl Eq for ByDispatch {}

impl PartialOrd for ByDispatch {
    fn partial_cmp(&self, other: &ByDispatch) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for ByDispatch {
    fn cmp(&self, other: &ByDispatch) -> Ordering {
        other.0.dispatch_key().cmp(&self.0.dispatch_key())
    }
}

/// Bounded admission queue with shed-recording overflow.
#[derive(Debug)]
pub struct AdmissionQueue {
    capacity: usize,
    pending: BinaryHeap<ByDispatch>,
    shed: Vec<ShedRecord>,
    peak: usize,
}

impl AdmissionQueue {
    /// A queue admitting at most `capacity` requests at once.
    pub fn new(capacity: usize) -> AdmissionQueue {
        AdmissionQueue {
            capacity,
            pending: BinaryHeap::new(),
            shed: Vec::new(),
            peak: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// High-water mark of admitted requests (≤ capacity, by
    /// construction — the bound the saturation test leans on).
    pub fn peak(&self) -> usize {
        self.peak
    }

    /// Requests shed so far.
    pub fn shed_count(&self) -> usize {
        self.shed.len()
    }

    /// Admit the request, or shed it (recorded, reason
    /// [`ShedReason::QueueFull`]) when the queue is at capacity. `at`
    /// is the modeled cycle of the admission attempt — the request's
    /// arrival instant. Returns whether the request was admitted (the
    /// serve loop records the matching trace event).
    pub(crate) fn offer(&mut self, id: usize, req: &Request, at: u64) -> bool {
        if self.pending.len() >= self.capacity {
            self.shed.push(ShedRecord {
                id,
                spec: req.spec,
                reason: ShedReason::QueueFull,
                at,
            });
            false
        } else {
            self.pending.push(ByDispatch(Pending {
                id,
                spec: req.spec,
                arrival: req.arrival,
                deadline: req.deadline,
                priority: req.priority,
            }));
            self.peak = self.peak.max(self.pending.len());
            true
        }
    }

    /// Earliest arrival among queued requests. The heap orders by
    /// dispatch key, not arrival, so this is a linear scan — but over
    /// at most `qdepth` entries, once per batch window.
    pub(crate) fn oldest_arrival(&self) -> Option<u64> {
        self.pending.iter().map(|p| p.0.arrival).min()
    }

    /// The queued request next in dispatch order, if any.
    pub(crate) fn peek(&self) -> Option<&Pending> {
        self.pending.peek().map(|p| &p.0)
    }

    /// Remove and return the queued request next in dispatch order.
    pub(crate) fn pop(&mut self) -> Option<Pending> {
        self.pending.pop().map(|p| p.0)
    }

    /// Record a shed decided outside the queue (deadline expiry at
    /// batch formation).
    pub(crate) fn shed_record(&mut self, rec: ShedRecord) {
        self.shed.push(rec);
    }

    /// Shed records so far, in the order the requests were turned
    /// away. The serve loop keeps a cursor into this slice to emit
    /// shed trace events without the queue or batcher knowing about
    /// recording.
    pub(crate) fn shed_records(&self) -> &[ShedRecord] {
        &self.shed
    }

    /// All shed records, in the order the requests were turned away.
    pub(crate) fn into_shed(self) -> Vec<ShedRecord> {
        self.shed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(arrival: u64) -> Request {
        Request::new(KernelSpec::Reduction { n: 64 }).at(arrival)
    }

    #[test]
    fn overflow_sheds_with_reason_and_time() {
        let mut q = AdmissionQueue::new(2);
        q.offer(0, &req(5), 5);
        q.offer(1, &req(6), 6);
        q.offer(2, &req(7), 7);
        assert_eq!(q.len(), 2);
        assert_eq!(q.peak(), 2);
        assert_eq!(q.shed_count(), 1);
        let shed = q.into_shed();
        assert_eq!(shed[0].id, 2);
        assert_eq!(shed[0].reason, ShedReason::QueueFull);
        assert_eq!(shed[0].at, 7);
    }

    #[test]
    fn pops_follow_the_dispatch_key_order() {
        let mut q = AdmissionQueue::new(8);
        q.offer(0, &req(3), 3); // no deadline, late arrival
        q.offer(1, &req(2).due_by(900), 2); // latest deadline
        q.offer(2, &req(1).due_by(500), 1); // earliest deadline
        q.offer(3, &req(0).priority(3), 0); // no deadline, urgent
        assert_eq!(q.oldest_arrival(), Some(0));
        assert_eq!(q.peek().map(|p| p.id), Some(2));
        let order: Vec<usize> = std::iter::from_fn(|| q.pop()).map(|p| p.id).collect();
        assert_eq!(order, vec![2, 1, 3, 0]);
        // Popping consumes admission but not the high-water mark.
        assert_eq!(q.peak(), 4);
    }
}
