//! Deadline/priority-aware batch formation.
//!
//! The batcher decides *when* a batch window closes and *which* queued
//! requests fill it:
//!
//! - The window closes when the batch fills ([`BatchPolicy::max_batch`]
//!   requests) or when the oldest queued request has lingered
//!   [`BatchPolicy::max_linger`] modeled cycles since its arrival —
//!   whichever comes first. Lingering trades a little latency for
//!   fuller batches (more cross-core overlap per dispatch).
//! - Slots go oldest-deadline-first (requests without a deadline sort
//!   last), then highest priority, then arrival, then submission
//!   order. The key is a total order over distinct requests, so batch
//!   contents and dispatch order are deterministic.
//! - A request whose deadline has already passed at dispatch time is
//!   shed ([`ShedReason::DeadlineExpired`]) rather than burning fleet
//!   time on an answer nobody can use.
//!
//! The queue keeps its pending set heap-ordered by the dispatch key,
//! so drawing a batch pops at most `max_batch` entries plus the
//! expired prefix — O(k log n) per window — instead of re-sorting
//! everything queued. Expired deadlines *are* a prefix of the dispatch
//! order: the key leads with the deadline, so every entry with
//! `deadline ≤ now` sorts strictly before every entry with a later (or
//! no) deadline, and shedding them head-first is exactly the old
//! full-sort-then-scan behavior.

use super::queue::{AdmissionQueue, Pending};
use super::{ShedReason, ShedRecord};

/// Batch-formation knobs (modeled time; see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchPolicy {
    /// Maximum requests per dispatched batch.
    pub max_batch: usize,
    /// Maximum bus cycles the oldest queued request lingers before the
    /// window closes regardless of batch fill.
    pub max_linger: u64,
}

impl BatchPolicy {
    /// Latest window close, given the clock and the oldest queued
    /// arrival: the oldest request never lingers past `max_linger`,
    /// and a window never closes in the past.
    pub(crate) fn close_by(&self, now: u64, oldest_arrival: u64) -> u64 {
        now.max(oldest_arrival.saturating_add(self.max_linger))
    }
}

/// Draw the next batch from the queue at modeled time `now`: expired
/// deadlines are shed (recorded on the queue), the best
/// `policy.max_batch` survivors are returned in dispatch order, and
/// the rest keep their queue slots (and heap positions).
#[cfg(test)]
pub(crate) fn draw_batch(
    queue: &mut AdmissionQueue,
    policy: &BatchPolicy,
    now: u64,
) -> Vec<Pending> {
    let mut batch = Vec::new();
    draw_batch_into(queue, policy, now, &mut batch);
    batch
}

/// `draw_batch` into a caller-retained buffer: the serve loop reuses
/// one `Vec` across every window, so steady-state batch formation
/// allocates nothing.
pub(crate) fn draw_batch_into(
    queue: &mut AdmissionQueue,
    policy: &BatchPolicy,
    now: u64,
    batch: &mut Vec<Pending>,
) {
    batch.clear();
    while let Some(head) = queue.peek() {
        if head.deadline.is_some_and(|d| d <= now) {
            let p = queue.pop().expect("peeked entry pops");
            queue.shed_record(ShedRecord {
                id: p.id,
                spec: p.spec,
                reason: ShedReason::DeadlineExpired,
                at: now,
            });
            continue;
        }
        if batch.len() >= policy.max_batch {
            break;
        }
        batch.push(queue.pop().expect("peeked entry pops"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KernelSpec;
    use crate::serve::Request;

    fn queued(reqs: Vec<Request>) -> AdmissionQueue {
        let mut q = AdmissionQueue::new(reqs.len());
        for (id, r) in reqs.into_iter().enumerate() {
            q.offer(id, &r, r.arrival);
        }
        q
    }

    fn spec() -> KernelSpec {
        KernelSpec::Reduction { n: 64 }
    }

    #[test]
    fn deadline_then_priority_then_arrival_orders_the_batch() {
        let mut q = queued(vec![
            Request::new(spec()).at(3),             // no deadline, late
            Request::new(spec()).at(2).due_by(900), // latest deadline
            Request::new(spec()).at(1).due_by(500), // earliest deadline
            Request::new(spec()).at(0).priority(3), // no deadline, urgent
            Request::new(spec()).at(9).due_by(500), // same deadline, later arrival
        ]);
        let policy = BatchPolicy {
            max_batch: 8,
            max_linger: 100,
        };
        let batch = draw_batch(&mut q, &policy, 10);
        let ids: Vec<usize> = batch.iter().map(|p| p.id).collect();
        assert_eq!(ids, vec![2, 4, 1, 3, 0]);
        assert!(q.is_empty());
    }

    #[test]
    fn expired_deadlines_are_shed_not_dispatched() {
        let mut q = queued(vec![
            Request::new(spec()).at(0).due_by(5),
            Request::new(spec()).at(0).due_by(500),
        ]);
        let policy = BatchPolicy {
            max_batch: 8,
            max_linger: 100,
        };
        let batch = draw_batch(&mut q, &policy, 10);
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 1);
        assert_eq!(q.shed_count(), 1);
        let shed = q.into_shed();
        assert_eq!(shed[0].reason, ShedReason::DeadlineExpired);
        assert_eq!(shed[0].at, 10);
    }

    #[test]
    fn overflow_stays_queued_for_the_next_window() {
        let mut q = queued(vec![
            Request::new(spec()).at(0).due_by(100),
            Request::new(spec()).at(0).due_by(200),
            Request::new(spec()).at(0).due_by(300),
        ]);
        let policy = BatchPolicy {
            max_batch: 2,
            max_linger: 100,
        };
        let batch = draw_batch(&mut q, &policy, 0);
        assert_eq!(batch.len(), 2);
        assert_eq!(q.len(), 1);
        assert_eq!(q.oldest_arrival(), Some(0));
        let next = draw_batch(&mut q, &policy, 0);
        assert_eq!(next[0].id, 2);
    }

    #[test]
    fn close_by_honors_linger_and_never_rewinds() {
        let p = BatchPolicy {
            max_batch: 4,
            max_linger: 50,
        };
        assert_eq!(p.close_by(10, 0), 50);
        assert_eq!(p.close_by(100, 0), 100);
        assert_eq!(p.close_by(0, u64::MAX), u64::MAX);
    }
}
