//! Latency telemetry: hand-rolled log₂ histograms and the aggregate
//! serving record.
//!
//! No metrics crate exists in the offline build (same story as
//! serde/criterion — DESIGN.md §Substitutions), so percentiles come
//! from a fixed-size power-of-two-bucketed histogram: integer-only
//! state, `PartialEq`-comparable, and therefore usable in the
//! bit-for-bit determinism assertions of `rust/tests/serve_runtime.rs`
//! (parallel and sequential serving must produce *identical*
//! telemetry, not merely similar distributions).

use super::RequestResult;

/// Power-of-two-bucketed histogram over `u64` samples (bus cycles).
///
/// Bucket 0 holds exact zeros; bucket `i ≥ 1` holds `[2^(i-1), 2^i)`.
/// Quantiles resolve to the containing bucket's upper bound, clamped
/// to the observed extrema — a deterministic estimate with ≤ 2×
/// relative error, which is plenty for p50/p95/p99 reporting and is
/// exactly reproducible across runs and dispatch modes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Histogram {
    buckets: [u64; 65],
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: [0; 65],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    /// Bucket index: 0 for 0, else `1 + floor(log₂ v)`.
    fn bucket(v: u64) -> usize {
        (64 - v.leading_zeros()) as usize
    }

    pub fn record(&mut self, v: u64) {
        self.buckets[Self::bucket(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Smallest recorded sample; 0 when empty.
    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Largest recorded sample; 0 when empty.
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Exact mean of the recorded samples; 0 when empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Quantile estimate for `p ∈ [0, 1]`: the upper bound of the
    /// bucket holding the `ceil(p·count)`-th smallest sample, clamped
    /// to `[min, max]`. Deterministic; 0 when empty.
    pub fn quantile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let hi = if i >= 64 { u64::MAX } else { (1u64 << i).saturating_sub(1) };
                return hi.clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }
}

/// Aggregate serving telemetry for one [`super::Server::serve`] call.
/// Integer-only (histograms + counters), so two runs can be compared
/// with `==` — the determinism contract of the serving tests.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Telemetry {
    /// Requests served to completion.
    pub completed: u64,
    /// Requests shed (queue overflow + expired deadlines).
    pub shed: u64,
    /// Batches dispatched.
    pub batches: u64,
    /// Served requests that finished after their deadline.
    pub deadline_missed: u64,
    /// High-water mark of the admission queue (≤ its capacity).
    pub peak_queue: usize,
    /// Earliest offered arrival (bus cycles).
    pub first_arrival: u64,
    /// Latest completion (bus cycles).
    pub last_end: u64,
    /// Cycles queued before the fleet touched each request.
    pub queue_wait: Histogram,
    /// Bus-acquisition → unload-complete cycles per request.
    pub service: Histogram,
    /// Arrival → unload-complete cycles per request.
    pub e2e: Histogram,
}

impl Telemetry {
    pub(crate) fn observe(&mut self, r: &RequestResult) {
        self.completed += 1;
        if !r.deadline_met() {
            self.deadline_missed += 1;
        }
        self.queue_wait.record(r.queue_wait());
        self.service.record(r.service());
        self.e2e.record(r.e2e());
        self.last_end = self.last_end.max(r.end);
    }

    /// Modeled span from first arrival to last completion, in bus
    /// cycles; 0 before anything completed.
    pub fn span_cycles(&self) -> u64 {
        self.last_end.saturating_sub(self.first_arrival)
    }

    /// Completed requests per modeled second at the given bus clock.
    pub fn jobs_per_s(&self, bus_mhz: f64) -> f64 {
        let span = self.span_cycles();
        if span == 0 {
            return 0.0;
        }
        self.completed as f64 * bus_mhz * 1e6 / span as f64
    }

    /// Fraction of offered requests shed; 0 on an empty workload.
    pub fn shed_rate(&self) -> f64 {
        let offered = self.completed + self.shed;
        if offered == 0 {
            0.0
        } else {
            self.shed as f64 / offered as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_is_all_zeros() {
        let h = Histogram::new();
        assert_eq!((h.count(), h.min(), h.max()), (0, 0, 0));
        assert_eq!(h.mean(), 0.0);
        assert_eq!((h.p50(), h.p95(), h.p99()), (0, 0, 0));
    }

    #[test]
    fn buckets_are_log2_ranges() {
        assert_eq!(Histogram::bucket(0), 0);
        assert_eq!(Histogram::bucket(1), 1);
        assert_eq!(Histogram::bucket(2), 2);
        assert_eq!(Histogram::bucket(3), 2);
        assert_eq!(Histogram::bucket(4), 3);
        assert_eq!(Histogram::bucket(u64::MAX), 64);
    }

    #[test]
    fn quantiles_clamp_to_observed_extrema() {
        let mut h = Histogram::new();
        for v in [100u64, 100, 100, 100] {
            h.record(v);
        }
        // All samples share bucket [64, 127]; the estimate clamps to
        // the exact observed value.
        assert_eq!(h.p50(), 100);
        assert_eq!(h.p99(), 100);
        assert_eq!(h.min(), 100);
        assert_eq!(h.max(), 100);
        assert_eq!(h.mean(), 100.0);
    }

    #[test]
    fn quantiles_order_across_buckets() {
        let mut h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let (p50, p95, p99) = (h.p50(), h.p95(), h.p99());
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // p50 of 1..=1000 lands in the bucket holding rank 500
        // ([512, 1023] upper bound, clamped to max 1000).
        assert!((500..=1000).contains(&p50), "{p50}");
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn zero_samples_live_in_bucket_zero() {
        let mut h = Histogram::new();
        h.record(0);
        h.record(0);
        assert_eq!(h.p50(), 0);
        assert_eq!(h.max(), 0);
    }

    #[test]
    fn jobs_per_s_guards_the_empty_span() {
        let t = Telemetry::default();
        assert_eq!(t.jobs_per_s(771.0), 0.0);
        assert_eq!(t.shed_rate(), 0.0);
        assert_eq!(t.span_cycles(), 0);
    }
}
