//! The serving runtime: admission → batcher → fleet, on one modeled
//! clock.
//!
//! [`Server::serve`] is a discrete-event loop over modeled bus cycles.
//! Each iteration opens a batch window (jumping an idle clock to the
//! next arrival), admits everything that has arrived (shedding on
//! overflow), extends the window until the batch fills or the oldest
//! request's linger expires, draws the batch in deadline/priority
//! order, aligns the fleet's timeline with the window close
//! ([`GpuArray::advance_timeline_to`] — the idle gap is modeled, not
//! ignored), and dispatches through the fleet's feature-routed,
//! wall-clock-aware placement path. Batches are serial on the modeled
//! timeline: the next window closes no earlier than the previous
//! batch's makespan, so arrivals during service queue up (and shed)
//! exactly as they would against a busy fleet.
//!
//! Everything the loop decides is integer arithmetic over modeled
//! time, and the fleet's parallel dispatch is bit-identical to its
//! sequential reference — so a fixed workload produces bit-identical
//! [`ServeReport`]s (results *and* telemetry) in both modes.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::api::{ApiError, FleetBuilder, GpuArray};
use crate::coordinator::ReuseStats;
use crate::kernels::{CacheStats, KernelCache};
use crate::obs::{EventKind, MetricsRegistry, Recorder, StatsSnapshot};
use crate::sim::config::ConfigError;
use crate::sim::{SuperplanActivity, SuperplanCacheStats};

use super::batcher::{draw_batch_into, BatchPolicy};
use super::queue::{AdmissionQueue, Pending};
use super::telemetry::Telemetry;
use super::{Request, RequestResult, ServeReport};

/// Builder for a [`Server`]: the fleet plus the serving knobs.
/// Defaults: the reference mixed fleet
/// ([`FleetBuilder::demo_mixed`]), queue depth 64, batches of 8, 8 µs
/// linger, parallel dispatch.
#[derive(Debug, Clone)]
pub struct ServerBuilder {
    fleet: FleetBuilder,
    qdepth: usize,
    max_batch: usize,
    linger_us: u64,
    sequential: bool,
    recording: bool,
}

impl Default for ServerBuilder {
    fn default() -> ServerBuilder {
        ServerBuilder::new()
    }
}

impl ServerBuilder {
    pub fn new() -> ServerBuilder {
        ServerBuilder {
            fleet: FleetBuilder::demo_mixed(),
            qdepth: 64,
            max_batch: 8,
            linger_us: 8,
            sequential: false,
            recording: false,
        }
    }

    /// Serve over this fleet instead of the demo mix.
    pub fn fleet(mut self, fleet: FleetBuilder) -> ServerBuilder {
        self.fleet = fleet;
        self
    }

    /// Admission-queue capacity (requests beyond it are shed).
    pub fn qdepth(mut self, qdepth: usize) -> ServerBuilder {
        self.qdepth = qdepth;
        self
    }

    /// Maximum requests per dispatched batch.
    pub fn max_batch(mut self, max_batch: usize) -> ServerBuilder {
        self.max_batch = max_batch;
        self
    }

    /// Maximum modeled linger of the oldest queued request, in µs
    /// (converted to bus cycles at build time).
    pub fn linger_us(mut self, linger_us: u64) -> ServerBuilder {
        self.linger_us = linger_us;
        self
    }

    /// Force the fleet's sequential dispatch path (`--seq`): results
    /// and telemetry are bit-identical to parallel dispatch, only
    /// wall-clock time differs.
    pub fn sequential(mut self, sequential: bool) -> ServerBuilder {
        self.sequential = sequential;
        self
    }

    /// Share a kernel-specialization cache with other devices.
    pub fn kernel_cache(mut self, cache: Arc<KernelCache>) -> ServerBuilder {
        self.fleet = self.fleet.kernel_cache(cache);
        self
    }

    /// Attach an event [`Recorder`] from the start (equivalent to
    /// calling [`Server::start_recording`] on the built server).
    /// Recording never changes a modeled cycle or result — only
    /// whether the trace is kept.
    pub fn recording(mut self, recording: bool) -> ServerBuilder {
        self.recording = recording;
        self
    }

    pub fn build(self) -> Result<Server, ApiError> {
        if self.qdepth == 0 {
            return Err(ApiError::Config(ConfigError(
                "a Server needs an admission queue (qdepth == 0)".into(),
            )));
        }
        if self.max_batch == 0 {
            return Err(ApiError::Config(ConfigError(
                "a Server needs a batch size of at least 1 (max_batch == 0)".into(),
            )));
        }
        let mut fleet = self.fleet.build()?;
        fleet.set_parallel(!self.sequential);
        if self.recording {
            fleet.start_recording();
        }
        let bus_khz = fleet.coordinator().bus_khz();
        let policy = BatchPolicy {
            max_batch: self.max_batch,
            max_linger: self.linger_us.saturating_mul(bus_khz) / 1000,
        };
        Ok(Server {
            fleet,
            qdepth: self.qdepth,
            policy,
            batch_buf: Vec::new(),
            metrics: MetricsRegistry::new(),
        })
    }
}

/// A continuous job-serving runtime over a heterogeneous fleet. Build
/// with [`Server::builder`]; feed workloads with [`Server::serve`].
/// The fleet's timeline, kernel cache and stream state persist across
/// `serve` calls — steady-state serving compiles each
/// `(spec, config fingerprint)` exactly once, however many workloads
/// replay it (assertable via [`Server::cache_stats`]).
pub struct Server {
    fleet: GpuArray,
    qdepth: usize,
    policy: BatchPolicy,
    /// Batch-window scratch, retained across windows and `serve` calls
    /// so steady-state batch formation allocates nothing.
    batch_buf: Vec<Pending>,
    /// Serving counters (offered/served/shed-by-reason/batches), kept
    /// out of the modeled timeline; [`Server::metrics`] merges in the
    /// fleet's [`StatsSnapshot`] gauges.
    metrics: MetricsRegistry,
}

impl Server {
    pub fn builder() -> ServerBuilder {
        ServerBuilder::new()
    }

    /// The fleet behind the server.
    pub fn fleet(&self) -> &GpuArray {
        &self.fleet
    }

    pub fn num_cores(&self) -> usize {
        self.fleet.num_cores()
    }

    /// Fraction of the modeled timeline each core spent occupied
    /// (idle gaps between batches count against utilization).
    pub fn core_utilization(&self) -> Vec<f64> {
        self.fleet.core_utilization()
    }

    /// Every runtime cache/reuse/pool counter in one struct — the
    /// unified surface the per-counter getters delegate to.
    pub fn stats_snapshot(&self) -> StatsSnapshot {
        self.fleet.stats_snapshot()
    }

    /// Kernel-cache counters — the "compile once, serve forever"
    /// property, assertable in tests.
    pub fn cache_stats(&self) -> CacheStats {
        self.stats_snapshot().cache
    }

    /// Machine-reuse counters — one level below [`Server::cache_stats`]:
    /// hits are dispatched jobs that skipped assembly *and*
    /// `load_program` because their core's machine already held the
    /// kernel's program (reset-don't-reallocate). Steady-state serving
    /// of a fixed request mix reaches zero reallocation per
    /// (core, fingerprint): repeat workloads add only hits.
    pub fn reuse_stats(&self) -> ReuseStats {
        self.stats_snapshot().reuse
    }

    /// Fleet-wide superplan cache counters — one level below
    /// [`Server::reuse_stats`]: each distinct (program, config
    /// fingerprint, threads) triple compiles its fused traces exactly
    /// once, shared across every core and serve batch. Deterministic
    /// between sequential and parallel dispatch.
    pub fn superplan_stats(&self) -> SuperplanCacheStats {
        self.stats_snapshot().superplan
    }

    /// Summed per-core superplan rebuild/fast-skip activity. After
    /// warmup, steady-state serving of a fixed request mix accumulates
    /// only fast skips — the zero-recompile property.
    pub fn superplan_activity(&self) -> SuperplanActivity {
        self.stats_snapshot().superplan_activity
    }

    /// Worker pools spawned by the fleet's coordinator: 0 under
    /// `--seq`, 1 from the first parallel batch on — never more,
    /// however many serve windows run.
    pub fn pool_spawns(&self) -> u64 {
        self.stats_snapshot().pool_spawns
    }

    /// Worker threads revived after dying (0 in normal operation).
    pub fn pool_revives(&self) -> u64 {
        self.stats_snapshot().pool_revives
    }

    /// Start (or fetch) the event recorder shared with the fleet's
    /// coordinator. Idempotent; recording changes no modeled cycle.
    pub fn start_recording(&mut self) -> Arc<Recorder> {
        self.fleet.start_recording()
    }

    /// The attached recorder, if recording is on.
    pub fn recorder(&self) -> Option<Arc<Recorder>> {
        self.fleet.recorder()
    }

    /// The serving metrics joined with the fleet's
    /// [`StatsSnapshot`] gauges: one deterministic registry holding
    /// every counter the server knows.
    pub fn metrics(&self) -> MetricsRegistry {
        let mut reg = self.metrics.clone();
        self.stats_snapshot().export_into(&mut reg);
        reg
    }

    /// The batching policy the builder resolved (linger in cycles).
    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Admission-queue capacity.
    pub fn qdepth(&self) -> usize {
        self.qdepth
    }

    /// Start a fresh accounting window at cycle 0 (the explicit reset
    /// of [`GpuArray::reset_timeline`]; by default successive
    /// [`Server::serve`] calls continue one cumulative timeline). The
    /// kernel cache is untouched — a reset server still serves from
    /// warm specializations.
    pub fn reset_timeline(&mut self) {
        self.fleet.reset_timeline();
    }

    /// The shared bus clock in integer kHz.
    pub fn bus_khz(&self) -> u64 {
        self.fleet.coordinator().bus_khz()
    }

    /// The shared bus clock in MHz.
    pub fn bus_mhz(&self) -> f64 {
        self.fleet.coordinator().bus_mhz()
    }

    /// Modeled µs → bus cycles (exact integer arithmetic, floor;
    /// saturating, so absurd CLI values clamp instead of panicking).
    pub fn us_to_cycles(&self, us: u64) -> u64 {
        us.saturating_mul(self.bus_khz()) / 1000
    }

    /// Bus cycles → modeled µs.
    pub fn cycles_to_us(&self, cycles: u64) -> f64 {
        cycles as f64 / self.bus_mhz()
    }

    /// Serve a workload to drain: admit by arrival time, batch, and
    /// dispatch until every request is served or shed. Returns the
    /// per-request results (dispatch order), every shed request, and
    /// the aggregate telemetry. Deterministic for a fixed workload.
    pub fn serve(&mut self, requests: Vec<Request>) -> Result<ServeReport, ApiError> {
        self.serve_slice(&requests)
    }

    /// Borrowed-workload twin of [`Server::serve`]: replays the trace
    /// without taking ownership, so a caller scoring the same trace
    /// against many fleets ([`crate::synth`]) stops cloning it once
    /// per replay. The serve loop is one and the same — `serve` is a
    /// thin delegate — so both paths produce identical
    /// [`ServeReport`]s. Input blocks are copied only for requests
    /// that actually dispatch, at dispatch time.
    pub fn serve_slice(&mut self, requests: &[Request]) -> Result<ServeReport, ApiError> {
        let policy = self.policy;
        // All span recording happens here on the dispatching thread,
        // from modeled values the loop already computed — the trace is
        // a pure function of the workload, identical across `--seq`
        // and parallel dispatch (asserted by `rust/tests/obs_trace.rs`).
        let recorder = self.fleet.recorder();
        let rec = recorder.as_deref();
        // Cursor over the queue's shed log: sheds are recorded by the
        // queue/batcher (which know nothing about tracing) and turned
        // into events here, once per batch window.
        let mut shed_cursor = 0usize;
        // Feed order: arrival time, ties by submission index. The feed
        // holds indices into `requests`; payloads stay in place.
        let mut feed: Vec<usize> = (0..requests.len()).collect();
        feed.sort_by_key(|&id| (requests[id].arrival, id));
        // Statically-checkable spec errors fail the whole workload up
        // front — a mid-batch compile failure would leave submitted
        // jobs queued on the coordinator.
        for &id in &feed {
            let r = &requests[id];
            if !r.spec.valid_dim() {
                return Err(ApiError::Assemble(format!(
                    "request {id}: kernel '{}' does not support DIM {}",
                    r.spec.generator(),
                    r.spec.dim()
                )));
            }
        }
        let mut telemetry = Telemetry {
            first_arrival: feed.first().map(|&id| requests[id].arrival).unwrap_or(0),
            ..Telemetry::default()
        };
        let mut feed: VecDeque<usize> = feed.into();

        let mut queue = AdmissionQueue::new(self.qdepth);
        let mut results: Vec<RequestResult> = Vec::new();
        let mut batches = 0usize;
        // The modeled clock continues the fleet's timeline: a second
        // workload on one server queues behind the first one's work.
        let mut now = self.fleet.makespan();

        while !feed.is_empty() || !queue.is_empty() {
            if queue.is_empty() {
                // Fleet idle, nothing queued: the window opens at the
                // next arrival.
                let head = feed
                    .front()
                    .map(|&id| requests[id].arrival)
                    .expect("feed is non-empty");
                now = now.max(head);
            }
            admit_up_to(requests, &mut feed, &mut queue, now, rec);
            let oldest = queue.oldest_arrival().expect("admission filled the queue");
            // The window closes when the batch fills or the oldest
            // request's linger expires; arrivals inside the window
            // join (or shed) as they come.
            let mut dispatch_at = if queue.len() >= policy.max_batch {
                now
            } else {
                policy.close_by(now, oldest)
            };
            while queue.len() < policy.max_batch {
                let due = feed
                    .front()
                    .map(|&id| requests[id].arrival)
                    .filter(|&a| a <= dispatch_at);
                let Some(arrival) = due else { break };
                let id = feed.pop_front().expect("front was just inspected");
                if queue.offer(id, &requests[id], arrival) {
                    if let Some(rec) = rec {
                        rec.record(arrival, EventKind::Admitted { req: id });
                    }
                }
                if queue.len() >= policy.max_batch {
                    dispatch_at = arrival; // filled early: close here
                }
            }
            now = now.max(dispatch_at);

            draw_batch_into(&mut queue, &policy, now, &mut self.batch_buf);
            if let Some(rec) = rec {
                // Sheds since the last window (queue-full at offer,
                // deadline expiry at draw), stamped at their own
                // modeled shed instants.
                for s in &queue.shed_records()[shed_cursor..] {
                    rec.record(
                        s.at,
                        EventKind::Shed {
                            req: s.id,
                            reason: s.reason.label(),
                        },
                    );
                }
                shed_cursor = queue.shed_records().len();
                for p in &self.batch_buf {
                    rec.record(
                        now,
                        EventKind::Batched {
                            req: p.id,
                            window: batches as u64,
                        },
                    );
                }
            }
            if self.batch_buf.is_empty() {
                // Every queued deadline had expired (all shed); reopen
                // the window at the next arrival.
                continue;
            }

            // Model the idle gap, then dispatch through the fleet's
            // placement path (feature routing + wall-clock scores).
            // Input blocks are copied out of the borrowed trace at
            // dispatch time (the batch entry is just the dispatch key
            // plus the request id); a launch failure flushes anything
            // already submitted so the coordinator queue is never left
            // dirty for a later serve() call.
            self.fleet.advance_timeline_to(now);
            let mut launch_err: Option<ApiError> = None;
            for p in &self.batch_buf {
                let req = &requests[p.id];
                let mut launch = match self.fleet.launch_spec_any(p.spec) {
                    Ok(l) => l,
                    Err(e) => {
                        launch_err = Some(e);
                        break;
                    }
                };
                for (base, data) in &req.loads {
                    launch = launch.input_words(*base, data.clone());
                }
                for &(base, len) in &req.unloads {
                    launch = launch.output(base, len);
                }
                launch.submit();
            }
            if let Some(e) = launch_err {
                let _ = self.fleet.sync();
                return Err(e);
            }
            let reports = self.fleet.sync()?;
            assert_eq!(
                reports.len(),
                self.batch_buf.len(),
                "one report per dispatched request"
            );
            self.metrics
                .observe("serve.batch_fill", self.batch_buf.len() as u64);
            for (p, r) in self.batch_buf.drain(..).zip(reports) {
                if let Some(rec) = rec {
                    rec.record(now, EventKind::Dispatched { req: p.id, core: r.core });
                    rec.record(
                        r.start,
                        EventKind::ExecStart {
                            req: p.id,
                            core: r.core,
                            name: r.name.clone(),
                        },
                    );
                    rec.record(
                        r.end,
                        EventKind::ExecEnd {
                            req: p.id,
                            core: r.core,
                            cycles: r.compute_cycles,
                            instructions: r.stats.instructions,
                        },
                    );
                    rec.record(r.end, EventKind::Retired { req: p.id, core: r.core });
                }
                let res = RequestResult {
                    id: p.id,
                    name: r.name,
                    batch: batches,
                    core: r.core,
                    arrival: p.arrival,
                    dispatched: now,
                    start: r.start,
                    end: r.end,
                    deadline: p.deadline,
                    compute_cycles: r.compute_cycles,
                    bus_cycles: r.bus_cycles,
                    outputs: r.outputs,
                };
                telemetry.observe(&res);
                results.push(res);
            }
            batches += 1;
            // Serial batches: the next window closes no earlier than
            // this batch's drain.
            now = now.max(self.fleet.makespan());
        }

        telemetry.batches = batches as u64;
        telemetry.peak_queue = queue.peak();
        telemetry.shed = queue.shed_count() as u64;
        // Serving counters accumulate across serve() calls, matching
        // the fleet's cumulative timeline. Shed reasons are the
        // breakdown the aggregate telemetry lacks.
        self.metrics.inc_by("serve.offered", requests.len() as u64);
        self.metrics.inc_by("serve.served", results.len() as u64);
        self.metrics.inc_by("serve.batches", batches as u64);
        self.metrics
            .inc_by("serve.deadline_missed", telemetry.deadline_missed);
        self.metrics.inc_by("serve.shed.queue_full", 0);
        self.metrics.inc_by("serve.shed.deadline_expired", 0);
        for s in queue.shed_records() {
            self.metrics
                .inc(&format!("serve.shed.{}", s.reason.label()));
        }
        Ok(ServeReport {
            results,
            shed: queue.into_shed(),
            telemetry,
        })
    }
}

/// Admit every request that has arrived by `t`, in arrival order,
/// shedding on overflow at each request's own arrival instant (queue
/// occupancy only changes at dispatch points, so lazy admission is
/// equivalent to admitting eagerly as each request arrives).
fn admit_up_to(
    requests: &[Request],
    feed: &mut VecDeque<usize>,
    queue: &mut AdmissionQueue,
    t: u64,
    rec: Option<&Recorder>,
) {
    while feed.front().is_some_and(|&id| requests[id].arrival <= t) {
        let id = feed.pop_front().expect("front was just inspected");
        let r = &requests[id];
        if queue.offer(id, r, r.arrival) {
            if let Some(rec) = rec {
                rec.record(r.arrival, EventKind::Admitted { req: id });
            }
        }
    }
}
