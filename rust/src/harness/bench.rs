//! Wall-clock micro-benchmark timing (criterion is unavailable offline;
//! the `rust/benches/*` binaries use this instead).

use std::time::Instant;

/// Timing summary over `samples` runs of a closure.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub samples: usize,
    pub median_ns: u128,
    pub mean_ns: u128,
    pub min_ns: u128,
    pub max_ns: u128,
}

impl Timing {
    pub fn median_ms(&self) -> f64 {
        self.median_ns as f64 / 1e6
    }
}

impl std::fmt::Display for Timing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "median {:.3} ms (min {:.3}, max {:.3}, n={})",
            self.median_ns as f64 / 1e6,
            self.min_ns as f64 / 1e6,
            self.max_ns as f64 / 1e6,
            self.samples
        )
    }
}

/// Time `f` `samples` times (after one warmup run). The closure should
/// return something observable to keep the optimizer honest; the value is
/// passed through `std::hint::black_box`.
pub fn time<T>(samples: usize, mut f: impl FnMut() -> T) -> Timing {
    assert!(samples > 0);
    std::hint::black_box(f());
    let mut ns: Vec<u128> = (0..samples)
        .map(|_| {
            let t0 = Instant::now();
            std::hint::black_box(f());
            t0.elapsed().as_nanos()
        })
        .collect();
    ns.sort_unstable();
    Timing {
        samples,
        median_ns: ns[ns.len() / 2],
        mean_ns: ns.iter().sum::<u128>() / ns.len() as u128,
        min_ns: ns[0],
        max_ns: *ns.last().unwrap(),
    }
}

/// Simulation throughput: simulated cycles per wall-clock second.
pub fn sim_rate(cycles: u64, t: &Timing) -> f64 {
    cycles as f64 / (t.median_ns as f64 / 1e9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_orders() {
        let t = time(5, || (0..1000u64).sum::<u64>());
        assert!(t.min_ns <= t.median_ns && t.median_ns <= t.max_ns);
        assert_eq!(t.samples, 5);
    }

    #[test]
    fn rate_math() {
        let t = Timing {
            samples: 1,
            median_ns: 1_000_000, // 1 ms
            mean_ns: 1_000_000,
            min_ns: 1_000_000,
            max_ns: 1_000_000,
        };
        assert_eq!(sim_rate(1000, &t), 1_000_000.0);
    }
}
