//! Shared wiring for the mixed-fleet demo batch.
//!
//! The `egpu fleet` CLI, the perf bench's `fleet` section,
//! `examples/fleet_serving.rs` and the heterogeneity integration test
//! all drive the same kind of batch: a cycle of kernels with mixed
//! feature requirements over the reference 2×DP + 2×QP fleet
//! (`api::FleetBuilder::demo_mixed`). This module is the one
//! definition of that batch's per-kernel data movement, so the four
//! surfaces cannot drift (the fleet itself is already shared the same
//! way).

use super::Rng;
use crate::kernels::{f32_bits, fft, KernelSpec};

/// `(loads, unloads)` for one job: blocks DMA'd in before the run and
/// `(base, len)` spans DMA'd out after.
pub type JobIo = (Vec<(usize, Vec<u32>)>, Vec<(usize, usize)>);

/// The demo batch's kernel cycle at dimension `n`: two any-core
/// kernels (reduction, FFT), two DP-only ones (predicated sort, DOT
/// reduction), and a wide-DMA transpose.
pub fn demo_specs(n: usize) -> [KernelSpec; 5] {
    [
        KernelSpec::Reduction { n },
        KernelSpec::Fft { n },
        KernelSpec::Bitonic { n },
        KernelSpec::ReductionDot { n },
        KernelSpec::Transpose { n },
    ]
}

/// Seeded input/output wiring for one demo spec. Reductions load `n`
/// floats at 0 and unload the scalar at `n`; the sort loads and
/// unloads `[0, n)` in place; the FFT loads `fft::shared_init` and
/// unloads the full `[0, 2n)` complex result; the transpose loads
/// `[0, n²)` and unloads `[n², 2n²)`.
///
/// # Panics
/// On specs outside [`demo_specs`]'s repertoire.
pub fn demo_job_io(spec: &KernelSpec, rng: &mut Rng) -> JobIo {
    let n = spec.dim();
    match spec {
        KernelSpec::Reduction { .. } | KernelSpec::ReductionDot { .. } => {
            let data: Vec<f32> = (0..n).map(|_| rng.f32_in(-2.0, 2.0)).collect();
            (vec![(0, f32_bits(&data))], vec![(n, 1)])
        }
        KernelSpec::Bitonic { .. } => {
            let data: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
            (vec![(0, data)], vec![(0, n)])
        }
        KernelSpec::Fft { .. } => {
            let re: Vec<f32> = (0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
            let im = vec![0f32; n];
            (fft::shared_init(&re, &im), vec![(0, 2 * n)])
        }
        KernelSpec::Transpose { .. } => {
            let mat: Vec<u32> = (0..n * n).map(|_| rng.next_u32()).collect();
            (vec![(0, mat)], vec![(n * n, n * n)])
        }
        other => panic!("no demo IO recipe for {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_demo_spec_has_io() {
        let mut rng = Rng::new(1);
        for spec in demo_specs(64) {
            let (loads, unloads) = demo_job_io(&spec, &mut rng);
            assert!(!loads.is_empty() && !unloads.is_empty(), "{spec}");
        }
    }
}
