//! The §7 benchmark suite runner: every Table 7/8 cell, measured.
//!
//! One function runs a (benchmark, dimension) pair on all four machines —
//! Nios II/e ISS, eGPU-DP, eGPU-QP, eGPU-Dot — verifies each result
//! against the kernel oracle, and returns the cycle counts, elapsed times
//! and Figure 6 profiles. The `rust/benches/table7_*`/`table8_*` binaries,
//! the CLI (`egpu bench`) and `examples/full_eval.rs` all share this path.

use crate::baseline::nios::{Nios, NiosStats, NIOS_MHZ};
use crate::baseline::nios_kernels::{self, FFT_Q};
use crate::kernels::{self, f32_bits, Kernel};
use crate::model::cost::{BENCH_COST_DOT, BENCH_COST_DP, BENCH_COST_NIOS, BENCH_COST_QP};
use crate::sim::config::{EgpuConfig, MemoryMode};
use crate::sim::profiler::Profile;

use super::rng::Rng;

/// The five §7 benchmarks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Benchmark {
    Reduction,
    Transpose,
    Mmm,
    Bitonic,
    Fft,
}

impl Benchmark {
    pub const ALL: [Benchmark; 5] = [
        Benchmark::Reduction,
        Benchmark::Transpose,
        Benchmark::Mmm,
        Benchmark::Bitonic,
        Benchmark::Fft,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Benchmark::Reduction => "Vector Reduction",
            Benchmark::Transpose => "Matrix Transpose",
            Benchmark::Mmm => "Matrix x Matrix",
            Benchmark::Bitonic => "Bitonic Sort",
            Benchmark::Fft => "FFT",
        }
    }

    /// The dimensions the paper reports (Table 7: 32/64/128; Table 8
    /// additionally 256).
    pub fn dims(self) -> &'static [usize] {
        match self {
            Benchmark::Bitonic | Benchmark::Fft => &[32, 64, 128, 256],
            _ => &[32, 64, 128],
        }
    }

    /// Does the paper report an eGPU-Dot column for this benchmark?
    pub fn has_dot(self) -> bool {
        matches!(self, Benchmark::Reduction | Benchmark::Mmm)
    }

    /// Does the eGPU kernel require predicates (cost +50%, §7)?
    pub fn predicated(self) -> bool {
        matches!(self, Benchmark::Bitonic)
    }
}

/// eGPU variant columns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    Nios,
    Dp,
    Qp,
    Dot,
}

impl Variant {
    pub fn label(self) -> &'static str {
        match self {
            Variant::Nios => "Nios",
            Variant::Dp => "eGPU-DP",
            Variant::Qp => "eGPU-QP",
            Variant::Dot => "eGPU-Dot",
        }
    }
}

/// One machine's measurement of one benchmark instance.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub cycles: u64,
    pub mhz: f64,
    /// Instruction/cycle mix (eGPU only; Figure 6).
    pub profile: Option<Profile>,
    /// Dynamic instruction count.
    pub instructions: u64,
}

impl Measurement {
    pub fn time_us(&self) -> f64 {
        self.cycles as f64 / self.mhz
    }
}

/// All four machines on one (benchmark, dim).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub bench: Benchmark,
    pub dim: usize,
    pub nios: Measurement,
    pub dp: Measurement,
    pub qp: Measurement,
    pub dot: Option<Measurement>,
}

impl BenchResult {
    fn get(&self, v: Variant) -> Option<&Measurement> {
        match v {
            Variant::Nios => Some(&self.nios),
            Variant::Dp => Some(&self.dp),
            Variant::Qp => Some(&self.qp),
            Variant::Dot => self.dot.as_ref(),
        }
    }

    /// Cycle ratio vs the eGPU-DP baseline (Table 7/8 "Ratio(cycles)").
    pub fn ratio_cycles(&self, v: Variant) -> Option<f64> {
        Some(self.get(v)?.cycles as f64 / self.dp.cycles as f64)
    }

    /// Time ratio vs the eGPU-DP baseline (Table 7/8 "Ratio(time)").
    pub fn ratio_time(&self, v: Variant) -> Option<f64> {
        Some(self.get(v)?.time_us() / self.dp.time_us())
    }

    /// Resource-normalized ratio (Table 7/8 "Normalized"): time ratio
    /// scaled by the variant's ALM-equivalent cost relative to eGPU-DP.
    /// Predicated benchmarks scale eGPU costs by 1.5 (§7).
    pub fn normalized(&self, v: Variant) -> Option<f64> {
        let pred = if self.bench.predicated() { 1.5 } else { 1.0 };
        let cost = |v: Variant| match v {
            Variant::Nios => BENCH_COST_NIOS,
            Variant::Dp => BENCH_COST_DP * pred,
            Variant::Qp => BENCH_COST_QP * pred,
            Variant::Dot => BENCH_COST_DOT * pred,
        };
        Some(self.ratio_time(v)? * cost(v) / cost(Variant::Dp))
    }
}

fn measure_nios(stats: NiosStats) -> Measurement {
    Measurement {
        cycles: stats.cycles,
        mhz: NIOS_MHZ,
        profile: None,
        instructions: stats.instructions,
    }
}

fn run_egpu(kernel: &Kernel, cfg: &EgpuConfig, init: &[(usize, Vec<u32>)]) -> (Measurement, crate::sim::Machine) {
    // Kernel::run is the api shim (Gpu::launch under the hood) that
    // hands the machine back for the oracle checks below.
    let (stats, m) = kernel
        .run(cfg, init)
        .unwrap_or_else(|e| panic!("{}: {e}", kernel.name));
    assert_eq!(
        stats.hazards, 0,
        "{}: generated program has pipeline hazards: {:?}",
        kernel.name, stats.hazard_samples
    );
    (
        Measurement {
            cycles: stats.cycles,
            mhz: cfg.core_mhz(),
            profile: Some(stats.profile),
            instructions: stats.instructions,
        },
        m,
    )
}

/// Run one benchmark instance on all machines, verifying every result.
pub fn run(bench: Benchmark, dim: usize) -> BenchResult {
    match bench {
        Benchmark::Reduction => run_reduction(dim),
        Benchmark::Transpose => run_transpose(dim),
        Benchmark::Mmm => run_mmm(dim),
        Benchmark::Bitonic => run_bitonic(dim),
        Benchmark::Fft => run_fft(dim),
    }
}

/// Run the full suite (every benchmark × every paper dimension).
pub fn run_all() -> Vec<BenchResult> {
    let mut out = Vec::new();
    for b in Benchmark::ALL {
        for &d in b.dims() {
            out.push(run(b, d));
        }
    }
    out
}

fn run_reduction(n: usize) -> BenchResult {
    // eGPU data: f32; Nios substitutes INT32 (§7).
    let mut rng = Rng::new(0xC0FFEE ^ n as u64);
    let fdata: Vec<f32> = (0..n).map(|_| rng.f32_in(-4.0, 4.0)).collect();
    let idata: Vec<i32> = (0..n).map(|_| rng.range_i64(-1000, 1000) as i32).collect();

    let mut nios = Nios::new(n + 1);
    nios.mem[..n].copy_from_slice(&idata);
    let nstats = nios.run(&nios_kernels::reduction(n), 100_000_000).unwrap();
    assert_eq!(nios.mem[n], idata.iter().sum::<i32>(), "nios reduction-{n}");

    let check = |m: &crate::sim::Machine| {
        let got = f32::from_bits(m.shared().read(n as u32).unwrap());
        let want: f32 = kernels::reduction::oracle(&fdata);
        assert!(
            (got - want).abs() < want.abs() * 1e-4 + 1e-2,
            "reduction-{n}: {got} vs {want}"
        );
    };
    let (dp, m) = run_egpu(
        &kernels::reduction::reduction(n),
        &EgpuConfig::benchmark(MemoryMode::Dp, false),
        &[(0, f32_bits(&fdata))],
    );
    check(&m);
    let (qp, m) = run_egpu(
        &kernels::reduction::reduction(n),
        &EgpuConfig::benchmark(MemoryMode::Qp, false),
        &[(0, f32_bits(&fdata))],
    );
    check(&m);
    let (dot, m) = run_egpu(
        &kernels::reduction::reduction_dot(n),
        &EgpuConfig::benchmark(MemoryMode::Dp, true),
        &[(0, f32_bits(&fdata))],
    );
    check(&m);
    BenchResult {
        bench: Benchmark::Reduction,
        dim: n,
        nios: measure_nios(nstats),
        dp,
        qp,
        dot: Some(dot),
    }
}

fn run_transpose(n: usize) -> BenchResult {
    let mut rng = Rng::new(0xBEEF ^ n as u64);
    let data: Vec<u32> = (0..n * n).map(|_| rng.next_u32()).collect();
    let want = kernels::transpose::oracle(&data, n);

    let mut nios = Nios::new(2 * n * n);
    for (i, &v) in data.iter().enumerate() {
        nios.mem[i] = v as i32;
    }
    let nstats = nios.run(&nios_kernels::transpose(n), 1_000_000_000).unwrap();
    for i in 0..n * n {
        assert_eq!(nios.mem[n * n + i] as u32, want[i], "nios transpose-{n} [{i}]");
    }

    let check = |m: &crate::sim::Machine| {
        assert_eq!(m.shared().read_block(n * n, n * n), &want[..], "transpose-{n}");
    };
    let (dp, m) = run_egpu(
        &kernels::transpose::transpose_for(n, MemoryMode::Dp),
        &EgpuConfig::benchmark(MemoryMode::Dp, false),
        &[(0, data.clone())],
    );
    check(&m);
    let (qp, m) = run_egpu(
        &kernels::transpose::transpose_for(n, MemoryMode::Qp),
        &EgpuConfig::benchmark(MemoryMode::Qp, false),
        &[(0, data.clone())],
    );
    check(&m);
    BenchResult {
        bench: Benchmark::Transpose,
        dim: n,
        nios: measure_nios(nstats),
        dp,
        qp,
        dot: None,
    }
}

fn run_mmm(n: usize) -> BenchResult {
    let mut rng = Rng::new(0x4D4D ^ n as u64);
    let a: Vec<f32> = (0..n * n).map(|_| rng.f32_in(-2.0, 2.0)).collect();
    let b: Vec<f32> = (0..n * n).map(|_| rng.f32_in(-2.0, 2.0)).collect();
    let want = kernels::mmm::oracle(&a, &b, n);
    let ia: Vec<i32> = a.iter().map(|&x| (x * 4.0) as i32).collect();
    let ib: Vec<i32> = b.iter().map(|&x| (x * 4.0) as i32).collect();

    let mut nios = Nios::new(3 * n * n);
    nios.mem[..n * n].copy_from_slice(&ia);
    nios.mem[n * n..2 * n * n].copy_from_slice(&ib);
    let nstats = nios.run(&nios_kernels::mmm(n), 4_000_000_000).unwrap();
    let iwant = |i: usize, j: usize| -> i32 {
        (0..n).map(|k| ia[i * n + k] * ib[k * n + j]).sum()
    };
    for i in [0usize, n / 2, n - 1] {
        for j in [0usize, n / 2, n - 1] {
            assert_eq!(nios.mem[2 * n * n + i * n + j], iwant(i, j), "nios mmm-{n}");
        }
    }

    let check = |m: &crate::sim::Machine| {
        for (idx, w) in want.iter().enumerate() {
            let got = f32::from_bits(m.shared().read((2 * n * n + idx) as u32).unwrap());
            assert!(
                (got - w).abs() < w.abs() * 1e-4 + 1e-2,
                "mmm-{n} C[{idx}]: {got} vs {w}"
            );
        }
    };
    let init = vec![(0, f32_bits(&a)), (n * n, f32_bits(&b))];
    let (dp, m) = run_egpu(
        &kernels::mmm::mmm_for(n, MemoryMode::Dp),
        &kernels::mmm::config(n, MemoryMode::Dp, false),
        &init,
    );
    check(&m);
    let (qp, m) = run_egpu(
        &kernels::mmm::mmm_for(n, MemoryMode::Qp),
        &kernels::mmm::config(n, MemoryMode::Qp, false),
        &init,
    );
    check(&m);
    let (dot, m) = run_egpu(
        &kernels::mmm::mmm_dot(n),
        &kernels::mmm::config(n, MemoryMode::Dp, true),
        &init,
    );
    check(&m);
    BenchResult {
        bench: Benchmark::Mmm,
        dim: n,
        nios: measure_nios(nstats),
        dp,
        qp,
        dot: Some(dot),
    }
}

fn run_bitonic(n: usize) -> BenchResult {
    let mut rng = Rng::new(0x5047 ^ n as u64);
    // Positive values so i32 (Nios) and u32 (eGPU) orderings agree.
    let data: Vec<u32> = (0..n).map(|_| rng.next_u32() >> 2).collect();
    let want = kernels::bitonic::oracle(&data);

    let mut nios = Nios::new(n);
    for (i, &v) in data.iter().enumerate() {
        nios.mem[i] = v as i32;
    }
    let nstats = nios.run(&nios_kernels::bitonic(n), 1_000_000_000).unwrap();
    for i in 0..n {
        assert_eq!(nios.mem[i] as u32, want[i], "nios bitonic-{n} [{i}]");
    }

    let check = |m: &crate::sim::Machine| {
        assert_eq!(m.shared().read_block(0, n), &want[..], "bitonic-{n}");
    };
    let (dp, m) = run_egpu(
        &kernels::bitonic::bitonic_for(n, MemoryMode::Dp),
        &EgpuConfig::benchmark_predicated(MemoryMode::Dp),
        &[(0, data.clone())],
    );
    check(&m);
    let (qp, m) = run_egpu(
        &kernels::bitonic::bitonic_for(n, MemoryMode::Qp),
        &EgpuConfig::benchmark_predicated(MemoryMode::Qp),
        &[(0, data.clone())],
    );
    check(&m);
    BenchResult {
        bench: Benchmark::Bitonic,
        dim: n,
        nios: measure_nios(nstats),
        dp,
        qp,
        dot: None,
    }
}

fn run_fft(n: usize) -> BenchResult {
    let mut rng = Rng::new(0xFF7 ^ n as u64);
    let re: Vec<f32> = (0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
    let im: Vec<f32> = (0..n).map(|_| rng.f32_in(-1.0, 1.0)).collect();
    let (want_r, want_i) = kernels::fft::oracle(&re, &im);

    // Nios: Q14 fixed-point substitution (§7 replaces FP32 with INT32).
    let scale = (1i64 << FFT_Q) as f64;
    let mut nios = Nios::new(3 * n);
    for i in 0..n {
        nios.mem[i] = (re[i] as f64 * scale * 0.25) as i32;
        nios.mem[n + i] = (im[i] as f64 * scale * 0.25) as i32;
    }
    for t in 0..n / 2 {
        let w = 2.0 * std::f64::consts::PI * t as f64 / n as f64;
        nios.mem[2 * n + t] = (w.cos() * scale) as i32;
        nios.mem[2 * n + n / 2 + t] = (w.sin() * scale) as i32;
    }
    let nstats = nios.run(&nios_kernels::fft(n), 1_000_000_000).unwrap();

    let tol = 1e-3 * n as f64;
    let check = |m: &crate::sim::Machine| {
        for k in 0..n {
            let gr = f32::from_bits(m.shared().read(k as u32).unwrap()) as f64;
            let gi = f32::from_bits(m.shared().read((n + k) as u32).unwrap()) as f64;
            assert!(
                (gr - want_r[k]).abs() < tol && (gi - want_i[k]).abs() < tol,
                "fft-{n} bin {k}: ({gr},{gi}) vs ({},{})",
                want_r[k],
                want_i[k]
            );
        }
    };
    let init = kernels::fft::shared_init(&re, &im);
    let (dp, m) = run_egpu(
        &kernels::fft::fft_for(n, MemoryMode::Dp),
        &EgpuConfig::benchmark(MemoryMode::Dp, false),
        &init,
    );
    check(&m);
    let (qp, m) = run_egpu(
        &kernels::fft::fft_for(n, MemoryMode::Qp),
        &EgpuConfig::benchmark(MemoryMode::Qp, false),
        &init,
    );
    check(&m);
    BenchResult {
        bench: Benchmark::Fft,
        dim: n,
        nios: measure_nios(nstats),
        dp,
        qp,
        dot: None,
    }
}

// ---------------------------------------------------------------------
// Paper reference values (Tables 7 and 8), for comparison columns and
// the `paper_tables` integration tests.
// ---------------------------------------------------------------------

/// Published cycle counts: (bench, dim, variant) → cycles.
pub fn paper_cycles(bench: Benchmark, dim: usize, v: Variant) -> Option<u64> {
    use Benchmark::*;
    use Variant::*;
    let t = |v: u64| Some(v);
    match (bench, dim, v) {
        (Reduction, 32, Nios) => t(459),
        (Reduction, 32, Dp) => t(168),
        (Reduction, 32, Qp) => t(160),
        (Reduction, 32, Dot) => t(62),
        (Reduction, 64, Nios) => t(1803),
        (Reduction, 64, Dp) => t(202),
        (Reduction, 64, Qp) => t(194),
        (Reduction, 64, Dot) => t(94),
        (Reduction, 128, Nios) => t(3595),
        (Reduction, 128, Dp) => t(216),
        (Reduction, 128, Qp) => t(208),
        (Reduction, 128, Dot) => t(101),
        (Transpose, 32, Nios) => t(21_809),
        (Transpose, 32, Dp) => t(1720),
        (Transpose, 32, Qp) => t(1208),
        (Transpose, 64, Nios) => t(86_609),
        (Transpose, 64, Dp) => t(5529),
        (Transpose, 64, Qp) => t(3481),
        (Transpose, 128, Nios) => t(345_233),
        (Transpose, 128, Dp) => t(20_481),
        (Transpose, 128, Qp) => t(12_649),
        (Mmm, 32, Nios) => t(1_450_000),
        (Mmm, 32, Dp) => t(111_546),
        (Mmm, 32, Qp) => t(103_354),
        (Mmm, 32, Dot) => t(19_800),
        (Mmm, 64, Nios) => t(11_600_000),
        (Mmm, 64, Dp) => t(451_066),
        (Mmm, 64, Qp) => t(418_671),
        (Mmm, 64, Dot) => t(84_425),
        (Mmm, 128, Nios) => t(92_500_000),
        (Mmm, 128, Dp) => t(2_342_356),
        (Mmm, 128, Qp) => t(2_212_136),
        (Mmm, 128, Dot) => t(886_452),
        (Bitonic, 32, Nios) => t(8457),
        (Bitonic, 32, Dp) => t(1742),
        (Bitonic, 32, Qp) => t(1543),
        (Bitonic, 64, Nios) => t(20_687),
        (Bitonic, 64, Dp) => t(3728),
        (Bitonic, 64, Qp) => t(3054),
        (Bitonic, 128, Nios) => t(49_741),
        (Bitonic, 128, Dp) => t(8326),
        (Bitonic, 128, Qp) => t(6536),
        (Bitonic, 256, Nios) => t(149_271),
        (Bitonic, 256, Dp) => t(16_578),
        (Bitonic, 256, Qp) => t(11_974),
        (Fft, 32, Nios) => t(9165),
        (Fft, 32, Dp) => t(876),
        (Fft, 32, Qp) => t(714),
        (Fft, 64, Nios) => t(20_848),
        (Fft, 64, Dp) => t(1695),
        (Fft, 64, Qp) => t(1312),
        (Fft, 128, Nios) => t(46_667),
        (Fft, 128, Dp) => t(3463),
        (Fft, 128, Qp) => t(2558),
        (Fft, 256, Nios) => t(103_636),
        (Fft, 256, Dp) => t(6813),
        (Fft, 256, Qp) => t(4736),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduction_result_shape() {
        let r = run(Benchmark::Reduction, 32);
        assert!(r.dot.is_some());
        assert!(r.nios.cycles > r.dp.cycles, "SIMT must beat scalar");
        assert!((r.ratio_cycles(Variant::Dp).unwrap() - 1.0).abs() < 1e-9);
        assert!(r.ratio_time(Variant::Nios).unwrap() > 1.0);
        // Dot beats the tree on both cycles and normalized cost.
        assert!(r.normalized(Variant::Dot).unwrap() < 1.0);
    }

    #[test]
    fn paper_reference_complete_for_all_cells() {
        for b in Benchmark::ALL {
            for &d in b.dims() {
                for v in [Variant::Nios, Variant::Dp, Variant::Qp] {
                    assert!(
                        paper_cycles(b, d, v).is_some(),
                        "missing paper value {b:?} {d} {v:?}"
                    );
                }
                assert_eq!(paper_cycles(b, d, Variant::Dot).is_some(), b.has_dot() );
            }
        }
    }

    #[test]
    fn fft_and_bitonic_have_256() {
        assert_eq!(Benchmark::Fft.dims().len(), 4);
        assert_eq!(Benchmark::Reduction.dims().len(), 3);
    }
}
