//! Deterministic seeded load generator for the serving runtime.
//!
//! Drives [`crate::serve::Server`] with reproducible traffic: a seeded
//! arrival process over the shared fleet-demo request mix
//! ([`demo_specs`]/[`demo_job_io`] — the same kernels `egpu fleet`,
//! the perf bench and `examples/fleet_serving.rs` batch over
//! `FleetBuilder::demo_mixed`). The CLI (`egpu serve`), the perf
//! bench's `serving` section and `rust/tests/serve_runtime.rs` all
//! offer traces from here, so "the reference serving workload" has one
//! definition. Everything — arrivals, input data, priorities,
//! deadlines — is derived from the [`LoadSpec`] seed: the same spec
//! always yields a bit-identical trace.
//!
//! The harness is closed-loop end to end: the trace is finite, the
//! server drains it to completion, and backpressure is absorbed by the
//! bounded admission queue (sheds are reported, the backlog cannot
//! grow without bound), so a serving run always terminates with a full
//! accounting of every offered request.

use super::fleet_demo::{demo_job_io, demo_specs};
use super::Rng;
use crate::serve::Request;

/// Knobs for one offered-load trace. All times are modeled bus cycles
/// (the serving layer's clock; convert µs through
/// `Server::us_to_cycles`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LoadSpec {
    /// PRNG seed (arrivals, request data, priorities, deadlines).
    pub seed: u64,
    /// Number of requests to generate.
    pub requests: usize,
    /// Mean inter-arrival gap in bus cycles (gaps are uniform in
    /// `[0, 2·mean]`); 0 = everything arrives at cycle 0 (saturation).
    pub mean_gap: u64,
    /// Kernel dimension for the demo mix.
    pub dim: usize,
    /// Deadline slack in bus cycles: a seeded coin gives half the
    /// requests a deadline of `arrival + slack + jitter` with jitter
    /// uniform in `[0, slack]`; `None` = no deadlines.
    pub deadline_slack: Option<u64>,
}

impl LoadSpec {
    /// The reference trace the CLI and the perf bench use: moderate
    /// offered load against the demo fleet (near its service rate, so
    /// queues form and lingering matters, but shedding stays rare),
    /// with deadlines on half the requests.
    pub fn demo(requests: usize) -> LoadSpec {
        LoadSpec {
            seed: 0x5EED,
            requests,
            mean_gap: 2_000,
            dim: 64,
            deadline_slack: Some(60_000),
        }
    }
}

/// Generate the request trace: the demo kernel mix cycled over
/// `spec.requests`, arrivals from the seeded gap process, priorities
/// uniform in 0..4 (higher = more urgent). Deterministic.
pub fn demo_requests(spec: &LoadSpec) -> Vec<Request> {
    let mut rng = Rng::new(spec.seed);
    let specs = demo_specs(spec.dim);
    let mut at = 0u64;
    let mut out = Vec::with_capacity(spec.requests);
    for i in 0..spec.requests {
        let kspec = specs[i % specs.len()];
        let (loads, unloads) = demo_job_io(&kspec, &mut rng);
        let mut req = Request::new(kspec).at(at);
        for (base, data) in loads {
            req = req.load(base, data);
        }
        for (base, len) in unloads {
            req = req.unload(base, len);
        }
        req = req.priority(rng.below(4) as u8);
        if let Some(slack) = spec.deadline_slack {
            if rng.chance(0.5) {
                // Saturating throughout: absurd slack/gap values clamp
                // instead of overflowing (never a panic path).
                let jitter = rng.below(slack.saturating_add(1) as usize) as u64;
                req = req.due_by(at.saturating_add(slack).saturating_add(jitter));
            }
        }
        out.push(req);
        if spec.mean_gap > 0 {
            let span = spec.mean_gap.saturating_mul(2).saturating_add(1);
            at = at.saturating_add(rng.below(span as usize) as u64);
        }
    }
    out
}

/// Knobs for a seeded heavy-tail trace: bursty arrivals (runs of
/// near-simultaneous requests separated by occasionally very long
/// lulls) over mixed kernel dimensions. This is the traffic shape that
/// actually differentiates fleet compositions — steady single-dim
/// arrivals reward whatever core is fastest, while bursts of mixed
/// sizes reward fleets with enough parallel capacity *and* the right
/// feature coverage. Used by `egpu synth`, the synthesis bench section
/// and the synthesis tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BurstSpec {
    /// PRNG seed (burst lengths, lulls, dims, data, deadlines).
    pub seed: u64,
    /// Number of requests to generate.
    pub requests: usize,
    /// Mean inter-burst gap in bus cycles; lulls stretch it with a
    /// heavy-tail multiplier (see [`heavy_tail_requests`]).
    pub mean_gap: u64,
    /// Largest burst size (each burst is 1..=max_burst requests).
    pub max_burst: usize,
    /// Deadline slack, as in [`LoadSpec::deadline_slack`].
    pub deadline_slack: Option<u64>,
}

impl BurstSpec {
    /// The reference heavy-tail trace for fleet synthesis: bursts of
    /// up to 5 requests over dims {32, 64, 128}, lulls long enough
    /// that batching decisions matter, deadlines loose enough that a
    /// well-shaped fleet can meet most of them.
    pub fn demo(requests: usize) -> BurstSpec {
        BurstSpec {
            seed: 0xB0257,
            requests,
            mean_gap: 24_000,
            max_burst: 5,
            deadline_slack: Some(120_000),
        }
    }
}

/// Generate a heavy-tail trace: requests arrive in bursts (members a
/// few hundred cycles apart), bursts are separated by either a short
/// uniform gap or — with probability 0.2 — a lull of 2–7× the mean
/// gap. Kernel dims are drawn from a mix weighted toward small
/// (32, 32, 32, 64, 64, 128) and the kernel itself uniformly from the
/// demo mix at that dim, so shared-memory demand and feature needs
/// both vary request to request. Deterministic from the seed; arrivals
/// are non-decreasing.
pub fn heavy_tail_requests(spec: &BurstSpec) -> Vec<Request> {
    const DIMS: [usize; 6] = [32, 32, 32, 64, 64, 128];
    let mut rng = Rng::new(spec.seed);
    let max_burst = spec.max_burst.max(1);
    let mut at = 0u64;
    let mut burst_left = 0usize;
    let mut out = Vec::with_capacity(spec.requests);
    for i in 0..spec.requests {
        if burst_left == 0 {
            burst_left = 1 + rng.below(max_burst);
            if i > 0 {
                at = at.saturating_add(if rng.chance(0.2) {
                    // Heavy tail: a lull of 2–7 mean gaps.
                    spec.mean_gap.saturating_mul(2 + rng.below(6) as u64)
                } else {
                    rng.below(spec.mean_gap.saturating_add(1) as usize) as u64
                });
            }
        } else if i > 0 {
            // Within a burst: near-simultaneous arrivals.
            at = at.saturating_add(rng.below(256) as u64);
        }
        burst_left -= 1;
        let dim = *rng.choose(&DIMS);
        let specs = demo_specs(dim);
        let kspec = specs[rng.below(specs.len())];
        let (loads, unloads) = demo_job_io(&kspec, &mut rng);
        let mut req = Request::new(kspec).at(at);
        for (base, data) in loads {
            req = req.load(base, data);
        }
        for (base, len) in unloads {
            req = req.unload(base, len);
        }
        req = req.priority(rng.below(4) as u8);
        if let Some(slack) = spec.deadline_slack {
            if rng.chance(0.5) {
                let jitter = rng.below(slack.saturating_add(1) as usize) as u64;
                req = req.due_by(at.saturating_add(slack).saturating_add(jitter));
            }
        }
        out.push(req);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_are_reproducible_from_the_seed() {
        let spec = LoadSpec::demo(20);
        let a = demo_requests(&spec);
        let b = demo_requests(&spec);
        assert_eq!(a, b, "same seed must yield a bit-identical trace");
        let c = demo_requests(&LoadSpec { seed: 1, ..spec });
        assert_ne!(a, c, "a different seed must perturb the trace");
    }

    #[test]
    fn arrivals_are_sorted_and_mix_cycles() {
        let trace = demo_requests(&LoadSpec::demo(25));
        assert_eq!(trace.len(), 25);
        assert!(trace.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        // The 5-kernel demo mix cycles: request 7 repeats request 2's
        // generator.
        assert_eq!(trace[7].spec.generator(), trace[2].spec.generator());
        // Deadlines, when present, leave room after arrival.
        for r in &trace {
            if let Some(d) = r.deadline {
                assert!(d > r.arrival);
            }
            assert!(!r.loads.is_empty() && !r.unloads.is_empty());
        }
    }

    #[test]
    fn zero_gap_saturates_at_cycle_zero() {
        let trace = demo_requests(&LoadSpec {
            mean_gap: 0,
            deadline_slack: None,
            ..LoadSpec::demo(10)
        });
        assert!(trace.iter().all(|r| r.arrival == 0 && r.deadline.is_none()));
    }

    #[test]
    fn heavy_tail_traces_are_reproducible_and_sorted() {
        let spec = BurstSpec::demo(40);
        let a = heavy_tail_requests(&spec);
        let b = heavy_tail_requests(&spec);
        assert_eq!(a, b, "same seed must yield a bit-identical trace");
        assert_eq!(a.len(), 40);
        assert!(a.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        let c = heavy_tail_requests(&BurstSpec { seed: 7, ..spec });
        assert_ne!(a, c, "a different seed must perturb the trace");
    }

    #[test]
    fn heavy_tail_traces_mix_dims_and_actually_burst() {
        let trace = heavy_tail_requests(&BurstSpec::demo(60));
        // Mixed kernel dimensions: more than one dim must appear.
        let mut dims: Vec<usize> = trace.iter().map(|r| r.spec.dim()).collect();
        dims.sort_unstable();
        dims.dedup();
        assert!(dims.len() > 1, "heavy-tail trace must mix dims, got {dims:?}");
        // Bursty arrivals: some consecutive gaps are tiny (within a
        // burst) and some are huge (a lull) — both tails must show up.
        let gaps: Vec<u64> = trace.windows(2).map(|w| w[1].arrival - w[0].arrival).collect();
        assert!(gaps.iter().any(|&g| g < 256), "no intra-burst gaps seen");
        assert!(
            gaps.iter().any(|&g| g >= 24_000),
            "no heavy-tail lulls seen (max gap {:?})",
            gaps.iter().max()
        );
        // Requests stay fully formed (I/O attached, deadline after
        // arrival when present).
        for r in &trace {
            assert!(!r.loads.is_empty() && !r.unloads.is_empty());
            if let Some(d) = r.deadline {
                assert!(d > r.arrival);
            }
        }
    }
}
