//! Deterministic PRNG for tests, benches and data generation.
//!
//! criterion/proptest are not available in this offline build, so the
//! property tests (`rust/tests/asm_sim_properties.rs`) and workload
//! generators use this splitmix64-seeded xoshiro256** implementation.
//! Everything downstream is reproducible from the seed.

/// xoshiro256** with splitmix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        // splitmix64 to fill the state (never all-zero).
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`. `n` must be positive.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + (self.next_u64() % ((hi - lo) as u64 + 1)) as i64
    }

    /// Uniform f32 in `[lo, hi)`, always normal-range.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        let u = (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32;
        lo + u * (hi - lo)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        ((self.next_u64() >> 11) as f64 / (1u64 << 53) as f64) < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
            let v = r.range_i64(-5, 5);
            assert!((-5..=5).contains(&v));
            let f = r.f32_in(0.5, 2.0);
            assert!((0.5..2.0).contains(&f));
        }
    }

    #[test]
    fn rough_uniformity() {
        let mut r = Rng::new(1);
        let mut buckets = [0usize; 8];
        for _ in 0..8000 {
            buckets[r.below(8)] += 1;
        }
        for b in buckets {
            assert!((800..1200).contains(&b), "{buckets:?}");
        }
    }
}
