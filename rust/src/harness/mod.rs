//! Bench/table/property-test scaffolding.
//!
//! criterion and proptest are unavailable in this offline build, so the
//! `rust/benches/*` binaries (compiled with `harness = false`) and the
//! property tests use this module instead:
//!
//! - [`rng`] — deterministic xoshiro256** PRNG (seeded workloads,
//!   hand-rolled property testing)
//! - [`bench`] — wall-clock micro-benchmark timing
//! - [`table`] — aligned text tables for paper-vs-measured output
//! - [`suite`] — the §7 benchmark suite runner shared by the Table 7/8
//!   benches, the CLI and `examples/full_eval.rs`
//! - [`loadgen`] — seeded request traces for the serving runtime
//!   (`egpu serve`, the perf bench's `serving` section and
//!   `rust/tests/serve_runtime.rs`)

pub mod bench;
pub mod fleet_demo;
pub mod loadgen;
pub mod rng;
pub mod suite;
pub mod table;

pub use bench::{sim_rate, time, Timing};
pub use fleet_demo::{demo_job_io, demo_specs, JobIo};
pub use rng::Rng;
pub use suite::{paper_cycles, run_all, BenchResult, Benchmark, Measurement, Variant};
pub use table::{vs_paper, within_band, Table};
