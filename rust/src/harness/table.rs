//! Plain-text table rendering for the bench binaries and the CLI.
//!
//! Every `rust/benches/*` binary regenerates one of the paper's tables or
//! figures; this module gives them a uniform, diff-able output format.

/// A column-aligned text table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>) -> Table {
        Table {
            title: title.into(),
            headers: Vec::new(),
            rows: Vec::new(),
        }
    }

    pub fn headers<S: Into<String>>(&mut self, hs: impl IntoIterator<Item = S>) -> &mut Self {
        self.headers = hs.into_iter().map(Into::into).collect();
        self
    }

    pub fn row<S: Into<String>>(&mut self, cells: impl IntoIterator<Item = S>) -> &mut Self {
        self.rows.push(cells.into_iter().map(Into::into).collect());
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncols = self
            .headers
            .len()
            .max(self.rows.iter().map(Vec::len).max().unwrap_or(0));
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.headers.iter().enumerate() {
            widths[i] = widths[i].max(h.chars().count());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for i in 0..ncols {
                let c = cells.get(i).map(String::as_str).unwrap_or("");
                if i > 0 {
                    s.push_str("  ");
                }
                s.push_str(&format!("{c:>w$}", w = widths[i]));
            }
            s.trim_end().to_string()
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        if !self.headers.is_empty() {
            out.push_str(&line(&self.headers));
            out.push('\n');
            out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
            out.push('\n');
        }
        for r in &self.rows {
            out.push_str(&line(r));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a measured-vs-paper pair with the ratio, e.g. `1720 / 1641 (0.95x)`.
pub fn vs_paper(measured: f64, paper: f64) -> String {
    format!("{measured:.0} vs {paper:.0} ({:.2}x)", measured / paper)
}

/// Does `measured` fall within `band`× of `paper` (both directions)?
pub fn within_band(measured: f64, paper: f64, band: f64) -> bool {
    let r = measured / paper;
    r <= band && r >= 1.0 / band
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T");
        t.headers(["a", "bbbb"]);
        t.row(["1", "2"]).row(["333", "4"]);
        let s = t.render();
        assert!(s.contains("== T =="));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
        // right-aligned in equal-width columns
        assert!(lines[3].ends_with("   2"));
        assert!(lines[4].starts_with("333"));
    }

    #[test]
    fn band_check() {
        assert!(within_band(150.0, 100.0, 2.0));
        assert!(within_band(60.0, 100.0, 2.0));
        assert!(!within_band(250.0, 100.0, 2.0));
        assert!(!within_band(40.0, 100.0, 2.0));
    }
}
