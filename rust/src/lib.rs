//! eGPU: a statically and dynamically scalable soft GPGPU.
//!
//! Reproduction of Langhammer & Constantinides, *"A Statically and
//! Dynamically Scalable Soft GPGPU"* (2024). The crate contains:
//!
//! - [`api`] — the unified runtime API: [`api::GpuBuilder`] (static
//!   scalability), [`api::Gpu`] + typed [`api::Buffer`]s with uniform
//!   bus accounting, [`api::LaunchBuilder`] (dynamic scalability), and
//!   [`api::Stream`]s over a multi-core [`api::GpuArray`] — start here
//! - [`isa`] — the 61-instruction ISA, instruction-word codec (Figure 3),
//!   dynamic thread-space control (Table 3)
//! - [`asm`] — the assembler/disassembler the benchmarks are written in
//! - [`sim`] — the cycle-accurate SM simulator (16 SPs, predicate stacks,
//!   DP/QP shared-memory port arbitration, 8-stage pipeline model)
//! - [`datapath`] — interchangeable wavefront datapath backends: bit-exact
//!   native rust, or the AOT-compiled XLA artifacts via PJRT
//! - [`runtime`] — the PJRT client wrapper that loads `artifacts/*.hlo.txt`
//! - [`baseline`] — Nios II/e-class scalar ISS and the FlexGrip model used
//!   as comparison points in the paper's §7
//! - [`model`] — the resource (ALM/register/DSP/M20K) and Fmax models that
//!   regenerate Tables 1/4/5/6
//! - [`place`] — the Agilex sector placement model behind Figures 4/5
//! - [`kc`] — the kernel compiler: typed IR over virtual registers, a
//!   hazard-derived list scheduler that fills the interlock-free
//!   pipeline's delay slots, linear-scan register allocation, and direct
//!   lowering to [`asm::Program`]
//! - [`kernels`] — generators for the paper's benchmark programs
//!   (reduction, transpose, MMM, bitonic sort, FFT), built through
//!   [`kc::KernelBuilder`]
//! - [`coordinator`] — multi-core dispatch and the 32-bit data-bus model
//! - [`serve`] — the continuous serving runtime over the fleet:
//!   bounded admission with load-shedding, deadline/priority batching,
//!   and latency telemetry (`api::Server`)
//! - [`obs`] — deterministic modeled-time observability: typed event
//!   recording in bus cycles, the unified [`obs::StatsSnapshot`] /
//!   [`obs::MetricsRegistry`] counter surface, Chrome-trace export and
//!   per-core occupancy reports (`egpu serve --trace-out`)
//! - [`synth`] — workload-driven fleet synthesis: beam search over the
//!   static-configuration space under an Agilex area budget, scored by
//!   trace replay through [`serve`] (`egpu synth`)
//! - [`harness`] — bench/table/property-test scaffolding used by the
//!   `rust/benches/` binaries (criterion is unavailable offline)
//!
//! See DESIGN.md for the paper→module map and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod api;
pub mod asm;
pub mod baseline;
pub mod coordinator;
pub mod datapath;
pub mod harness;
pub mod isa;
pub mod kc;
pub mod kernels;
pub mod model;
pub mod obs;
pub mod place;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod synth;
