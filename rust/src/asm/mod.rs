//! The eGPU assembler.
//!
//! The paper's benchmarks were "written in assembly code (we have not
//! written our compiler yet)" (§7); this module is that assembler. It is
//! line-oriented, two-pass (label collection, then encoding), and performs
//! the same static checks the hardware configuration implies: register
//! indices against the configured register space, instruction groups
//! against the configured feature subset, and shift amounts against the
//! configured shift precision are validated by `sim::config` when a
//! program is loaded.
//!
//! # Syntax
//!
//! ```text
//! ; vector add, one element per thread
//! .mode [w16,dall]          ; default thread-space for following instrs
//! start:
//!     tdx r0                ; r0 = thread id
//!     lod r1, (r0)+0        ; r1 = shared[r0 + 0]
//!     lod r2, (r0)+512
//!     fadd r3, r1, r2
//!     sto r3, (r0)+1024
//!     [w1,d0] stop          ; per-instruction thread-space override
//! ```
//!
//! - comments: `;`, `#` or `//` to end of line
//! - labels: `name:`; branch targets are label names or absolute numbers
//! - TYPE suffixes: `.i32` `.u32` `.f32` (FP mnemonics imply `.f32`)
//! - conditions: `if.lt.i32 r1, r2` (unsigned aliases `lo/ls/hi/hs` imply
//!   `.u32`)
//! - immediates: `#42`, `#-3`, `#0x1F`
//! - thread-space annotation: `[w16|w4|w1, d0|dall|dhalf|dquart]`

mod parser;
mod program;

pub use parser::{assemble, AsmError};
pub use program::{Program, SourceLine};

use crate::isa::{Instr, WordLayout};

/// Disassemble an encoded program back to source text.
pub fn disassemble(words: &[u64], layout: WordLayout) -> Result<String, String> {
    let mut out = String::new();
    for (pc, &w) in words.iter().enumerate() {
        let i = layout
            .decode(w)
            .map_err(|e| format!("word {pc}: {e}"))?;
        out.push_str(&format!("{pc:5}: {}\n", i.disasm()));
    }
    Ok(out)
}

/// Convenience: assemble and return just the decoded instructions.
pub fn assemble_instrs(src: &str, layout: WordLayout) -> Result<Vec<Instr>, AsmError> {
    Ok(assemble(src, layout)?.instrs)
}
