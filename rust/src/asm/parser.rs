//! Two-pass line-oriented parser for eGPU assembly.

use std::collections::BTreeMap;
use std::fmt;

use super::program::{Program, SourceLine};
use crate::isa::opcode::OperandShape;
use crate::isa::{CondCode, DepthSel, Instr, Opcode, TType, ThreadCtrl, WidthSel, WordLayout};

/// Assembly error with line context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AsmError {
    pub line_no: usize,
    pub message: String,
}

impl fmt::Display for AsmError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "line {}: {}", self.line_no, self.message)
    }
}

impl std::error::Error for AsmError {}

fn err<T>(line_no: usize, msg: impl Into<String>) -> Result<T, AsmError> {
    Err(AsmError {
        line_no,
        message: msg.into(),
    })
}

/// Strip comments (`;`, `#` not inside an immediate, `//`).
fn strip_comment(line: &str) -> &str {
    let mut end = line.len();
    let bytes = line.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b';' => {
                end = i;
                break;
            }
            b'/' if i + 1 < bytes.len() && bytes[i + 1] == b'/' => {
                end = i;
                break;
            }
            // '#' starts a comment only when not immediately followed by a
            // number sign or digit (immediates are written `#42`, `#-3`,
            // `#0x..`).
            b'#' => {
                let rest = &line[i + 1..];
                let is_imm = rest
                    .chars()
                    .next()
                    .map(|c| c.is_ascii_digit() || c == '-' || c == '+')
                    .unwrap_or(false);
                if !is_imm {
                    end = i;
                    break;
                }
                i += 1;
            }
            _ => i += 1,
        }
    }
    line[..end].trim()
}

/// Parse a `[w..,d..]` annotation; returns (ctrl, rest-of-line).
fn parse_annotation(line: &str, line_no: usize) -> Result<(Option<ThreadCtrl>, &str), AsmError> {
    let line = line.trim_start();
    if !line.starts_with('[') {
        return Ok((None, line));
    }
    let close = match line.find(']') {
        Some(c) => c,
        None => return err(line_no, "unterminated thread-space annotation"),
    };
    let inner = &line[1..close];
    let mut width = None;
    let mut depth = None;
    for part in inner.split(',') {
        let p = part.trim().to_ascii_lowercase();
        if let Some(w) = WidthSel::from_name(&p) {
            width = Some(w);
        } else if let Some(d) = DepthSel::from_name(&p) {
            depth = Some(d);
        } else {
            return err(line_no, format!("unknown thread-space selector '{p}'"));
        }
    }
    let tc = ThreadCtrl::new(width.unwrap_or_default(), depth.unwrap_or_default());
    Ok((Some(tc), line[close + 1..].trim_start()))
}

fn parse_reg(tok: &str, layout: WordLayout, line_no: usize) -> Result<u8, AsmError> {
    let t = tok.trim();
    if let Some(n) = t.strip_prefix('r').or_else(|| t.strip_prefix('R')) {
        if let Ok(v) = n.parse::<u32>() {
            if v <= layout.max_reg() as u32 {
                return Ok(v as u8);
            }
            return err(
                line_no,
                format!(
                    "register r{v} exceeds the configured register space (max r{})",
                    layout.max_reg()
                ),
            );
        }
    }
    err(line_no, format!("expected register, got '{t}'"))
}

fn parse_int(tok: &str, line_no: usize) -> Result<i64, AsmError> {
    let t = tok.trim();
    let (neg, t) = match t.strip_prefix('-') {
        Some(rest) => (true, rest),
        None => (false, t.strip_prefix('+').unwrap_or(t)),
    };
    let v = if let Some(hex) = t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
        i64::from_str_radix(hex, 16)
    } else if let Some(bin) = t.strip_prefix("0b").or_else(|| t.strip_prefix("0B")) {
        i64::from_str_radix(bin, 2)
    } else {
        t.parse::<i64>()
    };
    match v {
        Ok(v) => Ok(if neg { -v } else { v }),
        Err(_) => err(line_no, format!("bad integer literal '{tok}'")),
    }
}

fn parse_imm(tok: &str, line_no: usize) -> Result<u16, AsmError> {
    let t = tok.trim();
    let t = t.strip_prefix('#').unwrap_or(t);
    let v = parse_int(t, line_no)?;
    if !(-32768..=65535).contains(&v) {
        return err(line_no, format!("immediate {v} does not fit in 16 bits"));
    }
    Ok(v as u16)
}

/// Split mnemonic into (base opcode token, suffix tokens).
fn split_mnemonic(m: &str) -> (String, Vec<String>) {
    let mut parts = m.split('.');
    let base = parts.next().unwrap_or("").to_ascii_lowercase();
    let suffixes = parts.map(|s| s.to_ascii_lowercase()).collect();
    (base, suffixes)
}

struct PendingInstr {
    instr: Instr,
    /// Unresolved branch target label, if any.
    target: Option<String>,
    line_no: usize,
}

/// Assemble source text into a `Program`.
pub fn assemble(src: &str, layout: WordLayout) -> Result<Program, AsmError> {
    let mut labels: BTreeMap<String, usize> = BTreeMap::new();
    let mut pending: Vec<PendingInstr> = Vec::new();
    let mut source: Vec<SourceLine> = Vec::new();
    let mut default_tc = ThreadCtrl::FULL;

    for (idx, raw_line) in src.lines().enumerate() {
        let line_no = idx + 1;
        let mut line = strip_comment(raw_line);
        if line.is_empty() {
            continue;
        }

        // Labels (possibly several, possibly followed by an instruction).
        while let Some(colon) = line.find(':') {
            let (name, rest) = line.split_at(colon);
            let name = name.trim();
            if name.is_empty()
                || !name
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_')
                || name.chars().next().unwrap().is_ascii_digit()
            {
                break; // not a label — let the instruction parser complain
            }
            if labels.insert(name.to_string(), pending.len()).is_some() {
                return err(line_no, format!("duplicate label '{name}'"));
            }
            line = rest[1..].trim_start();
        }
        if line.is_empty() {
            continue;
        }

        // Directives.
        if let Some(rest) = line.strip_prefix(".mode") {
            let (tc, leftover) = parse_annotation(rest.trim_start(), line_no)?;
            let tc = match tc {
                Some(tc) => tc,
                None => {
                    // Allow `.mode w16, dall` without brackets.
                    let mut width = WidthSel::default();
                    let mut depth = DepthSel::default();
                    let mut any = false;
                    for part in rest.split(',') {
                        let p = part.trim().to_ascii_lowercase();
                        if p.is_empty() {
                            continue;
                        }
                        if let Some(w) = WidthSel::from_name(&p) {
                            width = w;
                            any = true;
                        } else if let Some(d) = DepthSel::from_name(&p) {
                            depth = d;
                            any = true;
                        } else {
                            return err(line_no, format!("bad .mode selector '{p}'"));
                        }
                    }
                    if !any {
                        return err(line_no, ".mode needs selectors");
                    }
                    default_tc = ThreadCtrl::new(width, depth);
                    continue;
                }
            };
            if !leftover.is_empty() {
                return err(line_no, "unexpected text after .mode");
            }
            default_tc = tc;
            continue;
        }
        if line.starts_with('.') {
            return err(line_no, format!("unknown directive '{line}'"));
        }

        // Optional per-instruction thread-space annotation.
        let (tc_override, rest) = parse_annotation(line, line_no)?;
        let tc = tc_override.unwrap_or(default_tc);

        // Mnemonic and operand split.
        let rest = rest.trim();
        let (mn, ops_str) = match rest.find(char::is_whitespace) {
            Some(sp) => (&rest[..sp], rest[sp..].trim()),
            None => (rest, ""),
        };
        let (base, suffixes) = split_mnemonic(mn);
        let op = match Opcode::from_mnemonic(&base) {
            Some(op) => op,
            None => return err(line_no, format!("unknown instruction '{base}'")),
        };

        let mut instr = Instr::new(op);
        instr.tc = tc;

        // TYPE / condition-code suffixes.
        let mut cc: Option<CondCode> = None;
        let mut ttype: Option<TType> = None;
        for s in &suffixes {
            if let Some(t) = TType::from_suffix(s) {
                if ttype.replace(t).is_some() {
                    return err(line_no, "duplicate TYPE suffix");
                }
            } else if let Some((c, unsigned)) = CondCode::from_mnemonic(s) {
                if op != Opcode::If {
                    return err(line_no, format!("condition suffix '.{s}' only valid on IF"));
                }
                if cc.replace(c).is_some() {
                    return err(line_no, "duplicate condition suffix");
                }
                if unsigned {
                    ttype.get_or_insert(TType::Uint);
                }
            } else {
                return err(line_no, format!("unknown suffix '.{s}'"));
            }
        }
        if op == Opcode::If && cc.is_none() {
            return err(line_no, "IF needs a condition code (e.g. if.lt.i32)");
        }
        instr.ttype = match ttype {
            Some(t) => t,
            None if op.group() == crate::isa::Group::FpAlu
                || op == Opcode::InvSqr
                || op == Opcode::Dot
                || op == Opcode::Sum =>
            {
                TType::Fp32
            }
            None => TType::Int,
        };
        if let Some(c) = cc {
            instr.imm = c.bits() as u16;
        }

        // Operands.
        let operands: Vec<&str> = if ops_str.is_empty() {
            vec![]
        } else {
            ops_str.split(',').map(|s| s.trim()).collect()
        };
        let mut target: Option<String> = None;
        let shape = op.operands();
        let expect = |n: usize| -> Result<(), AsmError> {
            if operands.len() != n {
                err(
                    line_no,
                    format!(
                        "{} expects {n} operand(s), got {}",
                        op.mnemonic(),
                        operands.len()
                    ),
                )
            } else {
                Ok(())
            }
        };
        match shape {
            OperandShape::None => expect(0)?,
            OperandShape::Rd => {
                expect(1)?;
                instr.rd = parse_reg(operands[0], layout, line_no)?;
            }
            OperandShape::RdRa => {
                expect(2)?;
                instr.rd = parse_reg(operands[0], layout, line_no)?;
                instr.ra = parse_reg(operands[1], layout, line_no)?;
            }
            OperandShape::RdRaRb => {
                expect(3)?;
                instr.rd = parse_reg(operands[0], layout, line_no)?;
                instr.ra = parse_reg(operands[1], layout, line_no)?;
                instr.rb = parse_reg(operands[2], layout, line_no)?;
            }
            OperandShape::RaRb => {
                expect(2)?;
                instr.ra = parse_reg(operands[0], layout, line_no)?;
                instr.rb = parse_reg(operands[1], layout, line_no)?;
            }
            OperandShape::RdMem => {
                expect(2)?;
                instr.rd = parse_reg(operands[0], layout, line_no)?;
                // `(ra)+imm` or `(ra)` with implicit 0.
                let m = operands[1];
                let open = m.find('(');
                let close = m.find(')');
                match (open, close) {
                    (Some(o), Some(c)) if c > o => {
                        instr.ra = parse_reg(&m[o + 1..c], layout, line_no)?;
                        let off = m[c + 1..].trim();
                        let off = off.strip_prefix('+').unwrap_or(off).trim();
                        if !off.is_empty() {
                            let v = parse_int(off, line_no)?;
                            if !(0..=65535).contains(&v) {
                                return err(line_no, format!("offset {v} out of range"));
                            }
                            instr.imm = v as u16;
                        }
                    }
                    _ => {
                        return err(
                            line_no,
                            format!("expected memory operand '(rN)+off', got '{m}'"),
                        )
                    }
                }
            }
            OperandShape::RdImm => {
                expect(2)?;
                instr.rd = parse_reg(operands[0], layout, line_no)?;
                instr.imm = parse_imm(operands[1], line_no)?;
            }
            OperandShape::Imm => {
                expect(1)?;
                instr.imm = parse_imm(operands[0], line_no)?;
            }
            OperandShape::Addr => {
                expect(1)?;
                let t = operands[0];
                if t.chars().next().map(|c| c.is_ascii_digit()).unwrap_or(false) {
                    instr.imm = parse_imm(t, line_no)?;
                } else {
                    target = Some(t.to_string());
                }
            }
        }

        source.push(SourceLine {
            line_no,
            text: raw_line.trim().to_string(),
        });
        pending.push(PendingInstr {
            instr,
            target,
            line_no,
        });
    }

    // Pass 2: resolve labels, encode.
    let mut instrs = Vec::with_capacity(pending.len());
    let mut words = Vec::with_capacity(pending.len());
    for p in pending {
        let mut i = p.instr;
        if let Some(t) = &p.target {
            match labels.get(t) {
                Some(&addr) => {
                    if addr > 0xFFFF {
                        return err(p.line_no, format!("label '{t}' address {addr} overflows"));
                    }
                    i.imm = addr as u16;
                }
                None => return err(p.line_no, format!("undefined label '{t}'")),
            }
        }
        words.push(layout.encode(&i));
        instrs.push(i);
    }

    // Pass 3: compile the decode-time issue plans (classification,
    // operand shape, thread-space geometry, profiler slots) so the
    // simulator's hot loop never re-derives them. Infallible on parser
    // output — the condition-code and opcode checks above already ran —
    // but mapped to a source line defensively.
    let plans = crate::sim::plan::compile(&instrs).map_err(|e| AsmError {
        line_no: source.get(e.pc).map(|s| s.line_no).unwrap_or(0),
        message: e.message,
    })?;

    Ok(Program {
        instrs,
        words,
        labels,
        layout,
        source,
        plans,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Group;

    fn l32() -> WordLayout {
        WordLayout::for_regs(32)
    }

    #[test]
    fn basic_program() {
        let src = "
            tdx r0
            lod r1, (r0)+0
            fadd r2, r1, r1
            sto r2, (r0)+64
            stop
        ";
        let p = assemble(src, l32()).unwrap();
        assert_eq!(p.len(), 5);
        assert_eq!(p.instrs[0].op, Opcode::TdX);
        assert_eq!(p.instrs[1].op, Opcode::Lod);
        assert_eq!(p.instrs[1].imm, 0);
        assert_eq!(p.instrs[3].imm, 64);
        assert_eq!(p.instrs[2].ttype, TType::Fp32);
    }

    #[test]
    fn labels_and_branches() {
        let src = "
            ldi r0, #0
            init #3
        top:
            add.i32 r0, r0, r0
            loop top
            jmp end
            nop
        end:
            stop
        ";
        let p = assemble(src, l32()).unwrap();
        assert_eq!(p.labels["top"], 2);
        assert_eq!(p.labels["end"], 6);
        let loop_i = &p.instrs[3];
        assert_eq!(loop_i.op, Opcode::Loop);
        assert_eq!(loop_i.imm_u(), 2);
        assert_eq!(p.instrs[4].imm_u(), 6);
    }

    #[test]
    fn undefined_label_errors() {
        let e = assemble("jmp nowhere\n", l32()).unwrap_err();
        assert!(e.message.contains("undefined label"));
    }

    #[test]
    fn duplicate_label_errors() {
        let e = assemble("a:\na:\nnop\n", l32()).unwrap_err();
        assert!(e.message.contains("duplicate label"));
    }

    #[test]
    fn type_suffixes() {
        let p = assemble("add.u32 r1, r2, r3\nshr.i32 r1, r2, r3\n", l32()).unwrap();
        assert_eq!(p.instrs[0].ttype, TType::Uint);
        assert_eq!(p.instrs[1].ttype, TType::Int);
    }

    #[test]
    fn if_conditions() {
        let p = assemble(
            "if.lt.i32 r1, r2\nelse\nendif\nif.hs r3, r4\nif.gt.f32 r1, r2\n",
            l32(),
        )
        .unwrap();
        assert_eq!(p.instrs[0].cond(), Some(CondCode::Lt));
        assert_eq!(p.instrs[0].ttype, TType::Int);
        // unsigned alias implies UINT
        assert_eq!(p.instrs[3].cond(), Some(CondCode::Ge));
        assert_eq!(p.instrs[3].ttype, TType::Uint);
        assert_eq!(p.instrs[4].ttype, TType::Fp32);
        assert_eq!(p.instrs[1].op, Opcode::Else);
    }

    #[test]
    fn if_without_condition_errors() {
        let e = assemble("if r1, r2\n", l32()).unwrap_err();
        assert!(e.message.contains("condition code"));
    }

    #[test]
    fn annotations_and_mode() {
        let src = "
            .mode [w4,dhalf]
            add.i32 r1, r1, r1
            [w1,d0] sto r1, (r0)+0
            add.i32 r2, r2, r2
        ";
        let p = assemble(src, l32()).unwrap();
        assert_eq!(
            p.instrs[0].tc,
            ThreadCtrl::new(WidthSel::Quarter4, DepthSel::Half)
        );
        assert_eq!(p.instrs[1].tc, ThreadCtrl::MCU);
        // .mode persists past per-instruction overrides
        assert_eq!(
            p.instrs[2].tc,
            ThreadCtrl::new(WidthSel::Quarter4, DepthSel::Half)
        );
    }

    #[test]
    fn register_range_checked_against_layout() {
        let e = assemble("add.i32 r16, r0, r0\n", WordLayout::for_regs(16)).unwrap_err();
        assert!(e.message.contains("exceeds"));
        assert!(assemble("add.i32 r16, r0, r0\n", l32()).is_ok());
    }

    #[test]
    fn immediates_hex_negative() {
        let p = assemble("ldi r1, #0x1F\nldi r2, #-5\nldi r3, #0b101\n", l32()).unwrap();
        assert_eq!(p.instrs[0].imm_i(), 31);
        assert_eq!(p.instrs[1].imm_i(), -5);
        assert_eq!(p.instrs[2].imm_i(), 5);
    }

    #[test]
    fn immediate_overflow_errors() {
        assert!(assemble("ldi r1, #70000\n", l32()).is_err());
        assert!(assemble("ldi r1, #-40000\n", l32()).is_err());
    }

    #[test]
    fn comments_stripped() {
        let src = "nop ; trailing\nnop // c++ style\nnop # hash comment\nldi r1, #3 ; imm keeps hash\n";
        let p = assemble(src, l32()).unwrap();
        assert_eq!(p.len(), 4);
        assert_eq!(p.instrs[3].imm_i(), 3);
    }

    #[test]
    fn wrong_operand_count_errors() {
        assert!(assemble("add.i32 r1, r2\n", l32()).is_err());
        assert!(assemble("rts r1\n", l32()).is_err());
        assert!(assemble("tdx\n", l32()).is_err());
    }

    #[test]
    fn mem_operand_forms() {
        let p = assemble("lod r1, (r2)\nlod r1, (r2)+8\nsto r1, (r2)+0x10\n", l32()).unwrap();
        assert_eq!(p.instrs[0].imm, 0);
        assert_eq!(p.instrs[1].imm, 8);
        assert_eq!(p.instrs[2].imm, 16);
    }

    #[test]
    fn disassemble_roundtrip() {
        let src = "
            .mode [w16,dall]
            tdx r0
            ldi r1, #-7
            fadd r2, r1, r0
            max.u32 r3, r2, r1
            lod r4, (r0)+12
            [w1,d0] sto r4, (r0)+3
            if.le.f32 r2, r4
            else
            endif
            dot r5, r2, r4
            invsqr r6, r5
            jsr 14
            rts
            init #7
            stop
        ";
        let p = assemble(src, l32()).unwrap();
        // Re-assemble the disassembly; encodings must be identical.
        let dis: String = p
            .instrs
            .iter()
            .map(|i| format!("{}\n", i.disasm()))
            .collect();
        let p2 = assemble(&dis, l32()).unwrap();
        assert_eq!(p.words, p2.words);
    }

    #[test]
    fn plans_compiled_at_assembly() {
        use crate::sim::plan::PlanKind;
        let p = assemble("tdx r0\nlod r1, (r0)+4\nif.lt.i32 r0, r1\nendif\nstop\n", l32())
            .unwrap();
        assert_eq!(p.plans.len(), p.instrs.len());
        assert_eq!(p.plans[0].kind, PlanKind::TdX);
        assert_eq!(p.plans[1].kind, PlanKind::Load);
        assert_eq!(p.plans[1].imm, 4);
        assert!(matches!(p.plans[2].kind, PlanKind::If { .. }));
        assert_eq!(p.plans[4].kind, PlanKind::Stop);
    }

    #[test]
    fn numeric_branch_targets() {
        let p = assemble("jmp 5\nloop 0\n", l32()).unwrap();
        assert_eq!(p.instrs[0].imm_u(), 5);
        assert_eq!(p.instrs[1].imm_u(), 0);
    }

    #[test]
    fn fp_mnemonics_imply_fp32() {
        let p = assemble("fmul r1, r2, r3\ndot r4, r5, r6\nsum r4, r5, r6\ninvsqr r1, r2\n", l32())
            .unwrap();
        for i in &p.instrs {
            assert_eq!(i.ttype, TType::Fp32, "{:?}", i.op);
        }
        assert_eq!(p.instrs[1].op.group(), Group::Extension);
    }
}
