//! Assembled-program container.

use std::collections::BTreeMap;

use crate::isa::{Instr, WordLayout};
use crate::sim::plan::IssuePlan;

/// Mapping from an instruction back to its source line (for errors,
/// listings and the hazard checker's diagnostics).
#[derive(Debug, Clone)]
pub struct SourceLine {
    pub line_no: usize,
    pub text: String,
}

/// An assembled eGPU program: decoded instructions plus the encoded words
/// exactly as they would sit in the instruction M20Ks, plus the
/// decode-time issue plans the simulator executes from.
#[derive(Debug, Clone)]
pub struct Program {
    pub instrs: Vec<Instr>,
    pub words: Vec<u64>,
    pub labels: BTreeMap<String, usize>,
    pub layout: WordLayout,
    pub source: Vec<SourceLine>,
    /// Pre-compiled issue plans, one per instruction
    /// ([`crate::sim::plan`]), produced at assembly — both an early
    /// validation pass and an inspectable artifact. Because every field
    /// here is public (and `instrs` may be edited in place),
    /// `Machine::load_program` recompiles plans from `instrs` rather
    /// than trusting these; hand-built programs may leave the vector
    /// empty.
    pub plans: Vec<IssuePlan>,
}

impl Program {
    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Number of M20Ks needed to store this program (§5.4): an M20K holds
    /// 20480 bits (512 × 40), so a program of `n` words of `word_bits`
    /// packs into ⌈n·word_bits / 20480⌉ blocks — reproducing the paper's
    /// "1k word program space would require three M20Ks [43-bit IW], and a
    /// 4k program space nine M20Ks".
    pub fn instruction_m20ks(&self) -> usize {
        let n = self.len().max(1);
        (n * self.layout.word_bits() as usize).div_ceil(20480)
    }

    /// Assembly listing with addresses, encodings and source.
    pub fn listing(&self) -> String {
        let mut out = String::new();
        let hexw = (self.layout.word_bits() as usize).div_ceil(4);
        for (pc, (i, w)) in self.instrs.iter().zip(&self.words).enumerate() {
            out.push_str(&format!("{pc:5}  {w:0hexw$x}  {}\n", i.disasm()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::assemble;

    #[test]
    fn instruction_m20k_counts_match_paper() {
        // §5.4: "A single M20K can store 512 40-bit instruction words";
        // "a 1k word program space would require three M20Ks [43-bit IW],
        // and a 4k program space nine M20Ks".
        let l40 = WordLayout::for_regs(16);
        let l43 = WordLayout::for_regs(32);
        let mk = |n: usize, layout: WordLayout| Program {
            instrs: vec![crate::isa::Instr::nop(); n],
            words: vec![0; n],
            labels: BTreeMap::new(),
            layout,
            source: vec![],
            plans: vec![],
        };
        assert_eq!(mk(512, l40).instruction_m20ks(), 1);
        assert_eq!(mk(1024, l43).instruction_m20ks(), 3);
        assert_eq!(mk(4096, l43).instruction_m20ks(), 9);
    }

    #[test]
    fn listing_contains_every_instruction() {
        let src = "tdx r0\nfadd r1, r0, r0\nstop\n";
        let p = assemble(src, WordLayout::for_regs(16)).unwrap();
        let listing = p.listing();
        assert_eq!(listing.lines().count(), 3);
        assert!(listing.contains("fadd r1, r0, r0"));
    }
}
