//! `kc` — the kernel compiler.
//!
//! The paper wrote its benchmarks by hand: "All benchmarks were written in
//! assembly code (we have not written our compiler yet)" (§7), and on a
//! statically scheduled, interlock-free pipeline the compiler *is* the
//! performance story — every RAW/memory/extension-core delay slot that
//! isn't filled with useful work becomes a NOP. This module is that
//! compiler layer:
//!
//! 1. **IR** ([`ir::KernelBuilder`], [`V`]) — typed instructions over
//!    virtual registers, with labels, hardware loops, subroutines and
//!    predicates. `_into` redefinitions express predicated merges and
//!    loop-carried updates.
//! 2. **Dependence graph + schedule** (`sched`) — dependences and
//!    latencies derive from the *one* authoritative hazard model
//!    (`sim::hazard` windows + the issue charges of `Machine::step_plan`).
//!    A list scheduler moves independent instructions into the delay slots
//!    and pads only residual slack; per chain it never emits more cycles
//!    than the in-order padded form.
//! 3. **Register allocation** (`regalloc`) — linear scan onto the
//!    configured `WordLayout`, with one assignment shared by every
//!    schedule mode so scheduled and fenced builds are register-identical.
//! 4. **Lowering** (`lower`) — directly to [`crate::asm::Program`] (words
//!    encoded, labels resolved, issue plans attached); the pretty-printed
//!    listing is kept only for humans and reassembles to the identical
//!    program.
//!
//! Three build modes pin correctness the way PR 2's issue-plan engine was
//! pinned: [`SchedMode::Fenced`] (full pipeline settle before every
//! instruction — the schedule-disabled oracle), [`SchedMode::Linear`]
//! (original order, minimal padding — the legacy `kernels::Sched`
//! behavior), and [`SchedMode::List`]. For every kernel the scheduled and
//! fenced builds must produce bit-identical registers and shared memory
//! through `Machine::run`, with zero hazards and `List ≤ Linear ≤ Fenced`
//! cycles (`rust/tests/kc_schedule.rs`).

pub mod ir;
mod lower;
mod regalloc;
mod sched;

pub use ir::{KernelBuilder, V};

use crate::asm::Program;

/// Which schedule a build emits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedMode {
    /// List-scheduled: independent instructions fill the delay slots.
    List,
    /// Original order with minimal RAW/memory padding (what the legacy
    /// string emitter produced).
    Linear,
    /// Original order with a full pipeline settle before every
    /// instruction — the schedule-disabled correctness oracle.
    Fenced,
}

impl SchedMode {
    pub fn name(self) -> &'static str {
        match self {
            SchedMode::List => "list",
            SchedMode::Linear => "linear",
            SchedMode::Fenced => "fenced",
        }
    }
}

/// Static schedule statistics for one compiled kernel. All three modes are
/// measured on every build (the layouts are needed for register allocation
/// anyway), so the delay-slot win is always reportable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScheduleStats {
    /// The mode the emitted program uses.
    pub mode: SchedMode,
    /// Real (non-NOP) instructions.
    pub instructions: usize,
    pub nops_scheduled: usize,
    pub nops_linear: usize,
    pub nops_fenced: usize,
    /// Straight-line cycle estimates (loop bodies counted once); dynamic
    /// modeled cycles come from running the program.
    pub static_cycles_scheduled: u64,
    pub static_cycles_linear: u64,
    pub static_cycles_fenced: u64,
}

impl ScheduleStats {
    /// Straight-line cycle estimate of the mode the kernel actually
    /// emitted — the figure wall-clock-aware fleet placement scales by
    /// a core's clock when choosing among eligible cores.
    pub fn static_cycles_emitted(&self) -> u64 {
        match self.mode {
            SchedMode::List => self.static_cycles_scheduled,
            SchedMode::Linear => self.static_cycles_linear,
            SchedMode::Fenced => self.static_cycles_fenced,
        }
    }

    /// NOPs eliminated by list scheduling relative to in-order padding.
    pub fn nops_filled(&self) -> usize {
        self.nops_linear.saturating_sub(self.nops_scheduled)
    }

    /// Static-cycle reduction of the list schedule vs in-order padding,
    /// as a fraction of the padded cycles.
    pub fn static_reduction_vs_linear(&self) -> f64 {
        if self.static_cycles_linear == 0 {
            return 0.0;
        }
        1.0 - self.static_cycles_scheduled as f64 / self.static_cycles_linear as f64
    }
}

/// A compiled kernel: the program (plans attached), its listing, and the
/// schedule statistics.
#[derive(Debug, Clone)]
pub struct Compiled {
    pub program: Program,
    pub asm: String,
    pub stats: ScheduleStats,
}

/// Compilation error (register pressure, label resolution).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KcError(pub String);

impl std::fmt::Display for KcError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "kernel compiler: {}", self.0)
    }
}

impl std::error::Error for KcError {}

impl KernelBuilder {
    /// Schedule, allocate and lower the kernel in the requested mode.
    pub fn finish(self, mode: SchedMode) -> Result<Compiled, KcError> {
        let flat = sched::flatten(&self);
        let model = sched::CostModel::new(self.threads, self.memory);
        let lay_list = sched::schedule(&flat, &model, SchedMode::List);
        let lay_linear = sched::schedule(&flat, &model, SchedMode::Linear);
        let lay_fenced = sched::schedule(&flat, &model, SchedMode::Fenced);
        // One register assignment valid across all three layouts: the
        // List/Linear/Fenced builds of a kernel differ only in NOPs and
        // instruction order, never in register names — which is what lets
        // the correctness harness compare their register files bit for
        // bit.
        let assignment = regalloc::allocate(
            &flat,
            &[&lay_list, &lay_linear, &lay_fenced],
            &model,
            self.layout.max_reg(),
        )
        .map_err(KcError)?;
        let chosen = match mode {
            SchedMode::List => &lay_list,
            SchedMode::Linear => &lay_linear,
            SchedMode::Fenced => &lay_fenced,
        };
        let (program, asm) =
            lower::lower(&self.name, self.threads, &flat, chosen, &assignment, self.layout)
                .map_err(KcError)?;
        let stats = ScheduleStats {
            mode,
            instructions: flat.nodes.len(),
            nops_scheduled: lay_list.nops,
            nops_linear: lay_linear.nops,
            nops_fenced: lay_fenced.nops,
            static_cycles_scheduled: lay_list.end_cycle,
            static_cycles_linear: lay_linear.end_cycle,
            static_cycles_fenced: lay_fenced.end_cycle,
        };
        Ok(Compiled { program, asm, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::assemble;
    use crate::isa::{DepthSel, ThreadCtrl, WidthSel, WordLayout};
    use crate::sim::config::{EgpuConfig, MemoryMode};
    use crate::sim::hazard::REG_WINDOW;
    use crate::sim::Machine;

    fn layout() -> WordLayout {
        WordLayout::for_regs(32)
    }

    fn run(c: &Compiled, threads: usize) -> crate::sim::RunStats {
        let mut cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
        cfg.dot_core = true;
        let mut m = Machine::new(cfg).unwrap();
        m.set_threads(threads).unwrap();
        m.load_program(c.program.clone()).unwrap();
        m.run(1_000_000).unwrap()
    }

    /// A shallow (1-wave) dependent chain next to independent work: list
    /// scheduling must fill the delay slots the linear form pads.
    fn chain_with_filler(mode: SchedMode) -> Compiled {
        let mut b = KernelBuilder::new("t", 16, layout(), MemoryMode::Dp);
        let x = b.ldi(1);
        let y = b.op2(crate::isa::Opcode::Add, crate::isa::TType::Uint, x, x);
        let z = b.add_u(y, y);
        let w = b.add_u(z, z);
        // Independent work the scheduler can move into the slots.
        let a = b.ldi(10);
        let bb = b.ldi(11);
        let c = b.ldi(12);
        let d = b.add_u(a, bb);
        let e = b.add_u(c, d);
        let f = b.add_u(w, e);
        let base = b.ldi(64);
        b.sto(f, base, 0);
        b.stop();
        b.finish(mode).unwrap()
    }

    #[test]
    fn list_fills_delay_slots_of_a_shallow_chain() {
        let list = chain_with_filler(SchedMode::List);
        let linear = chain_with_filler(SchedMode::Linear);
        let fenced = chain_with_filler(SchedMode::Fenced);
        assert!(
            list.stats.nops_scheduled < list.stats.nops_linear,
            "list {} vs linear {} NOPs",
            list.stats.nops_scheduled,
            list.stats.nops_linear
        );
        assert!(list.stats.static_cycles_scheduled <= list.stats.static_cycles_linear);
        assert!(list.stats.static_cycles_linear <= list.stats.static_cycles_fenced);
        // Dynamic check: all three run hazard-free, same shared result,
        // ordered cycles.
        let (sl, sn, sf) = (run(&list, 16), run(&linear, 16), run(&fenced, 16));
        assert_eq!(sl.hazards, 0, "{}", list.asm);
        assert_eq!(sn.hazards, 0);
        assert_eq!(sf.hazards, 0);
        assert!(sl.cycles <= sn.cycles && sn.cycles <= sf.cycles);
    }

    #[test]
    fn deep_machines_need_no_padding() {
        let mut b = KernelBuilder::new("t", 512, layout(), MemoryMode::Dp);
        let t = b.tdx();
        let x = b.lod(t, 0);
        let y = b.fadd(x, x);
        b.sto(y, t, 2048);
        b.stop();
        let c = b.finish(SchedMode::List).unwrap();
        assert_eq!(c.stats.nops_scheduled, 0, "{}", c.asm);
        assert_eq!(run(&c, 512).hazards, 0);
    }

    #[test]
    fn narrowed_ops_are_padded_exactly() {
        // [w1,d0] writer feeding a [w1,d0] reader: 6-cycle window, 1-cycle
        // writer => 5 pads in the linear form, and the machine agrees.
        let mut b = KernelBuilder::new("t", 512, layout(), MemoryMode::Dp);
        b.space(ThreadCtrl::MCU);
        let x = b.ldi(1);
        let y = b.add_u(x, x);
        let base = b.ldi(64);
        b.sto(y, base, 0);
        b.stop();
        let c = b.finish(SchedMode::Linear).unwrap();
        assert_eq!(c.stats.nops_linear as u64, REG_WINDOW - 1 + (REG_WINDOW - 1));
        assert_eq!(run(&c, 512).hazards, 0);
    }

    #[test]
    fn store_load_turnaround_and_loops_settle() {
        // A hardware loop whose body stores then reloads the same address:
        // the back-edge settle keeps every iteration hazard-free.
        let mut b = KernelBuilder::new("t", 16, layout(), MemoryMode::Dp);
        let t = b.tdx();
        let acc = b.ldi(0);
        b.init(4);
        b.label("body");
        b.sto(acc, t, 128);
        let r = b.lod(t, 128);
        b.add_u_into(acc, r, r);
        b.loop_("body");
        b.sto(acc, t, 256);
        b.stop();
        let c = b.finish(SchedMode::List).unwrap();
        let stats = run(&c, 16);
        assert_eq!(stats.hazards, 0, "{:?}\n{}", stats.hazard_samples, c.asm);
    }

    #[test]
    fn predicate_barriers_are_not_crossed() {
        // The ELSE arm's redefinition must stay in its arm; both arms
        // write the same destination register.
        let mut b = KernelBuilder::new("t", 32, layout(), MemoryMode::Dp);
        let t = b.tdx();
        let lim = b.ldi(16);
        b.if_cc(crate::isa::CondCode::Lt, crate::isa::TType::Uint, t, lim);
        let m = b.or_i(t, lim);
        b.else_();
        b.or_i_into(m, lim, lim);
        b.endif();
        b.sto(m, t, 64);
        b.stop();
        let c = b.finish(SchedMode::List).unwrap();
        let p = &c.program;
        // if ... else ... endif must appear in order in the lowered code.
        let pos = |op: crate::isa::Opcode| {
            p.instrs.iter().position(|i| i.op == op).unwrap()
        };
        let (i_if, i_else, i_end) = (
            pos(crate::isa::Opcode::If),
            pos(crate::isa::Opcode::Else),
            pos(crate::isa::Opcode::EndIf),
        );
        assert!(i_if < i_else && i_else < i_end);
        // Both Or instructions write the same physical register, one per arm.
        let ors: Vec<usize> = p
            .instrs
            .iter()
            .enumerate()
            .filter(|(_, i)| i.op == crate::isa::Opcode::Or)
            .map(|(k, _)| k)
            .collect();
        assert_eq!(ors.len(), 2);
        assert_eq!(p.instrs[ors[0]].rd, p.instrs[ors[1]].rd);
        assert!(i_if < ors[0] && ors[0] < i_else);
        assert!(i_else < ors[1] && ors[1] < i_end);
    }

    #[test]
    fn listing_reassembles_to_the_lowered_program() {
        let c = chain_with_filler(SchedMode::List);
        let p2 = assemble(&c.asm, layout()).unwrap();
        assert_eq!(c.program.instrs, p2.instrs, "\n{}", c.asm);
        assert_eq!(c.program.words, p2.words);
    }

    #[test]
    fn register_pressure_overflows_cleanly() {
        // 40 simultaneously-live values cannot fit 16 registers.
        let mut b = KernelBuilder::new("t", 16, WordLayout::for_regs(16), MemoryMode::Dp);
        let vs: Vec<_> = (0..40).map(|i| b.ldi(i)).collect();
        let mut acc = vs[0];
        for &v in &vs[1..] {
            acc = b.add_u(acc, v);
        }
        let base = b.ldi(64);
        b.sto(acc, base, 0);
        b.stop();
        assert!(b.finish(SchedMode::List).is_err());
    }

    #[test]
    fn subroutine_values_survive_the_call() {
        // A caller value used after the call must not share a register
        // with callee temps (the call-span rule).
        let mut b = KernelBuilder::new("t", 16, layout(), MemoryMode::Dp);
        let t = b.tdx();
        let keep = b.ldi(7);
        b.jsr("sub");
        let s = b.add_u(keep, t);
        b.sto(s, t, 300);
        b.stop();
        b.label("sub");
        // Callee temps that would otherwise be free to reuse keep's slot.
        let a = b.ldi(1);
        let bb = b.ldi(2);
        let cc = b.add_u(a, bb);
        b.sto(cc, t, 400);
        b.rts();
        let c = b.finish(SchedMode::List).unwrap();
        let stats = run(&c, 16);
        assert_eq!(stats.hazards, 0);
        // Thread 0 register holding s = 7 + 0.
        let mut cfg = EgpuConfig::benchmark(MemoryMode::Dp, false);
        cfg.dot_core = true;
        let mut m = Machine::new(cfg).unwrap();
        m.set_threads(16).unwrap();
        m.load_program(c.program.clone()).unwrap();
        m.run(1_000_000).unwrap();
        assert_eq!(m.shared().read(300).unwrap(), 7);
        assert_eq!(m.shared().read(400).unwrap(), 3);
    }

    #[test]
    fn narrow_selector_geometry_matches_machine_costs() {
        // Cost model vs machine: a [w4,dhalf] load on 512 threads charges
        // ceil(16*4... waves=16, lanes=4 => sel 64 => 16 cycles.
        let mut b = KernelBuilder::new("t", 512, layout(), MemoryMode::Dp);
        b.space(ThreadCtrl::new(WidthSel::Quarter4, DepthSel::Half));
        let t = b.tdx();
        let x = b.lod(t, 0);
        b.sto(x, t, 1024);
        b.full();
        b.stop();
        let c = b.finish(SchedMode::Linear).unwrap();
        let stats = run(&c, 512);
        assert_eq!(stats.hazards, 0);
        // static estimate must match the machine exactly for straight-line
        // programs: tdx(16) + lod(16) + pads + sto(64) + stop(1) + drain(8).
        assert_eq!(
            stats.cycles,
            c.stats.static_cycles_linear + crate::sim::PIPELINE_DEPTH
        );
    }
}
